"""Repo-root pytest config: make `compile.*` importable when the suite is
invoked as `pytest python/tests/` from the repository root (the Makefile's
`cd python && pytest tests/` path needs no help)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
