#!/usr/bin/env bash
# Profile-guided-optimization build of the `pfl` binary:
#
#   1. rebuild with -Cprofile-generate (instrumented),
#   2. drive the instrumented binary through the profile workload —
#      `pfl bench --smoke` (round engine + megafleet shard scale + the
#      event-queue and kernel microbenches) and two sim presets
#      (`megafleet` sync, `megafleet-async` buffered) so both the
#      timing-wheel scheduler and the sharded cohort engine get hot
#      profiles,
#   3. merge the raw profiles with llvm-profdata (located inside the
#      active rustc's sysroot — `rustup component add llvm-tools` if the
#      probe comes up empty),
#   4. rebuild with -Cprofile-use against the merged profile.
#
# Usage:
#   bench/run_pgo.sh                 # instrument → profile → rebuild
#   PGO_DIR=/tmp/pfl-pgo bench/run_pgo.sh   # override the profile dir
#
# The optimized binary lands at target/release/pfl (same path as a plain
# release build). Run `bench/compare.sh` afterwards to quantify the win —
# and only promote baselines recorded by the build configuration CI
# actually runs, or the regression gate will compare unlike with unlike.

set -euo pipefail
cd "$(dirname "$0")/.."

PGO_DIR="${PGO_DIR:-$(pwd)/bench/pgo-data}"
rm -rf "$PGO_DIR"
mkdir -p "$PGO_DIR"

# locate llvm-profdata: llvm-tools ships it inside the rustc sysroot
SYSROOT="$(rustc --print sysroot)"
PROFDATA="$(find "$SYSROOT" -name llvm-profdata -type f 2>/dev/null | head -n1)"
if [ -z "$PROFDATA" ]; then
  PROFDATA="$(command -v llvm-profdata || true)"
fi
if [ -z "$PROFDATA" ]; then
  echo "llvm-profdata not found — install it with:" >&2
  echo "  rustup component add llvm-tools" >&2
  exit 1
fi
echo "using $PROFDATA"

echo "== 1/4: instrumented build =="
RUSTFLAGS="-Cprofile-generate=$PGO_DIR" cargo build --release

echo "== 2/4: profile workload =="
PROFILE_OUT="$PGO_DIR/run-out"
mkdir -p "$PROFILE_OUT"
./target/release/pfl bench --smoke \
  --out "$PROFILE_OUT/BENCH_round.json" \
  --shard-out "$PROFILE_OUT/BENCH_shard.json" \
  --kernels-out "$PROFILE_OUT/BENCH_kernels.json"
./target/release/pfl sim --scenario megafleet --smoke \
  --out "$PROFILE_OUT/sim-megafleet"
./target/release/pfl sim --scenario megafleet-async --smoke \
  --out "$PROFILE_OUT/sim-megafleet-async"

echo "== 3/4: merge profiles =="
"$PROFDATA" merge -o "$PGO_DIR/merged.profdata" "$PGO_DIR"/*.profraw

echo "== 4/4: optimized rebuild =="
# touch the crate so cargo actually rebuilds under the new RUSTFLAGS
cargo clean --release -p pfl
RUSTFLAGS="-Cprofile-use=$PGO_DIR/merged.profdata -Cllvm-args=-pgo-warn-missing-function" \
  cargo build --release

echo
echo "PGO build complete: target/release/pfl"
echo "profiles: $PGO_DIR/merged.profdata"
echo "quantify: bench/compare.sh"
