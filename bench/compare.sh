#!/usr/bin/env bash
# Run every `pfl bench` section (round engine, megafleet shard scale,
# SIMD kernel microbench) and compare against the committed baselines.
#
# Usage:
#   bench/compare.sh            # full configuration
#   bench/compare.sh --smoke    # CI-sized configuration
#
# Outputs land in bench/out/ — committed baselines are never clobbered:
#   BENCH_round.json  BENCH_shard.json  BENCH_kernels.json  perf.md
#
# When committed BENCH_*.json baselines exist at the repo root, the run
# renders a delta-per-benchmark table (perf.md) and exits non-zero if a
# tracked headline number regressed by more than 10%. Without baselines
# it records current numbers only. Promote a good run to baseline with:
#   cp bench/out/BENCH_*.json .

set -euo pipefail
cd "$(dirname "$0")/.."

SMOKE=""
for arg in "$@"; do
  case "$arg" in
    --smoke) SMOKE="--smoke" ;;
    *) echo "unknown argument: $arg (only --smoke is accepted)" >&2; exit 2 ;;
  esac
done

OUT=bench/out
mkdir -p "$OUT"

cargo build --release

COMPARE=""
if ls BENCH_*.json >/dev/null 2>&1; then
  COMPARE="--compare ."
else
  echo "no committed BENCH_*.json baselines at the repo root —" \
       "recording current numbers only (no regression gate)"
fi

status=0
# shellcheck disable=SC2086  # SMOKE/COMPARE are intentionally word-split
./target/release/pfl bench $SMOKE $COMPARE \
  --out "$OUT/BENCH_round.json" \
  --shard-out "$OUT/BENCH_shard.json" \
  --kernels-out "$OUT/BENCH_kernels.json" \
  --perf-out "$OUT/perf.md" || status=$?

# show the delta table even when the gate failed (CI log + artifact)
if [ -f "$OUT/perf.md" ]; then
  echo
  cat "$OUT/perf.md"
fi

echo
echo "outputs in $OUT/  (promote to baseline: cp $OUT/BENCH_*.json .)"
exit "$status"
