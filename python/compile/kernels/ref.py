"""Pure-jnp correctness oracles for every Pallas kernel (L1).

Each kernel in this package has a reference implementation here written in
straight-line jax.numpy. The pytest suite (python/tests/) sweeps shapes and
dtypes with hypothesis and asserts `assert_allclose(kernel(...), ref(...))`.
The randomized compressors take their uniform variates as *explicit inputs*
so kernel and reference are compared on identical randomness.
"""

from __future__ import annotations

import jax.numpy as jnp


# --------------------------------------------------------------------------
# Fused logistic-regression gradient (the paper's convex experiments, §VII-A)
# --------------------------------------------------------------------------

def logreg_grad_ref(w, x, y, sw, l2):
    """Weighted L2-regularized logistic loss: gradient, value, #correct.

    Args:
      w:  f32[D]   parameter vector.
      x:  f32[M,D] design matrix (rows may be padding).
      y:  f32[M]   labels in {+1, -1}.
      sw: f32[M]   per-sample weights; padding rows carry weight 0.
      l2: f32[]    ridge coefficient (the paper uses L2 = 0.01).

    Returns (grad f32[D], loss f32[], correct f32[]), with
      loss = (1/W) Σ_j sw_j · log(1 + exp(-y_j x_jᵀw)) + (l2/2)‖w‖²,
      W = Σ_j sw_j.
    """
    z = x @ w                                     # f32[M]
    m = jnp.sum(sw)
    # log(1 + exp(-t)) computed stably as logaddexp(0, -t).
    losses = jnp.logaddexp(0.0, -y * z)
    loss = jnp.sum(sw * losses) / m + 0.5 * l2 * jnp.sum(w * w)
    # d/dz log(1 + exp(-y z)) = -y · σ(-y z) = -y / (1 + exp(y z)).
    coef = sw * (-y) / (1.0 + jnp.exp(y * z))
    grad = x.T @ coef / m + l2 * w
    correct = jnp.sum(sw * (z * y > 0).astype(jnp.float32))
    return grad, loss, correct


# --------------------------------------------------------------------------
# Tiled matmul (dense layers of the DNN models)
# --------------------------------------------------------------------------

def matmul_ref(a, b):
    """Plain f32 matmul oracle for the MXU-tiled Pallas kernel."""
    return jnp.matmul(a, b)


# --------------------------------------------------------------------------
# Natural compression (Horváth et al.) — unbiased, ω = 1/8
# --------------------------------------------------------------------------

def natural_compress_ref(x, u):
    """Stochastic rounding of |x| to the nearest powers of two.

    For x ≠ 0 with 2^e ≤ |x| < 2^{e+1}: round up to 2^{e+1} with probability
    (|x| − 2^e)/2^e, else down to 2^e; the sign is preserved and 0 maps to 0.
    `u ∈ [0,1)` supplies the randomness. E[C(x)] = x and
    E‖C(x) − x‖² ≤ (1/8)‖x‖² (Assumption 1 with ω = 1/8).
    """
    a = jnp.abs(x)
    e = jnp.floor(jnp.log2(jnp.where(a > 0, a, 1.0)))
    low = jnp.exp2(e)
    p_up = (a - low) / low                        # ∈ [0, 1)
    mag = jnp.where(u < p_up, 2.0 * low, low)
    return jnp.where(a > 0, jnp.sign(x) * mag, 0.0)


# --------------------------------------------------------------------------
# Random dithering / QSGD with s levels — unbiased
# --------------------------------------------------------------------------

def dither_ref(x, u, s):
    """QSGD-style random dithering against the ℓ2 norm.

    C(x)_i = ‖x‖₂ · sign(x_i) · ξ_i/s with ξ_i ∈ {⌊t⌋, ⌈t⌉}, t = s|x_i|/‖x‖₂,
    P(ξ = ⌈t⌉) = t − ⌊t⌋. Unbiased; ω ≤ min(d/s², √d/s).
    """
    norm = jnp.sqrt(jnp.sum(x * x))
    safe = jnp.where(norm > 0, norm, 1.0)
    t = s * jnp.abs(x) / safe
    lo = jnp.floor(t)
    level = lo + (u < (t - lo)).astype(x.dtype)
    out = norm * jnp.sign(x) * level / s
    return jnp.where(norm > 0, out, 0.0)


# --------------------------------------------------------------------------
# Aggregation step (Algorithm 1, ξ_k = 1 branch)
# --------------------------------------------------------------------------

def aggregation_step_ref(xi, avg, eta_lambda_np):
    """x_i ← x_i − (ηλ/np)(x_i − avg): the L2GD aggregation update."""
    return xi - eta_lambda_np * (xi - avg)
