"""L1 Pallas kernels: the paper's unbiased compressors as on-device math.

The wire formats live in the Rust coordinator (rust/src/compress/), but the
*numerics* of natural compression and QSGD random dithering are validated
here against ref.py, on explicit uniform variates, so L3's codecs and L1's
kernels provably implement the same operator (Assumption 1).

Both kernels are pure VPU work (elementwise exponent/mantissa manipulation,
8×128 lanes); they tile a flattened vector into (BLOCK,) chunks. The dither
kernel needs the global ℓ2 norm, which is computed by a first fused pass
(jnp) and broadcast to every block — the two-pass structure matches how a
real TPU implementation would schedule it (norm reduce, then quantize).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 1024


def _natural_kernel(x_ref, u_ref, o_ref):
    x = x_ref[...]
    u = u_ref[...]
    a = jnp.abs(x)
    e = jnp.floor(jnp.log2(jnp.where(a > 0, a, 1.0)))
    low = jnp.exp2(e)
    p_up = (a - low) / low
    mag = jnp.where(u < p_up, 2.0 * low, low)
    o_ref[...] = jnp.where(a > 0, jnp.sign(x) * mag, 0.0)


@functools.partial(jax.jit, static_argnames=("block",))
def natural_compress(x, u, block: int = DEFAULT_BLOCK):
    """Natural compression C_nat; mirrors `ref.natural_compress_ref`."""
    (d,) = x.shape
    b = min(block, d)
    pad = (-d) % b
    if pad:
        x = jnp.pad(x, (0, pad))
        u = jnp.pad(u, (0, pad))
    out = pl.pallas_call(
        _natural_kernel,
        grid=((d + pad) // b,),
        in_specs=[
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d + pad,), jnp.float32),
        interpret=True,
    )(x, u)
    return out[:d]


def _dither_kernel(x_ref, u_ref, norm_ref, s_ref, o_ref):
    x = x_ref[...]
    u = u_ref[...]
    norm = norm_ref[0]
    s = s_ref[0]
    safe = jnp.where(norm > 0, norm, 1.0)
    t = s * jnp.abs(x) / safe
    lo = jnp.floor(t)
    level = lo + (u < (t - lo)).astype(x.dtype)
    out = norm * jnp.sign(x) * level / s
    o_ref[...] = jnp.where(norm > 0, out, 0.0)


@functools.partial(jax.jit, static_argnames=("block",))
def dither(x, u, s, block: int = DEFAULT_BLOCK):
    """QSGD random dithering with s levels; mirrors `ref.dither_ref`."""
    (d,) = x.shape
    b = min(block, d)
    pad = (-d) % b
    norm = jnp.sqrt(jnp.sum(x * x))[None]          # pass 1: global reduce
    s_arr = jnp.asarray(s, jnp.float32)[None]
    if pad:
        x = jnp.pad(x, (0, pad))
        u = jnp.pad(u, (0, pad))
    out = pl.pallas_call(
        _dither_kernel,
        grid=((d + pad) // b,),
        in_specs=[
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),    # norm: broadcast
            pl.BlockSpec((1,), lambda i: (0,)),    # s: broadcast
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d + pad,), jnp.float32),
        interpret=True,
    )(x, u, norm, s_arr)
    return out[:d]
