"""L1 Pallas kernel: MXU-tiled matmul used by the dense layers of the L2 zoo.

The GPU original would tile with threadblocks + shared memory; on TPU the
BlockSpec index maps express the HBM↔VMEM schedule directly: grid
(M/BM, N/BN, K/BK) with the K axis innermost so each (BM, BN) output block
stays resident in VMEM while K-slabs of A and B stream through. The output
block doubles as the accumulator (`@pl.when`-guarded init on the first K
step), which is the Pallas idiom for the MXU's accumulate-in-place.

interpret=True: CPU PJRT cannot run Mosaic custom-calls; the same code path
is what `aot.py` lowers into the artifacts the Rust runtime executes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 128×128×128 tiles fill the MXU systolic array; VMEM working set is
# (BM·BK + BK·BN + BM·BN)·4B = 192 KiB ≪ 16 MiB, leaving room for
# double-buffered prefetch of the next K slab.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += a_ref[...] @ b_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(a, b, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN, bk: int = DEFAULT_BK):
    """Tiled `a @ b` for f32[M,K] × f32[K,N]; mirrors `ref.matmul_ref`.

    Inputs are zero-padded up to tile multiples; padding contributes zeros to
    the accumulation and is sliced away from the result.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims disagree: {k} vs {k2}"
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))

    out = pl.pallas_call(
        _kernel,
        grid=((m + pm) // bm, (n + pn) // bn, (k + pk) // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m + pm, n + pn), jnp.float32),
        interpret=True,
    )(a, b)
    return out[:m, :n]


# --------------------------------------------------------------------------
# Differentiable wrapper — backward pass also runs on the tiled kernel, so
# both fwd and bwd matmuls of every dense layer lower through Pallas into
# the AOT artifacts.
# --------------------------------------------------------------------------

@jax.custom_vjp
def pmatmul(a, b):
    """`a @ b` on the Pallas kernel, differentiable w.r.t. both operands."""
    return matmul(a, b)


def _pmatmul_fwd(a, b):
    return matmul(a, b), (a, b)


def _pmatmul_bwd(res, g):
    a, b = res
    # dA = g @ Bᵀ, dB = Aᵀ @ g — same kernel, transposed tiles.
    return matmul(g, b.T), matmul(a.T, g)


pmatmul.defvjp(_pmatmul_fwd, _pmatmul_bwd)
