"""L1 Pallas kernels (build-time only; never imported at runtime).

All kernels run with interpret=True so that AOT lowering produces plain HLO
the CPU PJRT client can execute (real-TPU Mosaic lowering is compile-only in
this environment; see DESIGN.md §Hardware-Adaptation).
"""

from .fused_logreg import logreg_grad
from .matmul import matmul, pmatmul
from .quantize import dither, natural_compress

__all__ = ["logreg_grad", "matmul", "pmatmul", "natural_compress", "dither"]
