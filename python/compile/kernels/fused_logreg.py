"""L1 Pallas kernel: fused logistic-regression gradient.

This is the compute hot-spot of the paper's convex experiments (§VII-A):
every local step of L2GD evaluates the *full* local gradient

    ∇f_i(w) = (1/W) Xᵀ (sw ⊙ (−y) ⊙ σ(−y ⊙ Xw)) + L₂ w

over the device's shard. A naive implementation runs three separate HBM
passes (X@w, the elementwise residual, Xᵀ@coef). The kernel below fuses all
three into a single tiled pass over X: each grid step streams one (BM, D)
tile of X into VMEM, forms the logits and residual coefficients in-register,
and accumulates both the D-wide gradient partial and the scalar loss/correct
partials into VMEM-resident outputs — one HBM read of X per gradient.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the (BM×D)·(D×1) and
(D×BM)·(BM×1) contractions are MXU-shaped; the residual math is VPU lanes.
interpret=True is mandatory here — the CPU PJRT client cannot execute Mosaic
custom-calls — so the same code lowers to plain HLO for the Rust runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default row-tile. 128 keeps the VMEM working set ≈ BM·D·4B ≤ 64 KiB for
# d ≤ 128 and matches the MXU systolic edge.
DEFAULT_BM = 128


def _kernel(w_ref, x_ref, y_ref, sw_ref, grad_ref, loss_ref, corr_ref):
    """One (BM, D) tile: accumulate unnormalized grad/loss/correct sums."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        grad_ref[...] = jnp.zeros_like(grad_ref)
        loss_ref[...] = jnp.zeros_like(loss_ref)
        corr_ref[...] = jnp.zeros_like(corr_ref)

    x = x_ref[...]                                # f32[BM, D]
    y = y_ref[...]                                # f32[BM]
    sw = sw_ref[...]                              # f32[BM]
    w = w_ref[...]                                # f32[D]

    z = x @ w                                     # MXU: (BM,D)·(D,)
    yz = y * z
    losses = jnp.logaddexp(0.0, -yz)              # stable log(1+e^{-yz})
    coef = sw * (-y) / (1.0 + jnp.exp(yz))        # VPU elementwise

    grad_ref[...] += coef @ x                     # MXU: (BM,)·(BM,D)
    loss_ref[...] += jnp.sum(sw * losses)[None]
    corr_ref[...] += jnp.sum(sw * (yz > 0.0).astype(jnp.float32))[None]


@functools.partial(jax.jit, static_argnames=("block_m",))
def logreg_grad(w, x, y, sw, l2, block_m: int = DEFAULT_BM):
    """Fused weighted logistic gradient; mirrors `ref.logreg_grad_ref`.

    Shapes: w f32[D], x f32[M,D], y f32[M] (±1), sw f32[M], l2 f32[].
    Returns (grad f32[D], loss f32[], correct f32[]).
    """
    m, d = x.shape
    bm = min(block_m, max(8, m))
    pad = (-m) % bm
    if pad:
        # Zero-weight padding rows contribute nothing to any accumulator.
        x = jnp.pad(x, ((0, pad), (0, 0)))
        y = jnp.pad(y, (0, pad))
        sw = jnp.pad(sw, (0, pad))
    mp = m + pad

    grad_sum, loss_sum, corr = pl.pallas_call(
        _kernel,
        grid=(mp // bm,),
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),       # w: resident
            pl.BlockSpec((bm, d), lambda i: (i, 0)),  # x: streamed tiles
            pl.BlockSpec((bm,), lambda i: (i,)),      # y
            pl.BlockSpec((bm,), lambda i: (i,)),      # sw
        ],
        out_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),       # grad accumulator
            pl.BlockSpec((1,), lambda i: (0,)),       # loss accumulator
            pl.BlockSpec((1,), lambda i: (0,)),       # correct accumulator
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=True,
    )(w, x, y, sw)

    total_w = jnp.sum(sw)
    grad = grad_sum / total_w + l2 * w
    loss = loss_sum[0] / total_w + 0.5 * l2 * jnp.sum(w * w)
    return grad, loss, corr[0]
