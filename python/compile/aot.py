"""AOT compile path: lower the L2 model zoo to HLO text + manifest.

Run once via ``make artifacts``. For every model in ``model.default_zoo()``
this writes:

  artifacts/<name>.grad.hlo.txt   — (theta, *batch) -> (grad, loss, correct)
  artifacts/<name>.eval.hlo.txt   — (theta, *batch) -> (loss, correct)
  artifacts/<name>.init.bin       — raw little-endian f32[P] initial params
  artifacts/manifest.json         — index the Rust runtime loads

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
≥ 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
Functions are lowered with ``return_tuple=True``; the Rust side unwraps the
tuple with ``Literal::to_tuple``.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import ModelDef, default_zoo

_DTYPE_NAMES = {"float32": "f32", "int32": "i32"}


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(structs) -> list:
    out = []
    for s in structs:
        name = _DTYPE_NAMES.get(str(s.dtype), str(s.dtype))
        out.append({"shape": list(s.shape), "dtype": name})
    return out


def lower_model(md: ModelDef, out_dir: str, seed: int = 0) -> dict:
    """Lower one model's grad + eval and write its artifacts."""
    theta_s = jax.ShapeDtypeStruct((md.param_count,), "float32")

    entry = {
        "name": md.name,
        "family": md.family,
        "param_count": md.param_count,
        "meta": md.meta,
        "init": f"{md.name}.init.bin",
    }
    for fn_name, fn, args in (("grad", md.grad_fn, md.grad_args),
                              ("eval", md.eval_fn, md.eval_args)):
        lowered = jax.jit(fn).lower(theta_s, *args)
        text = to_hlo_text(lowered)
        fname = f"{md.name}.{fn_name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        n_out = 3 if fn_name == "grad" else 2
        entry[fn_name] = {
            "hlo": fname,
            "inputs": _sig((theta_s, *args)),
            "num_outputs": n_out,
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        }

    init = md.spec.init_flat(seed)
    assert init.size == md.param_count
    with open(os.path.join(out_dir, f"{md.name}.init.bin"), "wb") as f:
        f.write(init.astype("<f4").tobytes())
    return entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--seed", type=int, default=0,
                    help="init-parameter seed (recorded in the manifest)")
    ap.add_argument("--only", default=None,
                    help="comma-separated model names (default: full zoo)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    zoo = default_zoo()
    if args.only:
        keep = set(args.only.split(","))
        zoo = [m for m in zoo if m.name in keep]

    entries = []
    for md in zoo:
        print(f"lowering {md.name} (P={md.param_count}) ...", flush=True)
        entries.append(lower_model(md, args.out, seed=args.seed))

    manifest = {"version": 1, "seed": args.seed, "models": entries}
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(entries)} models to {args.out}/manifest.json")


if __name__ == "__main__":
    main()
