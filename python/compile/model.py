"""L2: the model zoo, defined over a single flat f32[P] parameter vector.

Every model in the paper's experiments is represented here:

  - ``logreg``           — §VII-A convex experiments (a1a/a2a-style data);
                           its gradient is the fused Pallas kernel directly.
  - ``mlp``              — small nonconvex baseline.
  - ``resnet_tiny``      — residual blocks (the paper's ResNet-18/56 family).
  - ``densenet_tiny``    — dense concatenation blocks (DenseNet-121 family).
  - ``mobilenet_tiny``   — depthwise-separable blocks (MobileNet family).
  - ``transformer_tiny`` — causal LM for the end-to-end driver example.

The flat-vector convention mirrors the paper's formulation (each device owns
x_i ∈ R^d) and makes the Rust side uniform: a client model is a Vec<f32>
that the compressors/aggregator operate on directly. All dense layers run
through the Pallas ``pmatmul`` kernel (fwd *and* bwd), so the L1 kernels lower
into the very HLO artifacts the Rust runtime executes; convolutions stay at
the lax level (their tiling is XLA's job on every backend).

This module is build-time only: ``aot.py`` lowers each model's ``grad`` and
``eval`` functions to HLO text once, and Python never runs on the training
path.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import logreg_grad, pmatmul


# ===========================================================================
# Flat-parameter machinery
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Layout of a flat parameter vector: ordered (name, shape, init) slots.

    ``init`` is one of: "zeros", "he", "glorot", "embed", "ones".
    """

    slots: Tuple[Tuple[str, Tuple[int, ...], str], ...]

    @property
    def sizes(self) -> List[int]:
        return [int(np.prod(s)) for _, s, _ in self.slots]

    @property
    def param_count(self) -> int:
        return sum(self.sizes)

    def unpack(self, theta: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        """Split f32[P] into named, shaped arrays (pure slicing: free in XLA)."""
        out = {}
        off = 0
        for (name, shape, _), size in zip(self.slots, self.sizes):
            out[name] = theta[off:off + size].reshape(shape)
            off += size
        return out

    def init_flat(self, seed: int) -> np.ndarray:
        """Properly scaled initial parameters as a flat numpy vector."""
        rng = np.random.default_rng(seed)
        parts = []
        for name, shape, init in self.slots:
            n = int(np.prod(shape))
            if init == "zeros":
                parts.append(np.zeros(n, np.float32))
            elif init == "ones":
                parts.append(np.ones(n, np.float32))
            elif init == "embed":
                parts.append(rng.normal(0.0, 0.02, n).astype(np.float32))
            else:
                fan_in, fan_out = _fans(shape)
                if init == "he":
                    std = math.sqrt(2.0 / fan_in)
                else:  # glorot
                    std = math.sqrt(2.0 / (fan_in + fan_out))
                parts.append(rng.normal(0.0, std, n).astype(np.float32))
        return np.concatenate(parts) if parts else np.zeros(0, np.float32)


def _fans(shape: Sequence[int]) -> Tuple[int, int]:
    if len(shape) == 1:
        return shape[0], shape[0]
    if len(shape) == 2:
        return shape[0], shape[1]
    # conv HWIO: receptive field × channels
    rf = int(np.prod(shape[:-2]))
    return rf * shape[-2], rf * shape[-1]


@dataclasses.dataclass(frozen=True)
class ModelDef:
    """Everything aot.py needs to lower one model.

    ``grad_args`` / ``eval_args`` are ShapeDtypeStructs *excluding* theta
    (which is always the first argument, f32[P]).
    """

    name: str
    family: str
    spec: ParamSpec
    grad_fn: Callable            # (theta, *batch) -> (grad, loss, correct)
    eval_fn: Callable            # (theta, *batch) -> (loss_sum, correct)
    grad_args: Tuple[jax.ShapeDtypeStruct, ...]
    eval_args: Tuple[jax.ShapeDtypeStruct, ...]
    meta: Dict

    @property
    def param_count(self) -> int:
        return self.spec.param_count


# ===========================================================================
# Logistic regression (convex; §VII-A)
# ===========================================================================

def make_logreg(name: str, dim: int, batch: int, eval_batch: int,
                l2: float = 0.01) -> ModelDef:
    """Binary logistic regression with ridge; gradient = fused Pallas kernel.

    The batch carries explicit sample weights so one static-shape executable
    serves any shard size ≤ batch (padding rows get weight 0) — this is how
    the a1a (321/worker) and a2a (453/worker) shards share artifacts.
    """
    spec = ParamSpec((("w", (dim,), "zeros"),))

    def grad_fn(theta, x, y, sw):
        g, loss, correct = logreg_grad(theta, x, y, sw, jnp.float32(l2))
        return g, loss, correct

    def eval_fn(theta, x, y, sw):
        z = x @ theta
        losses = jnp.logaddexp(0.0, -y * z)
        m = jnp.sum(sw)
        loss = jnp.sum(sw * losses) / m + 0.5 * l2 * jnp.sum(theta * theta)
        correct = jnp.sum(sw * (z * y > 0).astype(jnp.float32))
        return loss, correct

    f32 = jnp.float32
    grad_args = (
        jax.ShapeDtypeStruct((batch, dim), f32),
        jax.ShapeDtypeStruct((batch,), f32),
        jax.ShapeDtypeStruct((batch,), f32),
    )
    eval_args = (
        jax.ShapeDtypeStruct((eval_batch, dim), f32),
        jax.ShapeDtypeStruct((eval_batch,), f32),
        jax.ShapeDtypeStruct((eval_batch,), f32),
    )
    meta = {"input_dim": dim, "num_classes": 2, "train_batch": batch,
            "eval_batch": eval_batch, "l2": l2, "kind": "logreg"}
    return ModelDef(name, "logreg", spec, grad_fn, eval_fn,
                    grad_args, eval_args, meta)


# ===========================================================================
# Shared pieces for the classifier zoo
# ===========================================================================

def _xent_and_correct(logits: jnp.ndarray, labels: jnp.ndarray):
    """Mean cross-entropy + #correct for int labels."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == labels)
                      .astype(jnp.float32))
    return jnp.mean(nll), correct


def _dense(p: Dict[str, jnp.ndarray], name: str, x: jnp.ndarray):
    """Dense layer on the Pallas matmul kernel (differentiable)."""
    return pmatmul(x, p[f"{name}.w"]) + p[f"{name}.b"]


def _conv(p, name, x, stride=1, groups=1):
    return jax.lax.conv_general_dilated(
        x, p[f"{name}.w"], window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    ) + p[f"{name}.b"]


def _classifier_modeldef(name, family, spec, forward, batch, eval_batch,
                         image_hw, channels, num_classes, weight_decay):
    """Wrap a forward(params, images)->logits into grad/eval ModelDef."""

    def loss_fn(theta, x, y):
        p = spec.unpack(theta)
        logits = forward(p, x)
        loss, correct = _xent_and_correct(logits, y)
        if weight_decay > 0.0:
            loss = loss + 0.5 * weight_decay * jnp.sum(theta * theta)
        return loss, correct

    def grad_fn(theta, x, y):
        (loss, correct), g = jax.value_and_grad(loss_fn, has_aux=True)(
            theta, x, y)
        return g, loss, correct

    def eval_fn(theta, x, y):
        return loss_fn(theta, x, y)

    f32, i32 = jnp.float32, jnp.int32
    h, w = image_hw
    grad_args = (jax.ShapeDtypeStruct((batch, h, w, channels), f32),
                 jax.ShapeDtypeStruct((batch,), i32))
    eval_args = (jax.ShapeDtypeStruct((eval_batch, h, w, channels), f32),
                 jax.ShapeDtypeStruct((eval_batch,), i32))
    meta = {"image_hw": list(image_hw), "channels": channels,
            "num_classes": num_classes, "train_batch": batch,
            "eval_batch": eval_batch, "l2": weight_decay, "kind": "image"}
    return ModelDef(name, family, spec, grad_fn, eval_fn,
                    grad_args, eval_args, meta)


# ===========================================================================
# MLP
# ===========================================================================

def make_mlp(name: str, dim: int, hidden: int, num_classes: int,
             batch: int, eval_batch: int, weight_decay: float = 0.0) -> ModelDef:
    spec = ParamSpec((
        ("fc1.w", (dim, hidden), "he"), ("fc1.b", (hidden,), "zeros"),
        ("fc2.w", (hidden, num_classes), "glorot"),
        ("fc2.b", (num_classes,), "zeros"),
    ))

    def loss_fn(theta, x, y):
        p = spec.unpack(theta)
        h = jax.nn.relu(_dense(p, "fc1", x))
        logits = _dense(p, "fc2", h)
        loss, correct = _xent_and_correct(logits, y)
        if weight_decay > 0.0:
            loss = loss + 0.5 * weight_decay * jnp.sum(theta * theta)
        return loss, correct

    def grad_fn(theta, x, y):
        (loss, correct), g = jax.value_and_grad(loss_fn, has_aux=True)(
            theta, x, y)
        return g, loss, correct

    f32, i32 = jnp.float32, jnp.int32
    grad_args = (jax.ShapeDtypeStruct((batch, dim), f32),
                 jax.ShapeDtypeStruct((batch,), i32))
    eval_args = (jax.ShapeDtypeStruct((eval_batch, dim), f32),
                 jax.ShapeDtypeStruct((eval_batch,), i32))
    meta = {"input_dim": dim, "num_classes": num_classes,
            "train_batch": batch, "eval_batch": eval_batch,
            "l2": weight_decay, "kind": "flat"}
    return ModelDef(name, "mlp", spec, grad_fn, loss_fn,
                    grad_args, eval_args, meta)


# ===========================================================================
# ResNet-tiny — residual adds, the ResNet-18/56 architectural signature
# ===========================================================================

def make_resnet_tiny(name: str = "resnet_tiny", hw: int = 16, c0: int = 8,
                     num_classes: int = 10, batch: int = 32,
                     eval_batch: int = 256) -> ModelDef:
    c1 = 2 * c0
    slots = [
        ("stem.w", (3, 3, 3, c0), "he"), ("stem.b", (c0,), "zeros"),
        # residual block 1 (c0 → c0)
        ("b1c1.w", (3, 3, c0, c0), "he"), ("b1c1.b", (c0,), "zeros"),
        ("b1c2.w", (3, 3, c0, c0), "he"), ("b1c2.b", (c0,), "zeros"),
        # downsample + widen
        ("down.w", (3, 3, c0, c1), "he"), ("down.b", (c1,), "zeros"),
        # residual block 2 (c1 → c1)
        ("b2c1.w", (3, 3, c1, c1), "he"), ("b2c1.b", (c1,), "zeros"),
        ("b2c2.w", (3, 3, c1, c1), "he"), ("b2c2.b", (c1,), "zeros"),
        ("head.w", (c1, num_classes), "glorot"),
        ("head.b", (num_classes,), "zeros"),
    ]
    spec = ParamSpec(tuple(slots))

    def forward(p, x):
        h = jax.nn.relu(_conv(p, "stem", x))
        r = jax.nn.relu(_conv(p, "b1c1", h))
        h = jax.nn.relu(h + _conv(p, "b1c2", r))          # residual add
        h = jax.nn.relu(_conv(p, "down", h, stride=2))
        r = jax.nn.relu(_conv(p, "b2c1", h))
        h = jax.nn.relu(h + _conv(p, "b2c2", r))          # residual add
        h = jnp.mean(h, axis=(1, 2))                      # global avg pool
        return _dense(p, "head", h)

    return _classifier_modeldef(name, "resnet", spec, forward, batch,
                                eval_batch, (hw, hw), 3, num_classes, 0.0)


# ===========================================================================
# DenseNet-tiny — feature concatenation, the DenseNet-121 signature
# ===========================================================================

def make_densenet_tiny(name: str = "densenet_tiny", hw: int = 16,
                       c0: int = 8, growth: int = 6, layers: int = 4,
                       num_classes: int = 10, batch: int = 32,
                       eval_batch: int = 256) -> ModelDef:
    slots = [("stem.w", (3, 3, 3, c0), "he"), ("stem.b", (c0,), "zeros")]
    cin = c0
    for i in range(layers):
        slots += [(f"d{i}.w", (3, 3, cin, growth), "he"),
                  (f"d{i}.b", (growth,), "zeros")]
        cin += growth                                     # concat grows width
    slots += [("trans.w", (1, 1, cin, 2 * c0), "he"),
              ("trans.b", (2 * c0,), "zeros"),
              ("head.w", (2 * c0, num_classes), "glorot"),
              ("head.b", (num_classes,), "zeros")]
    spec = ParamSpec(tuple(slots))

    def forward(p, x):
        h = jax.nn.relu(_conv(p, "stem", x))
        for i in range(layers):
            new = jax.nn.relu(_conv(p, f"d{i}", h))
            h = jnp.concatenate([h, new], axis=-1)        # dense connectivity
        h = jax.nn.relu(_conv(p, "trans", h))             # 1×1 transition
        h = jnp.mean(h, axis=(1, 2))
        return _dense(p, "head", h)

    return _classifier_modeldef(name, "densenet", spec, forward, batch,
                                eval_batch, (hw, hw), 3, num_classes, 0.0)


# ===========================================================================
# MobileNet-tiny — depthwise-separable convs, the MobileNet signature
# ===========================================================================

def make_mobilenet_tiny(name: str = "mobilenet_tiny", hw: int = 16,
                        c0: int = 8, num_classes: int = 10, batch: int = 32,
                        eval_batch: int = 256) -> ModelDef:
    c1 = 2 * c0
    slots = [("stem.w", (3, 3, 3, c0), "he"), ("stem.b", (c0,), "zeros")]
    # two depthwise-separable blocks: dw 3×3 (per-channel) + pw 1×1
    blocks = [("s1", c0, c0, 1), ("s2", c0, c1, 2), ("s3", c1, c1, 1)]
    for bname, ci, co, _ in blocks:
        slots += [(f"{bname}dw.w", (3, 3, 1, ci), "he"),
                  (f"{bname}dw.b", (ci,), "zeros"),
                  (f"{bname}pw.w", (1, 1, ci, co), "he"),
                  (f"{bname}pw.b", (co,), "zeros")]
    slots += [("head.w", (c1, num_classes), "glorot"),
              ("head.b", (num_classes,), "zeros")]
    spec = ParamSpec(tuple(slots))

    def forward(p, x):
        h = jax.nn.relu(_conv(p, "stem", x))
        for bname, ci, _co, stride in blocks:
            h = jax.nn.relu(_conv(p, f"{bname}dw", h, stride=stride,
                                  groups=ci))             # depthwise
            h = jax.nn.relu(_conv(p, f"{bname}pw", h))    # pointwise 1×1
        h = jnp.mean(h, axis=(1, 2))
        return _dense(p, "head", h)

    return _classifier_modeldef(name, "mobilenet", spec, forward, batch,
                                eval_batch, (hw, hw), 3, num_classes, 0.0)


# ===========================================================================
# Transformer-tiny — causal LM for the end-to-end driver
# ===========================================================================

def make_transformer_tiny(name: str = "transformer_tiny", vocab: int = 256,
                          seq: int = 32, d_model: int = 64, heads: int = 2,
                          layers: int = 2, d_ff: int = 128, batch: int = 16,
                          eval_batch: int = 64) -> ModelDef:
    slots = [("embed", (vocab, d_model), "embed"),
             ("pos", (seq, d_model), "embed")]
    for i in range(layers):
        slots += [
            (f"l{i}.ln1.g", (d_model,), "ones"), (f"l{i}.ln1.b", (d_model,), "zeros"),
            (f"l{i}.qkv.w", (d_model, 3 * d_model), "glorot"),
            (f"l{i}.qkv.b", (3 * d_model,), "zeros"),
            (f"l{i}.proj.w", (d_model, d_model), "glorot"),
            (f"l{i}.proj.b", (d_model,), "zeros"),
            (f"l{i}.ln2.g", (d_model,), "ones"), (f"l{i}.ln2.b", (d_model,), "zeros"),
            (f"l{i}.ff1.w", (d_model, d_ff), "he"), (f"l{i}.ff1.b", (d_ff,), "zeros"),
            (f"l{i}.ff2.w", (d_ff, d_model), "glorot"), (f"l{i}.ff2.b", (d_model,), "zeros"),
        ]
    slots += [("lnf.g", (d_model,), "ones"), ("lnf.b", (d_model,), "zeros"),
              ("unembed.w", (d_model, vocab), "glorot"),
              ("unembed.b", (vocab,), "zeros")]
    spec = ParamSpec(tuple(slots))
    hd = d_model // heads

    def _ln(g, b, x):
        mu = jnp.mean(x, -1, keepdims=True)
        var = jnp.var(x, -1, keepdims=True)
        return g * (x - mu) * jax.lax.rsqrt(var + 1e-5) + b

    def _mm(x2d, w):
        # all projections run through the Pallas kernel
        return pmatmul(x2d, w)

    def forward(p, tokens):
        """tokens i32[B, seq+1]: input = [:, :seq], target = [:, 1:]."""
        b = tokens.shape[0]
        inp = tokens[:, :seq]
        tgt = tokens[:, 1:]
        h = p["embed"][inp] + p["pos"][None, :, :]
        mask = jnp.tril(jnp.ones((seq, seq), jnp.float32))
        for i in range(layers):
            x = _ln(p[f"l{i}.ln1.g"], p[f"l{i}.ln1.b"], h)
            qkv = (_mm(x.reshape(b * seq, d_model), p[f"l{i}.qkv.w"])
                   + p[f"l{i}.qkv.b"]).reshape(b, seq, 3, heads, hd)
            q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
            att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(hd)
            att = jnp.where(mask[None, None] > 0, att, -1e9)
            att = jax.nn.softmax(att, axis=-1)
            o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, seq, d_model)
            o = (_mm(o.reshape(b * seq, d_model), p[f"l{i}.proj.w"])
                 + p[f"l{i}.proj.b"]).reshape(b, seq, d_model)
            h = h + o
            x = _ln(p[f"l{i}.ln2.g"], p[f"l{i}.ln2.b"], h)
            f = jax.nn.relu(_mm(x.reshape(b * seq, d_model), p[f"l{i}.ff1.w"])
                            + p[f"l{i}.ff1.b"])
            f = (_mm(f, p[f"l{i}.ff2.w"])
                 + p[f"l{i}.ff2.b"]).reshape(b, seq, d_model)
            h = h + f
        h = _ln(p["lnf.g"], p["lnf.b"], h)
        logits = (_mm(h.reshape(b * seq, d_model), p["unembed.w"])
                  + p["unembed.b"])
        logp = jax.nn.log_softmax(logits, axis=-1)
        flat_tgt = tgt.reshape(b * seq)
        nll = -jnp.take_along_axis(logp, flat_tgt[:, None], axis=-1)[:, 0]
        correct = jnp.sum((jnp.argmax(logits, -1) == flat_tgt)
                          .astype(jnp.float32))
        return jnp.mean(nll), correct

    def loss_fn(theta, tokens):
        p = spec.unpack(theta)
        return forward(p, tokens)

    def grad_fn(theta, tokens):
        (loss, correct), g = jax.value_and_grad(loss_fn, has_aux=True)(
            theta, tokens)
        return g, loss, correct

    i32 = jnp.int32
    grad_args = (jax.ShapeDtypeStruct((batch, seq + 1), i32),)
    eval_args = (jax.ShapeDtypeStruct((eval_batch, seq + 1), i32),)
    meta = {"vocab": vocab, "seq": seq, "d_model": d_model,
            "num_classes": vocab, "train_batch": batch,
            "eval_batch": eval_batch, "l2": 0.0, "kind": "lm",
            "tokens_per_sample": seq}
    return ModelDef(name, "transformer", spec, grad_fn, loss_fn,
                    grad_args, eval_args, meta)


# ===========================================================================
# The zoo lowered by aot.py
# ===========================================================================

def default_zoo() -> List[ModelDef]:
    """Model instances covering every experiment in DESIGN.md §6."""
    return [
        # §VII-A convex: a1a-like (d=123, 321 rows/worker) and a2a-like
        # (453 rows/worker) share one 512-row weighted executable.
        make_logreg("logreg123", dim=123, batch=512, eval_batch=2048),
        make_mlp("mlp_synth", dim=64, hidden=64, num_classes=10,
                 batch=32, eval_batch=256),
        make_resnet_tiny(),
        make_densenet_tiny(),
        make_mobilenet_tiny(),
        make_transformer_tiny(),
    ]
