"""L1 correctness: every Pallas kernel vs its pure-jnp oracle (ref.py).

Hypothesis sweeps shapes (including tile-edge / non-divisible cases) and the
randomized compressors are compared on *identical* uniform variates.
This suite is the core correctness signal for the AOT artifacts: the same
kernels lower into the HLO the Rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dither, logreg_grad, matmul, natural_compress, pmatmul
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# fused logistic gradient
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 400),
    d=st.integers(1, 200),
    l2=st.sampled_from([0.0, 0.01, 1.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_logreg_grad_matches_ref(m, d, l2, seed):
    r = _rng(seed)
    x = jnp.asarray(r.normal(size=(m, d)).astype(np.float32))
    y = jnp.asarray(np.where(r.random(m) < 0.5, 1.0, -1.0).astype(np.float32))
    sw = jnp.asarray((r.random(m) < 0.8).astype(np.float32))
    if float(jnp.sum(sw)) == 0.0:
        sw = sw.at[0].set(1.0)
    w = jnp.asarray(r.normal(scale=0.3, size=(d,)).astype(np.float32))

    g_k, l_k, c_k = logreg_grad(w, x, y, sw, jnp.float32(l2))
    g_r, l_r, c_r = ref.logreg_grad_ref(w, x, y, sw, l2)
    np.testing.assert_allclose(g_k, g_r, rtol=5e-5, atol=1e-5)
    np.testing.assert_allclose(l_k, l_r, rtol=5e-5, atol=1e-6)
    assert float(c_k) == float(c_r)


def test_logreg_grad_padding_rows_are_inert():
    """Zero-weight rows (static-shape padding) must not change the result."""
    r = _rng(7)
    x = jnp.asarray(r.normal(size=(100, 30)).astype(np.float32))
    y = jnp.sign(jnp.asarray(r.normal(size=(100,)).astype(np.float32)) + 0.1)
    w = jnp.asarray(r.normal(size=(30,)).astype(np.float32))
    sw = jnp.ones(100)
    g1, l1, c1 = logreg_grad(w, x, y, sw, jnp.float32(0.01))

    pad_x = jnp.concatenate([x, 1e3 * jnp.ones((28, 30))])
    pad_y = jnp.concatenate([y, jnp.ones(28)])
    pad_sw = jnp.concatenate([sw, jnp.zeros(28)])
    g2, l2_, c2 = logreg_grad(w, pad_x, pad_y, pad_sw, jnp.float32(0.01))
    np.testing.assert_allclose(g1, g2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(l1, l2_, rtol=1e-5)
    assert float(c1) == float(c2)


def test_logreg_grad_matches_autodiff():
    """Cross-check the hand-fused gradient against jax.grad of the loss."""
    r = _rng(3)
    x = jnp.asarray(r.normal(size=(64, 20)).astype(np.float32))
    y = jnp.sign(jnp.asarray(r.normal(size=(64,)) + 0.05).astype(np.float32))
    sw = jnp.ones(64)
    w = jnp.asarray(r.normal(scale=0.5, size=(20,)).astype(np.float32))

    def loss(w):
        z = x @ w
        return (jnp.mean(jnp.logaddexp(0.0, -y * z))
                + 0.5 * 0.01 * jnp.sum(w * w))

    g_auto = jax.grad(loss)(w)
    g_k, _, _ = logreg_grad(w, x, y, sw, jnp.float32(0.01))
    np.testing.assert_allclose(g_k, g_auto, rtol=5e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# tiled matmul
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 300),
    k=st.integers(1, 300),
    n=st.integers(1, 300),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    r = _rng(seed)
    a = jnp.asarray(r.normal(size=(m, k)).astype(np.float32))
    b = jnp.asarray(r.normal(size=(k, n)).astype(np.float32))
    np.testing.assert_allclose(matmul(a, b), ref.matmul_ref(a, b),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [(128, 128, 128), (129, 127, 130),
                                   (1, 1, 1), (256, 384, 128), (5, 500, 3)])
def test_matmul_tile_edges(shape):
    m, k, n = shape
    r = _rng(0)
    a = jnp.asarray(r.normal(size=(m, k)).astype(np.float32))
    b = jnp.asarray(r.normal(size=(k, n)).astype(np.float32))
    np.testing.assert_allclose(matmul(a, b), a @ b, rtol=1e-4, atol=1e-4)


def test_pmatmul_gradients_match_dot():
    """custom-VJP backward must equal autodiff through jnp.matmul."""
    r = _rng(11)
    a = jnp.asarray(r.normal(size=(33, 47)).astype(np.float32))
    b = jnp.asarray(r.normal(size=(47, 21)).astype(np.float32))

    def f_pallas(a, b):
        return jnp.sum(jnp.sin(pmatmul(a, b)))

    def f_ref(a, b):
        return jnp.sum(jnp.sin(a @ b))

    ga_p, gb_p = jax.grad(f_pallas, argnums=(0, 1))(a, b)
    ga_r, gb_r = jax.grad(f_ref, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(ga_p, ga_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gb_p, gb_r, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# compressor kernels (natural compression, QSGD dithering)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(d=st.integers(1, 5000), seed=st.integers(0, 2**31 - 1))
def test_natural_matches_ref(d, seed):
    r = _rng(seed)
    x = jnp.asarray((r.normal(size=(d,)) * 10.0**r.integers(-3, 3))
                    .astype(np.float32))
    u = jnp.asarray(r.random(d).astype(np.float32))
    np.testing.assert_allclose(natural_compress(x, u),
                               ref.natural_compress_ref(x, u), rtol=1e-6)


def test_natural_zero_maps_to_zero():
    x = jnp.zeros(100)
    u = jnp.asarray(_rng(0).random(100).astype(np.float32))
    assert float(jnp.max(jnp.abs(natural_compress(x, u)))) == 0.0


def test_natural_output_is_signed_power_of_two():
    r = _rng(5)
    x = jnp.asarray(r.normal(size=(2048,)).astype(np.float32))
    u = jnp.asarray(r.random(2048).astype(np.float32))
    out = np.asarray(natural_compress(x, u))
    nz = out[out != 0]
    exps = np.log2(np.abs(nz))
    np.testing.assert_allclose(exps, np.round(exps), atol=1e-6)
    assert np.all(np.sign(nz) == np.sign(np.asarray(x)[out != 0]))


def test_natural_unbiased_monte_carlo():
    """E[C(x)] = x within Monte-Carlo CI; variance ≤ (1/8)‖x‖² (ω = 1/8)."""
    r = _rng(42)
    x = jnp.asarray(r.normal(size=(256,)).astype(np.float32))
    trials = 600
    us = r.random((trials, 256)).astype(np.float32)
    outs = np.stack([np.asarray(natural_compress(x, jnp.asarray(u)))
                     for u in us])
    mean = outs.mean(0)
    # per-coordinate 5σ bound: sd(C(x)_i) ≤ |x_i|/√8, so the MC mean of T
    # trials deviates by ≤ 5·|x_i|/(√8·√T) with overwhelming probability.
    tol = 5.0 * np.abs(np.asarray(x)) / np.sqrt(8.0 * trials) + 1e-4
    assert np.all(np.abs(mean - np.asarray(x)) <= tol)
    sq_err = ((outs - np.asarray(x)) ** 2).sum(1).mean()
    assert sq_err <= (1.0 / 8.0) * float(jnp.sum(x * x)) * 1.05


@settings(max_examples=25, deadline=None)
@given(d=st.integers(1, 5000), s=st.sampled_from([1.0, 4.0, 16.0, 255.0]),
       seed=st.integers(0, 2**31 - 1))
def test_dither_matches_ref(d, s, seed):
    r = _rng(seed)
    x = jnp.asarray(r.normal(size=(d,)).astype(np.float32))
    u = jnp.asarray(r.random(d).astype(np.float32))
    np.testing.assert_allclose(dither(x, u, s), ref.dither_ref(x, u, s),
                               rtol=1e-5, atol=1e-6)


def test_dither_levels_are_quantized():
    """Outputs must sit on the s-level grid scaled by ‖x‖."""
    r = _rng(9)
    s = 8.0
    x = jnp.asarray(r.normal(size=(512,)).astype(np.float32))
    u = jnp.asarray(r.random(512).astype(np.float32))
    out = np.asarray(dither(x, u, s))
    norm = float(jnp.linalg.norm(x))
    levels = np.abs(out) / norm * s
    np.testing.assert_allclose(levels, np.round(levels), atol=1e-4)


def test_dither_unbiased_monte_carlo():
    r = _rng(17)
    x = jnp.asarray(r.normal(size=(128,)).astype(np.float32))
    trials = 800
    outs = np.stack([
        np.asarray(dither(x, jnp.asarray(r.random(128).astype(np.float32)), 4.0))
        for _ in range(trials)])
    # dither step is ‖x‖/s; per-coordinate sd ≤ step/2, so MC mean deviates
    # by ≤ 5·step/(2√T) with overwhelming probability.
    step = float(jnp.linalg.norm(x)) / 4.0
    tol = 5.0 * step / (2.0 * np.sqrt(trials)) + 1e-4
    assert np.all(np.abs(outs.mean(0) - np.asarray(x)) <= tol)
