"""AOT path: manifest consistency and HLO-text well-formedness.

Requires `make artifacts` to have run (skips otherwise): validates the
exact bundle the Rust runtime will load.
"""

import json
import os
import struct

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first")


def _manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_manifest_lists_all_zoo_models():
    from compile.model import default_zoo
    names = {m["name"] for m in _manifest()["models"]}
    assert names == {m.name for m in default_zoo()}


def test_hlo_files_exist_and_parse_shape():
    man = _manifest()
    for m in man["models"]:
        for fn in ("grad", "eval"):
            path = os.path.join(ART, m[fn]["hlo"])
            assert os.path.exists(path), path
            text = open(path).read()
            assert "ENTRY" in text and "ROOT" in text, path
            # return_tuple=True: the entry computation must return a tuple
            assert "tuple(" in text or ") tuple" in text or "(" in text


def test_manifest_signatures_match_zoo():
    from compile.model import default_zoo
    zoo = {m.name: m for m in default_zoo()}
    for m in _manifest()["models"]:
        md = zoo[m["name"]]
        assert m["param_count"] == md.param_count
        grad_in = m["grad"]["inputs"]
        assert grad_in[0] == {"shape": [md.param_count], "dtype": "f32"}
        assert len(grad_in) == 1 + len(md.grad_args)
        for sig, s in zip(grad_in[1:], md.grad_args):
            assert sig["shape"] == list(s.shape)


def test_init_bins_match_param_count_and_spec():
    from compile.model import default_zoo
    zoo = {m.name: m for m in default_zoo()}
    man = _manifest()
    for m in man["models"]:
        path = os.path.join(ART, m["init"])
        raw = open(path, "rb").read()
        assert len(raw) == 4 * m["param_count"]
        vals = np.frombuffer(raw, "<f4")
        assert np.isfinite(vals).all()
        expect = zoo[m["name"]].spec.init_flat(man["seed"])
        np.testing.assert_array_equal(vals, expect)


def test_grad_hlo_contains_while_loop_from_pallas():
    """The interpret-mode Pallas kernels lower to grid while-loops; the
    logreg grad artifact must actually contain the fused kernel."""
    man = _manifest()
    logreg = next(m for m in man["models"] if m["family"] == "logreg")
    text = open(os.path.join(ART, logreg["grad"]["hlo"])).read()
    assert "while" in text
