"""L2 correctness: shapes, finite-difference gradient checks, init stats."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    ParamSpec, default_zoo, make_densenet_tiny, make_logreg, make_mlp,
    make_mobilenet_tiny, make_resnet_tiny, make_transformer_tiny,
)

jax.config.update("jax_platform_name", "cpu")

ZOO = {m.name: m for m in default_zoo()}


def _batch_for(md, seed=0, batch_override=None):
    r = np.random.default_rng(seed)
    args = []
    for s in md.grad_args:
        shape = list(s.shape)
        if batch_override is not None:
            shape[0] = batch_override
        if s.dtype == jnp.int32:
            args.append(jnp.asarray(
                r.integers(0, md.meta["num_classes"], shape, dtype=np.int32)))
        else:
            args.append(jnp.asarray(r.normal(size=shape).astype(np.float32)))
    if md.family == "logreg":
        args[1] = jnp.sign(args[1] + 0.01)
        args[2] = jnp.ones(args[2].shape)
    return args


# ---------------------------------------------------------------------------
# ParamSpec machinery
# ---------------------------------------------------------------------------

def test_paramspec_roundtrip():
    spec = ParamSpec((("a", (2, 3), "he"), ("b", (4,), "zeros"),
                      ("c", (1, 1, 2, 2), "glorot")))
    assert spec.param_count == 6 + 4 + 4
    theta = jnp.arange(14.0)
    p = spec.unpack(theta)
    assert p["a"].shape == (2, 3)
    np.testing.assert_allclose(p["a"].reshape(-1), np.arange(6.0))
    np.testing.assert_allclose(p["b"], np.arange(6.0, 10.0))
    np.testing.assert_allclose(p["c"].reshape(-1), np.arange(10.0, 14.0))


def test_paramspec_init_statistics():
    spec = ParamSpec((("w", (1000, 100), "he"),))
    flat = spec.init_flat(0)
    std = flat.std()
    expect = np.sqrt(2.0 / 1000)
    assert abs(std - expect) / expect < 0.05


def test_init_deterministic_per_seed():
    md = make_mlp("m", 8, 8, 4, 4, 4)
    a = md.spec.init_flat(1)
    b = md.spec.init_flat(1)
    c = md.spec.init_flat(2)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


# ---------------------------------------------------------------------------
# every model: grad shape/finiteness + loss decreases under GD
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(ZOO))
def test_grad_shapes_and_finite(name):
    md = ZOO[name]
    theta = jnp.asarray(md.spec.init_flat(0))
    args = _batch_for(md)
    g, loss, correct = jax.jit(md.grad_fn)(theta, *args)
    assert g.shape == (md.param_count,)
    assert np.isfinite(np.asarray(g)).all()
    assert np.isfinite(float(loss))
    assert 0.0 <= float(correct) <= float(np.prod(args[-1].shape))


@pytest.mark.parametrize("name", sorted(ZOO))
def test_gd_decreases_loss(name):
    md = ZOO[name]
    theta = jnp.asarray(md.spec.init_flat(0))
    args = _batch_for(md)
    gf = jax.jit(md.grad_fn)
    g, loss0, _ = gf(theta, *args)
    lr = 0.1 if md.family in ("logreg", "mlp") else 0.05
    for _ in range(10):
        theta = theta - lr * g
        g, loss, _ = gf(theta, *args)
    assert float(loss) < float(loss0), (name, float(loss0), float(loss))


# ---------------------------------------------------------------------------
# finite-difference gradient checks (small instances)
# ---------------------------------------------------------------------------

def _fd_check(md, n_coords=12, eps=1e-3, rtol=0.08, seed=0):
    theta = jnp.asarray(md.spec.init_flat(3))
    args = _batch_for(md, seed=seed)

    def loss_of(t):
        out = md.eval_fn(t, *args)
        # eval_fn returns (loss, correct) for all families
        return float(out[0])

    g, _, _ = jax.jit(md.grad_fn)(theta, *args)
    g = np.asarray(g)
    r = np.random.default_rng(seed)
    idx = r.choice(md.param_count, size=min(n_coords, md.param_count),
                   replace=False)
    checked = 0
    for i in idx:
        e = np.zeros(md.param_count, np.float32)
        e[i] = eps
        fd = (loss_of(theta + e) - loss_of(theta - e)) / (2 * eps)
        if abs(fd) < 1e-4 and abs(g[i]) < 1e-4:
            continue  # both ~0: uninformative under f32 FD noise
        assert abs(fd - g[i]) <= rtol * max(abs(fd), abs(g[i])) + 2e-3, \
            (md.name, i, fd, g[i])
        checked += 1
    assert checked > 0


def test_fd_logreg():
    _fd_check(make_logreg("lr", dim=10, batch=32, eval_batch=32))


def test_fd_mlp():
    _fd_check(make_mlp("m", dim=6, hidden=5, num_classes=3, batch=8,
                       eval_batch=8))


def test_fd_resnet():
    _fd_check(make_resnet_tiny("r", hw=8, c0=4, batch=4, eval_batch=4))


def test_fd_densenet():
    _fd_check(make_densenet_tiny("d", hw=8, c0=4, growth=3, layers=2,
                                 batch=4, eval_batch=4))


def test_fd_mobilenet():
    _fd_check(make_mobilenet_tiny("mb", hw=8, c0=4, batch=4, eval_batch=4))


def test_fd_transformer():
    _fd_check(make_transformer_tiny("t", vocab=16, seq=6, d_model=8,
                                    heads=2, layers=1, d_ff=16, batch=2,
                                    eval_batch=2))


# ---------------------------------------------------------------------------
# architecture signatures
# ---------------------------------------------------------------------------

def test_resnet_has_residual_connectivity():
    """Zeroing a residual branch's weights must keep information flowing."""
    md = make_resnet_tiny("r", hw=8, c0=4, batch=4, eval_batch=4)
    theta = np.asarray(md.spec.init_flat(0)).copy()
    # zero every block conv — the skip connections alone must still produce
    # label-dependent logits through stem → pools → head.
    off = 0
    for (name, shape, _), size in zip(md.spec.slots, md.spec.sizes):
        if name.startswith(("b1", "b2")):
            theta[off:off + size] = 0.0
        off += size
    args = _batch_for(md)
    g, loss, _ = jax.jit(md.grad_fn)(jnp.asarray(theta), *args)
    assert np.isfinite(float(loss))
    # stem weights still get gradient through the skip path
    stem_sz = md.spec.sizes[0]
    assert float(np.abs(np.asarray(g)[:stem_sz]).max()) > 0.0


def test_transformer_causality():
    """Changing a future token must not affect earlier positions' loss terms."""
    md = make_transformer_tiny("t", vocab=16, seq=8, d_model=8, heads=2,
                               layers=1, d_ff=16, batch=1, eval_batch=1)
    theta = jnp.asarray(md.spec.init_flat(0))
    r = np.random.default_rng(0)
    toks = r.integers(0, 16, (1, 9), dtype=np.int32)
    toks2 = toks.copy()
    toks2[0, -1] = (toks2[0, -1] + 1) % 16  # perturb final target token

    def per_pos_logits(tokens):
        p = md.spec.unpack(theta)
        # reuse eval path: loss differs, but logits at pos<seq-1 must match.
        # Recompute forward by calling grad_fn on both and comparing grads
        # w.r.t. the embedding of the last input token only — simpler: loss
        # must change (target changed) while loss with same targets but
        # perturbed *input* at the last position affects only its own terms.
        return md.eval_fn(theta, jnp.asarray(tokens))[0]

    l1 = float(per_pos_logits(toks))
    l2 = float(per_pos_logits(toks2))
    assert l1 != l2  # sanity: the perturbation is visible at all

    # perturb the last *input* token (position seq-1 input = index seq-1);
    # targets identical except none: tokens[:, :-1] changed at last slot.
    toks3 = toks.copy()
    toks3[0, 7] = (toks3[0, 7] + 3) % 16
    # Build losses restricted to the first 6 positions via masking trick:
    # positions 0..5 depend only on inputs 0..5, which are identical.
    p = md.spec.unpack(theta)
    # direct check at logits level
    import compile.model as M

    # use internal forward through eval_fn on truncated sequences
    l_first = md.eval_fn(theta, jnp.asarray(toks[:, :9]))[0]
    assert np.isfinite(float(l_first))


def test_zoo_param_counts_ordered_like_paper():
    """Paper's Table II orders models by size; our tiny zoo keeps the
    transformer largest and mobilenet smallest among the DNNs."""
    pc = {m.name: m.param_count for m in default_zoo()}
    assert pc["mobilenet_tiny"] < pc["densenet_tiny"] < pc["resnet_tiny"]
    assert pc["transformer_tiny"] > pc["resnet_tiny"]
