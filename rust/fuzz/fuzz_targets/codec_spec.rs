//! Codec-spec fuzz target: the registry parser never panics, and every
//! accepted spec's canonical name (`Compressor::name`) reparses to the
//! same canonical name.
#![no_main]

use libfuzzer_sys::fuzz_target;
use pfl::compress::Compressor as _;

fuzz_target!(|data: &[u8]| {
    let Ok(s) = std::str::from_utf8(data) else { return };
    let Ok(codec) = pfl::compress::from_spec(s) else { return };
    let name = codec.name();
    let re = pfl::compress::from_spec(&name).unwrap_or_else(|e| {
        panic!("`{s}` parsed but its name `{name}` fails: {e:#}")
    });
    assert_eq!(re.name(), name, "name of `{s}` is not a fixpoint");
});
