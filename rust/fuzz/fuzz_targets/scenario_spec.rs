//! Scenario-spec fuzz target: parsing never panics on arbitrary input,
//! and every accepted spec round-trips — `to_spec()` reparses to the
//! same configuration and printing is a fixpoint.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    let Ok(s) = std::str::from_utf8(data) else { return };
    // must never panic — errors are the contract for malformed specs
    let Ok(sc) = pfl::sim::scenario::from_spec(s) else { return };
    let printed = sc.to_spec();
    let re = pfl::sim::scenario::from_spec(&printed).unwrap_or_else(|e| {
        panic!("`{s}` parsed but its print `{printed}` fails: {e:#}")
    });
    assert!(sc.same_config(&re),
            "`{s}` → `{printed}` changed the configuration");
    assert_eq!(printed, re.to_spec(), "print of `{s}` is not a fixpoint");
});
