//! Wire-frame fuzz target: `decode_frame` never panics on arbitrary
//! bytes, and every accepted frame re-encodes to exactly the input
//! (decode∘encode = id on the accepted set).
#![no_main]

use libfuzzer_sys::fuzz_target;
use pfl::transport::frame::{decode_frame, encode_frame};

fuzz_target!(|data: &[u8]| {
    let Ok((header, payload)) = decode_frame(data) else { return };
    let mut out = Vec::new();
    encode_frame(&header, payload, &mut out);
    assert_eq!(out.as_slice(), data,
               "decode→encode did not reproduce the frame bytes");
});
