//! 8-lane unrolled f32 kernels written for reliable autovectorization.
//!
//! Every loop body is shaped so LLVM's loop vectorizer maps it onto one
//! `<8 x f32>` operation per iteration (fixed-width inner loops over
//! `chunks_exact(8)`, independent lanes, no cross-lane reduction inside
//! the hot loop). The elementwise kernels ([`axpy`], [`aggregation_step`],
//! [`add_assign`], [`scale`]) are **bit-identical** to their scalar
//! equivalents — each output element depends only on the same-index
//! inputs, so unrolling cannot reassociate anything. [`dot`] carries 8
//! independent accumulators and therefore rounds differently from a
//! strictly sequential sum; callers that need sequential-bit-exact sums
//! should not use it (nothing in the training path does — the gradient
//! dot products were never compared bitwise across layouts).

// fixed-width index loops over `chunks_exact` blocks are the
// autovectorization idiom; iterator rewrites obscure the lane structure
#![allow(clippy::needless_range_loop)]

const LANES: usize = 8;

/// Dot product with 8 independent accumulators (vectorizes to one FMA-free
/// multiply-add per lane; ~4-6× the throughput of the naive sequential
/// fold at logreg dimensions).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let split = a.len() - a.len() % LANES;
    let mut acc = [0.0f32; LANES];
    for (xa, xb) in a[..split].chunks_exact(LANES).zip(b[..split].chunks_exact(LANES)) {
        for l in 0..LANES {
            acc[l] += xa[l] * xb[l];
        }
    }
    let mut s = ((acc[0] + acc[4]) + (acc[2] + acc[6]))
        + ((acc[1] + acc[5]) + (acc[3] + acc[7]));
    for (xa, xb) in a[split..].iter().zip(&b[split..]) {
        s += xa * xb;
    }
    s
}

/// In-place `x ← x + a·y`. Elementwise ⇒ bit-identical to the scalar loop.
pub fn axpy(x: &mut [f32], a: f32, y: &[f32]) {
    debug_assert_eq!(x.len(), y.len());
    let split = x.len() - x.len() % LANES;
    let (cx, rx) = x.split_at_mut(split);
    for (xs, ys) in cx.chunks_exact_mut(LANES).zip(y[..split].chunks_exact(LANES)) {
        for l in 0..LANES {
            xs[l] += a * ys[l];
        }
    }
    for (xi, yi) in rx.iter_mut().zip(&y[split..]) {
        *xi += a * yi;
    }
}

/// In-place aggregation step (Algorithm 1, ξ = 1):
/// `x ← x − a·(x − anchor)` ≡ `x ← (1−a)·x + a·anchor`.
/// Elementwise ⇒ bit-identical to the scalar loop.
pub fn aggregation_step(x: &mut [f32], a: f32, anchor: &[f32]) {
    debug_assert_eq!(x.len(), anchor.len());
    let split = x.len() - x.len() % LANES;
    let (cx, rx) = x.split_at_mut(split);
    for (xs, ms) in cx.chunks_exact_mut(LANES).zip(anchor[..split].chunks_exact(LANES)) {
        for l in 0..LANES {
            xs[l] -= a * (xs[l] - ms[l]);
        }
    }
    for (xi, mi) in rx.iter_mut().zip(&anchor[split..]) {
        *xi -= a * (*xi - mi);
    }
}

/// In-place `acc ← acc + v` (the tree-reduction combine).
pub fn add_assign(acc: &mut [f32], v: &[f32]) {
    debug_assert_eq!(acc.len(), v.len());
    let split = acc.len() - acc.len() % LANES;
    let (ca, ra) = acc.split_at_mut(split);
    for (xs, vs) in ca.chunks_exact_mut(LANES).zip(v[..split].chunks_exact(LANES)) {
        for l in 0..LANES {
            xs[l] += vs[l];
        }
    }
    for (ai, vi) in ra.iter_mut().zip(&v[split..]) {
        *ai += vi;
    }
}

/// In-place `x ← s·x`.
pub fn scale(x: &mut [f32], s: f32) {
    let split = x.len() - x.len() % LANES;
    let (cx, rx) = x.split_at_mut(split);
    for xs in cx.chunks_exact_mut(LANES) {
        for l in 0..LANES {
            xs[l] *= s;
        }
    }
    for xi in rx {
        *xi *= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn vecs(d: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let a = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let b = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        (a, b)
    }

    #[test]
    fn dot_matches_sequential_within_tolerance() {
        for d in [1usize, 7, 8, 9, 63, 123, 1000] {
            let (a, b) = vecs(d, d as u64);
            let seq: f64 = a.iter().zip(&b).map(|(x, y)| (*x as f64) * (*y as f64)).sum();
            let got = dot(&a, &b) as f64;
            assert!((got - seq).abs() < 1e-3 * (1.0 + seq.abs()),
                    "d={d}: {got} vs {seq}");
        }
    }

    #[test]
    fn axpy_is_bit_identical_to_scalar() {
        for d in [1usize, 8, 13, 123] {
            let (mut x, y) = vecs(d, 3 + d as u64);
            let mut x_ref = x.clone();
            for (xi, yi) in x_ref.iter_mut().zip(&y) {
                *xi += -0.37 * yi;
            }
            axpy(&mut x, -0.37, &y);
            assert_eq!(x, x_ref, "d={d}");
        }
    }

    #[test]
    fn aggregation_is_bit_identical_to_scalar() {
        for d in [1usize, 8, 17, 123] {
            let (mut x, m) = vecs(d, 11 + d as u64);
            let mut x_ref = x.clone();
            for (xi, mi) in x_ref.iter_mut().zip(&m) {
                *xi -= 0.25 * (*xi - mi);
            }
            aggregation_step(&mut x, 0.25, &m);
            assert_eq!(x, x_ref, "d={d}");
        }
    }

    #[test]
    fn add_assign_and_scale() {
        let (mut a, b) = vecs(29, 5);
        let expect: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
        add_assign(&mut a, &b);
        assert_eq!(a, expect);
        let expect2: Vec<f32> = a.iter().map(|x| x * 0.5).collect();
        scale(&mut a, 0.5);
        assert_eq!(a, expect2);
    }
}
