//! Runtime-dispatched SIMD f32 kernels with scalar bit-exactness oracles.
//!
//! The hot kernels ([`dot`], [`axpy`], [`aggregation_step`], [`add_assign`],
//! [`scale`]) no longer rely on LLVM autovectorization: on x86-64 they
//! dispatch at runtime to hand-written AVX-512 or AVX2 intrinsics
//! (detected via `is_x86_feature_detected!`) with an SSE2 path as the
//! baseline-ABI fallback; every other architecture takes the portable
//! [`scalar`] path. The dispatch decision is made once per process
//! ([`active_level`]) and `PFL_FORCE_KERNEL_LEVEL=<avx512|avx2|sse2|scalar>`
//! pins any tier (clamped to the next-slower level the host can actually
//! run) — the escape hatch for A/B timing and for debugging a suspected
//! intrinsics bug. `PFL_FORCE_SCALAR_KERNELS=1` is kept as an alias for
//! `PFL_FORCE_KERNEL_LEVEL=scalar`.
//!
//! Bit-exactness contract: the previous 8-lane autovectorizable forms are
//! retained verbatim in [`scalar`] as oracles, and **every intrinsic path
//! is bit-identical to them**. The elementwise kernels are trivially so
//! (each output element depends only on same-index inputs, so the vector
//! width cannot reassociate anything). `dot` carries 8 independent
//! accumulators; the AVX2 path keeps exactly one 8-lane accumulator whose
//! lane `l` sees the same multiply/add sequence as the oracle's `acc[l]`,
//! uses separate mul+add (never FMA — fused rounding would diverge), and
//! reduces the lanes in the oracle's exact tree order; the SSE2 path
//! splits the same 8 accumulators across two 4-lane registers over 8-wide
//! blocks; the AVX-512 path widens loads and multiplies to 512 bits but
//! keeps the *accumulator* 8 lanes wide, folding each product's low then
//! high 256-bit half into it — lane `l` still sees products in the
//! oracle's exact `k = 0, 1, 2, …` block order, so nothing reassociates.
//! Golden series (`rust/tests/golden/`) are therefore unchanged by
//! dispatch level, and `rust/tests/kernel_parity.rs` pins every kernel ×
//! every available level bitwise. As before, `dot` rounds differently
//! from a strictly sequential fold; nothing in the training path compares
//! sums bitwise across layouts.

// fixed-width index loops over `chunks_exact` blocks (and intrinsic tail
// loops) are the lane-structure idiom; iterator rewrites obscure it
#![allow(clippy::needless_range_loop)]

use std::sync::OnceLock;

const LANES: usize = 8;

/// Portable 8-lane unrolled forms — the bit-exactness oracles the
/// intrinsic paths are pinned against, and the production path on
/// non-x86-64 targets (each loop body still autovectorizes; on aarch64
/// LLVM maps it onto NEON). Kept verbatim from the pre-dispatch kernels.
pub mod scalar {
    use super::LANES;

    /// Dot product with 8 independent accumulators and a fixed reduction
    /// tree (vectorizes to one FMA-free multiply-add per lane).
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let split = a.len() - a.len() % LANES;
        let mut acc = [0.0f32; LANES];
        for (xa, xb) in a[..split].chunks_exact(LANES).zip(b[..split].chunks_exact(LANES)) {
            for l in 0..LANES {
                acc[l] += xa[l] * xb[l];
            }
        }
        let mut s = ((acc[0] + acc[4]) + (acc[2] + acc[6]))
            + ((acc[1] + acc[5]) + (acc[3] + acc[7]));
        for (xa, xb) in a[split..].iter().zip(&b[split..]) {
            s += xa * xb;
        }
        s
    }

    /// In-place `x ← x + a·y`. Elementwise ⇒ bit-identical to the scalar
    /// loop.
    pub fn axpy(x: &mut [f32], a: f32, y: &[f32]) {
        debug_assert_eq!(x.len(), y.len());
        let split = x.len() - x.len() % LANES;
        let (cx, rx) = x.split_at_mut(split);
        for (xs, ys) in cx.chunks_exact_mut(LANES).zip(y[..split].chunks_exact(LANES)) {
            for l in 0..LANES {
                xs[l] += a * ys[l];
            }
        }
        for (xi, yi) in rx.iter_mut().zip(&y[split..]) {
            *xi += a * yi;
        }
    }

    /// In-place aggregation step (Algorithm 1, ξ = 1):
    /// `x ← x − a·(x − anchor)` ≡ `x ← (1−a)·x + a·anchor`.
    pub fn aggregation_step(x: &mut [f32], a: f32, anchor: &[f32]) {
        debug_assert_eq!(x.len(), anchor.len());
        let split = x.len() - x.len() % LANES;
        let (cx, rx) = x.split_at_mut(split);
        for (xs, ms) in cx.chunks_exact_mut(LANES).zip(anchor[..split].chunks_exact(LANES)) {
            for l in 0..LANES {
                xs[l] -= a * (xs[l] - ms[l]);
            }
        }
        for (xi, mi) in rx.iter_mut().zip(&anchor[split..]) {
            *xi -= a * (*xi - mi);
        }
    }

    /// In-place `acc ← acc + v` (the tree-reduction combine).
    pub fn add_assign(acc: &mut [f32], v: &[f32]) {
        debug_assert_eq!(acc.len(), v.len());
        let split = acc.len() - acc.len() % LANES;
        let (ca, ra) = acc.split_at_mut(split);
        for (xs, vs) in ca.chunks_exact_mut(LANES).zip(v[..split].chunks_exact(LANES)) {
            for l in 0..LANES {
                xs[l] += vs[l];
            }
        }
        for (ai, vi) in ra.iter_mut().zip(&v[split..]) {
            *ai += vi;
        }
    }

    /// In-place `x ← s·x`.
    pub fn scale(x: &mut [f32], s: f32) {
        let split = x.len() - x.len() % LANES;
        let (cx, rx) = x.split_at_mut(split);
        for xs in cx.chunks_exact_mut(LANES) {
            for l in 0..LANES {
                xs[l] *= s;
            }
        }
        for xi in rx {
            *xi *= s;
        }
    }
}

/// x86-64 intrinsic paths. Unaligned loads/stores throughout (the stores
/// hand out arbitrary row offsets); bit-identity to [`scalar`] is argued
/// per function and pinned by `rust/tests/kernel_parity.rs`.
#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    /// Low 256-bit half of a 512-bit f32 vector.
    #[target_feature(enable = "avx512f")]
    unsafe fn lo256(v: __m512) -> __m256 {
        _mm512_castps512_ps256(v)
    }

    /// High 256-bit half of a 512-bit f32 vector. Routed through the f64
    /// domain because `_mm512_extractf32x8_ps` needs AVX512DQ while the
    /// `f64x4` extract is plain AVX512F; bit casts don't touch lanes.
    #[target_feature(enable = "avx512f")]
    unsafe fn hi256(v: __m512) -> __m256 {
        _mm256_castpd_ps(_mm512_extractf64x4_pd(_mm512_castps_pd(v), 1))
    }

    /// AVX-512 dot: 512-bit loads and multiplies, but the accumulator
    /// stays one 8-lane register — each 16-wide block's product folds its
    /// low then high 256-bit half into it, so lane `l` performs exactly
    /// the oracle's `acc[l] += a[8k+l] * b[8k+l]` sequence for
    /// `k = 2j, 2j+1, …` (separate `mul`+`add`, never FMA). A widened
    /// 16-lane accumulator would reassociate the sum; this keeps the
    /// memory bandwidth win without changing a single rounding step. The
    /// reduction reuses the oracle's exact tree order, then the same
    /// sequential tail (one 8-wide AVX2 block first when `len % 16 ≥ 8` —
    /// `avx512f` implies `avx2`, so 256-bit ops are in-budget here).
    ///
    /// # Safety
    /// Caller must ensure the CPU supports AVX-512F (`active_level()` /
    /// `available_levels()` gate on `is_x86_feature_detected!("avx512f")`).
    #[target_feature(enable = "avx512f")]
    pub unsafe fn dot_avx512(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let split16 = a.len() - a.len() % 16;
        let split8 = a.len() - a.len() % 8;
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc = _mm256_setzero_ps();
        let mut k = 0usize;
        while k < split16 {
            let va = _mm512_loadu_ps(pa.add(k));
            let vb = _mm512_loadu_ps(pb.add(k));
            let prod = _mm512_mul_ps(va, vb);
            acc = _mm256_add_ps(acc, lo256(prod));
            acc = _mm256_add_ps(acc, hi256(prod));
            k += 16;
        }
        if split8 > split16 {
            let va = _mm256_loadu_ps(pa.add(k));
            let vb = _mm256_loadu_ps(pb.add(k));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut s = ((lanes[0] + lanes[4]) + (lanes[2] + lanes[6]))
            + ((lanes[1] + lanes[5]) + (lanes[3] + lanes[7]));
        for i in split8..a.len() {
            s += a[i] * b[i];
        }
        s
    }

    /// # Safety
    /// Caller must ensure the CPU supports AVX-512F. Elementwise ⇒ the
    /// 16-lane width cannot reassociate anything (see module docs).
    #[target_feature(enable = "avx512f")]
    pub unsafe fn axpy_avx512(x: &mut [f32], a: f32, y: &[f32]) {
        debug_assert_eq!(x.len(), y.len());
        let split = x.len() - x.len() % 16;
        let px = x.as_mut_ptr();
        let py = y.as_ptr();
        let va = _mm512_set1_ps(a);
        let mut k = 0usize;
        while k < split {
            let vx = _mm512_loadu_ps(px.add(k));
            let vy = _mm512_loadu_ps(py.add(k));
            // x + (a·y): same operation order as the oracle — no FMA
            _mm512_storeu_ps(px.add(k), _mm512_add_ps(vx, _mm512_mul_ps(va, vy)));
            k += 16;
        }
        for i in split..x.len() {
            x[i] += a * y[i];
        }
    }

    /// # Safety
    /// Caller must ensure the CPU supports AVX-512F.
    #[target_feature(enable = "avx512f")]
    pub unsafe fn aggregation_step_avx512(x: &mut [f32], a: f32, anchor: &[f32]) {
        debug_assert_eq!(x.len(), anchor.len());
        let split = x.len() - x.len() % 16;
        let px = x.as_mut_ptr();
        let pm = anchor.as_ptr();
        let va = _mm512_set1_ps(a);
        let mut k = 0usize;
        while k < split {
            let vx = _mm512_loadu_ps(px.add(k));
            let vm = _mm512_loadu_ps(pm.add(k));
            // x − a·(x − m): oracle order `xs[l] -= a * (xs[l] - ms[l])`
            let step = _mm512_mul_ps(va, _mm512_sub_ps(vx, vm));
            _mm512_storeu_ps(px.add(k), _mm512_sub_ps(vx, step));
            k += 16;
        }
        for i in split..x.len() {
            x[i] -= a * (x[i] - anchor[i]);
        }
    }

    /// # Safety
    /// Caller must ensure the CPU supports AVX-512F.
    #[target_feature(enable = "avx512f")]
    pub unsafe fn add_assign_avx512(acc: &mut [f32], v: &[f32]) {
        debug_assert_eq!(acc.len(), v.len());
        let split = acc.len() - acc.len() % 16;
        let pa = acc.as_mut_ptr();
        let pv = v.as_ptr();
        let mut k = 0usize;
        while k < split {
            let va = _mm512_loadu_ps(pa.add(k));
            let vv = _mm512_loadu_ps(pv.add(k));
            _mm512_storeu_ps(pa.add(k), _mm512_add_ps(va, vv));
            k += 16;
        }
        for i in split..acc.len() {
            acc[i] += v[i];
        }
    }

    /// # Safety
    /// Caller must ensure the CPU supports AVX-512F.
    #[target_feature(enable = "avx512f")]
    pub unsafe fn scale_avx512(x: &mut [f32], s: f32) {
        let split = x.len() - x.len() % 16;
        let px = x.as_mut_ptr();
        let vs = _mm512_set1_ps(s);
        let mut k = 0usize;
        while k < split {
            let vx = _mm512_loadu_ps(px.add(k));
            _mm512_storeu_ps(px.add(k), _mm512_mul_ps(vx, vs));
            k += 16;
        }
        for i in split..x.len() {
            x[i] *= s;
        }
    }

    /// AVX2 dot: one 8-lane accumulator whose lane `l` performs exactly
    /// the oracle's `acc[l] += a[8k+l] * b[8k+l]` sequence (separate
    /// `mul`+`add`, never FMA), then a store-and-scalar reduction in the
    /// oracle's exact tree order, then the same sequential tail.
    ///
    /// # Safety
    /// Caller must ensure the CPU supports AVX2 (`active_level()` /
    /// `available_levels()` gate on `is_x86_feature_detected!("avx2")`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let split = a.len() - a.len() % 8;
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc = _mm256_setzero_ps();
        let mut k = 0usize;
        while k < split {
            let va = _mm256_loadu_ps(pa.add(k));
            let vb = _mm256_loadu_ps(pb.add(k));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
            k += 8;
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut s = ((lanes[0] + lanes[4]) + (lanes[2] + lanes[6]))
            + ((lanes[1] + lanes[5]) + (lanes[3] + lanes[7]));
        for i in split..a.len() {
            s += a[i] * b[i];
        }
        s
    }

    /// SSE2 dot: the oracle's 8 accumulators split across two 4-lane
    /// registers (`acc_lo` ≡ `acc[0..4]`, `acc_hi` ≡ `acc[4..8]`) over the
    /// same 8-wide blocks, reduced in the same tree order. SSE2 is part of
    /// the x86-64 baseline ABI, so this needs no feature gate.
    pub fn dot_sse2(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let split = a.len() - a.len() % 8;
        // Safety: in-bounds unaligned loads — `k + 8 <= split <= len`.
        unsafe {
            let pa = a.as_ptr();
            let pb = b.as_ptr();
            let mut acc_lo = _mm_setzero_ps();
            let mut acc_hi = _mm_setzero_ps();
            let mut k = 0usize;
            while k < split {
                let a_lo = _mm_loadu_ps(pa.add(k));
                let b_lo = _mm_loadu_ps(pb.add(k));
                acc_lo = _mm_add_ps(acc_lo, _mm_mul_ps(a_lo, b_lo));
                let a_hi = _mm_loadu_ps(pa.add(k + 4));
                let b_hi = _mm_loadu_ps(pb.add(k + 4));
                acc_hi = _mm_add_ps(acc_hi, _mm_mul_ps(a_hi, b_hi));
                k += 8;
            }
            let mut lanes = [0.0f32; 8];
            _mm_storeu_ps(lanes.as_mut_ptr(), acc_lo);
            _mm_storeu_ps(lanes.as_mut_ptr().add(4), acc_hi);
            let mut s = ((lanes[0] + lanes[4]) + (lanes[2] + lanes[6]))
                + ((lanes[1] + lanes[5]) + (lanes[3] + lanes[7]));
            for i in split..a.len() {
                s += a[i] * b[i];
            }
            s
        }
    }

    /// # Safety
    /// Caller must ensure the CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_avx2(x: &mut [f32], a: f32, y: &[f32]) {
        debug_assert_eq!(x.len(), y.len());
        let split = x.len() - x.len() % 8;
        let px = x.as_mut_ptr();
        let py = y.as_ptr();
        let va = _mm256_set1_ps(a);
        let mut k = 0usize;
        while k < split {
            let vx = _mm256_loadu_ps(px.add(k));
            let vy = _mm256_loadu_ps(py.add(k));
            // x + (a·y): same operation order as the oracle's
            // `xs[l] += a * ys[l]` — no FMA
            _mm256_storeu_ps(px.add(k), _mm256_add_ps(vx, _mm256_mul_ps(va, vy)));
            k += 8;
        }
        for i in split..x.len() {
            x[i] += a * y[i];
        }
    }

    pub fn axpy_sse2(x: &mut [f32], a: f32, y: &[f32]) {
        debug_assert_eq!(x.len(), y.len());
        let split = x.len() - x.len() % 4;
        // Safety: in-bounds unaligned loads/stores; x and y are distinct
        // slices (aliasing is ruled out by &mut).
        unsafe {
            let px = x.as_mut_ptr();
            let py = y.as_ptr();
            let va = _mm_set1_ps(a);
            let mut k = 0usize;
            while k < split {
                let vx = _mm_loadu_ps(px.add(k));
                let vy = _mm_loadu_ps(py.add(k));
                _mm_storeu_ps(px.add(k), _mm_add_ps(vx, _mm_mul_ps(va, vy)));
                k += 4;
            }
        }
        for i in split..x.len() {
            x[i] += a * y[i];
        }
    }

    /// # Safety
    /// Caller must ensure the CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn aggregation_step_avx2(x: &mut [f32], a: f32, anchor: &[f32]) {
        debug_assert_eq!(x.len(), anchor.len());
        let split = x.len() - x.len() % 8;
        let px = x.as_mut_ptr();
        let pm = anchor.as_ptr();
        let va = _mm256_set1_ps(a);
        let mut k = 0usize;
        while k < split {
            let vx = _mm256_loadu_ps(px.add(k));
            let vm = _mm256_loadu_ps(pm.add(k));
            // x − a·(x − m): oracle order `xs[l] -= a * (xs[l] - ms[l])`
            let step = _mm256_mul_ps(va, _mm256_sub_ps(vx, vm));
            _mm256_storeu_ps(px.add(k), _mm256_sub_ps(vx, step));
            k += 8;
        }
        for i in split..x.len() {
            x[i] -= a * (x[i] - anchor[i]);
        }
    }

    pub fn aggregation_step_sse2(x: &mut [f32], a: f32, anchor: &[f32]) {
        debug_assert_eq!(x.len(), anchor.len());
        let split = x.len() - x.len() % 4;
        // Safety: in-bounds unaligned loads/stores, distinct slices.
        unsafe {
            let px = x.as_mut_ptr();
            let pm = anchor.as_ptr();
            let va = _mm_set1_ps(a);
            let mut k = 0usize;
            while k < split {
                let vx = _mm_loadu_ps(px.add(k));
                let vm = _mm_loadu_ps(pm.add(k));
                let step = _mm_mul_ps(va, _mm_sub_ps(vx, vm));
                _mm_storeu_ps(px.add(k), _mm_sub_ps(vx, step));
                k += 4;
            }
        }
        for i in split..x.len() {
            x[i] -= a * (x[i] - anchor[i]);
        }
    }

    /// # Safety
    /// Caller must ensure the CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn add_assign_avx2(acc: &mut [f32], v: &[f32]) {
        debug_assert_eq!(acc.len(), v.len());
        let split = acc.len() - acc.len() % 8;
        let pa = acc.as_mut_ptr();
        let pv = v.as_ptr();
        let mut k = 0usize;
        while k < split {
            let va = _mm256_loadu_ps(pa.add(k));
            let vv = _mm256_loadu_ps(pv.add(k));
            _mm256_storeu_ps(pa.add(k), _mm256_add_ps(va, vv));
            k += 8;
        }
        for i in split..acc.len() {
            acc[i] += v[i];
        }
    }

    pub fn add_assign_sse2(acc: &mut [f32], v: &[f32]) {
        debug_assert_eq!(acc.len(), v.len());
        let split = acc.len() - acc.len() % 4;
        // Safety: in-bounds unaligned loads/stores, distinct slices.
        unsafe {
            let pa = acc.as_mut_ptr();
            let pv = v.as_ptr();
            let mut k = 0usize;
            while k < split {
                let va = _mm_loadu_ps(pa.add(k));
                let vv = _mm_loadu_ps(pv.add(k));
                _mm_storeu_ps(pa.add(k), _mm_add_ps(va, vv));
                k += 4;
            }
        }
        for i in split..acc.len() {
            acc[i] += v[i];
        }
    }

    /// # Safety
    /// Caller must ensure the CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn scale_avx2(x: &mut [f32], s: f32) {
        let split = x.len() - x.len() % 8;
        let px = x.as_mut_ptr();
        let vs = _mm256_set1_ps(s);
        let mut k = 0usize;
        while k < split {
            let vx = _mm256_loadu_ps(px.add(k));
            _mm256_storeu_ps(px.add(k), _mm256_mul_ps(vx, vs));
            k += 8;
        }
        for i in split..x.len() {
            x[i] *= s;
        }
    }

    pub fn scale_sse2(x: &mut [f32], s: f32) {
        let split = x.len() - x.len() % 4;
        // Safety: in-bounds unaligned loads/stores.
        unsafe {
            let px = x.as_mut_ptr();
            let vs = _mm_set1_ps(s);
            let mut k = 0usize;
            while k < split {
                let vx = _mm_loadu_ps(px.add(k));
                _mm_storeu_ps(px.add(k), _mm_mul_ps(vx, vs));
                k += 4;
            }
        }
        for i in split..x.len() {
            x[i] *= s;
        }
    }
}

/// Instruction-set level a kernel call executes at. Ordered fastest
/// first (discriminants are the speed rank, used by the dispatch clamp);
/// recorded as `cpu_features` in every `BENCH_*.json`.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum KernelLevel {
    /// 16-lane AVX-512 intrinsics (x86-64 with runtime-detected AVX512F).
    Avx512 = 0,
    /// 8-lane AVX2 intrinsics (x86-64 with runtime-detected AVX2).
    Avx2 = 1,
    /// 4-lane SSE2 intrinsics (the x86-64 baseline ABI).
    Sse2 = 2,
    /// Portable 8-lane unrolled loops (non-x86 targets, or the
    /// `PFL_FORCE_KERNEL_LEVEL=scalar` escape hatch).
    Scalar = 3,
}

impl KernelLevel {
    pub fn name(self) -> &'static str {
        match self {
            KernelLevel::Avx512 => "avx512",
            KernelLevel::Avx2 => "avx2",
            KernelLevel::Sse2 => "sse2",
            KernelLevel::Scalar => "scalar",
        }
    }

    /// Parse a `PFL_FORCE_KERNEL_LEVEL` value (the `name()` vocabulary).
    pub fn parse(s: &str) -> Option<KernelLevel> {
        match s {
            "avx512" => Some(KernelLevel::Avx512),
            "avx2" => Some(KernelLevel::Avx2),
            "sse2" => Some(KernelLevel::Sse2),
            "scalar" => Some(KernelLevel::Scalar),
            _ => None,
        }
    }
}

/// The dispatch decision as a pure function of the escape hatch — what
/// [`active_level`] caches after reading the env. `None` (no forcing)
/// picks the fastest level the hardware supports; `Some(level)` pins that
/// tier, clamped to the next-slower level this host can actually execute
/// (e.g. `avx512` requested on an AVX2-only box runs AVX2), so a forced
/// run can never hand out an illegal instruction.
pub fn level_for(forced: Option<KernelLevel>) -> KernelLevel {
    let avail = available_levels();
    match forced {
        None => avail[0],
        Some(want) => *avail
            .iter()
            .find(|&&l| l as usize >= want as usize)
            .unwrap_or(&KernelLevel::Scalar),
    }
}

/// The tier pinned by `PFL_FORCE_KERNEL_LEVEL=<avx512|avx2|sse2|scalar>`,
/// or by the legacy alias `PFL_FORCE_SCALAR_KERNELS=1` (= `scalar`).
/// Unknown values warn once on stderr and fall through to auto-detection
/// rather than silently changing the dispatch.
pub fn forced_level() -> Option<KernelLevel> {
    if let Some(v) = std::env::var_os("PFL_FORCE_KERNEL_LEVEL") {
        let s = v.to_string_lossy();
        let parsed = KernelLevel::parse(s.trim());
        if parsed.is_none() {
            eprintln!(
                "warning: ignoring PFL_FORCE_KERNEL_LEVEL={s:?} \
                 (expected avx512|avx2|sse2|scalar)"
            );
        }
        return parsed;
    }
    if std::env::var_os("PFL_FORCE_SCALAR_KERNELS").is_some_and(|v| v == "1") {
        return Some(KernelLevel::Scalar);
    }
    None
}

static LEVEL: OnceLock<KernelLevel> = OnceLock::new();

/// The level every dispatched kernel call runs at, decided once per
/// process: the env escape hatch first, then feature detection. The env
/// read and detection happen only on the first call, so the steady state
/// is a single atomic load — the zero-allocation wire path never sees an
/// env lookup.
pub fn active_level() -> KernelLevel {
    *LEVEL.get_or_init(|| level_for(forced_level()))
}

/// Every level this host can execute, fastest first. `active_level()` is
/// always `available_levels()[0]` unless the escape hatch is set.
/// The parity tests and the kernels microbench sweep this list so one
/// process exercises every path.
#[cfg(target_arch = "x86_64")]
pub fn available_levels() -> &'static [KernelLevel] {
    if std::arch::is_x86_feature_detected!("avx512f") {
        &[KernelLevel::Avx512, KernelLevel::Avx2, KernelLevel::Sse2, KernelLevel::Scalar]
    } else if std::arch::is_x86_feature_detected!("avx2") {
        &[KernelLevel::Avx2, KernelLevel::Sse2, KernelLevel::Scalar]
    } else {
        &[KernelLevel::Sse2, KernelLevel::Scalar]
    }
}

#[cfg(not(target_arch = "x86_64"))]
pub fn available_levels() -> &'static [KernelLevel] {
    &[KernelLevel::Scalar]
}

#[cfg(target_arch = "x86_64")]
mod dispatch {
    use super::{scalar, x86, KernelLevel};

    pub fn dot_at(level: KernelLevel, a: &[f32], b: &[f32]) -> f32 {
        match level {
            // Safety: Avx512/Avx2 are only handed out by active_level() /
            // available_levels() after runtime detection succeeded.
            KernelLevel::Avx512 => unsafe { x86::dot_avx512(a, b) },
            KernelLevel::Avx2 => unsafe { x86::dot_avx2(a, b) },
            KernelLevel::Sse2 => x86::dot_sse2(a, b),
            KernelLevel::Scalar => scalar::dot(a, b),
        }
    }

    pub fn axpy_at(level: KernelLevel, x: &mut [f32], a: f32, y: &[f32]) {
        match level {
            // Safety: see dot_at.
            KernelLevel::Avx512 => unsafe { x86::axpy_avx512(x, a, y) },
            KernelLevel::Avx2 => unsafe { x86::axpy_avx2(x, a, y) },
            KernelLevel::Sse2 => x86::axpy_sse2(x, a, y),
            KernelLevel::Scalar => scalar::axpy(x, a, y),
        }
    }

    pub fn aggregation_step_at(level: KernelLevel, x: &mut [f32], a: f32, anchor: &[f32]) {
        match level {
            // Safety: see dot_at.
            KernelLevel::Avx512 => unsafe { x86::aggregation_step_avx512(x, a, anchor) },
            KernelLevel::Avx2 => unsafe { x86::aggregation_step_avx2(x, a, anchor) },
            KernelLevel::Sse2 => x86::aggregation_step_sse2(x, a, anchor),
            KernelLevel::Scalar => scalar::aggregation_step(x, a, anchor),
        }
    }

    pub fn add_assign_at(level: KernelLevel, acc: &mut [f32], v: &[f32]) {
        match level {
            // Safety: see dot_at.
            KernelLevel::Avx512 => unsafe { x86::add_assign_avx512(acc, v) },
            KernelLevel::Avx2 => unsafe { x86::add_assign_avx2(acc, v) },
            KernelLevel::Sse2 => x86::add_assign_sse2(acc, v),
            KernelLevel::Scalar => scalar::add_assign(acc, v),
        }
    }

    pub fn scale_at(level: KernelLevel, x: &mut [f32], s: f32) {
        match level {
            // Safety: see dot_at.
            KernelLevel::Avx512 => unsafe { x86::scale_avx512(x, s) },
            KernelLevel::Avx2 => unsafe { x86::scale_avx2(x, s) },
            KernelLevel::Sse2 => x86::scale_sse2(x, s),
            KernelLevel::Scalar => scalar::scale(x, s),
        }
    }
}

#[cfg(not(target_arch = "x86_64"))]
mod dispatch {
    use super::{scalar, KernelLevel};

    pub fn dot_at(_level: KernelLevel, a: &[f32], b: &[f32]) -> f32 {
        scalar::dot(a, b)
    }

    pub fn axpy_at(_level: KernelLevel, x: &mut [f32], a: f32, y: &[f32]) {
        scalar::axpy(x, a, y);
    }

    pub fn aggregation_step_at(_level: KernelLevel, x: &mut [f32], a: f32, anchor: &[f32]) {
        scalar::aggregation_step(x, a, anchor);
    }

    pub fn add_assign_at(_level: KernelLevel, acc: &mut [f32], v: &[f32]) {
        scalar::add_assign(acc, v);
    }

    pub fn scale_at(_level: KernelLevel, x: &mut [f32], s: f32) {
        scalar::scale(x, s);
    }
}

pub use dispatch::{add_assign_at, aggregation_step_at, axpy_at, dot_at, scale_at};

/// Dot product (dispatched; bit-identical to [`scalar::dot`] at every
/// level — see the module docs for the accumulator/reduction contract).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    dot_at(active_level(), a, b)
}

/// In-place `x ← x + a·y` (dispatched; bit-identical across levels).
pub fn axpy(x: &mut [f32], a: f32, y: &[f32]) {
    axpy_at(active_level(), x, a, y);
}

/// In-place aggregation step (Algorithm 1, ξ = 1):
/// `x ← x − a·(x − anchor)` (dispatched; bit-identical across levels).
pub fn aggregation_step(x: &mut [f32], a: f32, anchor: &[f32]) {
    aggregation_step_at(active_level(), x, a, anchor);
}

/// In-place `acc ← acc + v` (dispatched; bit-identical across levels).
pub fn add_assign(acc: &mut [f32], v: &[f32]) {
    add_assign_at(active_level(), acc, v);
}

/// In-place `x ← s·x` (dispatched; bit-identical across levels).
pub fn scale(x: &mut [f32], s: f32) {
    scale_at(active_level(), x, s);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn vecs(d: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let a = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let b = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        (a, b)
    }

    #[test]
    fn dot_matches_sequential_within_tolerance() {
        for d in [1usize, 7, 8, 9, 63, 123, 1000] {
            let (a, b) = vecs(d, d as u64);
            let seq: f64 = a.iter().zip(&b).map(|(x, y)| (*x as f64) * (*y as f64)).sum();
            let got = dot(&a, &b) as f64;
            assert!((got - seq).abs() < 1e-3 * (1.0 + seq.abs()),
                    "d={d}: {got} vs {seq}");
        }
    }

    #[test]
    fn axpy_is_bit_identical_to_scalar() {
        for d in [1usize, 8, 13, 123] {
            let (mut x, y) = vecs(d, 3 + d as u64);
            let mut x_ref = x.clone();
            for (xi, yi) in x_ref.iter_mut().zip(&y) {
                *xi += -0.37 * yi;
            }
            axpy(&mut x, -0.37, &y);
            assert_eq!(x, x_ref, "d={d}");
        }
    }

    #[test]
    fn aggregation_is_bit_identical_to_scalar() {
        for d in [1usize, 8, 17, 123] {
            let (mut x, m) = vecs(d, 11 + d as u64);
            let mut x_ref = x.clone();
            for (xi, mi) in x_ref.iter_mut().zip(&m) {
                *xi -= 0.25 * (*xi - mi);
            }
            aggregation_step(&mut x, 0.25, &m);
            assert_eq!(x, x_ref, "d={d}");
        }
    }

    #[test]
    fn add_assign_and_scale() {
        let (mut a, b) = vecs(29, 5);
        let expect: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
        add_assign(&mut a, &b);
        assert_eq!(a, expect);
        let expect2: Vec<f32> = a.iter().map(|x| x * 0.5).collect();
        scale(&mut a, 0.5);
        assert_eq!(a, expect2);
    }

    /// Every available intrinsic level reproduces the scalar oracle dot
    /// bit-for-bit (the full-length sweep lives in
    /// `rust/tests/kernel_parity.rs`).
    #[test]
    fn every_level_matches_the_scalar_dot_oracle() {
        for d in [9usize, 123, 1000] {
            let (a, b) = vecs(d, 21 + d as u64);
            let want = scalar::dot(&a, &b);
            for &level in available_levels() {
                let got = dot_at(level, &a, &b);
                assert_eq!(got.to_bits(), want.to_bits(),
                           "d={d} level={}", level.name());
            }
        }
    }

    #[test]
    fn dispatch_decision_honors_the_escape_hatch() {
        assert_eq!(level_for(Some(KernelLevel::Scalar)), KernelLevel::Scalar);
        assert_eq!(level_for(None), available_levels()[0]);
        // the cached decision is one of the executable levels
        assert!(available_levels().contains(&active_level()));
        assert_eq!(active_level(), level_for(forced_level()));
    }

    #[test]
    fn forced_levels_clamp_to_what_the_host_can_run() {
        for &want in
            &[KernelLevel::Avx512, KernelLevel::Avx2, KernelLevel::Sse2, KernelLevel::Scalar]
        {
            let got = level_for(Some(want));
            // never faster than requested, always executable
            assert!(got as usize >= want as usize, "{:?} -> {:?}", want, got);
            assert!(available_levels().contains(&got));
        }
        // a request the host can satisfy is honored exactly
        for &l in available_levels() {
            assert_eq!(level_for(Some(l)), l);
        }
    }

    #[test]
    fn level_names_parse_back() {
        for &l in &[
            KernelLevel::Avx512,
            KernelLevel::Avx2,
            KernelLevel::Sse2,
            KernelLevel::Scalar,
        ] {
            assert_eq!(KernelLevel::parse(l.name()), Some(l));
        }
        assert_eq!(KernelLevel::parse("neon"), None);
    }

    #[test]
    fn level_names_are_the_bench_metadata_vocabulary() {
        assert_eq!(KernelLevel::Avx512.name(), "avx512");
        assert_eq!(KernelLevel::Avx2.name(), "avx2");
        assert_eq!(KernelLevel::Sse2.name(), "sse2");
        assert_eq!(KernelLevel::Scalar.name(), "scalar");
        assert!(!available_levels().is_empty());
    }
}
