//! Flat-parameter model state and vector algebra.
//!
//! Mirrors the paper's formulation: device i owns x_i ∈ R^d, stored flat.
//! The L2 zoo (python/compile/model.py) is defined over the same flat
//! vector, so compressors, the aggregation step, and the HLO executables
//! all share one representation with zero translation.
//!
//! Layout: client state lives behind the pluggable [`ClientStore`] trait
//! ([`store`]): the lockstep configuration keeps the n per-client models
//! eagerly in one contiguous [`ParamMatrix`] ([`DenseStore`], row per
//! client) and runs the 8-lane [`kernels`] over row views; at fleet scale
//! the copy-on-write [`ShardedStore`] keeps only the *divergent* rows
//! (resident memory ∝ touched clients, not fleet size). One generic round
//! engine ([`crate::algorithms::engine`]) drives either. The free
//! functions below are thin wrappers kept for the nested-`Vec` call sites
//! (tests, reference oracle, examples) and are bit-compatible with the
//! kernel path.

pub mod kernels;
pub mod matrix;
pub mod sharded;
pub mod store;

pub use matrix::ParamMatrix;
pub use sharded::ShardedStore;
pub use store::{ClientStore, DenseStore, ModelView, REDUCE_LEAF};

/// In-place `x ← x + a·y`.
pub fn axpy(x: &mut [f32], a: f32, y: &[f32]) {
    kernels::axpy(x, a, y);
}

/// In-place aggregation step (Algorithm 1, ξ = 1):
/// `x ← x − a·(x − anchor)` ≡ `x ← (1−a)·x + a·anchor`.
pub fn aggregation_step(x: &mut [f32], a: f32, anchor: &[f32]) {
    kernels::aggregation_step(x, a, anchor);
}

/// Mean of n equal-length vectors.
pub fn mean_of(vectors: &[Vec<f32>]) -> Vec<f32> {
    assert!(!vectors.is_empty());
    let d = vectors[0].len();
    let mut out = vec![0.0f32; d];
    for v in vectors {
        debug_assert_eq!(v.len(), d);
        for (o, x) in out.iter_mut().zip(v) {
            *o += x;
        }
    }
    let inv = 1.0 / vectors.len() as f32;
    for o in &mut out {
        *o *= inv;
    }
    out
}

/// Weighted mean (FedAvg aggregation with |D_i| weights).
pub fn weighted_mean(vectors: &[Vec<f32>], weights: &[f64]) -> Vec<f32> {
    assert_eq!(vectors.len(), weights.len());
    assert!(!vectors.is_empty());
    let total: f64 = weights.iter().sum();
    let d = vectors[0].len();
    let mut out = vec![0.0f32; d];
    for (v, &w) in vectors.iter().zip(weights) {
        let s = (w / total) as f32;
        for (o, x) in out.iter_mut().zip(v) {
            *o += s * x;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let mut x = vec![1.0, 2.0];
        axpy(&mut x, 2.0, &[10.0, 20.0]);
        assert_eq!(x, vec![21.0, 42.0]);
    }

    #[test]
    fn aggregation_moves_toward_anchor() {
        let mut x = vec![0.0f32, 10.0];
        aggregation_step(&mut x, 0.25, &[4.0, 2.0]);
        assert_eq!(x, vec![1.0, 8.0]);
        // a = 1 jumps exactly onto the anchor (the FedAvg-equivalence regime)
        let mut y = vec![-3.0f32, 7.0];
        aggregation_step(&mut y, 1.0, &[4.0, 2.0]);
        assert_eq!(y, vec![4.0, 2.0]);
    }

    #[test]
    fn aggregation_preserves_mean_when_anchor_is_mean() {
        // the uncompressed-L2GD invariant: x̄ is a fixed point
        let mut xs = vec![vec![1.0f32, 0.0], vec![3.0, 4.0]];
        let avg = mean_of(&xs);
        for x in xs.iter_mut() {
            aggregation_step(x, 0.3, &avg);
        }
        let new_avg = mean_of(&xs);
        for (a, b) in avg.iter().zip(&new_avg) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn means() {
        let vs = vec![vec![1.0f32, 2.0], vec![3.0, 6.0]];
        assert_eq!(mean_of(&vs), vec![2.0, 4.0]);
        assert_eq!(weighted_mean(&vs, &[3.0, 1.0]), vec![1.5, 3.0]);
    }
}
