//! Sharded, copy-on-write client-state store — the million-device
//! counterpart of [`super::ParamMatrix`].
//!
//! The dense matrix materializes every client's personalized model, so
//! memory (and every sweep) scales with the *fleet* size. The paper's
//! probabilistic protocol only ever touches a sampled cohort per event, so
//! at fleet scale almost every device still equals the shared state it was
//! initialized (or last fully reset) to. `ShardedStore` stores **only the
//! divergent rows**:
//!
//! * Clients are partitioned into `S = ⌈n / shard_size⌉` contiguous
//!   shards. Each shard owns a compact row arena plus an id → slot map for
//!   its materialized clients.
//! * A client with no materialized row implicitly equals the engine's
//!   *base* vector (the shared init, or the last fleet-wide reset anchor —
//!   the engine owns that vector and passes it in; the store never copies
//!   it per client).
//! * A row **materializes on the first divergent step** (local gradient
//!   step, or an aggregation step with coefficient ≠ 1): the base is
//!   copied in, then mutated in place. Until then the device costs zero
//!   resident row bytes.
//! * A fleet-wide reset (`clear`) releases every row at once — the
//!   "fully reset by a broadcast it equals" transition where the engine
//!   re-bases the implicit value onto the new anchor.
//!
//! Resident memory therefore scales with |ever-touched clients|, not the
//! fleet size — asserted via [`ShardedStore::materialized_rows`] /
//! [`ShardedStore::resident_bytes`] (occupancy, not RSS) in the
//! integration suite and the `pfl bench` scale section.
//!
//! Shard boundaries are multiples of the aggregation tree's leaf size (the
//! engine picks `shard_size` via [`ShardedStore::auto_shard_size`]), so
//! every reduction leaf lives inside exactly one shard and the
//! per-shard partial accumulation composes bit-exactly into the dense
//! engine's flat leaf reduction.

use std::collections::HashMap;

use crate::util::threadpool::ThreadPool;

/// One contiguous client-range shard: a compact arena of materialized rows.
#[derive(Clone, Debug, Default)]
struct Shard {
    /// global client id per materialized row, in materialization order
    ids: Vec<u32>,
    /// client id → row slot in `rows`
    slot_of: HashMap<u32, u32>,
    /// row-major arena, `ids.len() × d`
    rows: Vec<f32>,
}

impl Shard {
    /// Copy-on-write materialization local to this shard (see
    /// [`ShardedStore::materialize`]). Safe to run concurrently across
    /// *different* shards — each shard's arena is independent.
    fn materialize(&mut self, id: u32, d: usize, base: &[f32]) -> &mut [f32] {
        let slot = match self.slot_of.get(&id) {
            Some(&slot) => slot as usize,
            None => {
                let slot = self.ids.len();
                self.ids.push(id);
                self.slot_of.insert(id, slot as u32);
                self.rows.extend_from_slice(base);
                slot
            }
        };
        let at = slot * d;
        &mut self.rows[at..at + d]
    }

    /// Mutable access to an already-materialized row of this shard.
    fn row_mut(&mut self, id: u32, d: usize) -> Option<&mut [f32]> {
        self.slot_of.get(&id).copied().map(move |slot| {
            let at = slot as usize * d;
            &mut self.rows[at..at + d]
        })
    }
}

/// Raw-pointer wrapper so disjoint per-shard `&mut` access can cross the
/// pool's `Sync` closure boundary (the same pattern the pool's own
/// `scope_chunks_mut` uses over the dense matrix).
struct ShardPtr(*mut Shard);
unsafe impl Send for ShardPtr {}
unsafe impl Sync for ShardPtr {}

#[derive(Clone, Debug)]
pub struct ShardedStore {
    n: usize,
    d: usize,
    shard_size: usize,
    shards: Vec<Shard>,
}

impl ShardedStore {
    pub fn new(n: usize, d: usize, shard_size: usize) -> ShardedStore {
        assert!(shard_size > 0, "shard_size must be positive");
        assert!(n > 0, "empty fleet");
        let s = n.div_ceil(shard_size);
        ShardedStore { n, d, shard_size, shards: vec![Shard::default(); s] }
    }

    /// Shard size for an `n`-client fleet with reduction leaves of `leaf`
    /// clients: ~256 shards for large fleets, one shard for small ones,
    /// always a multiple of `leaf` so no reduction leaf straddles a shard
    /// boundary.
    pub fn auto_shard_size(n: usize, leaf: usize) -> usize {
        let leaf = leaf.max(1);
        if n <= leaf * 256 {
            return n.next_multiple_of(leaf);
        }
        n.div_ceil(256).next_multiple_of(leaf)
    }

    /// Fleet size (materialized or not).
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    pub fn shard_size(&self) -> usize {
        self.shard_size
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Which shard client `i` belongs to.
    pub fn shard_of(&self, i: usize) -> usize {
        debug_assert!(i < self.n);
        i / self.shard_size
    }

    /// The materialized row of client `i`, if it has diverged from the
    /// base (`None` ⇒ the client implicitly equals the base vector).
    pub fn row(&self, i: usize) -> Option<&[f32]> {
        let sh = &self.shards[self.shard_of(i)];
        sh.slot_of.get(&(i as u32)).map(|&slot| {
            let at = slot as usize * self.d;
            &sh.rows[at..at + self.d]
        })
    }

    /// Mutable access to an already-materialized row.
    pub fn row_mut(&mut self, i: usize) -> Option<&mut [f32]> {
        let d = self.d;
        let s = self.shard_of(i);
        self.shards[s].row_mut(i as u32, d)
    }

    /// Copy-on-write materialization: return client `i`'s row, copying
    /// `base` in first if the client had not diverged yet. The divergent
    /// step's mutation happens in place on the returned slice.
    pub fn materialize(&mut self, i: usize, base: &[f32]) -> &mut [f32] {
        debug_assert_eq!(base.len(), self.d);
        let d = self.d;
        let s = self.shard_of(i);
        self.shards[s].materialize(i as u32, d, base)
    }

    /// Run `f(id, row)` for every client of the sorted `cohort`, with the
    /// per-shard runs `spans` (`[lo, hi)` index ranges into `cohort`, one
    /// per distinct shard, in order — contiguous because `shard_of` is
    /// monotonic over a sorted cohort) executing concurrently on `pool`.
    ///
    /// `materialize_missing` selects the copy-on-write behaviour: `true`
    /// materializes absent rows from `base` first (local-sweep semantics),
    /// `false` skips clients that still equal the base (the engine's
    /// cached-aggregation no-op while the anchor *is* the base).
    ///
    /// Bit-identity to the sequential cohort loop: shards own disjoint
    /// arenas, each span runs its ids in cohort (ascending) order on one
    /// worker, so every shard materializes rows in exactly the order the
    /// sequential loop would produce, and `f` only touches the row it was
    /// handed — the result is independent of the pool size. Worker-side
    /// allocations are the same arena/map growth the sequential loop
    /// performs, so the CountingAlloc budgets are unchanged.
    ///
    /// # Panics
    /// Debug builds assert that every span is a non-empty single-shard
    /// range and that consecutive spans hit strictly increasing shards —
    /// the soundness contract for handing each worker its own `&mut
    /// Shard`.
    pub fn par_cohort_rows(
        &mut self,
        pool: &ThreadPool,
        cohort: &[u32],
        spans: &[(u32, u32)],
        base: &[f32],
        materialize_missing: bool,
        f: impl Fn(usize, &mut [f32]) + Sync,
    ) {
        debug_assert_eq!(base.len(), self.d);
        debug_assert!(spans.windows(2).all(|w| {
            w[0].1 <= w[1].0
                && self.shard_of(cohort[w[0].0 as usize] as usize)
                    < self.shard_of(cohort[w[1].0 as usize] as usize)
        }), "spans must be ordered and shard-distinct");
        let d = self.d;
        let shard_size = self.shard_size;
        let shards = ShardPtr(self.shards.as_mut_ptr());
        pool.scope_for(spans.len(), |j| {
            let (lo, hi) = spans[j];
            let ids = &cohort[lo as usize..hi as usize];
            debug_assert!(!ids.is_empty(), "empty span");
            let s = ids[0] as usize / shard_size;
            debug_assert!(ids.iter().all(|&i| i as usize / shard_size == s),
                          "span straddles shards");
            // Safety: each span addresses a distinct shard (debug-checked
            // above), so this &mut aliases no other worker's; the borrow
            // of `self.shards` outlives the scope (scope_for blocks).
            let shard = unsafe { &mut *shards.0.add(s) };
            for &id in ids {
                if materialize_missing {
                    f(id as usize, shard.materialize(id, d, base));
                } else if let Some(row) = shard.row_mut(id, d) {
                    f(id as usize, row);
                }
            }
        });
    }

    /// Release one row (its client snaps back to the implicit base).
    /// Swap-remove: the shard's last row fills the hole.
    pub fn release(&mut self, i: usize) {
        let d = self.d;
        let s = self.shard_of(i);
        let sh = &mut self.shards[s];
        let Some(slot) = sh.slot_of.remove(&(i as u32)) else {
            return;
        };
        let slot = slot as usize;
        let last = sh.ids.len() - 1;
        if slot != last {
            let moved = sh.ids[last];
            sh.ids[slot] = moved;
            sh.slot_of.insert(moved, slot as u32);
            let (head, tail) = sh.rows.split_at_mut(last * d);
            head[slot * d..slot * d + d].copy_from_slice(&tail[..d]);
        }
        sh.ids.truncate(last);
        sh.rows.truncate(last * d);
    }

    /// Fleet-wide reset: every client equals the (new) base again. Keeps
    /// the arenas' capacity.
    pub fn clear(&mut self) {
        for sh in &mut self.shards {
            sh.ids.clear();
            sh.slot_of.clear();
            sh.rows.clear();
        }
    }

    /// Occupancy: number of materialized (divergent) rows.
    pub fn materialized_rows(&self) -> usize {
        self.shards.iter().map(|s| s.ids.len()).sum()
    }

    /// Materialized rows in shard `s`.
    pub fn shard_rows(&self, s: usize) -> usize {
        self.shards[s].ids.len()
    }

    /// Estimated resident client-state bytes: row arenas plus per-row
    /// bookkeeping (ids + map entries), by capacity. This is the quantity
    /// the scale tests bound against |touched clients| — deliberately the
    /// store's own accounting, not process RSS.
    pub fn resident_bytes(&self) -> usize {
        self.shards
            .iter()
            .map(|s| {
                s.rows.capacity() * std::mem::size_of::<f32>()
                    + s.ids.capacity() * std::mem::size_of::<u32>()
                    // HashMap entry ≈ key + value + control byte, over
                    // capacity
                    + s.slot_of.capacity() * (std::mem::size_of::<(u32, u32)>() + 1)
            })
            .sum()
    }

    /// Visit every materialized row (shards in order, rows in
    /// materialization order — deterministic because materialization is).
    pub fn for_each_row(&self, mut f: impl FnMut(usize, &[f32])) {
        for sh in &self.shards {
            for (j, &id) in sh.ids.iter().enumerate() {
                let at = j * self.d;
                f(id as usize, &sh.rows[at..at + self.d]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_shard_size_is_leaf_aligned() {
        for n in [1, 5, 8, 9, 4096, 100_000, 1_000_000] {
            let s = ShardedStore::auto_shard_size(n, 8);
            assert_eq!(s % 8, 0, "n={n} shard_size={s}");
            assert!(s > 0);
            let store = ShardedStore::new(n, 4, s);
            assert!(store.n_shards() >= 1);
            assert!(store.n_shards() <= 260, "n={n}: {} shards", store.n_shards());
        }
        // small fleets collapse to one shard
        assert_eq!(ShardedStore::new(5, 4, ShardedStore::auto_shard_size(5, 8))
                       .n_shards(),
                   1);
    }

    #[test]
    fn materialize_copies_base_then_diverges() {
        let mut st = ShardedStore::new(10, 3, 8);
        let base = [1.0f32, 2.0, 3.0];
        assert!(st.row(4).is_none());
        assert_eq!(st.materialized_rows(), 0);
        {
            let r = st.materialize(4, &base);
            assert_eq!(r, &base);
            r[0] = -9.0;
        }
        assert_eq!(st.row(4).unwrap(), &[-9.0, 2.0, 3.0]);
        assert_eq!(st.materialized_rows(), 1);
        // re-materialize returns the existing (divergent) row, not base
        assert_eq!(st.materialize(4, &base), &[-9.0, 2.0, 3.0]);
        assert_eq!(st.materialized_rows(), 1);
        // untouched neighbours stay implicit
        assert!(st.row(3).is_none());
        assert!(st.row_mut(3).is_none());
    }

    #[test]
    fn release_swap_removes_and_clear_resets() {
        let mut st = ShardedStore::new(20, 2, 8);
        let base = [0.0f32, 0.0];
        for i in [1usize, 2, 3] {
            let r = st.materialize(i, &base);
            r[0] = i as f32;
        }
        assert_eq!(st.materialized_rows(), 3);
        st.release(1); // row 3 swaps into row 1's slot
        assert!(st.row(1).is_none());
        assert_eq!(st.row(2).unwrap()[0], 2.0);
        assert_eq!(st.row(3).unwrap()[0], 3.0);
        assert_eq!(st.materialized_rows(), 2);
        st.release(1); // double release is a no-op
        assert_eq!(st.materialized_rows(), 2);
        st.clear();
        assert_eq!(st.materialized_rows(), 0);
        assert!(st.row(2).is_none());
        assert!(st.row(3).is_none());
    }

    #[test]
    fn rows_land_in_their_shard() {
        let mut st = ShardedStore::new(32, 1, 8);
        assert_eq!(st.n_shards(), 4);
        assert_eq!(st.shard_of(7), 0);
        assert_eq!(st.shard_of(8), 1);
        assert_eq!(st.shard_of(31), 3);
        st.materialize(9, &[1.0]);
        st.materialize(30, &[2.0]);
        assert_eq!(st.shard_rows(0), 0);
        assert_eq!(st.shard_rows(1), 1);
        assert_eq!(st.shard_rows(3), 1);
        let mut seen = Vec::new();
        st.for_each_row(|id, row| seen.push((id, row[0])));
        assert_eq!(seen, vec![(9, 1.0), (30, 2.0)]);
    }

    /// The parallel per-shard cohort sweep materializes the same rows, in
    /// the same per-shard order, with the same values as the sequential
    /// loop — at several pool sizes, with and without the skip-missing
    /// mode.
    #[test]
    fn par_cohort_rows_matches_sequential_loop() {
        let d = 5;
        let n = 1000;
        let base = vec![1.0f32; d];
        let cohort: Vec<u32> = (0..n as u32).step_by(7).collect();
        let spans = spans_of(&cohort, 8);
        assert!(spans.len() > 1, "cohort must span several shards");

        // sequential oracle: materialize + touch in cohort order
        let mut seq = ShardedStore::new(n, d, 8);
        for &i in &cohort {
            let row = seq.materialize(i as usize, &base);
            row[0] += i as f32;
        }

        for pool_size in [1usize, 2, 8] {
            let pool = ThreadPool::new(pool_size);
            let mut par = ShardedStore::new(n, d, 8);
            par.par_cohort_rows(&pool, &cohort, &spans, &base, true,
                                |i, row| row[0] += i as f32);
            assert_eq!(par.materialized_rows(), seq.materialized_rows());
            let mut a = Vec::new();
            let mut b = Vec::new();
            seq.for_each_row(|i, r| a.push((i, r.to_vec())));
            par.for_each_row(|i, r| b.push((i, r.to_vec())));
            assert_eq!(a, b, "pool={pool_size}: order or values diverge");

            // skip-missing mode touches only already-resident rows
            let before = par.materialized_rows();
            let wider: Vec<u32> = (0..n as u32).step_by(3).collect();
            let wider_spans = spans_of(&wider, 8);
            par.par_cohort_rows(&pool, &wider, &wider_spans, &base, false,
                                |_, row| row[1] = -3.0);
            assert_eq!(par.materialized_rows(), before,
                       "skip mode must not materialize");
            for &i in &wider {
                match par.row(i as usize) {
                    Some(r) => assert_eq!(r[1], -3.0, "resident id {i}"),
                    None => assert!(!cohort.contains(&i)),
                }
            }
        }
    }

    /// Test-local span partition (the engine owns the production one).
    fn spans_of(cohort: &[u32], shard_size: usize) -> Vec<(u32, u32)> {
        let mut out = Vec::new();
        let mut start = 0usize;
        while start < cohort.len() {
            let s = cohort[start] as usize / shard_size;
            let mut end = start + 1;
            while end < cohort.len() && cohort[end] as usize / shard_size == s {
                end += 1;
            }
            out.push((start as u32, end as u32));
            start = end;
        }
        out
    }

    #[test]
    fn resident_bytes_track_occupancy_not_fleet() {
        let d = 64;
        let mut st = ShardedStore::new(1_000_000, d,
                                       ShardedStore::auto_shard_size(1_000_000, 8));
        let base = vec![0.5f32; d];
        let empty = st.resident_bytes();
        // an untouched million-device store costs (near) nothing
        assert!(empty < 64 * 1024, "empty store resident {empty} B");
        for i in (0..1000).map(|k| k * 997) {
            st.materialize(i, &base);
        }
        let occupied = st.resident_bytes();
        assert_eq!(st.materialized_rows(), 1000);
        // proportional to touched rows (×4 slack for Vec/HashMap growth
        // doubling), never to the 10⁶ fleet
        let per_row = d * 4 + 32;
        assert!(occupied <= empty + 4 * 1000 * per_row,
                "resident {occupied} B for 1000 rows");
    }
}
