//! Pluggable client-state storage: the [`ClientStore`] trait behind the
//! generic round engine ([`crate::algorithms::engine::Engine`]).
//!
//! The paper's formulation gives every device a personalized model
//! x_i ∈ R^d. *How* the fleet's { x_i } are stored is an implementation
//! axis orthogonal to the protocol itself, so the engine is generic over
//! it:
//!
//! * [`DenseStore`] — every row eagerly materialized in one contiguous
//!   [`ParamMatrix`]. O(fleet) memory, O(1) row access, and the engine
//!   can run pooled full-fleet sweeps straight over the flat buffer. The
//!   lockstep configuration ([`crate::algorithms::L2gdEngine`]).
//! * [`crate::model::ShardedStore`] — copy-on-write: only rows that have
//!   *diverged* from the shared `base` vector are resident, in ~256
//!   leaf-aligned shards. Resident memory ∝ |ever-touched clients|, the
//!   million-device configuration
//!   ([`crate::algorithms::ShardedL2gdEngine`]).
//!
//! The contract both impls share:
//!
//! * `row(i)` returns the client's materialized row, or `None` when the
//!   client implicitly equals the engine-owned `base` vector (never for a
//!   dense store).
//! * `materialize(i, base)` is copy-on-write: the first divergent step
//!   copies `base` in, later calls return the existing row.
//! * Occupancy (`materialized_rows`, `resident_bytes`) is the store's own
//!   accounting — what the mega-fleet resident-bytes bounds assert
//!   against, deliberately not process RSS.
//! * **Leaf alignment**: stores promise that the fixed [`REDUCE_LEAF`]
//!   aggregation leaves of the master's ȳ decode-accumulate never
//!   straddle an internal storage boundary, so per-leaf partial sums
//!   compose bit-exactly into one flat reduction whichever store runs
//!   under the engine ([`ShardedStore::auto_shard_size`] picks shard
//!   sizes as leaf multiples; the dense matrix is trivially aligned).

use super::matrix::ParamMatrix;
use super::sharded::ShardedStore;

/// Clients per leaf of the master's decode-accumulate tree reduction.
/// Constant (not pool-derived) so the reduction order — and therefore the
/// training series — is machine-independent; n ≤ LEAF degenerates to the
/// seed's exact sequential accumulation. Sharded stores keep shard
/// boundaries at multiples of it so no leaf straddles a shard.
pub const REDUCE_LEAF: usize = 8;

/// Per-client model state as seen by [`crate::algorithms::evaluate`]:
/// truly personalized (a [`ParamMatrix`] row per client), one shared
/// global model (the lockstep FedAvg/FedOpt case — the seed materialized
/// `n` clones of `w` per evaluation to express this), or copy-on-write
/// sharded state (a [`ShardedStore`] where an unmaterialized client
/// implicitly equals the `base` vector).
#[derive(Clone, Copy)]
pub enum ModelView<'a> {
    PerClient(&'a ParamMatrix),
    Shared { model: &'a [f32], n: usize },
    Cow { store: &'a ShardedStore, base: &'a [f32] },
}

impl<'a> ModelView<'a> {
    pub fn n(&self) -> usize {
        match self {
            ModelView::PerClient(m) => m.n_rows(),
            ModelView::Shared { n, .. } => *n,
            ModelView::Cow { store, .. } => store.len(),
        }
    }

    pub fn row(&self, i: usize) -> &'a [f32] {
        match self {
            ModelView::PerClient(m) => m.row(i),
            ModelView::Shared { model, .. } => model,
            ModelView::Cow { store, base } => store.row(i).unwrap_or(base),
        }
    }

    /// Global model = mean of the client models, accumulated in client
    /// order — bit-compatible with the seed's `mean_of` (including the
    /// `Shared` case, where the seed averaged n identical clones, and the
    /// `Cow` case, which walks every client's effective row in index
    /// order exactly as the dense matrix does).
    pub fn mean_into(&self, out: &mut [f32]) {
        match self {
            ModelView::PerClient(m) => m.mean_into(out),
            ModelView::Shared { model, n } => {
                out.fill(0.0);
                for _ in 0..*n {
                    super::kernels::add_assign(out, model);
                }
                super::kernels::scale(out, 1.0 / *n as f32);
            }
            ModelView::Cow { store, base } => {
                out.fill(0.0);
                for i in 0..store.len() {
                    super::kernels::add_assign(out, store.row(i).unwrap_or(base));
                }
                super::kernels::scale(out, 1.0 / store.len() as f32);
            }
        }
    }
}

/// Pluggable per-client model storage for the generic round engine. See
/// the module docs for the contract.
pub trait ClientStore {
    /// `true` when rows are copy-on-write against the engine's base
    /// vector (undiverged clients cost nothing and full-fleet exact
    /// resets re-base + release). `false` when every row is eagerly
    /// resident and release is meaningless.
    const COW: bool;

    /// Build the store for an `n`-client fleet at dimension `d` with the
    /// shared initial model `init` (dense stores replicate it; sparse
    /// stores remember nothing — the engine keeps `init` as its base).
    fn new_fleet(n: usize, d: usize, init: &[f32]) -> Self
    where
        Self: Sized;

    /// Fleet size (materialized or not).
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn dim(&self) -> usize;

    /// Client `i`'s materialized row; `None` ⇒ implicitly the base.
    fn row(&self, i: usize) -> Option<&[f32]>;

    /// Copy-on-write materialization (see [`ShardedStore::materialize`]).
    fn materialize(&mut self, i: usize, base: &[f32]) -> &mut [f32];

    /// Release one row back to the implicit base (no-op on dense stores).
    fn release(&mut self, i: usize);

    /// Occupancy: resident (divergent) rows.
    fn materialized_rows(&self) -> usize;

    /// Resident client-state bytes by the store's own accounting.
    fn resident_bytes(&self) -> usize;

    /// Visit every materialized row in the store's deterministic order.
    fn for_each_row<F: FnMut(usize, &[f32])>(&self, f: F);

    /// Clients per transport attribution bucket
    /// ([`crate::transport::Network::sharded`]): 1 for per-client
    /// attribution, the shard size for fleet-scale stores.
    fn link_shard_size(&self) -> usize;

    /// Evaluation view over the fleet given the engine's base vector.
    fn view<'a>(&'a self, base: &'a [f32]) -> ModelView<'a>;

    /// The flat matrix, when this store is dense — the engine's pooled
    /// full-fleet sweeps go straight over it. `None` for sparse stores.
    fn as_dense_mut(&mut self) -> Option<&mut ParamMatrix> {
        None
    }

    /// The copy-on-write sharded store, when this store is one — the
    /// engine's pooled per-shard cohort sweeps
    /// ([`ShardedStore::par_cohort_rows`]) go straight over it. `None`
    /// for dense stores.
    fn as_sharded_mut(&mut self) -> Option<&mut ShardedStore> {
        None
    }
}

/// Eager dense storage: one [`ParamMatrix`] row per client.
#[derive(Clone, Debug)]
pub struct DenseStore {
    m: ParamMatrix,
}

impl DenseStore {
    /// The underlying matrix (row i = client i).
    pub fn matrix(&self) -> &ParamMatrix {
        &self.m
    }
}

impl ClientStore for DenseStore {
    const COW: bool = false;

    fn new_fleet(n: usize, _d: usize, init: &[f32]) -> DenseStore {
        DenseStore { m: ParamMatrix::replicate(n, init) }
    }

    fn len(&self) -> usize {
        self.m.n_rows()
    }

    fn dim(&self) -> usize {
        self.m.dim()
    }

    fn row(&self, i: usize) -> Option<&[f32]> {
        Some(self.m.row(i))
    }

    fn materialize(&mut self, i: usize, _base: &[f32]) -> &mut [f32] {
        self.m.row_mut(i)
    }

    fn release(&mut self, _i: usize) {}

    fn materialized_rows(&self) -> usize {
        self.m.n_rows()
    }

    fn resident_bytes(&self) -> usize {
        self.m.as_slice().len() * std::mem::size_of::<f32>()
    }

    fn for_each_row<F: FnMut(usize, &[f32])>(&self, mut f: F) {
        for (i, row) in self.m.rows().enumerate() {
            f(i, row);
        }
    }

    fn link_shard_size(&self) -> usize {
        1
    }

    fn view<'a>(&'a self, _base: &'a [f32]) -> ModelView<'a> {
        ModelView::PerClient(&self.m)
    }

    fn as_dense_mut(&mut self) -> Option<&mut ParamMatrix> {
        Some(&mut self.m)
    }
}

impl ClientStore for ShardedStore {
    const COW: bool = true;

    fn new_fleet(n: usize, d: usize, _init: &[f32]) -> ShardedStore {
        ShardedStore::new(n, d, ShardedStore::auto_shard_size(n, REDUCE_LEAF))
    }

    fn len(&self) -> usize {
        ShardedStore::len(self)
    }

    fn dim(&self) -> usize {
        ShardedStore::dim(self)
    }

    fn row(&self, i: usize) -> Option<&[f32]> {
        ShardedStore::row(self, i)
    }

    fn materialize(&mut self, i: usize, base: &[f32]) -> &mut [f32] {
        ShardedStore::materialize(self, i, base)
    }

    fn release(&mut self, i: usize) {
        ShardedStore::release(self, i)
    }

    fn materialized_rows(&self) -> usize {
        ShardedStore::materialized_rows(self)
    }

    fn resident_bytes(&self) -> usize {
        ShardedStore::resident_bytes(self)
    }

    fn for_each_row<F: FnMut(usize, &[f32])>(&self, f: F) {
        ShardedStore::for_each_row(self, f)
    }

    fn link_shard_size(&self) -> usize {
        self.shard_size()
    }

    fn view<'a>(&'a self, base: &'a [f32]) -> ModelView<'a> {
        ModelView::Cow { store: self, base }
    }

    fn as_sharded_mut(&mut self) -> Option<&mut ShardedStore> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exercise<S: ClientStore>(mut st: S, n: usize) {
        let base = vec![1.0f32; st.dim()];
        assert_eq!(st.len(), n);
        // materialize copies base in, then diverges in place
        {
            let r = st.materialize(2, &base);
            assert_eq!(r, &base[..]);
            r[0] = 7.0;
        }
        assert_eq!(st.row(2).unwrap()[0], 7.0);
        assert!(st.materialized_rows() >= 1);
        assert!(st.resident_bytes() > 0);
        let mut seen = false;
        st.for_each_row(|i, row| {
            if i == 2 {
                assert_eq!(row[0], 7.0);
                seen = true;
            }
        });
        assert!(seen, "for_each_row must visit the divergent row");
        assert!(st.link_shard_size() >= 1);
    }

    #[test]
    fn dense_store_contract() {
        let init = vec![1.0f32; 4];
        let st = DenseStore::new_fleet(6, 4, &init);
        assert!(!DenseStore::COW);
        assert_eq!(st.materialized_rows(), 6, "dense rows are always resident");
        assert!(matches!(st.view(&init), ModelView::PerClient(_)));
        exercise(st, 6);
    }

    #[test]
    fn sharded_store_contract() {
        let init = vec![1.0f32; 4];
        let st = <ShardedStore as ClientStore>::new_fleet(100, 4, &init);
        assert!(<ShardedStore as ClientStore>::COW);
        assert_eq!(ClientStore::materialized_rows(&st), 0, "CoW starts empty");
        assert_eq!(st.shard_size() % REDUCE_LEAF, 0, "leaf-aligned shards");
        assert!(matches!(st.view(&init), ModelView::Cow { .. }));
        exercise(st, 100);
    }

    #[test]
    fn release_is_noop_on_dense_and_reclaims_on_sharded() {
        let init = vec![0.5f32; 3];
        let mut d = DenseStore::new_fleet(3, 3, &init);
        d.materialize(1, &init)[0] = 9.0;
        d.release(1);
        assert_eq!(d.row(1).unwrap()[0], 9.0, "dense release keeps the row");
        let mut s = <ShardedStore as ClientStore>::new_fleet(16, 3, &init);
        s.materialize(1, &init)[0] = 9.0;
        ClientStore::release(&mut s, 1);
        assert!(ClientStore::row(&s, 1).is_none(), "sharded release reclaims");
    }
}
