//! Contiguous structure-of-arrays model state: one flat `n × d` buffer,
//! row per client.
//!
//! The seed stored per-client models as `Vec<Vec<f32>>` — n separately
//! allocated, pointer-chased heap blocks. The round engine sweeps every
//! client every step (local gradients, aggregation), so the layout is the
//! hot-path data structure: a single flat buffer keeps the sweep
//! prefetcher-friendly, lets the thread pool hand out disjoint `&mut` row
//! chunks with no per-row allocation, and makes the whole state one
//! `memcpy` to snapshot.

use super::kernels;

#[derive(Clone, Debug, PartialEq)]
pub struct ParamMatrix {
    data: Vec<f32>,
    n: usize,
    d: usize,
}

impl ParamMatrix {
    pub fn zeros(n: usize, d: usize) -> ParamMatrix {
        ParamMatrix { data: vec![0.0; n * d], n, d }
    }

    /// n copies of one row (Algorithm 1's shared x̄^{-1} init).
    pub fn replicate(n: usize, row: &[f32]) -> ParamMatrix {
        let d = row.len();
        let mut data = Vec::with_capacity(n * d);
        for _ in 0..n {
            data.extend_from_slice(row);
        }
        ParamMatrix { data, n, d }
    }

    /// Build from nested rows (interop with the seed layout).
    pub fn from_nested(rows: &[Vec<f32>]) -> ParamMatrix {
        assert!(!rows.is_empty());
        let d = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * d);
        for r in rows {
            assert_eq!(r.len(), d, "ragged rows");
            data.extend_from_slice(r);
        }
        ParamMatrix { data, n: rows.len(), d }
    }

    pub fn n_rows(&self) -> usize {
        self.n
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.d..(i + 1) * self.d]
    }

    /// All rows, in order (a `chunks_exact` view over the flat buffer).
    pub fn rows(&self) -> std::slice::ChunksExact<'_, f32> {
        self.data.chunks_exact(self.d)
    }

    pub fn rows_mut(&mut self) -> std::slice::ChunksExactMut<'_, f32> {
        self.data.chunks_exact_mut(self.d)
    }

    /// The flat buffer (row-major): what the pool's chunk sweeps take.
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Row mean into a caller buffer. Accumulates rows in index order —
    /// the same association as the seed's `mean_of`, so results are
    /// bit-identical to the nested-layout path.
    pub fn mean_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.d);
        out.fill(0.0);
        for row in self.rows() {
            kernels::add_assign(out, row);
        }
        kernels::scale(out, 1.0 / self.n as f32);
    }

    /// Weighted row mean (FedAvg aggregation with |D_i| weights), same
    /// operation order as the seed's `weighted_mean`.
    pub fn weighted_mean_into(&self, weights: &[f64], out: &mut [f32]) {
        assert_eq!(weights.len(), self.n);
        assert_eq!(out.len(), self.d);
        let total: f64 = weights.iter().sum();
        out.fill(0.0);
        for (row, &w) in self.rows().zip(weights) {
            kernels::axpy(out, (w / total) as f32, row);
        }
    }

    /// Materialize the seed's nested layout (tests / interop).
    pub fn to_nested(&self) -> Vec<Vec<f32>> {
        self.rows().map(|r| r.to_vec()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replicate_and_rows() {
        let m = ParamMatrix::replicate(3, &[1.0, 2.0]);
        assert_eq!(m.n_rows(), 3);
        assert_eq!(m.dim(), 2);
        assert_eq!(m.row(2), &[1.0, 2.0]);
        assert_eq!(m.rows().count(), 3);
    }

    #[test]
    fn row_mut_is_disjoint_storage() {
        let mut m = ParamMatrix::zeros(2, 3);
        m.row_mut(1).copy_from_slice(&[1.0, 2.0, 3.0]);
        assert_eq!(m.row(0), &[0.0, 0.0, 0.0]);
        assert_eq!(m.row(1), &[1.0, 2.0, 3.0]);
        assert_eq!(m.as_slice(), &[0.0, 0.0, 0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn mean_matches_seed_mean_of_bitwise() {
        let nested = vec![vec![1.0f32, 0.25, -3.0], vec![0.5, 4.0, 9.5],
                          vec![-2.25, 1.125, 0.75]];
        let m = ParamMatrix::from_nested(&nested);
        let mut out = vec![0.0f32; 3];
        m.mean_into(&mut out);
        assert_eq!(out, super::super::mean_of(&nested));
    }

    #[test]
    fn weighted_mean_matches_seed_bitwise() {
        let nested = vec![vec![1.0f32, 2.0], vec![3.0, 6.0], vec![-1.0, 0.5]];
        let w = [3.0, 1.0, 2.0];
        let m = ParamMatrix::from_nested(&nested);
        let mut out = vec![0.0f32; 2];
        m.weighted_mean_into(&w, &mut out);
        assert_eq!(out, super::super::weighted_mean(&nested, &w));
    }

    #[test]
    fn nested_roundtrip() {
        let nested = vec![vec![1.0f32, 2.0], vec![3.0, 4.0]];
        assert_eq!(ParamMatrix::from_nested(&nested).to_nested(), nested);
    }
}
