//! `pfl` — launcher CLI for the compressed-L2GD system.
//!
//! Subcommands:
//!   train        run one configured training job (config file + overrides)
//!   repro <id>   regenerate a paper table/figure (fig2 fig3 fig4 fig5 fig6
//!                fig78 fig9 table1 table2) at configurable scale
//!   theory       Theorems 3–4 calculator: composed ω of the compression
//!                specs + optimal p for rate/communication (`tune` = alias)
//!   compressors  measured Table I (bits/coord, ω) for every registered
//!                operator, pipelines included
//!   models       list AOT artifact models
//!
//! Compressor specs accept pipelines: `randk:50>qsgd:8` sparsifies then
//! quantizes the survivors, `ef(<spec>)` adds error feedback. See
//! `pfl train --help`.
//!
//! Examples:
//!   pfl train --model native_logreg --algo l2gd --p 0.4 --lambda 10 --n 5
//!   pfl train --algo l2gd --client-comp "ef(randk:50>qsgd:8)" --master-comp natural
//!   pfl repro fig3 --scale 0.2
//!   pfl theory --n 10 --lf 2.0 --mu 0.01 --lambda 5 --client-comp "randk:50>qsgd:8"
//!   (quote pipeline specs: an unquoted `>` is shell redirection)

use pfl::algorithms::FedAlgorithm as _;
use pfl::config::TrainConfig;
use pfl::coordinator;
use pfl::experiments::{bench_kernels, bench_round, dnn, fig2, fig3, fig78,
                       perf_compare, table1};
use pfl::runtime::XlaRuntime;
use pfl::sim;
use pfl::theory::Consts;
use pfl::util::cli::Args;
use pfl::util::json::Value;

/// Counting global allocator: lets `pfl bench` assert the round engine's
/// zero-allocation steady state (one relaxed atomic add per allocation —
/// unmeasurable against real work).
#[global_allocator]
static ALLOC: pfl::util::alloc_count::CountingAlloc =
    pfl::util::alloc_count::CountingAlloc;

const FLAGS: &[&str] = &["help", "full", "smoke"];

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let args = Args::from_env(FLAGS)?;
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "train" => cmd_train(&args),
        "repro" => cmd_repro(&args),
        "theory" | "tune" => cmd_theory(&args),
        "compressors" => cmd_compressors(&args),
        "bench" => cmd_bench(&args),
        "sim" => cmd_sim(&args),
        "models" => cmd_models(&args),
        _ => {
            print!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "\
pfl — Personalized Federated Learning with Communication Compression

usage: pfl <command> [options]

commands:
  train        run one training job (`pfl train --help` for the full
               compressor-spec grammar)
               --model <name|native_logreg> --algo <l2gd|fedavg|fedopt>
               --n <clients> --steps <k> --p --lambda --eta --agg
               --local-lr --local-steps --client-comp --master-comp
               --config <file.json> --out <dir>
  repro <id>   regenerate a paper artifact: fig2 fig3 fig4 fig5 fig6
               fig78 fig9 table1 table2   [--scale 0..1] [--out results]
  theory       composed ω of the given specs + optimal p per Theorems 3-4
               (alias: tune):
               --n --lf --mu --lambda --client-comp --master-comp [--dim]
  compressors  measured Table I for every registered operator
  bench        round-engine throughput on the Fig-3 convex config: engine
               vs seed-semantics baseline, zero-alloc assertion, emits
               BENCH_round.json — plus the million-device sharded-engine
               scale section (events/sec, resident-bytes/device, emits
               BENCH_shard.json) and the SIMD kernel microbench (per-kernel
               GB/s at every dispatch level, emits BENCH_kernels.json).
               --compare <baseline file|dir> renders a delta table (perf.md)
               against committed BENCH_*.json and fails on >10% regression
               of tracked headline numbers (see bench/compare.sh).
               --queue-floor <N> fails the run when the timing-wheel
               event-queue microbench (the BENCH_shard.json `event_queue`
               section, wheel vs binary-heap oracle on a megafleet-async
               stream) measures below N ops/sec (CI's queue-smoke job).
               [--smoke] [--steps N] [--out file] [--shard-out file]
               [--kernels-out file] [--compare path] [--perf-out file]
               [--queue-floor N]
  sim          discrete-event fleet simulation of the Fig-3 config under
               scenario presets (partial participation, churn, stragglers,
               byte-accurate wire frames, million-device megafleet presets
               on copy-on-write sharded state) for any registered fleet
               algorithm (alg=l2gd|fedavg|fedopt), synchronously or with
               overlapping rounds and staleness-weighted buffered
               aggregation (async=buffered); `pfl sim --help` documents
               the scenario grammar  [--scenarios a;b] [--smoke] [--out dir]
  models       list AOT models (needs `make artifacts`)
";

const TRAIN_HELP: &str = "\
pfl train — run one training job

  --model <name>        native_logreg, or an AOT artifact model
  --algo <a>            l2gd | fedavg | fedopt
  --n --steps --eval-every --seed
  --p --lambda --eta --agg            (L2GD; eta 0 derives from local-lr/agg)
  --local-lr --local-steps --server-lr
  --client-comp <spec>  client→master compression (default natural)
  --master-comp <spec>  master→clients compression (default natural)
  --config <file.json> --out <dir> --artifacts <dir>

compressor spec grammar:
  spec  := \"ef(\" spec \")\" | chain        ef(...) = error feedback: the
                                          residual x+e−C(x+e) carries over
                                          rounds (stateful, biased)
  chain := atom (\">\" atom)*              a>b pipes a's output into b;
                                          selector stages hand only their
                                          survivors on: randk:50>qsgd:8
                                          quantizes 50 values, not d
  atom  := name [\":\" arg]

registered operators (pfl compressors measures them):
";

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    if args.flag("help") {
        print!("{}", TRAIN_HELP);
        for (name, help) in pfl::compress::registry::help_lines() {
            println!("  {name:<12} {help}");
        }
        println!("\nexamples (quote pipeline specs — an unquoted `>` is shell \
                  redirection):");
        println!("  pfl train --algo l2gd --client-comp natural --master-comp natural");
        println!("  pfl train --algo l2gd --client-comp \"ef(randk:50>qsgd:8)\" \
                  --master-comp natural");
        println!("  pfl train --algo fedavg --client-comp \"topk:100>natural\"");
        return Ok(());
    }
    let cfg = TrainConfig::from_args(args)?;
    let env = if cfg.model == "native_logreg" {
        coordinator::logreg_env(&coordinator::LogregEnvCfg {
            n_clients: cfg.n_clients,
            seed: cfg.seed,
            ..Default::default()
        })
    } else {
        let rt = XlaRuntime::load_filtered(&cfg.artifacts, Some(&[cfg.model.as_str()]))?;
        coordinator::env_for_model(&rt, &cfg.model, cfg.n_clients,
                                   cfg.dirichlet_alpha, cfg.seed)?
    };
    let mut algo = coordinator::algo_from_config(&cfg)?;
    eprintln!("running {} on {} ({} clients, {} steps)",
              algo.label(), cfg.model, cfg.n_clients, cfg.steps);
    let t0 = std::time::Instant::now();
    let series = algo.run(&env, cfg.steps, cfg.eval_every)?;
    let dt = t0.elapsed().as_secs_f64();
    let path = format!("{}/train_{}_{}.csv", cfg.out_dir, cfg.model, cfg.algo);
    series.write_csv(&path)?;
    let last = series.last().unwrap();
    println!("done in {dt:.1}s → {path}");
    println!("final: step {} | bits/n {:.3e} | train loss {:.4} acc {:.3} | \
              test loss {:.4} acc {:.3} | personal loss {:.4}",
             last.step, last.bits_per_client, last.train_loss, last.train_acc,
             last.test_loss, last.test_acc, last.personal_loss);
    Ok(())
}

fn scale_of(args: &Args) -> anyhow::Result<f64> {
    let s: f64 = args.parse_or("scale", if args.flag("full") { 1.0 } else { 0.25 })?;
    anyhow::ensure!(s > 0.0 && s <= 1.0, "--scale must be in (0,1]");
    Ok(s)
}

/// Every artifact `pfl repro` can regenerate — the unknown-id error lists
/// these, same UX as the codec registry's unknown-codec error.
const REPRO_IDS: &[&str] = &["fig2", "fig3", "fig4", "fig5", "fig6", "fig78",
                             "fig9", "fig10", "fig11", "table1", "table2"];

fn cmd_repro(args: &Args) -> anyhow::Result<()> {
    let id = args
        .positional
        .get(1)
        .map(String::as_str)
        .ok_or_else(|| anyhow::anyhow!("repro needs an id (known: {})",
                                       REPRO_IDS.join(", ")))?;
    let out = args.str_or("out", "results");
    let scale = scale_of(args)?;
    let artifacts = args.str_or("artifacts", "artifacts");
    match id {
        "fig2" => {
            let text = fig2::render(0.5, 3, 64, 7);
            std::fs::create_dir_all(&out)?;
            std::fs::write(format!("{out}/fig2_protocol.txt"), &text)?;
            print!("{text}");
        }
        "fig3" => {
            for (tag, mut cfg) in [("a1a", fig3::Fig3Cfg::a1a()), ("a2a", fig3::Fig3Cfg::a2a())] {
                cfg.iters = (100.0 * scale).max(20.0) as u64;
                let (psweep, lsweep) = fig3::run_and_write(&cfg, tag, &out)?;
                println!("fig3 {tag}: loss vs p (λ=10):");
                for (p, l) in &psweep {
                    println!("  p={p:.2}  f={l:.5}");
                }
                println!("fig3 {tag}: loss vs λ (p=0.65):");
                for (lam, l) in &lsweep {
                    println!("  λ={lam:<5} f={l:.5}");
                }
            }
        }
        "fig4" | "fig5" | "fig6" => {
            let model = match id {
                "fig4" => "resnet_tiny",
                "fig5" => "densenet_tiny",
                _ => "mobilenet_tiny",
            };
            let rt = XlaRuntime::load_filtered(&artifacts, Some(&[model]))?;
            let steps = (1200.0 * scale).max(40.0) as u64;
            let cfg = dnn::DnnCfg::for_model(model, steps);
            let series = dnn::run_comparison(&rt, &cfg)?;
            dnn::write_series(&series, id, &out)?;
            println!("{id} ({model}, {steps} steps):");
            for s in &series {
                let r = s.last().unwrap();
                println!("  {:<34} bits/n {:>10.3e}  train loss {:.4}  test acc {:.3}",
                         s.label, r.bits_per_client, r.train_loss, r.test_acc);
            }
        }
        "fig78" => {
            let rt = XlaRuntime::load_filtered(&artifacts, Some(&["resnet_tiny"]))?;
            let mut cfg = fig78::Fig78Cfg::default();
            cfg.steps = (600.0 * scale).max(40.0) as u64;
            cfg.eval_every = (cfg.steps / 12).max(1);
            let outp = fig78::run(&rt, &cfg)?;
            pfl::metrics::write_multi_csv(
                &[outp.l2gd.clone(), outp.fedavg.clone()],
                format!("{out}/fig78.csv"),
            )?;
            println!("fig7/8: FedAvg ≡ L2GD at ηλ/np = 1 (n={}, {} steps)",
                     cfg.n_clients, cfg.steps);
            println!("  max test-acc gap   = {:.4}", outp.max_acc_gap);
            println!("  max train-loss gap = {:.4}", outp.max_loss_gap);
        }
        "fig9" | "fig10" | "fig11" => {
            let model = match id {
                "fig9" => "resnet_tiny",
                "fig10" => "densenet_tiny",
                _ => "mobilenet_tiny",
            };
            let rt = XlaRuntime::load_filtered(&artifacts, Some(&[model]))?;
            let steps = (1200.0 * scale).max(40.0) as u64;
            let cfg = dnn::DnnCfg::for_model(model, steps);
            let series = dnn::run_vs_fedopt(&rt, &cfg)?;
            dnn::write_series(&series, id, &out)?;
            for s in &series {
                let r = s.last().unwrap();
                println!("  {:<34} bits/n {:>10.3e}  train loss {:.4}  test acc {:.3}",
                         s.label, r.bits_per_client, r.train_loss, r.test_acc);
            }
        }
        "table1" => cmd_compressors(args)?,
        "table2" => {
            let models = ["resnet_tiny", "densenet_tiny", "mobilenet_tiny"];
            let rt = XlaRuntime::load_filtered(&artifacts, Some(&models))?;
            let target: f64 = args.parse_or("target", 0.5)?;
            let steps = (2000.0 * scale).max(60.0) as u64;
            println!("Table II (target test acc {target}):");
            println!("{:<16} {:>8} {:>14} {:>14} {:>8}",
                     "model", "params", "L2GD bits/n", "FedAvg bits/n", "ratio");
            std::fs::create_dir_all(&out)?;
            let mut csv = String::from("model,params,l2gd_bits,fedavg_bits,ratio\n");
            for m in models {
                let cfg = dnn::DnnCfg::for_model(m, steps);
                let row = dnn::run_table2(&rt, &cfg, target)?;
                let fmt = |x: Option<f64>| x.map_or("—".to_string(), |v| format!("{v:.3e}"));
                println!("{:<16} {:>8} {:>14} {:>14} {:>8}",
                         row.model, row.params, fmt(row.l2gd_bits),
                         fmt(row.baseline_bits),
                         row.ratio().map_or("—".to_string(), |r| format!("{r:.1}x")));
                csv.push_str(&format!("{},{},{},{},{}\n", row.model, row.params,
                    fmt(row.l2gd_bits), fmt(row.baseline_bits),
                    row.ratio().map_or(String::new(), |r| format!("{r:.2}"))));
            }
            std::fs::write(format!("{out}/table2.csv"), csv)?;
        }
        other => anyhow::bail!("unknown repro id `{other}` (known: {})",
                               REPRO_IDS.join(", ")),
    }
    Ok(())
}

fn cmd_theory(args: &Args) -> anyhow::Result<()> {
    let n: usize = args.parse_or("n", 10)?;
    let dim: usize = args.parse_or("dim", 10_000)?;
    let mu: f64 = args.parse_or("mu", 0.01)?;
    let lambda: f64 = args.parse_or("lambda", 5.0)?;
    let client = args.str_or("client-comp", "natural");
    let master = args.str_or("master-comp", "natural");
    // L_f: either given, or estimated from a synthetic logreg instance
    let lf: f64 = match args.get("lf") {
        Some(s) => s.parse()?,
        None => {
            let data = pfl::data::synth::logistic(512, dim.min(512), 0.05, 0);
            pfl::theory::logreg_smoothness(&data, 0.01, 30)
        }
    };
    // composed ω of the (possibly chained) specs — biased specs refused
    let c = Consts::for_specs(n, lf, mu, lambda, dim, &client, &master)?;
    let (omega, omega_m) = (c.omega, c.omega_m);
    println!("constants: n={n} L_f={lf:.4} μ={mu} λ={lambda}");
    println!("composed ω  (client `{client}`): {omega:.4}");
    println!("composed ω_M (master `{master}`): {omega_m:.4}");
    println!("α = {:.4}", c.alpha());
    let pr = c.p_star_rate();
    let pc = c.p_star_comm();
    println!("Theorem 3 (rate-optimal):  p* = {pr:.4}   γ(p*) = {:.4}   η_max = {:.6}",
             c.gamma(pr), c.eta_max(pr));
    println!("Theorem 4 (comm-optimal):  p* = {pc:.4}   γ(p*) = {:.4}", c.gamma(pc));
    println!("at p*_rate: iterations to 1e-2 ≈ {:.0}, comm rounds ≈ {:.0}",
             c.iterations_to_eps(pr, 1e-2), c.comm_rounds_to_eps(pr, 1e-2));
    Ok(())
}

fn cmd_compressors(_args: &Args) -> anyhow::Result<()> {
    let rows = table1::run(4096, 20);
    print!("{}", table1::format_table(&rows));
    Ok(())
}

fn cmd_bench(args: &Args) -> anyhow::Result<()> {
    let mut cfg = if args.flag("smoke") {
        bench_round::BenchCfg::smoke()
    } else {
        bench_round::BenchCfg::fig3()
    };
    cfg.steps = args.parse_or("steps", cfg.steps)?;
    cfg.seed = args.parse_or("seed", cfg.seed)?;
    let out = args.str_or("out", "BENCH_round.json");
    let shard_out = args.str_or("shard-out", "BENCH_shard.json");
    eprintln!("round-engine bench: n={} d={} rows/worker={} ({} steps + {} warmup)",
              cfg.n_clients, cfg.dim, cfg.rows_per_worker, cfg.steps, cfg.warmup);
    let res = bench_round::run_and_write(&cfg, &out)?;
    println!("engine    (identity wire): {:>10.0} steps/s  (raw step loop)",
             res.engine_steps_per_sec);
    println!("engine    (natural wire):  {:>10.0} steps/s  (raw step loop)",
             res.engine_natural_steps_per_sec);
    println!("engine    (paired run):    {:>10.0} steps/s", res.engine_paired_steps_per_sec);
    println!("reference (seed layout):   {:>10.0} steps/s", res.reference_steps_per_sec);
    println!("speedup vs reference:      {:>10.2}x  (paired run shapes)", res.speedup());
    match res.engine_allocs_per_step {
        Some(a) => println!("steady-state allocations:  {a:>10.2} per step (asserted 0)"),
        None => println!("steady-state allocations:  not measured (counting \
                          allocator absent)"),
    }
    println!("sim scheduler:             {:>10.0} events/s  (straggler-heavy)",
             res.sim_events_per_sec);
    for (alg, eps) in &res.sim_alg_events_per_sec {
        println!("sim engine [{alg:<6}]:       {eps:>10.0} events/s  \
                  (engine-vs-engine)");
    }
    match res.sim_allocs_per_event {
        Some(a) => println!("sim allocations:           {a:>10.2} per event \
                             (asserted < {})",
                            pfl::experiments::bench_round::SIM_ALLOCS_PER_EVENT_BOUND),
        None => println!("sim allocations:           not measured (counting \
                          allocator absent)"),
    }
    println!("async scheduler:           {:>10.0} events/s  (async-bursty, \
              {} applied)",
             res.async_events_per_sec, res.async_applied_updates);
    match res.async_allocs_per_event {
        Some(a) => println!("async allocations:         {a:>10.2} per event \
                             (asserted < {})",
                            pfl::experiments::bench_round::SIM_ALLOCS_PER_EVENT_BOUND),
        None => println!("async allocations:         not measured (counting \
                          allocator absent)"),
    }
    println!("final personal loss:       {:>10.4}", res.final_personal_loss);
    println!("wrote {out}");

    // scale section: the sharded cohort engine at one million devices
    let mut scfg = if args.flag("smoke") {
        bench_round::ShardBenchCfg::smoke()
    } else {
        bench_round::ShardBenchCfg::megafleet()
    };
    scfg.seed = cfg.seed;
    scfg.queue_ops_floor = args.parse_or("queue-floor", scfg.queue_ops_floor)?;
    eprintln!("scale bench: {} ({} steps + {} warmup)",
              scfg.scenario, scfg.steps, scfg.warmup);
    let sres = bench_round::run_and_write_shard(&scfg, &shard_out)?;
    println!("sharded engine:            {:>10.0} events/s  ({} devices)",
             sres.events_per_sec, sres.fleet_size);
    println!("touched clients:           {:>10}  (rows resident: {})",
             sres.touched_clients, sres.resident_rows);
    println!("resident bytes/device:     {:>10.2}  (dense row would be {} B)",
             sres.resident_bytes_per_device, 4 * cfg.dim);
    match sres.allocs_per_touch {
        Some(a) => println!("allocations/new client:    {a:>10.2}  (bound {})",
                            bench_round::SHARD_ALLOCS_PER_TOUCH_BOUND),
        None => println!("allocations:               not measured (counting \
                          allocator absent)"),
    }
    println!("event queue (wheel):       {:>10.0} ops/s  ({:.2}x vs heap, \
              depth {})",
             sres.queue.wheel_ops_per_sec, sres.queue.speedup(),
             sres.queue.max_depth);
    if scfg.queue_ops_floor > 0.0 {
        println!("queue floor:               {:>10.0} ops/s  (passed)",
                 scfg.queue_ops_floor);
    }
    println!("wrote {shard_out}");

    // kernels microbench: per-kernel effective GB/s at every runtime
    // dispatch level (avx2/sse2/scalar as available on this host)
    let kcfg = if args.flag("smoke") {
        bench_kernels::KernelBenchCfg::smoke()
    } else {
        bench_kernels::KernelBenchCfg::full()
    };
    let kernels_out = args.str_or("kernels-out", "BENCH_kernels.json");
    eprintln!("kernels microbench: d={} ({} iters + {} warmup per level)",
              kcfg.dim, kcfg.iters, kcfg.warmup);
    let kres = bench_kernels::run_and_write(&kcfg, &kernels_out)?;
    bench_kernels::print_summary(&kres);
    println!("wrote {kernels_out}");

    // delta report against a committed baseline set; a tracked headline
    // more than 10% below baseline fails the whole command (CI gate)
    if let Some(baseline) = args.get("compare") {
        let set = perf_compare::BaselineSet::load(baseline)?;
        let (rj, sj, kj) = (res.to_json(), sres.to_json(), kres.to_json());
        let cmp = perf_compare::compare(&set, Some(&rj), Some(&sj), Some(&kj));
        let perf_out = args.str_or("perf-out", "perf.md");
        perf_compare::write_markdown(&cmp, &perf_out)?;
        println!("wrote {perf_out}");
        cmp.check()?;
        println!("perf gate: OK — no tracked metric more than {:.0}% below \
                  baseline",
                 perf_compare::REGRESSION_TOLERANCE * 100.0);
    }
    Ok(())
}

const SIM_HELP: &str = "\
pfl sim — discrete-event fleet simulation of the unified algorithm family

Runs the Fig-3 convex configuration over a modeled device fleet: per-client
compute speed and link quality drawn from distributions, seeded churn
traces, cohort sampling per communication event with first-k-of-m quorum
under a straggler deadline, and byte-accurate wire frames (header +
byte-aligned payload) feeding the link accounting instead of theoretical
bit formulas. Emits one loss-vs-simulated-seconds CSV per scenario plus a
JSON summary.

One generic cohort engine drives every registered algorithm (`alg=` in
the scenario grammar): compressed L2GD's probabilistic protocol, or the
FedAvg/FedOpt fixed-cadence baselines — so the paper's bits-per-accuracy
comparison runs under identical fleets, churn, and framing.

Mega scenarios (`megafleet*`, or ≥65536 clients) run with lazy per-device
profiles, O(cohort) id-space sampling, and copy-on-write client state
whose resident bytes scale with the clients actually touched — a
million-device fleet fits in a laptop run, for l2gd and the baselines
alike.

  --scenarios <s;s;..>  scenario specs, `;`-separated (default: all presets)
  --scenario <spec>     single scenario (overrides --scenarios)
  --smoke               CI-sized: two presets, small shards, few steps
  --steps N --eval-every N --seed S
  --n N                 fleet size when the scenario doesn't pin one
  --p --lambda --eta    L2GD meta-parameters (Fig-3 defaults)
  --local-lr --local-steps --server-lr   FedAvg/FedOpt parameters
  --client-comp --master-comp   compressor specs (default natural)
  --out <dir>           output directory (default results)
  --trace <file>        record round/engine/transport spans and write a
                        Chrome trace-event JSON (open in chrome://tracing
                        or Perfetto): pid 1 = sim-time lanes (round slots,
                        sampled devices), pid 2 = wall-clock lanes
                        (engine, transport, pool workers)
  --trace-jsonl <file>  raw event stream, one JSON object per line
  --metrics-out <file>  Prometheus text exposition of the always-on
                        histogram/counter registry (staleness, queue
                        depth, cohort size, round bits, shard occupancy,
                        worker busy-ns) — default <out>/metrics.prom

scenario spec grammar (parsed by a real lexer — malformed specs get a
caret pointing at the offending bytes plus a \"did you mean\" suggestion;
whitespace is insignificant, each key may appear once per phase):
  spec     := \"phases\" \"(\" phase (\";\" phase)+ \")\" | single
  phase    := single [\"@\" \"rounds\" \"=\" N]   (every phase but the last
             needs @rounds; fleet size, mega mode, and alg stay constant
             across phases, everything else may change at the boundary)
  single   := name [\":\" key \"=\" value (\",\" key \"=\" value)*]
  keys     := alg | async | buffer | clients | codec | deadline
            | inflight | max_stale | quorum | sample | stale
  sample   = fraction of the fleet drawn per comm event, (0,1]
             (drawn devices that churn has offline drop out of the cohort)
  quorum   = fraction of the sampled cohort to wait for, (0,1]
  deadline = straggler deadline in seconds (inf = wait for quorum)
  alg      = fleet algorithm (unknown names list what is registered)
  codec    = compressor spec from the codec registry, applied in both
             directions (e.g. codec=qsgd:8 or codec=ef(randk:50>qsgd:8));
             overrides --client-comp/--master-comp for the phase
  async    = dispatch discipline: buffered | sync. `buffered` overlaps up
             to `inflight` version-stamped rounds in the event queue and
             meters the staleness distribution plus uplink goodput
  buffer   = K updates to buffer before a staleness-weighted server
             commit, K ≥ 1, or `cohort` to commit whole rounds
             (requires async=buffered)
  inflight = max overlapping rounds (requires async=buffered);
             inflight=1 with buffer=cohort reproduces the synchronous
             runner bit for bit
  stale    = staleness weight: const | inv | poly | poly:ALPHA
             (const: w=1; inv: w=1/(1+s); poly: w=(1+s)^-ALPHA)
  max_stale= discard updates staler than this many server commits, ≥ 1
             (their bytes still meter as stale traffic); `none` = no
             cutoff (`max_stale=0` is rejected as silently degenerate)

async runs additionally emit a sim_stale_<scenario>.csv staleness
histogram and staleness/goodput keys in sim_summary.json.

registered algorithms:
";

fn cmd_sim(args: &Args) -> anyhow::Result<()> {
    if args.flag("help") {
        print!("{}", SIM_HELP);
        for &alg in pfl::algorithms::FLEET_ALGS {
            println!("  {alg}");
        }
        println!("\npresets:");
        let mut presets = sim::scenario::PRESETS.to_vec();
        presets.sort_by_key(|&(name, _)| name);
        for (name, help) in presets {
            println!("  {name:<16} {help}");
        }
        println!("\nexamples:");
        println!("  pfl sim --scenario straggler-heavy:clients=20,quorum=0.6,deadline=2");
        println!("  pfl sim --scenarios \"uniform;diurnal-churn:clients=16\" --steps 800");
        println!("  pfl sim --scenario \"megafleet-fedavg\" --smoke");
        println!("  pfl sim --scenario \"uniform:alg=fedopt\" --local-steps 5");
        println!("  pfl sim --scenario \"async-bursty:inflight=8,stale=poly:1\"");
        println!("  pfl sim --scenario \"megafleet-async\" --smoke");
        println!("  pfl sim --scenario \
                  \"diurnal-churn:async=buffered,buffer=4,inflight=6,stale=inv\"");
        println!("  pfl sim --scenario \"uniform:codec=ef(randk:50>qsgd:8)\"");
        println!("  pfl sim --scenario \
                  \"phases(uniform @rounds=200; uniform:codec=qsgd:4)\"");
        return Ok(());
    }
    let smoke = args.flag("smoke");
    let default_scenarios = if smoke {
        "uniform;straggler-heavy;async-bursty".to_string()
    } else {
        sim::scenario::preset_names().join(";")
    };
    let spec_list = match args.get("scenario") {
        Some(one) => one.to_string(),
        None => args.str_or("scenarios", &default_scenarios),
    };
    let out = args.str_or("out", "results");
    std::fs::create_dir_all(&out)?;
    // observability: the registry is always on (pure atomics) and starts
    // this command from zero; span recording is opt-in via --trace /
    // --trace-jsonl (one relaxed atomic load per call site when off —
    // the bench harness pins that path allocation-free)
    pfl::obs::registry::reset();
    let trace_out = args.get("trace").map(str::to_string);
    let jsonl_out = args.get("trace-jsonl").map(str::to_string);
    if trace_out.is_some() || jsonl_out.is_some() {
        pfl::obs::enable(1 << 18);
    }
    let mut summaries: Vec<Value> = Vec::new();
    // paren-aware split: a `;` inside `phases(...)` separates phases,
    // not list entries
    for spec in sim::scenario::split_specs(&spec_list) {
        let scenario = sim::scenario::from_spec(spec)?;
        let mut cfg = if smoke {
            sim::SimCfg::smoke(scenario)
        } else {
            sim::SimCfg::fig3(scenario)
        };
        cfg.steps = args.parse_or("steps", cfg.steps)?;
        cfg.eval_every = args.parse_or("eval-every", cfg.eval_every)?;
        cfg.seed = args.parse_or("seed", cfg.seed)?;
        cfg.n_clients = args.parse_or("n", cfg.n_clients)?;
        cfg.p = args.parse_or("p", cfg.p)?;
        cfg.lambda = args.parse_or("lambda", cfg.lambda)?;
        cfg.eta = args.parse_or("eta", cfg.eta)?;
        cfg.local_lr = args.parse_or("local-lr", cfg.local_lr)?;
        cfg.local_steps = args.parse_or("local-steps", cfg.local_steps)?;
        cfg.server_lr = args.parse_or("server-lr", cfg.server_lr)?;
        if let Some(v) = args.get("client-comp") { cfg.client_comp = v.to_string(); }
        if let Some(v) = args.get("master-comp") { cfg.master_comp = v.to_string(); }
        eprintln!("sim {} [{}]: n={} steps={} wire {}|{}",
                  cfg.scenario.name, cfg.scenario.alg, cfg.effective_clients(),
                  cfg.steps, cfg.client_comp, cfg.master_comp);
        let res = if cfg.scenario.async_sched.is_async() {
            sim::async_runner::run(&cfg)?
        } else {
            sim::runner::run(&cfg)?
        };
        // filename from the full spec (two variants of one preset must not
        // clobber each other), with shell/FS-hostile characters mapped away
        let slug: String = res.scenario.chars()
            .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' {
                c
            } else {
                '-'
            })
            .collect();
        let csv_path = format!("{out}/sim_{slug}.csv");
        res.series.write_csv(&csv_path)?;
        let last = res.series.last().unwrap();
        println!("{:<18} t={:>9.2}s  comm {:>4} (skip {}, drop {})  \
                  mean cohort {:>5.1}  bytes/n ↑{:.3e} ↓{:.3e}  \
                  personal loss {:.5}  → {csv_path}",
                 res.scenario, last.sim_time_s, res.stats.comm_events,
                 res.stats.skipped_rounds, res.stats.dropped_stragglers,
                 res.stats.mean_participants(),
                 last.bits_up as f64 / 8.0 / cfg.effective_clients() as f64,
                 last.bits_down as f64 / 8.0 / cfg.effective_clients() as f64,
                 last.personal_loss);
        if cfg.scenario.mega {
            println!("{:<18} fleet {}  touched {}  resident rows {}  \
                      {:.2} B/device (copy-on-write)",
                     "", res.fleet_size, res.touched_clients,
                     res.resident_rows,
                     res.resident_bytes as f64 / res.fleet_size.max(1) as f64);
        }
        if let Some(ast) = &res.async_stats {
            println!("{:<18} async: {} dispatched, {} applied, {} stale  \
                      staleness mean {:.2} p95 {}  goodput {:.3}",
                     "", ast.dispatched_rounds, ast.applied_updates,
                     ast.stale_discarded, ast.mean_staleness(),
                     ast.p95_staleness(), res.goodput);
            let mut csv = String::from("staleness,count\n");
            for (s, &count) in ast.histogram().iter().enumerate() {
                csv.push_str(&format!("{s},{count}\n"));
            }
            let stale_path = format!("{out}/sim_stale_{slug}.csv");
            std::fs::write(&stale_path, csv)?;
            println!("{:<18} staleness histogram → {stale_path}", "");
        }
        summaries.push(res.to_json());
    }
    anyhow::ensure!(!summaries.is_empty(), "no scenarios given");
    if let Some(sink) = pfl::obs::disable() {
        if let Some(p) = &trace_out {
            write_creating_parent(p, &sink.to_chrome_trace())?;
            println!("wrote {p} ({} events, {} overwritten)",
                     sink.len(), sink.dropped());
        }
        if let Some(p) = &jsonl_out {
            write_creating_parent(p, &sink.to_jsonl())?;
            println!("wrote {p}");
        }
    }
    let snap = pfl::obs::registry::snapshot();
    let prom_path = args.str_or("metrics-out", &format!("{out}/metrics.prom"));
    write_creating_parent(&prom_path, &snap.to_prom())?;
    println!("wrote {prom_path}");
    let summary = Value::obj(vec![
        ("bench".into(), Value::Str("fleet_sim".into())),
        ("obs".into(), snap.to_json()),
        ("scenarios".into(), Value::Arr(summaries)),
    ]);
    let path = format!("{out}/sim_summary.json");
    let mut text = summary.to_string_pretty();
    text.push('\n');
    std::fs::write(&path, text)?;
    println!("wrote {path}");
    Ok(())
}

/// Write `text`, creating the file's parent directory if needed — trace
/// and metrics paths routinely point into not-yet-created output dirs.
fn write_creating_parent(path: &str, text: &str) -> anyhow::Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, text).map_err(|e| anyhow::anyhow!("writing {path}: {e}"))
}

fn cmd_models(args: &Args) -> anyhow::Result<()> {
    let artifacts = args.str_or("artifacts", "artifacts");
    let rt = XlaRuntime::load(&artifacts)?;
    println!("models in {artifacts}:");
    for name in rt.model_names() {
        let be = rt.backend(&name)?;
        let m = be.meta();
        println!("  {:<18} P={:<8} kind={:<7} train_batch={} classes={}",
                 m.name, m.param_count, m.kind, m.train_batch, m.num_classes);
    }
    Ok(())
}
