//! Federated training algorithms.
//!
//! * [`l2gd::L2gd`] — **the paper's contribution**: compressed L2GD
//!   (Algorithm 1) with bidirectional compression over the probabilistic
//!   protocol.
//! * [`fedavg::FedAvg`] — the FedAvg baseline, plus the paper's
//!   error-feedback-style difference compression (§VII-B).
//! * [`fedopt::FedOpt`] — server-Adam baseline (Reddi et al.), the paper's
//!   strongest no-compression comparator.
//!
//! All algorithms run against a [`FedEnv`] (backend + shards + test data)
//! and emit a [`Series`] of per-evaluation [`Record`]s with exact bit
//! accounting from the transport layer.

pub mod fedavg;
pub mod fedopt;
pub mod l2gd;

use std::sync::Arc;

use crate::data::Dataset;
use crate::metrics::{Record, Series};
use crate::runtime::Backend;
use crate::transport::Network;
use crate::util::threadpool::ThreadPool;
use crate::util::Rng;

pub use fedavg::FedAvg;
pub use fedopt::FedOpt;
pub use l2gd::L2gd;

/// Shared training environment.
pub struct FedEnv {
    pub backend: Arc<dyn Backend>,
    /// per-client training shards (heterogeneous)
    pub shards: Vec<Dataset>,
    /// subsample of the union train set for global-model train metrics
    pub train_eval: Dataset,
    pub test: Dataset,
    pub pool: ThreadPool,
    pub seed: u64,
}

impl FedEnv {
    pub fn n_clients(&self) -> usize {
        self.shards.len()
    }

    /// |D_i| weights for weighted aggregation (the paper's w_i).
    pub fn shard_weights(&self) -> Vec<f64> {
        self.shards.iter().map(|s| s.len() as f64).collect()
    }
}

/// Common trait: run for `steps` iterations, evaluating every `eval_every`.
pub trait FedAlgorithm {
    fn label(&self) -> String;
    fn run(&mut self, env: &FedEnv, steps: u64, eval_every: u64) -> anyhow::Result<Series>;
}

/// Evaluate global + personalized metrics into a `Record`.
///
/// `xs` are the per-client models (identical copies for the global-model
/// algorithms). The global model is the plain mean — the paper's evaluation
/// object for Top-1 accuracy; the personalized objective (1/n)Σ f_i(x_i)
/// is what Fig 3 plots.
pub fn evaluate(env: &FedEnv, xs: &[Vec<f32>], step: u64, net: &Network)
                -> anyhow::Result<Record> {
    let global = crate::model::mean_of(xs);
    let be = &env.backend;
    let train_b = be.make_eval_batch(&env.train_eval);
    let test_b = be.make_eval_batch(&env.test);
    let train = be.eval(&global, &train_b)?;
    let test = be.eval(&global, &test_b)?;

    // personalized: each client's model on its own shard (pooled)
    let per: Vec<(f64, f64)> = env.pool.scope_map(xs, |i, x| {
        let b = be.make_eval_batch(&env.shards[i]);
        match be.eval(x, &b) {
            Ok(e) => (e.loss, e.accuracy),
            Err(_) => (f64::NAN, f64::NAN),
        }
    });
    let n = per.len() as f64;
    let personal_loss = per.iter().map(|p| p.0).sum::<f64>() / n;
    let personal_acc = per.iter().map(|p| p.1).sum::<f64>() / n;
    // non-finite metrics are recorded, not raised: divergence is a result
    // (the paper reports FedAvg diverging at stepsize 0.2, §B) — runs stop
    // early via `Record::is_finite` in the training loops.

    Ok(Record {
        step,
        comm_rounds: net.comm_rounds(),
        bits_per_client: net.bits_per_client(),
        bits_up: net.total_bits_up(),
        bits_down: net.total_bits_down(),
        train_loss: train.loss,
        train_acc: train.accuracy,
        test_loss: test.loss,
        test_acc: test.accuracy,
        personal_loss,
        personal_acc,
        sim_time_s: net.simulated_comm_time_s(),
    })
}

/// Per-client RNG streams forked deterministically from the run seed.
pub fn client_rngs(seed: u64, n: usize) -> Vec<Rng> {
    let mut root = Rng::new(seed);
    (0..n).map(|i| root.fork(i as u64 + 1)).collect()
}
