//! Federated training algorithms — one engine skeleton, pluggable state
//! storage, pluggable communication schedule (the unified-formulation
//! view of Hanzely & Richtárik 2020 / Hanzely, Zhao, Kolar 2021).
//!
//! * [`engine::Engine`] — **the** round engine, generic over
//!   [`crate::model::ClientStore`] (dense lockstep matrix, alias
//!   [`L2gdEngine`]; copy-on-write million-device store, alias
//!   [`ShardedL2gdEngine`]) and parameterized by an [`engine::AlgSpec`]
//!   (schedule + server transform + wire specs): L2GD's Bernoulli coin,
//!   or the FedAvg/FedOpt fixed cadence ([`engine::FLEET_ALGS`]).
//! * [`l2gd::L2gd`] — **the paper's contribution**: the compressed-L2GD
//!   (Algorithm 1) configuration front-end for the engine.
//! * [`fedavg::FedAvg`] — the lockstep FedAvg baseline, plus the paper's
//!   error-feedback-style difference compression (§VII-B). Its
//!   fleet-scale counterpart is `AlgSpec::fedavg` on the cohort engine.
//! * [`fedopt::FedOpt`] — lockstep server-Adam baseline (Reddi et al.),
//!   the paper's strongest no-compression comparator; fleet-scale via
//!   `AlgSpec::fedopt`.
//! * [`reference`] — the seed-semantics `Vec<Vec<f32>>` oracle the engine
//!   is tested (bit-for-bit) and benchmarked against.
//!
//! All algorithms run against a [`FedEnv`] (backend + shards + test data +
//! cached batches) and emit a [`Series`] of per-evaluation [`Record`]s
//! with exact bit accounting from the transport layer.

pub mod engine;
pub mod fedavg;
pub mod fedopt;
pub mod l2gd;
pub mod reference;

use std::sync::{Arc, OnceLock};

use crate::data::Dataset;
use crate::metrics::{Record, Series};
use crate::runtime::{Backend, Batch};
use crate::transport::Network;
use crate::util::threadpool::ThreadPool;
use crate::util::Rng;

pub use engine::{AlgSpec, Engine, L2gdEngine, ShardedL2gdEngine, FLEET_ALGS};
pub use fedavg::FedAvg;
pub use fedopt::FedOpt;
pub use l2gd::L2gd;

/// Per-client model state as seen by [`evaluate`] — re-exported from the
/// model layer, where the stores live.
pub use crate::model::ModelView;

/// Batches assembled once at environment construction. Evaluation batches
/// are deterministic by the `Backend` contract; per-shard **training**
/// batches are cached only when the backend advertises
/// `static_train_batch` (the full-gradient convex regimes, where the seed
/// re-assembled — allocated, zero-filled and copied — an identical padded
/// batch every local step of every client).
struct BatchCache {
    /// one training batch per shard, built on first use and only when
    /// `backend.static_train_batch()` (lazy: constructing an environment
    /// must stay cheap and must not assume the backend can batch every
    /// shard — several tests pair a tiny native backend with image/token
    /// data purely to inspect partitioning)
    shard_train: OnceLock<Vec<Batch>>,
    /// one eval batch per shard (personalized metrics)
    shard_eval: Vec<Batch>,
    /// global-train eval batch
    train_eval: Batch,
    /// test eval batch
    test: Batch,
}

/// Shared training environment.
///
/// Construct with [`FedEnv::new`] — it pre-assembles the evaluation
/// batches (and, for static-batch backends, the per-shard training
/// batches) that the round engine and [`evaluate`] reuse every step.
///
/// The data fields stay `pub` for inspection and `pool` may be swapped
/// freely, but **do not mutate `shards` / `train_eval` / `test` after
/// construction**: the cached batches are built from them once and would
/// go stale (build a fresh `FedEnv` instead).
pub struct FedEnv {
    pub backend: Arc<dyn Backend>,
    /// per-client training shards (heterogeneous)
    pub shards: Vec<Dataset>,
    /// subsample of the union train set for global-model train metrics
    pub train_eval: Dataset,
    pub test: Dataset,
    pub pool: ThreadPool,
    pub seed: u64,
    cache: BatchCache,
}

impl FedEnv {
    pub fn new(backend: Arc<dyn Backend>, shards: Vec<Dataset>, train_eval: Dataset,
               test: Dataset, pool: ThreadPool, seed: u64) -> FedEnv {
        let shard_eval: Vec<Batch> =
            shards.iter().map(|s| backend.make_eval_batch(s)).collect();
        let train_eval_b = backend.make_eval_batch(&train_eval);
        let test_b = backend.make_eval_batch(&test);
        FedEnv {
            backend,
            shards,
            train_eval,
            test,
            pool,
            seed,
            cache: BatchCache {
                shard_train: OnceLock::new(),
                shard_eval,
                train_eval: train_eval_b,
                test: test_b,
            },
        }
    }

    pub fn n_clients(&self) -> usize {
        self.shards.len()
    }

    /// |D_i| weights for weighted aggregation (the paper's w_i).
    pub fn shard_weights(&self) -> Vec<f64> {
        self.shards.iter().map(|s| s.len() as f64).collect()
    }

    /// Cached training batch for shard `i`, when the backend's training
    /// batches are static. `None` means the caller must assemble one via
    /// `make_train_batch` (stochastic regimes). First call builds every
    /// shard's batch (thread-safe; steady state is an atomic load).
    pub fn train_batch_cached(&self, i: usize) -> Option<&Batch> {
        if !self.backend.static_train_batch() {
            return None;
        }
        let batches = self.cache.shard_train.get_or_init(|| {
            // the backend ignores the RNG by contract when batches are
            // static, so a throwaway stream is fine here
            let mut rng = Rng::new(self.seed ^ 0xBA7C4);
            self.shards
                .iter()
                .map(|s| self.backend.make_train_batch(s, &mut rng))
                .collect()
        });
        Some(&batches[i])
    }

    /// Cached evaluation batch for shard `i` (personalized metrics).
    pub fn shard_eval_batch(&self, i: usize) -> &Batch {
        &self.cache.shard_eval[i]
    }

    /// Cached global-train evaluation batch.
    pub fn train_eval_batch(&self) -> &Batch {
        &self.cache.train_eval
    }

    /// Cached test evaluation batch.
    pub fn test_batch(&self) -> &Batch {
        &self.cache.test
    }

    /// Force every lazily built cache (the per-shard train batches) to
    /// materialize now. Benchmarks call this before their timed windows so
    /// the first measured step never pays one-time batch assembly.
    pub fn warm_caches(&self) {
        let _ = self.train_batch_cached(0);
    }
}

/// Common trait: run for `steps` iterations, evaluating every `eval_every`.
pub trait FedAlgorithm {
    fn label(&self) -> String;
    fn run(&mut self, env: &FedEnv, steps: u64, eval_every: u64) -> anyhow::Result<Series>;
}

/// Evaluate global + personalized metrics into a `Record`.
///
/// The global model is the plain mean — the paper's evaluation object for
/// Top-1 accuracy; the personalized objective (1/n)Σ f_i(x_i) is what
/// Fig 3 plots. All evaluation batches come from the [`FedEnv`] cache —
/// the seed re-assembled the global-train and test batches from scratch on
/// every evaluation record.
pub fn evaluate(env: &FedEnv, view: ModelView<'_>, step: u64, net: &Network)
                -> anyhow::Result<Record> {
    let be = &env.backend;
    let mut global = vec![0.0f32; be.param_count()];
    view.mean_into(&mut global);
    let train = be.eval(&global, env.train_eval_batch())?;
    let test = be.eval(&global, env.test_batch())?;

    // personalized: each client's model on its own shard (pooled)
    let per: Vec<(f64, f64)> = env.pool.scope_map_n(view.n(), |i| {
        match be.eval(view.row(i), env.shard_eval_batch(i)) {
            Ok(e) => (e.loss, e.accuracy),
            Err(_) => (f64::NAN, f64::NAN),
        }
    });
    let n = per.len() as f64;
    let personal_loss = per.iter().map(|p| p.0).sum::<f64>() / n;
    let personal_acc = per.iter().map(|p| p.1).sum::<f64>() / n;
    // non-finite metrics are recorded, not raised: divergence is a result
    // (the paper reports FedAvg diverging at stepsize 0.2, §B) — runs stop
    // early via `Record::is_finite` in the training loops.

    Ok(Record {
        step,
        comm_rounds: net.comm_rounds(),
        bits_per_client: net.bits_per_client(),
        bits_up: net.total_bits_up(),
        bits_down: net.total_bits_down(),
        train_loss: train.loss,
        train_acc: train.accuracy,
        test_loss: test.loss,
        test_acc: test.accuracy,
        personal_loss,
        personal_acc,
        sim_time_s: net.simulated_comm_time_s(),
        participants: net.last_round_participants(),
    })
}

/// Per-client RNG streams forked deterministically from the run seed.
pub fn client_rngs(seed: u64, n: usize) -> Vec<Rng> {
    let mut root = Rng::new(seed);
    (0..n).map(|i| root.fork(i as u64 + 1)).collect()
}

/// Surface the first error parked by a pooled sweep (clearing it), in
/// client order. The park-then-drain protocol: worker closures can't
/// return `Result` through the allocation-free chunk sweeps, so they
/// stash the error in their slot and every sweep is followed by exactly
/// one `drain_slot_errors` before any result of the sweep is consumed.
pub(crate) fn drain_slot_errors<'a>(
    errs: impl Iterator<Item = &'a mut Option<anyhow::Error>>,
) -> anyhow::Result<()> {
    for e in errs {
        if let Some(e) = e.take() {
            return Err(e);
        }
    }
    Ok(())
}
