//! FedOpt / FedAdam (Reddi et al. 2020): FedAvg local training with an
//! adaptive server optimizer over the aggregated pseudo-gradient. The paper
//! uses it as its strongest no-compression baseline ("the only comparable
//! baseline for L2GD", §VII-B).
//!
//! Engine layout mirrors the other algorithms: per-client deltas live in a
//! contiguous [`ParamMatrix`], each client's working model / RNG / gradient
//! buffer in its slot, and the whole client round runs as one pooled sweep
//! against the environment's cached batches with zero steady-state
//! allocation on the convex path.
//!
//! This is the **lockstep** FedOpt (full participation, |D_i|-weighted
//! pseudo-gradient), pinned against the [`super::reference`] oracle. At
//! fleet scale FedOpt runs as [`super::engine::AlgSpec::fedopt`] on the
//! generic cohort engine: the fixed-cadence family member whose server
//! transform is Adam on w − ȳ, driven by [`crate::sim::FleetSim`] under
//! `alg=fedopt` scenarios.

use super::{client_rngs, drain_slot_errors, evaluate, FedAlgorithm, FedEnv, ModelView};
use crate::metrics::Series;
use crate::model::{kernels, ParamMatrix};
use crate::runtime::{Backend as _, GradBuf};
use crate::transport::Network;
use crate::util::Rng;

struct ClientSlot {
    rng: Rng,
    wi: Vec<f32>,
    grad: GradBuf,
    err: Option<anyhow::Error>,
}

pub struct FedOpt {
    pub local_lr: f64,
    pub local_steps: usize,
    /// server Adam parameters
    pub server_lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub tau: f64,
}

impl FedOpt {
    pub fn new(local_lr: f64, local_steps: usize, server_lr: f64) -> FedOpt {
        FedOpt { local_lr, local_steps, server_lr, beta1: 0.9, beta2: 0.99, tau: 1e-3 }
    }
}

impl FedAlgorithm for FedOpt {
    fn label(&self) -> String {
        format!("fedopt:lr={},T={},slr={}", self.local_lr, self.local_steps, self.server_lr)
    }

    fn run(&mut self, env: &FedEnv, rounds: u64, eval_every: u64) -> anyhow::Result<Series> {
        let n = env.n_clients();
        let d = env.backend.param_count();
        let weights = env.shard_weights();
        let lr = self.local_lr as f32;

        let mut w = env.backend.init_params();
        let mut m = vec![0.0f64; d];
        let mut v = vec![0.0f64; d];
        let mut net = Network::new(n);
        let mut deltas = ParamMatrix::zeros(n, d);
        let mut dbar = vec![0.0f32; d];
        let mut slots: Vec<ClientSlot> = client_rngs(env.seed ^ 0x0b7, n)
            .into_iter()
            .map(|rng| ClientSlot {
                rng,
                wi: vec![0.0f32; d],
                grad: GradBuf::with_dim(d),
                err: None,
            })
            .collect();

        let mut series = Series::new(self.label());
        series.records.push(evaluate(env, ModelView::Shared { model: &w, n }, 0, &net)?);

        let bits_model = 32 * d as u64; // uncompressed f32 wire

        for r in 1..=rounds {
            net.begin_round();
            net.downlink_broadcast(r, bits_model);

            let local_steps = self.local_steps;
            let w_ref = &w;
            env.pool.scope_chunks_zip_mut(deltas.as_mut_slice(), d, &mut slots,
                                          |i, delta, slot| {
                slot.wi.copy_from_slice(w_ref);
                for _ in 0..local_steps {
                    let res = match env.train_batch_cached(i) {
                        Some(b) => env.backend.grad_into(&slot.wi, b, &mut slot.grad),
                        None => {
                            let b = env.backend.make_train_batch(&env.shards[i],
                                                                 &mut slot.rng);
                            env.backend.grad_into(&slot.wi, &b, &mut slot.grad)
                        }
                    };
                    match res {
                        Ok(()) => kernels::axpy(&mut slot.wi, -lr, &slot.grad.grad),
                        Err(e) => {
                            slot.err = Some(e);
                            return;
                        }
                    }
                }
                // pseudo-gradient Δ_i = w − w_i
                for j in 0..delta.len() {
                    delta[j] = w_ref[j] - slot.wi[j];
                }
            });
            drain_slot_errors(slots.iter_mut().map(|s| &mut s.err))?;
            for i in 0..n {
                net.uplink(r, i, bits_model);
            }
            net.end_round();

            // server Adam on the pseudo-gradient Δ̄
            deltas.weighted_mean_into(&weights, &mut dbar);
            for j in 0..d {
                let g = dbar[j] as f64;
                m[j] = self.beta1 * m[j] + (1.0 - self.beta1) * g;
                v[j] = self.beta2 * v[j] + (1.0 - self.beta2) * g * g;
                w[j] -= (self.server_lr * m[j] / (v[j].sqrt() + self.tau)) as f32;
            }

            if r % eval_every == 0 || r == rounds {
                series.records.push(
                    evaluate(env, ModelView::Shared { model: &w, n }, r, &net)?);
                if !series.records.last().unwrap().is_finite() {
                    break; // diverged: record it and stop (paper §B)
                }
            }
        }
        Ok(series)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::runtime::NativeLogreg;
    use crate::util::threadpool::ThreadPool;
    use std::sync::Arc;

    fn env(n: usize, seed: u64) -> FedEnv {
        let (data, test) = synth::logistic_split(40 * n, 80, 12, 0.02, seed);
        let shards = data.split_contiguous(n);
        FedEnv::new(Arc::new(NativeLogreg::new(12, 0.01, 64, 128)),
                    shards, data, test, ThreadPool::new(4), seed)
    }

    #[test]
    fn fedopt_learns() {
        let e = env(4, 0);
        let mut alg = FedOpt::new(0.5, 3, 0.05);
        let s = alg.run(&e, 50, 10).unwrap();
        let last = s.records.last().unwrap();
        assert!(last.test_acc > 0.8, "acc {}", last.test_acc);
        assert!(last.train_loss < s.records[0].train_loss);
    }

    #[test]
    fn sends_full_models_every_round() {
        let e = env(3, 1);
        let mut alg = FedOpt::new(0.3, 2, 0.05);
        let s = alg.run(&e, 10, 5).unwrap();
        let last = s.records.last().unwrap();
        assert_eq!(last.bits_up, 10 * 3 * 32 * 12);
        assert_eq!(last.bits_down, 10 * 3 * 32 * 12);
    }

    #[test]
    fn adam_state_stays_finite() {
        let e = env(2, 2);
        let mut alg = FedOpt::new(1.0, 4, 0.5); // aggressive rates
        let s = alg.run(&e, 30, 30).unwrap();
        assert!(s.records.last().unwrap().train_loss.is_finite());
    }

    #[test]
    fn deterministic_given_seed() {
        let e = env(3, 3);
        let mut a = FedOpt::new(0.4, 2, 0.05);
        let mut b = FedOpt::new(0.4, 2, 0.05);
        let sa = a.run(&e, 20, 5).unwrap();
        let sb = b.run(&e, 20, 5).unwrap();
        for (ra, rb) in sa.records.iter().zip(&sb.records) {
            assert_eq!(ra.train_loss, rb.train_loss);
            assert_eq!(ra.test_loss, rb.test_loss);
        }
    }
}
