//! The generic round engine: **one** protocol implementation, pluggable
//! along the two axes the unified formulation (Hanzely & Richtárik 2020;
//! Hanzely, Zhao, Kolar 2021) identifies:
//!
//! * **State storage** — [`Engine`] is generic over
//!   [`crate::model::ClientStore`]: [`DenseStore`] (every row eagerly in
//!   one [`ParamMatrix`]; the lockstep configuration, alias
//!   [`L2gdEngine`]) or [`ShardedStore`] (copy-on-write divergent rows
//!   only; the million-device configuration, alias
//!   [`ShardedL2gdEngine`]). Full-participation series are **bit
//!   identical** across the two stores — pinned by
//!   `tests/integration_sim.rs` and `tests/golden_series.rs`.
//! * **Communication schedule** — a [`CommSchedule`] deals each
//!   iteration's [`StepKind`]: the paper's Bernoulli ξ [`Coin`] (L2GD) or
//!   the baselines' [`FixedCadence`] (T local steps, then communicate).
//!   A [`ServerOpt`] hook transforms the aggregated ȳ into the broadcast
//!   anchor: plain averaging (L2GD, FedAvg) or server Adam on the
//!   pseudo-gradient w − ȳ (FedOpt). [`AlgSpec`] bundles one point in
//!   this family; [`FLEET_ALGS`] lists the registered names.
//!
//! ### The protocol surface (sorted cohort-id lists)
//! Every phase takes a **sorted list of distinct client ids** and does
//! O(cohort · d) work — the fleet simulator's contract:
//!
//! * [`Engine::step_local`] — fused gradient+update for the cohort (a CoW
//!   row materializes on this first divergent step).
//! * [`Engine::step_aggregate_cached`] — aggregation toward the cached
//!   anchor, no communication.
//! * [`Engine::compress_uplinks`] / [`Engine::complete_fresh`] /
//!   [`Engine::abort_fresh`] — the two-phase communicating round:
//!   compress the cohort's models into their wire buffers (read-only on
//!   the store), then meter arrivals (stragglers as discarded traffic),
//!   decode-accumulate ȳ over fixed [`REDUCE_LEAF`]-client leaves,
//!   broadcast the anchor to the arrived cohort, and aggregate.
//!
//! The historical `&[bool]` participation masks survive only as thin
//! `*_masked` adapters for the lockstep tests — they translate to sorted
//! cohorts and are bit-identical to the id-list entry points (pinned by
//! the adapter-equivalence tests).
//!
//! Lockstep [`Engine::step`] drives the same phases with the full-fleet
//! cohort, so a simulator that executes every drawn kind with everyone
//! participating reproduces it exactly. Dense stores additionally take
//! pooled full-fleet sweeps over the flat matrix (bit-equal to the
//! sequential cohort loop — rows are disjoint and the arithmetic is
//! per-row); after warmup a dense lockstep step touches the allocator
//! zero times (asserted in `pfl bench` / `benches/perf_round_latency.rs`).
//! Sharded stores take pooled **per-shard** cohort sweeps: the sorted
//! cohort partitions into contiguous per-shard spans (`shard_spans_of`)
//! and each span runs on one worker via
//! [`ShardedStore::par_cohort_rows`] — shards own disjoint arenas, ids
//! run in cohort order within a span, and the ȳ reduction already uses
//! fixed leaves, so the series stays bit-identical to the sequential
//! loop at any pool size (pinned in `rust/tests/kernel_parity.rs`) and
//! the CountingAlloc budgets are unchanged (workers perform exactly the
//! sequential loop's arena growth). The pooled local sweeps require
//! cached static batches (the convex hot path `pfl bench` tracks);
//! non-static backends and the uplink compression phase run the
//! sequential cohort loop — per-client state lives in a lazy map, and
//! compressing n small models is noise next to the gradient work. If a
//! dense non-static workload ever becomes hot (it needs a real PJRT
//! runtime, absent offline), give it a pooled slot-vector sweep like the
//! pre-unification engine's.
//!
//! ### Per-client wire state
//! Every client's batch-RNG stream, compressor state (own RNG stream, EF
//! residual) and wire buffer live in a lazily materialized [`CohortSlot`],
//! seeded by *random-access* stream derivation
//! ([`crate::util::rng::stream_seed`]): client i's streams are a pure
//! function of (run seed, i), so dense and sharded engines — and the
//! reference oracle — instantiate bit-identical state no matter when (or
//! whether) a client is first touched.
//!
//! ### Wire framing
//! [`Engine::enable_wire_framing`] switches the metering (not the math)
//! to byte-accurate [`crate::transport::frame`] frames: each payload is
//! framed, decode-roundtripped, and `LinkStats` is fed the serialized
//! size. Transport attribution is per client for dense stores and per
//! client-shard for sharded ones ([`crate::transport::Network::sharded`]).

use std::cell::RefCell;
use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex};

use super::{evaluate, FedEnv, L2gd};
use crate::compress::{Compressed, Compressor, CompressorState};
use crate::metrics::Record;
use crate::model::{kernels, ClientStore, DenseStore, ParamMatrix, ShardedStore,
                   REDUCE_LEAF};
use crate::obs;
use crate::protocol::{Coin, CoinStats, CommSchedule, FixedCadence, StepKind};
use crate::runtime::{Backend as _, GradBuf};
use crate::transport::frame::{self, FrameHeader, SpecTable};
use crate::transport::Network;
use crate::util::rng::stream_seed;
use crate::util::Rng;

/// Salt for per-client compression-stream seeds: client i's compressor
/// state is seeded `stream_seed(env.seed ^ COMP_STREAM_SALT, i)` — O(1)
/// random access, so any engine (or the reference oracle) instantiates
/// the *identical* stream lazily on a client's first touch.
pub const COMP_STREAM_SALT: u64 = 0xC09B;

/// Per-client batch-sampling stream for client `i` — the random-access
/// counterpart of the old sequential fork walk, shared by both stores'
/// engines and the reference oracle.
pub fn client_stream(seed: u64, i: usize) -> Rng {
    Rng::stream(seed, i as u64 + 1)
}

/// Registered fleet-algorithm names — what `alg=` accepts in the scenario
/// grammar and `pfl sim` lists in its errors and `--help`.
pub const FLEET_ALGS: &[&str] = &["l2gd", "fedavg", "fedopt"];

/// Byte-accurate wire mode (see the module docs): spec-id table plus a
/// reusable frame buffer. Metering-only — the training math never touches
/// this.
pub(crate) struct Framing {
    pub(crate) table: SpecTable,
    pub(crate) client_id: u16,
    pub(crate) master_id: u16,
    pub(crate) buf: Vec<u8>,
}

impl Framing {
    /// Intern the two wire specs and start with an empty frame buffer.
    pub(crate) fn new(client_spec: &str, master_spec: &str) -> Framing {
        let mut table = SpecTable::new();
        let client_id = table.intern(client_spec);
        let master_id = table.intern(master_spec);
        Framing { table, client_id, master_id, buf: Vec::new() }
    }

    /// Encode, decode back, verify, and return the serialized size in bits.
    fn roundtrip(&mut self, h: FrameHeader, payload: &[u8]) -> anyhow::Result<u64> {
        frame::encode_frame(&h, payload, &mut self.buf);
        let (h2, p2) = frame::decode_frame(&self.buf)?;
        anyhow::ensure!(h2 == h && p2 == payload,
                        "wire frame roundtrip mismatch at step {}", h.round);
        Ok((self.buf.len() * 8) as u64)
    }

    pub(crate) fn uplink_bits(&mut self, k: u64, client: usize, wire: &Compressed)
                              -> anyhow::Result<u64> {
        let h = FrameHeader::uplink(k, client, self.client_id, wire)?;
        self.roundtrip(h, &wire.payload)
    }

    pub(crate) fn broadcast_bits(&mut self, k: u64, wire: &Compressed)
                                 -> anyhow::Result<u64> {
        let h = FrameHeader::broadcast(k, self.master_id, wire)?;
        self.roundtrip(h, &wire.payload)
    }
}

/// Lazily materialized per-client wire state, created on the client's
/// first touch with random-access stream seeds (see the module docs).
struct CohortSlot {
    /// batch-sampling stream (drawn only for non-static backends)
    rng: Rng,
    /// stateful compressor instance (own RNG stream, EF residual)
    comp: Box<dyn CompressorState>,
    /// reusable wire buffer
    wire: Compressed,
}

fn new_slot(seed: u64, d: usize, comp: &Arc<dyn Compressor>, i: u32) -> CohortSlot {
    CohortSlot {
        rng: client_stream(seed, i as usize),
        comp: comp.instantiate(d, stream_seed(seed ^ COMP_STREAM_SALT, i as u64)),
        wire: Compressed::empty(),
    }
}

thread_local! {
    /// Per-worker gradient buffer for the pooled dense local sweep (the
    /// sequential cohort path uses the engine's own buffer instead).
    /// Warmed by `on_each_worker` at engine build so dynamic client →
    /// worker assignment can't surface a first-use allocation inside a
    /// measured steady state.
    static POOL_GRAD: RefCell<GradBuf> = RefCell::new(GradBuf::new());
}

/// How the engine schedules communication — see [`CommSchedule`].
#[derive(Clone, Copy, Debug)]
pub enum ScheduleSpec {
    /// Bernoulli ξ coin at probability `p` (L2GD).
    Coin { p: f64 },
    /// `local_steps` local iterations, then one communicating aggregation
    /// (FedAvg / FedOpt).
    Every { local_steps: u64 },
}

/// How the master turns the aggregated ȳ into the broadcast anchor.
#[derive(Clone, Copy, Debug)]
pub enum ServerSpec {
    /// Broadcast C_M(ȳ) itself (L2GD, FedAvg).
    Average,
    /// Server Adam (Reddi et al. 2020) on the pseudo-gradient w − ȳ;
    /// broadcast C_M(w) (FedOpt).
    Adam { lr: f64, beta1: f64, beta2: f64, tau: f64 },
}

/// One member of the unified algorithm family: coefficients, schedule,
/// server transform, and the two compression descriptors. Build with the
/// per-algorithm constructors; [`Engine::from_spec`] runs it over either
/// store.
pub struct AlgSpec {
    /// registered name (one of [`FLEET_ALGS`])
    pub name: String,
    /// local gradient-step coefficient (η/(n(1−p)) for L2GD, the local
    /// learning rate for the baselines)
    pub local_coef: f64,
    /// aggregation-step coefficient x ← x − a·(x − anchor); exactly 1 for
    /// the reset-onto-the-broadcast baselines
    pub agg_coef: f64,
    pub schedule: ScheduleSpec,
    pub server: ServerSpec,
    /// client → master compression descriptor C_i
    pub client_comp: Arc<dyn Compressor>,
    /// master → clients compression descriptor C_M
    pub master_comp: Arc<dyn Compressor>,
}

impl AlgSpec {
    /// The paper's compressed L2GD (Algorithm 1) at fleet size `fleet_n`.
    pub fn l2gd(alg: &L2gd, fleet_n: usize) -> anyhow::Result<AlgSpec> {
        anyhow::ensure!(alg.p > 0.0 || alg.lambda == 0.0,
                        "p = 0 only valid for λ = 0 (pure local training)");
        Ok(AlgSpec {
            name: "l2gd".into(),
            local_coef: alg.local_coef(fleet_n),
            agg_coef: alg.agg_coef(fleet_n),
            schedule: ScheduleSpec::Coin { p: alg.p },
            server: ServerSpec::Average,
            client_comp: Arc::clone(&alg.client_comp),
            master_comp: Arc::clone(&alg.master_comp),
        })
    }

    /// FedAvg as the unified family's fixed-cadence, reset-to-anchor
    /// member (Figs 7–8: FedAvg ≡ L2GD at ηλ/np = 1): `local_steps` local
    /// iterations per round, uplink C(x_i), anchor = C_M(ȳ), aggregation
    /// coefficient 1 (every arrived client resets onto the broadcast —
    /// under full participation with identity wires this *is* FedAvg with
    /// a uniform client average).
    pub fn fedavg(local_lr: f64, local_steps: u64, client_spec: &str,
                  master_spec: &str) -> anyhow::Result<AlgSpec> {
        anyhow::ensure!(local_lr > 0.0, "fedavg local_lr must be positive");
        anyhow::ensure!(local_steps > 0, "fedavg needs ≥ 1 local step per round");
        Ok(AlgSpec {
            name: "fedavg".into(),
            local_coef: local_lr,
            agg_coef: 1.0,
            schedule: ScheduleSpec::Every { local_steps },
            server: ServerSpec::Average,
            client_comp: crate::compress::from_spec(client_spec)?,
            master_comp: crate::compress::from_spec(master_spec)?,
        })
    }

    /// FedOpt / FedAdam (Reddi et al. 2020): the FedAvg cadence with a
    /// server Adam over the pseudo-gradient w − ȳ; the broadcast anchor
    /// is the updated server model w.
    pub fn fedopt(local_lr: f64, local_steps: u64, server_lr: f64,
                  client_spec: &str, master_spec: &str) -> anyhow::Result<AlgSpec> {
        anyhow::ensure!(local_lr > 0.0, "fedopt local_lr must be positive");
        anyhow::ensure!(local_steps > 0, "fedopt needs ≥ 1 local step per round");
        anyhow::ensure!(server_lr > 0.0, "fedopt server_lr must be positive");
        Ok(AlgSpec {
            name: "fedopt".into(),
            local_coef: local_lr,
            agg_coef: 1.0,
            schedule: ScheduleSpec::Every { local_steps },
            server: ServerSpec::Adam { lr: server_lr, beta1: 0.9, beta2: 0.99,
                                       tau: 1e-3 },
            client_comp: crate::compress::from_spec(client_spec)?,
            master_comp: crate::compress::from_spec(master_spec)?,
        })
    }
}

/// Server-side anchor transform state (see [`ServerSpec`]).
enum ServerOpt {
    Average,
    Adam {
        /// the server model w (initialized to the shared init)
        w: Vec<f32>,
        m: Vec<f64>,
        v: Vec<f64>,
        lr: f64,
        beta1: f64,
        beta2: f64,
        tau: f64,
    },
}

/// The unified round engine — see the module docs. `S` picks the state
/// storage; the [`AlgSpec`] picks the algorithm.
pub struct Engine<'e, S: ClientStore> {
    env: &'e FedEnv,
    /// fleet size (may vastly exceed `env.n_clients()` data shards)
    n: usize,
    d: usize,
    local_coef: f32,
    agg_coef: f32,
    store: S,
    /// implicit value of every unmaterialized row (shared init; re-based
    /// only by a full-fleet exact reset on CoW stores)
    base: Vec<f32>,
    /// last broadcast anchor
    anchor: Vec<f32>,
    /// true until the first fresh round: the anchor still *is* the base,
    /// so cached aggregation on an unmaterialized row is a bitwise no-op
    /// and must not materialize it
    anchor_is_base: bool,
    /// master accumulator ȳ = (1/|cohort|) Σ C_i(x_i)
    ybar: Vec<f32>,
    slots: HashMap<u32, CohortSlot>,
    /// every client that has ever been in a cohort
    touched: HashSet<u32>,
    client_comp: Arc<dyn Compressor>,
    master_state: Box<dyn CompressorState>,
    master_buf: Compressed,
    /// gradient buffer for the sequential cohort sweep (the pooled dense
    /// sweep uses per-worker thread-local buffers)
    grad: GradBuf,
    schedule: Box<dyn CommSchedule>,
    server: ServerOpt,
    net: Network,
    seed: u64,
    /// canonical spec strings (frame header spec-id interning)
    client_spec: String,
    master_spec: String,
    /// byte-accurate wire metering, enabled by the fleet simulator
    framing: Option<Framing>,
    /// exact (dense-compatible) evaluation when the fleet == data shards
    exact_eval: bool,
    // reusable scratch (the hot loops are allocation-bounded)
    leaf_rows: Vec<f32>,
    leaf_spans: Vec<(u32, u32)>,
    /// per-shard `[lo, hi)` runs of the current cohort — scratch for the
    /// pooled per-shard sweeps on CoW stores
    shard_spans: Vec<(u32, u32)>,
    release_scratch: Vec<u32>,
    /// lazily built full-fleet cohort for the lockstep [`Engine::step`]
    full: Vec<u32>,
    /// bool-mask adapter scratch
    mask_a: Vec<u32>,
    mask_b: Vec<u32>,
    /// error parked by a pooled sweep worker (allocates only on failure)
    sweep_err: Mutex<Option<anyhow::Error>>,
}

/// The lockstep dense configuration (the historical `L2gdEngine`).
pub type L2gdEngine<'e> = Engine<'e, DenseStore>;

/// The copy-on-write fleet-scale configuration (the historical
/// `ShardedL2gdEngine` — now just the generic engine over a
/// [`ShardedStore`]).
pub type ShardedL2gdEngine<'e> = Engine<'e, ShardedStore>;

impl<'e, S: ClientStore> Engine<'e, S> {
    /// L2GD (Algorithm 1) over a `fleet_n`-device fleet on `env`'s data
    /// shards. `fleet_n == env.n_clients()` is the lockstep-equivalent
    /// configuration (exact evaluation, identity data mapping).
    pub fn new(alg: &L2gd, env: &'e FedEnv, fleet_n: usize)
               -> anyhow::Result<Engine<'e, S>> {
        Self::from_spec(&AlgSpec::l2gd(alg, fleet_n)?, env, fleet_n)
    }

    /// Build the engine for any member of the unified family.
    pub fn from_spec(spec: &AlgSpec, env: &'e FedEnv, fleet_n: usize)
                     -> anyhow::Result<Engine<'e, S>> {
        anyhow::ensure!(fleet_n > 0, "empty fleet");
        anyhow::ensure!(env.n_clients() > 0, "environment has no data shards");
        let d = env.backend.param_count();
        let local_coef = spec.local_coef as f32;
        let agg_coef = spec.agg_coef as f32;
        // x ← (1−a)x + a·anchor is a contraction toward the anchor only
        // for a ∈ (0, 2); beyond 2 the aggregation step diverges. (The
        // paper's stable regimes are a ∈ (0, 0.17] and a ≈ 1 — §VII-B;
        // the fixed-cadence baselines sit at exactly 1.)
        anyhow::ensure!(agg_coef.is_finite() && (0.0..2.0).contains(&agg_coef),
                        "aggregation coefficient {agg_coef} outside [0,2): \
                         aggregation diverges");
        let init = env.backend.init_params();
        let store = S::new_fleet(fleet_n, d, &init);
        let schedule: Box<dyn CommSchedule> = match spec.schedule {
            // the same coin stream whatever the store, so dense and
            // sharded runs share one protocol trajectory
            ScheduleSpec::Coin { p } => Box::new(Coin::new(p, env.seed ^ 0xC011)),
            ScheduleSpec::Every { local_steps } => {
                Box::new(FixedCadence::new(local_steps))
            }
        };
        let server = match spec.server {
            ServerSpec::Average => ServerOpt::Average,
            ServerSpec::Adam { lr, beta1, beta2, tau } => ServerOpt::Adam {
                w: init.clone(),
                m: vec![0.0f64; d],
                v: vec![0.0f64; d],
                lr,
                beta1,
                beta2,
                tau,
            },
        };
        // Warm every worker's thread-local compression scratch and
        // gradient buffer with throwaway state of the same shapes:
        // client → worker assignment is dynamic, so without this a cold
        // worker could take its first-use allocation in the middle of a
        // measured steady state.
        let comp = &spec.client_comp;
        env.pool.on_each_worker(|w| {
            let mut st = comp.instantiate(d, 0x3CA7F ^ w as u64);
            let mut buf = Compressed::empty();
            let probe = vec![0.0f32; d];
            let _ = st.compress_into(&probe, &mut buf);
            POOL_GRAD.with(|g| g.borrow_mut().grad.resize(d, 0.0));
        });
        // force the lazy per-shard train-batch cache off the hot path
        let _ = env.train_batch_cached(0);
        let net = Network::sharded(fleet_n, store.link_shard_size());
        Ok(Engine {
            env,
            n: fleet_n,
            d,
            local_coef,
            agg_coef,
            store,
            base: init.clone(),
            anchor: init,
            anchor_is_base: true,
            ybar: vec![0.0f32; d],
            slots: HashMap::new(),
            touched: HashSet::new(),
            client_comp: Arc::clone(&spec.client_comp),
            master_state: spec.master_comp.instantiate(d, env.seed ^ 0x3a57e5),
            master_buf: Compressed::empty(),
            grad: GradBuf::with_dim(d),
            schedule,
            server,
            net,
            seed: env.seed,
            client_spec: spec.client_comp.name(),
            master_spec: spec.master_comp.name(),
            framing: None,
            exact_eval: fleet_n == env.n_clients(),
            leaf_rows: Vec::new(),
            leaf_spans: Vec::new(),
            shard_spans: Vec::new(),
            release_scratch: Vec::new(),
            full: Vec::new(),
            mask_a: Vec::new(),
            mask_b: Vec::new(),
            sweep_err: Mutex::new(None),
        })
    }

    /// Fleet size.
    pub fn n_fleet(&self) -> usize {
        self.n
    }

    /// The client-state store (occupancy / resident-bytes assertions).
    pub fn store(&self) -> &S {
        &self.store
    }

    /// Distinct clients that have ever appeared in a cohort.
    pub fn touched_clients(&self) -> usize {
        self.touched.len()
    }

    pub fn net(&self) -> &Network {
        &self.net
    }

    /// Client `i`'s effective model row (the base when undiverged).
    pub fn row_or_base(&self, i: usize) -> &[f32] {
        self.store.row(i).unwrap_or(&self.base)
    }

    /// The shared base vector missing rows implicitly equal.
    pub fn base(&self) -> &[f32] {
        &self.base
    }

    /// Data shard fleet device `i` trains/evaluates on — the canonical
    /// `i mod data shards` mapping (documented in [`crate::sim`]).
    pub fn data_shard(&self, i: usize) -> usize {
        i % self.env.n_clients()
    }

    /// Switch the wire metering to byte-accurate frames: `LinkStats` is
    /// fed the serialized frame size (header + byte-aligned payload), and
    /// every frame is encode/decode roundtrip-checked. The training math —
    /// and therefore the loss series — is unchanged.
    pub fn enable_wire_framing(&mut self) {
        self.framing = Some(Framing::new(&self.client_spec, &self.master_spec));
    }

    /// The frame spec-id table (present once framing is enabled).
    pub fn spec_table(&self) -> Option<&SpecTable> {
        self.framing.as_ref().map(|f| &f.table)
    }

    /// Swap the wire codecs mid-run (scenario phase boundaries). Safe on
    /// a live engine: in-flight [`Compressed`] buffers are self-describing
    /// and stay decodable whatever compressor produced them, so only the
    /// *next* compression uses the new codec. Per-client compressor
    /// states are re-instantiated under their original per-client stream
    /// seeds (a codec switch starts wire memory — EF residuals, RNG —
    /// fresh), and frame metering interns the new spec strings into the
    /// existing table, so ids already stamped on emitted frames keep
    /// resolving.
    pub fn set_compressors(&mut self, client: Arc<dyn Compressor>,
                           master: Arc<dyn Compressor>) {
        self.master_state = master.instantiate(self.d, self.seed ^ 0x3a57e5);
        self.client_spec = client.name();
        self.master_spec = master.name();
        for (&i, slot) in self.slots.iter_mut() {
            slot.comp = client
                .instantiate(self.d,
                             stream_seed(self.seed ^ COMP_STREAM_SALT,
                                         i as u64));
        }
        self.client_comp = client;
        if let Some(f) = &mut self.framing {
            f.client_id = f.table.intern(&self.client_spec);
            f.master_id = f.table.intern(&self.master_spec);
        }
    }

    /// Deal the next iteration's step kind — the simulator's dispatch
    /// point (lockstep [`Engine::step`] draws from the same schedule, so
    /// a simulator that executes every drawn kind reproduces it exactly).
    pub fn draw(&mut self) -> StepKind {
        self.schedule.draw()
    }

    /// Schedule statistics (locals / fresh / cached counts).
    pub fn coin_stats(&self) -> &CoinStats {
        self.schedule.stats()
    }

    /// Lockstep full-participation iteration (step index `k` is used for
    /// bit accounting only). On a warmed dense engine this performs zero
    /// heap allocations.
    pub fn step(&mut self, k: u64) -> anyhow::Result<()> {
        if self.full.len() != self.n {
            self.full = (0..self.n as u32).collect();
        }
        let full = std::mem::take(&mut self.full);
        let res = match self.schedule.draw() {
            StepKind::Local => self.step_local(&full),
            StepKind::AggregateFresh => self
                .compress_uplinks(&full)
                .and_then(|()| self.complete_fresh(k, &full, &full)),
            StepKind::AggregateCached => {
                self.step_aggregate_cached(&full);
                Ok(())
            }
        };
        self.full = full;
        res
    }

    /// Run `count` iterations starting after step `from` (so the last
    /// step index is `from + count`).
    pub fn run_steps(&mut self, from: u64, count: u64) -> anyhow::Result<()> {
        for k in from + 1..=from + count {
            self.step(k)?;
        }
        Ok(())
    }

    #[inline]
    fn debug_check_cohort(cohort: &[u32], n: usize) {
        debug_assert!(cohort.windows(2).all(|w| w[0] < w[1]),
                      "cohort must be sorted and distinct");
        debug_assert!(cohort.last().map_or(true, |&i| (i as usize) < n),
                      "cohort id out of range");
    }

    /// Partition a sorted cohort into maximal per-shard runs: one
    /// `[lo, hi)` index range per distinct shard, in cohort order.
    /// `shard_of(i) = i / shard_size` is monotonic over a sorted cohort,
    /// so the runs are contiguous and each shard appears at most once —
    /// the disjointness contract of
    /// [`ShardedStore::par_cohort_rows`].
    fn shard_spans_of(cohort: &[u32], shard_size: usize, out: &mut Vec<(u32, u32)>) {
        out.clear();
        let mut start = 0usize;
        while start < cohort.len() {
            let s = cohort[start] as usize / shard_size;
            let mut end = start + 1;
            while end < cohort.len() && cohort[end] as usize / shard_size == s {
                end += 1;
            }
            out.push((start as u32, end as u32));
            start = end;
        }
    }

    /// Surface the first worker-parked pooled-sweep error.
    fn take_sweep_err(&mut self) -> anyhow::Result<()> {
        match self.sweep_err.get_mut().unwrap().take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Local gradient step for the cohort — each member materializes its
    /// row on this first divergent step and updates it in place.
    pub fn step_local(&mut self, cohort: &[u32]) -> anyhow::Result<()> {
        obs::span_begin(obs::LOCAL_SWEEP, obs::LANE_ENGINE, obs::NO_SIM_TIME);
        let res = self.step_local_inner(cohort);
        obs::span_end(obs::LOCAL_SWEEP, obs::LANE_ENGINE, obs::NO_SIM_TIME);
        res
    }

    fn step_local_inner(&mut self, cohort: &[u32]) -> anyhow::Result<()> {
        Self::debug_check_cohort(cohort, self.n);
        for &i in cohort {
            self.touched.insert(i);
        }
        let env = self.env;
        let coef = self.local_coef;
        let nd = env.n_clients();
        let d = self.d;
        // Pooled full-fleet sweep over the flat matrix: dense store,
        // cached static batches (no per-client RNG draws). Rows are
        // disjoint and the arithmetic is per-row, so this is bit-identical
        // to the sequential cohort loop below.
        if cohort.len() == self.n && env.train_batch_cached(0).is_some() {
            if let Some(m) = self.store.as_dense_mut() {
                let err = &self.sweep_err;
                env.pool.scope_chunks_mut(m.as_mut_slice(), d, |i, x| {
                    let b = env.train_batch_cached(i % nd).expect("static batch");
                    POOL_GRAD.with(|g| {
                        let g = &mut *g.borrow_mut();
                        match env.backend.grad_into(x, b, g) {
                            Ok(()) => kernels::axpy(x, -coef, &g.grad),
                            Err(e) => *err.lock().unwrap() = Some(e),
                        }
                    });
                });
                return self.take_sweep_err();
            }
        }
        // Pooled per-shard cohort sweep for CoW stores: cached static
        // batches only (the non-static path threads per-client RNG slots
        // and stays sequential), and only when the cohort actually spans
        // several shards — single-shard cohorts (small fleets) keep the
        // sequential loop. Shards own disjoint arenas and each span runs
        // its ids in cohort order, so materialization order and
        // arithmetic are bit-identical to the sequential loop (pinned in
        // `rust/tests/kernel_parity.rs`).
        if env.train_batch_cached(0).is_some() {
            if let Some(st) = self.store.as_sharded_mut() {
                let mut spans = std::mem::take(&mut self.shard_spans);
                Self::shard_spans_of(cohort, st.shard_size(), &mut spans);
                let pooled = spans.len() > 1;
                if pooled {
                    let err = &self.sweep_err;
                    st.par_cohort_rows(&env.pool, cohort, &spans, &self.base, true,
                                       |i, x| {
                        let b = env.train_batch_cached(i % nd).expect("static batch");
                        POOL_GRAD.with(|g| {
                            let g = &mut *g.borrow_mut();
                            match env.backend.grad_into(x, b, g) {
                                Ok(()) => kernels::axpy(x, -coef, &g.grad),
                                Err(e) => *err.lock().unwrap() = Some(e),
                            }
                        });
                    });
                }
                self.shard_spans = spans;
                if pooled {
                    return self.take_sweep_err();
                }
            }
        }
        let seed = self.seed;
        let comp = &self.client_comp;
        let store = &mut self.store;
        let base = &self.base;
        let slots = &mut self.slots;
        let grad = &mut self.grad;
        for &i in cohort {
            let ds = i as usize % nd;
            let x = store.materialize(i as usize, base);
            match env.train_batch_cached(ds) {
                Some(b) => env.backend.grad_into(x, b, grad)?,
                None => {
                    let slot = slots
                        .entry(i)
                        .or_insert_with(|| new_slot(seed, d, comp, i));
                    let b = env.backend.make_train_batch(&env.shards[ds], &mut slot.rng);
                    env.backend.grad_into(x, &b, grad)?;
                }
            }
            kernels::axpy(x, -coef, &grad.grad);
        }
        Ok(())
    }

    /// Cached-anchor aggregation for the cohort.
    pub fn step_aggregate_cached(&mut self, cohort: &[u32]) {
        Self::debug_check_cohort(cohort, self.n);
        for &i in cohort {
            self.touched.insert(i);
        }
        self.apply_aggregation(cohort);
    }

    /// Phase 1 of a fresh round: compress the cohort's effective models
    /// into their (lazily created) wire buffers. Read-only on the store —
    /// an undiverged member compresses the base without materializing.
    pub fn compress_uplinks(&mut self, cohort: &[u32]) -> anyhow::Result<()> {
        obs::span_begin(obs::COMPRESS, obs::LANE_ENGINE, obs::NO_SIM_TIME);
        Self::debug_check_cohort(cohort, self.n);
        let (seed, d) = (self.seed, self.d);
        let comp = &self.client_comp;
        let store = &self.store;
        let base = &self.base;
        let slots = &mut self.slots;
        for &i in cohort {
            self.touched.insert(i);
            let x = store.row(i as usize).unwrap_or(base);
            let slot = slots.entry(i).or_insert_with(|| new_slot(seed, d, comp, i));
            slot.comp.compress_into(x, &mut slot.wire)?;
        }
        obs::span_end(obs::COMPRESS, obs::LANE_ENGINE, obs::NO_SIM_TIME);
        Ok(())
    }

    /// Serialized uplink frame size (bytes) for client `i`'s pending wire
    /// buffer — valid after [`Engine::compress_uplinks`] included `i`.
    pub fn uplink_frame_bytes(&self, i: usize) -> u64 {
        let slot = self.slots.get(&(i as u32)).expect("client has no wire buffer");
        (frame::HEADER_BYTES + slot.wire.payload.len()) as u64
    }

    /// Serialized downlink (anchor broadcast) frame size in bytes — valid
    /// after a fresh aggregation round.
    pub fn downlink_frame_bytes(&self) -> u64 {
        (frame::HEADER_BYTES + self.master_buf.payload.len()) as u64
    }

    /// Phase 2: meter uplinks (`sampled` − `arrived` as discarded
    /// straggler traffic), decode-accumulate ȳ over the arrived cohort
    /// via fixed-leaf partials, run the server transform, broadcast the
    /// anchor to the arrived cohort, and aggregate. Errors on an empty
    /// cohort (the simulator skips the round instead).
    pub fn complete_fresh(&mut self, k: u64, arrived: &[u32], sampled: &[u32])
                          -> anyhow::Result<()> {
        Self::debug_check_cohort(arrived, self.n);
        Self::debug_check_cohort(sampled, self.n);
        anyhow::ensure!(!arrived.is_empty(), "fresh aggregation with an empty cohort");
        obs::span_begin(obs::AGGREGATE, obs::LANE_ENGINE, obs::NO_SIM_TIME);
        let count = arrived.len();
        self.net.begin_round();
        // meter every transmitted frame; only arrived devices participate
        {
            let slots = &self.slots;
            let framing = &mut self.framing;
            let net = &mut self.net;
            let mut ai = 0usize;
            for &i in sampled {
                let is_arrived = ai < arrived.len() && arrived[ai] == i;
                if is_arrived {
                    ai += 1;
                }
                let slot = slots.get(&i).expect("sampled client has no wire buffer");
                let bits = match framing {
                    Some(f) => f.uplink_bits(k, i as usize, &slot.wire)?,
                    None => slot.wire.bits,
                };
                if is_arrived {
                    net.uplink(k, i as usize, bits);
                } else {
                    net.uplink_wasted(k, i as usize, bits);
                }
            }
            debug_assert_eq!(ai, arrived.len(), "arrived must be a subset of sampled");
        }
        // master: ȳ = (1/count) Σ_arrived C_i(x_i). Small fleets
        // accumulate sequentially (bit-identical to the seed); larger
        // fleets reduce per-leaf partials over the pool and combine them
        // in leaf order — deterministic, pool-size independent, and
        // bit-equal to a flat reduction because absent leaves would only
        // ever contribute +0.0.
        let inv = 1.0 / count as f32;
        if self.n <= REDUCE_LEAF {
            self.ybar.fill(0.0);
            for &i in arrived {
                self.slots[&i].wire.decode_add(&mut self.ybar, inv);
            }
        } else {
            let d = self.d;
            self.leaf_spans.clear();
            let mut start = 0usize;
            while start < arrived.len() {
                let leaf = arrived[start] as usize / REDUCE_LEAF;
                let mut end = start + 1;
                while end < arrived.len()
                    && arrived[end] as usize / REDUCE_LEAF == leaf
                {
                    end += 1;
                }
                self.leaf_spans.push((start as u32, end as u32));
                start = end;
            }
            self.leaf_rows.clear();
            self.leaf_rows.resize(self.leaf_spans.len() * d, 0.0);
            let spans = &self.leaf_spans;
            let slots = &self.slots;
            self.env.pool.scope_chunks_mut(&mut self.leaf_rows, d, |j, row| {
                row.fill(0.0);
                let (lo, hi) = spans[j];
                for &i in &arrived[lo as usize..hi as usize] {
                    slots[&i].wire.decode_add(row, inv);
                }
            });
            self.ybar.fill(0.0);
            for row in self.leaf_rows.chunks_exact(d) {
                kernels::add_assign(&mut self.ybar, row);
            }
        }
        self.server_transform_and_broadcast(k, arrived)?;
        self.apply_aggregation(arrived);
        obs::span_end(obs::AGGREGATE, obs::LANE_ENGINE, obs::NO_SIM_TIME);
        Ok(())
    }

    /// Shared tail of a communicating aggregate once ȳ is accumulated:
    /// server transform, anchor compression, downlink metering to the
    /// arrived cohort, anchor decode, and the round close.
    fn server_transform_and_broadcast(&mut self, k: u64, arrived: &[u32])
                                      -> anyhow::Result<()> {
        // server transform: plain averaging broadcasts C_M(ȳ); server
        // Adam treats Δ = w − ȳ as a pseudo-gradient, updates w, and
        // broadcasts C_M(w)
        let d = self.d;
        let src: &[f32] = match &mut self.server {
            ServerOpt::Average => &self.ybar,
            ServerOpt::Adam { w, m, v, lr, beta1, beta2, tau } => {
                for j in 0..d {
                    let g = (w[j] - self.ybar[j]) as f64;
                    m[j] = *beta1 * m[j] + (1.0 - *beta1) * g;
                    v[j] = *beta2 * v[j] + (1.0 - *beta2) * g * g;
                    w[j] -= (*lr * m[j] / (v[j].sqrt() + *tau)) as f32;
                }
                w.as_slice()
            }
        };
        self.master_state.compress_into(src, &mut self.master_buf)?;
        // downlink the anchor to the arrived cohort only
        let down_bits = match &mut self.framing {
            Some(f) => f.broadcast_bits(k, &self.master_buf)?,
            None => self.master_buf.bits,
        };
        for &i in arrived {
            self.net.downlink(k, i as usize, down_bits);
        }
        obs::span_begin(obs::DECOMPRESS, obs::LANE_ENGINE, obs::NO_SIM_TIME);
        self.master_buf.decode_into(&mut self.anchor);
        obs::span_end(obs::DECOMPRESS, obs::LANE_ENGINE, obs::NO_SIM_TIME);
        self.anchor_is_base = false;
        self.net.end_round();
        Ok(())
    }

    /// Phase 2 of an asynchronous *buffered* aggregate: like
    /// [`Engine::complete_fresh`], but ȳ is the staleness-weighted convex
    /// combination ȳ = Σ w_j·C_j(x_j) / Σ w_j over the applied updates —
    /// the anchor stays a weighted average of client models, so the L2GD
    /// aggregation semantics survive (constant weights recover the
    /// uniform mean). Only applied updates meter here; the async runner
    /// meters stale and straggler discards via [`Engine::discard_uplink`].
    pub fn complete_fresh_weighted(&mut self, k: u64, arrived: &[u32],
                                   weights: &[f32]) -> anyhow::Result<()> {
        Self::debug_check_cohort(arrived, self.n);
        anyhow::ensure!(!arrived.is_empty(),
                        "weighted aggregation with an empty buffer");
        anyhow::ensure!(arrived.len() == weights.len(),
                        "{} updates with {} weights",
                        arrived.len(), weights.len());
        obs::span_begin(obs::AGGREGATE, obs::LANE_ENGINE, obs::NO_SIM_TIME);
        let mut wsum = 0.0f64;
        for &w in weights {
            anyhow::ensure!(w.is_finite() && w > 0.0,
                            "staleness weight {w} must be positive and finite");
            wsum += w as f64;
        }
        self.net.begin_round();
        {
            let slots = &self.slots;
            let framing = &mut self.framing;
            let net = &mut self.net;
            for &i in arrived {
                let slot =
                    slots.get(&i).expect("applied client has no wire buffer");
                let bits = match framing {
                    Some(f) => f.uplink_bits(k, i as usize, &slot.wire)?,
                    None => slot.wire.bits,
                };
                net.uplink(k, i as usize, bits);
            }
        }
        // buffered cohorts are buffer-sized (small): accumulate
        // sequentially in sorted-id order — deterministic whatever the
        // fleet size, no leaf partials needed
        self.ybar.fill(0.0);
        for (&i, &w) in arrived.iter().zip(weights) {
            let scale = (w as f64 / wsum) as f32;
            self.slots[&i].wire.decode_add(&mut self.ybar, scale);
        }
        self.server_transform_and_broadcast(k, arrived)?;
        self.apply_aggregation(arrived);
        obs::span_end(obs::AGGREGATE, obs::LANE_ENGINE, obs::NO_SIM_TIME);
        Ok(())
    }

    /// Meter client `i`'s pending uplink as traffic the async master
    /// discarded — stale (`stale = true`, past `max_stale` versions) or
    /// straggler-wasted — outside any round bracket (overlapping cohorts
    /// close independently of the engine's rounds). Valid after
    /// [`Engine::compress_uplinks`] included `i`.
    pub fn discard_uplink(&mut self, k: u64, i: u32, stale: bool)
                          -> anyhow::Result<()> {
        let bits = {
            let slot = self.slots.get(&i).ok_or_else(|| {
                anyhow::anyhow!("client {i} has no wire buffer to discard")
            })?;
            match &mut self.framing {
                Some(f) => f.uplink_bits(k, i as usize, &slot.wire)?,
                None => slot.wire.bits,
            }
        };
        if stale {
            self.net.offround_uplink_stale(k, i as usize, bits);
        } else {
            self.net.offround_uplink_wasted(k, i as usize, bits);
        }
        Ok(())
    }

    /// A fresh attempt where nobody made the deadline: the cohort's
    /// frames still metered as discarded traffic, nothing aggregates, the
    /// anchor does not move, and the round records zero participants.
    pub fn abort_fresh(&mut self, k: u64, sampled: &[u32]) -> anyhow::Result<()> {
        Self::debug_check_cohort(sampled, self.n);
        self.net.begin_round();
        for &i in sampled {
            let slot = self.slots.get(&i).expect("sampled client has no wire buffer");
            let bits = match &mut self.framing {
                Some(f) => f.uplink_bits(k, i as usize, &slot.wire)?,
                None => slot.wire.bits,
            };
            self.net.uplink_wasted(k, i as usize, bits);
        }
        self.net.end_round();
        Ok(())
    }

    /// `x_i ← x_i − a(x_i − anchor)` for the cohort. While the anchor is
    /// still the base (no fresh round yet), the step is a bitwise no-op
    /// on undiverged rows — they stay unmaterialized. On CoW stores a
    /// *full-fleet* exact reset (a = 1, every client in the cohort — the
    /// FedAvg regime) re-bases the implicit value onto the anchor and
    /// releases every row that landed exactly on it: "fully reset by a
    /// broadcast it equals, stores no row". (Re-basing is only sound when
    /// no client is left holding the old implicit value, hence the
    /// full-cohort guard; rows whose reset rounded off the anchor stay
    /// resident, preserving bit-equality with the dense store.)
    fn apply_aggregation(&mut self, cohort: &[u32]) {
        let a = self.agg_coef;
        // pooled full-fleet elementwise pass for dense stores when the
        // sweep is large enough to amortize dispatch (serial and pooled
        // orders are bit-identical — the kernel is elementwise)
        if !S::COW && cohort.len() == self.n {
            let d = self.d;
            let nd_total = self.n * d;
            let anchor = &self.anchor;
            if let Some(m) = self.store.as_dense_mut() {
                if nd_total < 1 << 15 {
                    for x in m.rows_mut() {
                        kernels::aggregation_step(x, a, anchor);
                    }
                } else {
                    self.env.pool.scope_chunks_mut(m.as_mut_slice(), d, |_i, x| {
                        kernels::aggregation_step(x, a, anchor);
                    });
                }
                return;
            }
        }
        // Pooled per-shard cohort aggregation for CoW stores (the kernel
        // is elementwise, so per-shard execution order cannot change a
        // bit; within a shard rows still materialize in cohort order).
        // While the anchor is still the base the step is a bitwise no-op
        // on unmaterialized rows, so skip-missing mode reproduces the
        // sequential loop's continue exactly. Single-shard cohorts keep
        // the sequential loop.
        let mut pooled = false;
        if let Some(st) = self.store.as_sharded_mut() {
            let mut spans = std::mem::take(&mut self.shard_spans);
            Self::shard_spans_of(cohort, st.shard_size(), &mut spans);
            if spans.len() > 1 {
                let anchor = &self.anchor;
                st.par_cohort_rows(&self.env.pool, cohort, &spans, &self.base,
                                   !self.anchor_is_base,
                                   |_, x| kernels::aggregation_step(x, a, anchor));
                pooled = true;
            }
            self.shard_spans = spans;
        }
        if !pooled {
            for &i in cohort {
                if self.anchor_is_base && self.store.row(i as usize).is_none() {
                    // x = base, anchor = base ⇒ x − a·(x − x) ≡ x bitwise
                    continue;
                }
                let x = self.store.materialize(i as usize, &self.base);
                kernels::aggregation_step(x, a, &self.anchor);
            }
        }
        if S::COW && a == 1.0 && cohort.len() == self.n && !self.anchor_is_base {
            self.base.copy_from_slice(&self.anchor);
            self.anchor_is_base = true; // anchor ≡ base again
            {
                let scratch = &mut self.release_scratch;
                scratch.clear();
                let base = &self.base;
                self.store.for_each_row(|id, row| {
                    if row == &base[..] {
                        scratch.push(id as u32);
                    }
                });
            }
            let scratch = std::mem::take(&mut self.release_scratch);
            for &i in &scratch {
                self.store.release(i as usize);
            }
            self.release_scratch = scratch;
        }
    }

    // --- bool-mask adapters -------------------------------------------------
    //
    // The historical `&[bool]` participation surface, kept only for the
    // lockstep/equivalence tests: each adapter translates its mask to a
    // sorted cohort (reusable scratch) and calls the id-list entry point,
    // so the two surfaces are bit-identical by construction — pinned by
    // the adapter-equivalence tests in `tests/integration_fleet_algs.rs`.

    fn mask_to(mask: &[bool], out: &mut Vec<u32>) {
        out.clear();
        for (i, &b) in mask.iter().enumerate() {
            if b {
                out.push(i as u32);
            }
        }
    }

    /// [`Engine::step_local`] over a participation mask.
    pub fn step_local_masked(&mut self, active: &[bool]) -> anyhow::Result<()> {
        anyhow::ensure!(active.len() == self.n,
                        "participation mask length {} != n {}", active.len(), self.n);
        let mut c = std::mem::take(&mut self.mask_a);
        Self::mask_to(active, &mut c);
        let res = self.step_local(&c);
        self.mask_a = c;
        res
    }

    /// [`Engine::step_aggregate_cached`] over a participation mask.
    pub fn step_aggregate_cached_masked(&mut self, active: &[bool]) {
        assert_eq!(active.len(), self.n, "participation mask length != n");
        let mut c = std::mem::take(&mut self.mask_a);
        Self::mask_to(active, &mut c);
        self.step_aggregate_cached(&c);
        self.mask_a = c;
    }

    /// [`Engine::compress_uplinks`] over a participation mask.
    pub fn compress_uplinks_masked(&mut self, sampled: &[bool]) -> anyhow::Result<()> {
        anyhow::ensure!(sampled.len() == self.n,
                        "participation mask length {} != n {}", sampled.len(), self.n);
        let mut c = std::mem::take(&mut self.mask_a);
        Self::mask_to(sampled, &mut c);
        let res = self.compress_uplinks(&c);
        self.mask_a = c;
        res
    }

    /// [`Engine::complete_fresh`] over participation masks.
    pub fn complete_fresh_masked(&mut self, k: u64, arrived: &[bool],
                                 sampled: &[bool]) -> anyhow::Result<()> {
        anyhow::ensure!(arrived.len() == self.n && sampled.len() == self.n,
                        "participation mask length != n {}", self.n);
        let mut a = std::mem::take(&mut self.mask_a);
        let mut s = std::mem::take(&mut self.mask_b);
        Self::mask_to(arrived, &mut a);
        Self::mask_to(sampled, &mut s);
        let res = self.complete_fresh(k, &a, &s);
        self.mask_a = a;
        self.mask_b = s;
        res
    }

    /// [`Engine::abort_fresh`] over a participation mask.
    pub fn abort_fresh_masked(&mut self, k: u64, sampled: &[bool])
                              -> anyhow::Result<()> {
        anyhow::ensure!(sampled.len() == self.n,
                        "participation mask length {} != n {}", sampled.len(), self.n);
        let mut c = std::mem::take(&mut self.mask_a);
        Self::mask_to(sampled, &mut c);
        let res = self.abort_fresh(k, &c);
        self.mask_a = c;
        res
    }

    // --- evaluation ---------------------------------------------------------

    /// Evaluate into a `Record`. Exact (store-view) evaluation when the
    /// fleet equals the data-shard count; O(occupancy) at fleet scale.
    pub fn evaluate(&self, step: u64) -> anyhow::Result<Record> {
        if self.exact_eval {
            return evaluate(self.env, self.store.view(&self.base), step, &self.net);
        }
        self.evaluate_touched(step)
    }

    /// Personalized metrics in touched-mode evaluation cover at most this
    /// many divergent rows (deterministic materialization order): keeps a
    /// record's cost bounded however many clients a long run touches. The
    /// global-model metrics are always exact over the whole fleet.
    pub const PERSONAL_EVAL_CAP: usize = 2048;

    /// Fleet-scale evaluation in O(occupancy): exact global mean via the
    /// base identity `x̄ = ((n−m)·base + Σ materialized)/n`, personalized
    /// metrics averaged over (a capped sample of) the divergent clients
    /// (the base on data shard 0 when nothing has diverged yet).
    fn evaluate_touched(&self, step: u64) -> anyhow::Result<Record> {
        let be = &self.env.backend;
        let m = self.store.materialized_rows();
        let mut global = vec![0.0f32; self.d];
        self.store.for_each_row(|_, row| kernels::add_assign(&mut global, row));
        let n_f = self.n as f32;
        kernels::scale(&mut global, 1.0 / n_f);
        kernels::axpy(&mut global, (self.n - m) as f32 / n_f, &self.base);
        let train = be.eval(&global, self.env.train_eval_batch())?;
        let test = be.eval(&global, self.env.test_batch())?;

        let nd = self.env.n_clients();
        let (mut pl, mut pa, mut cnt) = (0.0f64, 0.0f64, 0usize);
        self.store.for_each_row(|i, row| {
            if cnt >= Self::PERSONAL_EVAL_CAP {
                return;
            }
            match be.eval(row, self.env.shard_eval_batch(i % nd)) {
                Ok(e) => {
                    pl += e.loss;
                    pa += e.accuracy;
                }
                Err(_) => {
                    pl += f64::NAN;
                    pa += f64::NAN;
                }
            }
            cnt += 1;
        });
        let (personal_loss, personal_acc) = if cnt == 0 {
            let e = be.eval(&self.base, self.env.shard_eval_batch(0))?;
            (e.loss, e.accuracy)
        } else {
            (pl / cnt as f64, pa / cnt as f64)
        };
        Ok(Record {
            step,
            comm_rounds: self.net.comm_rounds(),
            bits_per_client: self.net.bits_per_client(),
            bits_up: self.net.total_bits_up(),
            bits_down: self.net.total_bits_down(),
            train_loss: train.loss,
            train_acc: train.accuracy,
            test_loss: test.loss,
            test_acc: test.accuracy,
            personal_loss,
            personal_acc,
            sim_time_s: self.net.simulated_comm_time_s(),
            participants: self.net.last_round_participants(),
        })
    }
}

impl<'e> Engine<'e, DenseStore> {
    /// The per-client models (row i = client i) — the lockstep tests' and
    /// benches' view of the dense store.
    pub fn xs(&self) -> &ParamMatrix {
        self.store.matrix()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::runtime::NativeLogreg;
    use crate::util::threadpool::ThreadPool;
    use std::sync::Arc;

    fn env(n: usize, seed: u64) -> FedEnv {
        let (data, test) = synth::logistic_split(50 * n, 100, 16, 0.02, seed);
        let shards = data.split_contiguous(n);
        FedEnv::new(Arc::new(NativeLogreg::new(16, 0.01, 64, 128)),
                    shards, data, test, ThreadPool::new(4), seed)
    }

    fn assert_rows_equal(dense: &L2gdEngine, cow: &ShardedL2gdEngine, tag: &str) {
        for i in 0..dense.xs().n_rows() {
            assert_eq!(dense.xs().row(i), cow.row_or_base(i), "{tag}: row {i}");
        }
    }

    fn assert_records_equal(a: &Record, b: &Record, tag: &str) {
        assert_eq!(a.train_loss, b.train_loss, "{tag}");
        assert_eq!(a.test_loss, b.test_loss, "{tag}");
        assert_eq!(a.personal_loss, b.personal_loss, "{tag}");
        assert_eq!(a.personal_acc, b.personal_acc, "{tag}");
        assert_eq!(a.bits_up, b.bits_up, "{tag}");
        assert_eq!(a.bits_down, b.bits_down, "{tag}");
        assert_eq!(a.comm_rounds, b.comm_rounds, "{tag}");
    }

    /// Tentpole: one generic engine, two stores, bit-identical lockstep
    /// series — small fleet (sequential master accumulate) on stochastic
    /// wires.
    #[test]
    fn lockstep_matches_across_stores_small_fleet() {
        for wire in ["identity", "natural", "qsgd:8"] {
            let e = env(5, 31);
            let alg = L2gd::from_local_and_agg(0.35, 0.4, 0.5, 5, wire, wire).unwrap();
            let mut dense = alg.engine(&e).unwrap();
            let mut cow = ShardedL2gdEngine::new(&alg, &e, 5).unwrap();
            for k in 1..=120 {
                dense.step(k).unwrap();
                cow.step(k).unwrap();
            }
            assert_rows_equal(&dense, &cow, wire);
            let rd = dense.evaluate(120).unwrap();
            let rc = cow.evaluate(120).unwrap();
            assert_records_equal(&rd, &rc, wire);
        }
    }

    /// n > REDUCE_LEAF exercises the pooled leaf-partial aggregation on
    /// both stores.
    #[test]
    fn lockstep_matches_across_stores_tree_path() {
        let e = env(12, 32);
        let alg = L2gd::from_local_and_agg(0.4, 0.3, 0.5, 12,
                                           "natural", "natural").unwrap();
        let mut dense = alg.engine(&e).unwrap();
        let mut cow = ShardedL2gdEngine::new(&alg, &e, 12).unwrap();
        for k in 1..=100 {
            dense.step(k).unwrap();
            cow.step(k).unwrap();
        }
        assert_rows_equal(&dense, &cow, "tree");
        assert_records_equal(&dense.evaluate(100).unwrap(),
                             &cow.evaluate(100).unwrap(), "tree");
    }

    /// Partial participation: the cohort entry points agree across stores,
    /// including straggler metering.
    #[test]
    fn partial_participation_matches_across_stores() {
        let e = env(12, 33);
        let alg = L2gd::from_local_and_agg(0.4, 0.3, 0.5, 12,
                                           "natural", "natural").unwrap();
        let mut dense = alg.engine(&e).unwrap();
        let mut cow = ShardedL2gdEngine::new(&alg, &e, 12).unwrap();
        let all: Vec<u32> = (0..12).collect();
        let act: Vec<u32> = vec![0, 2, 3, 5, 8, 9, 11];
        let sampled: Vec<u32> = vec![0, 2, 5, 8, 11];
        let arrived: Vec<u32> = vec![2, 5, 11];

        dense.step_local(&all).unwrap();
        cow.step_local(&all).unwrap();
        dense.step_local(&act).unwrap();
        cow.step_local(&act).unwrap();

        dense.compress_uplinks(&sampled).unwrap();
        cow.compress_uplinks(&sampled).unwrap();
        dense.complete_fresh(1, &arrived, &sampled).unwrap();
        cow.complete_fresh(1, &arrived, &sampled).unwrap();
        assert_rows_equal(&dense, &cow, "after fresh");

        dense.step_aggregate_cached(&act);
        cow.step_aggregate_cached(&act);
        dense.step_local(&sampled).unwrap();
        cow.step_local(&sampled).unwrap();
        assert_rows_equal(&dense, &cow, "after cached+local");

        // wasted straggler traffic meters identically
        assert_eq!(dense.net().total_bits_up(), cow.net().total_bits_up());
        assert_eq!(dense.net().total_bits_down(), cow.net().total_bits_down());
        assert_eq!(dense.net().last_round_participants(),
                   cow.net().last_round_participants());
    }

    /// The copy-on-write contract at fleet scale: untouched devices store
    /// nothing, cohort compression does not materialize, local steps do.
    #[test]
    fn occupancy_scales_with_touched_not_fleet() {
        let e = env(5, 34);
        let alg = L2gd::from_local_and_agg(0.4, 0.3, 0.5, 100_000,
                                           "natural", "natural").unwrap();
        let mut cow = ShardedL2gdEngine::new(&alg, &e, 100_000).unwrap();
        assert_eq!(cow.store().materialized_rows(), 0);
        assert!(cow.store().n_shards() > 1);

        // a cohort that only compresses (fresh phase 1) stays row-free
        let sampled: Vec<u32> = (0..64u32).map(|j| j * 997).collect();
        cow.compress_uplinks(&sampled).unwrap();
        assert_eq!(cow.store().materialized_rows(), 0,
                   "uplink compression must not materialize rows");
        assert_eq!(cow.touched_clients(), 64);
        cow.complete_fresh(1, &sampled, &sampled).unwrap();
        // the aggregation step materializes only the cohort
        assert!(cow.store().materialized_rows() <= 64);

        // local steps materialize their cohort
        let workers: Vec<u32> = (0..40u32).map(|j| 1000 + j * 131).collect();
        cow.step_local(&workers).unwrap();
        assert!(cow.store().materialized_rows() <= 64 + 40);
        assert_eq!(cow.touched_clients(), 104);
        assert!(cow.row_or_base(99_999) == cow.base(), "untouched ⇒ base");
        assert!(cow.store().row(99_999).is_none());

        // resident bytes track occupancy, not the 100k fleet
        let rows = cow.store().materialized_rows();
        let per_row = 16 * 4 + 64;
        assert!(cow.store().resident_bytes() <= 4 * rows * per_row + 64 * 1024,
                "resident {} B for {rows} rows", cow.store().resident_bytes());

        // fleet-scale evaluation is finite and O(occupancy)
        let rec = cow.evaluate(2).unwrap();
        assert!(rec.train_loss.is_finite());
        assert!(rec.personal_loss.is_finite());
    }

    /// The FedAvg-equivalence regime (ηλ/np = 1, full cohort): a fresh
    /// broadcast resets every client onto the anchor, the CoW engine
    /// re-bases the implicit value, releases the rows the reset landed
    /// exactly on that value — and stays bit-identical to the dense
    /// engine throughout.
    #[test]
    fn full_fleet_exact_reset_rebases_and_releases() {
        let e = env(4, 36);
        // p=0.5, n=4, η=1, λ=2 ⇒ ηλ/np = 1.0 exactly
        let alg = L2gd::new(0.5, 2.0, 1.0, 4, "identity", "identity").unwrap();
        assert_eq!(alg.agg_coef(4) as f32, 1.0);
        let mut dense = alg.engine(&e).unwrap();
        let mut cow = ShardedL2gdEngine::new(&alg, &e, 4).unwrap();
        let init: Vec<f32> = cow.base().to_vec();
        let all: Vec<u32> = (0..4).collect();
        // diverge, then commit a full-fleet fresh round at a = 1
        dense.step_local(&all).unwrap();
        cow.step_local(&all).unwrap();
        assert_eq!(cow.store().materialized_rows(), 4);
        dense.compress_uplinks(&all).unwrap();
        cow.compress_uplinks(&all).unwrap();
        dense.complete_fresh(1, &all, &all).unwrap();
        cow.complete_fresh(1, &all, &all).unwrap();
        // bit-identical state regardless of what was released...
        assert_rows_equal(&dense, &cow, "post-reset");
        // ...and the re-base happened: the implicit value moved off the
        // init; rows whose reset rounded may stay resident
        assert_ne!(cow.base(), &init[..]);
        assert!(cow.store().materialized_rows() <= 4);
        // a second consecutive reset lands every row exactly on the
        // anchor (all rows are within ulps of ȳ, so x − (x − ȳ) is exact
        // by Sterbenz) — the store must be fully reclaimed
        dense.compress_uplinks(&all).unwrap();
        cow.compress_uplinks(&all).unwrap();
        dense.complete_fresh(2, &all, &all).unwrap();
        cow.complete_fresh(2, &all, &all).unwrap();
        assert_rows_equal(&dense, &cow, "second reset");
        assert_eq!(cow.store().materialized_rows(), 0,
                   "back-to-back a = 1 full-fleet resets must release every row");
        // training continues identically after the reclaim
        dense.step_local(&all).unwrap();
        cow.step_local(&all).unwrap();
        assert_rows_equal(&dense, &cow, "post-reset local");
    }

    /// Pre-communication cached aggregation is a bitwise no-op on
    /// undiverged rows and must not materialize them.
    #[test]
    fn cached_aggregation_before_first_broadcast_stays_implicit() {
        let e = env(5, 35);
        let alg = L2gd::from_local_and_agg(0.5, 0.3, 0.5, 1000,
                                           "identity", "identity").unwrap();
        let mut cow = ShardedL2gdEngine::new(&alg, &e, 1000).unwrap();
        let cohort: Vec<u32> = (0..200).collect();
        cow.step_aggregate_cached(&cohort);
        assert_eq!(cow.store().materialized_rows(), 0);
        assert_eq!(cow.touched_clients(), 200);
    }

    /// FedAvg on the unified engine: fixed cadence, reset-to-anchor.
    /// Under lockstep full participation the fleet learns and every
    /// (T+1)-th iteration communicates.
    #[test]
    fn fedavg_spec_learns_on_both_stores() {
        let spec = AlgSpec::fedavg(0.5, 3, "identity", "identity").unwrap();
        let e = env(4, 40);
        let mut dense = Engine::<DenseStore>::from_spec(&spec, &e, 4).unwrap();
        let mut cow = Engine::<ShardedStore>::from_spec(&spec, &e, 4).unwrap();
        let init: Vec<f32> = cow.base().to_vec();
        let first_d = dense.evaluate(0).unwrap();
        for k in 1..=120 {
            dense.step(k).unwrap();
            cow.step(k).unwrap();
        }
        assert_rows_equal(&dense, &cow, "fedavg");
        let rd = dense.evaluate(120).unwrap();
        let rc = cow.evaluate(120).unwrap();
        assert_records_equal(&rd, &rc, "fedavg");
        // 120 iterations at T = 3 ⇒ 30 communicating rounds exactly
        assert_eq!(rd.comm_rounds, 30);
        assert_eq!(dense.coin_stats().fresh, 30);
        assert_eq!(dense.coin_stats().cached, 0);
        assert!(rd.train_loss < first_d.train_loss,
                "fedavg must learn: {} -> {}", first_d.train_loss, rd.train_loss);
        // reset-to-anchor at full participation: iteration 120 is a fresh
        // round, so every client just reset onto the broadcast and the
        // full-fleet re-base released every row whose reset landed
        // exactly on the anchor — occupancy can only be the rounded few
        assert!(cow.store().materialized_rows() <= 4,
                "a=1 full-fleet reset must re-base (rows: {})",
                cow.store().materialized_rows());
        assert_ne!(cow.base(), &init[..],
                   "the implicit base must track the broadcast");
    }

    /// FedOpt on the unified engine: server Adam moves the anchor, the
    /// run learns, and dense ≡ sharded bit for bit.
    #[test]
    fn fedopt_spec_learns_and_matches_across_stores() {
        let spec = AlgSpec::fedopt(0.5, 2, 0.05, "identity", "identity").unwrap();
        let e = env(4, 41);
        let mut dense = Engine::<DenseStore>::from_spec(&spec, &e, 4).unwrap();
        let mut cow = Engine::<ShardedStore>::from_spec(&spec, &e, 4).unwrap();
        let first = dense.evaluate(0).unwrap();
        for k in 1..=90 {
            dense.step(k).unwrap();
            cow.step(k).unwrap();
        }
        assert_rows_equal(&dense, &cow, "fedopt");
        let rd = dense.evaluate(90).unwrap();
        assert_records_equal(&rd, &cow.evaluate(90).unwrap(), "fedopt");
        assert_eq!(rd.comm_rounds, 30); // every 3rd iteration at T = 2
        assert!(rd.train_loss < first.train_loss,
                "fedopt must learn: {} -> {}", first.train_loss, rd.train_loss);
        assert!(rd.train_loss.is_finite());
    }

    /// The weighted buffered aggregate at constant weights over a
    /// power-of-two cohort is bit-identical to the uniform fresh round
    /// (w/Σw = 1/count exactly), and weight scaling is invariant — the
    /// normalization makes ȳ a convex combination whatever the scale.
    #[test]
    fn weighted_constant_aggregate_matches_uniform() {
        let e = env(4, 50);
        let alg = L2gd::from_local_and_agg(0.35, 0.4, 0.5, 4,
                                           "natural", "natural").unwrap();
        let all: Vec<u32> = (0..4).collect();
        let mut a = ShardedL2gdEngine::new(&alg, &e, 4).unwrap();
        let mut b = ShardedL2gdEngine::new(&alg, &e, 4).unwrap();
        let mut c = ShardedL2gdEngine::new(&alg, &e, 4).unwrap();
        for eng in [&mut a, &mut b, &mut c] {
            eng.step_local(&all).unwrap();
            eng.compress_uplinks(&all).unwrap();
        }
        a.complete_fresh(1, &all, &all).unwrap();
        b.complete_fresh_weighted(1, &all, &[1.0; 4]).unwrap();
        c.complete_fresh_weighted(1, &all, &[2.5; 4]).unwrap();
        for i in 0..4 {
            assert_eq!(a.row_or_base(i), b.row_or_base(i), "row {i}");
            assert_eq!(b.row_or_base(i), c.row_or_base(i), "scaled row {i}");
        }
        assert_eq!(a.net().total_bits_up(), b.net().total_bits_up());
        assert_eq!(a.net().total_bits_down(), b.net().total_bits_down());
        assert_eq!(b.net().last_round_participants(), 4);
        assert_eq!(b.net().comm_rounds(), 1);
        // all applied traffic: goodput 1
        assert_eq!(b.net().uplink_goodput(), 1.0);
    }

    /// Weighted-aggregate validation and off-round discard metering.
    #[test]
    fn weighted_aggregate_validates_and_discards_meter() {
        let e = env(4, 51);
        let alg = L2gd::from_local_and_agg(0.35, 0.4, 0.5, 4,
                                           "identity", "identity").unwrap();
        let mut eng = ShardedL2gdEngine::new(&alg, &e, 4).unwrap();
        eng.enable_wire_framing();
        let all: Vec<u32> = (0..4).collect();
        eng.step_local(&all).unwrap();
        eng.compress_uplinks(&all).unwrap();
        assert!(eng.complete_fresh_weighted(1, &[], &[]).is_err(), "empty");
        assert!(eng.complete_fresh_weighted(1, &[0, 1], &[1.0]).is_err(),
                "length mismatch");
        assert!(eng.complete_fresh_weighted(1, &[0, 1], &[1.0, 0.0]).is_err(),
                "non-positive weight");
        assert!(eng.complete_fresh_weighted(1, &[0, 1], &[1.0, f32::NAN])
                    .is_err(), "non-finite weight");
        // discards meter framed bits off-round: no new comm round
        let frame_bits = eng.uplink_frame_bytes(2) * 8;
        eng.discard_uplink(1, 2, false).unwrap();
        eng.discard_uplink(1, 3, true).unwrap();
        assert_eq!(eng.net().comm_rounds(), 0);
        assert_eq!(eng.net().total_bits_up_wasted(), frame_bits);
        assert_eq!(eng.net().total_bits_up_stale(), frame_bits);
        assert_eq!(eng.net().total_bits_up(), 2 * frame_bits);
        // a client that never compressed has nothing to discard
        let mut fresh = ShardedL2gdEngine::new(&alg, &e, 4).unwrap();
        assert!(fresh.discard_uplink(1, 0, false).is_err());
    }

    /// Invalid baseline parameters are rejected at spec construction.
    #[test]
    fn alg_spec_validates_parameters() {
        assert!(AlgSpec::fedavg(0.0, 3, "identity", "identity").is_err());
        assert!(AlgSpec::fedavg(0.5, 0, "identity", "identity").is_err());
        assert!(AlgSpec::fedopt(0.5, 2, 0.0, "identity", "identity").is_err());
        assert!(AlgSpec::fedavg(0.5, 3, "warp-drive", "identity").is_err());
        let l2gd = L2gd::new(0.0, 1.0, 1.0, 4, "identity", "identity").unwrap();
        assert!(AlgSpec::l2gd(&l2gd, 4).is_err(), "p = 0 with λ > 0");
    }
}
