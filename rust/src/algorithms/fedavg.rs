//! FedAvg (McMahan et al. 2017) baseline, with the paper's
//! error-feedback-style difference compression (§VII-B).
//!
//! Per round r: the master broadcasts the global model w (optionally
//! compressed); every client runs `local_steps` SGD steps from w on its own
//! shard, producing w_i; the descent direction is d_i = w − w_i.
//!
//! Compression schema exactly as the paper describes:
//!   (i)  the client forms g_computed = d_i,
//!   (ii) it uplinks C(g_computed − g^{r−1}_i),
//!   (iii) both ends update g^r_i = g^{r−1}_i + C(g_computed − g^{r−1}_i).
//! The master then applies w ← w − Σ_i ω_i g^r_i (ω_i = |D_i| weights).
//! With the identity compressor this is exact FedAvg. (This difference
//! schema is itself a form of error feedback; an explicit `ef(...)` uplink
//! spec stacks a second residual on top — usually redundant here, but the
//! pipeline grammar allows it.)
//!
//! Engine layout mirrors L2GD: the compression memories g_i live in one
//! contiguous [`ParamMatrix`] row per client; each client's working model,
//! RNG stream, gradient buffer, diff buffer, compressor state and wire
//! buffer sit in its slot. The whole client round — local SGD, difference
//! compression, memory update — runs as one pooled sweep with zero
//! steady-state allocation (the cached-batch convex path), against the
//! environment's cached batches.
//!
//! This is the **lockstep** FedAvg (full participation, |D_i|-weighted
//! aggregation, difference compression) pinned bit-for-bit against the
//! seed-semantics oracle in [`super::reference`]. At *fleet* scale —
//! cohort sampling, churn, stragglers, a million devices — FedAvg runs
//! as [`super::engine::AlgSpec::fedavg`] on the generic cohort engine
//! instead: the unified-formulation member with a fixed local-step
//! cadence and aggregation coefficient 1 (Figs 7–8), driven by
//! [`crate::sim::FleetSim`] under `alg=fedavg` scenarios.

use std::sync::Arc;

use super::{client_rngs, drain_slot_errors, evaluate, FedAlgorithm, FedEnv, ModelView};
use crate::compress::{Compressed, Compressor, CompressorState};
use crate::metrics::Series;
use crate::model::{kernels, ParamMatrix};
use crate::runtime::{Backend as _, GradBuf};
use crate::transport::Network;
use crate::util::Rng;

struct ClientSlot {
    rng: Rng,
    /// client working model w_i for the current round
    wi: Vec<f32>,
    /// g_computed − g^{r−1}_i staging buffer
    diff: Vec<f32>,
    grad: GradBuf,
    comp: Box<dyn CompressorState>,
    wire: Compressed,
    err: Option<anyhow::Error>,
}

pub struct FedAvg {
    pub local_lr: f64,
    /// SGD steps per round. The paper uses 1 local epoch; our harness maps
    /// epochs to ⌈|D_i|/B⌉ steps via `steps_for_epoch`.
    pub local_steps: usize,
    /// client → master compression descriptor (difference compression
    /// w/ memory)
    pub up_comp: Arc<dyn Compressor>,
    /// master → clients descriptor (the paper's baseline keeps this
    /// identity)
    pub down_comp: Arc<dyn Compressor>,
    pub tag: String,
}

impl FedAvg {
    pub fn new(local_lr: f64, local_steps: usize, up_spec: &str, down_spec: &str)
               -> anyhow::Result<FedAvg> {
        Ok(FedAvg {
            local_lr,
            local_steps,
            up_comp: crate::compress::from_spec(up_spec)?,
            down_comp: crate::compress::from_spec(down_spec)?,
            tag: format!("fedavg[{up_spec}|{down_spec}]"),
        })
    }

    /// Steps approximating one local epoch at batch size `batch`.
    pub fn steps_for_epoch(shard_len: usize, batch: usize) -> usize {
        shard_len.div_ceil(batch).max(1)
    }
}

impl FedAlgorithm for FedAvg {
    fn label(&self) -> String {
        format!("{}:lr={},T={}", self.tag, self.local_lr, self.local_steps)
    }

    fn run(&mut self, env: &FedEnv, rounds: u64, eval_every: u64) -> anyhow::Result<Series> {
        let n = env.n_clients();
        let d = env.backend.param_count();
        let weights = env.shard_weights();
        let lr = self.local_lr as f32;

        let mut w = env.backend.init_params();
        // shared compression memories g_i (client and master copies agree)
        let mut g_mem = ParamMatrix::zeros(n, d);
        let mut net = Network::new(n);

        // per-client slots: RNG stream, working model, staging + wire
        // buffers, stateful uplink compressor — all allocated once here
        let mut seeder = Rng::new(env.seed ^ 0xFEDB);
        let mut slots: Vec<ClientSlot> = client_rngs(env.seed ^ 0xFEDA, n)
            .into_iter()
            .map(|rng| ClientSlot {
                rng,
                wi: vec![0.0f32; d],
                diff: vec![0.0f32; d],
                grad: GradBuf::with_dim(d),
                comp: self.up_comp.instantiate(d, seeder.next_u64()),
                wire: Compressed::empty(),
                err: None,
            })
            .collect();
        let mut down_state = self.down_comp.instantiate(d, env.seed ^ 0xFEDC);
        let mut down_buf = Compressed::empty();
        let mut w_received = vec![0.0f32; d];
        let mut g_bar = vec![0.0f32; d];

        let mut series = Series::new(self.label());
        series.records.push(evaluate(env, ModelView::Shared { model: &w, n }, 0, &net)?);

        for r in 1..=rounds {
            net.begin_round();
            // downlink: broadcast the (compressed) global model
            down_state.compress_into(&w, &mut down_buf)?;
            net.downlink_broadcast(r, down_buf.bits);
            down_buf.decode_into(&mut w_received);

            // one pooled sweep per round: local SGD from w_received,
            // difference compression, shared-memory update g_i += C(diff)
            let local_steps = self.local_steps;
            let w_recv = &w_received;
            env.pool.scope_chunks_zip_mut(g_mem.as_mut_slice(), d, &mut slots,
                                          |i, gm, slot| {
                slot.wi.copy_from_slice(w_recv);
                for _ in 0..local_steps {
                    let res = match env.train_batch_cached(i) {
                        Some(b) => env.backend.grad_into(&slot.wi, b, &mut slot.grad),
                        None => {
                            let b = env.backend.make_train_batch(&env.shards[i],
                                                                 &mut slot.rng);
                            env.backend.grad_into(&slot.wi, &b, &mut slot.grad)
                        }
                    };
                    match res {
                        Ok(()) => kernels::axpy(&mut slot.wi, -lr, &slot.grad.grad),
                        Err(e) => {
                            slot.err = Some(e);
                            return;
                        }
                    }
                }
                // g_computed = w_received − w_i (descent direction)
                for j in 0..gm.len() {
                    slot.diff[j] = (w_recv[j] - slot.wi[j]) - gm[j];
                }
                match slot.comp.compress_into(&slot.diff, &mut slot.wire) {
                    Ok(()) => slot.wire.decode_add(gm, 1.0), // both ends
                    Err(e) => slot.err = Some(e),
                }
            });
            drain_slot_errors(slots.iter_mut().map(|s| &mut s.err))?;
            for (i, slot) in slots.iter().enumerate() {
                net.uplink(r, i, slot.wire.bits);
            }
            net.end_round();

            // server: w ← w − Σ ω_i g_i
            g_mem.weighted_mean_into(&weights, &mut g_bar);
            kernels::axpy(&mut w, -1.0, &g_bar);

            if r % eval_every == 0 || r == rounds {
                series.records.push(
                    evaluate(env, ModelView::Shared { model: &w, n }, r, &net)?);
                if !series.records.last().unwrap().is_finite() {
                    break; // diverged: record it and stop (paper §B)
                }
            }
        }
        Ok(series)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::runtime::NativeLogreg;
    use crate::util::threadpool::ThreadPool;
    use std::sync::Arc;

    fn env(n: usize, seed: u64) -> FedEnv {
        let (data, test) = synth::logistic_split(40 * n, 80, 12, 0.02, seed);
        let shards = data.split_contiguous(n);
        FedEnv::new(Arc::new(NativeLogreg::new(12, 0.01, 64, 128)),
                    shards, data, test, ThreadPool::new(4), seed)
    }

    #[test]
    fn exact_fedavg_learns() {
        let e = env(4, 0);
        let mut alg = FedAvg::new(0.5, 3, "identity", "identity").unwrap();
        let s = alg.run(&e, 40, 10).unwrap();
        let first = s.records.first().unwrap();
        let last = s.records.last().unwrap();
        assert!(last.train_loss < first.train_loss * 0.8);
        assert!(last.test_acc > 0.8, "acc {}", last.test_acc);
    }

    #[test]
    fn compressed_fedavg_learns_with_memory() {
        let e = env(4, 1);
        let mut alg = FedAvg::new(0.5, 3, "natural", "identity").unwrap();
        let s = alg.run(&e, 60, 20).unwrap();
        let last = s.records.last().unwrap();
        assert!(last.test_acc > 0.75, "acc {}", last.test_acc);
        // natural uplink ⇒ up bits ≈ (9/32)·down bits per round
        let per_round_up = last.bits_up as f64 / (4.0 * last.comm_rounds as f64);
        let per_round_down = last.bits_down as f64 / (4.0 * last.comm_rounds as f64);
        assert!(per_round_up < 0.35 * per_round_down,
                "up {per_round_up} down {per_round_down}");
    }

    #[test]
    fn every_round_communicates() {
        let e = env(3, 2);
        let mut alg = FedAvg::new(0.3, 2, "identity", "identity").unwrap();
        let s = alg.run(&e, 25, 5).unwrap();
        let last = s.records.last().unwrap();
        assert_eq!(last.comm_rounds, 25); // fixed schedule, unlike L2GD
        // 12-dim identity: up 32·12 per client-round, down the same
        assert_eq!(last.bits_up, 25 * 3 * 32 * 12);
        assert_eq!(last.bits_down, 25 * 3 * 32 * 12);
    }

    #[test]
    fn identity_memory_schema_matches_plain_fedavg() {
        // with C = identity, g_i = d_i exactly ⇒ w_{r+1} = Σω_i w_i:
        // run two rounds manually and compare against the algorithm
        let e = env(2, 3);
        let mut alg = FedAvg::new(0.2, 2, "identity", "identity").unwrap();
        let s = alg.run(&e, 2, 1).unwrap();
        assert_eq!(s.records.len(), 3);
        // sanity: loss finite and decreasing-ish
        assert!(s.records[2].train_loss.is_finite());
    }

    #[test]
    fn pipeline_uplink_spec_runs_and_saves_bits() {
        // top-k survivors quantized by natural: biased, but the difference
        // schema's memory compensates — and the wire is tiny
        let e = env(4, 4);
        let mut alg = FedAvg::new(0.5, 3, "topk:4>natural", "identity").unwrap();
        let s = alg.run(&e, 60, 20).unwrap();
        let last = s.records.last().unwrap();
        assert!(last.test_acc > 0.7, "acc {}", last.test_acc);
        // 4 indices (4 bits each at d=12) + 4 survivors (9 bits) per client
        let per_client_round = last.bits_up / (4 * last.comm_rounds);
        assert_eq!(per_client_round, 4 * 4 + 4 * 9);
    }

    #[test]
    fn deterministic_given_seed() {
        let e = env(3, 5);
        let mut a = FedAvg::new(0.4, 2, "qsgd:8", "natural").unwrap();
        let mut b = FedAvg::new(0.4, 2, "qsgd:8", "natural").unwrap();
        let sa = a.run(&e, 30, 10).unwrap();
        let sb = b.run(&e, 30, 10).unwrap();
        for (ra, rb) in sa.records.iter().zip(&sb.records) {
            assert_eq!(ra.train_loss, rb.train_loss);
            assert_eq!(ra.test_loss, rb.test_loss);
            assert_eq!(ra.bits_up, rb.bits_up);
        }
    }

    #[test]
    fn steps_for_epoch() {
        assert_eq!(FedAvg::steps_for_epoch(500, 256), 2);
        assert_eq!(FedAvg::steps_for_epoch(100, 256), 1);
        assert_eq!(FedAvg::steps_for_epoch(512, 256), 2);
    }
}
