//! Sharded cohort engine: compressed L2GD over a copy-on-write
//! [`ShardedStore`] — the fleet-scale counterpart of the dense
//! [`super::l2gd::L2gdEngine`].
//!
//! ### Why a second engine
//! The dense engine materializes every client's model in one n×d matrix
//! and sweeps the whole fleet per step, so memory and wall-clock scale
//! with the *fleet*. The paper's probabilistic protocol only ever touches
//! a sampled cohort per event; at a million devices the state that
//! actually diverges from the shared anchor is a tiny sliver of the fleet.
//! This engine stores exactly that sliver:
//!
//! * **State** — a [`ShardedStore`] of divergent rows plus one `base`
//!   vector (the shared init, re-based on fleet-wide resets). A device
//!   that never took a divergent step stores no row and implicitly equals
//!   `base`; a row materializes on the device's first divergent step.
//!   Per-client wire state (batch RNG, compressor stream, EF residual,
//!   wire buffer) materializes lazily too, seeded by *random-access*
//!   stream derivation ([`crate::util::rng::stream_seed`]) — the identical
//!   streams the dense engine builds eagerly, so the two engines are
//!   bit-interchangeable.
//! * **Cohorts, not masks** — every entry point takes a sorted list of
//!   client ids and does O(cohort · d) work. The dense engine's `&[bool]`
//!   masks are O(fleet) to even scan.
//! * **Hierarchical aggregation** — the master's ȳ decode-accumulate
//!   runs as per-shard partials over the same fixed
//!   [`REDUCE_LEAF`]-client leaves as the dense tree reduction (shard
//!   boundaries are leaf multiples, so no leaf straddles a shard), and the
//!   final combine walks shard partials in shard order — leaf order
//!   globally. Untouched leaves contribute exactly `+0.0` in the dense
//!   path, so skipping them is bit-exact, and the whole pipeline
//!   reproduces the flat reduction **bit for bit**.
//! * **Data mapping** — fleet device i trains and evaluates on data shard
//!   `i mod env.n_clients()`, decoupling the modeled fleet size from the
//!   number of distinct data shards the environment carries.
//!
//! With cohort = the full fleet and equal seeds, every sweep here runs the
//! same arithmetic in the same order as the dense engine, so the training
//! series matches it bit for bit (pinned in `tests/integration_sim.rs` and
//! the module tests below). Under partial participation it matches the
//! dense engine's masked entry points the same way.
//!
//! ### Evaluation
//! When the fleet size equals the environment's shard count the engine
//! evaluates through the shared [`evaluate`] path (bit-identical records).
//! At fleet scale it switches to O(occupancy) evaluation: the global mean
//! is computed exactly as `((n−m)·base + Σ materialized rows)/n`, and the
//! personalized objective averages over the divergent clients only.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use super::l2gd::{client_stream, Framing, COMP_STREAM_SALT, REDUCE_LEAF};
use super::{evaluate, FedEnv, L2gd, ModelView};
use crate::compress::{Compressed, Compressor, CompressorState};
use crate::metrics::Record;
use crate::model::{kernels, ShardedStore};
use crate::protocol::{Coin, CoinStats, StepKind};
use crate::runtime::{Backend as _, GradBuf};
use crate::transport::frame;
use crate::transport::Network;
use crate::util::rng::stream_seed;
use crate::util::Rng;

/// Lazily materialized per-client wire state: the sharded analogue of the
/// dense engine's `ClientSlot`, created on the client's first cohort
/// membership with the same stream seeds.
struct CohortSlot {
    /// batch-sampling stream (drawn only for non-static backends)
    rng: Rng,
    /// stateful compressor instance (own RNG stream, EF residual)
    comp: Box<dyn CompressorState>,
    /// reusable wire buffer
    wire: Compressed,
}

fn new_slot(seed: u64, d: usize, comp: &Arc<dyn Compressor>, i: u32) -> CohortSlot {
    CohortSlot {
        rng: client_stream(seed, i as usize),
        comp: comp.instantiate(d, stream_seed(seed ^ COMP_STREAM_SALT, i as u64)),
        wire: Compressed::empty(),
    }
}

pub struct ShardedL2gdEngine<'e> {
    env: &'e FedEnv,
    /// fleet size (may vastly exceed `env.n_clients()` data shards)
    n: usize,
    d: usize,
    local_coef: f32,
    agg_coef: f32,
    /// divergent rows only (copy-on-write against `base`)
    store: ShardedStore,
    /// implicit value of every unmaterialized row (shared init; re-based
    /// only by an explicit fleet-wide reset)
    base: Vec<f32>,
    /// last broadcast C_M(ȳ)
    anchor: Vec<f32>,
    /// true until the first fresh round: the anchor still *is* the base,
    /// so cached aggregation on an unmaterialized row is a bitwise no-op
    /// and must not materialize it
    anchor_is_base: bool,
    ybar: Vec<f32>,
    slots: HashMap<u32, CohortSlot>,
    /// every client that has ever been in a cohort
    touched: HashSet<u32>,
    client_comp: Arc<dyn Compressor>,
    master_state: Box<dyn CompressorState>,
    master_buf: Compressed,
    grad: GradBuf,
    coin: Coin,
    net: Network,
    seed: u64,
    client_spec: String,
    master_spec: String,
    framing: Option<Framing>,
    /// exact (dense-compatible) evaluation when the fleet == data shards
    exact_eval: bool,
    // reusable fresh-round scratch
    leaf_rows: Vec<f32>,
    leaf_spans: Vec<(u32, u32)>,
    release_scratch: Vec<u32>,
    /// lazily built full-fleet cohort for the lockstep [`Self::step`]
    full: Vec<u32>,
}

impl<'e> ShardedL2gdEngine<'e> {
    /// Build the engine for a `fleet_n`-device fleet over `env`'s data
    /// shards. `fleet_n == env.n_clients()` is the dense-equivalent
    /// configuration (exact evaluation, identity data mapping).
    pub fn new(alg: &L2gd, env: &'e FedEnv, fleet_n: usize)
               -> anyhow::Result<ShardedL2gdEngine<'e>> {
        anyhow::ensure!(fleet_n > 0, "empty fleet");
        anyhow::ensure!(env.n_clients() > 0, "environment has no data shards");
        anyhow::ensure!(alg.p > 0.0 || alg.lambda == 0.0,
                        "p = 0 only valid for λ = 0 (pure local training)");
        let d = env.backend.param_count();
        let local_coef = alg.local_coef(fleet_n) as f32;
        let agg_coef = alg.agg_coef(fleet_n) as f32;
        anyhow::ensure!(agg_coef.is_finite() && (0.0..2.0).contains(&agg_coef),
                        "ηλ/np = {agg_coef} outside [0,2): aggregation diverges");
        let init = env.backend.init_params();
        let shard_size = ShardedStore::auto_shard_size(fleet_n, REDUCE_LEAF);
        // force the lazy per-shard train-batch cache off the hot path
        let _ = env.train_batch_cached(0);
        Ok(ShardedL2gdEngine {
            env,
            n: fleet_n,
            d,
            local_coef,
            agg_coef,
            store: ShardedStore::new(fleet_n, d, shard_size),
            base: init.clone(),
            anchor: init,
            anchor_is_base: true,
            ybar: vec![0.0f32; d],
            slots: HashMap::new(),
            touched: HashSet::new(),
            client_comp: Arc::clone(&alg.client_comp),
            master_state: alg.master_comp.instantiate(d, env.seed ^ 0x3a57e5),
            master_buf: Compressed::empty(),
            grad: GradBuf::with_dim(d),
            coin: Coin::new(alg.p, env.seed ^ 0xC011), // same coin stream
            net: Network::sharded(fleet_n, shard_size),
            seed: env.seed,
            client_spec: alg.client_comp.name(),
            master_spec: alg.master_comp.name(),
            framing: None,
            exact_eval: fleet_n == env.n_clients(),
            leaf_rows: Vec::new(),
            leaf_spans: Vec::new(),
            release_scratch: Vec::new(),
            full: Vec::new(),
        })
    }

    /// Fleet size.
    pub fn n_fleet(&self) -> usize {
        self.n
    }

    /// The copy-on-write store (occupancy / resident-bytes assertions).
    pub fn store(&self) -> &ShardedStore {
        &self.store
    }

    /// Distinct clients that have ever appeared in a cohort.
    pub fn touched_clients(&self) -> usize {
        self.touched.len()
    }

    pub fn net(&self) -> &Network {
        &self.net
    }

    /// Client `i`'s effective model row (the base when undiverged).
    pub fn row_or_base(&self, i: usize) -> &[f32] {
        self.store.row(i).unwrap_or(&self.base)
    }

    /// The shared base vector missing rows implicitly equal.
    pub fn base(&self) -> &[f32] {
        &self.base
    }

    /// Data shard fleet device `i` trains/evaluates on.
    pub fn data_shard(&self, i: usize) -> usize {
        i % self.env.n_clients()
    }

    /// Byte-accurate wire metering (see the dense engine) — metering only.
    pub fn enable_wire_framing(&mut self) {
        self.framing = Some(Framing::new(&self.client_spec, &self.master_spec));
    }

    /// The frame spec-id table (present once framing is enabled).
    pub fn spec_table(&self) -> Option<&crate::transport::frame::SpecTable> {
        self.framing.as_ref().map(|f| &f.table)
    }

    /// Draw the ξ coin (same stream as the dense engine's).
    pub fn draw(&mut self) -> StepKind {
        self.coin.draw()
    }

    pub fn coin_stats(&self) -> &CoinStats {
        &self.coin.stats
    }

    /// Lockstep full-participation iteration — the dense-equivalence path.
    pub fn step(&mut self, k: u64) -> anyhow::Result<()> {
        if self.full.len() != self.n {
            self.full = (0..self.n as u32).collect();
        }
        let full = std::mem::take(&mut self.full);
        let res = match self.coin.draw() {
            StepKind::Local => self.step_local(&full),
            StepKind::AggregateFresh => self
                .compress_uplinks(&full)
                .and_then(|()| self.complete_fresh(k, &full, &full)),
            StepKind::AggregateCached => {
                self.step_aggregate_cached(&full);
                Ok(())
            }
        };
        self.full = full;
        res
    }

    pub fn run_steps(&mut self, from: u64, count: u64) -> anyhow::Result<()> {
        for k in from + 1..=from + count {
            self.step(k)?;
        }
        Ok(())
    }

    #[inline]
    fn debug_check_cohort(cohort: &[u32], n: usize) {
        debug_assert!(cohort.windows(2).all(|w| w[0] < w[1]),
                      "cohort must be sorted and distinct");
        debug_assert!(cohort.last().map_or(true, |&i| (i as usize) < n),
                      "cohort id out of range");
    }

    /// Local gradient step for the cohort — each member materializes its
    /// row on this first divergent step and updates it in place. Same
    /// per-client arithmetic and order as the dense engine's masked sweep.
    pub fn step_local(&mut self, cohort: &[u32]) -> anyhow::Result<()> {
        Self::debug_check_cohort(cohort, self.n);
        let env = self.env;
        let coef = self.local_coef;
        let nd = env.n_clients();
        let (seed, d) = (self.seed, self.d);
        let comp = &self.client_comp;
        let store = &mut self.store;
        let base = &self.base;
        let slots = &mut self.slots;
        let grad = &mut self.grad;
        for &i in cohort {
            self.touched.insert(i);
            let ds = i as usize % nd;
            let x = store.materialize(i as usize, base);
            match env.train_batch_cached(ds) {
                Some(b) => env.backend.grad_into(x, b, grad)?,
                None => {
                    let slot = slots
                        .entry(i)
                        .or_insert_with(|| new_slot(seed, d, comp, i));
                    let b = env.backend.make_train_batch(&env.shards[ds], &mut slot.rng);
                    env.backend.grad_into(x, &b, grad)?;
                }
            }
            kernels::axpy(x, -coef, &grad.grad);
        }
        Ok(())
    }

    /// Cached-anchor aggregation for the cohort.
    pub fn step_aggregate_cached(&mut self, cohort: &[u32]) {
        Self::debug_check_cohort(cohort, self.n);
        for &i in cohort {
            self.touched.insert(i);
        }
        self.apply_aggregation(cohort);
    }

    /// Phase 1 of a fresh round: compress the cohort's effective models
    /// into their (lazily created) wire buffers. Read-only on the store —
    /// an undiverged member compresses the base without materializing.
    pub fn compress_uplinks(&mut self, cohort: &[u32]) -> anyhow::Result<()> {
        Self::debug_check_cohort(cohort, self.n);
        let (seed, d) = (self.seed, self.d);
        let comp = &self.client_comp;
        let store = &self.store;
        let base = &self.base;
        let slots = &mut self.slots;
        for &i in cohort {
            self.touched.insert(i);
            let x = store.row(i as usize).unwrap_or(base);
            let slot = slots.entry(i).or_insert_with(|| new_slot(seed, d, comp, i));
            slot.comp.compress_into(x, &mut slot.wire)?;
        }
        Ok(())
    }

    /// Serialized uplink frame size (bytes) for client `i`'s pending wire
    /// buffer — valid after [`Self::compress_uplinks`] included `i`.
    pub fn uplink_frame_bytes(&self, i: usize) -> u64 {
        let slot = self.slots.get(&(i as u32)).expect("client has no wire buffer");
        (frame::HEADER_BYTES + slot.wire.payload.len()) as u64
    }

    /// Serialized downlink (anchor broadcast) frame size in bytes.
    pub fn downlink_frame_bytes(&self) -> u64 {
        (frame::HEADER_BYTES + self.master_buf.payload.len()) as u64
    }

    /// Phase 2: meter uplinks (`sampled` − `arrived` as discarded
    /// straggler traffic), decode-accumulate ȳ over the arrived cohort via
    /// per-shard leaf partials, broadcast C_M(ȳ) to the cohort, aggregate.
    /// Bit-identical to the dense engine's `complete_fresh` for equal
    /// cohorts.
    pub fn complete_fresh(&mut self, k: u64, arrived: &[u32], sampled: &[u32])
                          -> anyhow::Result<()> {
        Self::debug_check_cohort(arrived, self.n);
        Self::debug_check_cohort(sampled, self.n);
        anyhow::ensure!(!arrived.is_empty(), "fresh aggregation with an empty cohort");
        let count = arrived.len();
        self.net.begin_round();
        // meter every transmitted frame; only arrived devices participate
        {
            let slots = &self.slots;
            let framing = &mut self.framing;
            let net = &mut self.net;
            let mut ai = 0usize;
            for &i in sampled {
                let is_arrived = ai < arrived.len() && arrived[ai] == i;
                if is_arrived {
                    ai += 1;
                }
                let slot = slots.get(&i).expect("sampled client has no wire buffer");
                let bits = match framing {
                    Some(f) => f.uplink_bits(k, i as usize, &slot.wire)?,
                    None => slot.wire.bits,
                };
                if is_arrived {
                    net.uplink(k, i as usize, bits);
                } else {
                    net.uplink_wasted(k, i as usize, bits);
                }
            }
            debug_assert_eq!(ai, arrived.len(), "arrived must be a subset of sampled");
        }
        // master: ȳ = (1/count) Σ_arrived C_i(x_i). Small fleets accumulate
        // sequentially (the dense engine's n ≤ REDUCE_LEAF path); larger
        // fleets reduce per-shard leaf partials over the pool and combine
        // them in shard (= global leaf) order — bit-equal to the dense
        // flat reduction because untouched leaves only ever contribute
        // +0.0 there.
        let inv = 1.0 / count as f32;
        if self.n <= REDUCE_LEAF {
            self.ybar.fill(0.0);
            for &i in arrived {
                self.slots[&i].wire.decode_add(&mut self.ybar, inv);
            }
        } else {
            let d = self.d;
            self.leaf_spans.clear();
            let mut start = 0usize;
            while start < arrived.len() {
                let leaf = arrived[start] as usize / REDUCE_LEAF;
                let mut end = start + 1;
                while end < arrived.len()
                    && arrived[end] as usize / REDUCE_LEAF == leaf
                {
                    end += 1;
                }
                self.leaf_spans.push((start as u32, end as u32));
                start = end;
            }
            self.leaf_rows.clear();
            self.leaf_rows.resize(self.leaf_spans.len() * d, 0.0);
            let spans = &self.leaf_spans;
            let slots = &self.slots;
            self.env.pool.scope_chunks_mut(&mut self.leaf_rows, d, |j, row| {
                row.fill(0.0);
                let (lo, hi) = spans[j];
                for &i in &arrived[lo as usize..hi as usize] {
                    slots[&i].wire.decode_add(row, inv);
                }
            });
            self.ybar.fill(0.0);
            for row in self.leaf_rows.chunks_exact(d) {
                kernels::add_assign(&mut self.ybar, row);
            }
        }
        // downlink C_M(ȳ) to the arrived cohort only
        self.master_state.compress_into(&self.ybar, &mut self.master_buf)?;
        let down_bits = match &mut self.framing {
            Some(f) => f.broadcast_bits(k, &self.master_buf)?,
            None => self.master_buf.bits,
        };
        for &i in arrived {
            self.net.downlink(k, i as usize, down_bits);
        }
        self.master_buf.decode_into(&mut self.anchor);
        self.anchor_is_base = false;
        self.net.end_round();
        self.apply_aggregation(arrived);
        Ok(())
    }

    /// A fresh attempt where nobody made the deadline: the cohort's frames
    /// still metered as discarded traffic, nothing aggregates.
    pub fn abort_fresh(&mut self, k: u64, sampled: &[u32]) -> anyhow::Result<()> {
        Self::debug_check_cohort(sampled, self.n);
        self.net.begin_round();
        for &i in sampled {
            let slot = self.slots.get(&i).expect("sampled client has no wire buffer");
            let bits = match &mut self.framing {
                Some(f) => f.uplink_bits(k, i as usize, &slot.wire)?,
                None => slot.wire.bits,
            };
            self.net.uplink_wasted(k, i as usize, bits);
        }
        self.net.end_round();
        Ok(())
    }

    /// `x_i ← x_i − a(x_i − anchor)` for the cohort. While the anchor is
    /// still the base (no fresh round yet), the step is a bitwise no-op on
    /// undiverged rows — they stay unmaterialized. A *full-fleet* exact
    /// reset (a = 1, every client in the cohort — the FedAvg-equivalence
    /// regime) re-bases the implicit value onto the anchor and releases
    /// every row that landed exactly on it: "fully reset by a broadcast it
    /// equals, stores no row". (Re-basing is only sound when no client is
    /// left holding the old implicit value, hence the full-cohort guard;
    /// rows whose reset rounded off the anchor stay resident, preserving
    /// bit-equality with the dense engine.)
    fn apply_aggregation(&mut self, cohort: &[u32]) {
        let a = self.agg_coef;
        for &i in cohort {
            if self.anchor_is_base && self.store.row(i as usize).is_none() {
                // x = base, anchor = base ⇒ x − a·(x − x) ≡ x bitwise
                continue;
            }
            let x = self.store.materialize(i as usize, &self.base);
            kernels::aggregation_step(x, a, &self.anchor);
        }
        if a == 1.0 && cohort.len() == self.n && !self.anchor_is_base {
            self.base.copy_from_slice(&self.anchor);
            self.anchor_is_base = true; // anchor ≡ base again
            {
                let scratch = &mut self.release_scratch;
                scratch.clear();
                let base = &self.base;
                self.store.for_each_row(|id, row| {
                    if row == &base[..] {
                        scratch.push(id as u32);
                    }
                });
            }
            let scratch = std::mem::take(&mut self.release_scratch);
            for &i in &scratch {
                self.store.release(i as usize);
            }
            self.release_scratch = scratch;
        }
    }

    /// Evaluate into a `Record`. Exact (dense-bit-identical) when the
    /// fleet equals the data-shard count; O(occupancy) at fleet scale.
    pub fn evaluate(&self, step: u64) -> anyhow::Result<Record> {
        if self.exact_eval {
            return evaluate(self.env,
                            ModelView::Cow { store: &self.store, base: &self.base },
                            step, &self.net);
        }
        self.evaluate_touched(step)
    }

    /// Personalized metrics in touched-mode evaluation cover at most this
    /// many divergent rows (deterministic materialization order): keeps a
    /// record's cost bounded however many clients a long run touches. The
    /// global-model metrics are always exact over the whole fleet.
    pub const PERSONAL_EVAL_CAP: usize = 2048;

    /// Fleet-scale evaluation in O(occupancy): exact global mean via the
    /// base identity `x̄ = ((n−m)·base + Σ materialized)/n`, personalized
    /// metrics averaged over (a capped sample of) the divergent clients
    /// (the base on data shard 0 when nothing has diverged yet).
    fn evaluate_touched(&self, step: u64) -> anyhow::Result<Record> {
        let be = &self.env.backend;
        let m = self.store.materialized_rows();
        let mut global = vec![0.0f32; self.d];
        self.store.for_each_row(|_, row| kernels::add_assign(&mut global, row));
        let n_f = self.n as f32;
        kernels::scale(&mut global, 1.0 / n_f);
        kernels::axpy(&mut global, (self.n - m) as f32 / n_f, &self.base);
        let train = be.eval(&global, self.env.train_eval_batch())?;
        let test = be.eval(&global, self.env.test_batch())?;

        let nd = self.env.n_clients();
        let (mut pl, mut pa, mut cnt) = (0.0f64, 0.0f64, 0usize);
        self.store.for_each_row(|i, row| {
            if cnt >= Self::PERSONAL_EVAL_CAP {
                return;
            }
            match be.eval(row, self.env.shard_eval_batch(i % nd)) {
                Ok(e) => {
                    pl += e.loss;
                    pa += e.accuracy;
                }
                Err(_) => {
                    pl += f64::NAN;
                    pa += f64::NAN;
                }
            }
            cnt += 1;
        });
        let (personal_loss, personal_acc) = if cnt == 0 {
            let e = be.eval(&self.base, self.env.shard_eval_batch(0))?;
            (e.loss, e.accuracy)
        } else {
            (pl / cnt as f64, pa / cnt as f64)
        };
        Ok(Record {
            step,
            comm_rounds: self.net.comm_rounds(),
            bits_per_client: self.net.bits_per_client(),
            bits_up: self.net.total_bits_up(),
            bits_down: self.net.total_bits_down(),
            train_loss: train.loss,
            train_acc: train.accuracy,
            test_loss: test.loss,
            test_acc: test.accuracy,
            personal_loss,
            personal_acc,
            sim_time_s: self.net.simulated_comm_time_s(),
            participants: self.net.last_round_participants(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::l2gd::L2gdEngine;
    use crate::data::synth;
    use crate::runtime::NativeLogreg;
    use crate::util::threadpool::ThreadPool;
    use std::sync::Arc;

    fn env(n: usize, seed: u64) -> FedEnv {
        let (data, test) = synth::logistic_split(50 * n, 100, 16, 0.02, seed);
        let shards = data.split_contiguous(n);
        FedEnv::new(Arc::new(NativeLogreg::new(16, 0.01, 64, 128)),
                    shards, data, test, ThreadPool::new(4), seed)
    }

    fn assert_rows_equal(dense: &L2gdEngine, cow: &ShardedL2gdEngine, tag: &str) {
        for i in 0..dense.xs().n_rows() {
            assert_eq!(dense.xs().row(i), cow.row_or_base(i), "{tag}: row {i}");
        }
    }

    fn assert_records_equal(a: &Record, b: &Record, tag: &str) {
        assert_eq!(a.train_loss, b.train_loss, "{tag}");
        assert_eq!(a.test_loss, b.test_loss, "{tag}");
        assert_eq!(a.personal_loss, b.personal_loss, "{tag}");
        assert_eq!(a.personal_acc, b.personal_acc, "{tag}");
        assert_eq!(a.bits_up, b.bits_up, "{tag}");
        assert_eq!(a.bits_down, b.bits_down, "{tag}");
        assert_eq!(a.comm_rounds, b.comm_rounds, "{tag}");
    }

    /// Lockstep full participation ≡ dense engine, bit for bit — small
    /// fleet (sequential master accumulate) and stochastic wire.
    #[test]
    fn lockstep_matches_dense_engine_small_fleet() {
        for wire in ["identity", "natural", "qsgd:8"] {
            let e = env(5, 31);
            let alg = L2gd::from_local_and_agg(0.35, 0.4, 0.5, 5, wire, wire).unwrap();
            let mut dense = alg.engine(&e).unwrap();
            let mut cow = ShardedL2gdEngine::new(&alg, &e, 5).unwrap();
            for k in 1..=120 {
                dense.step(k).unwrap();
                cow.step(k).unwrap();
            }
            assert_rows_equal(&dense, &cow, wire);
            let rd = dense.evaluate(120).unwrap();
            let rc = cow.evaluate(120).unwrap();
            assert_records_equal(&rd, &rc, wire);
        }
    }

    /// n > REDUCE_LEAF exercises the hierarchical per-shard/leaf
    /// aggregation against the dense flat tree reduction.
    #[test]
    fn lockstep_matches_dense_engine_tree_path() {
        let e = env(12, 32);
        let alg = L2gd::from_local_and_agg(0.4, 0.3, 0.5, 12,
                                           "natural", "natural").unwrap();
        let mut dense = alg.engine(&e).unwrap();
        let mut cow = ShardedL2gdEngine::new(&alg, &e, 12).unwrap();
        for k in 1..=100 {
            dense.step(k).unwrap();
            cow.step(k).unwrap();
        }
        assert_rows_equal(&dense, &cow, "tree");
        assert_records_equal(&dense.evaluate(100).unwrap(),
                             &cow.evaluate(100).unwrap(), "tree");
    }

    /// Partial participation: the cohort entry points reproduce the dense
    /// engine's masked entry points, including straggler metering.
    #[test]
    fn partial_participation_matches_dense_masked_path() {
        let e = env(12, 33);
        let alg = L2gd::from_local_and_agg(0.4, 0.3, 0.5, 12,
                                           "natural", "natural").unwrap();
        let mut dense = alg.engine(&e).unwrap();
        let mut cow = ShardedL2gdEngine::new(&alg, &e, 12).unwrap();
        let to_mask = |ids: &[u32]| {
            let mut m = vec![false; 12];
            for &i in ids {
                m[i as usize] = true;
            }
            m
        };
        let all: Vec<u32> = (0..12).collect();
        let act: Vec<u32> = vec![0, 2, 3, 5, 8, 9, 11];
        let sampled: Vec<u32> = vec![0, 2, 5, 8, 11];
        let arrived: Vec<u32> = vec![2, 5, 11];

        dense.step_local(&to_mask(&all)).unwrap();
        cow.step_local(&all).unwrap();
        dense.step_local(&to_mask(&act)).unwrap();
        cow.step_local(&act).unwrap();

        dense.compress_uplinks(&to_mask(&sampled)).unwrap();
        cow.compress_uplinks(&sampled).unwrap();
        dense.complete_fresh(1, &to_mask(&arrived), &to_mask(&sampled)).unwrap();
        cow.complete_fresh(1, &arrived, &sampled).unwrap();
        assert_rows_equal(&dense, &cow, "after fresh");

        dense.step_aggregate_cached(&to_mask(&act));
        cow.step_aggregate_cached(&act);
        dense.step_local(&to_mask(&sampled)).unwrap();
        cow.step_local(&sampled).unwrap();
        assert_rows_equal(&dense, &cow, "after cached+local");

        // wasted straggler traffic meters identically
        assert_eq!(dense.net().total_bits_up(), cow.net().total_bits_up());
        assert_eq!(dense.net().total_bits_down(), cow.net().total_bits_down());
        assert_eq!(dense.net().last_round_participants(),
                   cow.net().last_round_participants());
    }

    /// The copy-on-write contract at fleet scale: untouched devices store
    /// nothing, cohort compression does not materialize, local steps do.
    #[test]
    fn occupancy_scales_with_touched_not_fleet() {
        let e = env(5, 34);
        let alg = L2gd::from_local_and_agg(0.4, 0.3, 0.5, 100_000,
                                           "natural", "natural").unwrap();
        let mut cow = ShardedL2gdEngine::new(&alg, &e, 100_000).unwrap();
        assert_eq!(cow.store().materialized_rows(), 0);
        assert!(cow.store().n_shards() > 1);

        // a cohort that only compresses (fresh phase 1) stays row-free
        let sampled: Vec<u32> = (0..64u32).map(|j| j * 997).collect();
        cow.compress_uplinks(&sampled).unwrap();
        assert_eq!(cow.store().materialized_rows(), 0,
                   "uplink compression must not materialize rows");
        assert_eq!(cow.touched_clients(), 64);
        cow.complete_fresh(1, &sampled, &sampled).unwrap();
        // the aggregation step materializes only the cohort
        assert!(cow.store().materialized_rows() <= 64);

        // local steps materialize their cohort
        let workers: Vec<u32> = (0..40u32).map(|j| 1000 + j * 131).collect();
        cow.step_local(&workers).unwrap();
        assert!(cow.store().materialized_rows() <= 64 + 40);
        assert_eq!(cow.touched_clients(), 104);
        assert!(cow.row_or_base(99_999) == cow.base(), "untouched ⇒ base");
        assert!(cow.store().row(99_999).is_none());

        // resident bytes track occupancy, not the 100k fleet
        let rows = cow.store().materialized_rows();
        let per_row = 16 * 4 + 64;
        assert!(cow.store().resident_bytes() <= 4 * rows * per_row + 64 * 1024,
                "resident {} B for {rows} rows", cow.store().resident_bytes());

        // fleet-scale evaluation is finite and O(occupancy)
        let rec = cow.evaluate(2).unwrap();
        assert!(rec.train_loss.is_finite());
        assert!(rec.personal_loss.is_finite());
    }

    /// The FedAvg-equivalence regime (ηλ/np = 1, full cohort): a fresh
    /// broadcast resets every client onto the anchor, the engine re-bases
    /// the implicit value, releases the rows the reset landed exactly on
    /// that value — and stays bit-identical to the dense engine throughout.
    #[test]
    fn full_fleet_exact_reset_rebases_and_releases() {
        let e = env(4, 36);
        // p=0.5, n=4, η=1, λ=2 ⇒ ηλ/np = 1.0 exactly
        let alg = L2gd::new(0.5, 2.0, 1.0, 4, "identity", "identity").unwrap();
        assert_eq!(alg.agg_coef(4) as f32, 1.0);
        let mut dense = alg.engine(&e).unwrap();
        let mut cow = ShardedL2gdEngine::new(&alg, &e, 4).unwrap();
        let init: Vec<f32> = cow.base().to_vec();
        let all: Vec<u32> = (0..4).collect();
        let mask = [true; 4];
        // diverge, then commit a full-fleet fresh round at a = 1
        dense.step_local(&mask).unwrap();
        cow.step_local(&all).unwrap();
        assert_eq!(cow.store().materialized_rows(), 4);
        dense.compress_uplinks(&mask).unwrap();
        cow.compress_uplinks(&all).unwrap();
        dense.complete_fresh(1, &mask, &mask).unwrap();
        cow.complete_fresh(1, &all, &all).unwrap();
        // bit-identical state regardless of what was released...
        assert_rows_equal(&dense, &cow, "post-reset");
        // ...and the re-base happened: the implicit value moved off the
        // init; rows whose reset rounded may stay resident
        assert_ne!(cow.base(), &init[..]);
        assert!(cow.store().materialized_rows() <= 4);
        // a second consecutive reset lands every row exactly on the
        // anchor (all rows are within ulps of ȳ, so x − (x − ȳ) is exact
        // by Sterbenz) — the store must be fully reclaimed
        dense.compress_uplinks(&mask).unwrap();
        cow.compress_uplinks(&all).unwrap();
        dense.complete_fresh(2, &mask, &mask).unwrap();
        cow.complete_fresh(2, &all, &all).unwrap();
        assert_rows_equal(&dense, &cow, "second reset");
        assert_eq!(cow.store().materialized_rows(), 0,
                   "back-to-back a = 1 full-fleet resets must release every row");
        // training continues identically after the reclaim
        dense.step_local(&mask).unwrap();
        cow.step_local(&all).unwrap();
        assert_rows_equal(&dense, &cow, "post-reset local");
    }

    /// Pre-communication cached aggregation is a bitwise no-op on
    /// undiverged rows and must not materialize them.
    #[test]
    fn cached_aggregation_before_first_broadcast_stays_implicit() {
        let e = env(5, 35);
        let alg = L2gd::from_local_and_agg(0.5, 0.3, 0.5, 1000,
                                           "identity", "identity").unwrap();
        let mut cow = ShardedL2gdEngine::new(&alg, &e, 1000).unwrap();
        let cohort: Vec<u32> = (0..200).collect();
        cow.step_aggregate_cached(&cohort);
        assert_eq!(cow.store().materialized_rows(), 0);
        assert_eq!(cow.touched_clients(), 200);
    }
}
