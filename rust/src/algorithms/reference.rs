//! Seed-semantics reference implementations — the oracle the round engine
//! is tested and benchmarked against.
//!
//! These reproduce the pre-engine training loops exactly: per-client
//! models as `Vec<Vec<f32>>`, a freshly assembled training batch and a
//! freshly allocated gradient for every client every step, serial
//! decode-accumulate on the master, and per-evaluation batch assembly.
//! They share the `Backend` oracle, the RNG fork constants and the
//! compressor instantiation seeds with the engine, so for a fixed seed the
//! engine must reproduce these series **bit for bit** (for L2GD up to
//! n ≤ 8 clients, where the master reduction is sequential in both paths;
//! the pooled tree reduction over 8-client leaves reassociates the
//! floating-point sum beyond that).
//!
//! Scope of the guarantee: it pins the **engine refactor** (layout,
//! caching, buffer reuse, parallel sweeps — and, since the store
//! unification, the generic [`super::engine::Engine`] over either
//! [`crate::model::ClientStore`] impl) against the shared oracle. It
//! is deliberately *not* a cross-commit guarantee against the
//! pre-refactor seed: `NativeLogreg::forward` itself changed numerically
//! (8-accumulator `kernels::dot` reassociates the row product; the
//! sigmoid coefficient is now derived in f64 from the single `e^{−|yz|}`),
//! and both paths here share that new forward.
//!
//! Used by the module tests below and by the `pfl bench` /
//! `perf_round_latency` harnesses as the pre-refactor throughput baseline
//! ("measured by the same harness").

use std::sync::Mutex;

use super::{client_rngs, FedAlgorithm as _, FedEnv};
use crate::compress::{Compressed, Compressor as _, CompressorState};
use crate::metrics::{Record, Series};
use crate::model::{aggregation_step, axpy, mean_of, weighted_mean};
use crate::protocol::{Coin, StepKind};
use crate::runtime::Backend as _;
use crate::transport::Network;
use crate::util::Rng;

/// The seed's `evaluate`: nested rows, per-call eval batch assembly.
fn evaluate_nested(env: &FedEnv, xs: &[Vec<f32>], step: u64, net: &Network)
                   -> anyhow::Result<Record> {
    let global = mean_of(xs);
    let be = &env.backend;
    let train_b = be.make_eval_batch(&env.train_eval);
    let test_b = be.make_eval_batch(&env.test);
    let train = be.eval(&global, &train_b)?;
    let test = be.eval(&global, &test_b)?;

    let mut personal_loss = 0.0f64;
    let mut personal_acc = 0.0f64;
    for (i, x) in xs.iter().enumerate() {
        let b = be.make_eval_batch(&env.shards[i]);
        match be.eval(x, &b) {
            Ok(e) => {
                personal_loss += e.loss;
                personal_acc += e.accuracy;
            }
            Err(_) => {
                personal_loss += f64::NAN;
                personal_acc += f64::NAN;
            }
        }
    }
    let n = xs.len() as f64;
    Ok(Record {
        step,
        comm_rounds: net.comm_rounds(),
        bits_per_client: net.bits_per_client(),
        bits_up: net.total_bits_up(),
        bits_down: net.total_bits_down(),
        train_loss: train.loss,
        train_acc: train.accuracy,
        test_loss: test.loss,
        test_acc: test.accuracy,
        personal_loss: personal_loss / n,
        personal_acc: personal_acc / n,
        sim_time_s: net.simulated_comm_time_s(),
        participants: net.last_round_participants(),
    })
}

/// Seed-layout compressed L2GD (Algorithm 1).
pub fn run_l2gd(alg: &super::L2gd, env: &FedEnv, steps: u64, eval_every: u64)
                -> anyhow::Result<Series> {
    let n = env.n_clients();
    anyhow::ensure!(alg.p > 0.0 || alg.lambda == 0.0,
                    "p = 0 only valid for λ = 0 (pure local training)");
    let d = env.backend.param_count();
    let local_coef = alg.local_coef(n) as f32;
    let agg_coef = alg.agg_coef(n) as f32;
    anyhow::ensure!(agg_coef.is_finite() && (0.0..2.0).contains(&agg_coef),
                    "ηλ/np = {agg_coef} outside [0,2): aggregation diverges");

    let init = env.backend.init_params();
    let mut xs: Vec<Vec<f32>> = vec![init.clone(); n];
    let mut anchor = init;
    let mut coin = Coin::new(alg.p, env.seed ^ 0xC011);
    let mut net = Network::new(n);
    // mutex-wrapped streams, as the seed shared them with the pooled
    // gradient fan-out — but derived by random-access stream index
    // (`l2gd::client_stream` / `stream_seed`), matching the engine and the
    // sharded cohort engine so all three share one per-client stream
    // contract
    let rngs: Vec<Mutex<Rng>> = (0..n)
        .map(|i| Mutex::new(super::l2gd::client_stream(env.seed, i)))
        .collect();
    let mut uplinks: Vec<(Box<dyn CompressorState>, Compressed)> = (0..n)
        .map(|i| {
            let seed = crate::util::rng::stream_seed(
                env.seed ^ super::l2gd::COMP_STREAM_SALT, i as u64);
            (alg.client_comp.instantiate(d, seed), Compressed::empty())
        })
        .collect();
    let mut master_state = alg.master_comp.instantiate(d, env.seed ^ 0x3a57e5);
    let mut master_buf = Compressed::empty();
    let mut ybar = vec![0.0f32; d];

    let mut series = Series::new(alg.label());
    series.records.push(evaluate_nested(env, &xs, 0, &net)?);

    for k in 1..=steps {
        match coin.draw() {
            StepKind::Local => {
                // all devices: one local gradient step (pooled, as the seed
                // ran it — per-call batch assembly, allocating grad)
                let outs = env.pool.scope_map(&xs, |i, x| {
                    let mut rng = rngs[i].lock().unwrap();
                    let batch = env.backend.make_train_batch(&env.shards[i], &mut rng);
                    env.backend.grad(x, &batch)
                });
                for (x, out) in xs.iter_mut().zip(outs) {
                    let g = out?;
                    axpy(x, -local_coef, &g.grad);
                }
            }
            StepKind::AggregateFresh => {
                net.begin_round();
                for (i, x) in xs.iter().enumerate() {
                    let (state, buf) = &mut uplinks[i];
                    state.compress_into(x, buf)?;
                }
                ybar.fill(0.0);
                let inv_n = 1.0 / n as f32;
                for (i, (_, c)) in uplinks.iter().enumerate() {
                    net.uplink(k, i, c.bits);
                    c.decode_add(&mut ybar, inv_n);
                }
                master_state.compress_into(&ybar, &mut master_buf)?;
                net.downlink_broadcast(k, master_buf.bits);
                master_buf.decode_into(&mut anchor);
                net.end_round();
                for x in xs.iter_mut() {
                    aggregation_step(x, agg_coef, &anchor);
                }
            }
            StepKind::AggregateCached => {
                for x in xs.iter_mut() {
                    aggregation_step(x, agg_coef, &anchor);
                }
            }
        }
        if k % eval_every == 0 || k == steps {
            series.records.push(evaluate_nested(env, &xs, k, &net)?);
            if !series.records.last().unwrap().is_finite() {
                break;
            }
        }
    }
    Ok(series)
}

/// Seed-layout FedAvg with difference compression.
pub fn run_fedavg(alg: &super::FedAvg, env: &FedEnv, rounds: u64, eval_every: u64)
                  -> anyhow::Result<Series> {
    let n = env.n_clients();
    let d = env.backend.param_count();
    let weights = env.shard_weights();
    let lr = alg.local_lr as f32;

    let mut w = env.backend.init_params();
    let mut g_mem: Vec<Vec<f32>> = vec![vec![0.0f32; d]; n];
    let mut net = Network::new(n);
    let rngs: Vec<Mutex<Rng>> =
        client_rngs(env.seed ^ 0xFEDA, n).into_iter().map(Mutex::new).collect();
    let mut seeder = Rng::new(env.seed ^ 0xFEDB);
    let mut uplinks: Vec<(Box<dyn CompressorState>, Compressed)> = (0..n)
        .map(|_| (alg.up_comp.instantiate(d, seeder.next_u64()),
                  Compressed::empty()))
        .collect();
    let mut down_state = alg.down_comp.instantiate(d, env.seed ^ 0xFEDC);
    let mut down_buf = Compressed::empty();
    let mut w_received = vec![0.0f32; d];
    let mut diff = vec![0.0f32; d];

    let mut series = Series::new(alg.label());
    series.records.push(evaluate_nested(env, &vec![w.clone(); n], 0, &net)?);

    for r in 1..=rounds {
        net.begin_round();
        down_state.compress_into(&w, &mut down_buf)?;
        net.downlink_broadcast(r, down_buf.bits);
        down_buf.decode_into(&mut w_received);

        // local training (pooled, as the seed ran it)
        let local_steps = alg.local_steps;
        let w_recv_ref = &w_received;
        let locals = env.pool.scope_map(&env.shards, |i, shard| {
            let mut rng = rngs[i].lock().unwrap();
            let mut wi = w_recv_ref.clone();
            for _ in 0..local_steps {
                let batch = env.backend.make_train_batch(shard, &mut rng);
                match env.backend.grad(&wi, &batch) {
                    Ok(g) => axpy(&mut wi, -lr, &g.grad),
                    Err(e) => return Err(e),
                }
            }
            Ok(wi)
        });
        for (i, wi) in locals.into_iter().enumerate() {
            let wi = wi?;
            for j in 0..d {
                diff[j] = (w_received[j] - wi[j]) - g_mem[i][j];
            }
            let (state, buf) = &mut uplinks[i];
            state.compress_into(&diff, buf)?;
            net.uplink(r, i, buf.bits);
            buf.decode_add(&mut g_mem[i], 1.0);
        }
        net.end_round();

        let g_bar = weighted_mean(&g_mem, &weights);
        axpy(&mut w, -1.0, &g_bar);

        if r % eval_every == 0 || r == rounds {
            series.records.push(evaluate_nested(env, &vec![w.clone(); n], r, &net)?);
            if !series.records.last().unwrap().is_finite() {
                break;
            }
        }
    }
    Ok(series)
}

/// Seed-layout FedOpt (server Adam).
pub fn run_fedopt(alg: &super::FedOpt, env: &FedEnv, rounds: u64, eval_every: u64)
                  -> anyhow::Result<Series> {
    let n = env.n_clients();
    let d = env.backend.param_count();
    let weights = env.shard_weights();
    let lr = alg.local_lr as f32;

    let mut w = env.backend.init_params();
    let mut m = vec![0.0f64; d];
    let mut v = vec![0.0f64; d];
    let mut net = Network::new(n);
    let rngs: Vec<Mutex<Rng>> =
        client_rngs(env.seed ^ 0x0b7, n).into_iter().map(Mutex::new).collect();

    let mut series = Series::new(alg.label());
    series.records.push(evaluate_nested(env, &vec![w.clone(); n], 0, &net)?);

    let bits_model = 32 * d as u64;

    for r in 1..=rounds {
        net.begin_round();
        net.downlink_broadcast(r, bits_model);

        let local_steps = alg.local_steps;
        let w_ref = &w;
        let locals = env.pool.scope_map(&env.shards, |i, shard| {
            let mut rng = rngs[i].lock().unwrap();
            let mut wi = w_ref.clone();
            for _ in 0..local_steps {
                let batch = env.backend.make_train_batch(shard, &mut rng);
                match env.backend.grad(&wi, &batch) {
                    Ok(g) => axpy(&mut wi, -lr, &g.grad),
                    Err(e) => return Err(e),
                }
            }
            Ok(wi)
        });
        let mut deltas: Vec<Vec<f32>> = Vec::with_capacity(n);
        for (i, wi) in locals.into_iter().enumerate() {
            let wi = wi?;
            net.uplink(r, i, bits_model);
            let delta: Vec<f32> = w.iter().zip(&wi).map(|(a, b)| a - b).collect();
            deltas.push(delta);
        }
        net.end_round();

        let dbar = weighted_mean(&deltas, &weights);
        for j in 0..d {
            let g = dbar[j] as f64;
            m[j] = alg.beta1 * m[j] + (1.0 - alg.beta1) * g;
            v[j] = alg.beta2 * v[j] + (1.0 - alg.beta2) * g * g;
            w[j] -= (alg.server_lr * m[j] / (v[j].sqrt() + alg.tau)) as f32;
        }

        if r % eval_every == 0 || r == rounds {
            series.records.push(evaluate_nested(env, &vec![w.clone(); n], r, &net)?);
            if !series.records.last().unwrap().is_finite() {
                break;
            }
        }
    }
    Ok(series)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{FedAlgorithm, FedAvg, FedOpt, L2gd};
    use crate::data::synth;
    use crate::runtime::NativeLogreg;
    use crate::util::threadpool::ThreadPool;
    use std::sync::Arc;

    fn env(n: usize, d: usize, seed: u64) -> FedEnv {
        let (data, test) = synth::logistic_split(40 * n, 80, d, 0.02, seed);
        let shards = data.split_contiguous(n);
        FedEnv::new(Arc::new(NativeLogreg::new(d, 0.01, 64, 128)),
                    shards, data, test, ThreadPool::new(4), seed)
    }

    fn assert_series_identical(a: &Series, b: &Series) {
        assert_eq!(a.records.len(), b.records.len(), "record counts differ");
        for (ra, rb) in a.records.iter().zip(&b.records) {
            assert_eq!(ra.step, rb.step);
            assert_eq!(ra.train_loss, rb.train_loss, "step {}", ra.step);
            assert_eq!(ra.train_acc, rb.train_acc, "step {}", ra.step);
            assert_eq!(ra.test_loss, rb.test_loss, "step {}", ra.step);
            assert_eq!(ra.test_acc, rb.test_acc, "step {}", ra.step);
            assert_eq!(ra.personal_loss, rb.personal_loss, "step {}", ra.step);
            assert_eq!(ra.personal_acc, rb.personal_acc, "step {}", ra.step);
            assert_eq!(ra.bits_up, rb.bits_up, "step {}", ra.step);
            assert_eq!(ra.bits_down, rb.bits_down, "step {}", ra.step);
            assert_eq!(ra.comm_rounds, rb.comm_rounds, "step {}", ra.step);
        }
    }

    #[test]
    fn l2gd_engine_reproduces_seed_series_bitwise_identity() {
        let e = env(5, 16, 21);
        let mut alg = L2gd::from_local_and_agg(0.4, 0.3, 0.5, 5,
                                               "identity", "identity").unwrap();
        let engine = alg.run(&e, 100, 25).unwrap();
        let oracle = run_l2gd(&alg, &e, 100, 25).unwrap();
        assert_series_identical(&engine, &oracle);
    }

    #[test]
    fn l2gd_engine_reproduces_seed_series_bitwise_compressed() {
        // stochastic wire path: qsgd client / natural master exercises the
        // per-client RNG streams and the fused decode-accumulate
        let e = env(4, 24, 22);
        let mut alg = L2gd::from_local_and_agg(0.35, 0.3, 0.4, 4,
                                               "qsgd:8", "natural").unwrap();
        let engine = alg.run(&e, 120, 30).unwrap();
        let oracle = run_l2gd(&alg, &e, 120, 30).unwrap();
        assert_series_identical(&engine, &oracle);
    }

    #[test]
    fn l2gd_engine_reproduces_seed_series_bitwise_ef_pipeline() {
        let e = env(3, 32, 23);
        let mut alg = L2gd::from_local_and_agg(0.4, 0.3, 0.5, 3,
                                               "ef(randk:8>qsgd:8)", "natural").unwrap();
        let engine = alg.run(&e, 90, 30).unwrap();
        let oracle = run_l2gd(&alg, &e, 90, 30).unwrap();
        assert_series_identical(&engine, &oracle);
    }

    #[test]
    fn fedavg_engine_reproduces_seed_series_bitwise() {
        let e = env(4, 12, 24);
        let mut alg = FedAvg::new(0.4, 3, "natural", "identity").unwrap();
        let engine = alg.run(&e, 40, 10).unwrap();
        let oracle = run_fedavg(&alg, &e, 40, 10).unwrap();
        assert_series_identical(&engine, &oracle);
    }

    #[test]
    fn fedopt_engine_reproduces_seed_series_bitwise() {
        let e = env(4, 12, 25);
        let mut alg = FedOpt::new(0.4, 2, 0.05);
        let engine = alg.run(&e, 30, 10).unwrap();
        let oracle = run_fedopt(&alg, &e, 30, 10).unwrap();
        assert_series_identical(&engine, &oracle);
    }
}
