//! Compressed L2GD — Algorithm 1 of the paper.
//!
//! State: personalized models x_1..x_n, a cached aggregation anchor, and
//! the ξ coin. Per iteration k:
//!
//! * ξ_k = 0 (prob 1−p): every device takes the local step
//!   `x_i ← x_i − η/(n(1−p)) ∇f_i(x_i)` — no communication.
//! * ξ_k = 1, ξ_{k−1} = 0: **the only communicating step**. Device i
//!   uplinks `C_i(x_i)`; the master forms `ȳ = (1/n) Σ C_i(x_i)` (fused
//!   decode-accumulate), compresses it once and broadcasts `C_M(ȳ)`;
//!   devices aggregate `x_i ← x_i − (ηλ/np)(x_i − C_M(ȳ))`.
//! * ξ_k = 1, ξ_{k−1} = 1: aggregation toward the **cached** anchor, no
//!   communication. (With identity compression the anchor is the exact
//!   running average, which is a fixed point of consecutive aggregation
//!   steps — §III; under compression we reuse the last broadcast C_M(ȳ),
//!   the only shared quantity the devices possess.)
//!
//! `eta_lambda_np = ηλ/(np)` is the aggregation step size; the paper's
//! sweet spots are (0, 0.17] and ≈ 1 (§VII-B), and exactly 1 recovers
//! FedAvg with a random number of local steps (Figs 7–8).
//!
//! Compression plumbing: `client_comp`/`master_comp` are shareable
//! descriptors ([`Compressor`]); `run` instantiates one stateful
//! [`CompressorState`] per client (own RNG stream, error-feedback residual
//! if the spec asks for one) plus a reusable wire buffer, so the
//! communication hot path performs no steady-state allocation and needs no
//! RNG mutexes.

use std::sync::{Arc, Mutex};

use super::{client_rngs, evaluate, FedAlgorithm, FedEnv};
use crate::compress::{Compressed, Compressor, CompressorState};
use crate::metrics::Series;
use crate::model::aggregation_step;
use crate::protocol::{Coin, StepKind};
use crate::runtime::Backend as _;
use crate::transport::Network;

pub struct L2gd {
    /// aggregation probability p ∈ (0, 1)
    pub p: f64,
    /// penalty strength λ
    pub lambda: f64,
    /// stepsize η (Theorem 1 requires η ≤ 1/(2γ))
    pub eta: f64,
    /// client-side compression descriptor C_i (each client gets its own
    /// stateful instance at run time)
    pub client_comp: Arc<dyn Compressor>,
    /// master-side compression descriptor C_M
    pub master_comp: Arc<dyn Compressor>,
    /// label suffix for the metric series
    pub tag: String,
}

impl L2gd {
    /// Uniform client compressor from spec strings (`n` clients share one
    /// descriptor; states are instantiated per client inside `run`).
    pub fn new(p: f64, lambda: f64, eta: f64, _n: usize,
               client_spec: &str, master_spec: &str) -> anyhow::Result<L2gd> {
        let client_comp = crate::compress::from_spec(client_spec)?;
        let master_comp = crate::compress::from_spec(master_spec)?;
        Ok(L2gd {
            p,
            lambda,
            eta,
            client_comp,
            master_comp,
            tag: format!("l2gd[{client_spec}|{master_spec}]"),
        })
    }

    /// Practitioner parameterization: choose the *local* stepsize
    /// `local_lr` (the effective ∇f_i coefficient) and the aggregation step
    /// `agg = ηλ/np` directly; η and λ are derived. This is how the paper's
    /// DNN experiments are tuned (§VII-B).
    pub fn from_local_and_agg(p: f64, local_lr: f64, agg: f64, n: usize,
                              client_spec: &str, master_spec: &str)
                              -> anyhow::Result<L2gd> {
        anyhow::ensure!(p > 0.0 && p < 1.0, "p must be in (0,1)");
        let eta = local_lr * n as f64 * (1.0 - p);
        let lambda = agg * n as f64 * p / eta;
        Self::new(p, lambda, eta, n, client_spec, master_spec)
    }

    /// local-step coefficient η/(n(1−p))
    pub fn local_coef(&self, n: usize) -> f64 {
        self.eta / (n as f64 * (1.0 - self.p))
    }

    /// aggregation-step coefficient ηλ/(np)
    pub fn agg_coef(&self, n: usize) -> f64 {
        self.eta * self.lambda / (n as f64 * self.p)
    }
}

impl FedAlgorithm for L2gd {
    fn label(&self) -> String {
        format!("{}:p={},λ={}", self.tag, self.p, self.lambda)
    }

    fn run(&mut self, env: &FedEnv, steps: u64, eval_every: u64) -> anyhow::Result<Series> {
        let n = env.n_clients();
        anyhow::ensure!(self.p > 0.0 || self.lambda == 0.0,
                        "p = 0 only valid for λ = 0 (pure local training)");
        let d = env.backend.param_count();
        let local_coef = self.local_coef(n) as f32;
        let agg_coef = self.agg_coef(n) as f32;
        // x ← (1−a)x + a·anchor is a contraction toward the anchor only for
        // a ∈ (0, 2); beyond 2 the aggregation step diverges. (The paper's
        // stable regimes are a ∈ (0, 0.17] and a ≈ 1; a ∈ [0.5, 0.95) shows
        // high variance — §VII-B.)
        anyhow::ensure!(agg_coef.is_finite() && (0.0..2.0).contains(&agg_coef),
                        "ηλ/np = {agg_coef} outside [0,2): aggregation diverges");

        let init = env.backend.init_params();
        let mut xs: Vec<Vec<f32>> = vec![init.clone(); n];
        // ξ_{-1} = 1 with x̄^{-1} = mean of identical inits = init
        let mut anchor = init;
        let mut coin = Coin::new(self.p, env.seed ^ 0xC011); // coin stream
        let mut net = Network::new(n);
        // batch-sampling streams (shared with the gradient fan-out)
        let rngs: Vec<Mutex<crate::util::Rng>> =
            client_rngs(env.seed, n).into_iter().map(Mutex::new).collect();
        // per-client compression state + reusable wire buffer: own RNG
        // streams, no mutex, no allocation after the first round
        let mut seeder = crate::util::Rng::new(env.seed ^ 0xC09B);
        let mut uplinks: Vec<(Box<dyn CompressorState>, Compressed)> = (0..n)
            .map(|_| (self.client_comp.instantiate(d, seeder.next_u64()),
                      Compressed::empty()))
            .collect();
        let mut master_state = self.master_comp.instantiate(d, env.seed ^ 0x3a57e5);
        let mut master_buf = Compressed::empty();
        let mut ybar = vec![0.0f32; d];

        let mut series = Series::new(self.label());
        series.records.push(evaluate(env, &xs, 0, &net)?);

        for k in 1..=steps {
            match coin.draw() {
                StepKind::Local => {
                    // all devices: one local gradient step (parallel)
                    let outs = env.pool.scope_map(&xs, |i, x| {
                        let mut rng = rngs[i].lock().unwrap();
                        let batch = env.backend.make_train_batch(&env.shards[i], &mut rng);
                        env.backend.grad(x, &batch)
                    });
                    for (x, out) in xs.iter_mut().zip(outs) {
                        let g = out?;
                        crate::model::axpy(x, -local_coef, &g.grad);
                    }
                }
                StepKind::AggregateFresh => {
                    net.begin_round();
                    // uplink: compress each local model into its reusable
                    // buffer (parallel, per-client mutable state)
                    let results = env.pool.scope_zip_mut(&mut uplinks, &xs,
                                                         |_i, (state, buf), x| {
                        state.compress_into(x, buf)
                    });
                    for res in results {
                        res?;
                    }
                    // master: ȳ = (1/n) Σ C_i(x_i), fused decode-accumulate
                    ybar.fill(0.0);
                    let inv_n = 1.0 / n as f32;
                    for (i, (_, c)) in uplinks.iter().enumerate() {
                        net.uplink(k, i, c.bits);
                        c.decode_add(&mut ybar, inv_n);
                    }
                    // downlink: broadcast C_M(ȳ)
                    master_state.compress_into(&ybar, &mut master_buf)?;
                    net.downlink_broadcast(k, master_buf.bits);
                    master_buf.decode_into(&mut anchor);
                    net.end_round();
                    for x in xs.iter_mut() {
                        aggregation_step(x, agg_coef, &anchor);
                    }
                }
                StepKind::AggregateCached => {
                    // no communication: reuse the cached anchor
                    for x in xs.iter_mut() {
                        aggregation_step(x, agg_coef, &anchor);
                    }
                }
            }
            if k % eval_every == 0 || k == steps {
                series.records.push(evaluate(env, &xs, k, &net)?);
                if !series.records.last().unwrap().is_finite() {
                    break; // diverged: record it and stop (paper §B)
                }
            }
        }
        Ok(series)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::runtime::NativeLogreg;
    use crate::util::threadpool::ThreadPool;
    use std::sync::Arc;

    fn env(n: usize, seed: u64) -> FedEnv {
        let (data, test) = synth::logistic_split(50 * n, 100, 16, 0.02, seed);
        let shards = data.split_contiguous(n);
        FedEnv {
            backend: Arc::new(NativeLogreg::new(16, 0.01, 64, 128)),
            shards,
            train_eval: data,
            test,
            pool: ThreadPool::new(4),
            seed,
        }
    }

    #[test]
    fn uncompressed_l2gd_decreases_personal_loss() {
        let e = env(5, 0);
        let mut alg = L2gd::from_local_and_agg(0.3, 0.5, 0.5, 5, "identity", "identity").unwrap();
        let series = alg.run(&e, 150, 50).unwrap();
        let first = series.records.first().unwrap().personal_loss;
        let last = series.records.last().unwrap().personal_loss;
        assert!(last < first * 0.8, "personal loss {first} -> {last}");
    }

    #[test]
    fn compressed_l2gd_converges_with_natural() {
        let e = env(5, 1);
        let mut alg = L2gd::from_local_and_agg(0.3, 0.5, 0.5, 5, "natural", "natural").unwrap();
        let series = alg.run(&e, 150, 50).unwrap();
        let first = series.records.first().unwrap().personal_loss;
        let last = series.records.last().unwrap().personal_loss;
        assert!(last < first * 0.85, "personal loss {first} -> {last}");
        // and actually communicated fewer bits than identity would
        let bits = series.records.last().unwrap().bits_per_client;
        assert!(bits > 0.0);
    }

    #[test]
    fn communication_only_on_fresh_transitions() {
        let e = env(3, 2);
        let mut alg = L2gd::from_local_and_agg(0.5, 0.3, 0.5, 3, "identity", "identity").unwrap();
        let series = alg.run(&e, 200, 200).unwrap();
        let last = series.records.last().unwrap();
        // comm rounds ≈ p(1−p)·K = 50; generous deterministic-seed bounds
        assert!(last.comm_rounds > 20 && last.comm_rounds < 80,
                "comm_rounds = {}", last.comm_rounds);
        // bits = comm_rounds × (up 32d + down 32d)
        let d = 16u64;
        assert_eq!(last.bits_up + last.bits_down,
                   last.comm_rounds * (32 * d) * 3 + last.comm_rounds * (32 * d) * 3);
    }

    #[test]
    fn natural_sends_fewer_bits_than_identity_per_round() {
        let e = env(4, 3);
        let mut a = L2gd::from_local_and_agg(0.4, 0.3, 0.5, 4, "identity", "identity").unwrap();
        let mut b = L2gd::from_local_and_agg(0.4, 0.3, 0.5, 4, "natural", "natural").unwrap();
        let sa = a.run(&e, 100, 100).unwrap();
        let sb = b.run(&e, 100, 100).unwrap();
        let ra = sa.records.last().unwrap();
        let rb = sb.records.last().unwrap();
        let per_round_a = (ra.bits_up + ra.bits_down) as f64 / ra.comm_rounds as f64;
        let per_round_b = (rb.bits_up + rb.bits_down) as f64 / rb.comm_rounds as f64;
        // 9 bits vs 32 bits per coordinate ⇒ ~3.5× reduction
        assert!(per_round_b < per_round_a * 0.4,
                "identity {per_round_a} vs natural {per_round_b}");
    }

    #[test]
    fn lambda_zero_is_pure_local_training() {
        let e = env(3, 4);
        let mut alg = L2gd::new(0.2, 0.0, 1.0, 3, "identity", "identity").unwrap();
        let series = alg.run(&e, 100, 100).unwrap();
        let last = series.records.last().unwrap();
        // aggregation steps are no-ops (coef 0) but still draw the coin;
        // communication still happens on transitions yet models ignore it —
        // personalized loss must still drop via local steps
        assert!(last.personal_loss < series.records[0].personal_loss);
    }

    #[test]
    fn deterministic_given_seed() {
        let e = env(3, 5);
        let mut a = L2gd::from_local_and_agg(0.3, 0.3, 0.5, 3, "qsgd:8", "natural").unwrap();
        let mut b = L2gd::from_local_and_agg(0.3, 0.3, 0.5, 3, "qsgd:8", "natural").unwrap();
        let sa = a.run(&e, 60, 20).unwrap();
        let sb = b.run(&e, 60, 20).unwrap();
        for (ra, rb) in sa.records.iter().zip(&sb.records) {
            assert_eq!(ra.train_loss, rb.train_loss);
            assert_eq!(ra.bits_up, rb.bits_up);
        }
    }

    #[test]
    fn pipeline_and_ef_specs_run_end_to_end() {
        // the ISSUE's flagship spec: error feedback around a
        // sparsify-then-quantize chain, against a natural master
        let e = env(4, 6);
        let mut alg = L2gd::from_local_and_agg(0.4, 0.4, 0.5, 4,
                                               "ef(randk:10>qsgd:8)", "natural")
            .unwrap();
        let s = alg.run(&e, 120, 40).unwrap();
        let last = s.records.last().unwrap();
        assert!(last.comm_rounds > 0);
        assert!(last.bits_up > 0);
        assert!(last.personal_loss < s.records[0].personal_loss,
                "loss {} -> {}", s.records[0].personal_loss, last.personal_loss);
        // uplink is seed + 10 quantized survivors ≪ identity's 32·16 bits
        let up_per_client_round = last.bits_up as f64 / (4 * last.comm_rounds) as f64;
        assert!(up_per_client_round < 32.0 * 16.0 * 0.8,
                "bits/client/round = {up_per_client_round}");
    }

    #[test]
    fn oversized_sparsifier_fails_at_compress_time() {
        // d = 16 here, so randk:500 must surface a clean error from run()
        let e = env(3, 7);
        let mut alg = L2gd::from_local_and_agg(0.5, 0.3, 0.5, 3,
                                               "randk:500", "identity").unwrap();
        let err = alg.run(&e, 100, 100).expect_err("k > d must error");
        assert!(format!("{err:#}").contains("exceeds the dimension"), "{err:#}");
    }

    #[test]
    fn from_local_and_agg_roundtrip() {
        let alg = L2gd::from_local_and_agg(0.4, 0.05, 1.0, 10, "identity", "identity")
            .unwrap();
        assert!((alg.local_coef(10) - 0.05).abs() < 1e-12);
        assert!((alg.agg_coef(10) - 1.0).abs() < 1e-12);
    }
}
