//! Compressed L2GD — Algorithm 1 of the paper.
//!
//! State: personalized models x_1..x_n, a cached aggregation anchor, and
//! the ξ coin. Per iteration k:
//!
//! * ξ_k = 0 (prob 1−p): every device takes the local step
//!   `x_i ← x_i − η/(n(1−p)) ∇f_i(x_i)` — no communication.
//! * ξ_k = 1, ξ_{k−1} = 0: **the only communicating step**. Device i
//!   uplinks `C_i(x_i)`; the master forms `ȳ = (1/n) Σ C_i(x_i)` (fused
//!   decode-accumulate), compresses it once and broadcasts `C_M(ȳ)`;
//!   devices aggregate `x_i ← x_i − (ηλ/np)(x_i − C_M(ȳ))`.
//! * ξ_k = 1, ξ_{k−1} = 1: aggregation toward the **cached** anchor, no
//!   communication. (With identity compression the anchor is the exact
//!   running average, which is a fixed point of consecutive aggregation
//!   steps — §III; under compression we reuse the last broadcast C_M(ȳ),
//!   the only shared quantity the devices possess.)
//!
//! `eta_lambda_np = ηλ/(np)` is the aggregation step size; the paper's
//! sweet spots are (0, 0.17] and ≈ 1 (§VII-B), and exactly 1 recovers
//! FedAvg with a random number of local steps (Figs 7–8).
//!
//! This module holds the **configuration** ([`L2gd`]): the execution
//! lives in the generic round engine ([`super::engine::Engine`]), which
//! runs the same protocol over a dense [`crate::model::ParamMatrix`]
//! ([`L2gdEngine`] — the lockstep path, zero steady-state allocation) or
//! a copy-on-write [`crate::model::ShardedStore`]
//! ([`super::ShardedL2gdEngine`] — the million-device fleet path), with
//! the schedule and server transform pluggable for the FedAvg/FedOpt
//! baselines ([`super::engine::AlgSpec`]).

use std::sync::Arc;

pub use super::engine::{client_stream, L2gdEngine, COMP_STREAM_SALT};
use super::{FedAlgorithm, FedEnv};
use crate::compress::Compressor;
use crate::metrics::Series;

pub struct L2gd {
    /// aggregation probability p ∈ (0, 1)
    pub p: f64,
    /// penalty strength λ
    pub lambda: f64,
    /// stepsize η (Theorem 1 requires η ≤ 1/(2γ))
    pub eta: f64,
    /// client-side compression descriptor C_i (each client gets its own
    /// stateful instance at run time)
    pub client_comp: Arc<dyn Compressor>,
    /// master-side compression descriptor C_M
    pub master_comp: Arc<dyn Compressor>,
    /// label suffix for the metric series
    pub tag: String,
}

impl L2gd {
    /// Uniform client compressor from spec strings (`n` clients share one
    /// descriptor; states are instantiated per client inside the engine).
    pub fn new(p: f64, lambda: f64, eta: f64, _n: usize,
               client_spec: &str, master_spec: &str) -> anyhow::Result<L2gd> {
        let client_comp = crate::compress::from_spec(client_spec)?;
        let master_comp = crate::compress::from_spec(master_spec)?;
        Ok(L2gd {
            p,
            lambda,
            eta,
            client_comp,
            master_comp,
            tag: format!("l2gd[{client_spec}|{master_spec}]"),
        })
    }

    /// Practitioner parameterization: choose the *local* stepsize
    /// `local_lr` (the effective ∇f_i coefficient) and the aggregation step
    /// `agg = ηλ/np` directly; η and λ are derived. This is how the paper's
    /// DNN experiments are tuned (§VII-B).
    pub fn from_local_and_agg(p: f64, local_lr: f64, agg: f64, n: usize,
                              client_spec: &str, master_spec: &str)
                              -> anyhow::Result<L2gd> {
        anyhow::ensure!(p > 0.0 && p < 1.0, "p must be in (0,1)");
        let eta = local_lr * n as f64 * (1.0 - p);
        let lambda = agg * n as f64 * p / eta;
        Self::new(p, lambda, eta, n, client_spec, master_spec)
    }

    /// local-step coefficient η/(n(1−p))
    pub fn local_coef(&self, n: usize) -> f64 {
        self.eta / (n as f64 * (1.0 - self.p))
    }

    /// aggregation-step coefficient ηλ/(np)
    pub fn agg_coef(&self, n: usize) -> f64 {
        self.eta * self.lambda / (n as f64 * self.p)
    }

    /// Build the lockstep (dense-store) engine over `env` (validates the
    /// configuration). The engine borrows `env`; [`L2gdEngine::step`] then
    /// advances one protocol iteration with zero steady-state allocation.
    pub fn engine<'e>(&self, env: &'e FedEnv) -> anyhow::Result<L2gdEngine<'e>> {
        L2gdEngine::new(self, env, env.n_clients())
    }
}

impl FedAlgorithm for L2gd {
    fn label(&self) -> String {
        format!("{}:p={},λ={}", self.tag, self.p, self.lambda)
    }

    fn run(&mut self, env: &FedEnv, steps: u64, eval_every: u64) -> anyhow::Result<Series> {
        let mut eng = self.engine(env)?;
        let mut series = Series::new(self.label());
        series.records.push(eng.evaluate(0)?);
        for k in 1..=steps {
            eng.step(k)?;
            if k % eval_every == 0 || k == steps {
                series.records.push(eng.evaluate(k)?);
                if !series.records.last().unwrap().is_finite() {
                    break; // diverged: record it and stop (paper §B)
                }
            }
        }
        Ok(series)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::runtime::NativeLogreg;
    use crate::util::threadpool::ThreadPool;
    use std::sync::Arc;

    fn env(n: usize, seed: u64) -> FedEnv {
        let (data, test) = synth::logistic_split(50 * n, 100, 16, 0.02, seed);
        let shards = data.split_contiguous(n);
        FedEnv::new(Arc::new(NativeLogreg::new(16, 0.01, 64, 128)),
                    shards, data, test, ThreadPool::new(4), seed)
    }

    #[test]
    fn uncompressed_l2gd_decreases_personal_loss() {
        let e = env(5, 0);
        let mut alg = L2gd::from_local_and_agg(0.3, 0.5, 0.5, 5, "identity", "identity").unwrap();
        let series = alg.run(&e, 150, 50).unwrap();
        let first = series.records.first().unwrap().personal_loss;
        let last = series.records.last().unwrap().personal_loss;
        assert!(last < first * 0.8, "personal loss {first} -> {last}");
    }

    #[test]
    fn compressed_l2gd_converges_with_natural() {
        let e = env(5, 1);
        let mut alg = L2gd::from_local_and_agg(0.3, 0.5, 0.5, 5, "natural", "natural").unwrap();
        let series = alg.run(&e, 150, 50).unwrap();
        let first = series.records.first().unwrap().personal_loss;
        let last = series.records.last().unwrap().personal_loss;
        assert!(last < first * 0.85, "personal loss {first} -> {last}");
        // and actually communicated fewer bits than identity would
        let bits = series.records.last().unwrap().bits_per_client;
        assert!(bits > 0.0);
    }

    #[test]
    fn communication_only_on_fresh_transitions() {
        let e = env(3, 2);
        let mut alg = L2gd::from_local_and_agg(0.5, 0.3, 0.5, 3, "identity", "identity").unwrap();
        let series = alg.run(&e, 200, 200).unwrap();
        let last = series.records.last().unwrap();
        // comm rounds ≈ p(1−p)·K = 50; generous deterministic-seed bounds
        assert!(last.comm_rounds > 20 && last.comm_rounds < 80,
                "comm_rounds = {}", last.comm_rounds);
        // identity wire at d = 16 over n = 3 clients: uplink and downlink
        // each carry exactly comm_rounds × n × 32·d bits — checked
        // independently per direction (the seed asserted only their sum
        // against itself)
        let per_round = 3 * 32 * 16u64;
        assert_eq!(last.bits_up, last.comm_rounds * per_round);
        assert_eq!(last.bits_down, last.comm_rounds * per_round);
    }

    #[test]
    fn natural_sends_fewer_bits_than_identity_per_round() {
        let e = env(4, 3);
        let mut a = L2gd::from_local_and_agg(0.4, 0.3, 0.5, 4, "identity", "identity").unwrap();
        let mut b = L2gd::from_local_and_agg(0.4, 0.3, 0.5, 4, "natural", "natural").unwrap();
        let sa = a.run(&e, 100, 100).unwrap();
        let sb = b.run(&e, 100, 100).unwrap();
        let ra = sa.records.last().unwrap();
        let rb = sb.records.last().unwrap();
        let per_round_a = (ra.bits_up + ra.bits_down) as f64 / ra.comm_rounds as f64;
        let per_round_b = (rb.bits_up + rb.bits_down) as f64 / rb.comm_rounds as f64;
        // 9 bits vs 32 bits per coordinate ⇒ ~3.5× reduction
        assert!(per_round_b < per_round_a * 0.4,
                "identity {per_round_a} vs natural {per_round_b}");
    }

    #[test]
    fn lambda_zero_is_pure_local_training() {
        let e = env(3, 4);
        let mut alg = L2gd::new(0.2, 0.0, 1.0, 3, "identity", "identity").unwrap();
        let series = alg.run(&e, 100, 100).unwrap();
        let last = series.records.last().unwrap();
        // aggregation steps are no-ops (coef 0) but still draw the coin;
        // communication still happens on transitions yet models ignore it —
        // personalized loss must still drop via local steps
        assert!(last.personal_loss < series.records[0].personal_loss);
    }

    #[test]
    fn deterministic_given_seed() {
        let e = env(3, 5);
        let mut a = L2gd::from_local_and_agg(0.3, 0.3, 0.5, 3, "qsgd:8", "natural").unwrap();
        let mut b = L2gd::from_local_and_agg(0.3, 0.3, 0.5, 3, "qsgd:8", "natural").unwrap();
        let sa = a.run(&e, 60, 20).unwrap();
        let sb = b.run(&e, 60, 20).unwrap();
        for (ra, rb) in sa.records.iter().zip(&sb.records) {
            assert_eq!(ra.train_loss, rb.train_loss);
            assert_eq!(ra.bits_up, rb.bits_up);
        }
    }

    #[test]
    fn pipeline_and_ef_specs_run_end_to_end() {
        // the ISSUE's flagship spec: error feedback around a
        // sparsify-then-quantize chain, against a natural master
        let e = env(4, 6);
        let mut alg = L2gd::from_local_and_agg(0.4, 0.4, 0.5, 4,
                                               "ef(randk:10>qsgd:8)", "natural")
            .unwrap();
        let s = alg.run(&e, 120, 40).unwrap();
        let last = s.records.last().unwrap();
        assert!(last.comm_rounds > 0);
        assert!(last.bits_up > 0);
        assert!(last.personal_loss < s.records[0].personal_loss,
                "loss {} -> {}", s.records[0].personal_loss, last.personal_loss);
        // uplink is seed + 10 quantized survivors ≪ identity's 32·16 bits
        let up_per_client_round = last.bits_up as f64 / (4 * last.comm_rounds) as f64;
        assert!(up_per_client_round < 32.0 * 16.0 * 0.8,
                "bits/client/round = {up_per_client_round}");
    }

    #[test]
    fn oversized_sparsifier_fails_at_compress_time() {
        // d = 16 here, so randk:500 must surface a clean error from run()
        let e = env(3, 7);
        let mut alg = L2gd::from_local_and_agg(0.5, 0.3, 0.5, 3,
                                               "randk:500", "identity").unwrap();
        let err = alg.run(&e, 100, 100).expect_err("k > d must error");
        assert!(format!("{err:#}").contains("exceeds the dimension"), "{err:#}");
    }

    #[test]
    fn from_local_and_agg_roundtrip() {
        let alg = L2gd::from_local_and_agg(0.4, 0.05, 1.0, 10, "identity", "identity")
            .unwrap();
        assert!((alg.local_coef(10) - 0.05).abs() < 1e-12);
        assert!((alg.agg_coef(10) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn engine_stepping_matches_run() {
        // run() is a thin loop over the public engine API; driving the
        // engine by hand must land on the same state
        let e = env(3, 8);
        let alg = L2gd::from_local_and_agg(0.4, 0.3, 0.5, 3, "natural", "natural").unwrap();
        let mut manual = alg.engine(&e).unwrap();
        manual.run_steps(0, 80).unwrap();
        let rec_manual = manual.evaluate(80).unwrap();
        let mut alg2 = L2gd::from_local_and_agg(0.4, 0.3, 0.5, 3, "natural", "natural").unwrap();
        let s = alg2.run(&e, 80, 80).unwrap();
        let rec_run = s.records.last().unwrap();
        assert_eq!(rec_manual.train_loss, rec_run.train_loss);
        assert_eq!(rec_manual.personal_loss, rec_run.personal_loss);
        assert_eq!(rec_manual.bits_up, rec_run.bits_up);
    }

    #[test]
    fn large_n_tree_reduction_is_deterministic_and_close_to_serial() {
        // n > REDUCE_LEAF exercises the pooled tree reduction; the series
        // must be identical across pool sizes (fixed leaves) and the run
        // must still learn
        let run = |pool: usize| {
            let mut e = env(12, 9);
            e.pool = ThreadPool::new(pool);
            let mut alg = L2gd::from_local_and_agg(0.4, 0.3, 0.5, 12,
                                                   "identity", "identity").unwrap();
            alg.run(&e, 80, 40).unwrap()
        };
        let a = run(1);
        let b = run(8);
        for (ra, rb) in a.records.iter().zip(&b.records) {
            assert_eq!(ra.train_loss, rb.train_loss);
            assert_eq!(ra.personal_loss, rb.personal_loss);
        }
        assert!(a.records.last().unwrap().personal_loss
                < a.records[0].personal_loss);
    }

    /// The bool-mask adapters are thin translations onto the sorted-cohort
    /// entry points: an all-true mask reproduces the lockstep series.
    #[test]
    fn all_true_mask_adapters_match_lockstep() {
        let e = env(5, 10);
        let alg = L2gd::from_local_and_agg(0.4, 0.3, 0.5, 5, "natural", "natural").unwrap();
        let mut lock = alg.engine(&e).unwrap();
        let mut masked = alg.engine(&e).unwrap();
        let mask = [true; 5];
        for k in 1..=60 {
            // replay the lockstep coin through the masked surface
            match masked.draw() {
                crate::protocol::StepKind::Local => {
                    masked.step_local_masked(&mask).unwrap();
                }
                crate::protocol::StepKind::AggregateFresh => {
                    masked.compress_uplinks_masked(&mask).unwrap();
                    masked.complete_fresh_masked(k, &mask, &mask).unwrap();
                }
                crate::protocol::StepKind::AggregateCached => {
                    masked.step_aggregate_cached_masked(&mask);
                }
            }
            lock.step(k).unwrap();
        }
        for i in 0..5 {
            assert_eq!(lock.xs().row(i), masked.xs().row(i), "row {i}");
        }
        let rl = lock.evaluate(60).unwrap();
        let rm = masked.evaluate(60).unwrap();
        assert_eq!(rl.train_loss, rm.train_loss);
        assert_eq!(rl.bits_up, rm.bits_up);
        assert_eq!(rl.bits_down, rm.bits_down);
    }
}
