//! Compressed L2GD — Algorithm 1 of the paper — executed by a
//! **zero-steady-state-allocation round engine**.
//!
//! State: personalized models x_1..x_n, a cached aggregation anchor, and
//! the ξ coin. Per iteration k:
//!
//! * ξ_k = 0 (prob 1−p): every device takes the local step
//!   `x_i ← x_i − η/(n(1−p)) ∇f_i(x_i)` — no communication.
//! * ξ_k = 1, ξ_{k−1} = 0: **the only communicating step**. Device i
//!   uplinks `C_i(x_i)`; the master forms `ȳ = (1/n) Σ C_i(x_i)` (fused
//!   decode-accumulate), compresses it once and broadcasts `C_M(ȳ)`;
//!   devices aggregate `x_i ← x_i − (ηλ/np)(x_i − C_M(ȳ))`.
//! * ξ_k = 1, ξ_{k−1} = 1: aggregation toward the **cached** anchor, no
//!   communication. (With identity compression the anchor is the exact
//!   running average, which is a fixed point of consecutive aggregation
//!   steps — §III; under compression we reuse the last broadcast C_M(ȳ),
//!   the only shared quantity the devices possess.)
//!
//! `eta_lambda_np = ηλ/(np)` is the aggregation step size; the paper's
//! sweet spots are (0, 0.17] and ≈ 1 (§VII-B), and exactly 1 recovers
//! FedAvg with a random number of local steps (Figs 7–8).
//!
//! ### Engine layout ([`L2gdEngine`])
//! The n models live in one contiguous [`ParamMatrix`] (row per client);
//! every per-client resource — batch-sampling RNG stream, gradient buffer,
//! compressor state, wire buffer — lives in that client's [`ClientSlot`].
//! Local steps run `Backend::grad_into` against the environment's cached
//! batch and apply the update in the same pooled sweep over disjoint
//! matrix rows; aggregation is a single parallel pass over the matrix; the
//! master's decode-accumulate runs as a pooled tree reduction over fixed
//! 8-client leaves (fixed leaf size ⇒ results are independent of the pool
//! size, and for n ≤ 8 bit-identical to the seed's sequential loop).
//! After the first communication round, a steady-state step touches the
//! allocator **zero** times — asserted under a counting global allocator
//! in `benches/perf_round_latency.rs` and `pfl bench`.
//!
//! ### Partial participation (the fleet simulator's entry points)
//! Every phase also exists in a masked form — [`L2gdEngine::step_local`],
//! [`L2gdEngine::compress_uplinks`] / [`L2gdEngine::complete_fresh`],
//! [`L2gdEngine::step_aggregate_cached`] — driven by the discrete-event
//! simulator in [`crate::sim`]: only available devices take local steps,
//! only the sampled-and-arrived cohort uplinks and receives the anchor.
//! The masked sweeps run the *same* arithmetic in the same order, so an
//! all-true mask reproduces the lockstep series bit for bit.
//! [`L2gdEngine::enable_wire_framing`] switches the metering (not the
//! math) to byte-accurate wire frames: each payload is framed with a
//! [`crate::transport::frame`] header, decode-roundtripped, and `LinkStats`
//! is fed the serialized frame size instead of the theoretical bit count.

use std::sync::Arc;

use super::{drain_slot_errors, evaluate, FedAlgorithm, FedEnv, ModelView};
use crate::compress::{Compressed, Compressor, CompressorState};
use crate::metrics::Series;
use crate::model::{kernels, ParamMatrix};
use crate::protocol::{Coin, StepKind};
use crate::runtime::{Backend as _, GradBuf};
use crate::transport::frame::{self, FrameHeader, SpecTable};
use crate::transport::Network;
use crate::util::rng::stream_seed;
use crate::util::Rng;

/// Clients per leaf of the master's decode-accumulate tree reduction.
/// Constant (not pool-derived) so the reduction order — and therefore the
/// training series — is machine-independent; n ≤ LEAF degenerates to the
/// seed's exact sequential accumulation. Shared with the sharded cohort
/// engine, whose shard boundaries are multiples of it (a leaf never
/// straddles a shard, so the per-shard partials compose bit-exactly into
/// this flat reduction).
pub(crate) const REDUCE_LEAF: usize = 8;

/// Salt for per-client compression-stream seeds: client i's compressor
/// state is seeded `stream_seed(env.seed ^ COMP_STREAM_SALT, i)` — O(1)
/// random access, so the sharded cohort engine can instantiate the
/// *identical* stream lazily on a client's first touch. The reference
/// oracle derives its seeds the same way.
pub(crate) const COMP_STREAM_SALT: u64 = 0xC09B;

/// Per-client batch-sampling stream for client `i` — the random-access
/// counterpart of the old sequential fork walk, shared by the dense
/// engine, the reference oracle, and the sharded cohort engine.
pub(crate) fn client_stream(seed: u64, i: usize) -> Rng {
    Rng::stream(seed, i as u64 + 1)
}

/// Participation mask test: `None` is the lockstep full-participation
/// path (no branch on the seed-equivalence path beyond this inlined
/// `map_or`), `Some(mask)` restricts a sweep to the marked clients.
#[inline]
fn on(mask: Option<&[bool]>, i: usize) -> bool {
    mask.map_or(true, |m| m[i])
}

/// Byte-accurate wire mode (see the module docs): spec-id table plus a
/// reusable frame buffer. Metering-only — the training math never touches
/// this. Shared with the sharded cohort engine.
pub(crate) struct Framing {
    pub(crate) table: SpecTable,
    pub(crate) client_id: u16,
    pub(crate) master_id: u16,
    pub(crate) buf: Vec<u8>,
}

impl Framing {
    /// Intern the two wire specs and start with an empty frame buffer.
    pub(crate) fn new(client_spec: &str, master_spec: &str) -> Framing {
        let mut table = SpecTable::new();
        let client_id = table.intern(client_spec);
        let master_id = table.intern(master_spec);
        Framing { table, client_id, master_id, buf: Vec::new() }
    }

    /// Encode, decode back, verify, and return the serialized size in bits.
    fn roundtrip(&mut self, h: FrameHeader, payload: &[u8]) -> anyhow::Result<u64> {
        frame::encode_frame(&h, payload, &mut self.buf);
        let (h2, p2) = frame::decode_frame(&self.buf)?;
        anyhow::ensure!(h2 == h && p2 == payload,
                        "wire frame roundtrip mismatch at step {}", h.round);
        Ok((self.buf.len() * 8) as u64)
    }

    pub(crate) fn uplink_bits(&mut self, k: u64, client: usize, wire: &Compressed)
                              -> anyhow::Result<u64> {
        let h = FrameHeader::uplink(k, client, self.client_id, wire)?;
        self.roundtrip(h, &wire.payload)
    }

    pub(crate) fn broadcast_bits(&mut self, k: u64, wire: &Compressed)
                                 -> anyhow::Result<u64> {
        let h = FrameHeader::broadcast(k, self.master_id, wire)?;
        self.roundtrip(h, &wire.payload)
    }
}

/// Per-client engine state: everything a worker touches for client i,
/// packed together so the pooled sweeps need no locks and no allocation.
struct ClientSlot {
    /// batch-sampling stream (only drawn from for non-static backends)
    rng: Rng,
    /// reusable gradient output buffer
    grad: GradBuf,
    /// stateful compressor instance (own RNG stream, EF residual)
    comp: Box<dyn CompressorState>,
    /// reusable wire buffer
    wire: Compressed,
    /// error parked by a worker, surfaced after the sweep (allocates only
    /// on the failure path)
    err: Option<anyhow::Error>,
}

pub struct L2gd {
    /// aggregation probability p ∈ (0, 1)
    pub p: f64,
    /// penalty strength λ
    pub lambda: f64,
    /// stepsize η (Theorem 1 requires η ≤ 1/(2γ))
    pub eta: f64,
    /// client-side compression descriptor C_i (each client gets its own
    /// stateful instance at run time)
    pub client_comp: Arc<dyn Compressor>,
    /// master-side compression descriptor C_M
    pub master_comp: Arc<dyn Compressor>,
    /// label suffix for the metric series
    pub tag: String,
}

impl L2gd {
    /// Uniform client compressor from spec strings (`n` clients share one
    /// descriptor; states are instantiated per client inside the engine).
    pub fn new(p: f64, lambda: f64, eta: f64, _n: usize,
               client_spec: &str, master_spec: &str) -> anyhow::Result<L2gd> {
        let client_comp = crate::compress::from_spec(client_spec)?;
        let master_comp = crate::compress::from_spec(master_spec)?;
        Ok(L2gd {
            p,
            lambda,
            eta,
            client_comp,
            master_comp,
            tag: format!("l2gd[{client_spec}|{master_spec}]"),
        })
    }

    /// Practitioner parameterization: choose the *local* stepsize
    /// `local_lr` (the effective ∇f_i coefficient) and the aggregation step
    /// `agg = ηλ/np` directly; η and λ are derived. This is how the paper's
    /// DNN experiments are tuned (§VII-B).
    pub fn from_local_and_agg(p: f64, local_lr: f64, agg: f64, n: usize,
                              client_spec: &str, master_spec: &str)
                              -> anyhow::Result<L2gd> {
        anyhow::ensure!(p > 0.0 && p < 1.0, "p must be in (0,1)");
        let eta = local_lr * n as f64 * (1.0 - p);
        let lambda = agg * n as f64 * p / eta;
        Self::new(p, lambda, eta, n, client_spec, master_spec)
    }

    /// local-step coefficient η/(n(1−p))
    pub fn local_coef(&self, n: usize) -> f64 {
        self.eta / (n as f64 * (1.0 - self.p))
    }

    /// aggregation-step coefficient ηλ/(np)
    pub fn agg_coef(&self, n: usize) -> f64 {
        self.eta * self.lambda / (n as f64 * self.p)
    }

    /// Build the stepping engine (validates the configuration against the
    /// environment). The engine borrows `env`; [`L2gdEngine::step`] then
    /// advances one protocol iteration with zero steady-state allocation.
    pub fn engine<'e>(&self, env: &'e FedEnv) -> anyhow::Result<L2gdEngine<'e>> {
        L2gdEngine::new(self, env)
    }
}

/// The stepping round engine. See the module docs for the layout.
pub struct L2gdEngine<'e> {
    env: &'e FedEnv,
    local_coef: f32,
    agg_coef: f32,
    /// n × d personalized models, row per client
    xs: ParamMatrix,
    /// last broadcast C_M(ȳ) (Algorithm 1's cached anchor)
    anchor: Vec<f32>,
    /// master accumulator ȳ = (1/n) Σ C_i(x_i)
    ybar: Vec<f32>,
    /// per-leaf partial sums of the pooled tree reduction (0 rows when the
    /// serial path is used, i.e. n ≤ REDUCE_LEAF)
    reduce: ParamMatrix,
    slots: Vec<ClientSlot>,
    master_state: Box<dyn CompressorState>,
    master_buf: Compressed,
    coin: Coin,
    net: Network,
    /// canonical spec strings (frame header spec-id interning)
    client_spec: String,
    master_spec: String,
    /// byte-accurate wire metering, enabled by the fleet simulator
    framing: Option<Framing>,
}

impl<'e> L2gdEngine<'e> {
    fn new(alg: &L2gd, env: &'e FedEnv) -> anyhow::Result<L2gdEngine<'e>> {
        let n = env.n_clients();
        anyhow::ensure!(alg.p > 0.0 || alg.lambda == 0.0,
                        "p = 0 only valid for λ = 0 (pure local training)");
        let d = env.backend.param_count();
        let local_coef = alg.local_coef(n) as f32;
        let agg_coef = alg.agg_coef(n) as f32;
        // x ← (1−a)x + a·anchor is a contraction toward the anchor only for
        // a ∈ (0, 2); beyond 2 the aggregation step diverges. (The paper's
        // stable regimes are a ∈ (0, 0.17] and a ≈ 1; a ∈ [0.5, 0.95) shows
        // high variance — §VII-B.)
        anyhow::ensure!(agg_coef.is_finite() && (0.0..2.0).contains(&agg_coef),
                        "ηλ/np = {agg_coef} outside [0,2): aggregation diverges");

        let init = env.backend.init_params();
        // ξ_{-1} = 1 with x̄^{-1} = mean of identical inits = init
        let xs = ParamMatrix::replicate(n, &init);
        let anchor = init;
        // per-client batch-sampling streams + compression states, derived
        // by *random-access* stream index (`stream_seed`) rather than a
        // sequential seeder walk: client i's streams are a pure function
        // of (run seed, i), so the sharded cohort engine can lazily
        // instantiate bit-identical state for exactly the clients a cohort
        // touches. The reference oracle derives its seeds the same way.
        let slots: Vec<ClientSlot> = (0..n)
            .map(|i| ClientSlot {
                rng: client_stream(env.seed, i),
                grad: GradBuf::with_dim(d),
                comp: alg.client_comp
                    .instantiate(d, stream_seed(env.seed ^ COMP_STREAM_SALT, i as u64)),
                wire: Compressed::empty(),
                err: None,
            })
            .collect();
        let leaves = if n > REDUCE_LEAF { n.div_ceil(REDUCE_LEAF) } else { 0 };
        // Warm every worker's thread-local compression scratch with a
        // throwaway state of the same spec: client→worker assignment is
        // dynamic, so without this a cold worker could take its first-use
        // scratch allocation in the middle of a measured steady state.
        let comp = &alg.client_comp;
        env.pool.on_each_worker(|w| {
            let mut st = comp.instantiate(d, 0x3CA7F ^ w as u64);
            let mut buf = Compressed::empty();
            let probe = vec![0.0f32; d];
            let _ = st.compress_into(&probe, &mut buf);
        });
        // force the lazy per-shard train-batch cache off the hot path
        let _ = env.train_batch_cached(0);
        Ok(L2gdEngine {
            env,
            local_coef,
            agg_coef,
            xs,
            anchor,
            ybar: vec![0.0f32; d],
            reduce: ParamMatrix::zeros(leaves, d),
            slots,
            master_state: alg.master_comp.instantiate(d, env.seed ^ 0x3a57e5),
            master_buf: Compressed::empty(),
            coin: Coin::new(alg.p, env.seed ^ 0xC011), // coin stream
            net: Network::new(n),
            client_spec: alg.client_comp.name(),
            master_spec: alg.master_comp.name(),
            framing: None,
        })
    }

    /// The per-client models (row i = client i).
    pub fn xs(&self) -> &ParamMatrix {
        &self.xs
    }

    pub fn net(&self) -> &Network {
        &self.net
    }

    /// Switch the wire metering to byte-accurate frames: `LinkStats` is fed
    /// the serialized frame size (header + byte-aligned payload), and every
    /// frame is encode/decode roundtrip-checked. The training math — and
    /// therefore the loss series — is unchanged.
    pub fn enable_wire_framing(&mut self) {
        self.framing = Some(Framing::new(&self.client_spec, &self.master_spec));
    }

    /// The frame spec-id table (present once framing is enabled).
    pub fn spec_table(&self) -> Option<&SpecTable> {
        self.framing.as_ref().map(|f| &f.table)
    }

    /// Advance one protocol iteration (step index `k` is used for bit
    /// accounting only). Steady state performs zero heap allocations.
    pub fn step(&mut self, k: u64) -> anyhow::Result<()> {
        match self.coin.draw() {
            StepKind::Local => self.local_step(None)?,
            StepKind::AggregateFresh => self.aggregate_fresh(k)?,
            StepKind::AggregateCached => self.apply_aggregation(None),
        }
        Ok(())
    }

    /// Draw the ξ coin for the next iteration — the simulator's dispatch
    /// point (lockstep [`Self::step`] draws from the same stream, so a
    /// simulator that executes every drawn kind reproduces it exactly).
    pub fn draw(&mut self) -> StepKind {
        self.coin.draw()
    }

    /// Protocol coin statistics (locals / fresh / cached counts).
    pub fn coin_stats(&self) -> &crate::protocol::CoinStats {
        &self.coin.stats
    }

    /// Local gradient step restricted to `active` devices (an offline
    /// device keeps its model and draws nothing from its streams). With an
    /// all-true mask this is bit-identical to the lockstep local step.
    pub fn step_local(&mut self, active: &[bool]) -> anyhow::Result<()> {
        debug_assert_eq!(active.len(), self.slots.len());
        self.local_step(Some(active))
    }

    /// Cached-anchor aggregation applied to `active` devices only.
    pub fn step_aggregate_cached(&mut self, active: &[bool]) {
        debug_assert_eq!(active.len(), self.slots.len());
        self.apply_aggregation(Some(active));
    }

    /// Phase 1 of a fresh aggregation under partial participation:
    /// compress the local models of the `sampled` devices into their wire
    /// buffers (each drawing from its own compression stream). The
    /// simulator then reads payload sizes via [`Self::uplink_frame_bytes`]
    /// to schedule arrivals, and commits the round with
    /// [`Self::complete_fresh`] over the subset that made the deadline.
    pub fn compress_uplinks(&mut self, sampled: &[bool]) -> anyhow::Result<()> {
        debug_assert_eq!(sampled.len(), self.slots.len());
        self.compress_step(Some(sampled))
    }

    /// Serialized uplink frame size (bytes) client `i`'s pending wire
    /// buffer occupies — valid after [`Self::compress_uplinks`] marked `i`.
    pub fn uplink_frame_bytes(&self, i: usize) -> u64 {
        (frame::HEADER_BYTES + self.slots[i].wire.payload.len()) as u64
    }

    /// Serialized downlink (anchor broadcast) frame size in bytes — valid
    /// after a fresh aggregation round.
    pub fn downlink_frame_bytes(&self) -> u64 {
        (frame::HEADER_BYTES + self.master_buf.payload.len()) as u64
    }

    /// Phase 2: meter the round's uplinks — `arrived` devices as
    /// participants, `sampled`-but-late devices as transmitted-but-
    /// discarded straggler traffic — average the arrived cohort's
    /// compressed models into ȳ, broadcast C_M(ȳ) to the cohort, and
    /// apply the aggregation step to the cohort. Errors on an empty
    /// cohort (the simulator skips the round instead). With all-true
    /// masks the model update is bit-identical to the lockstep fresh
    /// aggregation.
    pub fn complete_fresh(&mut self, k: u64, arrived: &[bool], sampled: &[bool])
                          -> anyhow::Result<()> {
        anyhow::ensure!(arrived.len() == self.slots.len()
                            && sampled.len() == self.slots.len(),
                        "participation mask length != n {}", self.slots.len());
        debug_assert!(arrived.iter().zip(sampled).all(|(&a, &s)| s || !a),
                      "arrived must be a subset of sampled");
        self.finish_fresh(k, Some(arrived), Some(sampled))
    }

    /// A fresh-aggregation attempt where *no* sampled device made the
    /// deadline: every cohort member still transmitted its frame, so the
    /// bytes meter as discarded traffic — but nothing aggregates, the
    /// anchor does not move, and the round records zero participants.
    pub fn abort_fresh(&mut self, k: u64, sampled: &[bool]) -> anyhow::Result<()> {
        anyhow::ensure!(sampled.len() == self.slots.len(),
                        "participation mask length {} != n {}",
                        sampled.len(), self.slots.len());
        self.net.begin_round();
        for (i, slot) in self.slots.iter().enumerate() {
            if !sampled[i] {
                continue;
            }
            let bits = match &mut self.framing {
                Some(f) => f.uplink_bits(k, i, &slot.wire)?,
                None => slot.wire.bits,
            };
            self.net.uplink_wasted(k, i, bits);
        }
        self.net.end_round();
        Ok(())
    }

    /// Run `count` iterations starting after step `from` (so the last step
    /// index is `from + count`).
    pub fn run_steps(&mut self, from: u64, count: u64) -> anyhow::Result<()> {
        for k in from + 1..=from + count {
            self.step(k)?;
        }
        Ok(())
    }

    /// Evaluate the current state into a `Record`.
    pub fn evaluate(&self, step: u64) -> anyhow::Result<crate::metrics::Record> {
        evaluate(self.env, ModelView::PerClient(&self.xs), step, &self.net)
    }

    /// Surface the first worker-parked error.
    fn take_err(&mut self) -> anyhow::Result<()> {
        drain_slot_errors(self.slots.iter_mut().map(|s| &mut s.err))
    }

    /// One local gradient step (all devices, or the `mask`ed subset),
    /// fused compute+update in a single pooled sweep over disjoint matrix
    /// rows.
    fn local_step(&mut self, mask: Option<&[bool]>) -> anyhow::Result<()> {
        let env = self.env;
        let coef = self.local_coef;
        let d = self.xs.dim();
        env.pool.scope_chunks_zip_mut(self.xs.as_mut_slice(), d, &mut self.slots,
                                      |i, x, slot| {
            if !on(mask, i) {
                return;
            }
            let res = match env.train_batch_cached(i) {
                Some(b) => env.backend.grad_into(x, b, &mut slot.grad),
                None => {
                    let b = env.backend.make_train_batch(&env.shards[i], &mut slot.rng);
                    env.backend.grad_into(x, &b, &mut slot.grad)
                }
            };
            match res {
                Ok(()) => kernels::axpy(x, -coef, &slot.grad.grad),
                Err(e) => slot.err = Some(e),
            }
        });
        self.take_err()
    }

    /// The lockstep communicating step: compress everyone, then finish.
    fn aggregate_fresh(&mut self, k: u64) -> anyhow::Result<()> {
        self.compress_step(None)?;
        self.finish_fresh(k, None, None)
    }

    /// Compress local models into the per-client wire buffers (parallel,
    /// per-client mutable state; masked devices draw nothing).
    fn compress_step(&mut self, mask: Option<&[bool]>) -> anyhow::Result<()> {
        let env = self.env;
        let d = self.xs.dim();
        env.pool.scope_chunks_zip_mut(self.xs.as_mut_slice(), d, &mut self.slots,
                                      |i, x, slot| {
            if !on(mask, i) {
                return;
            }
            if let Err(e) = slot.comp.compress_into(x, &mut slot.wire) {
                slot.err = Some(e);
            }
        });
        self.take_err()
    }

    /// Meter uplinks, decode-accumulate ȳ, broadcast C_M(ȳ), aggregate —
    /// over the full fleet (`None` masks, the seed-equivalent path) or a
    /// cohort. `sampled` devices outside the cohort transmitted too:
    /// their frames meter as discarded traffic, not participation.
    fn finish_fresh(&mut self, k: u64, mask: Option<&[bool]>,
                    sampled: Option<&[bool]>) -> anyhow::Result<()> {
        let env = self.env;
        let n = self.slots.len();
        let d = self.xs.dim();
        let count = match mask {
            None => n,
            Some(m) => m.iter().filter(|&&b| b).count(),
        };
        anyhow::ensure!(count > 0, "fresh aggregation with an empty cohort");
        self.net.begin_round();
        for (i, slot) in self.slots.iter().enumerate() {
            let arrived = on(mask, i);
            let transmitted = arrived || sampled.is_some_and(|s| s[i]);
            if !transmitted {
                continue;
            }
            let bits = match &mut self.framing {
                Some(f) => f.uplink_bits(k, i, &slot.wire)?,
                None => slot.wire.bits,
            };
            if arrived {
                self.net.uplink(k, i, bits);
            } else {
                self.net.uplink_wasted(k, i, bits);
            }
        }
        // master: ȳ = (1/count) Σ_cohort C_i(x_i), fused decode-accumulate.
        // Small n accumulates sequentially (bit-identical to the seed);
        // large n reduces over fixed 8-client leaves on the pool, combined
        // in leaf order (deterministic, pool-size independent).
        let inv = 1.0 / count as f32;
        if self.reduce.n_rows() == 0 {
            self.ybar.fill(0.0);
            for (i, slot) in self.slots.iter().enumerate() {
                if !on(mask, i) {
                    continue;
                }
                slot.wire.decode_add(&mut self.ybar, inv);
            }
        } else {
            let slots = &self.slots;
            env.pool.scope_chunks_mut(self.reduce.as_mut_slice(), d, |leaf, row| {
                row.fill(0.0);
                let lo = leaf * REDUCE_LEAF;
                let hi = (lo + REDUCE_LEAF).min(n);
                for (j, slot) in slots[lo..hi].iter().enumerate() {
                    if !on(mask, lo + j) {
                        continue;
                    }
                    slot.wire.decode_add(row, inv);
                }
            });
            self.ybar.fill(0.0);
            for leaf in self.reduce.rows() {
                kernels::add_assign(&mut self.ybar, leaf);
            }
        }
        // downlink: C_M(ȳ) to everyone (lockstep broadcast) or per cohort
        // member (an offline device receives nothing)
        self.master_state.compress_into(&self.ybar, &mut self.master_buf)?;
        let down_bits = match &mut self.framing {
            Some(f) => f.broadcast_bits(k, &self.master_buf)?,
            None => self.master_buf.bits,
        };
        match mask {
            None => self.net.downlink_broadcast(k, down_bits),
            Some(m) => {
                for (i, &a) in m.iter().enumerate() {
                    if a {
                        self.net.downlink(k, i, down_bits);
                    }
                }
            }
        }
        self.master_buf.decode_into(&mut self.anchor);
        self.net.end_round();
        self.apply_aggregation(mask);
        Ok(())
    }

    /// `x_i ← x_i − a(x_i − anchor)` for every (unmasked) client: one pass
    /// over the matrix, pooled when the sweep is large enough to amortize
    /// dispatch. Elementwise, so serial and pooled orders are bit-identical.
    fn apply_aggregation(&mut self, mask: Option<&[bool]>) {
        let a = self.agg_coef;
        let d = self.xs.dim();
        let n = self.xs.n_rows();
        if n * d < 1 << 15 {
            for (i, x) in self.xs.rows_mut().enumerate() {
                if on(mask, i) {
                    kernels::aggregation_step(x, a, &self.anchor);
                }
            }
        } else {
            let anchor = &self.anchor;
            self.env.pool.scope_chunks_mut(self.xs.as_mut_slice(), d, |i, x| {
                if on(mask, i) {
                    kernels::aggregation_step(x, a, anchor);
                }
            });
        }
    }
}

impl FedAlgorithm for L2gd {
    fn label(&self) -> String {
        format!("{}:p={},λ={}", self.tag, self.p, self.lambda)
    }

    fn run(&mut self, env: &FedEnv, steps: u64, eval_every: u64) -> anyhow::Result<Series> {
        let mut eng = self.engine(env)?;
        let mut series = Series::new(self.label());
        series.records.push(eng.evaluate(0)?);
        for k in 1..=steps {
            eng.step(k)?;
            if k % eval_every == 0 || k == steps {
                series.records.push(eng.evaluate(k)?);
                if !series.records.last().unwrap().is_finite() {
                    break; // diverged: record it and stop (paper §B)
                }
            }
        }
        Ok(series)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::runtime::NativeLogreg;
    use crate::util::threadpool::ThreadPool;
    use std::sync::Arc;

    fn env(n: usize, seed: u64) -> FedEnv {
        let (data, test) = synth::logistic_split(50 * n, 100, 16, 0.02, seed);
        let shards = data.split_contiguous(n);
        FedEnv::new(Arc::new(NativeLogreg::new(16, 0.01, 64, 128)),
                    shards, data, test, ThreadPool::new(4), seed)
    }

    #[test]
    fn uncompressed_l2gd_decreases_personal_loss() {
        let e = env(5, 0);
        let mut alg = L2gd::from_local_and_agg(0.3, 0.5, 0.5, 5, "identity", "identity").unwrap();
        let series = alg.run(&e, 150, 50).unwrap();
        let first = series.records.first().unwrap().personal_loss;
        let last = series.records.last().unwrap().personal_loss;
        assert!(last < first * 0.8, "personal loss {first} -> {last}");
    }

    #[test]
    fn compressed_l2gd_converges_with_natural() {
        let e = env(5, 1);
        let mut alg = L2gd::from_local_and_agg(0.3, 0.5, 0.5, 5, "natural", "natural").unwrap();
        let series = alg.run(&e, 150, 50).unwrap();
        let first = series.records.first().unwrap().personal_loss;
        let last = series.records.last().unwrap().personal_loss;
        assert!(last < first * 0.85, "personal loss {first} -> {last}");
        // and actually communicated fewer bits than identity would
        let bits = series.records.last().unwrap().bits_per_client;
        assert!(bits > 0.0);
    }

    #[test]
    fn communication_only_on_fresh_transitions() {
        let e = env(3, 2);
        let mut alg = L2gd::from_local_and_agg(0.5, 0.3, 0.5, 3, "identity", "identity").unwrap();
        let series = alg.run(&e, 200, 200).unwrap();
        let last = series.records.last().unwrap();
        // comm rounds ≈ p(1−p)·K = 50; generous deterministic-seed bounds
        assert!(last.comm_rounds > 20 && last.comm_rounds < 80,
                "comm_rounds = {}", last.comm_rounds);
        // identity wire at d = 16 over n = 3 clients: uplink and downlink
        // each carry exactly comm_rounds × n × 32·d bits — checked
        // independently per direction (the seed asserted only their sum
        // against itself)
        let per_round = 3 * 32 * 16u64;
        assert_eq!(last.bits_up, last.comm_rounds * per_round);
        assert_eq!(last.bits_down, last.comm_rounds * per_round);
    }

    #[test]
    fn natural_sends_fewer_bits_than_identity_per_round() {
        let e = env(4, 3);
        let mut a = L2gd::from_local_and_agg(0.4, 0.3, 0.5, 4, "identity", "identity").unwrap();
        let mut b = L2gd::from_local_and_agg(0.4, 0.3, 0.5, 4, "natural", "natural").unwrap();
        let sa = a.run(&e, 100, 100).unwrap();
        let sb = b.run(&e, 100, 100).unwrap();
        let ra = sa.records.last().unwrap();
        let rb = sb.records.last().unwrap();
        let per_round_a = (ra.bits_up + ra.bits_down) as f64 / ra.comm_rounds as f64;
        let per_round_b = (rb.bits_up + rb.bits_down) as f64 / rb.comm_rounds as f64;
        // 9 bits vs 32 bits per coordinate ⇒ ~3.5× reduction
        assert!(per_round_b < per_round_a * 0.4,
                "identity {per_round_a} vs natural {per_round_b}");
    }

    #[test]
    fn lambda_zero_is_pure_local_training() {
        let e = env(3, 4);
        let mut alg = L2gd::new(0.2, 0.0, 1.0, 3, "identity", "identity").unwrap();
        let series = alg.run(&e, 100, 100).unwrap();
        let last = series.records.last().unwrap();
        // aggregation steps are no-ops (coef 0) but still draw the coin;
        // communication still happens on transitions yet models ignore it —
        // personalized loss must still drop via local steps
        assert!(last.personal_loss < series.records[0].personal_loss);
    }

    #[test]
    fn deterministic_given_seed() {
        let e = env(3, 5);
        let mut a = L2gd::from_local_and_agg(0.3, 0.3, 0.5, 3, "qsgd:8", "natural").unwrap();
        let mut b = L2gd::from_local_and_agg(0.3, 0.3, 0.5, 3, "qsgd:8", "natural").unwrap();
        let sa = a.run(&e, 60, 20).unwrap();
        let sb = b.run(&e, 60, 20).unwrap();
        for (ra, rb) in sa.records.iter().zip(&sb.records) {
            assert_eq!(ra.train_loss, rb.train_loss);
            assert_eq!(ra.bits_up, rb.bits_up);
        }
    }

    #[test]
    fn pipeline_and_ef_specs_run_end_to_end() {
        // the ISSUE's flagship spec: error feedback around a
        // sparsify-then-quantize chain, against a natural master
        let e = env(4, 6);
        let mut alg = L2gd::from_local_and_agg(0.4, 0.4, 0.5, 4,
                                               "ef(randk:10>qsgd:8)", "natural")
            .unwrap();
        let s = alg.run(&e, 120, 40).unwrap();
        let last = s.records.last().unwrap();
        assert!(last.comm_rounds > 0);
        assert!(last.bits_up > 0);
        assert!(last.personal_loss < s.records[0].personal_loss,
                "loss {} -> {}", s.records[0].personal_loss, last.personal_loss);
        // uplink is seed + 10 quantized survivors ≪ identity's 32·16 bits
        let up_per_client_round = last.bits_up as f64 / (4 * last.comm_rounds) as f64;
        assert!(up_per_client_round < 32.0 * 16.0 * 0.8,
                "bits/client/round = {up_per_client_round}");
    }

    #[test]
    fn oversized_sparsifier_fails_at_compress_time() {
        // d = 16 here, so randk:500 must surface a clean error from run()
        let e = env(3, 7);
        let mut alg = L2gd::from_local_and_agg(0.5, 0.3, 0.5, 3,
                                               "randk:500", "identity").unwrap();
        let err = alg.run(&e, 100, 100).expect_err("k > d must error");
        assert!(format!("{err:#}").contains("exceeds the dimension"), "{err:#}");
    }

    #[test]
    fn from_local_and_agg_roundtrip() {
        let alg = L2gd::from_local_and_agg(0.4, 0.05, 1.0, 10, "identity", "identity")
            .unwrap();
        assert!((alg.local_coef(10) - 0.05).abs() < 1e-12);
        assert!((alg.agg_coef(10) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn engine_stepping_matches_run() {
        // run() is a thin loop over the public engine API; driving the
        // engine by hand must land on the same state
        let e = env(3, 8);
        let alg = L2gd::from_local_and_agg(0.4, 0.3, 0.5, 3, "natural", "natural").unwrap();
        let mut manual = alg.engine(&e).unwrap();
        manual.run_steps(0, 80).unwrap();
        let rec_manual = manual.evaluate(80).unwrap();
        let mut alg2 = L2gd::from_local_and_agg(0.4, 0.3, 0.5, 3, "natural", "natural").unwrap();
        let s = alg2.run(&e, 80, 80).unwrap();
        let rec_run = s.records.last().unwrap();
        assert_eq!(rec_manual.train_loss, rec_run.train_loss);
        assert_eq!(rec_manual.personal_loss, rec_run.personal_loss);
        assert_eq!(rec_manual.bits_up, rec_run.bits_up);
    }

    #[test]
    fn large_n_tree_reduction_is_deterministic_and_close_to_serial() {
        // n > REDUCE_LEAF exercises the pooled tree reduction; the series
        // must be identical across pool sizes (fixed leaves) and the run
        // must still learn
        let run = |pool: usize| {
            let mut e = env(12, 9);
            e.pool = ThreadPool::new(pool);
            let mut alg = L2gd::from_local_and_agg(0.4, 0.3, 0.5, 12,
                                                   "identity", "identity").unwrap();
            alg.run(&e, 80, 40).unwrap()
        };
        let a = run(1);
        let b = run(8);
        for (ra, rb) in a.records.iter().zip(&b.records) {
            assert_eq!(ra.train_loss, rb.train_loss);
            assert_eq!(ra.personal_loss, rb.personal_loss);
        }
        assert!(a.records.last().unwrap().personal_loss
                < a.records[0].personal_loss);
    }
}
