//! # pfl — Personalized Federated Learning with Communication Compression
//!
//! Rust coordinator (L3) for the compressed-L2GD system of Bergou,
//! Burlachenko, Dutta & Richtárik (2022), executing JAX/Pallas-authored
//! compute (L2/L1) through AOT-compiled XLA artifacts via PJRT.
//! See DESIGN.md for the full system inventory and experiment index.
//!
//! The [`sim`] layer runs every registered algorithm over discrete-event
//! device fleets — synchronously round-by-round or asynchronously with
//! overlapping rounds and staleness-weighted buffered aggregation
//! ([`sim::async_runner`]) — and [`transport`] meters every message as a
//! byte-accurate wire frame, replayable over real TCP
//! ([`transport::loopback`]).

pub mod algorithms;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod protocol;
pub mod runtime;
pub mod sim;
pub mod theory;
pub mod transport;
pub mod util;
