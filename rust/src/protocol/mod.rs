//! Communication schedules: *when* the protocol communicates.
//!
//! The unified-formulation view (Hanzely & Richtárik 2020; Hanzely, Zhao,
//! Kolar 2021) treats L2GD and the fixed-cadence baselines as one
//! algorithm skeleton whose iterations differ only in the step kind dealt
//! per iteration. That dealer is the [`CommSchedule`] trait; the generic
//! round engine ([`crate::algorithms::engine::Engine`]) holds one and
//! asks it what iteration k must do:
//!
//! * [`Coin`] — **the paper's probabilistic protocol** (§III–IV). Each
//!   iteration flips ξ_k ~ Bernoulli(p). ξ = 0 ⇒ all devices take a local
//!   gradient step (no communication). ξ = 1 ⇒ an aggregation step, and
//!   **only the 0→1 transition communicates**: devices uplink compressed
//!   models, the master averages and downlinks a compressed anchor. A 1→1
//!   step reuses the cached anchor (the average of local models does not
//!   change across consecutive aggregation steps — §III). Algorithm 1
//!   initializes ξ₋₁ = 1 with x̄⁻¹ = mean of the (identical) initial
//!   models, so a first-step aggregation is a *cached* one.
//! * [`FixedCadence`] — the FedAvg/FedOpt baseline schedule: exactly `T`
//!   local steps, then one communicating aggregation, repeating forever.
//!   Never deals a cached aggregation (at aggregation coefficient 1 every
//!   fresh round resets clients onto the broadcast, so there is no cached
//!   anchor left to reuse — Figs 7–8's "FedAvg = L2GD at ηλ/np = 1 with a
//!   deterministic number of local steps").
//!
//! A second, orthogonal axis lives here too: the **dispatch discipline**
//! ([`AsyncSchedule`]). The [`CommSchedule`] decides *when* an algorithm
//! communicates; the dispatch discipline decides *how many* communicating
//! rounds may overlap in simulated time and how late (stale) arrivals are
//! weighted ([`StalenessWeight`]). [`AsyncSchedule::RoundSync`] is the
//! classical one-round-at-a-time regime every synchronous runner uses;
//! [`AsyncSchedule::Buffered`] is the FedBuff-style buffered-aggregation
//! regime driven by [`crate::sim::async_runner`]. Either discipline
//! composes with any schedule — L2GD's coin, FedAvg's cadence, and
//! FedOpt's server Adam all run under both.

use crate::sim::lang::SpecError;
use crate::util::Rng;

/// How an arriving update of staleness `s` (server versions advanced
/// between dispatch and apply) is weighted inside a buffered aggregate.
/// Weights are *relative*: the async runner normalizes them into a convex
/// combination, so the anchor stays a weighted average of client models
/// (the L2GD aggregation semantics survive unchanged; constant weights
/// reduce exactly to the synchronous uniform mean).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StalenessWeight {
    /// w(s) = 1 — staleness-blind (the synchronous-equivalent choice).
    Constant,
    /// w(s) = 1/(1+s) — the FedBuff default (Nguyen et al. 2022).
    Inverse,
    /// w(s) = (1+s)^(−α) — polynomial decay; α = 1 recovers `Inverse`.
    Polynomial { alpha: f64 },
}

impl StalenessWeight {
    /// The (unnormalized) weight of an update that is `s` versions stale.
    pub fn weight(&self, s: u64) -> f64 {
        match self {
            StalenessWeight::Constant => 1.0,
            StalenessWeight::Inverse => 1.0 / (1.0 + s as f64),
            StalenessWeight::Polynomial { alpha } => {
                (1.0 + s as f64).powf(-alpha)
            }
        }
    }

    /// Parse a weight spec: `const` | `inv` | `poly` (α = 0.5) |
    /// `poly:A`. Unknown names list what exists (registry-style UX).
    pub fn from_spec(spec: &str) -> anyhow::Result<StalenessWeight> {
        let lo = spec.len() - spec.trim_start().len();
        let hi = spec.trim_end().len();
        Ok(Self::parse_at(spec, lo..hi.max(lo))?)
    }

    /// Parse the weight spec living at `span` inside `src`, reporting
    /// errors as span-pointing [`SpecError`]s against the *whole* source
    /// string — the scenario parser hands in the full spec so the caret
    /// lands on the offending `stale=` value, while [`Self::from_spec`]
    /// passes the bare weight spec.
    pub fn parse_at(
        src: &str,
        span: std::ops::Range<usize>,
    ) -> Result<StalenessWeight, SpecError> {
        let spec = &src[span.clone()];
        let (name, arg) = match spec.split_once(':') {
            Some((n, a)) => {
                let arg_lo = span.start + n.len() + 1;
                (n.trim(), Some((a.trim(), arg_lo..span.end)))
            }
            None => (spec.trim(), None),
        };
        // clone into the scrutinee: the fallthrough arm still needs `arg`
        match (name, arg.clone()) {
            ("const", None) => Ok(StalenessWeight::Constant),
            ("inv", None) => Ok(StalenessWeight::Inverse),
            ("poly", arg) => {
                let alpha = match &arg {
                    None => 0.5,
                    Some((a, a_span)) => a.parse::<f64>().map_err(|e| {
                        SpecError::new(
                            src,
                            a_span.clone(),
                            format!("stale=poly:{a}: {e}"),
                        )
                    })?,
                };
                if !(alpha.is_finite() && alpha > 0.0) {
                    let at = arg.map_or(span.clone(), |(_, s)| s);
                    return Err(SpecError::new(
                        src,
                        at,
                        format!(
                            "poly staleness exponent {alpha} must be \
                             positive and finite"
                        ),
                    ));
                }
                Ok(StalenessWeight::Polynomial { alpha })
            }
            _ => {
                let help = match (&arg, name) {
                    (Some(_), "const" | "inv") => {
                        Some(format!("`{name}` takes no argument"))
                    }
                    _ => crate::sim::lang::suggest(name, ["const", "inv", "poly"])
                        .map(|s| format!("did you mean `{s}`?")),
                };
                Err(SpecError::new(
                    src,
                    span,
                    format!(
                        "unknown staleness weight `{spec}` (known: const, \
                         inv, poly, poly:ALPHA)"
                    ),
                )
                .maybe_help(help))
            }
        }
    }

    /// Canonical spec string (`from_spec(w.spec())` round-trips).
    pub fn spec(&self) -> String {
        match self {
            StalenessWeight::Constant => "const".into(),
            StalenessWeight::Inverse => "inv".into(),
            StalenessWeight::Polynomial { alpha } => format!("poly:{alpha}"),
        }
    }
}

/// When a buffered-aggregation buffer closes. Historically "per-cohort"
/// was spelled as the sentinel `buffer: 0` while `buffer=0` was rejected
/// as invalid input — the same value meaning both "per-round closes" and
/// "illegal" made every printed spec unparseable. The explicit enum
/// removes the collision: `Cohort` prints as `buffer=cohort`, and an
/// update-count target is a [`NonZeroUsize`] by construction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BufferPolicy {
    /// Close each dispatched cohort's round on its own quorum — the
    /// synchronous-equivalent buffering (`buffer=cohort`).
    Cohort,
    /// Aggregate once this many updates accumulate, regardless of which
    /// cohort they came from (`buffer=K`, K ≥ 1).
    Updates(std::num::NonZeroUsize),
}

impl BufferPolicy {
    /// The update-count target, or `None` for per-cohort closes.
    pub fn target(&self) -> Option<usize> {
        match self {
            BufferPolicy::Cohort => None,
            BufferPolicy::Updates(k) => Some(k.get()),
        }
    }

    /// The `buffer=` value this policy prints as (round-trips through
    /// the scenario parser).
    pub fn spec(&self) -> String {
        match self {
            BufferPolicy::Cohort => "cohort".into(),
            BufferPolicy::Updates(k) => k.to_string(),
        }
    }
}

/// The dispatch discipline: how many communicating rounds overlap and how
/// a filled buffer aggregates. Orthogonal to [`CommSchedule`] — see the
/// module docs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AsyncSchedule {
    /// One round at a time: a communication event fully completes (or
    /// aborts) before the next cohort is drawn. The synchronous
    /// `FleetSim` regime.
    RoundSync,
    /// FedBuff-style buffered aggregation: up to `max_in_flight` cohorts
    /// overlap, each dispatched model stamped with the server version;
    /// arrivals accumulate into a buffer that aggregates
    /// staleness-weighted per the [`BufferPolicy`]. Updates staler than
    /// `max_stale` versions are discarded (metered as wasted stale
    /// traffic).
    Buffered {
        /// when the buffer aggregates: per-cohort or every K updates
        buffer: BufferPolicy,
        /// overlapping dispatched cohorts allowed, ≥ 1
        max_in_flight: usize,
        /// relative weight of an `s`-stale update in the aggregate
        stale: StalenessWeight,
        /// discard updates staler than this many server versions
        /// (`u64::MAX` = no cutoff, spelled `max_stale=none`)
        max_stale: u64,
    },
}

impl AsyncSchedule {
    /// True for any discipline other than the synchronous one.
    pub fn is_async(&self) -> bool {
        !matches!(self, AsyncSchedule::RoundSync)
    }
}

/// What iteration k must do.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepKind {
    /// ξ_k = 0: local gradient step on every device
    Local,
    /// ξ_k = 1, ξ_{k−1} = 0: communicate (uplink C_i(x_i), downlink C_M(ȳ))
    AggregateFresh,
    /// ξ_k = 1, ξ_{k−1} = 1: aggregation toward the cached anchor, no comm
    AggregateCached,
}

/// A pluggable per-iteration step dealer — the "communication schedule"
/// axis of the unified algorithm family. Implementations must be
/// deterministic given their construction seed (the simulator replays
/// runs bit-exactly) and must account every draw in their [`CoinStats`].
pub trait CommSchedule: Send {
    /// Deal the kind of iteration k (advances internal state).
    fn draw(&mut self) -> StepKind;

    /// Running step-kind counts (every draw accounted).
    fn stats(&self) -> &CoinStats;
}

/// The ξ coin with transition tracking.
#[derive(Clone, Debug)]
pub struct Coin {
    p: f64,
    prev: bool, // ξ_{k-1}; Algorithm 1 starts with ξ_{-1} = 1
    rng: Rng,
    pub stats: CoinStats,
}

#[derive(Clone, Debug, Default)]
pub struct CoinStats {
    pub locals: u64,
    pub fresh: u64,
    pub cached: u64,
}

impl CoinStats {
    pub fn total(&self) -> u64 {
        self.locals + self.fresh + self.cached
    }
}

impl Coin {
    pub fn new(p: f64, seed: u64) -> Coin {
        assert!((0.0..=1.0).contains(&p), "p must be a probability, got {p}");
        Coin { p, prev: true, rng: Rng::new(seed), stats: CoinStats::default() }
    }

    pub fn p(&self) -> f64 {
        self.p
    }

    /// Draw ξ_k and classify the step.
    pub fn draw(&mut self) -> StepKind {
        let xi = self.rng.bernoulli(self.p);
        let kind = match (self.prev, xi) {
            (_, false) => StepKind::Local,
            (false, true) => StepKind::AggregateFresh,
            (true, true) => StepKind::AggregateCached,
        };
        self.prev = xi;
        match kind {
            StepKind::Local => self.stats.locals += 1,
            StepKind::AggregateFresh => self.stats.fresh += 1,
            StepKind::AggregateCached => self.stats.cached += 1,
        }
        kind
    }

    /// Expected fraction of communicating steps: P(ξ_k=1, ξ_{k−1}=0) = p(1−p).
    pub fn expected_comm_rate(&self) -> f64 {
        self.p * (1.0 - self.p)
    }

    /// Expected number of local steps between communications: (1−p)/p·…
    /// — the paper's "random number of local steps" view (e.g. p = 0.5 ⇒
    /// FedAvg-like with an average of 3 steps per round, §VII-B).
    pub fn expected_steps_per_comm(&self) -> f64 {
        1.0 / self.expected_comm_rate()
    }
}

impl CommSchedule for Coin {
    fn draw(&mut self) -> StepKind {
        Coin::draw(self)
    }

    fn stats(&self) -> &CoinStats {
        &self.stats
    }
}

/// The fixed local-epoch cadence of the FedAvg/FedOpt baselines: `T`
/// local steps, then one communicating aggregation, repeating. One
/// "round" therefore spans `T + 1` engine iterations. Deterministic —
/// no seed, no RNG draws.
#[derive(Clone, Debug)]
pub struct FixedCadence {
    local_steps: u64,
    /// iterations dealt so far
    pos: u64,
    pub stats: CoinStats,
}

impl FixedCadence {
    pub fn new(local_steps: u64) -> FixedCadence {
        assert!(local_steps > 0, "a round needs at least one local step");
        FixedCadence { local_steps, pos: 0, stats: CoinStats::default() }
    }

    pub fn local_steps(&self) -> u64 {
        self.local_steps
    }

    /// Engine iterations per communication round (`T + 1`).
    pub fn round_len(&self) -> u64 {
        self.local_steps + 1
    }
}

impl CommSchedule for FixedCadence {
    fn draw(&mut self) -> StepKind {
        self.pos += 1;
        let kind = if self.pos % (self.local_steps + 1) == 0 {
            StepKind::AggregateFresh
        } else {
            StepKind::Local
        };
        match kind {
            StepKind::Local => self.stats.locals += 1,
            StepKind::AggregateFresh => self.stats.fresh += 1,
            StepKind::AggregateCached => unreachable!(),
        }
        kind
    }

    fn stats(&self) -> &CoinStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frequencies_match_p() {
        let mut coin = Coin::new(0.3, 1);
        let k = 100_000;
        for _ in 0..k {
            coin.draw();
        }
        let s = &coin.stats;
        assert_eq!(s.total(), k);
        let agg = (s.fresh + s.cached) as f64 / k as f64;
        assert!((agg - 0.3).abs() < 0.01, "agg rate {agg}");
        // fresh transitions occur at rate p(1−p) = 0.21
        let fresh = s.fresh as f64 / k as f64;
        assert!((fresh - 0.21).abs() < 0.01, "fresh rate {fresh}");
    }

    #[test]
    fn p_zero_never_communicates() {
        let mut coin = Coin::new(0.0, 2);
        for _ in 0..1000 {
            assert_eq!(coin.draw(), StepKind::Local);
        }
    }

    #[test]
    fn p_one_communicates_once_then_cached() {
        // ξ₋₁ = 1 and ξ_k ≡ 1 ⇒ every step is a cached aggregate:
        // the average never changes, no communication at all (§III).
        let mut coin = Coin::new(1.0, 3);
        for _ in 0..100 {
            assert_eq!(coin.draw(), StepKind::AggregateCached);
        }
    }

    #[test]
    fn first_aggregate_after_local_is_fresh() {
        let mut coin = Coin::new(0.5, 0);
        let mut prev = StepKind::AggregateCached; // ξ₋₁ = 1 effect
        let mut seen_fresh = false;
        for _ in 0..200 {
            let k = coin.draw();
            if k == StepKind::AggregateFresh {
                assert_eq!(prev, StepKind::Local);
                seen_fresh = true;
            }
            if k == StepKind::AggregateCached && prev == StepKind::Local {
                panic!("0→1 transition must be Fresh");
            }
            prev = k;
        }
        assert!(seen_fresh);
    }

    #[test]
    fn deterministic_by_seed() {
        let mut a = Coin::new(0.4, 7);
        let mut b = Coin::new(0.4, 7);
        for _ in 0..500 {
            assert_eq!(a.draw(), b.draw());
        }
    }

    #[test]
    fn expected_rates() {
        let coin = Coin::new(0.5, 0);
        assert!((coin.expected_comm_rate() - 0.25).abs() < 1e-12);
        assert!((coin.expected_steps_per_comm() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn fixed_cadence_deals_t_locals_then_fresh() {
        let mut s = FixedCadence::new(3);
        for round in 0..5 {
            for j in 0..3 {
                assert_eq!(CommSchedule::draw(&mut s), StepKind::Local,
                           "round {round} draw {j}");
            }
            assert_eq!(CommSchedule::draw(&mut s), StepKind::AggregateFresh,
                       "round {round}");
        }
        assert_eq!(s.stats.locals, 15);
        assert_eq!(s.stats.fresh, 5);
        assert_eq!(s.stats.cached, 0);
        assert_eq!(s.stats().total(), 20, "every draw accounted");
        assert_eq!(s.round_len(), 4);
    }

    #[test]
    fn coin_implements_comm_schedule() {
        // the trait surface deals the same stream as the inherent methods
        let mut a = Coin::new(0.4, 7);
        let mut b = Coin::new(0.4, 7);
        for _ in 0..200 {
            let dyn_b: &mut dyn CommSchedule = &mut b;
            assert_eq!(a.draw(), dyn_b.draw());
        }
        assert_eq!(CommSchedule::stats(&a).total(), 200);
    }

    /// Statistical check across a p grid: the empirical fraction of
    /// communicating (fresh) steps over many draws must match
    /// `expected_comm_rate()` = p(1−p), and the stats must account for
    /// every draw. Fresh transitions form a Markov chain, not an iid
    /// sequence, so the tolerance is a generous multiple of the iid
    /// binomial σ (deterministic seeds keep the test reproducible).
    #[test]
    fn empirical_comm_rate_matches_expected() {
        let draws: u64 = 200_000;
        for (i, &p) in [0.1, 0.3, 0.5, 0.65, 0.9].iter().enumerate() {
            let mut coin = Coin::new(p, 1_000 + i as u64);
            for _ in 0..draws {
                coin.draw();
            }
            assert_eq!(coin.stats.total(), draws,
                       "p={p}: stats must count every draw");
            let expected = coin.expected_comm_rate();
            let empirical = coin.stats.fresh as f64 / draws as f64;
            let sigma = (expected * (1.0 - expected) / draws as f64).sqrt();
            let tol = (8.0 * sigma).max(2e-3);
            assert!((empirical - expected).abs() < tol,
                    "p={p}: comm rate {empirical:.5} vs expected \
                     {expected:.5} (tol {tol:.5})");
            // aggregate rate (fresh + cached) matches p too
            let agg = (coin.stats.fresh + coin.stats.cached) as f64 / draws as f64;
            assert!((agg - p).abs() < tol.max(3e-3),
                    "p={p}: aggregate rate {agg:.5}");
        }
    }

    #[test]
    fn staleness_weights_evaluate_and_decay() {
        assert_eq!(StalenessWeight::Constant.weight(0), 1.0);
        assert_eq!(StalenessWeight::Constant.weight(100), 1.0);
        assert_eq!(StalenessWeight::Inverse.weight(0), 1.0);
        assert_eq!(StalenessWeight::Inverse.weight(3), 0.25);
        let poly = StalenessWeight::Polynomial { alpha: 2.0 };
        assert_eq!(poly.weight(0), 1.0);
        assert!((poly.weight(1) - 0.25).abs() < 1e-12);
        // poly at α = 1 recovers inverse
        let p1 = StalenessWeight::Polynomial { alpha: 1.0 };
        for s in [0u64, 1, 5, 40] {
            assert!((p1.weight(s) - StalenessWeight::Inverse.weight(s)).abs()
                        < 1e-12, "s={s}");
        }
        // every weight is positive and non-increasing in s
        for w in [StalenessWeight::Constant, StalenessWeight::Inverse, poly] {
            let mut prev = f64::INFINITY;
            for s in 0..50u64 {
                let v = w.weight(s);
                assert!(v > 0.0 && v <= prev, "{w:?} at s={s}");
                prev = v;
            }
        }
    }

    #[test]
    fn staleness_weight_specs_round_trip() {
        for spec in ["const", "inv", "poly", "poly:1.5"] {
            let w = StalenessWeight::from_spec(spec).unwrap();
            assert_eq!(StalenessWeight::from_spec(&w.spec()).unwrap(), w,
                       "{spec}");
        }
        assert_eq!(StalenessWeight::from_spec("const").unwrap(),
                   StalenessWeight::Constant);
        assert_eq!(StalenessWeight::from_spec("inv").unwrap(),
                   StalenessWeight::Inverse);
        assert_eq!(StalenessWeight::from_spec("poly:2").unwrap(),
                   StalenessWeight::Polynomial { alpha: 2.0 });
        // unknown names list what exists
        let err = format!("{:#}", StalenessWeight::from_spec("linear").unwrap_err());
        assert!(err.contains("unknown staleness weight"), "{err}");
        for known in ["const", "inv", "poly"] {
            assert!(err.contains(known), "{err}");
        }
        assert!(StalenessWeight::from_spec("poly:0").is_err());
        assert!(StalenessWeight::from_spec("poly:nope").is_err());
        assert!(StalenessWeight::from_spec("const:1").is_err());
    }

    #[test]
    fn async_schedule_classifies() {
        assert!(!AsyncSchedule::RoundSync.is_async());
        let b = AsyncSchedule::Buffered {
            buffer: BufferPolicy::Updates(std::num::NonZeroUsize::new(8).unwrap()),
            max_in_flight: 4,
            stale: StalenessWeight::Inverse,
            max_stale: 16,
        };
        assert!(b.is_async());
    }

    #[test]
    fn buffer_policy_targets_and_specs() {
        assert_eq!(BufferPolicy::Cohort.target(), None);
        assert_eq!(BufferPolicy::Cohort.spec(), "cohort");
        let k = BufferPolicy::Updates(std::num::NonZeroUsize::new(6).unwrap());
        assert_eq!(k.target(), Some(6));
        assert_eq!(k.spec(), "6");
    }

    #[test]
    fn staleness_weight_errors_carry_spans() {
        // parse error points at the alpha argument inside the full
        // scenario source handed in by the scenario parser
        let src = "uniform:stale=poly:nope";
        let err = StalenessWeight::parse_at(src, 14..src.len()).unwrap_err();
        assert_eq!(err.span(), 19..23);
        let rendered = err.to_string();
        assert!(rendered.contains("^^^^"), "{rendered}");

        // unknown name spans the whole weight spec and suggests
        let err = StalenessWeight::parse_at("inx", 0..3).unwrap_err();
        assert_eq!(err.span(), 0..3);
        assert!(err.to_string().contains("did you mean `inv`?"), "{err}");
    }
}
