//! Simulated master↔client transport with exact bit metering.
//!
//! The paper's headline metric is communicated data volume (bits/n). All
//! algorithm communication is routed through `Network`, which records the
//! exact encoded payload bits per direction per client, keeps an optional
//! event trace (the Fig 2-style communication pattern), and projects
//! wall-clock time under a configurable latency/bandwidth model — the
//! "constant speed network" hypothesis the paper cites for why fewer bits
//! mean faster training.

/// One communication event (for protocol traces / Fig 2).
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// client → master payload
    Up { step: u64, client: usize, bits: u64 },
    /// master → one client payload
    Down { step: u64, client: usize, bits: u64 },
}

#[derive(Clone, Debug, Default)]
pub struct LinkStats {
    pub bits_up: u64,
    pub bits_down: u64,
    pub msgs_up: u64,
    pub msgs_down: u64,
}

/// Simple time model: every communication round costs one latency plus the
/// serialized transfer of its largest link payload (synchronous rounds).
#[derive(Clone, Debug)]
pub struct TimeModel {
    pub latency_s: f64,
    pub bandwidth_bps: f64,
}

impl Default for TimeModel {
    fn default() -> Self {
        // a modest cross-device FL uplink: 20 ms RTT, 10 Mbit/s
        TimeModel { latency_s: 0.02, bandwidth_bps: 10e6 }
    }
}

pub struct Network {
    links: Vec<LinkStats>,
    pub trace: Option<Vec<Event>>,
    time_model: TimeModel,
    sim_time_s: f64,
    comm_rounds: u64,
    round_max_bits: u64,
    in_round: bool,
}

impl Network {
    pub fn new(n_clients: usize) -> Network {
        Network {
            links: vec![LinkStats::default(); n_clients],
            trace: None,
            time_model: TimeModel::default(),
            sim_time_s: 0.0,
            comm_rounds: 0,
            round_max_bits: 0,
            in_round: false,
        }
    }

    pub fn with_trace(mut self) -> Network {
        self.trace = Some(Vec::new());
        self
    }

    pub fn with_time_model(mut self, tm: TimeModel) -> Network {
        self.time_model = tm;
        self
    }

    pub fn n_clients(&self) -> usize {
        self.links.len()
    }

    /// Begin a synchronous communication round (latency accounting).
    pub fn begin_round(&mut self) {
        assert!(!self.in_round, "nested communication round");
        self.in_round = true;
        self.comm_rounds += 1;
        self.round_max_bits = 0;
    }

    /// Finish the round: advance simulated time by latency + slowest link.
    pub fn end_round(&mut self) {
        assert!(self.in_round, "end_round without begin_round");
        self.in_round = false;
        self.sim_time_s += self.time_model.latency_s
            + self.round_max_bits as f64 / self.time_model.bandwidth_bps;
    }

    /// Record a client → master payload of exactly `bits`.
    pub fn uplink(&mut self, step: u64, client: usize, bits: u64) {
        debug_assert!(self.in_round, "uplink outside a round");
        let l = &mut self.links[client];
        l.bits_up += bits;
        l.msgs_up += 1;
        self.round_max_bits = self.round_max_bits.max(bits);
        if let Some(t) = &mut self.trace {
            t.push(Event::Up { step, client, bits });
        }
    }

    /// Record a master → all-clients broadcast; each link pays `bits`.
    pub fn downlink_broadcast(&mut self, step: u64, bits: u64) {
        debug_assert!(self.in_round, "downlink outside a round");
        for (client, l) in self.links.iter_mut().enumerate() {
            l.bits_down += bits;
            l.msgs_down += 1;
            if let Some(t) = &mut self.trace {
                t.push(Event::Down { step, client, bits });
            }
        }
        self.round_max_bits = self.round_max_bits.max(bits);
    }

    pub fn link(&self, client: usize) -> &LinkStats {
        &self.links[client]
    }

    pub fn total_bits(&self) -> u64 {
        self.links.iter().map(|l| l.bits_up + l.bits_down).sum()
    }

    pub fn total_bits_up(&self) -> u64 {
        self.links.iter().map(|l| l.bits_up).sum()
    }

    pub fn total_bits_down(&self) -> u64 {
        self.links.iter().map(|l| l.bits_down).sum()
    }

    /// The paper's metric: total communicated bits normalized by n.
    pub fn bits_per_client(&self) -> f64 {
        self.total_bits() as f64 / self.links.len() as f64
    }

    pub fn comm_rounds(&self) -> u64 {
        self.comm_rounds
    }

    /// Projected wall-clock spent communicating under the time model.
    pub fn simulated_comm_time_s(&self) -> f64 {
        self.sim_time_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meters_per_link() {
        let mut net = Network::new(3);
        net.begin_round();
        net.uplink(0, 0, 100);
        net.uplink(0, 1, 200);
        net.downlink_broadcast(0, 50);
        net.end_round();
        assert_eq!(net.link(0).bits_up, 100);
        assert_eq!(net.link(1).bits_up, 200);
        assert_eq!(net.link(2).bits_up, 0);
        assert_eq!(net.link(2).bits_down, 50);
        assert_eq!(net.total_bits(), 100 + 200 + 3 * 50);
        assert_eq!(net.bits_per_client(), 450.0 / 3.0);
        assert_eq!(net.comm_rounds(), 1);
    }

    #[test]
    fn trace_records_events() {
        let mut net = Network::new(2).with_trace();
        net.begin_round();
        net.uplink(7, 1, 9);
        net.downlink_broadcast(7, 4);
        net.end_round();
        let t = net.trace.as_ref().unwrap();
        assert_eq!(t[0], Event::Up { step: 7, client: 1, bits: 9 });
        assert_eq!(t.len(), 3); // 1 up + 2 down
    }

    #[test]
    fn time_model_latency_plus_slowest_link() {
        let mut net = Network::new(2)
            .with_time_model(TimeModel { latency_s: 0.01, bandwidth_bps: 1000.0 });
        net.begin_round();
        net.uplink(0, 0, 500); // 0.5 s at 1 kbps
        net.uplink(0, 1, 100);
        net.end_round();
        assert!((net.simulated_comm_time_s() - 0.51).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn nested_rounds_panic() {
        let mut net = Network::new(1);
        net.begin_round();
        net.begin_round();
    }
}
