//! Simulated master↔client transport with exact bit metering.
//!
//! The paper's headline metric is communicated data volume (bits/n). All
//! algorithm communication is routed through `Network`, which records the
//! exact encoded payload bits per direction per client, keeps an optional
//! event trace (the Fig 2-style communication pattern), and projects
//! wall-clock time under a configurable latency/bandwidth model — the
//! "constant speed network" hypothesis the paper cites for why fewer bits
//! mean faster training.
//!
//! [`loopback`] drives the same [`frame`] codec over a real localhost
//! socket and pins the kernel-observed byte counts to this module's
//! simulated metering.

pub mod frame;
pub mod loopback;

/// One communication event (for protocol traces / Fig 2).
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// client → master payload
    Up { step: u64, client: usize, bits: u64 },
    /// master → one client payload
    Down { step: u64, client: usize, bits: u64 },
}

#[derive(Clone, Debug, Default)]
pub struct LinkStats {
    pub bits_up: u64,
    pub bits_down: u64,
    pub msgs_up: u64,
    pub msgs_down: u64,
    /// subset of `bits_up` the master discarded as straggler traffic
    /// (missed quorum or deadline)
    pub bits_up_wasted: u64,
    /// subset of `bits_up` the master discarded as too stale (async
    /// buffered aggregation past `max_stale`)
    pub bits_up_stale: u64,
}

/// What happened to an uplink at the master — drives the goodput
/// attribution (`wasted`/`stale` bits still count toward `bits_up`: the
/// bytes crossed the network either way).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum UplinkKind {
    Applied,
    Wasted,
    Stale,
}

/// Simple time model: every communication round costs one latency plus the
/// serialized transfer of its largest link payload (synchronous rounds).
#[derive(Clone, Debug)]
pub struct TimeModel {
    pub latency_s: f64,
    pub bandwidth_bps: f64,
}

impl Default for TimeModel {
    fn default() -> Self {
        // a modest cross-device FL uplink: 20 ms RTT, 10 Mbit/s
        TimeModel { latency_s: 0.02, bandwidth_bps: 10e6 }
    }
}

pub struct Network {
    /// one attribution bucket per client (`shard_size == 1`, dense
    /// stores) or per client *shard* (copy-on-write stores at fleet
    /// scale, where a million per-client buckets would reintroduce O(n)
    /// memory into a path that is otherwise O(cohort)). The generic
    /// engine picks the granularity from
    /// `crate::model::ClientStore::link_shard_size`, so every fleet
    /// algorithm meters through the same layout.
    links: Vec<LinkStats>,
    n_clients: usize,
    /// clients per attribution bucket
    shard_size: usize,
    pub trace: Option<Vec<Event>>,
    time_model: TimeModel,
    sim_time_s: f64,
    comm_rounds: u64,
    round_max_bits: u64,
    in_round: bool,
    /// uplinks seen in the round currently open
    round_uplinks: u64,
    /// uplink count of the last completed round (the round's cohort size)
    last_round_participants: u64,
}

impl Network {
    pub fn new(n_clients: usize) -> Network {
        Network::sharded(n_clients, 1)
    }

    /// A network whose `LinkStats` are attributed per contiguous
    /// `shard_size`-client shard instead of per client. Totals, round
    /// accounting and the time model are identical to the per-client
    /// layout; only the attribution granularity coarsens.
    pub fn sharded(n_clients: usize, shard_size: usize) -> Network {
        assert!(shard_size > 0, "shard_size must be positive");
        Network {
            links: vec![LinkStats::default(); n_clients.div_ceil(shard_size)],
            n_clients,
            shard_size,
            trace: None,
            time_model: TimeModel::default(),
            sim_time_s: 0.0,
            comm_rounds: 0,
            round_max_bits: 0,
            in_round: false,
            round_uplinks: 0,
            last_round_participants: 0,
        }
    }

    /// The attribution bucket for `client`.
    #[inline]
    fn bucket(&self, client: usize) -> usize {
        client / self.shard_size
    }

    pub fn with_trace(mut self) -> Network {
        self.trace = Some(Vec::new());
        self
    }

    pub fn with_time_model(mut self, tm: TimeModel) -> Network {
        self.time_model = tm;
        self
    }

    pub fn n_clients(&self) -> usize {
        self.n_clients
    }

    /// Number of attribution buckets (`n_clients` when `shard_size` is 1).
    pub fn n_shards(&self) -> usize {
        self.links.len()
    }

    /// Clients per attribution bucket.
    pub fn shard_size(&self) -> usize {
        self.shard_size
    }

    /// Begin a synchronous communication round (latency accounting).
    pub fn begin_round(&mut self) {
        assert!(!self.in_round, "nested communication round");
        self.in_round = true;
        self.comm_rounds += 1;
        self.round_max_bits = 0;
        self.round_uplinks = 0;
    }

    /// Finish the round: advance simulated time by latency + slowest link.
    pub fn end_round(&mut self) {
        assert!(self.in_round, "end_round without begin_round");
        self.in_round = false;
        self.last_round_participants = self.round_uplinks;
        self.sim_time_s += self.time_model.latency_s
            + self.round_max_bits as f64 / self.time_model.bandwidth_bps;
    }

    /// Shared uplink metering: bits, message count, trace, and the
    /// goodput attribution by `kind`.
    fn record_uplink(&mut self, step: u64, client: usize, bits: u64,
                     kind: UplinkKind) {
        let b = self.bucket(client);
        let l = &mut self.links[b];
        l.bits_up += bits;
        l.msgs_up += 1;
        match kind {
            UplinkKind::Applied => {}
            UplinkKind::Wasted => l.bits_up_wasted += bits,
            UplinkKind::Stale => l.bits_up_stale += bits,
        }
        if let Some(t) = &mut self.trace {
            t.push(Event::Up { step, client, bits });
        }
    }

    /// Record a client → master payload of exactly `bits`.
    pub fn uplink(&mut self, step: u64, client: usize, bits: u64) {
        debug_assert!(self.in_round, "uplink outside a round");
        self.round_max_bits = self.round_max_bits.max(bits);
        self.round_uplinks += 1;
        self.record_uplink(step, client, bits, UplinkKind::Applied);
    }

    /// Record a client → master payload the master *discarded* (a
    /// straggler that missed the quorum or deadline). The bytes crossed
    /// the network, so they meter like any uplink — but the sender does
    /// not count toward the round's participants.
    pub fn uplink_wasted(&mut self, step: u64, client: usize, bits: u64) {
        debug_assert!(self.in_round, "uplink outside a round");
        self.round_max_bits = self.round_max_bits.max(bits);
        self.record_uplink(step, client, bits, UplinkKind::Wasted);
    }

    /// Straggler traffic discarded *outside* any synchronous round — the
    /// async runner's overlapping cohorts close independently of the
    /// engine's round brackets, so their discards must not perturb
    /// `comm_rounds` or the last round's participant count.
    pub fn offround_uplink_wasted(&mut self, step: u64, client: usize,
                                  bits: u64) {
        self.record_uplink(step, client, bits, UplinkKind::Wasted);
    }

    /// An uplink the async master discarded as too stale (the dispatch's
    /// server version fell more than `max_stale` behind) — off-round, like
    /// [`Network::offround_uplink_wasted`].
    pub fn offround_uplink_stale(&mut self, step: u64, client: usize,
                                 bits: u64) {
        self.record_uplink(step, client, bits, UplinkKind::Stale);
    }

    /// Record a master → one-client payload of exactly `bits` (the fleet
    /// simulator's cohort downlink: offline clients receive nothing).
    pub fn downlink(&mut self, step: u64, client: usize, bits: u64) {
        debug_assert!(self.in_round, "downlink outside a round");
        let b = self.bucket(client);
        let l = &mut self.links[b];
        l.bits_down += bits;
        l.msgs_down += 1;
        self.round_max_bits = self.round_max_bits.max(bits);
        if let Some(t) = &mut self.trace {
            t.push(Event::Down { step, client, bits });
        }
    }

    /// Record a master → all-clients broadcast; each link pays `bits`.
    pub fn downlink_broadcast(&mut self, step: u64, bits: u64) {
        for client in 0..self.n_clients {
            self.downlink(step, client, bits);
        }
    }

    /// Attribution stats for `client`'s bucket (exactly this client when
    /// `shard_size` is 1; its shard otherwise).
    pub fn link(&self, client: usize) -> &LinkStats {
        &self.links[self.bucket(client)]
    }

    /// Attribution stats of shard `s` directly.
    pub fn shard_link(&self, s: usize) -> &LinkStats {
        &self.links[s]
    }

    pub fn total_bits(&self) -> u64 {
        self.links.iter().map(|l| l.bits_up + l.bits_down).sum()
    }

    pub fn total_bits_up(&self) -> u64 {
        self.links.iter().map(|l| l.bits_up).sum()
    }

    pub fn total_bits_down(&self) -> u64 {
        self.links.iter().map(|l| l.bits_down).sum()
    }

    /// Uplink bits discarded as straggler traffic (subset of
    /// `total_bits_up`).
    pub fn total_bits_up_wasted(&self) -> u64 {
        self.links.iter().map(|l| l.bits_up_wasted).sum()
    }

    /// Uplink bits discarded as stale (subset of `total_bits_up`).
    pub fn total_bits_up_stale(&self) -> u64 {
        self.links.iter().map(|l| l.bits_up_stale).sum()
    }

    /// Uplink bits the master actually aggregated.
    pub fn total_bits_up_applied(&self) -> u64 {
        self.total_bits_up() - self.total_bits_up_wasted()
            - self.total_bits_up_stale()
    }

    /// Goodput: applied uplink bits / total uplink bits, in [0, 1]
    /// (1.0 on a silent network — nothing transmitted, nothing wasted).
    pub fn uplink_goodput(&self) -> f64 {
        let total = self.total_bits_up();
        if total == 0 {
            return 1.0;
        }
        self.total_bits_up_applied() as f64 / total as f64
    }

    /// The paper's metric: total communicated bits normalized by n.
    pub fn bits_per_client(&self) -> f64 {
        self.total_bits() as f64 / self.n_clients as f64
    }

    pub fn comm_rounds(&self) -> u64 {
        self.comm_rounds
    }

    /// Uplink count of the last completed round — the cohort size under
    /// partial participation (0 before any round completes).
    pub fn last_round_participants(&self) -> u64 {
        self.last_round_participants
    }

    /// Projected wall-clock spent communicating under the time model.
    pub fn simulated_comm_time_s(&self) -> f64 {
        self.sim_time_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meters_per_link() {
        let mut net = Network::new(3);
        net.begin_round();
        net.uplink(0, 0, 100);
        net.uplink(0, 1, 200);
        net.downlink_broadcast(0, 50);
        net.end_round();
        assert_eq!(net.link(0).bits_up, 100);
        assert_eq!(net.link(1).bits_up, 200);
        assert_eq!(net.link(2).bits_up, 0);
        assert_eq!(net.link(2).bits_down, 50);
        assert_eq!(net.total_bits(), 100 + 200 + 3 * 50);
        assert_eq!(net.bits_per_client(), 450.0 / 3.0);
        assert_eq!(net.comm_rounds(), 1);
    }

    #[test]
    fn trace_records_events() {
        let mut net = Network::new(2).with_trace();
        net.begin_round();
        net.uplink(7, 1, 9);
        net.downlink_broadcast(7, 4);
        net.end_round();
        let t = net.trace.as_ref().unwrap();
        assert_eq!(t[0], Event::Up { step: 7, client: 1, bits: 9 });
        assert_eq!(t.len(), 3); // 1 up + 2 down
    }

    #[test]
    fn time_model_latency_plus_slowest_link() {
        let mut net = Network::new(2)
            .with_time_model(TimeModel { latency_s: 0.01, bandwidth_bps: 1000.0 });
        net.begin_round();
        net.uplink(0, 0, 500); // 0.5 s at 1 kbps
        net.uplink(0, 1, 100);
        net.end_round();
        assert!((net.simulated_comm_time_s() - 0.51).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn nested_rounds_panic() {
        let mut net = Network::new(1);
        net.begin_round();
        net.begin_round();
    }

    /// Satellite coverage: uplink/downlink totals and per-client
    /// attribution over several rounds, mixing the broadcast and
    /// per-client downlink paths.
    #[test]
    fn per_direction_totals_and_attribution() {
        let mut net = Network::new(3);
        net.begin_round();
        net.uplink(1, 0, 100);
        net.uplink(1, 2, 300);
        net.downlink(1, 0, 40);
        net.downlink(1, 2, 40);
        net.end_round();
        assert_eq!(net.last_round_participants(), 2);
        net.begin_round();
        net.uplink(5, 1, 700);
        net.downlink_broadcast(5, 60);
        net.end_round();
        assert_eq!(net.last_round_participants(), 1);

        assert_eq!(net.total_bits_up(), 100 + 300 + 700);
        assert_eq!(net.total_bits_down(), 40 + 40 + 3 * 60);
        assert_eq!(net.total_bits(), net.total_bits_up() + net.total_bits_down());
        // per-client attribution
        assert_eq!(net.link(0).bits_up, 100);
        assert_eq!(net.link(0).bits_down, 40 + 60);
        assert_eq!(net.link(0).msgs_up, 1);
        assert_eq!(net.link(0).msgs_down, 2);
        assert_eq!(net.link(1).bits_up, 700);
        assert_eq!(net.link(1).bits_down, 60);
        assert_eq!(net.link(1).msgs_up, 1);
        assert_eq!(net.link(2).bits_up, 300);
        assert_eq!(net.link(2).bits_down, 40 + 60);
        assert_eq!(net.comm_rounds(), 2);
        assert!((net.bits_per_client() - (1100.0 + 260.0) / 3.0).abs() < 1e-9);
    }

    /// Satellite coverage: `simulated_comm_time_s` under a non-default
    /// `TimeModel` — each round pays one latency plus its slowest link
    /// (uplink or downlink, whichever is largest).
    #[test]
    fn sim_time_under_custom_time_model_multi_round() {
        let mut net = Network::new(2)
            .with_time_model(TimeModel { latency_s: 0.5, bandwidth_bps: 100.0 });
        net.begin_round();
        net.uplink(0, 0, 50); // 0.5 s
        net.downlink(0, 1, 200); // 2.0 s — the round's slowest link
        net.end_round();
        net.begin_round();
        net.downlink_broadcast(1, 10); // 0.1 s
        net.end_round();
        // (0.5 + 2.0) + (0.5 + 0.1)
        assert!((net.simulated_comm_time_s() - 3.1).abs() < 1e-9,
                "t = {}", net.simulated_comm_time_s());
    }

    #[test]
    fn wasted_uplinks_meter_bits_but_not_participants() {
        let mut net = Network::new(3);
        net.begin_round();
        net.uplink(1, 0, 100);
        net.uplink_wasted(1, 1, 70);
        net.end_round();
        // the straggler's bytes count...
        assert_eq!(net.total_bits_up(), 170);
        assert_eq!(net.link(1).bits_up, 70);
        assert_eq!(net.link(1).msgs_up, 1);
        // ...but it did not take part in the round
        assert_eq!(net.last_round_participants(), 1);
    }

    /// Tentpole coverage: per-shard attribution — clients map onto
    /// `⌈n/shard_size⌉` buckets, totals and per-client normalization stay
    /// identical to the dense layout.
    #[test]
    fn sharded_attribution_buckets_by_client_shard() {
        let mut net = Network::sharded(10, 4); // shards {0..3} {4..7} {8,9}
        assert_eq!(net.n_clients(), 10);
        assert_eq!(net.n_shards(), 3);
        assert_eq!(net.shard_size(), 4);
        net.begin_round();
        net.uplink(0, 1, 100);
        net.uplink(0, 3, 50); // same shard as client 1
        net.uplink(0, 9, 70);
        net.downlink(0, 5, 40);
        net.end_round();
        assert_eq!(net.shard_link(0).bits_up, 150);
        assert_eq!(net.shard_link(0).msgs_up, 2);
        assert_eq!(net.shard_link(1).bits_up, 0);
        assert_eq!(net.shard_link(1).bits_down, 40);
        assert_eq!(net.shard_link(2).bits_up, 70);
        // `link(client)` resolves to the client's shard bucket
        assert_eq!(net.link(2).bits_up, 150);
        assert_eq!(net.link(8).bits_up, 70);
        // totals and the per-client normalizer use the true fleet size
        assert_eq!(net.total_bits_up(), 220);
        assert_eq!(net.last_round_participants(), 3);
        assert!((net.bits_per_client() - 260.0 / 10.0).abs() < 1e-12);
        // broadcast pays once per *client*, not per bucket
        net.begin_round();
        net.downlink_broadcast(1, 8);
        net.end_round();
        assert_eq!(net.total_bits_down(), 40 + 10 * 8);
        assert_eq!(net.shard_link(2).msgs_down, 2);
    }

    /// Goodput attribution: wasted and stale bits are disjoint subsets of
    /// `bits_up`; applied + wasted + stale = total, and goodput is their
    /// ratio. Off-round discards leave round accounting untouched.
    #[test]
    fn goodput_attribution_splits_uplink_bits() {
        let mut net = Network::new(4);
        assert_eq!(net.uplink_goodput(), 1.0, "silent network");
        net.begin_round();
        net.uplink(0, 0, 100);
        net.uplink(0, 1, 100);
        net.uplink_wasted(0, 2, 60);
        net.end_round();
        assert_eq!(net.comm_rounds(), 1);
        assert_eq!(net.last_round_participants(), 2);
        // discards arriving between rounds (the async regime)
        net.offround_uplink_wasted(1, 3, 40);
        net.offround_uplink_stale(1, 0, 30);
        assert_eq!(net.comm_rounds(), 1, "off-round discards open no round");
        assert_eq!(net.last_round_participants(), 2);
        assert_eq!(net.total_bits_up(), 100 + 100 + 60 + 40 + 30);
        assert_eq!(net.total_bits_up_wasted(), 60 + 40);
        assert_eq!(net.total_bits_up_stale(), 30);
        assert_eq!(net.total_bits_up_applied(), 200);
        assert_eq!(net.total_bits_up_applied() + net.total_bits_up_wasted()
                       + net.total_bits_up_stale(),
                   net.total_bits_up());
        assert!((net.uplink_goodput() - 200.0 / 330.0).abs() < 1e-12);
        // per-link attribution carries the split
        assert_eq!(net.link(2).bits_up_wasted, 60);
        assert_eq!(net.link(0).bits_up_stale, 30);
        assert_eq!(net.link(0).bits_up, 130);
        // every message traced, applied or not
        assert_eq!(net.link(0).msgs_up, 2);
        assert_eq!(net.link(3).msgs_up, 1);
    }

    #[test]
    fn per_client_downlink_traces_and_meters() {
        let mut net = Network::new(2).with_trace();
        net.begin_round();
        net.uplink(3, 0, 8);
        net.downlink(3, 1, 16);
        net.end_round();
        let t = net.trace.as_ref().unwrap();
        assert_eq!(t[1], Event::Down { step: 3, client: 1, bits: 16 });
        assert_eq!(net.link(1).bits_down, 16);
        assert_eq!(net.link(0).bits_down, 0);
    }
}
