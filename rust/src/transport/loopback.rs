//! Real-bytes loopback transport: the simulator's 22-byte frame codec
//! ([`super::frame`]) driven over an actual localhost TCP socket pair.
//!
//! Everywhere else in the crate, "bytes on the wire" is an *accounting*
//! statement — [`crate::transport::Network`] meters the serialized frame
//! length without any I/O. This module closes the loop: a
//! [`LoopbackServer`] accepts real connections, reads real frames off a
//! real socket, validates them with the same [`decode_frame`] the
//! simulator uses, and acknowledges each uplink with the mirrored
//! broadcast frame. The transport tests assert that the bytes observed on
//! both ends of the socket are *identical* to what the simulated metering
//! charges for the same traffic — so the simulator's byte counts are not
//! just internally consistent, they match what a kernel actually moves.
//!
//! ### Service model
//! The server is deliberately sequential: one connection is served to
//! completion (EOF or protocol violation) before the next is accepted,
//! so concurrent clients queue in the OS listen backlog — backpressure by
//! the kernel's own mechanism, not a reimplementation. Within a
//! connection, a client may pipeline many frames before reading a single
//! acknowledgment; replies stream back in order through the socket
//! buffers. Connection churn is the normal case: clients connect, ship a
//! few frames, and vanish — a clean EOF ends only that connection, and a
//! malformed frame (bad magic, inconsistent lengths) drops only the
//! offending client, counted in [`ServerStats::frames_rejected`].
//!
//! Reads on both ends carry a generous timeout so a wedged peer fails a
//! test instead of hanging it.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::obs;
use crate::obs::registry;

use super::frame::{decode_frame, encode_frame, Direction, FrameHeader,
                   BROADCAST, HEADER_BYTES};

/// Upper bound on the payload length a frame header may claim before the
/// server drops the connection — an echo server should not allocate
/// gigabytes on a peer's say-so.
pub const MAX_PAYLOAD_BYTES: usize = 1 << 24;

const IO_TIMEOUT: Duration = Duration::from_secs(10);

/// Counters the server thread accumulates over its lifetime, returned by
/// [`LoopbackServer::shutdown`].
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    /// connections accepted and served (the shutdown wake-up excluded)
    pub connections: u64,
    /// frames that validated and were acknowledged
    pub frames_ok: u64,
    /// frames rejected by [`decode_frame`] or the payload cap (each one
    /// also ends its connection)
    pub frames_rejected: u64,
    /// bytes read off the wire (headers + payloads of complete frames)
    pub bytes_in: u64,
    /// bytes written to the wire (acknowledgment frames)
    pub bytes_out: u64,
}

/// A localhost frame-echo server on an OS-assigned port, serving on a
/// background thread until [`shutdown`](LoopbackServer::shutdown).
pub struct LoopbackServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<ServerStats>>,
}

impl LoopbackServer {
    /// Bind `127.0.0.1:0` and start serving. The listener is bound before
    /// this returns, so clients may connect immediately.
    pub fn spawn() -> anyhow::Result<LoopbackServer> {
        let listener = TcpListener::bind(("127.0.0.1", 0))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&stop);
        let handle = std::thread::spawn(move || serve(listener, &flag));
        Ok(LoopbackServer { addr, stop, handle: Some(handle) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, join the server thread, and return its counters.
    /// Close (drop) every client first: the sequential server only checks
    /// the stop flag between connections, so a still-open client would
    /// hold up the join until its read times out.
    pub fn shutdown(mut self) -> anyhow::Result<ServerStats> {
        self.stop.store(true, Ordering::SeqCst);
        // wake the accept loop; the flag is checked before the connection
        // is served (or counted)
        let _ = TcpStream::connect(self.addr);
        let handle = self.handle.take().expect("server thread handle");
        handle.join()
            .map_err(|_| anyhow::anyhow!("loopback server thread panicked"))
    }
}

impl Drop for LoopbackServer {
    fn drop(&mut self) {
        if let Some(handle) = self.handle.take() {
            self.stop.store(true, Ordering::SeqCst);
            let _ = TcpStream::connect(self.addr);
            let _ = handle.join();
        }
    }
}

fn serve(listener: TcpListener, stop: &AtomicBool) -> ServerStats {
    let mut stats = ServerStats::default();
    for conn in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = conn else { continue };
        stats.connections += 1;
        // a connection-scoped failure (abrupt disconnect, timeout) ends
        // only that connection; the accept loop keeps serving
        let _ = handle_conn(stream, &mut stats);
    }
    stats
}

/// Serve one connection: read frames until EOF, acknowledge each valid
/// uplink with the mirrored broadcast frame, drop the peer on the first
/// protocol violation.
fn handle_conn(mut stream: TcpStream, stats: &mut ServerStats)
               -> std::io::Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    let mut frame = Vec::new();
    let mut reply = Vec::new();
    loop {
        let mut header = [0u8; HEADER_BYTES];
        if !read_header(&mut stream, &mut header)? {
            return Ok(()); // clean EOF between frames
        }
        let payload_len = u32::from_le_bytes([
            header[18], header[19], header[20], header[21],
        ]) as usize;
        if payload_len > MAX_PAYLOAD_BYTES {
            stats.frames_rejected += 1;
            return Ok(());
        }
        frame.clear();
        frame.extend_from_slice(&header);
        frame.resize(HEADER_BYTES + payload_len, 0);
        stream.read_exact(&mut frame[HEADER_BYTES..])?;
        stats.bytes_in += frame.len() as u64;
        match decode_frame(&frame) {
            Ok((h, payload)) => {
                stats.frames_ok += 1;
                let ack = FrameHeader {
                    dir: Direction::Down,
                    client: BROADCAST,
                    ..h
                };
                encode_frame(&ack, payload, &mut reply);
                stream.write_all(&reply)?;
                stats.bytes_out += reply.len() as u64;
            }
            Err(_) => {
                stats.frames_rejected += 1;
                return Ok(());
            }
        }
    }
}

/// Fill `buf` from the stream. `Ok(false)` = EOF on a frame boundary;
/// an EOF *inside* a header is an error (the peer died mid-frame).
fn read_header(stream: &mut TcpStream, buf: &mut [u8]) -> std::io::Result<bool> {
    let mut filled = 0;
    while filled < buf.len() {
        let n = stream.read(&mut buf[filled..])?;
        if n == 0 {
            if filled == 0 {
                return Ok(false);
            }
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "connection closed inside a frame header"));
        }
        filled += n;
    }
    Ok(true)
}

/// Client side of the loopback transport, counting every byte it moves.
pub struct LoopbackClient {
    stream: TcpStream,
    tx_buf: Vec<u8>,
    rx_buf: Vec<u8>,
    bytes_sent: u64,
    bytes_received: u64,
}

impl LoopbackClient {
    pub fn connect(addr: SocketAddr) -> anyhow::Result<LoopbackClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(IO_TIMEOUT))?;
        Ok(LoopbackClient {
            stream,
            tx_buf: Vec::new(),
            rx_buf: Vec::new(),
            bytes_sent: 0,
            bytes_received: 0,
        })
    }

    /// Serialize and ship one frame without waiting for the ack — frames
    /// may be pipelined and the acks drained later, in order.
    pub fn send(&mut self, h: &FrameHeader, payload: &[u8])
                -> anyhow::Result<()> {
        encode_frame(h, payload, &mut self.tx_buf);
        self.stream.write_all(&self.tx_buf)?;
        self.bytes_sent += self.tx_buf.len() as u64;
        registry::count(registry::Counter::LoopbackTxBytes,
                        self.tx_buf.len() as u64);
        obs::instant(obs::LOOPBACK_TX, obs::LANE_TRANSPORT, obs::NO_SIM_TIME,
                     self.tx_buf.len() as f64);
        Ok(())
    }

    /// Read and validate the next acknowledgment frame.
    pub fn recv_ack(&mut self) -> anyhow::Result<(FrameHeader, Vec<u8>)> {
        let mut header = [0u8; HEADER_BYTES];
        self.stream.read_exact(&mut header)?;
        let payload_len = u32::from_le_bytes([
            header[18], header[19], header[20], header[21],
        ]) as usize;
        anyhow::ensure!(payload_len <= MAX_PAYLOAD_BYTES,
                        "ack claims a {payload_len}-byte payload");
        self.rx_buf.clear();
        self.rx_buf.extend_from_slice(&header);
        self.rx_buf.resize(HEADER_BYTES + payload_len, 0);
        self.stream.read_exact(&mut self.rx_buf[HEADER_BYTES..])?;
        self.bytes_received += self.rx_buf.len() as u64;
        registry::count(registry::Counter::LoopbackRxBytes,
                        self.rx_buf.len() as u64);
        obs::instant(obs::LOOPBACK_RX, obs::LANE_TRANSPORT, obs::NO_SIM_TIME,
                     self.rx_buf.len() as f64);
        let (h, payload) = decode_frame(&self.rx_buf)?;
        Ok((h, payload.to_vec()))
    }

    /// [`send`](Self::send) one frame and read its ack.
    pub fn roundtrip(&mut self, h: &FrameHeader, payload: &[u8])
                     -> anyhow::Result<(FrameHeader, Vec<u8>)> {
        self.send(h, payload)?;
        self.recv_ack()
    }

    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent
    }

    pub fn bytes_received(&self) -> u64 {
        self.bytes_received
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{registry, testutil, Compressed};
    use crate::transport::frame::{framed_bits, SpecTable};
    use crate::transport::Network;

    /// Acceptance pin: every codec spec in the registry roundtrips over
    /// the real socket, the decoded vectors match, and the bytes the
    /// kernel moved equal the simulated `LinkStats` metering bit for bit
    /// — on the client, on the server, uplink and downlink.
    #[test]
    fn loopback_bytes_equal_simulated_metering_for_every_codec() {
        let server = LoopbackServer::spawn().unwrap();
        let mut client = LoopbackClient::connect(server.addr()).unwrap();
        let mut table = SpecTable::new();
        let mut net = Network::new(8);
        let mut frames = 0u64;
        for (name, example) in registry::examples() {
            let x = testutil::test_vector(96, 41);
            let c = testutil::compress(&example, &x, 57);
            let spec_id = table.intern(&example);
            let h = FrameHeader::uplink(frames, 3, spec_id, &c).unwrap();
            let (ack, payload) = client.roundtrip(&h, &c.payload)
                .unwrap_or_else(|e| panic!("{name} ({example}): {e:#}"));
            assert_eq!(ack.dir, Direction::Down, "{name}");
            assert_eq!(ack.client, BROADCAST, "{name}");
            assert_eq!(ack.round, frames as u32, "{name}");
            assert_eq!(ack.spec_id, spec_id, "{name}");
            assert_eq!(ack.payload_bits as u64, c.bits, "{name}");
            assert_eq!(payload, c.payload, "{name}: payload mangled in flight");
            // the receiver rebuilds the codec from the interned spec and
            // must reconstruct the identical vector from the real bytes
            let codec = registry::codec_from_spec(table.spec(spec_id).unwrap())
                .unwrap();
            let mut rx = Compressed::empty();
            rx.payload = payload;
            rx.bits = ack.payload_bits as u64;
            rx.dim = x.len();
            rx.set_codec(codec);
            assert_eq!(rx.decode(), c.decode(), "{name}: decoded vector differs");
            // meter the same traffic the way the simulator would
            net.begin_round();
            net.uplink(frames, 3, framed_bits(c.payload.len()));
            net.downlink(frames, 3, framed_bits(c.payload.len()));
            net.end_round();
            frames += 1;
        }
        assert!(frames > 0, "codec registry is empty");
        assert_eq!(client.bytes_sent() * 8, net.total_bits_up(),
                   "client-side uplink bytes drifted from the simulation");
        assert_eq!(client.bytes_received() * 8, net.total_bits_down(),
                   "client-side downlink bytes drifted from the simulation");
        drop(client);
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.connections, 1);
        assert_eq!(stats.frames_ok, frames);
        assert_eq!(stats.frames_rejected, 0);
        assert_eq!(stats.bytes_in * 8, net.total_bits_up(),
                   "server-side uplink bytes drifted from the simulation");
        assert_eq!(stats.bytes_out * 8, net.total_bits_down(),
                   "server-side downlink bytes drifted from the simulation");
    }

    /// Connection churn and misbehaving peers: short-lived clients each
    /// get served, a garbage frame drops only its own connection, and the
    /// server keeps accepting afterwards.
    #[test]
    fn churn_and_corrupt_frames_end_only_their_own_connection() {
        let server = LoopbackServer::spawn().unwrap();
        for round in 0..3u64 {
            let mut c = LoopbackClient::connect(server.addr()).unwrap();
            let x = testutil::test_vector(32, round);
            let comp = testutil::compress("natural", &x, round + 1);
            let h = FrameHeader::uplink(round, round as usize, 0, &comp).unwrap();
            let (ack, p) = c.roundtrip(&h, &comp.payload).unwrap();
            assert_eq!(ack.round, round as u32);
            assert_eq!(p, comp.payload);
        }
        {
            // 22 zero bytes: a "header" with bad magic and zero payload
            let mut s = TcpStream::connect(server.addr()).unwrap();
            s.write_all(&[0u8; HEADER_BYTES]).unwrap();
        }
        // the server shrugged off the violation; a fresh client is served
        let mut c = LoopbackClient::connect(server.addr()).unwrap();
        let x = testutil::test_vector(32, 9);
        let comp = testutil::compress("natural", &x, 5);
        let h = FrameHeader::uplink(9, 1, 0, &comp).unwrap();
        let (_, p) = c.roundtrip(&h, &comp.payload).unwrap();
        assert_eq!(p, comp.payload);
        drop(c);
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.connections, 5);
        assert_eq!(stats.frames_ok, 4);
        assert_eq!(stats.frames_rejected, 1);
    }

    /// Pipelining: many frames written before a single ack is read; the
    /// replies stream back in order through the socket buffers.
    #[test]
    fn pipelined_frames_are_acked_in_order() {
        let server = LoopbackServer::spawn().unwrap();
        let mut client = LoopbackClient::connect(server.addr()).unwrap();
        let x = testutil::test_vector(64, 8);
        let comp = testutil::compress("natural", &x, 3);
        let n = 50u64;
        for k in 0..n {
            let h = FrameHeader::uplink(k, 1, 0, &comp).unwrap();
            client.send(&h, &comp.payload).unwrap();
        }
        for k in 0..n {
            let (ack, p) = client.recv_ack().unwrap();
            assert_eq!(ack.round, k as u32, "acks out of order");
            assert_eq!(p, comp.payload);
        }
        let per_frame = framed_bits(comp.payload.len()) / 8;
        assert_eq!(client.bytes_sent(), n * per_frame);
        assert_eq!(client.bytes_received(), n * per_frame);
        drop(client);
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.frames_ok, n);
        assert_eq!(stats.bytes_in, n * per_frame);
        assert_eq!(stats.bytes_out, n * per_frame);
    }
}
