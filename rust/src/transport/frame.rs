//! Byte-accurate wire framing for the fleet simulator.
//!
//! The lockstep harness meters *theoretical* bit counts (`Compressed::bits`,
//! exact pre-padding encoder output). A real deployment ships byte-aligned
//! datagrams with a header, so the simulator frames every payload and
//! meters the serialized frame instead. The header is fixed-layout
//! little-endian, [`HEADER_BYTES`] long:
//!
//! | bytes | field        | notes                                         |
//! |-------|--------------|-----------------------------------------------|
//! | 0..2  | magic        | [`MAGIC`] = 0x5046 ("PF")                     |
//! | 2     | version      | [`VERSION`]                                   |
//! | 3     | direction    | 0 = uplink, 1 = downlink                      |
//! | 4..8  | round        | u32 protocol step k                           |
//! | 8..12 | client       | u32 client id; [`BROADCAST`] for a downlink   |
//! | 12..14| spec id      | u16 codec spec, interned via [`SpecTable`]    |
//! | 14..18| payload bits | u32 exact encoder bits (pre byte padding)     |
//! | 18..22| payload len  | u32 payload bytes that follow the header      |
//!
//! `payload_len` is stored explicitly (not derived from `payload bits`) so
//! a receiver can skip a frame it cannot decode; [`decode_frame`] still
//! cross-checks the two. Every frame the simulator puts on the wire is
//! decode-roundtripped before its bytes are metered, so the accounting can
//! never drift from what a receiver would actually parse.

use crate::compress::Compressed;
use crate::obs;
use crate::obs::registry;

pub const MAGIC: u16 = 0x5046;
pub const VERSION: u8 = 1;
pub const HEADER_BYTES: usize = 22;
/// `client` field value for a master → cohort broadcast frame.
pub const BROADCAST: u32 = u32::MAX;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    Up,
    Down,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrameHeader {
    pub dir: Direction,
    pub round: u32,
    pub client: u32,
    pub spec_id: u16,
    /// exact encoder bits before byte-alignment padding
    pub payload_bits: u32,
}

impl FrameHeader {
    /// Header for client `client`'s uplink of `wire` at step `round`.
    pub fn uplink(round: u64, client: usize, spec_id: u16, wire: &Compressed)
                  -> anyhow::Result<FrameHeader> {
        Self::build(Direction::Up, round, client as u32, spec_id, wire)
    }

    /// Header for the master's broadcast of `wire` at step `round`.
    pub fn broadcast(round: u64, spec_id: u16, wire: &Compressed)
                     -> anyhow::Result<FrameHeader> {
        Self::build(Direction::Down, round, BROADCAST, spec_id, wire)
    }

    fn build(dir: Direction, round: u64, client: u32, spec_id: u16,
             wire: &Compressed) -> anyhow::Result<FrameHeader> {
        anyhow::ensure!(round <= u32::MAX as u64,
                        "round {round} exceeds the u32 frame field");
        anyhow::ensure!(wire.bits <= u32::MAX as u64,
                        "payload of {} bits exceeds the u32 frame field", wire.bits);
        Ok(FrameHeader {
            dir,
            round: round as u32,
            client,
            spec_id,
            payload_bits: wire.bits as u32,
        })
    }
}

/// Serialize `header + payload` into `out` (cleared first; capacity is
/// reused, so a warmed buffer makes this allocation-free).
pub fn encode_frame(h: &FrameHeader, payload: &[u8], out: &mut Vec<u8>) {
    out.clear();
    out.reserve(HEADER_BYTES + payload.len());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(VERSION);
    out.push(match h.dir {
        Direction::Up => 0,
        Direction::Down => 1,
    });
    out.extend_from_slice(&h.round.to_le_bytes());
    out.extend_from_slice(&h.client.to_le_bytes());
    out.extend_from_slice(&h.spec_id.to_le_bytes());
    out.extend_from_slice(&h.payload_bits.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    registry::count(registry::Counter::FramesEncoded, 1);
    obs::instant(obs::FRAME_ENCODE, obs::LANE_TRANSPORT, obs::NO_SIM_TIME,
                 out.len() as f64);
}

/// Parse a frame, validating magic, version, direction, length, and the
/// `payload bits` / `payload len` consistency. Returns the header and a
/// borrow of the payload bytes.
pub fn decode_frame(buf: &[u8]) -> anyhow::Result<(FrameHeader, &[u8])> {
    anyhow::ensure!(buf.len() >= HEADER_BYTES,
                    "frame of {} bytes is shorter than the {HEADER_BYTES}-byte \
                     header", buf.len());
    let u16_at = |i: usize| u16::from_le_bytes([buf[i], buf[i + 1]]);
    let u32_at =
        |i: usize| u32::from_le_bytes([buf[i], buf[i + 1], buf[i + 2], buf[i + 3]]);
    let magic = u16_at(0);
    anyhow::ensure!(magic == MAGIC, "bad frame magic 0x{magic:04x}");
    anyhow::ensure!(buf[2] == VERSION, "unsupported frame version {}", buf[2]);
    let dir = match buf[3] {
        0 => Direction::Up,
        1 => Direction::Down,
        other => anyhow::bail!("bad frame direction byte {other}"),
    };
    let payload_bits = u32_at(14);
    let payload_len = u32_at(18) as usize;
    anyhow::ensure!(buf.len() == HEADER_BYTES + payload_len,
                    "frame length {} does not match header payload length {}",
                    buf.len(), HEADER_BYTES + payload_len);
    anyhow::ensure!((payload_bits as usize).div_ceil(8) == payload_len,
                    "payload of {payload_bits} bits cannot occupy {payload_len} \
                     bytes");
    let h = FrameHeader {
        dir,
        round: u32_at(4),
        client: u32_at(8),
        spec_id: u16_at(12),
        payload_bits,
    };
    registry::count(registry::Counter::FramesDecoded, 1);
    obs::instant(obs::FRAME_DECODE, obs::LANE_TRANSPORT, obs::NO_SIM_TIME,
                 buf.len() as f64);
    Ok((h, &buf[HEADER_BYTES..]))
}

/// Wire cost of a payload once framed, in bits (bytes are the wire unit;
/// ×8 keeps the existing `LinkStats` bit counters comparable).
pub fn framed_bits(payload_len: usize) -> u64 {
    ((HEADER_BYTES + payload_len) * 8) as u64
}

/// Interning table mapping codec spec strings to the u16 ids carried in
/// frame headers. Per-run (both ends derive it from the run config in the
/// same order), not global: ids are wire-local, specs are the identity.
#[derive(Clone, Debug, Default)]
pub struct SpecTable {
    names: Vec<String>,
}

impl SpecTable {
    pub fn new() -> SpecTable {
        SpecTable::default()
    }

    /// Id for `spec`, interning it on first use.
    pub fn intern(&mut self, spec: &str) -> u16 {
        if let Some(i) = self.names.iter().position(|n| n == spec) {
            return i as u16;
        }
        assert!(self.names.len() < u16::MAX as usize, "spec table full");
        self.names.push(spec.to_string());
        (self.names.len() - 1) as u16
    }

    pub fn spec(&self, id: u16) -> Option<&str> {
        self.names.get(id as usize).map(String::as_str)
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{registry, testutil};

    #[test]
    fn fixed_frame_roundtrip() {
        let h = FrameHeader {
            dir: Direction::Up,
            round: 1234,
            client: 7,
            spec_id: 3,
            payload_bits: 20,
        };
        let payload = [0xAB, 0xCD, 0x01];
        let mut buf = Vec::new();
        encode_frame(&h, &payload, &mut buf);
        assert_eq!(buf.len(), HEADER_BYTES + 3);
        let (h2, p2) = decode_frame(&buf).unwrap();
        assert_eq!(h2, h);
        assert_eq!(p2, &payload);
    }

    #[test]
    fn encode_reuses_buffer_capacity() {
        let h = FrameHeader {
            dir: Direction::Down,
            round: 1,
            client: BROADCAST,
            spec_id: 0,
            payload_bits: 64,
        };
        let payload = vec![0u8; 8];
        let mut buf = Vec::new();
        encode_frame(&h, &payload, &mut buf);
        let cap = buf.capacity();
        let ptr = buf.as_ptr();
        for _ in 0..5 {
            encode_frame(&h, &payload, &mut buf);
            assert_eq!(buf.capacity(), cap);
            assert_eq!(buf.as_ptr(), ptr);
        }
    }

    #[test]
    fn decode_rejects_corruption() {
        let h = FrameHeader {
            dir: Direction::Up,
            round: 9,
            client: 0,
            spec_id: 1,
            payload_bits: 16,
        };
        let mut buf = Vec::new();
        encode_frame(&h, &[1, 2], &mut buf);

        let mut bad = buf.clone();
        bad[0] ^= 0xFF; // magic
        assert!(decode_frame(&bad).is_err());

        let mut bad = buf.clone();
        bad[2] = 99; // version
        assert!(decode_frame(&bad).is_err());

        let mut bad = buf.clone();
        bad[3] = 2; // direction
        assert!(decode_frame(&bad).is_err());

        let mut bad = buf.clone();
        bad.pop(); // truncated payload
        assert!(decode_frame(&bad).is_err());

        let mut bad = buf.clone();
        bad[14] = 99; // payload bits inconsistent with payload length
        assert!(decode_frame(&bad).is_err());

        assert!(decode_frame(&buf[..10]).is_err(), "short header");
        assert!(decode_frame(&buf).is_ok(), "pristine frame still parses");
    }

    /// Satellite: every truncation of a valid frame — header cut short,
    /// payload cut short, even the empty buffer — is a clean `Err`, and
    /// any *extension* is rejected too (the length identity is exact), so
    /// a decoder can never read past what the header promised.
    #[test]
    fn decode_rejects_every_truncation_and_extension() {
        let h = FrameHeader {
            dir: Direction::Up,
            round: 77,
            client: 12,
            spec_id: 2,
            payload_bits: 130,
        };
        let payload: Vec<u8> = (0..17).collect();
        let mut buf = Vec::new();
        encode_frame(&h, &payload, &mut buf);
        assert!(decode_frame(&buf).is_ok());
        for len in 0..buf.len() {
            assert!(decode_frame(&buf[..len]).is_err(),
                    "truncation to {len} bytes must fail cleanly");
        }
        let mut extended = buf.clone();
        extended.push(0);
        assert!(decode_frame(&extended).is_err(), "trailing garbage must fail");
    }

    /// Satellite: decode survives *every single-bit mutation* of a valid
    /// frame without panicking or reading out of bounds — each flip either
    /// fails cleanly or decodes to a frame whose header round-trips. Flips
    /// in the validated fields (magic, version, direction, the
    /// length/bit-count pair) must all be rejected.
    #[test]
    fn decode_survives_every_single_bit_flip() {
        let h = FrameHeader {
            dir: Direction::Down,
            round: 123_456,
            client: BROADCAST,
            spec_id: 9,
            payload_bits: 100,
        };
        let payload: Vec<u8> = (0..13).map(|b| b * 7).collect();
        let mut buf = Vec::new();
        encode_frame(&h, &payload, &mut buf);
        for byte in 0..buf.len() {
            for bit in 0..8 {
                let mut bad = buf.clone();
                bad[byte] ^= 1 << bit;
                // must never panic; if it parses, the mutation hit a
                // non-validated field and re-encoding reproduces the bytes
                if let Ok((h2, p2)) = decode_frame(&bad) {
                    let mut re = Vec::new();
                    encode_frame(&h2, p2, &mut re);
                    assert_eq!(re, bad, "byte {byte} bit {bit}: lossy reparse");
                }
                // fields with a single valid value reject every flip:
                // magic (0..2), version (2), and payload_len (18..22 —
                // any change breaks the exact length identity). The
                // direction byte and the low bits of payload_bits can
                // mutate into other *valid* frames, which the roundtrip
                // check above already pins.
                let always_rejected = byte < 3 || (18..22).contains(&byte);
                if always_rejected {
                    assert!(decode_frame(&bad).is_err(),
                            "flip in validated byte {byte} (bit {bit}) parsed");
                }
            }
        }
        // the pristine frame still parses after all that cloning
        let (h2, p2) = decode_frame(&buf).unwrap();
        assert_eq!(h2, h);
        assert_eq!(p2, &payload[..]);
    }

    /// Satellite: the payload-length and bit-count header fields are
    /// cross-checked — a frame whose `payload_len` disagrees with the
    /// buffer, or whose `payload_bits` cannot occupy `payload_len` bytes,
    /// is rejected with a clean error naming the mismatch.
    #[test]
    fn decode_rejects_length_and_bitcount_disagreement() {
        let h = FrameHeader {
            dir: Direction::Up,
            round: 5,
            client: 3,
            spec_id: 0,
            payload_bits: 24,
        };
        let mut buf = Vec::new();
        encode_frame(&h, &[1, 2, 3], &mut buf);

        // payload_len claims one byte more than the buffer carries
        let mut bad = buf.clone();
        bad[18..22].copy_from_slice(&4u32.to_le_bytes());
        let err = format!("{:#}", decode_frame(&bad).unwrap_err());
        assert!(err.contains("length"), "{err}");

        // payload_bits says 9 bits (→ 2 bytes) but 3 bytes follow
        let mut bad = buf.clone();
        bad[14..18].copy_from_slice(&9u32.to_le_bytes());
        let err = format!("{:#}", decode_frame(&bad).unwrap_err());
        assert!(err.contains("bits"), "{err}");

        // zero-length payload with nonzero bit count
        let mut empty = Vec::new();
        encode_frame(&FrameHeader { payload_bits: 0, ..h }, &[], &mut empty);
        assert!(decode_frame(&empty).is_ok());
        let mut bad = empty.clone();
        bad[14] = 1;
        assert!(decode_frame(&bad).is_err());
    }

    #[test]
    fn spec_table_interns_stably() {
        let mut t = SpecTable::new();
        let a = t.intern("natural");
        let b = t.intern("qsgd:8");
        assert_eq!(t.intern("natural"), a);
        assert_ne!(a, b);
        assert_eq!(t.spec(a), Some("natural"));
        assert_eq!(t.spec(b), Some("qsgd:8"));
        assert_eq!(t.spec(99), None);
        assert_eq!(t.len(), 2);
    }

    /// Satellite: frame encode/decode roundtrip property test across every
    /// registered codec spec — the payload a codec produces must survive
    /// framing byte-for-byte, and the decoded payload must reconstruct the
    /// identical vector.
    #[test]
    fn frame_roundtrip_across_all_registered_codec_specs() {
        let mut table = SpecTable::new();
        let mut buf = Vec::new();
        for (name, example) in registry::examples() {
            let x = testutil::test_vector(96, 41);
            let c = testutil::compress(&example, &x, 57);
            let spec_id = table.intern(&example);
            let h = FrameHeader::uplink(11, 3, spec_id, &c).unwrap();
            encode_frame(&h, &c.payload, &mut buf);
            assert_eq!(buf.len() as u64 * 8, framed_bits(c.payload.len()),
                       "{name}: framed_bits disagrees with the encoder");
            let (h2, payload) = decode_frame(&buf)
                .unwrap_or_else(|e| panic!("{name} ({example}): {e:#}"));
            assert_eq!(h2, h, "{name}: header mangled");
            assert_eq!(payload, &c.payload[..], "{name}: payload mangled");
            assert_eq!(h2.payload_bits as u64, c.bits);
            // the receiver reconstructs the codec from the interned spec and
            // must decode the framed payload to the identical vector
            let codec = registry::codec_from_spec(table.spec(spec_id).unwrap())
                .unwrap();
            let mut rx = Compressed::empty();
            rx.payload = payload.to_vec();
            rx.bits = h2.payload_bits as u64;
            rx.dim = x.len();
            rx.set_codec(codec);
            assert_eq!(rx.decode(), c.decode(), "{name}: decoded vector differs");
        }
    }
}
