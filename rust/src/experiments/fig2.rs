//! Fig 2: the communication pattern — FedAvg's fixed schedule vs L2GD's
//! probabilistic protocol. Renders protocol traces as a step timeline
//! (`pfl repro fig2`), driven by the real transport event log.

use crate::protocol::{Coin, StepKind};

/// One rendered timeline: `L` = local step, `C` = communicating aggregation,
/// `c` = cached aggregation (no traffic).
pub fn l2gd_timeline(p: f64, steps: usize, seed: u64) -> String {
    let mut coin = Coin::new(p, seed);
    (0..steps)
        .map(|_| match coin.draw() {
            StepKind::Local => 'L',
            StepKind::AggregateFresh => 'C',
            StepKind::AggregateCached => 'c',
        })
        .collect()
}

/// FedAvg with T local steps per round: `LLL…C` repeated.
pub fn fedavg_timeline(local_steps: usize, steps: usize) -> String {
    let mut s = String::with_capacity(steps);
    let mut i = 0;
    while s.len() < steps {
        if i % (local_steps + 1) == local_steps {
            s.push('C');
        } else {
            s.push('L');
        }
        i += 1;
    }
    s
}

pub fn render(p: f64, local_steps: usize, steps: usize, seed: u64) -> String {
    format!(
        "FedAvg (T = {local_steps} fixed local steps per round):\n  {}\n\
         L2GD  (probabilistic, p = {p}):\n  {}\n\
         L = local gradient step, C = communication + aggregation, \
         c = cached aggregation (no traffic)\n",
        fedavg_timeline(local_steps, steps),
        l2gd_timeline(p, steps, seed)
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fedavg_schedule_is_periodic() {
        assert_eq!(fedavg_timeline(3, 8), "LLLCLLLC");
    }

    #[test]
    fn l2gd_timeline_has_no_adjacent_fresh_comms() {
        let t = l2gd_timeline(0.5, 500, 1);
        assert!(!t.contains("CC"), "two fresh comms in a row is impossible");
        assert!(t.contains('L') && t.contains('C'));
    }
}
