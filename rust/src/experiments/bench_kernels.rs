//! Kernel microbench — the `kernels` section of `pfl bench`
//! (`BENCH_kernels.json`): per-kernel effective bandwidth (GB/s) at every
//! dispatch level this host can execute (`gbps_avx512` down to
//! `gbps_scalar` as available), so the trajectory shows both the
//! intrinsics-vs-scalar speedup and any regression in either path.
//!
//! Methodology: one vector length (4096 + 5 — deliberately *not* a lane
//! multiple, so the intrinsic tail handling is always inside the measured
//! loop), explicit untimed warmup before every timed window, operands
//! routed through [`black_box`] so the dispatched call cannot be
//! constant-folded, and mutation parameters chosen so tens of thousands
//! of in-place applications stay finite (checked after each window — a
//! bench that silently degenerated to NaN throughput is worse than a
//! failed one). Bandwidth counts touched bytes per call: reads + writes
//! of f32 lanes.

use std::hint::black_box;
use std::time::Instant;

use crate::model::kernels;
use crate::util::json::Value;
use crate::util::meta;
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct KernelBenchCfg {
    /// vector length (a non-multiple of every lane width — 16 at avx512,
    /// 8 at avx2 — keeps the tail path hot)
    pub dim: usize,
    /// timed iterations per kernel × level
    pub iters: u64,
    /// untimed warmup iterations before each timed window
    pub warmup: u64,
}

impl KernelBenchCfg {
    pub fn full() -> KernelBenchCfg {
        KernelBenchCfg { dim: 4096 + 5, iters: 60_000, warmup: 6_000 }
    }

    /// CI-sized: same shapes, ~10× fewer iterations.
    pub fn smoke() -> KernelBenchCfg {
        KernelBenchCfg { iters: 6_000, warmup: 600, ..KernelBenchCfg::full() }
    }
}

/// The five dispatched kernels, in reporting order.
pub const KERNEL_NAMES: &[&str] =
    &["dot", "axpy", "aggregation_step", "add_assign", "scale"];

#[derive(Clone, Debug)]
pub struct KernelBenchResult {
    pub dim: usize,
    pub iters: u64,
    pub warmup: u64,
    /// dispatch level the production kernels run at in this process
    pub active_level: &'static str,
    /// (kernel, level name, GB/s), levels fastest-first per kernel
    pub rows: Vec<(&'static str, &'static str, f64)>,
}

impl KernelBenchResult {
    pub fn gbps(&self, kernel: &str, level: &str) -> Option<f64> {
        self.rows
            .iter()
            .find(|(k, l, _)| *k == kernel && *l == level)
            .map(|&(_, _, g)| g)
    }

    /// Active-level throughput over forced-scalar throughput (1.0 when
    /// the active level *is* scalar) — the headline the AVX2 acceptance
    /// criterion reads.
    pub fn speedup_vs_scalar(&self, kernel: &str) -> Option<f64> {
        let active = self.gbps(kernel, self.active_level)?;
        let scalar = self.gbps(kernel, "scalar")?;
        if scalar > 0.0 {
            Some(active / scalar)
        } else {
            None
        }
    }

    pub fn to_json(&self) -> Value {
        let mut kernels_obj = Vec::new();
        for &name in KERNEL_NAMES {
            let mut per_level = vec![(
                "bytes_per_call".to_string(),
                Value::Num(bytes_per_call(name, self.dim) as f64),
            )];
            for &(k, level, g) in &self.rows {
                if k == name {
                    per_level.push((format!("gbps_{level}"), Value::Num(g)));
                }
            }
            kernels_obj.push((name.to_string(), Value::obj(per_level)));
        }
        let speedups = KERNEL_NAMES
            .iter()
            .map(|&name| {
                (name.to_string(),
                 self.speedup_vs_scalar(name).map_or(Value::Null, Value::Num))
            })
            .collect();
        Value::obj(vec![
            ("bench".into(), Value::Str("kernels".into())),
            // the microbench itself is single-threaded by design — no
            // pool, so the busy fraction is identically zero
            ("meta".into(), meta::bench_meta(1, 0.0)),
            ("config".into(),
             Value::obj(vec![
                 ("dim".into(), Value::Num(self.dim as f64)),
                 ("iters".into(), Value::Num(self.iters as f64)),
                 ("warmup".into(), Value::Num(self.warmup as f64)),
             ])),
            ("active_level".into(), Value::Str(self.active_level.into())),
            ("kernels".into(), Value::obj(kernels_obj)),
            ("speedup_active_vs_scalar".into(), Value::obj(speedups)),
        ])
    }
}

/// Touched f32 bytes per call: reads + writes.
fn bytes_per_call(kernel: &str, d: usize) -> usize {
    let f = std::mem::size_of::<f32>();
    match kernel {
        // read a + read b
        "dot" => 2 * d * f,
        // read x + read y/anchor/v + write x
        "axpy" | "aggregation_step" | "add_assign" => 3 * d * f,
        // read x + write x
        "scale" => 2 * d * f,
        _ => unreachable!("unknown kernel {kernel}"),
    }
}

/// Untimed warmup, then a timed window; returns elapsed seconds.
fn timed_window(iters: u64, warmup: u64, mut f: impl FnMut()) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64()
}

fn gbps(bytes_per_call: usize, iters: u64, secs: f64) -> f64 {
    if secs <= 0.0 {
        return 0.0;
    }
    (bytes_per_call as f64 * iters as f64) / secs / 1e9
}

pub fn run(cfg: &KernelBenchCfg) -> anyhow::Result<KernelBenchResult> {
    let d = cfg.dim;
    let mut rng = Rng::new(0xBE9C);
    let x0: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let y: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut rows: Vec<(&'static str, &'static str, f64)> = Vec::new();

    for &level in kernels::available_levels() {
        let lname = level.name();

        // dot: pure — accumulate into a sink so no call can be elided
        let mut sink = 0.0f32;
        let dt = timed_window(cfg.iters, cfg.warmup, || {
            sink += kernels::dot_at(level, black_box(&x0), black_box(&y));
        });
        anyhow::ensure!(sink.is_finite(), "dot bench diverged at {lname}");
        rows.push(("dot", lname, gbps(bytes_per_call("dot", d), cfg.iters, dt)));

        // axpy: a tiny enough that iters applications stay O(1) magnitude
        let mut x = x0.clone();
        let dt = timed_window(cfg.iters, cfg.warmup, || {
            kernels::axpy_at(level, black_box(x.as_mut_slice()),
                             black_box(1e-7f32), black_box(&y));
        });
        anyhow::ensure!(x.iter().all(|v| v.is_finite()),
                        "axpy bench diverged at {lname}");
        rows.push(("axpy", lname, gbps(bytes_per_call("axpy", d), cfg.iters, dt)));

        // aggregation_step: contraction toward y — unconditionally stable
        let mut x = x0.clone();
        let dt = timed_window(cfg.iters, cfg.warmup, || {
            kernels::aggregation_step_at(level, black_box(x.as_mut_slice()),
                                         black_box(1e-7f32), black_box(&y));
        });
        anyhow::ensure!(x.iter().all(|v| v.is_finite()),
                        "aggregation bench diverged at {lname}");
        rows.push(("aggregation_step", lname,
                   gbps(bytes_per_call("aggregation_step", d), cfg.iters, dt)));

        // add_assign: grows linearly in iters — fine at ~1e5 magnitude
        let mut acc = vec![0.0f32; d];
        let dt = timed_window(cfg.iters, cfg.warmup, || {
            kernels::add_assign_at(level, black_box(acc.as_mut_slice()),
                                   black_box(&y));
        });
        anyhow::ensure!(acc.iter().all(|v| v.is_finite()),
                        "add_assign bench diverged at {lname}");
        rows.push(("add_assign", lname,
                   gbps(bytes_per_call("add_assign", d), cfg.iters, dt)));

        // scale by exactly 1.0 (runtime-opaque): bit-preserving forever
        let mut x = x0.clone();
        let dt = timed_window(cfg.iters, cfg.warmup, || {
            kernels::scale_at(level, black_box(x.as_mut_slice()),
                              black_box(1.0f32));
        });
        anyhow::ensure!(x.iter().all(|v| v.is_finite()),
                        "scale bench diverged at {lname}");
        rows.push(("scale", lname,
                   gbps(bytes_per_call("scale", d), cfg.iters, dt)));
    }

    Ok(KernelBenchResult {
        dim: cfg.dim,
        iters: cfg.iters,
        warmup: cfg.warmup,
        active_level: kernels::active_level().name(),
        rows,
    })
}

pub fn run_and_write(cfg: &KernelBenchCfg, path: &str)
                     -> anyhow::Result<KernelBenchResult> {
    let res = run(cfg)?;
    std::fs::write(path, res.to_json().to_string_pretty())
        .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
    Ok(res)
}

/// Console rendering for `pfl bench`.
pub fn print_summary(res: &KernelBenchResult) {
    println!("  kernels microbench (d={}, {} iters/level, active: {})",
             res.dim, res.iters, res.active_level);
    for &name in KERNEL_NAMES {
        let levels: Vec<String> = res
            .rows
            .iter()
            .filter(|(k, _, _)| *k == name)
            .map(|(_, l, g)| format!("{l} {g:.2} GB/s"))
            .collect();
        let speedup = res
            .speedup_vs_scalar(name)
            .map_or("n/a".to_string(), |s| format!("{s:.2}x"));
        println!("    {name:<16} {}  (active vs scalar: {speedup})",
                 levels.join(" | "));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> KernelBenchCfg {
        KernelBenchCfg { dim: 123, iters: 40, warmup: 8 }
    }

    #[test]
    fn microbench_reports_every_kernel_at_every_level() {
        let res = run(&tiny()).unwrap();
        let n_levels = kernels::available_levels().len();
        assert_eq!(res.rows.len(), KERNEL_NAMES.len() * n_levels);
        for &name in KERNEL_NAMES {
            for &level in kernels::available_levels() {
                let g = res.gbps(name, level.name()).unwrap();
                assert!(g.is_finite() && g > 0.0, "{name}@{}: {g}", level.name());
            }
            assert!(res.speedup_vs_scalar(name).unwrap() > 0.0);
        }
    }

    #[test]
    fn json_has_meta_and_per_level_numbers() {
        let res = run(&tiny()).unwrap();
        let v = crate::util::json::parse(&res.to_json().to_string_pretty()).unwrap();
        assert_eq!(v.get("bench").unwrap().as_str(), Some("kernels"));
        let m = v.get("meta").unwrap();
        assert!(m.get("threads").unwrap().as_usize().is_some());
        assert!(m.get("cpu_features").unwrap().as_str().is_some());
        assert!(m.get("git_rev").unwrap().as_str().is_some());
        assert_eq!(m.get("pool_utilization").unwrap().as_f64(), Some(0.0));
        let dot = v.get("kernels").unwrap().get("dot").unwrap();
        assert!(dot.get("bytes_per_call").unwrap().as_f64().unwrap() > 0.0);
        let active = v.get("active_level").unwrap().as_str().unwrap();
        assert!(dot.get(&format!("gbps_{active}")).unwrap()
                    .as_f64().unwrap() > 0.0);
        assert!(v.get("speedup_active_vs_scalar").unwrap()
                    .get("dot").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn bytes_per_call_counts_reads_and_writes() {
        assert_eq!(bytes_per_call("dot", 10), 80);
        assert_eq!(bytes_per_call("axpy", 10), 120);
        assert_eq!(bytes_per_call("scale", 10), 80);
    }
}
