//! Figs 4–6, 9–11 and Table II (§VII-B): DNN training on heterogeneous
//! synthetic-CIFAR, comparing compressed L2GD against FedAvg (± compression)
//! and FedOpt on loss/accuracy vs rounds AND vs communicated bits/n.

use std::sync::Arc;

use crate::algorithms::{FedAlgorithm, FedAvg, FedEnv, FedOpt, L2gd};
use crate::coordinator::{image_env, ImageEnvCfg};
use crate::metrics::{write_multi_csv, Series};
use crate::runtime::{Backend as _, XlaRuntime};

#[derive(Clone, Debug)]
pub struct DnnCfg {
    pub model: String,
    pub n_clients: usize,
    /// L2GD iterations; FedAvg/FedOpt rounds are scaled to match expected
    /// communication (L2GD communicates ~p(1−p) of its steps)
    pub steps: u64,
    pub eval_every: u64,
    pub p: f64,
    pub local_lr: f64,
    /// ηλ/np — the paper's best-behaved regimes are (0, 0.17] and ≈ 1
    pub agg: f64,
    pub fedavg_local_steps: usize,
    pub seed: u64,
    pub env: ImageEnvCfg,
}

impl DnnCfg {
    pub fn for_model(model: &str, steps: u64) -> DnnCfg {
        DnnCfg {
            model: model.to_string(),
            n_clients: 10,
            steps,
            eval_every: (steps / 12).max(1),
            // the paper's best-behaved compressed regime: moderate p and
            // ηλ/np ∈ (0, 0.17] (§VII-B); agg ≈ 1 is reserved for the
            // FedAvg-equivalence experiment (Figs 7–8).
            p: 0.35,
            local_lr: 0.2,
            agg: 0.1,
            fedavg_local_steps: 2,
            seed: 0,
            env: ImageEnvCfg::default(),
        }
    }

    fn fedavg_rounds(&self) -> u64 {
        // match L2GD's expected communication rounds: p(1−p)·steps
        ((self.p * (1.0 - self.p) * self.steps as f64).round() as u64).max(2)
    }
}

fn build_env(rt: &XlaRuntime, cfg: &DnnCfg) -> anyhow::Result<FedEnv> {
    let backend = Arc::new(rt.backend(&cfg.model)?);
    let mut env_cfg = cfg.env.clone();
    env_cfg.n_clients = cfg.n_clients;
    env_cfg.seed = cfg.seed;
    Ok(image_env(&env_cfg, backend))
}

/// The compressor line-up of Figs 4–6.
pub fn compressor_lineup(param_count: usize) -> Vec<(&'static str, String)> {
    let k = (param_count / 20).max(1);
    vec![
        ("natural", "natural".to_string()),
        ("qsgd", "qsgd:15".to_string()),
        ("terngrad", "terngrad".to_string()),
        ("bernoulli", "bernoulli:0.1".to_string()),
        ("topk", format!("topk:{k}")),
    ]
}

/// Run the full Figs 4–6 comparison for one model; returns all series.
pub fn run_comparison(rt: &XlaRuntime, cfg: &DnnCfg) -> anyhow::Result<Vec<Series>> {
    let env = build_env(rt, cfg)?;
    let d = env.backend.param_count();
    let mut out = Vec::new();

    // compressed L2GD, one series per compressor
    for (tag, spec) in compressor_lineup(d) {
        let mut alg = L2gd::from_local_and_agg(
            cfg.p, cfg.local_lr, cfg.agg, cfg.n_clients, &spec, &spec)?;
        alg.tag = format!("l2gd-{tag}");
        out.push(alg.run(&env, cfg.steps, cfg.eval_every)?);
    }

    // FedAvg baselines: no compression, and natural-compressed uplink
    // (the paper's Fig 4 finding: compression does not hurt FedAvg)
    let rounds = cfg.fedavg_rounds();
    let fa_eval = (cfg.eval_every as f64 * rounds as f64 / cfg.steps as f64)
        .round()
        .max(1.0) as u64;
    let mut fa = FedAvg::new(cfg.local_lr, cfg.fedavg_local_steps,
                             "identity", "identity")?;
    fa.tag = "fedavg".into();
    out.push(fa.run(&env, rounds, fa_eval)?);
    let mut fac = FedAvg::new(cfg.local_lr, cfg.fedavg_local_steps,
                              "natural", "identity")?;
    fac.tag = "fedavg-natural".into();
    out.push(fac.run(&env, rounds, fa_eval)?);

    // FedOpt (no compression)
    let mut fo = FedOpt::new(cfg.local_lr, cfg.fedavg_local_steps, 0.05);
    out.push(fo.run(&env, rounds, fa_eval)?);

    Ok(out)
}

/// Figs 9–11: L2GD(natural) head-to-head vs no-compression FedOpt.
pub fn run_vs_fedopt(rt: &XlaRuntime, cfg: &DnnCfg) -> anyhow::Result<Vec<Series>> {
    let env = build_env(rt, cfg)?;
    let mut out = Vec::new();
    let mut alg = L2gd::from_local_and_agg(
        cfg.p, cfg.local_lr, cfg.agg, cfg.n_clients, "natural", "natural")?;
    alg.tag = "l2gd-natural".into();
    out.push(alg.run(&env, cfg.steps, cfg.eval_every)?);
    let rounds = cfg.fedavg_rounds();
    let fa_eval = (cfg.eval_every * rounds / cfg.steps).max(1);
    let mut fo = FedOpt::new(cfg.local_lr, cfg.fedavg_local_steps, 0.05);
    out.push(fo.run(&env, rounds, fa_eval)?);
    Ok(out)
}

/// Table II: bits/n for L2GD-natural vs FedAvg-natural to reach the target
/// test accuracy. Returns (l2gd bits/n, fedavg bits/n) — `None` if the
/// budget ran out before the threshold.
pub struct Table2Row {
    pub model: String,
    pub params: usize,
    pub target_acc: f64,
    pub l2gd_bits: Option<f64>,
    pub baseline_bits: Option<f64>,
}

impl Table2Row {
    pub fn ratio(&self) -> Option<f64> {
        match (self.l2gd_bits, self.baseline_bits) {
            (Some(a), Some(b)) if a > 0.0 => Some(b / a),
            _ => None,
        }
    }
}

pub fn run_table2(rt: &XlaRuntime, cfg: &DnnCfg, target_acc: f64)
                  -> anyhow::Result<Table2Row> {
    let env = build_env(rt, cfg)?;
    let d = env.backend.param_count();

    let mut l2 = L2gd::from_local_and_agg(
        cfg.p, cfg.local_lr, cfg.agg, cfg.n_clients, "natural", "natural")?;
    l2.tag = "l2gd-natural".into();
    let s_l2 = l2.run(&env, cfg.steps, cfg.eval_every)?;

    let rounds = cfg.fedavg_rounds();
    let fa_eval = (cfg.eval_every * rounds / cfg.steps).max(1);
    let mut fa = FedAvg::new(cfg.local_lr, cfg.fedavg_local_steps,
                             "natural", "identity")?;
    fa.tag = "fedavg-natural".into();
    let s_fa = fa.run(&env, rounds, fa_eval)?;

    Ok(Table2Row {
        model: cfg.model.clone(),
        params: d,
        target_acc,
        l2gd_bits: s_l2.bits_to_test_accuracy(target_acc),
        baseline_bits: s_fa.bits_to_test_accuracy(target_acc),
    })
}

/// Write a comparison run to `results/<figname>.csv`.
pub fn write_series(series: &[Series], name: &str, out_dir: &str) -> anyhow::Result<()> {
    std::fs::create_dir_all(out_dir)?;
    write_multi_csv(series, format!("{out_dir}/{name}.csv"))
}
