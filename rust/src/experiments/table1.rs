//! Table I: the compressor inventory with *measured* properties —
//! bits/coordinate on the wire, Monte-Carlo E‖C(x)−x‖²/‖x‖² against the
//! theoretical ω, and unbiasedness. `pfl compressors` prints it.
//!
//! Registry-driven: the row list is spec strings, so pipeline chains
//! (`randk:51>qsgd:4`) and the error-feedback wrapper measure through the
//! exact same harness as the primitive operators.

use crate::compress::{self, Compressor, CompressorState};
use crate::util::stats::{l2_dist_sq, l2_norm};
use crate::util::Rng;

pub struct Table1Row {
    pub name: String,
    pub unbiased: bool,
    pub omega_theory: Option<f64>,
    pub omega_measured: f64,
    pub bits_per_coord: f64,
    pub compression_x: f64, // 32 / bits_per_coord
}

pub fn measure(c: &dyn Compressor, dim: usize, trials: usize, seed: u64) -> Table1Row {
    let mut rng = Rng::new(seed);
    let x: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let norm_sq = l2_norm(&x).powi(2);
    let mut state = c.instantiate(dim, seed ^ 0x7AB1E);
    let mut var_acc = 0.0;
    let mut bits_acc = 0u64;
    let mut buf = compress::Compressed::empty();
    for _ in 0..trials {
        state.compress_into(&x, &mut buf).expect("table-1 specs compress");
        bits_acc += buf.bits;
        let y = buf.decode();
        var_acc += l2_dist_sq(&y, &x);
    }
    let bits_per_coord = bits_acc as f64 / (trials * dim) as f64;
    Table1Row {
        name: c.name(),
        unbiased: c.unbiased(),
        omega_theory: c.omega(dim),
        omega_measured: var_acc / trials as f64 / norm_sq,
        bits_per_coord,
        compression_x: 32.0 / bits_per_coord,
    }
}

pub fn run(dim: usize, trials: usize) -> Vec<Table1Row> {
    let specs = ["identity", "natural", "qsgd:15", "terngrad",
                 "bernoulli:0.1", "randk:51", "topk:51",
                 // pipeline rows: quantized survivors + error feedback
                 "randk:51>qsgd:4", "bernoulli:0.1>natural", "ef(topk:51)"];
    specs
        .iter()
        .map(|s| measure(compress::from_spec(s).unwrap().as_ref(), dim, trials, 42))
        .collect()
}

pub fn format_table(rows: &[Table1Row]) -> String {
    let mut s = String::from(
        "compressor            unbiased  ω(theory)   ω(measured)  bits/coord  ×compression\n");
    for r in rows {
        s.push_str(&format!(
            "{:<21} {:<9} {:<11} {:<12.4} {:<11.2} {:.1}\n",
            r.name,
            r.unbiased,
            r.omega_theory.map_or("—".into(), |w| format!("{w:.4}")),
            r.omega_measured,
            r.bits_per_coord,
            r.compression_x
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measured_omega_within_theory_bounds() {
        for row in run(1024, 30) {
            if let Some(w) = row.omega_theory {
                assert!(row.omega_measured <= w * 1.1 + 1e-9,
                        "{}: measured {} > theory {}", row.name,
                        row.omega_measured, w);
            }
        }
    }

    #[test]
    fn natural_is_9_bits_and_terngrad_2() {
        let rows = run(1024, 5);
        let get = |n: &str| rows.iter().find(|r| r.name.starts_with(n)).unwrap();
        assert!((get("natural").bits_per_coord - 9.0).abs() < 0.01);
        assert!((get("terngrad").bits_per_coord - 2.0).abs() < 0.1);
        assert!((get("identity").bits_per_coord - 32.0).abs() < 1e-9);
    }

    #[test]
    fn chained_rows_measure_through_same_harness() {
        let rows = run(1024, 5);
        let chain = rows.iter().find(|r| r.name == "randk:51>qsgd:4").unwrap();
        assert!(chain.unbiased);
        // survivors quantized: well under plain randk's 64 + 32·51 bits
        assert!(chain.bits_per_coord < (64.0 + 32.0 * 51.0) / 1024.0,
                "bits/coord = {}", chain.bits_per_coord);
        let ef = rows.iter().find(|r| r.name == "ef(topk:51)").unwrap();
        assert!(!ef.unbiased);
        assert!(ef.omega_theory.is_none());
    }
}
