//! Figs 7–8 (§VII-B): FedAvg is a particular case of L2GD.
//!
//! When ηλ/np = 1 the aggregation step collapses to x_i ← x̄ — every device
//! jumps onto the average, which is FedAvg's synchronization with a
//! *random* number of local steps (p = 0.5 ⇒ 3 local steps on average
//! between communications, counting the cached aggregates). The paper shows
//! overlapping train/test curves for ResNet-56, n = 100; we reproduce the
//! equivalence on resnet_tiny at a scaled n and report the curve gap.

use std::sync::Arc;

use crate::algorithms::{FedAlgorithm, FedAvg, L2gd};
use crate::coordinator::{image_env, ImageEnvCfg};
use crate::metrics::Series;
use crate::runtime::XlaRuntime;

#[derive(Clone, Debug)]
pub struct Fig78Cfg {
    pub model: String,
    pub n_clients: usize,
    pub steps: u64,
    pub eval_every: u64,
    pub local_lr: f64,
    pub seed: u64,
    pub env: ImageEnvCfg,
}

impl Default for Fig78Cfg {
    fn default() -> Self {
        Fig78Cfg {
            model: "resnet_tiny".into(),
            n_clients: 20,
            steps: 600,
            eval_every: 50,
            local_lr: 0.05,
            seed: 0,
            env: ImageEnvCfg::default(),
        }
    }
}

pub struct Fig78Out {
    pub l2gd: Series,
    pub fedavg: Series,
    /// max |test-acc gap| between the two curves at matched eval points
    pub max_acc_gap: f64,
    /// max |train-loss gap|
    pub max_loss_gap: f64,
}

pub fn run(rt: &XlaRuntime, cfg: &Fig78Cfg) -> anyhow::Result<Fig78Out> {
    let backend = Arc::new(rt.backend(&cfg.model)?);
    let mut env_cfg = cfg.env.clone();
    env_cfg.n_clients = cfg.n_clients;
    env_cfg.seed = cfg.seed;
    let env = image_env(&env_cfg, backend);

    // L2GD in the FedAvg regime: ηλ/np = 1, p = 0.5, identity compression
    let mut l2 = L2gd::from_local_and_agg(0.5, cfg.local_lr, 1.0,
                                          cfg.n_clients, "identity", "identity")?;
    l2.tag = "l2gd-agg1".into();
    let s_l2 = l2.run(&env, cfg.steps, cfg.eval_every)?;

    // FedAvg with the matching expected work: p = 0.5 ⇒ a quarter of the
    // steps are communicating rounds and local steps average (1−p)/ (p(1−p))
    // = 2 per round of actual gradient work; use 2 local steps per round.
    let rounds = (cfg.steps as f64 * 0.25).round() as u64;
    let fa_eval = (cfg.eval_every as f64 * 0.25).round().max(1.0) as u64;
    let mut fa = FedAvg::new(cfg.local_lr, 2, "identity", "identity")?;
    fa.tag = "fedavg".into();
    let s_fa = fa.run(&env, rounds, fa_eval)?;

    // gap at matched eval indices (both series eval ~12 times)
    let k = s_l2.records.len().min(s_fa.records.len());
    let mut max_acc_gap = 0.0f64;
    let mut max_loss_gap = 0.0f64;
    for i in 0..k {
        max_acc_gap = max_acc_gap
            .max((s_l2.records[i].test_acc - s_fa.records[i].test_acc).abs());
        max_loss_gap = max_loss_gap
            .max((s_l2.records[i].train_loss - s_fa.records[i].train_loss).abs());
    }
    Ok(Fig78Out { l2gd: s_l2, fedavg: s_fa, max_acc_gap, max_loss_gap })
}
