//! `pfl bench` — the tracked round-engine throughput harness.
//!
//! Measures steady-state L2GD steps/sec on the Fig-3 convex configuration
//! (n = 5 workers, d = 123, a1a-sized shards, uncompressed wire) for:
//!
//! * the zero-allocation round engine ([`crate::algorithms::l2gd`]),
//! * the engine on a compressed wire (`natural`/`natural`), and
//! * the seed-semantics reference loop
//!   ([`crate::algorithms::reference::run_l2gd`]) — the pre-refactor
//!   baseline, measured by the *same* harness on the same environment.
//!
//! When the binary installs the counting global allocator
//! (`pfl` and `benches/perf_round_latency.rs` both do), the harness also
//! counts heap allocations across the measured engine window and — by
//! default — **asserts zero**: the warmed engine must not touch the
//! allocator, whatever mix of local / fresh-aggregate / cached-aggregate
//! steps the coin deals.
//!
//! Results are emitted as `BENCH_round.json` so successive PRs record a
//! comparable throughput trajectory (CI runs `pfl bench --smoke` and
//! uploads the file as an artifact). The `sim_algorithms` section adds
//! the engine-vs-engine comparison: fleet-scheduler events/sec for every
//! registered algorithm (`l2gd`, `fedavg`, `fedopt`) on the same
//! straggler-heavy scenario, and the `async_scheduler` section measures
//! the buffered-aggregation runtime ([`crate::sim::async_runner`]) —
//! overlapping version-stamped rounds and staleness-weighted applies —
//! under the same per-event allocation bound.

use std::time::Instant;

use super::fig3;
use crate::algorithms::l2gd::L2gdEngine;
use crate::algorithms::{reference, FedAlgorithm as _, FedEnv, L2gd};
use crate::obs;
use crate::protocol::AsyncSchedule;
use crate::sim::{self, AsyncShardedSim, EventQueue, FleetSim, HeapQueue};
use crate::util::alloc_count;
use crate::util::json::Value;
use crate::util::meta;

/// Allocation ceiling for the fleet-sim scheduler's hot loop, per
/// processed event (steps + arrival pushes/pops). The loop's scratch —
/// cohort buffers, the event heap, frame buffers — is reused, so warmed
/// steady state should sit at 0; the bound leaves slack for rare buffer
/// regrowth without letting per-event allocation creep back in.
pub const SIM_ALLOCS_PER_EVENT_BOUND: f64 = 8.0;

#[derive(Clone, Debug)]
pub struct BenchCfg {
    pub n_clients: usize,
    /// recorded for the JSON config echo; the environment comes from
    /// `fig3::build_env`, which fixes d = 123
    pub dim: usize,
    pub rows_per_worker: usize,
    /// measured engine steps
    pub steps: u64,
    /// engine warmup steps (lets buffer capacities settle and guarantees
    /// at least one fresh aggregation round has run)
    pub warmup: u64,
    /// measured reference-loop steps (the baseline is slow; keep modest)
    pub ref_steps: u64,
    pub p: f64,
    pub lambda: f64,
    pub eta: f64,
    pub seed: u64,
    /// fail (Err) if the measured engine window allocates while the
    /// counting allocator is installed
    pub assert_zero_alloc: bool,
}

impl BenchCfg {
    /// The Fig-3 convex configuration (§VII-A): n = 5, d = 123, a1a-sized
    /// shards, λ = 10 at p = 0.65 with the stability clamp of
    /// `experiments::fig3::loss_at`.
    pub fn fig3() -> BenchCfg {
        BenchCfg {
            n_clients: 5,
            dim: 123,
            rows_per_worker: 321,
            steps: 3000,
            warmup: 300,
            ref_steps: 600,
            p: 0.65,
            lambda: 10.0,
            eta: 1.0,
            seed: 0,
            assert_zero_alloc: true,
        }
    }

    /// CI-sized run: same shapes, two orders of magnitude fewer steps —
    /// still enough to warm the engine and exercise the zero-alloc
    /// assertion and the JSON emitter.
    pub fn smoke() -> BenchCfg {
        BenchCfg { steps: 300, warmup: 120, ref_steps: 60, ..BenchCfg::fig3() }
    }
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub cfg: BenchCfg,
    /// engine steps/sec on the raw step loop (no evaluations), identity
    /// wire — the headline ns/step number
    pub engine_steps_per_sec: f64,
    /// engine steps/sec, natural/natural wire (raw step loop)
    pub engine_natural_steps_per_sec: f64,
    /// engine steps/sec measured through `FedAlgorithm::run` over
    /// `ref_steps` with the same evaluation schedule as the reference —
    /// the symmetric side of the speedup ratio
    pub engine_paired_steps_per_sec: f64,
    /// seed-semantics reference steps/sec (same `run` shape: `ref_steps`
    /// steps, evaluations at 0 and the end)
    pub reference_steps_per_sec: f64,
    /// allocations per measured engine step; `None` when the counting
    /// allocator is not installed
    pub engine_allocs_per_step: Option<f64>,
    /// fleet-sim scheduler throughput (events/sec) on the straggler-heavy
    /// scenario over the same convex config (the `l2gd` engine — the
    /// allocation-disciplined measurement)
    pub sim_events_per_sec: f64,
    /// allocations per processed scheduler event; `None` without the
    /// counting allocator. Asserted `< SIM_ALLOCS_PER_EVENT_BOUND`.
    pub sim_allocs_per_event: Option<f64>,
    /// engine-vs-engine: events/sec per registered fleet algorithm on the
    /// same straggler-heavy scenario (`l2gd` repeats the measurement
    /// above; `fedavg`/`fedopt` run the fixed-cadence schedules)
    pub sim_alg_events_per_sec: Vec<(String, f64)>,
    /// asynchronous-runtime scheduler throughput (events/sec) on the
    /// `async-bursty` scenario: overlapping version-stamped rounds and
    /// staleness-weighted buffered aggregation in the shared event queue
    pub async_events_per_sec: f64,
    /// allocations per processed async-scheduler event; `None` without
    /// the counting allocator. Asserted `< SIM_ALLOCS_PER_EVENT_BOUND` —
    /// the async path reuses the sync path's scratch discipline.
    pub async_allocs_per_event: Option<f64>,
    /// staleness-weighted updates applied across the async run — proves
    /// the throughput number actually exercised the buffered-apply path
    pub async_applied_updates: u64,
    /// worker-pool size the measured environment ran with (recorded in
    /// the JSON `meta` so cross-machine deltas stay interpretable)
    pub threads: usize,
    /// busy fraction of the engine environment's worker pool over the
    /// bench (thread-pool profiling hooks; JSON `meta.pool_utilization`)
    pub pool_utilization: f64,
    pub final_personal_loss: f64,
}

impl BenchResult {
    /// Engine/reference ratio from the two symmetric `run` measurements
    /// (identical step counts and evaluation schedules on both sides).
    pub fn speedup(&self) -> f64 {
        self.engine_paired_steps_per_sec / self.reference_steps_per_sec
    }

    pub fn to_json(&self) -> Value {
        let c = &self.cfg;
        let opt = |v: Option<f64>| v.map_or(Value::Null, Value::Num);
        Value::obj(vec![
            ("bench".into(), Value::Str("round_engine".into())),
            ("meta".into(), meta::bench_meta(self.threads, self.pool_utilization)),
            ("config".into(), Value::obj(vec![
                ("n_clients".into(), Value::Num(c.n_clients as f64)),
                ("dim".into(), Value::Num(c.dim as f64)),
                ("rows_per_worker".into(), Value::Num(c.rows_per_worker as f64)),
                ("steps".into(), Value::Num(c.steps as f64)),
                ("warmup".into(), Value::Num(c.warmup as f64)),
                ("ref_steps".into(), Value::Num(c.ref_steps as f64)),
                ("p".into(), Value::Num(c.p)),
                ("lambda".into(), Value::Num(c.lambda)),
                ("eta".into(), Value::Num(c.eta)),
                ("seed".into(), Value::Num(c.seed as f64)),
                ("backend".into(), Value::Str("native_logreg".into())),
            ])),
            ("engine".into(), Value::obj(vec![
                ("wire".into(), Value::Str("identity|identity".into())),
                ("steps_per_sec".into(), Value::Num(self.engine_steps_per_sec)),
                ("ns_per_step".into(),
                 Value::Num(1e9 / self.engine_steps_per_sec)),
                ("allocs_per_step".into(), opt(self.engine_allocs_per_step)),
                ("alloc_counting".into(),
                 Value::Bool(self.engine_allocs_per_step.is_some())),
            ])),
            ("engine_natural".into(), Value::obj(vec![
                ("wire".into(), Value::Str("natural|natural".into())),
                ("steps_per_sec".into(),
                 Value::Num(self.engine_natural_steps_per_sec)),
            ])),
            ("engine_paired".into(), Value::obj(vec![
                ("wire".into(), Value::Str("identity|identity".into())),
                ("steps_per_sec".into(),
                 Value::Num(self.engine_paired_steps_per_sec)),
                ("shape".into(), Value::Str("FedAlgorithm::run, ref_steps \
                    steps, evals at 0 and end — symmetric to reference".into())),
            ])),
            ("reference".into(), Value::obj(vec![
                ("wire".into(), Value::Str("identity|identity".into())),
                ("steps_per_sec".into(), Value::Num(self.reference_steps_per_sec)),
                ("layout".into(), Value::Str("seed Vec<Vec<f32>>, per-call \
                    batch assembly, allocating grad".into())),
            ])),
            ("sim_scheduler".into(), Value::obj(vec![
                ("scenario".into(), Value::Str("straggler-heavy".into())),
                ("events_per_sec".into(), Value::Num(self.sim_events_per_sec)),
                ("allocs_per_event".into(), opt(self.sim_allocs_per_event)),
                ("allocs_per_event_bound".into(),
                 Value::Num(SIM_ALLOCS_PER_EVENT_BOUND)),
            ])),
            // engine-vs-engine: one events/sec entry per registered fleet
            // algorithm, same scenario, same environment
            ("sim_algorithms".into(), Value::obj(
                self.sim_alg_events_per_sec
                    .iter()
                    .map(|(alg, eps)| (alg.clone(), Value::Num(*eps)))
                    .collect())),
            ("async_scheduler".into(), Value::obj(vec![
                ("scenario".into(), Value::Str("async-bursty".into())),
                ("events_per_sec".into(),
                 Value::Num(self.async_events_per_sec)),
                ("allocs_per_event".into(), opt(self.async_allocs_per_event)),
                ("allocs_per_event_bound".into(),
                 Value::Num(SIM_ALLOCS_PER_EVENT_BOUND)),
                ("applied_updates".into(),
                 Value::Num(self.async_applied_updates as f64)),
            ])),
            ("speedup_vs_reference".into(), Value::Num(self.speedup())),
            ("final_personal_loss".into(), Value::Num(self.final_personal_loss)),
        ])
    }
}

/// The Fig-3 environment itself — built by `fig3::build_env` so the bench
/// can never drift from the configuration it claims to track (d is fixed
/// at 123 by that builder).
fn build_env(cfg: &BenchCfg) -> FedEnv {
    fig3::build_env(&fig3::Fig3Cfg {
        rows_per_worker: cfg.rows_per_worker,
        n_clients: cfg.n_clients,
        eta: cfg.eta,
        seed: cfg.seed,
        ..fig3::Fig3Cfg::a1a()
    })
}

/// λ clamped into the stable aggregation regime by the same helper the
/// Fig-3 sweeps use.
fn alg(cfg: &BenchCfg, client: &str, master: &str) -> anyhow::Result<L2gd> {
    let mut alg = L2gd::new(cfg.p, cfg.lambda, cfg.eta, cfg.n_clients, client, master)?;
    fig3::clamp_agg_stability(&mut alg, cfg.n_clients);
    Ok(alg)
}

/// Warm an engine, then time (and allocation-count) `steps` steady-state
/// steps. Returns (steps/sec, allocs/step if counting, the engine).
fn time_engine<'e>(alg: &L2gd, env: &'e FedEnv, warmup: u64, steps: u64)
                   -> anyhow::Result<(f64, Option<f64>, L2gdEngine<'e>)> {
    let mut eng = alg.engine(env)?;
    eng.run_steps(0, warmup)?;
    let counting = alloc_count::counting_enabled();
    let before = alloc_count::allocations();
    let t0 = Instant::now();
    eng.run_steps(warmup, steps)?;
    let dt = t0.elapsed().as_secs_f64();
    let allocs = alloc_count::allocations() - before;
    let per_step = counting.then(|| allocs as f64 / steps as f64);
    // sanity: the engine actually communicated during the window
    anyhow::ensure!(eng.net().comm_rounds() > 0, "no communication rounds ran");
    Ok((steps as f64 / dt, per_step, eng))
}

pub fn run(cfg: &BenchCfg) -> anyhow::Result<BenchResult> {
    // every zero-alloc assertion below doubles as a pin on the
    // *disabled-tracing* no-op path (obs emit = one relaxed atomic
    // load): refuse to measure with the trace gate open, so a stray
    // enable can never silently absorb an allocation regression
    anyhow::ensure!(!obs::enabled(),
                    "bench requires tracing disabled — the allocation \
                     bounds pin the no-op instrumentation path");
    let env = build_env(cfg);
    // untimed: materialize the lazily built per-shard train batches before
    // anything is measured (first-touch batch assembly is one-time cost)
    env.warm_caches();
    // arm the thread-pool profiling hooks for `meta.pool_utilization`
    env.pool.enable_profiling();

    // engine, identity wire (the Fig-3 configuration)
    let a_id = alg(cfg, "identity", "identity")?;
    let (engine_sps, allocs_per_step, eng) =
        time_engine(&a_id, &env, cfg.warmup, cfg.steps)?;
    if cfg.assert_zero_alloc {
        if let Some(per_step) = allocs_per_step {
            anyhow::ensure!(
                per_step == 0.0,
                "steady-state engine step allocated ({per_step:.2} allocs/step \
                 over {} steps)", cfg.steps
            );
        }
    }
    // the loss the measured run reached (regression canary: a "fast"
    // engine that stopped learning is a broken engine)
    let final_personal_loss = eng.evaluate(cfg.warmup + cfg.steps)?.personal_loss;

    // engine, natural/natural wire
    let a_nat = alg(cfg, "natural", "natural")?;
    let (natural_sps, _, _) = time_engine(&a_nat, &env, cfg.warmup, cfg.steps)?;

    // symmetric comparison: engine and reference both measured through the
    // identical `run` shape — ref_steps steps, evaluations at step 0 and
    // the end — so per-step evaluation cost amortizes equally on both
    // sides of the ratio. Each side gets the same short untimed warmup run
    // first (evaluation scratch, pool spin-up), keeping the ratio fair.
    let warm_steps = (cfg.ref_steps / 10).clamp(1, 50).min(cfg.ref_steps);
    let _ = alg(cfg, "identity", "identity")?.run(&env, warm_steps, warm_steps)?;
    let mut a_paired = alg(cfg, "identity", "identity")?;
    let t0 = Instant::now();
    let _ = a_paired.run(&env, cfg.ref_steps, cfg.ref_steps)?;
    let engine_paired_sps = cfg.ref_steps as f64 / t0.elapsed().as_secs_f64();

    let _ = reference::run_l2gd(&alg(cfg, "identity", "identity")?, &env,
                                warm_steps, warm_steps)?;
    let a_ref = alg(cfg, "identity", "identity")?;
    let t0 = Instant::now();
    let _ = reference::run_l2gd(&a_ref, &env, cfg.ref_steps, cfg.ref_steps)?;
    let reference_sps = cfg.ref_steps as f64 / t0.elapsed().as_secs_f64();

    // fleet-sim scheduler: throughput + allocation discipline of the
    // discrete-event hot loop (straggler-heavy = queue, quorum, deadline
    // drops all exercised) on the same convex config
    let scenario = sim::scenario::from_spec("straggler-heavy:quorum=0.6,deadline=1")?;
    let mut sim_cfg = sim::SimCfg::fig3(scenario);
    sim_cfg.n_clients = cfg.n_clients;
    sim_cfg.rows_per_worker = cfg.rows_per_worker;
    sim_cfg.seed = cfg.seed;
    sim_cfg.p = cfg.p;
    sim_cfg.lambda = cfg.lambda;
    sim_cfg.eta = cfg.eta;
    let sim_env = sim::runner::build_env(&sim_cfg);
    sim_env.warm_caches();
    let mut fsim = FleetSim::new(&sim_cfg, &sim_env)?;
    // untimed warmup before the measured window
    fsim.run_steps(0, cfg.warmup)?;
    let counting = alloc_count::counting_enabled();
    let ev0 = fsim.stats().events;
    let before = alloc_count::allocations();
    let t0 = Instant::now();
    fsim.run_steps(cfg.warmup, cfg.steps)?;
    let dt = t0.elapsed().as_secs_f64();
    let allocs = alloc_count::allocations() - before;
    let events = (fsim.stats().events - ev0).max(1);
    let sim_events_per_sec = events as f64 / dt;
    let sim_allocs_per_event = counting.then(|| allocs as f64 / events as f64);
    anyhow::ensure!(fsim.stats().comm_events > 0, "sim ran no communication rounds");
    if cfg.assert_zero_alloc {
        if let Some(per_event) = sim_allocs_per_event {
            anyhow::ensure!(
                per_event < SIM_ALLOCS_PER_EVENT_BOUND,
                "fleet-sim scheduler allocated {per_event:.2}/event over \
                 {events} events (bound {SIM_ALLOCS_PER_EVENT_BOUND})"
            );
        }
    }

    // engine-vs-engine: the same straggler-heavy scenario under every
    // registered fleet algorithm (l2gd repeats the measured number above
    // so the section is self-contained; fedavg/fedopt swap in the fixed
    // cadence via the scenario grammar's alg= key)
    let mut sim_alg_events = vec![("l2gd".to_string(), sim_events_per_sec)];
    for alg_name in ["fedavg", "fedopt"] {
        let scenario = sim::scenario::from_spec(
            &format!("straggler-heavy:quorum=0.6,deadline=1,alg={alg_name}"))?;
        let mut c = sim::SimCfg::fig3(scenario);
        c.n_clients = cfg.n_clients;
        c.rows_per_worker = cfg.rows_per_worker;
        c.seed = cfg.seed;
        let e = sim::runner::build_env(&c);
        e.warm_caches();
        let mut fs = FleetSim::new(&c, &e)?;
        // untimed warmup before the measured window
        fs.run_steps(0, cfg.warmup)?;
        let ev0 = fs.stats().events;
        let t0 = Instant::now();
        fs.run_steps(cfg.warmup, cfg.steps)?;
        let dt = t0.elapsed().as_secs_f64();
        let alg_events = (fs.stats().events - ev0).max(1);
        anyhow::ensure!(fs.stats().comm_events > 0,
                        "{alg_name} sim ran no communication rounds");
        sim_alg_events.push((alg_name.to_string(), alg_events as f64 / dt));
    }

    // async scheduler: the buffered-aggregation runtime's hot loop —
    // overlapping rounds, staleness re-checks at apply time, and weighted
    // aggregations all run out of the sync path's reusable scratch, so the
    // same per-event allocation bound applies. A small buffer and a modest
    // in-flight cap keep the apply path busy at bench-sized fleets.
    let scenario = sim::scenario::from_spec(
        "async-bursty:quorum=0.6,deadline=1,buffer=2,inflight=4")?;
    let mut a_cfg = sim::SimCfg::fig3(scenario);
    a_cfg.n_clients = cfg.n_clients;
    a_cfg.rows_per_worker = cfg.rows_per_worker;
    a_cfg.seed = cfg.seed;
    a_cfg.p = cfg.p;
    a_cfg.lambda = cfg.lambda;
    a_cfg.eta = cfg.eta;
    let a_env = sim::runner::build_env(&a_cfg);
    a_env.warm_caches();
    let mut asim = AsyncShardedSim::new(&a_cfg, &a_env)?;
    // untimed warmup before the measured window
    asim.run_steps(0, cfg.warmup)?;
    let ev0 = asim.stats().events;
    let before = alloc_count::allocations();
    let t0 = Instant::now();
    asim.run_steps(cfg.warmup, cfg.steps)?;
    let dt = t0.elapsed().as_secs_f64();
    let allocs = alloc_count::allocations() - before;
    let a_events = (asim.stats().events - ev0).max(1);
    let async_events_per_sec = a_events as f64 / dt;
    let async_allocs_per_event = counting.then(|| allocs as f64 / a_events as f64);
    let async_applied_updates = asim.async_stats().applied_updates;
    anyhow::ensure!(async_applied_updates > 0,
                    "async scheduler applied no buffered updates");
    if cfg.assert_zero_alloc {
        if let Some(per_event) = async_allocs_per_event {
            anyhow::ensure!(
                per_event < SIM_ALLOCS_PER_EVENT_BOUND,
                "async scheduler allocated {per_event:.2}/event over \
                 {a_events} events (bound {SIM_ALLOCS_PER_EVENT_BOUND})"
            );
        }
    }

    Ok(BenchResult {
        cfg: cfg.clone(),
        threads: env.pool.size(),
        pool_utilization: env.pool.utilization(),
        engine_steps_per_sec: engine_sps,
        engine_natural_steps_per_sec: natural_sps,
        engine_paired_steps_per_sec: engine_paired_sps,
        reference_steps_per_sec: reference_sps,
        engine_allocs_per_step: allocs_per_step,
        sim_events_per_sec,
        sim_allocs_per_event,
        sim_alg_events_per_sec: sim_alg_events,
        async_events_per_sec,
        async_allocs_per_event,
        async_applied_updates,
        final_personal_loss,
    })
}

/// Run and write `BENCH_round.json`; returns the result for display.
pub fn run_and_write(cfg: &BenchCfg, out_path: &str) -> anyhow::Result<BenchResult> {
    let res = run(cfg)?;
    let mut text = res.to_json().to_string_pretty();
    text.push('\n');
    std::fs::write(out_path, text)
        .map_err(|e| anyhow::anyhow!("write {out_path}: {e}"))?;
    Ok(res)
}

// ---------------------------------------------------------------------------
// Scale section: the sharded cohort engine at a million devices
// ---------------------------------------------------------------------------

/// Allocation ceiling per *newly touched* client in the sharded engine's
/// steady state. A client's first cohort membership legitimately allocates
/// (row materialization, lazy slot: compressor state + wire buffers, map
/// growth); after that, events must stay inside the reusable-scratch
/// budget of [`SIM_ALLOCS_PER_EVENT_BOUND`]. The scale bench asserts
/// `allocs ≤ touches·this + events·SIM_ALLOCS_PER_EVENT_BOUND`.
pub const SHARD_ALLOCS_PER_TOUCH_BOUND: f64 = 48.0;

/// Configuration of the `pfl bench` scale section (`BENCH_shard.json`).
#[derive(Clone, Debug)]
pub struct ShardBenchCfg {
    /// scenario spec — defaults to the 10⁶-device `megafleet` preset
    pub scenario: String,
    pub steps: u64,
    pub warmup: u64,
    pub rows_per_worker: usize,
    pub seed: u64,
    /// fail (Err) if the measured window exceeds the allocation bound
    /// while the counting allocator is installed
    pub assert_alloc_bounded: bool,
    /// fail (Err) if the `event_queue` microbench measures the timing
    /// wheel below this many ops/sec (0 = disabled; CI's queue-smoke job
    /// sets a conservative floor via `pfl bench --queue-floor`)
    pub queue_ops_floor: f64,
}

impl ShardBenchCfg {
    pub fn megafleet() -> ShardBenchCfg {
        ShardBenchCfg {
            scenario: "megafleet".into(),
            steps: 120,
            warmup: 40,
            rows_per_worker: 40,
            seed: 0,
            assert_alloc_bounded: true,
            queue_ops_floor: 0.0,
        }
    }

    /// CI-sized: fewer events, same 10⁶-device fleet (the fleet itself is
    /// lazy, so its size costs nothing).
    pub fn smoke() -> ShardBenchCfg {
        ShardBenchCfg { steps: 60, warmup: 20, ..ShardBenchCfg::megafleet() }
    }
}

#[derive(Clone, Debug)]
pub struct ShardBenchResult {
    pub cfg: ShardBenchCfg,
    /// worker-pool size of the measured environment (JSON `meta`)
    pub threads: usize,
    /// pool busy fraction over the bench (JSON `meta.pool_utilization`)
    pub pool_utilization: f64,
    pub fleet_size: u64,
    /// scheduler events/sec over the measured window
    pub events_per_sec: f64,
    /// allocations per event; `None` without the counting allocator
    pub allocs_per_event: Option<f64>,
    /// allocations per newly touched client over the window
    pub allocs_per_touch: Option<f64>,
    pub touched_clients: u64,
    pub resident_rows: u64,
    pub resident_bytes: u64,
    /// the headline scale number: resident client-state bytes over the
    /// whole fleet (copy-on-write ⇒ ≪ a dense row per device)
    pub resident_bytes_per_device: f64,
    pub mean_cohort: f64,
    pub link_shards: u64,
    /// timing-wheel vs binary-heap scheduler microbench (the
    /// `event_queue` JSON section)
    pub queue: QueueBenchResult,
}

/// Event-queue microbench: the timing wheel ([`EventQueue`]) against the
/// binary-heap oracle ([`HeapQueue`]) on a `megafleet-async`-shaped
/// stream — cohort-sized push bursts from the preset's device
/// distributions, `inflight` rounds overlapping before drains begin.
/// Both replay the identical pre-generated schedule; a separate untimed
/// pass asserts the pop sequences are bit-identical first.
#[derive(Clone, Debug)]
pub struct QueueBenchResult {
    pub scenario: String,
    /// total timed queue operations (pushes + pops, same for both queues)
    pub ops: u64,
    pub wheel_ops_per_sec: f64,
    pub heap_ops_per_sec: f64,
    /// high-water pending-event depth the stream reached
    pub max_depth: u64,
}

impl QueueBenchResult {
    pub fn speedup(&self) -> f64 {
        self.wheel_ops_per_sec / self.heap_ops_per_sec
    }
}

impl ShardBenchResult {
    pub fn to_json(&self) -> Value {
        let opt = |v: Option<f64>| v.map_or(Value::Null, Value::Num);
        Value::obj(vec![
            ("bench".into(), Value::Str("sharded_cohort_engine".into())),
            ("meta".into(), meta::bench_meta(self.threads, self.pool_utilization)),
            ("config".into(), Value::obj(vec![
                ("scenario".into(), Value::Str(self.cfg.scenario.clone())),
                ("steps".into(), Value::Num(self.cfg.steps as f64)),
                ("warmup".into(), Value::Num(self.cfg.warmup as f64)),
                ("rows_per_worker".into(),
                 Value::Num(self.cfg.rows_per_worker as f64)),
                ("seed".into(), Value::Num(self.cfg.seed as f64)),
            ])),
            ("fleet_size".into(), Value::Num(self.fleet_size as f64)),
            ("events_per_sec".into(), Value::Num(self.events_per_sec)),
            ("allocs_per_event".into(), opt(self.allocs_per_event)),
            ("allocs_per_touch".into(), opt(self.allocs_per_touch)),
            ("allocs_per_touch_bound".into(),
             Value::Num(SHARD_ALLOCS_PER_TOUCH_BOUND)),
            ("alloc_counting".into(),
             Value::Bool(self.allocs_per_event.is_some())),
            ("touched_clients".into(), Value::Num(self.touched_clients as f64)),
            ("resident_rows".into(), Value::Num(self.resident_rows as f64)),
            ("resident_bytes".into(), Value::Num(self.resident_bytes as f64)),
            ("resident_bytes_per_device".into(),
             Value::Num(self.resident_bytes_per_device)),
            ("mean_cohort".into(), Value::Num(self.mean_cohort)),
            ("link_shards".into(), Value::Num(self.link_shards as f64)),
            ("event_queue".into(), Value::obj(vec![
                ("scenario".into(), Value::Str(self.queue.scenario.clone())),
                ("ops".into(), Value::Num(self.queue.ops as f64)),
                ("wheel_ops_per_sec".into(),
                 Value::Num(self.queue.wheel_ops_per_sec)),
                ("heap_ops_per_sec".into(),
                 Value::Num(self.queue.heap_ops_per_sec)),
                ("speedup_vs_heap".into(), Value::Num(self.queue.speedup())),
                ("max_depth".into(), Value::Num(self.queue.max_depth as f64)),
            ])),
        ])
    }
}

/// Generate the `megafleet-async` arrival schedule once: `rounds` bursts
/// of `cohort` events each, event times drawn from the scenario's device
/// distributions (compute + latency + uplink transfer of one nominal
/// frame), the dispatch clock advancing by the fleet's mean step time per
/// round.
fn queue_bench_schedule(spec: &str, rounds: usize)
                        -> anyhow::Result<(sim::Scenario, Vec<f64>, usize, usize)> {
    let scenario = sim::scenario::from_spec(spec)?;
    let n = scenario.clients.max(1);
    let cohort =
        ((scenario.sample_frac * n as f64).ceil() as usize).clamp(1, n);
    let inflight = match scenario.async_sched {
        AsyncSchedule::Buffered { max_in_flight, .. } => max_in_flight.max(1),
        AsyncSchedule::RoundSync => 1,
    };
    // the async runner's uplink frame for the bench-sized model: 22-byte
    // header + payload; exact size only shifts the transfer term
    const FRAME_BITS: f64 = (22.0 + 139.0) * 8.0;
    let fleet_seed = 0xF1EE7u64;
    let mean_step = scenario.fleet.mean_step_time();
    let mut times = Vec::with_capacity(rounds * cohort);
    let mut clock = 0.0f64;
    for r in 0..rounds {
        for j in 0..cohort {
            let id = ((r * cohort + j) % n) as u64;
            let dev = scenario.fleet.device(fleet_seed, id);
            times.push(
                clock + dev.step_time_s + dev.latency_s + FRAME_BITS / dev.up_bps,
            );
        }
        clock += mean_step;
    }
    Ok((scenario, times, cohort, inflight))
}

/// Replay the schedule: burst-push each round's cohort, start draining a
/// cohort's worth per round once `inflight` rounds overlap, drain the
/// rest at the end. Identical op sequence for both queue types.
macro_rules! queue_replay {
    ($q:expr, $times:expr, $cohort:expr, $inflight:expr) => {{
        let q = $q;
        let mut ops = 0u64;
        for (r, chunk) in $times.chunks($cohort).enumerate() {
            for &t in chunk {
                q.push(t, 0u32);
                ops += 1;
            }
            if r + 1 >= $inflight {
                for _ in 0..$cohort {
                    if q.pop().is_some() {
                        ops += 1;
                    }
                }
            }
        }
        while q.pop().is_some() {
            ops += 1;
        }
        ops
    }};
}

/// Time the wheel and the heap on the same `megafleet-async`-shaped
/// stream. An untimed differential pass pins the pop sequences
/// bit-identical first, and each timed replay is preceded by an untimed
/// warmup replay on the same instance so bucket/heap capacities settle —
/// both sides measure steady-state scheduling only.
pub fn run_queue_bench(spec: &str, rounds: usize)
                       -> anyhow::Result<QueueBenchResult> {
    let (scenario, times, cohort, inflight) = queue_bench_schedule(spec, rounds)?;
    let granularity = EventQueue::<u32>::granularity_for(
        scenario.fleet.mean_step_time() + scenario.fleet.latency.mean(),
    );
    let cap = cohort * inflight;

    // differential pass: the wheel must pop bit-identically to the heap
    {
        let mut wheel = EventQueue::with_capacity_and_granularity(cap, granularity);
        let mut heap = HeapQueue::with_capacity(cap);
        for (r, chunk) in times.chunks(cohort).enumerate() {
            for (j, &t) in chunk.iter().enumerate() {
                wheel.push(t, j as u32);
                heap.push(t, j as u32);
            }
            if r + 1 >= inflight {
                for _ in 0..cohort {
                    let (w, h) = (wheel.pop(), heap.pop());
                    anyhow::ensure!(
                        w.map(|(t, v)| (t.to_bits(), v))
                            == h.map(|(t, v)| (t.to_bits(), v)),
                        "wheel diverged from heap oracle at round {r}: \
                         {w:?} vs {h:?}"
                    );
                }
            }
        }
        loop {
            let (w, h) = (wheel.pop(), heap.pop());
            anyhow::ensure!(
                w.map(|(t, v)| (t.to_bits(), v)) == h.map(|(t, v)| (t.to_bits(), v)),
                "wheel diverged from heap oracle in the final drain: {w:?} vs {h:?}"
            );
            if w.is_none() {
                break;
            }
        }
    }

    let mut wheel = EventQueue::with_capacity_and_granularity(cap, granularity);
    queue_replay!(&mut wheel, &times, cohort, inflight);
    let t0 = Instant::now();
    let wheel_ops = queue_replay!(&mut wheel, &times, cohort, inflight);
    let wheel_dt = t0.elapsed().as_secs_f64();

    let mut heap = HeapQueue::with_capacity(cap);
    queue_replay!(&mut heap, &times, cohort, inflight);
    let t0 = Instant::now();
    let heap_ops = queue_replay!(&mut heap, &times, cohort, inflight);
    let heap_dt = t0.elapsed().as_secs_f64();

    anyhow::ensure!(wheel_ops == heap_ops, "replays diverged in op count");
    Ok(QueueBenchResult {
        scenario: scenario.name.clone(),
        ops: wheel_ops,
        wheel_ops_per_sec: wheel_ops as f64 / wheel_dt.max(1e-12),
        heap_ops_per_sec: heap_ops as f64 / heap_dt.max(1e-12),
        max_depth: wheel.max_depth() as u64,
    })
}

/// Measure the sharded cohort engine under the mega-fleet scenario:
/// events/sec, resident-bytes/device, and the allocation discipline of
/// the O(cohort) hot loop (allocations bounded by new-client touches plus
/// the per-event scratch budget).
pub fn run_shard(cfg: &ShardBenchCfg) -> anyhow::Result<ShardBenchResult> {
    let scenario = sim::scenario::from_spec(&cfg.scenario)?;
    anyhow::ensure!(scenario.mega,
                    "the scale bench wants a mega scenario, got `{}`",
                    cfg.scenario);
    let mut sim_cfg = sim::SimCfg::fig3(scenario);
    sim_cfg.rows_per_worker = cfg.rows_per_worker;
    sim_cfg.seed = cfg.seed;
    let env = sim::runner::build_env(&sim_cfg);
    env.warm_caches();
    // profiling hooks for `meta.pool_utilization`
    env.pool.enable_profiling();
    let mut fsim = FleetSim::new(&sim_cfg, &env)?;
    // untimed warmup before the measured window
    fsim.run_steps(0, cfg.warmup)?;
    let counting = alloc_count::counting_enabled();
    let ev0 = fsim.stats().events;
    let touched0 = fsim.engine().touched_clients();
    let before = alloc_count::allocations();
    let t0 = Instant::now();
    fsim.run_steps(cfg.warmup, cfg.steps)?;
    let dt = t0.elapsed().as_secs_f64();
    let allocs = alloc_count::allocations() - before;
    let events = (fsim.stats().events - ev0).max(1);
    let touches = fsim.engine().touched_clients() - touched0;
    if cfg.assert_alloc_bounded && counting {
        let bound = touches as f64 * SHARD_ALLOCS_PER_TOUCH_BOUND
            + events as f64 * SIM_ALLOCS_PER_EVENT_BOUND;
        anyhow::ensure!(
            (allocs as f64) <= bound,
            "sharded engine allocated {allocs} times over {events} events / \
             {touches} new touches (bound {bound:.0})");
    }
    let store = fsim.engine().store();
    let fleet_size = store.len() as u64;
    let touched = fsim.engine().touched_clients();
    anyhow::ensure!(store.materialized_rows() <= touched,
                    "occupancy exceeds touched clients");

    // event-queue microbench on the megafleet-async stream shape (queue
    // ops only — no engine — so the scheduler swap is isolated); scale
    // the synthetic round count up from cfg.steps for a stable timing
    // window
    let queue = run_queue_bench("megafleet-async", cfg.steps as usize * 25)?;
    if cfg.queue_ops_floor > 0.0 {
        anyhow::ensure!(
            queue.wheel_ops_per_sec >= cfg.queue_ops_floor,
            "event-queue wheel measured {:.0} ops/sec, below the floor {:.0}",
            queue.wheel_ops_per_sec, cfg.queue_ops_floor
        );
    }

    Ok(ShardBenchResult {
        cfg: cfg.clone(),
        threads: env.pool.size(),
        pool_utilization: env.pool.utilization(),
        fleet_size,
        events_per_sec: events as f64 / dt,
        allocs_per_event: counting.then(|| allocs as f64 / events as f64),
        allocs_per_touch: counting.then(|| allocs as f64 / touches.max(1) as f64),
        touched_clients: touched as u64,
        resident_rows: store.materialized_rows() as u64,
        resident_bytes: store.resident_bytes() as u64,
        resident_bytes_per_device: store.resident_bytes() as f64
            / fleet_size.max(1) as f64,
        mean_cohort: fsim.stats().mean_participants(),
        link_shards: fsim.engine().net().n_shards() as u64,
        queue,
    })
}

/// Run the scale section and write `BENCH_shard.json`.
pub fn run_and_write_shard(cfg: &ShardBenchCfg, out_path: &str)
                           -> anyhow::Result<ShardBenchResult> {
    let res = run_shard(cfg)?;
    let mut text = res.to_json().to_string_pretty();
    text.push('\n');
    std::fs::write(out_path, text)
        .map_err(|e| anyhow::anyhow!("write {out_path}: {e}"))?;
    Ok(res)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_bench_runs_and_reports() {
        let mut cfg = BenchCfg::smoke();
        // keep the unit test fast: tiny shards, few steps
        cfg.rows_per_worker = 40;
        cfg.steps = 60;
        cfg.warmup = 30;
        cfg.ref_steps = 20;
        let res = run(&cfg).unwrap();
        assert!(res.engine_steps_per_sec > 0.0);
        assert!(res.engine_paired_steps_per_sec > 0.0);
        assert!(res.reference_steps_per_sec > 0.0);
        assert!(res.final_personal_loss.is_finite());
        assert!(res.sim_events_per_sec > 0.0);
        // the counting allocator is not installed in the test binary
        assert!(res.engine_allocs_per_step.is_none());
        assert!(res.sim_allocs_per_event.is_none());
        let v = res.to_json();
        assert_eq!(v.get("bench").unwrap().as_str(), Some("round_engine"));
        let m = v.get("meta").unwrap();
        assert!(m.get("threads").unwrap().as_usize().unwrap() >= 1);
        assert!(m.get("cpu_features").unwrap().as_str().is_some());
        assert!(m.get("git_rev").unwrap().as_str().is_some());
        let util = m.get("pool_utilization").unwrap().as_f64().unwrap();
        assert!((0.0..=1.0).contains(&util), "pool_utilization {util}");
        assert!(v.get("speedup_vs_reference").unwrap().as_f64().unwrap() > 0.0);
        let s = v.get("sim_scheduler").unwrap();
        assert_eq!(s.get("scenario").unwrap().as_str(), Some("straggler-heavy"));
        assert!(s.get("events_per_sec").unwrap().as_f64().unwrap() > 0.0);
        // the multi-algorithm section carries one events/sec entry per
        // registered fleet algorithm
        let algs = v.get("sim_algorithms").unwrap();
        for &name in crate::algorithms::FLEET_ALGS {
            assert!(algs.get(name).unwrap().as_f64().unwrap() > 0.0,
                    "sim_algorithms must report `{name}`");
        }
        // the async-runtime section reports throughput and proves the
        // buffered-apply path actually ran
        let a = v.get("async_scheduler").unwrap();
        assert_eq!(a.get("scenario").unwrap().as_str(), Some("async-bursty"));
        assert!(a.get("events_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert!(a.get("applied_updates").unwrap().as_f64().unwrap() > 0.0);
        assert!(res.async_allocs_per_event.is_none());
        let c = v.get("config").unwrap();
        assert_eq!(c.get("n_clients").unwrap().as_usize(), Some(5));
    }

    /// Scale section: the 10⁶-device sharded engine bench completes in
    /// CI-test time, reports a sparse store, and its JSON roundtrips.
    #[test]
    fn shard_smoke_bench_runs_and_reports() {
        let mut cfg = ShardBenchCfg::smoke();
        cfg.steps = 30;
        cfg.warmup = 10;
        let res = run_shard(&cfg).unwrap();
        assert_eq!(res.fleet_size, 1_000_000);
        assert!(res.events_per_sec > 0.0);
        assert!(res.touched_clients > 0);
        assert!(res.resident_rows <= res.touched_clients);
        // copy-on-write: a dense row would be 123·4 ≈ 492 B/device; the
        // sparse store must sit far below one row per fleet device
        assert!(res.resident_bytes_per_device < 50.0,
                "resident {} B/device", res.resident_bytes_per_device);
        // the counting allocator is not installed in the test binary
        assert!(res.allocs_per_event.is_none());
        let v = res.to_json();
        assert_eq!(v.get("bench").unwrap().as_str(),
                   Some("sharded_cohort_engine"));
        assert!(v.get("meta").unwrap().get("threads").unwrap()
                 .as_usize().unwrap() >= 1);
        assert!(v.get("meta").unwrap().get("pool_utilization").unwrap()
                 .as_f64().is_some());
        let text = v.to_string_pretty();
        let parsed = crate::util::json::parse(&text).unwrap();
        assert!(parsed.get("events_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert!(parsed.get("link_shards").unwrap().as_f64().unwrap() > 1.0);
        // the event-queue microbench rode along and pinned the wheel to
        // the heap oracle before timing either
        let q = parsed.get("event_queue").unwrap();
        assert_eq!(q.get("scenario").unwrap().as_str(), Some("megafleet-async"));
        assert!(q.get("ops").unwrap().as_f64().unwrap() > 0.0);
        assert!(q.get("wheel_ops_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert!(q.get("heap_ops_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert!(q.get("speedup_vs_heap").unwrap().as_f64().unwrap()
                 .is_finite());
        assert!(q.get("max_depth").unwrap().as_f64().unwrap() > 0.0);
    }

    /// The queue microbench's differential pass is itself a test: the
    /// wheel pops bit-identically to the heap on the megafleet-async
    /// arrival stream, and an armed floor rejects an absurd demand.
    #[test]
    fn queue_bench_pins_wheel_to_heap_and_floors_arm() {
        let res = run_queue_bench("megafleet-async", 200).unwrap();
        assert_eq!(res.scenario, "megafleet-async");
        assert!(res.ops > 0);
        assert!(res.wheel_ops_per_sec > 0.0);
        assert!(res.heap_ops_per_sec > 0.0);
        assert!(res.speedup().is_finite());
        // inflight bursts overlap, so the high-water mark spans several
        // cohorts of 200
        assert!(res.max_depth >= 200, "max_depth {}", res.max_depth);

        let mut cfg = ShardBenchCfg::smoke();
        cfg.steps = 20;
        cfg.warmup = 5;
        cfg.queue_ops_floor = f64::INFINITY;
        let err = run_shard(&cfg).unwrap_err().to_string();
        assert!(err.contains("below the floor"), "{err}");
    }

    #[test]
    fn json_roundtrips_through_parser() {
        let mut cfg = BenchCfg::smoke();
        cfg.rows_per_worker = 40;
        cfg.steps = 40;
        cfg.warmup = 20;
        cfg.ref_steps = 10;
        let res = run(&cfg).unwrap();
        let text = res.to_json().to_string_pretty();
        let v = crate::util::json::parse(&text).unwrap();
        assert!(v.get("engine").unwrap().get("steps_per_sec").unwrap()
                 .as_f64().unwrap() > 0.0);
    }
}
