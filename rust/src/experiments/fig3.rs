//! Fig 3 (meta-parameter study, §VII-A): uncompressed L2GD on a1a/a2a-shaped
//! logistic regression, n = 5 workers, K = 100 iterations, L₂ = 0.01.
//!
//! (a/c): loss f vs p at fixed λ; (b/d): loss f vs λ at fixed p = 0.65.
//! The loss reported is the personalized objective f(x) = (1/n)Σ f_i(x_i),
//! exactly what the paper plots.

use std::sync::Arc;

use crate::algorithms::{FedAlgorithm, FedEnv, L2gd};
use crate::data::synth;
use crate::runtime::NativeLogreg;
use crate::util::threadpool::ThreadPool;

#[derive(Clone, Debug)]
pub struct Fig3Cfg {
    /// 321 for a1a, 453 for a2a
    pub rows_per_worker: usize,
    pub n_clients: usize,
    pub iters: u64,
    /// fixed stepsize η — the sweep varies (p, λ) at constant η, which is
    /// what produces the paper's interior optimum: small p underfits in K
    /// iterations, large p pushes η/(n(1−p)) toward instability
    pub eta: f64,
    /// per-worker hyperplane tilt: a1a's natural worker heterogeneity
    pub hetero: f32,
    pub seed: u64,
    /// compressor specs (Fig 3 uses identity = uncompressed)
    pub client_comp: String,
    pub master_comp: String,
}

impl Fig3Cfg {
    pub fn a1a() -> Fig3Cfg {
        Fig3Cfg {
            rows_per_worker: 321,
            n_clients: 5,
            iters: 100,
            eta: 1.0,
            hetero: 0.8,
            seed: 0,
            client_comp: "identity".into(),
            master_comp: "identity".into(),
        }
    }

    pub fn a2a() -> Fig3Cfg {
        Fig3Cfg { rows_per_worker: 453, ..Fig3Cfg::a1a() }
    }
}

/// Build the heterogeneous Fig-3 environment (d = 123, a1a-style noise
/// and tilt). Public: `pfl bench` measures the round engine on exactly
/// this configuration, so the two must never drift apart.
pub fn build_env(cfg: &Fig3Cfg) -> FedEnv {
    let (shards, test) = synth::logistic_hetero(
        cfg.n_clients, cfg.rows_per_worker, 64, 123, 0.05, cfg.hetero, cfg.seed);
    let mut train_eval = shards[0].clone();
    for s in &shards[1..] {
        train_eval.features.extend_from_slice(&s.features);
        train_eval.labels.extend_from_slice(&s.labels);
    }
    FedEnv::new(
        Arc::new(NativeLogreg::new(
            123, 0.01, cfg.rows_per_worker.next_power_of_two().max(64), 2048)),
        shards,
        train_eval,
        test,
        ThreadPool::new(ThreadPool::default_size()),
        cfg.seed,
    )
}

/// λ such that ηλ/np ≥ 2 would make the aggregation step diverge; the
/// practitioner regime (paper §VII-B) clamps the effective step at the
/// stability edge. Keeps every grid (and bench) point well-defined.
pub fn clamp_agg_stability(alg: &mut L2gd, n: usize) {
    let agg = alg.agg_coef(n);
    if agg >= 1.9 {
        alg.lambda = alg.lambda * 1.9 / agg;
    }
}

/// Final personalized loss after K iterations at (p, λ).
pub fn loss_at(cfg: &Fig3Cfg, p: f64, lambda: f64) -> anyhow::Result<f64> {
    let env = build_env(cfg);
    let mut alg = L2gd::new(p, lambda, cfg.eta, cfg.n_clients,
                            &cfg.client_comp, &cfg.master_comp)?;
    clamp_agg_stability(&mut alg, cfg.n_clients);
    let series = alg.run(&env, cfg.iters, cfg.iters)?;
    Ok(series.records.last().unwrap().personal_loss)
}

/// Sweep loss vs p at fixed λ (Fig 3 a/c).
pub fn sweep_p(cfg: &Fig3Cfg, lambda: f64, ps: &[f64])
               -> anyhow::Result<Vec<(f64, f64)>> {
    ps.iter()
        .map(|&p| loss_at(cfg, p, lambda).map(|l| (p, l)))
        .collect()
}

/// Sweep loss vs λ at fixed p (Fig 3 b/d).
pub fn sweep_lambda(cfg: &Fig3Cfg, p: f64, lambdas: &[f64])
                    -> anyhow::Result<Vec<(f64, f64)>> {
    lambdas
        .iter()
        .map(|&l| loss_at(cfg, p, l).map(|loss| (l, loss)))
        .collect()
}

/// The paper's grids.
pub fn default_p_grid() -> Vec<f64> {
    (1..=18).map(|i| i as f64 * 0.05).collect() // 0.05 .. 0.90
}

pub fn default_lambda_grid() -> Vec<f64> {
    vec![0.0, 0.1, 0.5, 1.0, 2.0, 5.0, 10.0, 15.0, 20.0, 25.0]
}

/// Write both sweeps for one dataset as CSV; returns (p-sweep, λ-sweep).
pub fn run_and_write(cfg: &Fig3Cfg, tag: &str, out_dir: &str)
                     -> anyhow::Result<(Vec<(f64, f64)>, Vec<(f64, f64)>)> {
    let p_sweep = sweep_p(cfg, 10.0, &default_p_grid())?;
    let l_sweep = sweep_lambda(cfg, 0.65, &default_lambda_grid())?;
    std::fs::create_dir_all(out_dir)?;
    let mut csv = String::from("sweep,x,loss\n");
    for (p, loss) in &p_sweep {
        csv.push_str(&format!("p,{p:.3},{loss:.6}\n"));
    }
    for (l, loss) in &l_sweep {
        csv.push_str(&format!("lambda,{l:.3},{loss:.6}\n"));
    }
    std::fs::write(format!("{out_dir}/fig3_{tag}.csv"), csv)?;
    Ok((p_sweep, l_sweep))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_sweep_has_interior_structure() {
        // scaled-down a1a: the response over p must not be flat, and some
        // interior p must beat the no-communication end (the paper's
        // "small p is not good" takeaway).
        let cfg = Fig3Cfg {
            rows_per_worker: 60,
            iters: 60,
            ..Fig3Cfg::a1a()
        };
        let pts = sweep_p(&cfg, 10.0, &[0.05, 0.4, 0.9]).unwrap();
        let losses: Vec<f64> = pts.iter().map(|x| x.1).collect();
        assert!(losses.iter().all(|l| l.is_finite()));
        let spread = losses.iter().cloned().fold(f64::MIN, f64::max)
            - losses.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread > 1e-4, "flat response {losses:?}");
    }

    #[test]
    fn lambda_zero_vs_large_differ() {
        let cfg = Fig3Cfg { rows_per_worker: 60, iters: 60, ..Fig3Cfg::a1a() };
        let l0 = loss_at(&cfg, 0.65, 0.0).unwrap();
        let l25 = loss_at(&cfg, 0.65, 25.0).unwrap();
        assert!((l0 - l25).abs() > 1e-5, "λ has no effect: {l0} vs {l25}");
    }
}
