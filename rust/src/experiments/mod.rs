//! Experiment harnesses: one per paper table/figure (DESIGN.md §6).
//!
//! Every harness is scale-parameterized: `cargo bench` runs scaled-down
//! versions that print the paper's rows/series; `pfl repro <id>` runs the
//! full configuration and writes CSVs under `results/`.

pub mod bench_kernels;
pub mod bench_round;
pub mod dnn;
pub mod fig2;
pub mod fig3;
pub mod fig78;
pub mod perf_compare;
pub mod table1;
