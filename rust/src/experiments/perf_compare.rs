//! `pfl bench --compare <baseline>` — delta-per-benchmark reporting.
//!
//! Compares the JSON the current bench run just emitted against a
//! committed baseline set (`BENCH_round.json` / `BENCH_shard.json` /
//! `BENCH_kernels.json`), renders a markdown table (`perf.md`) with one
//! row per benchmark, and fails the run when any **tracked** headline
//! number regresses by more than [`REGRESSION_TOLERANCE`].
//!
//! Tracked metrics (the numbers CI guards):
//!
//! * round — `engine.steps_per_sec`, `sim_scheduler.events_per_sec`,
//!   `async_scheduler.events_per_sec`
//! * shard — `events_per_sec` (megafleet events/sec) and
//!   `event_queue.wheel_ops_per_sec` (timing-wheel scheduler ops/sec)
//! * kernels — per-kernel GB/s at the *current* active dispatch level
//!
//! Everything else in the files (reference loop, natural wire, per-level
//! kernel numbers, sim_algorithms) is reported informationally — visible
//! drift, but machine differences there don't fail CI. A baseline file
//! that predates a section (or was recorded at a different CPU feature
//! level) simply yields blank baseline cells: comparison never demands
//! history that doesn't exist.

use crate::util::json::{self, Value};

/// A tracked metric may drop this fraction below baseline before the
/// comparison fails (bench noise on shared CI runners is real; a genuine
/// perf bug is rarely subtle).
pub const REGRESSION_TOLERANCE: f64 = 0.10;

/// The three benchmark files a baseline set can carry. Any of them may be
/// absent — older baselines predate newer sections.
#[derive(Debug, Default)]
pub struct BaselineSet {
    pub round: Option<Value>,
    pub shard: Option<Value>,
    pub kernels: Option<Value>,
    /// where the set was loaded from, for the report header
    pub source: String,
}

impl BaselineSet {
    /// Load from a path that is either a directory holding the standard
    /// `BENCH_*.json` names, or one of the files (its siblings are picked
    /// up from the same directory). Individual files are slotted by their
    /// `"bench"` tag, so renamed baselines still land in the right spot.
    pub fn load(path: &str) -> anyhow::Result<BaselineSet> {
        let p = std::path::Path::new(path);
        anyhow::ensure!(p.exists(), "baseline path `{path}` does not exist");
        let dir = if p.is_dir() {
            p.to_path_buf()
        } else {
            p.parent()
                .filter(|d| !d.as_os_str().is_empty())
                .map(|d| d.to_path_buf())
                .unwrap_or_else(|| std::path::PathBuf::from("."))
        };
        let mut set = BaselineSet { source: path.to_string(), ..Default::default() };
        for name in ["BENCH_round.json", "BENCH_shard.json", "BENCH_kernels.json"] {
            if let Ok(text) = std::fs::read_to_string(dir.join(name)) {
                set.slot(json::parse(&text).map_err(|e| {
                    anyhow::anyhow!("baseline {name}: {e}")
                })?);
            }
        }
        if p.is_file() {
            let text = std::fs::read_to_string(p)
                .map_err(|e| anyhow::anyhow!("baseline {path}: {e}"))?;
            set.slot(json::parse(&text)
                .map_err(|e| anyhow::anyhow!("baseline {path}: {e}"))?);
        }
        anyhow::ensure!(
            set.round.is_some() || set.shard.is_some() || set.kernels.is_some(),
            "no BENCH_*.json baselines found at `{path}`"
        );
        Ok(set)
    }

    /// Place a parsed document by its `"bench"` tag.
    fn slot(&mut self, v: Value) {
        match v.get("bench").and_then(Value::as_str) {
            Some("round_engine") => self.round = Some(v),
            Some("sharded_cohort_engine") => self.shard = Some(v),
            Some("kernels") => self.kernels = Some(v),
            _ => {}
        }
    }
}

/// One comparison row: a metric in both (or either) run.
#[derive(Clone, Debug)]
pub struct MetricRow {
    pub section: &'static str,
    pub name: String,
    pub baseline: Option<f64>,
    pub current: Option<f64>,
    /// tracked rows participate in the regression gate
    pub tracked: bool,
}

impl MetricRow {
    /// Fractional change vs baseline (`+0.05` = 5% faster); `None` when
    /// either side is missing or the baseline is non-positive.
    pub fn delta(&self) -> Option<f64> {
        match (self.baseline, self.current) {
            (Some(b), Some(c)) if b > 0.0 => Some(c / b - 1.0),
            _ => None,
        }
    }

    /// A tracked row that dropped more than `tol` below its baseline.
    pub fn regressed(&self, tol: f64) -> bool {
        self.tracked && self.delta().is_some_and(|d| d < -tol)
    }
}

/// The full comparison: rows plus the metadata of both sides.
#[derive(Debug)]
pub struct Comparison {
    pub rows: Vec<MetricRow>,
    pub baseline_source: String,
    pub baseline_meta: String,
    pub current_meta: String,
}

impl Comparison {
    pub fn regressions(&self) -> Vec<&MetricRow> {
        self.rows
            .iter()
            .filter(|r| r.regressed(REGRESSION_TOLERANCE))
            .collect()
    }

    /// Err (one line per offending metric) when a tracked headline
    /// regressed beyond tolerance — this is what flips CI red.
    pub fn check(&self) -> anyhow::Result<()> {
        let bad = self.regressions();
        if bad.is_empty() {
            return Ok(());
        }
        let lines: Vec<String> = bad
            .iter()
            .map(|r| {
                format!(
                    "{}/{} {} (baseline {}, current {})",
                    r.section,
                    r.name,
                    fmt_delta(r.delta()),
                    fmt_num(r.baseline),
                    fmt_num(r.current)
                )
            })
            .collect();
        anyhow::bail!(
            "tracked perf regression beyond {:.0}%: {}",
            REGRESSION_TOLERANCE * 100.0,
            lines.join("; ")
        )
    }

    /// Render the delta table as markdown (`perf.md`).
    pub fn to_markdown(&self) -> String {
        let mut md = String::from("# pfl bench comparison\n\n");
        md.push_str(&format!("- baseline: `{}` — {}\n",
                             self.baseline_source, self.baseline_meta));
        md.push_str(&format!("- current: {}\n", self.current_meta));
        md.push_str(&format!(
            "- gate: tracked metrics may not drop more than {:.0}% below \
             baseline\n\n",
            REGRESSION_TOLERANCE * 100.0
        ));
        md.push_str("| section | benchmark | baseline | current | delta | tracked |\n");
        md.push_str("|---|---|---:|---:|---:|:---:|\n");
        for r in &self.rows {
            let mark = if r.regressed(REGRESSION_TOLERANCE) {
                " ⚠"
            } else {
                ""
            };
            md.push_str(&format!(
                "| {} | {} | {} | {} | {}{} | {} |\n",
                r.section,
                r.name,
                fmt_num(r.baseline),
                fmt_num(r.current),
                fmt_delta(r.delta()),
                mark,
                if r.tracked { "yes" } else { "" }
            ));
        }
        md.push('\n');
        let bad = self.regressions();
        if bad.is_empty() {
            md.push_str("**OK** — no tracked metric regressed beyond tolerance.\n");
        } else {
            md.push_str(&format!(
                "**REGRESSION** — {} tracked metric(s) beyond tolerance: {}\n",
                bad.len(),
                bad.iter()
                    .map(|r| format!("{}/{}", r.section, r.name))
                    .collect::<Vec<_>>()
                    .join(", ")
            ));
        }
        md
    }
}

fn fmt_num(v: Option<f64>) -> String {
    match v {
        None => "—".into(),
        Some(x) if x.abs() >= 1000.0 => format!("{x:.0}"),
        Some(x) => format!("{x:.3}"),
    }
}

fn fmt_delta(d: Option<f64>) -> String {
    match d {
        None => "—".into(),
        Some(d) => format!("{:+.1}%", d * 100.0),
    }
}

/// Number at a dotted path into nested JSON objects.
fn num_at(v: Option<&Value>, path: &str) -> Option<f64> {
    let mut cur = v?;
    for key in path.split('.') {
        cur = cur.get(key)?;
    }
    cur.as_f64()
}

/// One-line description of a bench document's `meta` block.
fn meta_line(v: Option<&Value>) -> String {
    let Some(m) = v.and_then(|v| v.get("meta")) else {
        return "no metadata recorded".into();
    };
    let s = |k: &str| m.get(k).and_then(Value::as_str).unwrap_or("?").to_string();
    let threads = m
        .get("threads")
        .and_then(Value::as_usize)
        .map_or("?".into(), |t| t.to_string());
    format!("git {}, {} threads, kernels {}",
            s("git_rev"), threads, s("cpu_features"))
}

/// Build the comparison from the baseline set and the three documents the
/// current run just produced (pass what ran; `None` skips the section).
pub fn compare(
    baseline: &BaselineSet,
    round: Option<&Value>,
    shard: Option<&Value>,
    kernels: Option<&Value>,
) -> Comparison {
    let mut rows = Vec::new();
    let mut row = |section: &'static str, name: &str,
                   b: Option<&Value>, c: Option<&Value>,
                   path: &str, tracked: bool,
                   rows: &mut Vec<MetricRow>| {
        let baseline = num_at(b, path);
        let current = num_at(c, path);
        if baseline.is_some() || current.is_some() {
            rows.push(MetricRow {
                section,
                name: name.to_string(),
                baseline,
                current,
                tracked,
            });
        }
    };

    let (b, c) = (baseline.round.as_ref(), round);
    for (path, tracked) in [
        ("engine.steps_per_sec", true),
        ("sim_scheduler.events_per_sec", true),
        ("async_scheduler.events_per_sec", true),
        ("engine_natural.steps_per_sec", false),
        ("engine_paired.steps_per_sec", false),
        ("reference.steps_per_sec", false),
        ("speedup_vs_reference", false),
        ("sim_algorithms.fedavg", false),
        ("sim_algorithms.fedopt", false),
    ] {
        row("round", path, b, c, path, tracked, &mut rows);
    }

    let (b, c) = (baseline.shard.as_ref(), shard);
    for (path, tracked) in [
        ("events_per_sec", true),
        ("event_queue.wheel_ops_per_sec", true),
        ("event_queue.speedup_vs_heap", false),
        ("resident_bytes_per_device", false),
        ("touched_clients", false),
    ] {
        row("shard", path, b, c, path, tracked, &mut rows);
    }

    let (b, c) = (baseline.kernels.as_ref(), kernels);
    // tracked at the level the *current* run dispatches to; a baseline from
    // a different machine simply has no matching key and the row degrades
    // to informational (regressed() needs both sides)
    let active = c
        .and_then(|v| v.get("active_level"))
        .and_then(Value::as_str)
        .unwrap_or("scalar")
        .to_string();
    for kernel in super::bench_kernels::KERNEL_NAMES {
        let path = format!("kernels.{kernel}.gbps_{active}");
        row("kernels", &path["kernels.".len()..], b, c, &path, true, &mut rows);
        if active != "scalar" {
            let spath = format!("kernels.{kernel}.gbps_scalar");
            row("kernels", &spath["kernels.".len()..], b, c, &spath, false,
                &mut rows);
        }
        let sp = format!("speedup_active_vs_scalar.{kernel}");
        row("kernels", &format!("{kernel}.speedup_vs_scalar"), b, c, &sp,
            false, &mut rows);
    }

    Comparison {
        rows,
        baseline_source: baseline.source.clone(),
        baseline_meta: meta_line(
            baseline.round.as_ref()
                .or_else(|| baseline.kernels.as_ref())
                .or_else(|| baseline.shard.as_ref()),
        ),
        current_meta: meta_line(kernels.or(round).or(shard)),
    }
}

/// Write `perf.md` and return the comparison for the regression gate.
pub fn write_markdown(cmp: &Comparison, path: &str) -> anyhow::Result<()> {
    std::fs::write(path, cmp.to_markdown())
        .map_err(|e| anyhow::anyhow!("write {path}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(bench: &str, pairs: &[(&str, Value)]) -> Value {
        let mut obj = vec![("bench".to_string(), Value::Str(bench.into()))];
        obj.extend(pairs.iter().map(|(k, v)| (k.to_string(), v.clone())));
        Value::obj(obj)
    }

    fn round_doc(steps_per_sec: f64) -> Value {
        doc("round_engine", &[
            ("engine", Value::obj(vec![
                ("steps_per_sec".into(), Value::Num(steps_per_sec)),
            ])),
            ("sim_scheduler", Value::obj(vec![
                ("events_per_sec".into(), Value::Num(500.0)),
            ])),
            ("async_scheduler", Value::obj(vec![
                ("events_per_sec".into(), Value::Num(400.0)),
            ])),
            ("meta", Value::obj(vec![
                ("threads".into(), Value::Num(4.0)),
                ("cpu_features".into(), Value::Str("avx2".into())),
                ("git_rev".into(), Value::Str("abc1234".into())),
            ])),
        ])
    }

    #[test]
    fn regression_beyond_tolerance_fails_the_gate() {
        let base = BaselineSet {
            round: Some(round_doc(1000.0)),
            source: "test".into(),
            ..Default::default()
        };
        // 20% slower on a tracked headline
        let cur = round_doc(800.0);
        let cmp = compare(&base, Some(&cur), None, None);
        assert_eq!(cmp.regressions().len(), 1);
        let err = cmp.check().unwrap_err().to_string();
        assert!(err.contains("engine.steps_per_sec"), "{err}");
        assert!(cmp.to_markdown().contains("REGRESSION"));
    }

    #[test]
    fn within_tolerance_passes() {
        let base = BaselineSet {
            round: Some(round_doc(1000.0)),
            source: "test".into(),
            ..Default::default()
        };
        let cur = round_doc(950.0); // -5% < 10% tolerance
        let cmp = compare(&base, Some(&cur), None, None);
        assert!(cmp.check().is_ok());
        let md = cmp.to_markdown();
        assert!(md.contains("| round | engine.steps_per_sec |"), "{md}");
        assert!(md.contains("-5.0%"), "{md}");
        assert!(md.contains("**OK**"), "{md}");
    }

    #[test]
    fn missing_sections_degrade_to_blank_cells() {
        // baseline has only the round file; current also ran kernels
        let base = BaselineSet {
            round: Some(round_doc(1000.0)),
            source: "test".into(),
            ..Default::default()
        };
        let kernels = doc("kernels", &[
            ("active_level", Value::Str("avx2".into())),
            ("kernels", Value::obj(vec![("dot".into(), Value::obj(vec![
                ("gbps_avx2".into(), Value::Num(30.0)),
                ("gbps_scalar".into(), Value::Num(10.0)),
            ]))])),
            ("speedup_active_vs_scalar", Value::obj(vec![
                ("dot".into(), Value::Num(3.0)),
            ])),
        ]);
        let cur = round_doc(1000.0);
        let cmp = compare(&base, Some(&cur), None, Some(&kernels));
        // kernel rows exist with no baseline ⇒ informational, not failing
        let dot = cmp.rows.iter()
            .find(|r| r.section == "kernels" && r.name == "dot.gbps_avx2")
            .unwrap();
        assert!(dot.tracked && dot.baseline.is_none() && !dot.regressed(0.1));
        assert!(cmp.check().is_ok());
        assert!(cmp.to_markdown().contains("| kernels | dot.gbps_avx2 | — |"));
    }

    #[test]
    fn baseline_loader_slots_by_bench_tag() {
        let dir = std::env::temp_dir().join("pfl_perf_compare_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_round.json");
        std::fs::write(&path, round_doc(1234.0).to_string_pretty()).unwrap();
        let set = BaselineSet::load(dir.to_str().unwrap()).unwrap();
        assert!(set.round.is_some());
        assert!(set.shard.is_none() && set.kernels.is_none());
        // loading via the file path finds the same sibling set
        let set2 = BaselineSet::load(path.to_str().unwrap()).unwrap();
        assert!(set2.round.is_some());
        std::fs::remove_dir_all(&dir).ok();
        assert!(BaselineSet::load("/no/such/dir").is_err());
    }
}
