//! Deterministic pseudo-random generation (no `rand` crate offline).
//!
//! `Rng` is xoshiro256++ seeded through splitmix64, the standard pairing:
//! splitmix64 whitens arbitrary u64 seeds into the 256-bit xoshiro state.
//! Everything downstream (client sampling, the ξ_k protocol coin, the
//! stochastic compressors, data synthesis) draws from this type, so entire
//! experiments replay bit-exactly from a single seed.

/// splitmix64 step — used for seeding and as a cheap standalone mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The `stream`-th child seed of `base`, as a pure function — O(1) random
/// access, no parent generator to advance.
///
/// [`Rng::fork`] derives child streams by *drawing* from the parent, so
/// stream i costs i sequential draws and every consumer must walk the
/// prefix. Million-device fleets need the opposite: device i's streams
/// (profile draw, batch sampling, compression randomness) must be
/// derivable on first touch, in any order, at O(1) — that is what makes
/// lazy cohort materialization possible. By construction the derivation is
/// prefix-stable: the seed for stream i is independent of how many streams
/// exist, so a fleet of n devices is a prefix of the fleet of 2n (pinned
/// by the statistical suite).
#[inline]
pub fn stream_seed(base: u64, stream: u64) -> u64 {
    let mut s = base ^ stream.wrapping_mul(0xA24B_AED4_963E_E407);
    splitmix64(&mut s)
}

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller variate
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Seed via splitmix64 so correlated integer seeds (0, 1, 2, ...) give
    /// uncorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent child stream (`stream` tags e.g. a client id).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let base = self.next_u64();
        Rng::new(base ^ stream.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// Independent child stream by index without a parent generator —
    /// the random-access counterpart of [`Rng::fork`] (see [`stream_seed`]).
    /// Device/client state that must materialize lazily (sharded cohort
    /// engine, lazy fleet profiles) is seeded through this.
    pub fn stream(base: u64, stream: u64) -> Rng {
        Rng::new(stream_seed(base, stream))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire reduction).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    #[inline]
    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (spare cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.gauss_spare = Some(r * s);
            return r * c;
        }
    }

    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (with Johnk boost for shape < 1).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // G(a) = G(a+1) * U^(1/a)
            let u = self.f64().max(f64::MIN_POSITIVE);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Dirichlet(α, ..., α) over k categories — the heterogeneity sampler
    /// used by the CIFAR partitioner (α = 0.5 in the paper).
    pub fn dirichlet_sym(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let sum: f64 = g.iter().sum();
        if sum <= 0.0 {
            // pathological underflow: fall back to a one-hot draw
            let mut out = vec![0.0; k];
            out[self.usize_below(k)] = 1.0;
            return out;
        }
        for v in &mut g {
            *v /= sum;
        }
        g
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx = Vec::new();
        self.sample_indices_into(n, k, &mut idx);
        idx
    }

    /// `sample_indices` into a caller-owned buffer (same draws, same
    /// result) — lets the rand-k wire path reuse its scratch across rounds.
    pub fn sample_indices_into(&mut self, n: usize, k: usize, idx: &mut Vec<usize>) {
        assert!(k <= n);
        idx.clear();
        idx.extend(0..n);
        for i in 0..k {
            let j = i + self.usize_below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
    }

    /// Fill a slice with uniform [0,1) f32s.
    pub fn fill_uniform_f32(&mut self, out: &mut [f32]) {
        for v in out {
            *v = self.f32();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_f64_in_range_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut r = Rng::new(1);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(5);
        for &shape in &[0.5, 1.0, 2.5] {
            let n = 30_000;
            let mean: f64 = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!((mean - shape).abs() < 0.05 * shape.max(1.0),
                    "shape={shape} mean={mean}");
        }
    }

    #[test]
    fn dirichlet_sums_to_one_and_mean_uniform() {
        let mut r = Rng::new(9);
        let k = 10;
        let mut acc = vec![0.0; k];
        for _ in 0..2000 {
            let d = r.dirichlet_sym(0.5, k);
            let s: f64 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            for (a, v) in acc.iter_mut().zip(&d) {
                *a += v;
            }
        }
        for a in &acc {
            assert!((a / 2000.0 - 0.1).abs() < 0.02);
        }
    }

    #[test]
    fn dirichlet_low_alpha_is_spiky() {
        // α = 0.1 should concentrate mass: max component usually > 0.5
        let mut r = Rng::new(2);
        let mut spiky = 0;
        for _ in 0..200 {
            let d = r.dirichlet_sym(0.1, 10);
            if d.iter().cloned().fold(0.0, f64::max) > 0.5 {
                spiky += 1;
            }
        }
        assert!(spiky > 120, "spiky={spiky}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(13);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut u = s.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), 20);
    }

    #[test]
    fn stream_is_random_access_and_order_free() {
        // the i-th stream is a pure function of (base, i): the same seed
        // regardless of which other streams were derived first
        let a = stream_seed(99, 5);
        let _ = stream_seed(99, 123_456_789);
        assert_eq!(stream_seed(99, 5), a);
        let mut r1 = Rng::stream(7, 3);
        let mut r2 = Rng::stream(7, 3);
        for _ in 0..16 {
            assert_eq!(r1.next_u64(), r2.next_u64());
        }
        // neighbouring streams decorrelate
        let mut r3 = Rng::stream(7, 3);
        let mut r4 = Rng::stream(7, 4);
        let x: Vec<u64> = (0..8).map(|_| r3.next_u64()).collect();
        let y: Vec<u64> = (0..8).map(|_| r4.next_u64()).collect();
        assert_ne!(x, y);
    }

    #[test]
    fn fork_streams_differ() {
        let mut root = Rng::new(0);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let xa: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let xb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xa, xb);
    }
}
