//! Counting global allocator shared by the perf harnesses.
//!
//! Binaries that want allocation accounting install [`CountingAlloc`]:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: pfl::util::alloc_count::CountingAlloc =
//!     pfl::util::alloc_count::CountingAlloc;
//! ```
//!
//! The `pfl` launcher and `benches/perf_round_latency.rs` both do, which
//! is what lets `pfl bench` and the bench assert the round engine's
//! zero-allocation steady state. The counter is a relaxed atomic
//! increment per `alloc`/`realloc` — negligible against any real
//! allocation — and deallocations are not counted (the claim under test
//! is "no allocations", not "balanced allocations"). When the allocator
//! is *not* installed (library tests, downstream users), the counter
//! simply never moves; [`counting_enabled`] probes for that so harness
//! code can report "not measured" instead of a vacuous zero.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);

/// System allocator with a global allocation counter.
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

/// Total allocations observed so far (0 forever if the counting allocator
/// is not installed as the global allocator).
pub fn allocations() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// True when [`CountingAlloc`] is actually installed: performs one heap
/// allocation and checks that the counter moved.
pub fn counting_enabled() -> bool {
    let before = allocations();
    std::hint::black_box(Box::new(0u8));
    allocations() != before
}

/// Allocations performed while running `f`.
pub fn allocations_during<R>(f: impl FnOnce() -> R) -> (R, u64) {
    let before = allocations();
    let r = f();
    (r, allocations() - before)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_is_monotone_and_probe_is_consistent() {
        // in the test binary the counting allocator is NOT installed, so
        // the probe must report disabled and the counter must not move
        let a = allocations();
        let (_, n) = allocations_during(|| std::hint::black_box(vec![1u8; 64]));
        let b = allocations();
        if counting_enabled() {
            assert!(n > 0);
        } else {
            assert_eq!(a, b);
            assert_eq!(n, 0);
        }
    }
}
