//! Tiny CLI argument parser (clap is not in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.
//! Typed getters parse on access and produce readable errors.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse an iterator of raw arguments (without argv[0]).
    /// `flag_names` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, flag_names: &[&str]) -> anyhow::Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&body) {
                    out.flags.push(body.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("option --{body} expects a value"))?;
                    out.options.insert(body.to_string(), v);
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn from_env(flag_names: &[&str]) -> anyhow::Result<Args> {
        Self::parse(std::env::args().skip(1), flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<T>()
                .map_err(|e| anyhow::anyhow!("--{name}={s}: {e}")),
        }
    }

    pub fn require(&self, name: &str) -> anyhow::Result<&str> {
        self.get(name)
            .ok_or_else(|| anyhow::anyhow!("missing required option --{name}"))
    }

    /// Unknown-option guard for subcommands: every provided option must be
    /// in `known` (catches typos like --lamda).
    pub fn check_known(&self, known: &[&str]) -> anyhow::Result<()> {
        for k in self.options.keys() {
            if !known.contains(&k.as_str()) {
                anyhow::bail!("unknown option --{k} (known: {})", known.join(", "));
            }
        }
        for f in &self.flags {
            if !known.contains(&f.as_str()) {
                anyhow::bail!("unknown flag --{f}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str], flags: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()), flags).unwrap()
    }

    #[test]
    fn parses_kv_and_flags() {
        let a = args(&["run", "--p", "0.4", "--lambda=10", "--verbose"], &["verbose"]);
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get("p"), Some("0.4"));
        assert_eq!(a.get("lambda"), Some("10"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_getters() {
        let a = args(&["--n", "25", "--eta", "0.5"], &[]);
        assert_eq!(a.parse_or("n", 0usize).unwrap(), 25);
        assert_eq!(a.parse_or("eta", 0.0f64).unwrap(), 0.5);
        assert_eq!(a.parse_or("missing", 7i32).unwrap(), 7);
        assert!(a.parse_or("eta", 0usize).is_err());
    }

    #[test]
    fn missing_value_errors() {
        let r = Args::parse(["--p".to_string()], &[]);
        assert!(r.is_err());
    }

    #[test]
    fn unknown_option_guard() {
        let a = args(&["--lamda", "3"], &[]);
        assert!(a.check_known(&["lambda", "p"]).is_err());
        let b = args(&["--lambda", "3"], &[]);
        assert!(b.check_known(&["lambda", "p"]).is_ok());
    }
}
