//! Minimal JSON parser/serializer (serde is not in the offline vendor set).
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null). Used for the AOT manifest, experiment configs
//! and result emission. Object key order is preserved (Vec of pairs) so
//! emitted files diff cleanly.

use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Value {
    // ---- constructors ----
    pub fn obj(pairs: Vec<(String, Value)>) -> Value {
        Value::Obj(pairs)
    }

    // ---- accessors ----
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> anyhow::Result<&Value> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key `{key}`"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|x| *x >= 0.0 && x.fract() == 0.0).map(|x| x as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    // typed convenience getters
    pub fn str_of(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("json key `{key}` is not a string"))
    }

    pub fn f64_of(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("json key `{key}` is not a number"))
    }

    pub fn usize_of(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("json key `{key}` is not a non-negative integer"))
    }

    pub fn arr_of(&self, key: &str) -> anyhow::Result<&[Value]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("json key `{key}` is not an array"))
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = fmt::Write::write_fmt(out, format_args!("{}", *x as i64));
                } else {
                    let _ = fmt::Write::write_fmt(out, format_args!("{x}"));
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Value::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !pairs.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document (rejects trailing garbage).
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(pairs));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1; // past 'u'
                            let cp = self.hex4()?;
                            // surrogate pairs
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            continue; // hex4 already advanced past the escape
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let rest = &self.b[start..];
                    let len = utf8_len(rest[0]);
                    if rest.len() < len {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&rest[..len])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos += len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.pos + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b0: u8) -> usize {
    match b0 {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse(" false ").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse(r#""hi""#).unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Value::Null));
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\nb\t\"q\" \\ A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" \\ A 😀");
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = parse("\"λ → ∞\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "λ → ∞");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"models":[{"name":"m","n":3,"ok":true,"x":1.5}],"z":null}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.to_string()).unwrap(), v);
        assert_eq!(parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn string_escape_roundtrip() {
        let v = Value::Str("line1\nline2\t\"quoted\" \\ \u{1}".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Value::Num(5.0).to_string(), "5");
        assert_eq!(Value::Num(5.5).to_string(), "5.5");
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = parse(&text).unwrap();
            assert!(v.get("models").unwrap().as_arr().unwrap().len() >= 4);
        }
    }

    #[test]
    fn typed_getters() {
        let v = parse(r#"{"n": 7, "s": "x", "f": 1.5}"#).unwrap();
        assert_eq!(v.usize_of("n").unwrap(), 7);
        assert_eq!(v.str_of("s").unwrap(), "x");
        assert_eq!(v.f64_of("f").unwrap(), 1.5);
        assert!(v.usize_of("f").is_err());
        assert!(v.str_of("missing").is_err());
    }
}
