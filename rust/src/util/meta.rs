//! Build/run metadata stamped into every `BENCH_*.json` so perf deltas
//! across machines and revisions stay interpretable: without the
//! revision, a thread count, and the kernel dispatch level, a "12% faster"
//! row could as easily be a different laptop as a different commit.

use crate::model::kernels;
use crate::util::json::Value;

/// Git revision of the working tree, read straight from `.git` (the
/// bench environments have no `git` binary on PATH guarantees): `HEAD`
/// is either a detached sha or `ref: <branch>`, dereferenced one level
/// through the loose ref file or `packed-refs`. Falls back to the
/// `GITHUB_SHA` env (Actions checkouts can be packed in exotic ways),
/// then `"unknown"` — metadata must never fail a bench run.
pub fn git_revision() -> String {
    let mut dir = std::env::current_dir().ok();
    while let Some(d) = dir {
        if d.join(".git").exists() {
            if let Some(rev) = revision_in(&d) {
                return rev;
            }
            break;
        }
        dir = d.parent().map(|p| p.to_path_buf());
    }
    std::env::var("GITHUB_SHA").unwrap_or_else(|_| "unknown".into())
}

fn revision_in(repo: &std::path::Path) -> Option<String> {
    let head = std::fs::read_to_string(repo.join(".git/HEAD")).ok()?;
    let head = head.trim();
    let Some(branch_ref) = head.strip_prefix("ref: ") else {
        // detached HEAD: the sha is right there
        return non_empty(head);
    };
    if let Ok(sha) = std::fs::read_to_string(repo.join(".git").join(branch_ref)) {
        if let Some(s) = non_empty(sha.trim()) {
            return Some(s);
        }
    }
    // loose ref absent ⇒ look the branch up in packed-refs
    let packed = std::fs::read_to_string(repo.join(".git/packed-refs")).ok()?;
    for line in packed.lines() {
        if let Some(sha) = line.strip_suffix(branch_ref) {
            if let Some(s) = non_empty(sha.trim()) {
                return Some(s);
            }
        }
    }
    None
}

fn non_empty(s: &str) -> Option<String> {
    if s.is_empty() {
        None
    } else {
        Some(s.to_string())
    }
}

/// The shared `meta` object every bench emitter embeds: worker threads
/// the measured section actually ran with, the kernel dispatch level
/// ([`kernels::active_level`] — reflects the `PFL_FORCE_KERNEL_LEVEL`
/// escape hatch), the git revision, and the thread pool's busy fraction
/// over the measured window (0.0 when the emitter ran without a pool or
/// without the profiling hooks armed).
pub fn bench_meta(threads: usize, pool_utilization: f64) -> Value {
    Value::obj(vec![
        ("threads".into(), Value::Num(threads as f64)),
        ("cpu_features".into(),
         Value::Str(kernels::active_level().name().into())),
        ("git_rev".into(), Value::Str(git_revision())),
        ("pool_utilization".into(), Value::Num(pool_utilization)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_meta_has_the_four_keys() {
        let m = bench_meta(7, 0.25);
        assert_eq!(m.get("threads").unwrap().as_usize(), Some(7));
        let feats = m.get("cpu_features").unwrap().as_str().unwrap();
        assert!(["avx512", "avx2", "sse2", "scalar"].contains(&feats), "{feats}");
        let rev = m.get("git_rev").unwrap().as_str().unwrap();
        assert!(!rev.is_empty());
        assert_eq!(m.get("pool_utilization").unwrap().as_f64(), Some(0.25));
    }

    #[test]
    fn git_revision_resolves_in_this_repo_or_falls_back() {
        // under `cargo test` the CWD is the workspace root, which is a git
        // repo — either a real sha (40 hex chars) or a declared fallback
        let rev = git_revision();
        assert!(rev == "unknown" || rev.len() >= 7, "suspicious rev {rev:?}");
    }
}
