//! Substrate utilities built from scratch for the offline environment:
//! deterministic RNG, bit-level I/O, JSON codec, CLI parsing, statistics,
//! and a fixed worker pool.

pub mod alloc_count;
pub mod bitio;
pub mod cli;
pub mod json;
pub mod meta;
pub mod rng;
pub mod stats;
pub mod threadpool;

pub use bitio::{BitReader, BitWriter};
pub use rng::Rng;
