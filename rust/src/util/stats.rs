//! Small statistics helpers used by metrics, tests and the bench harness.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Linear-interpolated quantile, q ∈ [0, 1].
pub fn quantile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Summary of repeated timing/metric samples.
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty());
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n: s.len(),
            mean: mean(&s),
            std: stddev(&s),
            min: s[0],
            p50: quantile(&s, 0.5),
            p95: quantile(&s, 0.95),
            max: *s.last().unwrap(),
        }
    }
}

/// Pearson χ² statistic of observed counts against a uniform expectation
/// (the cohort-sampling uniformity tests). 0.0 when the total is zero.
pub fn chi_square_uniform(counts: &[u64]) -> f64 {
    if counts.is_empty() {
        return 0.0;
    }
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let expected = total as f64 / counts.len() as f64;
    counts
        .iter()
        .map(|&c| {
            let d = c as f64 - expected;
            d * d / expected
        })
        .sum()
}

/// A generous upper critical value for χ² with `dof` degrees of freedom:
/// mean + 4σ of the χ² distribution (≈ p < 1e-4 by the normal
/// approximation). Loose on purpose — the statistical suite wants to catch
/// gross non-uniformity, not flake on tail mass.
pub fn chi_square_loose_critical(dof: usize) -> f64 {
    let k = dof as f64;
    k + 4.0 * (2.0 * k).sqrt()
}

/// ℓ2 norm of an f32 slice (f64 accumulation).
pub fn l2_norm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// Squared ℓ2 distance between two f32 slices.
pub fn l2_dist_sq(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(quantile(&xs, 0.25), 2.0);
    }

    #[test]
    fn summary_ordering() {
        let s = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.p50, 2.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.n, 3);
    }

    #[test]
    fn chi_square_uniform_basics() {
        // perfectly uniform counts score 0
        assert_eq!(chi_square_uniform(&[10, 10, 10, 10]), 0.0);
        assert_eq!(chi_square_uniform(&[]), 0.0);
        assert_eq!(chi_square_uniform(&[0, 0]), 0.0);
        // a gross skew blows past the loose critical value
        let skew = chi_square_uniform(&[400, 0, 0, 0]);
        assert!(skew > chi_square_loose_critical(3), "χ² = {skew}");
        // a mild, in-noise deviation stays under it
        let mild = chi_square_uniform(&[98, 104, 99, 99]);
        assert!(mild < chi_square_loose_critical(3), "χ² = {mild}");
    }

    #[test]
    fn norms() {
        assert_eq!(l2_norm(&[3.0, 4.0]), 5.0);
        assert_eq!(l2_dist_sq(&[1.0, 1.0], &[0.0, 0.0]), 2.0);
    }
}
