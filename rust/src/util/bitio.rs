//! Bit-level I/O for the compressor wire formats.
//!
//! `BitWriter`/`BitReader` pack LSB-first into a byte vector. The hot loops
//! buffer through a u64 accumulator so sub-byte symbols (2-bit ternary
//! digits, 9-bit natural-compression codes, Elias-γ QSGD buckets) cost a
//! couple of shifts each rather than per-bit branching — this is a §Perf
//! hot path (see EXPERIMENTS.md §Perf).

/// LSB-first bit writer over a growable byte buffer.
#[derive(Default, Debug)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// pending bits (low `fill` bits valid)
    acc: u64,
    fill: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(bytes: usize) -> Self {
        BitWriter { buf: Vec::with_capacity(bytes), acc: 0, fill: 0 }
    }

    /// Reuse an existing byte buffer (cleared, capacity kept) — the
    /// zero-alloc wire path: `compress_into` round-trips the payload `Vec`
    /// through here so steady-state encoding never touches the allocator.
    pub fn reuse(mut buf: Vec<u8>) -> Self {
        buf.clear();
        BitWriter { buf, acc: 0, fill: 0 }
    }

    /// Append the low `n` bits of `v` (n ≤ 57 to keep the accumulator safe).
    ///
    /// §Perf: spills 32 bits at a time (one `extend_from_slice` per ~4
    /// bytes instead of a per-byte loop); the emitted bitstream is
    /// identical to the byte-at-a-time version.
    #[inline]
    pub fn put(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 57, "put() supports up to 57 bits per call");
        let v = v & mask(n);
        if n <= 32 {
            self.put_raw(v, n);
        } else {
            self.put_raw(v & 0xFFFF_FFFF, 32);
            self.put_raw(v >> 32, n - 32);
        }
    }

    /// n ≤ 32; maintains the invariant `fill < 32` between calls.
    #[inline]
    fn put_raw(&mut self, v: u64, n: u32) {
        self.acc |= v << self.fill;
        self.fill += n;
        if self.fill >= 32 {
            self.buf.extend_from_slice(&(self.acc as u32).to_le_bytes());
            self.acc >>= 32;
            self.fill -= 32;
        }
    }

    /// Append a single bit.
    #[inline]
    pub fn put_bit(&mut self, b: bool) {
        self.put(b as u64, 1);
    }

    /// Append a full u32 (e.g. a float's bits or a seed).
    #[inline]
    pub fn put_u32(&mut self, v: u32) {
        self.put(v as u64, 32);
    }

    /// Append an f32 verbatim.
    #[inline]
    pub fn put_f32(&mut self, v: f32) {
        self.put_u32(v.to_bits());
    }

    /// Elias-γ code for v ≥ 1: ⌊log₂v⌋ zeros, then v's bits (MSB first
    /// conceptually; stored via (len, bits) here). Compact for the small
    /// bucket indices QSGD produces.
    pub fn put_elias_gamma(&mut self, v: u64) {
        debug_assert!(v >= 1);
        let nbits = 64 - v.leading_zeros();
        self.put(0, nbits - 1); // unary prefix of zeros
        // emit the value with its leading one, LSB-first of the nbits
        self.put(reverse_low_bits(v, nbits), nbits);
    }

    /// Bits written so far (before final flush padding).
    pub fn bit_len(&self) -> u64 {
        self.buf.len() as u64 * 8 + self.fill as u64
    }

    /// Flush and return the byte buffer (final partial byte zero-padded).
    pub fn finish(mut self) -> Vec<u8> {
        while self.fill > 0 {
            self.buf.push(self.acc as u8);
            self.acc >>= 8;
            self.fill = self.fill.saturating_sub(8);
        }
        self.buf
    }
}

/// LSB-first bit reader over a byte slice.
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize,
    acc: u64,
    fill: u32,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0, acc: 0, fill: 0 }
    }

    /// §Perf: loads 4 bytes at a time while aligned room remains, then
    /// finishes byte-wise at the tail. Consumption order is unchanged.
    #[inline]
    fn refill(&mut self) {
        while self.fill <= 56 {
            if self.fill <= 32 && self.pos + 4 <= self.buf.len() {
                let w = u32::from_le_bytes(
                    self.buf[self.pos..self.pos + 4].try_into().unwrap());
                self.acc |= (w as u64) << self.fill;
                self.pos += 4;
                self.fill += 32;
            } else if self.pos < self.buf.len() {
                self.acc |= (self.buf[self.pos] as u64) << self.fill;
                self.pos += 1;
                self.fill += 8;
            } else {
                break;
            }
        }
    }

    /// Read `n` bits (n ≤ 57). Returns 0 bits past the end (callers track
    /// symbol counts themselves; the codecs never over-read valid streams).
    #[inline]
    pub fn get(&mut self, n: u32) -> u64 {
        debug_assert!(n <= 57);
        if self.fill < n {
            self.refill();
        }
        let v = self.acc & mask(n);
        self.acc >>= n;
        self.fill = self.fill.saturating_sub(n);
        v
    }

    #[inline]
    pub fn get_bit(&mut self) -> bool {
        self.get(1) != 0
    }

    #[inline]
    pub fn get_u32(&mut self) -> u32 {
        self.get(32) as u32
    }

    #[inline]
    pub fn get_f32(&mut self) -> f32 {
        f32::from_bits(self.get_u32())
    }

    /// Decode an Elias-γ code written by `put_elias_gamma`.
    ///
    /// §Perf: fast path counts the unary prefix with `trailing_zeros` and
    /// consumes the whole code from the accumulator in two shifts; the
    /// bit-loop remains as the fallback for codes longer than the
    /// accumulator (level ≥ 2²⁸, unreachable for QSGD's levels).
    pub fn get_elias_gamma(&mut self) -> u64 {
        if self.fill < 57 {
            self.refill();
        }
        if self.acc != 0 {
            let tz = self.acc.trailing_zeros();
            let nbits = tz + 1;
            if 2 * nbits - 1 <= self.fill {
                self.acc >>= tz;
                self.fill -= tz;
                let v = self.acc & mask(nbits);
                self.acc >>= nbits;
                self.fill -= nbits;
                return reverse_low_bits(v, nbits);
            }
        }
        let mut zeros = 0u32;
        while !self.get_bit() {
            zeros += 1;
            debug_assert!(zeros <= 64, "corrupt elias-gamma stream");
        }
        let nbits = zeros + 1;
        // we consumed the leading 1 (it was the lowest bit of the reversed
        // value); reconstruct: remaining nbits-1 bits then reverse.
        let rest = self.get(nbits - 1);
        reverse_low_bits(1 | (rest << 1), nbits)
    }

    /// Bits consumed so far.
    pub fn bit_pos(&self) -> u64 {
        self.pos as u64 * 8 - self.fill as u64
    }
}

#[inline]
fn mask(n: u32) -> u64 {
    if n >= 64 { u64::MAX } else { (1u64 << n) - 1 }
}

#[inline]
fn reverse_low_bits(v: u64, n: u32) -> u64 {
    v.reverse_bits() >> (64 - n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_fixed_widths() {
        let mut w = BitWriter::new();
        w.put(0b101, 3);
        w.put(0xFFFF, 16);
        w.put_bit(true);
        w.put_u32(0xDEADBEEF);
        w.put_f32(3.75);
        let bits = w.bit_len();
        assert_eq!(bits, 3 + 16 + 1 + 32 + 32);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get(3), 0b101);
        assert_eq!(r.get(16), 0xFFFF);
        assert!(r.get_bit());
        assert_eq!(r.get_u32(), 0xDEADBEEF);
        assert_eq!(r.get_f32(), 3.75);
        assert_eq!(r.bit_pos(), bits);
    }

    #[test]
    fn roundtrip_random_streams() {
        let mut rng = Rng::new(77);
        for _ in 0..50 {
            let n = 1 + rng.usize_below(500);
            let items: Vec<(u64, u32)> = (0..n)
                .map(|_| {
                    let w = 1 + rng.below(33) as u32;
                    (rng.below(1u64 << w), w)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(v, width) in &items {
                w.put(v, width);
            }
            let bytes = w.finish();
            let mut r = BitReader::new(&bytes);
            for &(v, width) in &items {
                assert_eq!(r.get(width), v);
            }
        }
    }

    #[test]
    fn elias_gamma_roundtrip() {
        let vals = [1u64, 2, 3, 4, 7, 8, 100, 1023, 1024, 1 << 40];
        let mut w = BitWriter::new();
        for &v in &vals {
            w.put_elias_gamma(v);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &v in &vals {
            assert_eq!(r.get_elias_gamma(), v);
        }
    }

    #[test]
    fn elias_gamma_length_is_2floorlog_plus_1() {
        for &v in &[1u64, 2, 5, 17, 300] {
            let mut w = BitWriter::new();
            w.put_elias_gamma(v);
            let expect = 2 * (64 - v.leading_zeros() - 1) + 1;
            assert_eq!(w.bit_len(), expect as u64, "v={v}");
        }
    }

    #[test]
    fn bit_len_counts_before_padding() {
        let mut w = BitWriter::new();
        w.put(1, 3);
        assert_eq!(w.bit_len(), 3);
        let b = w.finish();
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn reader_past_end_returns_zero() {
        let bytes = vec![0xFF];
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get(8), 0xFF);
        assert_eq!(r.get(8), 0);
    }
}
