//! Fixed-size worker pool (tokio is not in the offline vendor set).
//!
//! The coordinator fans one closure per client out to the pool each protocol
//! step; `scope_map` blocks until all complete and returns results in input
//! order. Workers are long-lived OS threads fed through an mpsc channel, so
//! per-round overhead is one enqueue/dequeue per client, not thread spawn.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    shared_rx: Arc<Mutex<mpsc::Receiver<Msg>>>,
    handles: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let shared_rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&shared_rx);
            handles.push(
                thread::Builder::new()
                    .name(format!("pfl-worker-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => job(),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { tx, shared_rx, handles, size }
    }

    /// Pool sized to the machine (cores, capped at 16).
    pub fn default_size() -> usize {
        thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(16)
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Run `f(i, &items[i])` for every item on the pool; results in order.
    ///
    /// `f` must be `Sync` (shared across workers); items are only read.
    pub fn scope_map<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        let mut units = vec![(); items.len()];
        self.scope_zip_mut(&mut units, items, |i, _unit, item| f(i, item))
    }

    /// Run `f(i, &mut states[i], &items[i])` for every index on the pool;
    /// results in input order. The per-index `&mut` access is what the
    /// stateful compressor fan-out needs (each client owns its
    /// `CompressorState` + wire buffer) — no `Mutex` wrapping required.
    pub fn scope_zip_mut<S, T, R, F>(&self, states: &mut [S], items: &[T], f: F) -> Vec<R>
    where
        S: Send,
        T: Sync,
        R: Send,
        F: Fn(usize, &mut S, &T) -> R + Sync,
    {
        let n = items.len();
        assert_eq!(states.len(), n, "states/items length mismatch");
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        if n == 0 {
            return Vec::new();
        }
        // Scoped-threads trick without crossbeam: hand out raw slots guarded
        // by a completion channel. Safety: each index is written exactly once
        // (so the &mut derived per index is unique) and the borrows outlive
        // the jobs because we block below.
        let (done_tx, done_rx) = mpsc::channel::<()>();
        let out_ptr = SendPtr(out.as_mut_ptr());
        let state_ptr = SendPtr(states.as_mut_ptr());
        let f_ref = &f;
        for i in 0..n {
            let tx = done_tx.clone();
            let po = out_ptr;
            let ps = state_ptr;
            let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                // capture the whole SendPtrs, not their raw fields
                let po = po;
                let ps = ps;
                let r = unsafe { f_ref(i, &mut *ps.0.add(i), &items[i]) };
                unsafe {
                    *po.0.add(i) = Some(r);
                }
                let _ = tx.send(());
            });
            // lifetime erasure: sound because we block on the completion
            // channel below before any borrow (f, items, states, out) ends.
            let job: Job = unsafe { std::mem::transmute(job) };
            self.tx.send(Msg::Run(job)).expect("pool alive");
        }
        for _ in 0..n {
            done_rx.recv().expect("worker completed");
        }
        out.into_iter().map(|o| o.expect("slot written")).collect()
    }
}

struct SendPtr<T>(*mut T);
// manual impls: derive would require T: Copy/Clone
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.handles {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        let _ = &self.shared_rx; // keep rx alive until workers exit
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn maps_in_order() {
        let pool = ThreadPool::new(4);
        let items: Vec<u64> = (0..100).collect();
        let out = pool.scope_map(&items, |i, &x| (i as u64) * 1000 + x * x);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, (i as u64) * 1000 + (i as u64) * (i as u64));
        }
    }

    #[test]
    fn runs_concurrently() {
        let pool = ThreadPool::new(4);
        let hits = AtomicUsize::new(0);
        let items = vec![(); 16];
        pool.scope_map(&items, |_, _| {
            hits.fetch_add(1, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        assert_eq!(hits.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn empty_input() {
        let pool = ThreadPool::new(2);
        let out: Vec<u32> = pool.scope_map(&Vec::<u32>::new(), |_, &x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn zip_mut_mutates_each_state_once() {
        let pool = ThreadPool::new(4);
        let mut states: Vec<u64> = vec![100; 32];
        let items: Vec<u64> = (0..32).collect();
        let out = pool.scope_zip_mut(&mut states, &items, |i, s, &x| {
            *s += x;
            *s + i as u64
        });
        for i in 0..32 {
            assert_eq!(states[i], 100 + i as u64);
            assert_eq!(out[i], 100 + 2 * i as u64);
        }
    }

    #[test]
    fn zip_mut_empty_input() {
        let pool = ThreadPool::new(2);
        let out: Vec<()> = pool.scope_zip_mut(&mut Vec::<u8>::new(), &[], |_, _, _: &u8| ());
        assert!(out.is_empty());
    }

    #[test]
    fn reusable_across_calls() {
        let pool = ThreadPool::new(3);
        for round in 0..10 {
            let items: Vec<usize> = (0..20).collect();
            let out = pool.scope_map(&items, |_, &x| x + round);
            assert_eq!(out[5], 5 + round);
        }
    }
}
