//! Fixed-size worker pool (tokio is not in the offline vendor set), built
//! around a **zero-allocation broadcast scope**.
//!
//! The round engine fans one closure per client out to the pool every
//! protocol step, thousands of times per run. The seed implementation
//! boxed one job per client per call and pushed it through an mpsc channel
//! (one heap node per send); at L2GD rates that is the dominant steady-
//! state allocation source. This version posts a single type-erased
//! `&dyn Fn(usize)` task under a mutex; workers pull indices from a shared
//! cursor and signal completion over a condvar. Dispatch performs **no
//! heap allocation at all**, which is what lets
//! `benches/perf_round_latency.rs` assert a zero-alloc steady state for
//! the whole training step.
//!
//! Layers:
//! * [`ThreadPool::scope_for`] — the allocation-free core: run `f(i)` for
//!   `i in 0..n` across the workers, blocking until all complete.
//! * [`ThreadPool::scope_chunks_mut`] / [`ThreadPool::scope_chunks_zip_mut`]
//!   — disjoint `&mut` row/state access over contiguous storage (the
//!   ParamMatrix sweeps), also allocation-free.
//! * [`ThreadPool::scope_map`] / [`ThreadPool::scope_map_n`] /
//!   [`ThreadPool::scope_zip_mut`] — ordered-result conveniences (allocate
//!   only their output vector).

use std::cell::Cell;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::Instant;

use crate::obs;

thread_local! {
    /// True while this thread is executing a posted scope closure. Lets
    /// `scope_for` reject reentrant submission with a clean panic (which
    /// the worker's catch_unwind routes back to the outer submitter)
    /// instead of deadlocking or poisoning the pool mutex.
    static IN_SCOPE_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// Lifetime-erased reference to the posted closure. Soundness: the
/// submitter blocks inside `scope_for` until every index has completed and
/// clears the slot before returning, so the pointee outlives all uses.
type TaskFn = *const (dyn Fn(usize) + Sync);

struct State {
    /// currently posted broadcast task (`None` = idle)
    task: Option<TaskFn>,
    /// total indices of the current task
    n: usize,
    /// next index to hand out
    next: usize,
    /// indices handed out but not yet completed
    active: usize,
    /// first panic payload observed while running the current task
    panic: Option<Box<dyn std::any::Any + Send>>,
    shutdown: bool,
}

// `TaskFn` is a raw pointer; the dispatch protocol above is what makes
// sharing it across workers sound.
unsafe impl Send for State {}

struct Inner {
    state: Mutex<State>,
    /// workers wait here for a task (or shutdown)
    work: Condvar,
    /// the submitter waits here for task completion
    done: Condvar,
    /// profiling hooks: per-worker busy nanoseconds, accumulated around
    /// each executed closure while `profile` is set (or tracing is on)
    busy: Vec<AtomicU64>,
    profile: AtomicBool,
    /// wall anchor of the current profiling window (None = never enabled)
    profile_since: Mutex<Option<Instant>>,
}

pub struct ThreadPool {
    inner: Arc<Inner>,
    handles: Vec<thread::JoinHandle<()>>,
    size: usize,
}

fn worker(inner: Arc<Inner>, widx: usize) {
    let mut st = inner.state.lock().unwrap();
    loop {
        if st.shutdown {
            return;
        }
        if let Some(ptr) = st.task {
            if st.next < st.n {
                let i = st.next;
                st.next += 1;
                st.active += 1;
                drop(st);
                // Safety: the submitter keeps the closure alive until the
                // task completes (it is blocked in scope_for).
                let f = unsafe { &*ptr };
                // busy-time accounting (profiling hook) — a stack Instant
                // when armed, nothing at all otherwise
                let t0 = inner.profile.load(Ordering::Relaxed).then(Instant::now);
                obs::span_begin(obs::WORKER_TASK, obs::worker_lane(widx), obs::NO_SIM_TIME);
                IN_SCOPE_WORKER.with(|w| w.set(true));
                let res = std::panic::catch_unwind(AssertUnwindSafe(|| f(i)));
                IN_SCOPE_WORKER.with(|w| w.set(false));
                obs::span_end(obs::WORKER_TASK, obs::worker_lane(widx), obs::NO_SIM_TIME);
                if let Some(t0) = t0 {
                    let ns = t0.elapsed().as_nanos() as u64;
                    inner.busy[widx].fetch_add(ns, Ordering::Relaxed);
                }
                st = inner.state.lock().unwrap();
                st.active -= 1;
                if let Err(p) = res {
                    if st.panic.is_none() {
                        st.panic = Some(p);
                    }
                }
                if st.next >= st.n && st.active == 0 {
                    inner.done.notify_all();
                }
                continue;
            }
        }
        st = inner.work.wait(st).unwrap();
    }
}

impl ThreadPool {
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let inner = Arc::new(Inner {
            state: Mutex::new(State {
                task: None,
                n: 0,
                next: 0,
                active: 0,
                panic: None,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
            busy: (0..size).map(|_| AtomicU64::new(0)).collect(),
            profile: AtomicBool::new(false),
            profile_since: Mutex::new(None),
        });
        let mut handles = Vec::with_capacity(size);
        for i in 0..size {
            let inner = Arc::clone(&inner);
            handles.push(
                thread::Builder::new()
                    .name(format!("pfl-worker-{i}"))
                    .spawn(move || worker(inner, i))
                    .expect("spawn worker"),
            );
        }
        ThreadPool { inner, handles, size }
    }

    /// Pool sized to the machine (cores, capped at 16), unless the
    /// `PFL_THREADS` env override pins it — the reproducibility knob
    /// `pfl bench` records as `threads` in every `BENCH_*.json`, so perf
    /// deltas across machines stay interpretable (and a bench can be
    /// replayed at the baseline's width).
    pub fn default_size() -> usize {
        if let Some(n) = Self::size_from_override(
            std::env::var("PFL_THREADS").ok().as_deref()) {
            return n;
        }
        thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(16)
    }

    /// `PFL_THREADS` parsing as a pure function: a positive integer wins,
    /// anything else (unset, garbage, 0) falls through to autodetection.
    fn size_from_override(v: Option<&str>) -> Option<usize> {
        v.and_then(|s| s.trim().parse::<usize>().ok()).filter(|&n| n >= 1)
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Arm the per-worker busy-time profiling hooks: zero the busy
    /// counters and open a fresh measurement window. Off by default —
    /// un-profiled dispatch takes exactly one extra relaxed load per
    /// executed closure.
    pub fn enable_profiling(&self) {
        for b in &self.inner.busy {
            b.store(0, Ordering::Relaxed);
        }
        let mut since =
            self.inner.profile_since.lock().unwrap_or_else(|e| e.into_inner());
        *since = Some(Instant::now());
        drop(since);
        self.inner.profile.store(true, Ordering::SeqCst);
    }

    /// Per-worker busy nanoseconds accumulated since
    /// [`Self::enable_profiling`] (all zeros if never armed).
    pub fn busy_ns(&self) -> Vec<u64> {
        self.inner.busy.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Busy fraction of the pool over the profiling window:
    /// Σ busy-ns / (window-ns × workers), clamped to `0..=1`.
    /// Returns `0.0` if profiling was never enabled.
    pub fn utilization(&self) -> f64 {
        let since =
            *self.inner.profile_since.lock().unwrap_or_else(|e| e.into_inner());
        let Some(t0) = since else {
            return 0.0;
        };
        let window = t0.elapsed().as_nanos() as f64;
        if window <= 0.0 {
            return 0.0;
        }
        let busy: u64 = self.busy_ns().iter().sum();
        (busy as f64 / (window * self.size as f64)).clamp(0.0, 1.0)
    }

    /// Run `f(i)` for every `i in 0..n` on the pool and block until all
    /// complete. **Allocation-free**: the closure is posted by reference,
    /// indices are handed out from a shared cursor, completion is a
    /// condvar — no boxing, no channels.
    ///
    /// Not reentrant: calling any `scope_*` from inside a posted closure
    /// panics cleanly (the panic is checked *before* the pool mutex is
    /// touched, so it propagates to the outer submitter instead of
    /// poisoning the pool). Concurrent submitters from distinct threads
    /// serialize: later scopes wait for the active one to finish.
    ///
    /// A panic inside `f` is caught per index, the scope drains, and the
    /// first payload is re-raised on the calling thread.
    pub fn scope_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if n == 0 {
            return;
        }
        assert!(!IN_SCOPE_WORKER.with(|w| w.get()),
                "ThreadPool scopes are not reentrant from posted closures");
        let f_obj: &(dyn Fn(usize) + Sync) = &f;
        // Lifetime erasure (the same trick the seed pool used for its
        // boxed jobs): sound because we block below until every index has
        // completed, so the closure outlives all worker-side uses.
        let ptr: TaskFn = unsafe { std::mem::transmute(f_obj) };
        let mut st = self.inner.state.lock().unwrap();
        // another thread's scope may be in flight: wait for the slot
        while st.task.is_some() {
            st = self.inner.done.wait(st).unwrap();
        }
        st.task = Some(ptr);
        st.n = n;
        st.next = 0;
        st.active = 0;
        self.inner.work.notify_all();
        while !(st.next >= st.n && st.active == 0) {
            st = self.inner.done.wait(st).unwrap();
        }
        st.task = None;
        let panic = st.panic.take();
        // wake any submitter queued on the task slot
        self.inner.done.notify_all();
        drop(st);
        if let Some(p) = panic {
            std::panic::resume_unwind(p);
        }
    }

    /// Run `f(i)` for `i in 0..n`; results in index order.
    pub fn scope_map_n<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let out_ptr = SendPtr(out.as_mut_ptr());
        self.scope_for(n, |i| {
            let po = out_ptr;
            let r = f(i);
            // Safety: each index writes exactly its own slot, and `out`
            // outlives the scope (we block until completion).
            unsafe {
                *po.0.add(i) = Some(r);
            }
        });
        out.into_iter().map(|o| o.expect("slot written")).collect()
    }

    /// Run `f(i, &items[i])` for every item on the pool; results in order.
    ///
    /// `f` must be `Sync` (shared across workers); items are only read.
    pub fn scope_map<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        self.scope_map_n(items.len(), |i| f(i, &items[i]))
    }

    /// Run `f(i, &mut states[i], &items[i])` for every index on the pool;
    /// results in input order. The per-index `&mut` access is what the
    /// stateful compressor fan-out needs (each client owns its
    /// `CompressorState` + wire buffer) — no `Mutex` wrapping required.
    pub fn scope_zip_mut<S, T, R, F>(&self, states: &mut [S], items: &[T], f: F) -> Vec<R>
    where
        S: Send,
        T: Sync,
        R: Send,
        F: Fn(usize, &mut S, &T) -> R + Sync,
    {
        let n = items.len();
        assert_eq!(states.len(), n, "states/items length mismatch");
        let sp = SendPtr(states.as_mut_ptr());
        self.scope_map_n(n, |i| {
            let sp = sp;
            // Safety: index-disjoint &mut, borrow outlives the scope.
            let s = unsafe { &mut *sp.0.add(i) };
            f(i, s, &items[i])
        })
    }

    /// Parallel sweep over disjoint contiguous chunks:
    /// `f(i, &mut data[i*chunk .. (i+1)*chunk])` for `i in 0..len/chunk`.
    /// Allocation-free (no result vector) — the ParamMatrix row sweep of
    /// the round engine.
    pub fn scope_chunks_mut<T, F>(&self, data: &mut [T], chunk: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(chunk > 0, "chunk must be positive");
        assert_eq!(data.len() % chunk, 0, "data length not a chunk multiple");
        let n = data.len() / chunk;
        let dp = SendPtr(data.as_mut_ptr());
        self.scope_for(n, |i| {
            let dp = dp;
            // Safety: chunks are disjoint by construction; the borrow of
            // `data` outlives the scope.
            let row = unsafe { std::slice::from_raw_parts_mut(dp.0.add(i * chunk), chunk) };
            f(i, row);
        });
    }

    /// Run `f` exactly once **on every worker thread** (a barrier inside
    /// the task keeps a worker from grabbing a second index). Used to warm
    /// per-thread resources — e.g. the compression scratch pools — so that
    /// dynamic index assignment can never surface a first-use allocation
    /// on a cold worker in the middle of a measured steady state.
    pub fn on_each_worker<F>(&self, f: F)
    where
        F: Fn(usize) + Sync,
    {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let arrived = AtomicUsize::new(0);
        let size = self.size;
        self.scope_for(size, |i| {
            arrived.fetch_add(1, Ordering::SeqCst);
            // wait until every worker holds an index: each of the `size`
            // indices then necessarily sits on a distinct worker. Yield
            // while waiting — with more workers than cores, a pure spin
            // would burn whole scheduler quanta before the last worker
            // gets a core to arrive on.
            while arrived.load(Ordering::SeqCst) < size {
                std::thread::yield_now();
            }
            f(i);
        });
    }

    /// [`Self::scope_chunks_mut`] zipped with one `&mut` state per chunk:
    /// `f(i, row_i, &mut states[i])`. Allocation-free. This is the round
    /// engine's local-step shape: row i of the model matrix plus client
    /// i's slot (RNG stream, gradient buffer, compressor state).
    pub fn scope_chunks_zip_mut<T, S, F>(&self, data: &mut [T], chunk: usize,
                                         states: &mut [S], f: F)
    where
        T: Send,
        S: Send,
        F: Fn(usize, &mut [T], &mut S) + Sync,
    {
        assert!(chunk > 0, "chunk must be positive");
        assert_eq!(data.len(), states.len() * chunk, "data/states length mismatch");
        let dp = SendPtr(data.as_mut_ptr());
        let sp = SendPtr(states.as_mut_ptr());
        self.scope_for(states.len(), |i| {
            let dp = dp;
            let sp = sp;
            // Safety: chunk- and index-disjoint &mut, borrows outlive the
            // scope.
            let row = unsafe { std::slice::from_raw_parts_mut(dp.0.add(i * chunk), chunk) };
            let s = unsafe { &mut *sp.0.add(i) };
            f(i, row, s);
        });
    }
}

struct SendPtr<T>(*mut T);
// manual impls: derive would require T: Copy/Clone
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
            self.inner.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn thread_override_parses_positive_integers_only() {
        assert_eq!(ThreadPool::size_from_override(Some("3")), Some(3));
        assert_eq!(ThreadPool::size_from_override(Some(" 12 ")), Some(12));
        assert_eq!(ThreadPool::size_from_override(Some("0")), None);
        assert_eq!(ThreadPool::size_from_override(Some("-2")), None);
        assert_eq!(ThreadPool::size_from_override(Some("lots")), None);
        assert_eq!(ThreadPool::size_from_override(None), None);
        assert!(ThreadPool::default_size() >= 1);
    }

    #[test]
    fn maps_in_order() {
        let pool = ThreadPool::new(4);
        let items: Vec<u64> = (0..100).collect();
        let out = pool.scope_map(&items, |i, &x| (i as u64) * 1000 + x * x);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, (i as u64) * 1000 + (i as u64) * (i as u64));
        }
    }

    #[test]
    fn runs_concurrently() {
        let pool = ThreadPool::new(4);
        let hits = AtomicUsize::new(0);
        let items = vec![(); 16];
        pool.scope_map(&items, |_, _| {
            hits.fetch_add(1, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        assert_eq!(hits.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn empty_input() {
        let pool = ThreadPool::new(2);
        let out: Vec<u32> = pool.scope_map(&Vec::<u32>::new(), |_, &x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn zip_mut_mutates_each_state_once() {
        let pool = ThreadPool::new(4);
        let mut states: Vec<u64> = vec![100; 32];
        let items: Vec<u64> = (0..32).collect();
        let out = pool.scope_zip_mut(&mut states, &items, |i, s, &x| {
            *s += x;
            *s + i as u64
        });
        for i in 0..32 {
            assert_eq!(states[i], 100 + i as u64);
            assert_eq!(out[i], 100 + 2 * i as u64);
        }
    }

    #[test]
    fn zip_mut_empty_input() {
        let pool = ThreadPool::new(2);
        let out: Vec<()> = pool.scope_zip_mut(&mut Vec::<u8>::new(), &[], |_, _, _: &u8| ());
        assert!(out.is_empty());
    }

    #[test]
    fn reusable_across_calls() {
        let pool = ThreadPool::new(3);
        for round in 0..10 {
            let items: Vec<usize> = (0..20).collect();
            let out = pool.scope_map(&items, |_, &x| x + round);
            assert_eq!(out[5], 5 + round);
        }
    }

    #[test]
    fn scope_for_covers_every_index_exactly_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        pool.scope_for(64, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "index {i}");
        }
    }

    #[test]
    fn chunks_mut_touches_disjoint_rows() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0.0f32; 12 * 8];
        pool.scope_chunks_mut(&mut data, 8, |i, row| {
            assert_eq!(row.len(), 8);
            for (j, v) in row.iter_mut().enumerate() {
                *v = (i * 100 + j) as f32;
            }
        });
        for i in 0..12 {
            for j in 0..8 {
                assert_eq!(data[i * 8 + j], (i * 100 + j) as f32);
            }
        }
    }

    #[test]
    fn chunks_zip_mut_pairs_row_and_state() {
        let pool = ThreadPool::new(3);
        let mut data = vec![1.0f32; 10 * 4];
        let mut sums = vec![0.0f32; 10];
        pool.scope_chunks_zip_mut(&mut data, 4, &mut sums, |i, row, s| {
            for v in row.iter_mut() {
                *v += i as f32;
            }
            *s = row.iter().sum();
        });
        for i in 0..10 {
            assert_eq!(sums[i], 4.0 * (1.0 + i as f32));
        }
    }

    #[test]
    fn on_each_worker_hits_every_thread_once() {
        let pool = ThreadPool::new(4);
        let hits = AtomicUsize::new(0);
        let distinct = std::sync::Mutex::new(std::collections::BTreeSet::new());
        pool.on_each_worker(|_| {
            hits.fetch_add(1, Ordering::SeqCst);
            distinct.lock().unwrap().insert(std::thread::current().name()
                .unwrap_or("?").to_string());
        });
        assert_eq!(hits.load(Ordering::SeqCst), 4);
        assert_eq!(distinct.lock().unwrap().len(), 4, "must run on 4 distinct workers");
    }

    #[test]
    fn profiling_accumulates_busy_time_and_bounds_utilization() {
        let pool = ThreadPool::new(2);
        // never armed: identically zero
        assert_eq!(pool.utilization(), 0.0);
        assert!(pool.busy_ns().iter().all(|&ns| ns == 0));
        pool.enable_profiling();
        pool.scope_for(8, |_| {
            std::thread::sleep(std::time::Duration::from_millis(2));
        });
        let busy: u64 = pool.busy_ns().iter().sum();
        assert!(busy >= 8 * 1_000_000, "8 × 2ms of work must register, got {busy}ns");
        let u = pool.utilization();
        assert!((0.0..=1.0).contains(&u), "utilization {u} out of range");
        assert!(u > 0.0);
        // re-arming zeroes the window
        pool.enable_profiling();
        assert!(pool.busy_ns().iter().all(|&ns| ns == 0));
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.scope_for(8, |i| {
                if i == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "panic must reach the submitter");
        // the pool must still be fully functional afterwards
        let out = pool.scope_map_n(5, |i| i * 2);
        assert_eq!(out, vec![0, 2, 4, 6, 8]);
    }

    #[test]
    fn reentrant_scope_panics_cleanly_instead_of_hanging() {
        let pool = ThreadPool::new(2);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.scope_for(2, |_| {
                pool.scope_map_n(2, |i| i); // illegal: scope inside scope
            });
        }));
        assert!(r.is_err(), "reentrant scope must panic, not deadlock");
        // pool (and its mutex) must survive un-poisoned
        let out = pool.scope_map_n(3, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3]);
    }

    #[test]
    fn concurrent_submitters_serialize() {
        let pool = std::sync::Arc::new(ThreadPool::new(2));
        let total = std::sync::Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let pool = std::sync::Arc::clone(&pool);
            let total = std::sync::Arc::clone(&total);
            handles.push(std::thread::spawn(move || {
                for _ in 0..25 {
                    pool.scope_for(8, |_| {
                        total.fetch_add(1, Ordering::SeqCst);
                    });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(total.load(Ordering::SeqCst), 4 * 25 * 8);
    }
}
