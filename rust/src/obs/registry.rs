//! Alloc-free global metrics registry: log₂-bucketed histograms,
//! monotonic counters, and gauges, all backed by static atomics.
//!
//! `observe`/`count`/`set_gauge` are a handful of relaxed atomic ops —
//! no locks, no allocation — so they are safe to leave live inside the
//! zero-alloc scheduler and engine hot loops (the bench harness keeps
//! asserting `SIM_ALLOCS_PER_EVENT_BOUND` with the instrumented paths).
//!
//! A [`snapshot`] turns the atomics into plain numbers for the `obs`
//! block of `sim_summary.json` (p50/p95/p99 per histogram) and the
//! Prometheus text dump (`metrics.prom`). [`reset`] zeroes everything —
//! the registry is process-global, so runs that want a clean slate
//! (e.g. `pfl sim`) reset it up front.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Value;

const N_BUCKETS: usize = 64;

/// Histogram ids. Values are observed as `u64`s into log₂ buckets:
/// bucket 0 holds zeros, bucket `i ≥ 1` holds `[2^(i-1), 2^i)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Hist {
    /// server-version lag of each applied async update (rounds)
    Staleness = 0,
    /// event-queue depth sampled after each round's arrivals are queued
    QueueDepth = 1,
    /// drawn cohort size per fresh round
    CohortSize = 2,
    /// metered uplink+downlink bits per committed round
    RoundBits = 3,
    /// materialized (copy-on-write) client rows at evaluation points
    ShardOccupancy = 4,
    /// per-worker busy nanoseconds from the thread-pool profiling hooks
    WorkerBusyNs = 5,
}

const N_HISTS: usize = 6;
const HIST_NAMES: [&str; N_HISTS] = [
    "staleness",
    "queue_depth",
    "cohort_size",
    "round_bits",
    "shard_occupancy",
    "worker_busy_ns",
];
const ALL_HISTS: [Hist; N_HISTS] = [
    Hist::Staleness,
    Hist::QueueDepth,
    Hist::CohortSize,
    Hist::RoundBits,
    Hist::ShardOccupancy,
    Hist::WorkerBusyNs,
];

/// Monotonic counter ids.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    /// wire frames serialized by `transport::frame::encode_frame`
    FramesEncoded = 0,
    /// wire frames accepted by `transport::frame::decode_frame`
    FramesDecoded = 1,
    /// bytes written by the loopback TCP client
    LoopbackTxBytes = 2,
    /// bytes read back by the loopback TCP client
    LoopbackRxBytes = 3,
    /// trace events overwritten by ring wrap-around
    TraceEventsDropped = 4,
    /// events scheduled into the timing-wheel event queue
    QueuePush = 5,
    /// events drained from the timing-wheel event queue
    QueuePop = 6,
}

const N_COUNTERS: usize = 7;
const COUNTER_NAMES: [&str; N_COUNTERS] = [
    "frames_encoded",
    "frames_decoded",
    "loopback_tx_bytes",
    "loopback_rx_bytes",
    "trace_events_dropped",
    "queue_push",
    "queue_pop",
];

/// Gauge ids (last-write-wins f64).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Gauge {
    /// thread-pool busy fraction over the profiled window, 0..=1
    PoolUtilization = 0,
    /// high-water mark of pending events in the timing-wheel queue
    QueueMaxDepth = 1,
}

const N_GAUGES: usize = 2;
const GAUGE_NAMES: [&str; N_GAUGES] = ["pool_utilization", "queue_max_depth"];

static BUCKETS: [AtomicU64; N_HISTS * N_BUCKETS] =
    [const { AtomicU64::new(0) }; N_HISTS * N_BUCKETS];
static COUNTS: [AtomicU64; N_HISTS] = [const { AtomicU64::new(0) }; N_HISTS];
static SUMS: [AtomicU64; N_HISTS] = [const { AtomicU64::new(0) }; N_HISTS];
static COUNTERS: [AtomicU64; N_COUNTERS] = [const { AtomicU64::new(0) }; N_COUNTERS];
static GAUGES: [AtomicU64; N_GAUGES] = [const { AtomicU64::new(0) }; N_GAUGES];

fn bucket_of(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        (64 - v.leading_zeros() as usize).min(N_BUCKETS - 1)
    }
}

/// Inclusive upper bound of bucket `i` (`0` for the zero bucket).
fn bucket_upper(i: usize) -> u64 {
    if i == 0 {
        0
    } else if i >= 64 {
        u64::MAX
    } else {
        (1u64 << i) - 1
    }
}

/// Record one observation. Three relaxed atomic adds, nothing else.
#[inline]
pub fn observe(h: Hist, v: u64) {
    let base = h as usize * N_BUCKETS;
    BUCKETS[base + bucket_of(v)].fetch_add(1, Ordering::Relaxed);
    COUNTS[h as usize].fetch_add(1, Ordering::Relaxed);
    SUMS[h as usize].fetch_add(v, Ordering::Relaxed);
}

/// Bump a monotonic counter.
#[inline]
pub fn count(c: Counter, delta: u64) {
    COUNTERS[c as usize].fetch_add(delta, Ordering::Relaxed);
}

/// Set a gauge (stored as f64 bits).
#[inline]
pub fn set_gauge(g: Gauge, v: f64) {
    GAUGES[g as usize].store(v.to_bits(), Ordering::Relaxed);
}

pub fn gauge_value(g: Gauge) -> f64 {
    f64::from_bits(GAUGES[g as usize].load(Ordering::Relaxed))
}

pub fn counter_value(c: Counter) -> u64 {
    COUNTERS[c as usize].load(Ordering::Relaxed)
}

/// Zero every histogram, counter and gauge.
pub fn reset() {
    for b in BUCKETS.iter().chain(&COUNTS).chain(&SUMS).chain(&COUNTERS).chain(&GAUGES) {
        b.store(0, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// Snapshots
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct HistSnapshot {
    pub name: &'static str,
    pub count: u64,
    pub sum: u64,
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
    /// per-bucket counts, truncated after the last non-empty bucket
    pub buckets: Vec<u64>,
}

impl HistSnapshot {
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

#[derive(Clone, Debug)]
pub struct Snapshot {
    pub hists: Vec<HistSnapshot>,
    pub counters: Vec<(&'static str, u64)>,
    pub gauges: Vec<(&'static str, f64)>,
}

/// Quantile from log₂ buckets: the inclusive upper bound of the bucket
/// containing the `ceil(q·count)`-th observation — an upper estimate
/// within one power of two, monotone in `q` by construction.
fn quantile(buckets: &[u64], total: u64, q: f64) -> u64 {
    if total == 0 {
        return 0;
    }
    let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
    let mut seen = 0u64;
    for (i, &c) in buckets.iter().enumerate() {
        seen += c;
        if seen >= rank {
            return bucket_upper(i);
        }
    }
    bucket_upper(N_BUCKETS - 1)
}

/// Read every metric into plain numbers (relaxed loads; concurrent
/// observers may land either side of the cut — fine for reporting).
pub fn snapshot() -> Snapshot {
    let mut hists = Vec::with_capacity(N_HISTS);
    for h in ALL_HISTS {
        let base = h as usize * N_BUCKETS;
        let buckets: Vec<u64> =
            (0..N_BUCKETS).map(|i| BUCKETS[base + i].load(Ordering::Relaxed)).collect();
        let count = COUNTS[h as usize].load(Ordering::Relaxed);
        let sum = SUMS[h as usize].load(Ordering::Relaxed);
        let last = buckets.iter().rposition(|&c| c > 0).map_or(0, |i| i + 1);
        hists.push(HistSnapshot {
            name: HIST_NAMES[h as usize],
            count,
            sum,
            p50: quantile(&buckets, count, 0.50),
            p95: quantile(&buckets, count, 0.95),
            p99: quantile(&buckets, count, 0.99),
            buckets: buckets[..last].to_vec(),
        });
    }
    let counters = COUNTER_NAMES
        .iter()
        .enumerate()
        .map(|(i, &n)| (n, COUNTERS[i].load(Ordering::Relaxed)))
        .collect();
    let gauges = GAUGE_NAMES
        .iter()
        .enumerate()
        .map(|(i, &n)| (n, f64::from_bits(GAUGES[i].load(Ordering::Relaxed))))
        .collect();
    Snapshot { hists, counters, gauges }
}

impl Snapshot {
    /// The `obs` block of `sim_summary.json`.
    pub fn to_json(&self) -> Value {
        let hists = self
            .hists
            .iter()
            .map(|h| {
                (
                    h.name.to_string(),
                    Value::obj(vec![
                        ("count".into(), Value::Num(h.count as f64)),
                        ("mean".into(), Value::Num(h.mean())),
                        ("p50".into(), Value::Num(h.p50 as f64)),
                        ("p95".into(), Value::Num(h.p95 as f64)),
                        ("p99".into(), Value::Num(h.p99 as f64)),
                    ]),
                )
            })
            .collect();
        let counters = self
            .counters
            .iter()
            .map(|&(n, v)| (n.to_string(), Value::Num(v as f64)))
            .collect();
        let gauges =
            self.gauges.iter().map(|&(n, v)| (n.to_string(), Value::Num(v))).collect();
        Value::obj(vec![
            ("histograms".into(), Value::Obj(hists)),
            ("counters".into(), Value::Obj(counters)),
            ("gauges".into(), Value::Obj(gauges)),
        ])
    }

    /// Prometheus text exposition (histograms with cumulative `le`
    /// buckets, `_total` counters, plain gauges).
    pub fn to_prom(&self) -> String {
        let mut out = String::new();
        for h in &self.hists {
            let name = format!("pfl_{}", h.name);
            out.push_str(&format!("# TYPE {name} histogram\n"));
            let mut cum = 0u64;
            for (i, &c) in h.buckets.iter().enumerate() {
                cum += c;
                out.push_str(&format!(
                    "{name}_bucket{{le=\"{}\"}} {cum}\n",
                    bucket_upper(i)
                ));
            }
            out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count));
            out.push_str(&format!("{name}_sum {}\n", h.sum));
            out.push_str(&format!("{name}_count {}\n", h.count));
        }
        for &(n, v) in &self.counters {
            out.push_str(&format!("# TYPE pfl_{n}_total counter\npfl_{n}_total {v}\n"));
        }
        for &(n, v) in &self.gauges {
            out.push_str(&format!("# TYPE pfl_{n} gauge\npfl_{n} {v}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // the registry is process-global and the lib test binary is
    // concurrent, so assertions here are tolerant: they check structure
    // and monotonicity, not exact counts.

    #[test]
    fn buckets_are_log2_with_zero_bucket() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(1023), 10);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), N_BUCKETS - 1);
        assert_eq!(bucket_upper(0), 0);
        assert_eq!(bucket_upper(1), 1);
        assert_eq!(bucket_upper(10), 1023);
    }

    #[test]
    fn quantiles_are_monotone_and_bound_the_data() {
        let mut buckets = vec![0u64; N_BUCKETS];
        // 100 observations of 3 (bucket 2), 10 of 1000 (bucket 10)
        buckets[2] = 100;
        buckets[10] = 10;
        let p50 = quantile(&buckets, 110, 0.50);
        let p95 = quantile(&buckets, 110, 0.95);
        let p99 = quantile(&buckets, 110, 0.99);
        assert_eq!(p50, 3);
        assert!(p95 >= p50 && p99 >= p95);
        assert_eq!(p99, 1023);
        assert_eq!(quantile(&buckets, 0, 0.99), 0);
    }

    #[test]
    fn observe_count_gauge_roundtrip_into_snapshot() {
        observe(Hist::CohortSize, 5);
        observe(Hist::CohortSize, 9);
        count(Counter::FramesEncoded, 3);
        set_gauge(Gauge::PoolUtilization, 0.5);
        let s = snapshot();
        let h = s.hists.iter().find(|h| h.name == "cohort_size").unwrap();
        assert!(h.count >= 2);
        assert!(h.p50 <= h.p95 && h.p95 <= h.p99);
        let (_, frames) =
            s.counters.iter().find(|(n, _)| *n == "frames_encoded").unwrap();
        assert!(*frames >= 3);
        let (_, util) =
            s.gauges.iter().find(|(n, _)| *n == "pool_utilization").unwrap();
        assert!(util.is_finite());
    }

    #[test]
    fn snapshot_serializes_to_json_and_prom() {
        observe(Hist::QueueDepth, 4);
        let s = snapshot();
        let v = s.to_json();
        let q = v.get("histograms").unwrap().get("queue_depth").unwrap();
        assert!(q.get("count").unwrap().as_f64().unwrap() >= 1.0);
        assert!(q.get("p50").unwrap().as_f64().is_some());
        assert!(v.get("counters").unwrap().get("frames_encoded").is_some());
        assert!(v.get("counters").unwrap().get("queue_push").is_some());
        assert!(v.get("counters").unwrap().get("queue_pop").is_some());
        assert!(v.get("gauges").unwrap().get("pool_utilization").is_some());
        assert!(v.get("gauges").unwrap().get("queue_max_depth").is_some());
        let prom = s.to_prom();
        assert!(prom.contains("# TYPE pfl_queue_depth histogram"));
        assert!(prom.contains("pfl_queue_depth_bucket{le=\"+Inf\"}"));
        assert!(prom.contains("pfl_frames_encoded_total"));
        assert!(prom.contains("# TYPE pfl_pool_utilization gauge"));
    }
}
