//! Structured tracing + metrics for the engine, sim runners, transport
//! and thread pool.
//!
//! Two cooperating pieces:
//!
//! * a **span/event recorder** ([`sink::TraceSink`]) — a preallocated
//!   ring buffer of fixed-size [`Event`]s with interned static names and
//!   dual-clock stamps: deterministic sim-time (seconds from the event
//!   queue, stored as integer µs) and monotonic wall-clock ns. Exported
//!   as Chrome trace-event JSON (Perfetto-loadable) or raw JSONL.
//! * an **alloc-free metrics registry** ([`registry`]) — log₂-bucketed
//!   histograms, counters and gauges backed by static atomics, snapshot
//!   into the `obs` block of `sim_summary.json` and a Prometheus-style
//!   `metrics.prom` text dump.
//!
//! Tracing is **off by default** and every emit helper starts with a
//! single relaxed [`AtomicBool`] load: the disabled path performs no
//! locking and no allocation, which the bench harness asserts under the
//! counting allocator (`SIM_ALLOCS_PER_EVENT_BOUND` holds with the
//! instrumented scheduler). The registry's atomics are always live —
//! they never allocate either.
//!
//! Instrumentation must be *purely observational*: nothing in this
//! module touches an RNG stream, a float accumulator, or a scheduler
//! counter, so bit-for-bit pins (golden series, sync≡async at
//! `inflight=1`) hold with tracing on or off.

pub mod registry;
pub mod sink;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use sink::TraceSink;

// ---------------------------------------------------------------------------
// Interned event names
// ---------------------------------------------------------------------------

/// Interned event-name id; index into [`NAMES`].
pub type Name = u16;

pub const ROUND: Name = 0;
pub const COHORT_DRAW: Name = 1;
pub const QUORUM_WAIT: Name = 2;
pub const ROUND_COMMIT: Name = 3;
pub const ROUND_ABORT: Name = 4;
pub const DEADLINE_ABORT: Name = 5;
pub const DEVICE_ARRIVAL: Name = 6;
pub const STALE_APPLY: Name = 7;
pub const STALE_DISCARD: Name = 8;
pub const LOCAL_SWEEP: Name = 9;
pub const AGGREGATE: Name = 10;
pub const COMPRESS: Name = 11;
pub const DECOMPRESS: Name = 12;
pub const FRAME_ENCODE: Name = 13;
pub const FRAME_DECODE: Name = 14;
pub const LOOPBACK_TX: Name = 15;
pub const LOOPBACK_RX: Name = 16;
pub const WORKER_TASK: Name = 17;
pub const QUEUE_DEPTH: Name = 18;
pub const COHORT_SIZE: Name = 19;

/// Static name table — `NAMES[name as usize]` is the display string.
pub const NAMES: &[&str] = &[
    "round",
    "cohort_draw",
    "quorum_wait",
    "round_commit",
    "round_abort",
    "deadline_abort",
    "device_arrival",
    "stale_apply",
    "stale_discard",
    "local_sweep",
    "aggregate",
    "compress",
    "decompress",
    "frame_encode",
    "frame_decode",
    "loopback_tx",
    "loopback_rx",
    "worker_task",
    "queue_depth",
    "cohort_size",
];

pub fn name_str(n: Name) -> &'static str {
    NAMES.get(n as usize).copied().unwrap_or("?")
}

// ---------------------------------------------------------------------------
// Lanes (Chrome `tid`s)
// ---------------------------------------------------------------------------

/// Engine-internal work (sweeps, aggregation, codec stages).
pub const LANE_ENGINE: u32 = 1;
/// Transport-layer events (frame codec, loopback TX/RX).
pub const LANE_TRANSPORT: u32 = 2;

const ROUND_LANE_BASE: u32 = 0x2000_0000;
const DEVICE_LANE_BASE: u32 = 0x1000_0000;
const WORKER_LANE_BASE: u32 = 0x4000_0000;

/// Round-lifecycle lane for an in-flight round slot. The sync runner has
/// exactly one round in flight and always uses slot 0, so at
/// `inflight=1` the async runner lands on the same lane.
pub fn round_lane(slot: usize) -> u32 {
    ROUND_LANE_BASE + slot as u32
}

/// Sim-time lane for one sampled device.
pub fn device_lane(device: usize) -> u32 {
    DEVICE_LANE_BASE + device as u32
}

/// Wall-clock lane for one worker thread of the pool.
pub fn worker_lane(worker: usize) -> u32 {
    WORKER_LANE_BASE + worker as u32
}

/// True iff `lane` is a round-lifecycle lane (see [`round_lane`]).
pub fn is_round_lane(lane: u32) -> bool {
    (ROUND_LANE_BASE..ROUND_LANE_BASE + 0x1000_0000).contains(&lane)
}

// ---------------------------------------------------------------------------
// Events
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// Span begin (`ph: "B"`).
    Begin,
    /// Span end (`ph: "E"`).
    End,
    /// Instant (`ph: "i"`).
    Instant,
    /// Counter sample (`ph: "C"`, value in `args`).
    Counter,
}

impl Kind {
    pub fn ph(self) -> &'static str {
        match self {
            Kind::Begin => "B",
            Kind::End => "E",
            Kind::Instant => "i",
            Kind::Counter => "C",
        }
    }
}

/// One fixed-size trace record. `sim_us < 0` means the event carries no
/// deterministic sim-time stamp (wall-clock only — engine/pool work).
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub name: Name,
    pub kind: Kind,
    pub lane: u32,
    pub sim_us: i64,
    pub wall_ns: u64,
    pub value: f64,
}

/// Sentinel sim-time for events that only exist on the wall clock.
pub const NO_SIM_TIME: f64 = -1.0;

// ---------------------------------------------------------------------------
// Global gate + sink
// ---------------------------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);
static SINK: Mutex<Option<TraceSink>> = Mutex::new(None);

fn wall_anchor() -> &'static Instant {
    static ANCHOR: OnceLock<Instant> = OnceLock::new();
    ANCHOR.get_or_init(Instant::now)
}

/// The no-op gate: one relaxed atomic load. Every emit helper returns
/// immediately when this is false — no lock, no allocation.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Install a fresh ring-buffer sink of `capacity` events and open the
/// gate. A previously installed sink is discarded.
pub fn enable(capacity: usize) {
    let mut guard = lock_sink();
    *guard = Some(TraceSink::with_capacity(capacity));
    drop(guard);
    // touch the anchor before the gate opens so first stamps are cheap
    let _ = wall_anchor();
    ENABLED.store(true, Ordering::SeqCst);
}

/// Close the gate and take the recorded sink out (if any).
pub fn disable() -> Option<TraceSink> {
    ENABLED.store(false, Ordering::SeqCst);
    lock_sink().take()
}

fn lock_sink() -> std::sync::MutexGuard<'static, Option<TraceSink>> {
    SINK.lock().unwrap_or_else(|e| e.into_inner())
}

#[inline]
fn record(kind: Kind, name: Name, lane: u32, sim_s: f64, value: f64) {
    let wall_ns = wall_anchor().elapsed().as_nanos() as u64;
    let sim_us = if sim_s >= 0.0 { (sim_s * 1e6).round() as i64 } else { -1 };
    let ev = Event { name, kind, lane, sim_us, wall_ns, value };
    let mut guard = lock_sink();
    if let Some(sink) = guard.as_mut() {
        if sink.push(ev) {
            drop(guard);
            registry::count(registry::Counter::TraceEventsDropped, 1);
        }
    }
}

/// Open a span on `lane` at sim time `sim_s` (pass [`NO_SIM_TIME`] for
/// wall-clock-only work).
#[inline]
pub fn span_begin(name: Name, lane: u32, sim_s: f64) {
    if enabled() {
        record(Kind::Begin, name, lane, sim_s, 0.0);
    }
}

/// Close the most recent open span on `lane`.
#[inline]
pub fn span_end(name: Name, lane: u32, sim_s: f64) {
    if enabled() {
        record(Kind::End, name, lane, sim_s, 0.0);
    }
}

/// A point event, with an optional payload in `value`.
#[inline]
pub fn instant(name: Name, lane: u32, sim_s: f64, value: f64) {
    if enabled() {
        record(Kind::Instant, name, lane, sim_s, value);
    }
}

/// A counter sample (rendered as a Chrome counter track).
#[inline]
pub fn counter(name: Name, lane: u32, sim_s: f64, value: f64) {
    if enabled() {
        record(Kind::Counter, name, lane, sim_s, value);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // obs state is process-global; serialize the tests that toggle it
    // (the lib test binary runs tests concurrently).
    fn serial() -> std::sync::MutexGuard<'static, ()> {
        static GATE: Mutex<()> = Mutex::new(());
        GATE.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disabled_emits_are_no_ops() {
        let _g = serial();
        let _ = disable();
        span_begin(ROUND, round_lane(0), 0.0);
        instant(COHORT_DRAW, round_lane(0), 0.5, 3.0);
        // no sink installed, gate closed: nothing recorded, nothing panics
        assert!(disable().is_none());
    }

    #[test]
    fn enable_records_and_disable_returns_the_sink() {
        let _g = serial();
        enable(16);
        assert!(enabled());
        span_begin(LOCAL_SWEEP, LANE_ENGINE, NO_SIM_TIME);
        span_end(LOCAL_SWEEP, LANE_ENGINE, NO_SIM_TIME);
        instant(DEVICE_ARRIVAL, device_lane(3), 1.25, 0.0);
        let sink = disable().expect("sink");
        assert!(!enabled());
        let evs = sink.events_in_order();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].kind, Kind::Begin);
        assert_eq!(evs[0].sim_us, -1);
        assert_eq!(evs[2].sim_us, 1_250_000);
        assert_eq!(evs[2].lane, device_lane(3));
    }

    #[test]
    fn name_table_covers_every_id() {
        let _g = serial();
        for n in 0..NAMES.len() as Name {
            assert_ne!(name_str(n), "?");
        }
        assert_eq!(name_str(999), "?");
        assert_eq!(NAMES.len(), COHORT_SIZE as usize + 1);
    }

    #[test]
    fn lane_helpers_do_not_collide() {
        let _g = serial();
        assert!(is_round_lane(round_lane(0)));
        assert!(is_round_lane(round_lane(7)));
        assert!(!is_round_lane(device_lane(0)));
        assert!(!is_round_lane(worker_lane(0)));
        assert!(!is_round_lane(LANE_ENGINE));
        assert!(!is_round_lane(LANE_TRANSPORT));
    }
}
