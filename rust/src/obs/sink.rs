//! Preallocated ring-buffer trace sink and its two exporters.
//!
//! * [`TraceSink::to_chrome_trace`] — Chrome trace-event JSON
//!   (`chrome://tracing` / Perfetto). Events are split into two
//!   processes: **pid 1** is the deterministic sim-time domain (`ts` =
//!   sim µs) and **pid 2** is the wall clock (`ts` = monotonic µs since
//!   the trace anchor). Lanes map to `tid`s — round slots, sampled
//!   devices, the engine, the transport, and one lane per worker
//!   thread — each named through `thread_name` metadata events.
//! * [`TraceSink::to_jsonl`] — one compact JSON object per event, in
//!   recording order, for scripting.
//!
//! The ring keeps the **newest** `capacity` events: when full, the
//! oldest event is overwritten and counted. Because a wrapped ring can
//! open mid-span, the Chrome exporter re-balances each lane at export
//! time (unmatched `E`s dropped, dangling `B`s closed at the lane's
//! last timestamp) and clamps per-lane timestamps monotone, so the
//! emitted file always loads clean.

use std::collections::BTreeMap;

use super::{name_str, Event, Kind, LANE_ENGINE, LANE_TRANSPORT};
use crate::util::json::Value;

pub struct TraceSink {
    buf: Vec<Event>,
    capacity: usize,
    /// next write position once the ring has wrapped
    head: usize,
    dropped: u64,
}

impl TraceSink {
    pub fn with_capacity(capacity: usize) -> TraceSink {
        let capacity = capacity.max(1);
        TraceSink { buf: Vec::with_capacity(capacity), capacity, head: 0, dropped: 0 }
    }

    /// Append one event; returns `true` if an old event was overwritten.
    pub fn push(&mut self, ev: Event) -> bool {
        if self.buf.len() < self.capacity {
            self.buf.push(ev);
            false
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.capacity;
            self.dropped += 1;
            true
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Events overwritten by ring wrap-around.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Recorded events, oldest first.
    pub fn events_in_order(&self) -> Vec<Event> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
        out
    }

    // -- exporters ----------------------------------------------------------

    /// Raw event stream: one compact JSON object per line.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for ev in self.events_in_order() {
            let v = Value::obj(vec![
                ("name".into(), Value::Str(name_str(ev.name).into())),
                ("ph".into(), Value::Str(ev.kind.ph().into())),
                ("lane".into(), Value::Num(ev.lane as f64)),
                ("sim_us".into(), Value::Num(ev.sim_us as f64)),
                ("wall_ns".into(), Value::Num(ev.wall_ns as f64)),
                ("value".into(), Value::Num(ev.value)),
            ]);
            out.push_str(&v.to_string());
            out.push('\n');
        }
        out
    }

    /// Chrome trace-event JSON (`{"traceEvents": [...]}`).
    pub fn to_chrome_trace(&self) -> String {
        // (pid, tid) -> events, grouped in recording order. BTreeMap keeps
        // the output deterministic.
        let mut lanes: BTreeMap<(u8, u32), Vec<Event>> = BTreeMap::new();
        for ev in self.events_in_order() {
            lanes.entry((domain_pid(&ev), ev.lane)).or_default().push(ev);
        }
        let mut out: Vec<Value> =
            vec![process_name(SIM_PID, "sim-time"), process_name(WALL_PID, "wall-clock")];
        for (&(pid, tid), evs) in &lanes {
            out.push(thread_name(pid, tid));
            export_lane(&mut out, pid, tid, evs);
        }
        Value::obj(vec![
            ("traceEvents".into(), Value::Arr(out)),
            ("displayTimeUnit".into(), Value::Str("ms".into())),
        ])
        .to_string_pretty()
    }
}

/// Deterministic sim-time process.
const SIM_PID: u8 = 1;
/// Monotonic wall-clock process.
const WALL_PID: u8 = 2;

fn domain_pid(ev: &Event) -> u8 {
    if ev.sim_us >= 0 {
        SIM_PID
    } else {
        WALL_PID
    }
}

fn ts_us(pid: u8, ev: &Event) -> i64 {
    if pid == SIM_PID {
        ev.sim_us
    } else {
        (ev.wall_ns / 1_000) as i64
    }
}

/// One lane, re-balanced (B/E stack discipline) with monotone clamped
/// timestamps, appended to `out` as trace-event objects.
fn export_lane(out: &mut Vec<Value>, pid: u8, tid: u32, evs: &[Event]) {
    let mut last_ts = i64::MIN;
    // names of currently open spans, so dangling ones can be closed
    let mut open: Vec<&'static str> = Vec::new();
    for ev in evs {
        let ts = ts_us(pid, ev).max(last_ts).max(0);
        last_ts = ts.max(0);
        match ev.kind {
            Kind::Begin => {
                open.push(name_str(ev.name));
                out.push(trace_event(name_str(ev.name), "B", ts, pid, tid, None));
            }
            Kind::End => {
                // a ring that wrapped mid-span can hold an E with no B:
                // drop it, the lane stays balanced
                if let Some(name) = open.pop() {
                    out.push(trace_event(name, "E", ts, pid, tid, None));
                }
            }
            Kind::Instant => {
                out.push(trace_event(name_str(ev.name), "i", ts, pid, tid, Some(ev.value)));
            }
            Kind::Counter => {
                out.push(trace_event(name_str(ev.name), "C", ts, pid, tid, Some(ev.value)));
            }
        }
    }
    // close dangling spans (trace stopped mid-round) at the last stamp
    while let Some(name) = open.pop() {
        out.push(trace_event(name, "E", last_ts.max(0), pid, tid, None));
    }
}

fn trace_event(name: &str, ph: &str, ts: i64, pid: u8, tid: u32, value: Option<f64>) -> Value {
    let mut pairs = vec![
        ("name".into(), Value::Str(name.into())),
        ("ph".into(), Value::Str(ph.into())),
        ("ts".into(), Value::Num(ts as f64)),
        ("pid".into(), Value::Num(pid as f64)),
        ("tid".into(), Value::Num(tid as f64)),
    ];
    if ph == "i" {
        pairs.push(("s".into(), Value::Str("t".into())));
    }
    if let Some(v) = value {
        pairs.push(("args".into(), Value::obj(vec![("value".into(), Value::Num(v))])));
    }
    Value::obj(pairs)
}

fn process_name(pid: u8, name: &str) -> Value {
    Value::obj(vec![
        ("name".into(), Value::Str("process_name".into())),
        ("ph".into(), Value::Str("M".into())),
        ("pid".into(), Value::Num(pid as f64)),
        ("args".into(), Value::obj(vec![("name".into(), Value::Str(name.into()))])),
    ])
}

fn thread_name(pid: u8, tid: u32) -> Value {
    let label = lane_label(tid);
    Value::obj(vec![
        ("name".into(), Value::Str("thread_name".into())),
        ("ph".into(), Value::Str("M".into())),
        ("pid".into(), Value::Num(pid as f64)),
        ("tid".into(), Value::Num(tid as f64)),
        ("args".into(), Value::obj(vec![("name".into(), Value::Str(label))])),
    ])
}

fn lane_label(tid: u32) -> String {
    match tid {
        LANE_ENGINE => "engine".into(),
        LANE_TRANSPORT => "transport".into(),
        t if super::is_round_lane(t) => format!("round slot {}", t - 0x2000_0000),
        t if (0x1000_0000..0x2000_0000).contains(&t) => {
            format!("device {}", t - 0x1000_0000)
        }
        t if t >= 0x4000_0000 => format!("worker {}", t - 0x4000_0000),
        t => format!("lane {t}"),
    }
}

#[cfg(test)]
mod tests {
    use super::super::{
        device_lane, round_lane, COHORT_DRAW, DEVICE_ARRIVAL, LOCAL_SWEEP, QUORUM_WAIT, ROUND,
    };
    use super::*;
    use crate::util::json;

    fn ev(name: u16, kind: Kind, lane: u32, sim_us: i64, wall_ns: u64) -> Event {
        Event { name, kind, lane, sim_us, wall_ns, value: 0.0 }
    }

    #[test]
    fn ring_keeps_the_newest_events() {
        let mut sink = TraceSink::with_capacity(3);
        for i in 0..5 {
            sink.push(ev(ROUND, Kind::Instant, 0, i, i as u64));
        }
        assert_eq!(sink.len(), 3);
        assert_eq!(sink.dropped(), 2);
        let order: Vec<i64> = sink.events_in_order().iter().map(|e| e.sim_us).collect();
        assert_eq!(order, vec![2, 3, 4]);
    }

    #[test]
    fn chrome_export_parses_and_balances() {
        let mut sink = TraceSink::with_capacity(64);
        let lane = round_lane(0);
        sink.push(ev(ROUND, Kind::Begin, lane, 0, 10));
        sink.push(ev(COHORT_DRAW, Kind::Instant, lane, 0, 20));
        sink.push(ev(QUORUM_WAIT, Kind::Begin, lane, 0, 30));
        sink.push(ev(QUORUM_WAIT, Kind::End, lane, 500, 40));
        sink.push(ev(ROUND, Kind::End, lane, 500, 50));
        // wall-only engine span
        sink.push(ev(LOCAL_SWEEP, Kind::Begin, LANE_ENGINE, -1, 100));
        sink.push(ev(LOCAL_SWEEP, Kind::End, LANE_ENGINE, -1, 9_000));
        let v = json::parse(&sink.to_chrome_trace()).unwrap();
        let evs = v.get("traceEvents").unwrap().as_arr().unwrap();
        // per-lane B/E balance
        let mut depth = 0i64;
        for e in evs {
            match e.get("ph").unwrap().as_str().unwrap() {
                "B" => depth += 1,
                "E" => depth -= 1,
                _ => {}
            }
            assert!(depth >= 0);
        }
        assert_eq!(depth, 0);
        // the sim lane rides pid 1, the engine lane pid 2
        let pids: Vec<f64> = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() != Some("M"))
            .map(|e| e.get("pid").unwrap().as_f64().unwrap())
            .collect();
        assert!(pids.contains(&1.0) && pids.contains(&2.0));
    }

    #[test]
    fn wrapped_ring_still_exports_balanced_spans() {
        let mut sink = TraceSink::with_capacity(3);
        let lane = round_lane(0);
        // the B falls out of the ring; only the E and a fresh B survive
        sink.push(ev(ROUND, Kind::Begin, lane, 0, 0));
        sink.push(ev(COHORT_DRAW, Kind::Instant, lane, 1, 1));
        sink.push(ev(ROUND, Kind::End, lane, 2, 2));
        sink.push(ev(ROUND, Kind::Begin, lane, 3, 3)); // evicts the first B
        let v = json::parse(&sink.to_chrome_trace()).unwrap();
        let evs = v.get("traceEvents").unwrap().as_arr().unwrap();
        let mut depth = 0i64;
        for e in evs {
            match e.get("ph").unwrap().as_str().unwrap() {
                "B" => depth += 1,
                "E" => depth -= 1,
                _ => {}
            }
            assert!(depth >= 0, "unmatched E must be dropped");
        }
        assert_eq!(depth, 0, "dangling B must be closed at export");
    }

    #[test]
    fn timestamps_are_clamped_monotone_per_lane() {
        let mut sink = TraceSink::with_capacity(8);
        let lane = device_lane(7);
        sink.push(ev(DEVICE_ARRIVAL, Kind::Instant, lane, 900, 0));
        sink.push(ev(DEVICE_ARRIVAL, Kind::Instant, lane, 100, 1)); // out of order
        let v = json::parse(&sink.to_chrome_trace()).unwrap();
        let ts: Vec<f64> = v
            .get("traceEvents")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("i"))
            .map(|e| e.get("ts").unwrap().as_f64().unwrap())
            .collect();
        assert_eq!(ts, vec![900.0, 900.0]);
    }

    #[test]
    fn jsonl_emits_one_object_per_event() {
        let mut sink = TraceSink::with_capacity(8);
        sink.push(ev(ROUND, Kind::Begin, round_lane(0), 0, 0));
        sink.push(ev(ROUND, Kind::End, round_lane(0), 5, 5));
        let text = sink.to_jsonl();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            let v = json::parse(line).unwrap();
            assert_eq!(v.get("name").unwrap().as_str(), Some("round"));
        }
    }
}
