//! Compute backends.
//!
//! The training algorithms are generic over `Backend`: a gradient/eval
//! oracle for a model over a flat f32[P] parameter vector.
//!
//! * [`xla::XlaRuntime`] — the production path: loads the AOT artifacts
//!   (`artifacts/manifest.json` + HLO text) produced by `make artifacts`
//!   and executes them on the PJRT CPU client. Python is never involved.
//! * [`NativeLogreg`] — a pure-Rust implementation of the same logistic
//!   gradient the L1 Pallas kernel computes. It exists (a) to cross-check
//!   the HLO path numerically (integration tests assert XLA ≡ native), and
//!   (b) to run huge convex sweeps (Fig 3) at native speed.

pub mod xla;

use crate::data::{Batcher, Dataset};
use crate::util::Rng;

pub use xla::XlaRuntime;

/// One model-consumable batch.
#[derive(Clone, Debug)]
pub enum Batch {
    /// logreg family: features, ±1 labels, sample weights (padding = 0)
    Weighted { x: Vec<f32>, y: Vec<f32>, sw: Vec<f32> },
    /// classifier families: features + int class labels
    Labeled { x: Vec<f32>, y: Vec<i32> },
    /// LM family: token windows (input ∥ shifted targets)
    Tokens { t: Vec<i32> },
}

impl Batch {
    /// Number of effective prediction events (for accuracy normalization).
    pub fn count(&self, tokens_per_sample: usize) -> f64 {
        match self {
            Batch::Weighted { sw, .. } => sw.iter().map(|&w| w as f64).sum(),
            Batch::Labeled { y, .. } => y.len() as f64,
            Batch::Tokens { t } => {
                let w = tokens_per_sample + 1;
                (t.len() / w) as f64 * tokens_per_sample as f64
            }
        }
    }
}

#[derive(Clone, Debug)]
pub struct GradOut {
    pub grad: Vec<f32>,
    pub loss: f64,
    /// raw correct-prediction count on the batch
    pub correct: f64,
}

#[derive(Clone, Debug)]
pub struct EvalOut {
    pub loss: f64,
    pub accuracy: f64,
}

/// Gradient/eval oracle over flat parameters.
pub trait Backend: Send + Sync {
    fn name(&self) -> String;
    fn param_count(&self) -> usize;
    /// Initial parameters (identical on every device, as Algorithm 1 assumes
    /// a shared x̄^{-1}).
    fn init_params(&self) -> Vec<f32>;

    fn grad(&self, theta: &[f32], batch: &Batch) -> anyhow::Result<GradOut>;
    fn eval(&self, theta: &[f32], batch: &Batch) -> anyhow::Result<EvalOut>;

    /// Assemble a training batch from a client shard.
    fn make_train_batch(&self, shard: &Dataset, rng: &mut Rng) -> Batch;
    /// Assemble a deterministic evaluation batch.
    fn make_eval_batch(&self, data: &Dataset) -> Batch;
}

// ---------------------------------------------------------------------------
// Native logistic-regression backend
// ---------------------------------------------------------------------------

/// Pure-Rust weighted L2-regularized logistic regression; numerically
/// mirrors `python/compile/kernels/fused_logreg.py` / `ref.py`.
pub struct NativeLogreg {
    pub dim: usize,
    pub l2: f32,
    pub train_pad: usize,
    pub eval_pad: usize,
}

impl NativeLogreg {
    pub fn new(dim: usize, l2: f32, train_pad: usize, eval_pad: usize) -> NativeLogreg {
        NativeLogreg { dim, l2, train_pad, eval_pad }
    }

    fn forward(&self, theta: &[f32], x: &[f32], y: &[f32], sw: &[f32],
               grad: Option<&mut [f32]>) -> (f64, f64) {
        let d = self.dim;
        let m = x.len() / d;
        let total_w: f64 = sw.iter().map(|&w| w as f64).sum();
        let mut loss = 0.0f64;
        let mut correct = 0.0f64;
        let mut g = grad;
        for j in 0..m {
            let wj = sw[j];
            if wj == 0.0 {
                continue;
            }
            let row = &x[j * d..(j + 1) * d];
            let z: f32 = row.iter().zip(theta).map(|(a, b)| a * b).sum();
            let yz = (y[j] * z) as f64;
            // log(1 + e^{-yz}) stably
            loss += wj as f64 * if yz > 0.0 {
                (-yz).exp().ln_1p()
            } else {
                -yz + yz.exp().ln_1p()
            };
            if yz > 0.0 {
                correct += wj as f64;
            }
            if let Some(gbuf) = g.as_deref_mut() {
                let coef = wj * (-y[j]) / (1.0 + (y[j] * z).exp());
                for (gi, xi) in gbuf.iter_mut().zip(row) {
                    *gi += coef * xi;
                }
            }
        }
        let reg: f64 = theta.iter().map(|&t| 0.5 * self.l2 as f64 * (t as f64) * (t as f64)).sum();
        loss = loss / total_w + reg;
        if let Some(gbuf) = g {
            let inv = 1.0 / total_w as f32;
            for (gi, ti) in gbuf.iter_mut().zip(theta) {
                *gi = *gi * inv + self.l2 * ti;
            }
        }
        (loss, correct)
    }
}

impl Backend for NativeLogreg {
    fn name(&self) -> String {
        format!("native_logreg:{}", self.dim)
    }

    fn param_count(&self) -> usize {
        self.dim
    }

    fn init_params(&self) -> Vec<f32> {
        vec![0.0; self.dim] // matches model.py ("zeros" init for logreg)
    }

    fn grad(&self, theta: &[f32], batch: &Batch) -> anyhow::Result<GradOut> {
        let Batch::Weighted { x, y, sw } = batch else {
            anyhow::bail!("NativeLogreg expects a Weighted batch");
        };
        let mut grad = vec![0.0f32; self.dim];
        let (loss, correct) = self.forward(theta, x, y, sw, Some(&mut grad));
        Ok(GradOut { grad, loss, correct })
    }

    fn eval(&self, theta: &[f32], batch: &Batch) -> anyhow::Result<EvalOut> {
        let Batch::Weighted { x, y, sw } = batch else {
            anyhow::bail!("NativeLogreg expects a Weighted batch");
        };
        let (loss, correct) = self.forward(theta, x, y, sw, None);
        Ok(EvalOut { loss, accuracy: correct / batch.count(0) })
    }

    fn make_train_batch(&self, shard: &Dataset, _rng: &mut Rng) -> Batch {
        // the paper's convex experiments use the *full* local gradient
        let (x, y, sw) = Batcher::new(shard).full_weighted(self.train_pad);
        Batch::Weighted { x, y, sw }
    }

    fn make_eval_batch(&self, data: &Dataset) -> Batch {
        let (x, y, sw) = Batcher::new(data).eval_weighted(self.eval_pad, self.eval_pad);
        Batch::Weighted { x, y, sw }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn setup() -> (NativeLogreg, Dataset) {
        (NativeLogreg::new(20, 0.01, 64, 64), synth::logistic(60, 20, 0.05, 1))
    }

    #[test]
    fn grad_matches_finite_differences() {
        let (be, data) = setup();
        let mut rng = Rng::new(0);
        let batch = be.make_train_batch(&data, &mut rng);
        let mut theta: Vec<f32> = (0..20).map(|i| 0.05 * (i as f32 - 10.0)).collect();
        let g = be.grad(&theta, &batch).unwrap();
        let eps = 1e-3f32;
        for i in [0usize, 7, 19] {
            let orig = theta[i];
            theta[i] = orig + eps;
            let lp = be.eval(&theta, &batch).unwrap().loss;
            theta[i] = orig - eps;
            let lm = be.eval(&theta, &batch).unwrap().loss;
            theta[i] = orig;
            let fd = (lp - lm) / (2.0 * eps as f64);
            assert!((fd - g.grad[i] as f64).abs() < 5e-3,
                    "coord {i}: fd {fd} vs grad {}", g.grad[i]);
        }
    }

    #[test]
    fn gd_converges_on_separable_data() {
        let (be, data) = setup();
        let mut rng = Rng::new(0);
        let batch = be.make_train_batch(&data, &mut rng);
        let mut theta = be.init_params();
        let l0 = be.eval(&theta, &batch).unwrap().loss;
        for _ in 0..300 {
            let g = be.grad(&theta, &batch).unwrap();
            crate::model::axpy(&mut theta, -1.0, &g.grad);
        }
        let out = be.eval(&theta, &batch).unwrap();
        assert!(out.loss < l0 * 0.5, "loss {l0} -> {}", out.loss);
        assert!(out.accuracy > 0.9, "acc {}", out.accuracy);
    }

    #[test]
    fn zero_weight_padding_is_inert() {
        let (be, data) = setup();
        let mut rng = Rng::new(0);
        let theta: Vec<f32> = (0..20).map(|i| 0.1 * i as f32).collect();
        let b64 = be.make_train_batch(&data, &mut rng);
        let be_bigger = NativeLogreg::new(20, 0.01, 128, 64);
        let b128 = be_bigger.make_train_batch(&data, &mut rng);
        let g1 = be.grad(&theta, &b64).unwrap();
        let g2 = be_bigger.grad(&theta, &b128).unwrap();
        assert!((g1.loss - g2.loss).abs() < 1e-9);
        for (a, b) in g1.grad.iter().zip(&g2.grad) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
