//! Compute backends.
//!
//! The training algorithms are generic over `Backend`: a gradient/eval
//! oracle for a model over a flat f32[P] parameter vector.
//!
//! * [`xla::XlaRuntime`] — the production path: loads the AOT artifacts
//!   (`artifacts/manifest.json` + HLO text) produced by `make artifacts`
//!   and executes them on the PJRT CPU client. Python is never involved.
//! * [`NativeLogreg`] — a pure-Rust implementation of the same logistic
//!   gradient the L1 Pallas kernel computes. It exists (a) to cross-check
//!   the HLO path numerically (integration tests assert XLA ≡ native), and
//!   (b) to run huge convex sweeps (Fig 3) at native speed.
//!
//! ### The buffer-reusing hot path
//! [`Backend::grad_into`] writes the gradient into a caller-owned
//! [`GradBuf`], so steady-state training performs no per-step heap
//! allocation; the allocating [`Backend::grad`] remains as the convenience
//! entry (and the default `grad_into` wraps it, so backends like
//! [`xla::XlaBackend`] that marshal through PJRT literals keep working
//! unchanged). Backends whose training batch is a deterministic function
//! of the shard advertise [`Backend::static_train_batch`], which lets
//! `FedEnv` assemble each shard's batch once instead of per call.

pub mod xla;

use crate::data::{Batcher, Dataset};
use crate::util::Rng;

pub use xla::XlaRuntime;

/// One model-consumable batch.
#[derive(Clone, Debug)]
pub enum Batch {
    /// logreg family: features, ±1 labels, sample weights (padding = 0),
    /// and the weight sum precomputed once at assembly (the effective
    /// sample count — the forward pass normalizes by it every call).
    Weighted { x: Vec<f32>, y: Vec<f32>, sw: Vec<f32>, wsum: f64 },
    /// classifier families: features + int class labels
    Labeled { x: Vec<f32>, y: Vec<i32> },
    /// LM family: token windows (input ∥ shifted targets)
    Tokens { t: Vec<i32> },
}

impl Batch {
    /// Weighted logreg batch; sums the sample weights once here so the
    /// per-call forward never re-reduces them.
    pub fn weighted(x: Vec<f32>, y: Vec<f32>, sw: Vec<f32>) -> Batch {
        let wsum: f64 = sw.iter().map(|&w| w as f64).sum();
        Batch::Weighted { x, y, sw, wsum }
    }

    /// Number of effective prediction events (for accuracy normalization).
    pub fn count(&self, tokens_per_sample: usize) -> f64 {
        match self {
            Batch::Weighted { wsum, .. } => *wsum,
            Batch::Labeled { y, .. } => y.len() as f64,
            Batch::Tokens { t } => {
                let w = tokens_per_sample + 1;
                (t.len() / w) as f64 * tokens_per_sample as f64
            }
        }
    }
}

#[derive(Clone, Debug)]
pub struct GradOut {
    pub grad: Vec<f32>,
    pub loss: f64,
    /// raw correct-prediction count on the batch
    pub correct: f64,
}

/// Reusable gradient output buffer for [`Backend::grad_into`]. The `grad`
/// vector keeps its capacity across calls, so a per-client `GradBuf` makes
/// the local-step fan-out allocation-free in steady state.
#[derive(Clone, Debug, Default)]
pub struct GradBuf {
    pub grad: Vec<f32>,
    pub loss: f64,
    /// raw correct-prediction count on the batch
    pub correct: f64,
}

impl GradBuf {
    pub fn new() -> GradBuf {
        GradBuf::default()
    }

    /// Pre-sized buffer (avoids the one growth on first use).
    pub fn with_dim(d: usize) -> GradBuf {
        GradBuf { grad: vec![0.0; d], loss: 0.0, correct: 0.0 }
    }

    pub fn into_out(self) -> GradOut {
        GradOut { grad: self.grad, loss: self.loss, correct: self.correct }
    }
}

#[derive(Clone, Debug)]
pub struct EvalOut {
    pub loss: f64,
    pub accuracy: f64,
}

/// Gradient/eval oracle over flat parameters.
pub trait Backend: Send + Sync {
    fn name(&self) -> String;
    fn param_count(&self) -> usize;
    /// Initial parameters (identical on every device, as Algorithm 1 assumes
    /// a shared x̄^{-1}).
    fn init_params(&self) -> Vec<f32>;

    fn grad(&self, theta: &[f32], batch: &Batch) -> anyhow::Result<GradOut>;
    fn eval(&self, theta: &[f32], batch: &Batch) -> anyhow::Result<EvalOut>;

    /// Buffer-reusing gradient: fill `out` (resizing `out.grad` to
    /// `param_count` without reallocating once warm). The default wraps
    /// the allocating [`Backend::grad`] so existing backends keep working;
    /// hot-path backends override it.
    fn grad_into(&self, theta: &[f32], batch: &Batch, out: &mut GradBuf)
                 -> anyhow::Result<()> {
        let g = self.grad(theta, batch)?;
        out.grad.clear();
        out.grad.extend_from_slice(&g.grad);
        out.loss = g.loss;
        out.correct = g.correct;
        Ok(())
    }

    /// True when `make_train_batch` is a deterministic, RNG-free function
    /// of the shard (the full-gradient convex regimes). Lets the
    /// environment cache one batch per shard instead of assembling
    /// per call — the single largest saving in the round hot path.
    fn static_train_batch(&self) -> bool {
        false
    }

    /// Assemble a training batch from a client shard.
    fn make_train_batch(&self, shard: &Dataset, rng: &mut Rng) -> Batch;
    /// Assemble a deterministic evaluation batch.
    fn make_eval_batch(&self, data: &Dataset) -> Batch;
}

// ---------------------------------------------------------------------------
// Native logistic-regression backend
// ---------------------------------------------------------------------------

/// Pure-Rust weighted L2-regularized logistic regression; numerically
/// mirrors `python/compile/kernels/fused_logreg.py` / `ref.py`.
pub struct NativeLogreg {
    pub dim: usize,
    pub l2: f32,
    pub train_pad: usize,
    pub eval_pad: usize,
}

impl NativeLogreg {
    pub fn new(dim: usize, l2: f32, train_pad: usize, eval_pad: usize) -> NativeLogreg {
        NativeLogreg { dim, l2, train_pad, eval_pad }
    }

    /// Fused loss/accuracy/gradient pass. One transcendental per active
    /// sample: `t = e^{−|y·z|}` feeds both the stable softplus loss
    /// (`log(1+e^{−yz})`) and the sigmoid gradient coefficient
    /// (`σ(−yz) = t/(1+t)` or `1/(1+t)` by sign). `total_w` arrives
    /// precomputed from the batch (`Batch::weighted`).
    fn forward(&self, theta: &[f32], x: &[f32], y: &[f32], sw: &[f32], total_w: f64,
               grad: Option<&mut [f32]>) -> (f64, f64) {
        let d = self.dim;
        let m = x.len() / d;
        let mut loss = 0.0f64;
        let mut correct = 0.0f64;
        let mut g = grad;
        for j in 0..m {
            let wj = sw[j];
            if wj == 0.0 {
                continue;
            }
            let row = &x[j * d..(j + 1) * d];
            let z = crate::model::kernels::dot(row, theta);
            let yz = (y[j] * z) as f64;
            // t = e^{−|yz|}: log(1 + e^{−yz}) stably, in both branches
            let t = (-yz.abs()).exp();
            loss += wj as f64 * if yz > 0.0 { t.ln_1p() } else { -yz + t.ln_1p() };
            if yz > 0.0 {
                correct += wj as f64;
            }
            if let Some(gbuf) = g.as_deref_mut() {
                // σ(−yz), reusing t instead of a second exp
                let sig = if yz > 0.0 { t / (1.0 + t) } else { 1.0 / (1.0 + t) };
                let coef = wj * (-y[j]) * sig as f32;
                crate::model::kernels::axpy(gbuf, coef, row);
            }
        }
        let reg: f64 = theta.iter().map(|&t| 0.5 * self.l2 as f64 * (t as f64) * (t as f64)).sum();
        loss = loss / total_w + reg;
        if let Some(gbuf) = g {
            let inv = 1.0 / total_w as f32;
            for (gi, ti) in gbuf.iter_mut().zip(theta) {
                *gi = *gi * inv + self.l2 * ti;
            }
        }
        (loss, correct)
    }
}

impl Backend for NativeLogreg {
    fn name(&self) -> String {
        format!("native_logreg:{}", self.dim)
    }

    fn param_count(&self) -> usize {
        self.dim
    }

    fn init_params(&self) -> Vec<f32> {
        vec![0.0; self.dim] // matches model.py ("zeros" init for logreg)
    }

    fn grad(&self, theta: &[f32], batch: &Batch) -> anyhow::Result<GradOut> {
        let mut buf = GradBuf::new();
        self.grad_into(theta, batch, &mut buf)?;
        Ok(buf.into_out())
    }

    fn grad_into(&self, theta: &[f32], batch: &Batch, out: &mut GradBuf)
                 -> anyhow::Result<()> {
        let Batch::Weighted { x, y, sw, wsum } = batch else {
            anyhow::bail!("NativeLogreg expects a Weighted batch");
        };
        out.grad.clear();
        out.grad.resize(self.dim, 0.0);
        let (loss, correct) = self.forward(theta, x, y, sw, *wsum, Some(&mut out.grad));
        out.loss = loss;
        out.correct = correct;
        Ok(())
    }

    fn eval(&self, theta: &[f32], batch: &Batch) -> anyhow::Result<EvalOut> {
        let Batch::Weighted { x, y, sw, wsum } = batch else {
            anyhow::bail!("NativeLogreg expects a Weighted batch");
        };
        let (loss, correct) = self.forward(theta, x, y, sw, *wsum, None);
        Ok(EvalOut { loss, accuracy: correct / batch.count(0) })
    }

    fn static_train_batch(&self) -> bool {
        // the paper's convex experiments use the *full* local gradient:
        // the batch is a pure function of the shard
        true
    }

    fn make_train_batch(&self, shard: &Dataset, _rng: &mut Rng) -> Batch {
        let (x, y, sw) = Batcher::new(shard).full_weighted(self.train_pad);
        Batch::weighted(x, y, sw)
    }

    fn make_eval_batch(&self, data: &Dataset) -> Batch {
        let (x, y, sw) = Batcher::new(data).eval_weighted(self.eval_pad, self.eval_pad);
        Batch::weighted(x, y, sw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn setup() -> (NativeLogreg, Dataset) {
        (NativeLogreg::new(20, 0.01, 64, 64), synth::logistic(60, 20, 0.05, 1))
    }

    #[test]
    fn grad_matches_finite_differences() {
        let (be, data) = setup();
        let mut rng = Rng::new(0);
        let batch = be.make_train_batch(&data, &mut rng);
        let mut theta: Vec<f32> = (0..20).map(|i| 0.05 * (i as f32 - 10.0)).collect();
        let g = be.grad(&theta, &batch).unwrap();
        let eps = 1e-3f32;
        for i in [0usize, 7, 19] {
            let orig = theta[i];
            theta[i] = orig + eps;
            let lp = be.eval(&theta, &batch).unwrap().loss;
            theta[i] = orig - eps;
            let lm = be.eval(&theta, &batch).unwrap().loss;
            theta[i] = orig;
            let fd = (lp - lm) / (2.0 * eps as f64);
            assert!((fd - g.grad[i] as f64).abs() < 5e-3,
                    "coord {i}: fd {fd} vs grad {}", g.grad[i]);
        }
    }

    #[test]
    fn gd_converges_on_separable_data() {
        let (be, data) = setup();
        let mut rng = Rng::new(0);
        let batch = be.make_train_batch(&data, &mut rng);
        let mut theta = be.init_params();
        let l0 = be.eval(&theta, &batch).unwrap().loss;
        for _ in 0..300 {
            let g = be.grad(&theta, &batch).unwrap();
            crate::model::axpy(&mut theta, -1.0, &g.grad);
        }
        let out = be.eval(&theta, &batch).unwrap();
        assert!(out.loss < l0 * 0.5, "loss {l0} -> {}", out.loss);
        assert!(out.accuracy > 0.9, "acc {}", out.accuracy);
    }

    #[test]
    fn zero_weight_padding_is_inert() {
        let (be, data) = setup();
        let mut rng = Rng::new(0);
        let theta: Vec<f32> = (0..20).map(|i| 0.1 * i as f32).collect();
        let b64 = be.make_train_batch(&data, &mut rng);
        let be_bigger = NativeLogreg::new(20, 0.01, 128, 64);
        let b128 = be_bigger.make_train_batch(&data, &mut rng);
        let g1 = be.grad(&theta, &b64).unwrap();
        let g2 = be_bigger.grad(&theta, &b128).unwrap();
        assert!((g1.loss - g2.loss).abs() < 1e-9);
        for (a, b) in g1.grad.iter().zip(&g2.grad) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn grad_into_equals_grad_bitwise() {
        // the engine's buffer-reusing entry must be the *same computation*
        // as the allocating one: bit-for-bit, across reuses of the buffer
        let (be, data) = setup();
        let mut rng = Rng::new(3);
        let batch = be.make_train_batch(&data, &mut rng);
        let mut buf = GradBuf::new();
        for trial in 0..5u64 {
            let theta: Vec<f32> =
                (0..20).map(|_| rng.normal_f32(0.0, 0.5)).collect();
            let g = be.grad(&theta, &batch).unwrap();
            be.grad_into(&theta, &batch, &mut buf).unwrap();
            assert_eq!(buf.grad, g.grad, "trial {trial}");
            assert_eq!(buf.loss, g.loss, "trial {trial}");
            assert_eq!(buf.correct, g.correct, "trial {trial}");
        }
    }

    #[test]
    fn grad_into_reuses_buffer_storage() {
        let (be, data) = setup();
        let mut rng = Rng::new(4);
        let batch = be.make_train_batch(&data, &mut rng);
        let theta = vec![0.1f32; 20];
        let mut buf = GradBuf::new();
        be.grad_into(&theta, &batch, &mut buf).unwrap();
        let ptr = buf.grad.as_ptr();
        let cap = buf.grad.capacity();
        for _ in 0..8 {
            be.grad_into(&theta, &batch, &mut buf).unwrap();
            assert_eq!(buf.grad.as_ptr(), ptr, "gradient storage moved");
            assert_eq!(buf.grad.capacity(), cap, "gradient capacity changed");
        }
    }

    #[test]
    fn batch_weighted_precomputes_weight_sum() {
        let b = Batch::weighted(vec![0.0; 8], vec![1.0, -1.0, 1.0, 1.0],
                                vec![1.0, 1.0, 0.5, 0.0]);
        let Batch::Weighted { wsum, .. } = &b else { panic!() };
        assert_eq!(*wsum, 2.5);
        assert_eq!(b.count(0), 2.5);
    }

    #[test]
    fn native_train_batches_are_static() {
        let (be, data) = setup();
        assert!(be.static_train_batch());
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(999);
        let a = be.make_train_batch(&data, &mut r1);
        let b = be.make_train_batch(&data, &mut r2);
        let (Batch::Weighted { x: xa, wsum: wa, .. },
             Batch::Weighted { x: xb, wsum: wb, .. }) = (&a, &b) else { panic!() };
        assert_eq!(xa, xb);
        assert_eq!(wa, wb);
    }
}
