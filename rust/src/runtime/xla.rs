//! PJRT runtime: load the AOT artifact bundle and execute it from Rust.
//!
//! `make artifacts` writes `artifacts/manifest.json`, one HLO **text** file
//! per (model, function), and raw-f32 init binaries (HLO text is the
//! interchange format — xla_extension 0.5.1 rejects jax ≥ 0.5 serialized
//! protos; see DESIGN.md). This module compiles every HLO once at load and
//! serves `Backend` gradient/eval calls on the compiled executables.
//!
//! ### Thread-safety
//! The `xla` crate's `PjRtClient` wraps an `Rc`, so it is not `Send`. The
//! underlying XLA CPU client (TFRT) *is* thread-safe for execution, but we
//! stay conservative: executables live behind a `Mutex`, and a single
//! execute call already fans out across XLA's internal thread pool, so the
//! coordinator loses little by serializing submissions (measured in
//! EXPERIMENTS.md §Perf).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::data::{Batcher, Dataset};
use crate::util::json::{self, Value};
use crate::util::Rng;

use super::{Backend, Batch, EvalOut, GradOut};

/// Wrapper making the Rc-based xla handles shareable. Safety: we never
/// clone the inner Rc after construction; all access is via `&self` under
/// the containing `Mutex` (executables) or immutable (client keep-alive).
struct SendSync<T>(T);
unsafe impl<T> Send for SendSync<T> {}
unsafe impl<T> Sync for SendSync<T> {}

#[derive(Clone, Debug)]
struct TensorSig {
    shape: Vec<i64>,
    dtype: String, // "f32" | "i32"
}

#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub family: String,
    pub param_count: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub num_classes: usize,
    pub kind: String, // "logreg" | "image" | "flat" | "lm"
    pub tokens_per_sample: usize,
}

struct Executable {
    exe: Mutex<SendSync<xla::PjRtLoadedExecutable>>,
    inputs: Vec<TensorSig>,
    num_outputs: usize,
}

struct ModelEntry {
    meta: ModelMeta,
    grad: Executable,
    eval: Executable,
    init: Vec<f32>,
}

/// Loaded artifact bundle: PJRT client + one compiled entry per model.
pub struct XlaRuntime {
    client: Arc<SendSync<xla::PjRtClient>>,
    models: HashMap<String, Arc<ModelEntry>>,
    dir: PathBuf,
}

fn parse_sigs(fn_obj: &Value) -> anyhow::Result<Vec<TensorSig>> {
    let mut sigs = Vec::new();
    for s in fn_obj.arr_of("inputs")? {
        let shape: Vec<i64> = s
            .arr_of("shape")?
            .iter()
            .map(|v| v.as_f64().unwrap_or(0.0) as i64)
            .collect();
        sigs.push(TensorSig { shape, dtype: s.str_of("dtype")?.to_string() });
    }
    Ok(sigs)
}

impl XlaRuntime {
    /// Load and compile every model in `artifacts/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<XlaRuntime> {
        Self::load_filtered(dir, None)
    }

    /// Load a subset (compilation is the expensive part; benches load only
    /// the models they use).
    pub fn load_filtered(dir: impl AsRef<Path>, only: Option<&[&str]>)
                         -> anyhow::Result<XlaRuntime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} ({e}); run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let manifest = json::parse(&text)
            .map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;

        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PjRtClient::cpu: {e:?}"))?;

        let mut models = HashMap::new();
        for m in manifest.arr_of("models")? {
            let name = m.str_of("name")?.to_string();
            if let Some(keep) = only {
                if !keep.contains(&name.as_str()) {
                    continue;
                }
            }
            let meta_obj = m.req("meta")?;
            let meta = ModelMeta {
                name: name.clone(),
                family: m.str_of("family")?.to_string(),
                param_count: m.usize_of("param_count")?,
                train_batch: meta_obj.usize_of("train_batch")?,
                eval_batch: meta_obj.usize_of("eval_batch")?,
                num_classes: meta_obj.usize_of("num_classes")?,
                kind: meta_obj.str_of("kind")?.to_string(),
                tokens_per_sample: meta_obj
                    .get("tokens_per_sample")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(0),
            };
            let load_fn = |fn_name: &str| -> anyhow::Result<Executable> {
                let fn_obj = m.req(fn_name)?;
                let hlo_path = dir.join(fn_obj.str_of("hlo")?);
                let proto = xla::HloModuleProto::from_text_file(
                    hlo_path.to_str().unwrap(),
                )
                .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", hlo_path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| anyhow::anyhow!("compile {}: {e:?}", hlo_path.display()))?;
                Ok(Executable {
                    exe: Mutex::new(SendSync(exe)),
                    inputs: parse_sigs(fn_obj)?,
                    num_outputs: fn_obj.usize_of("num_outputs")?,
                })
            };
            let grad = load_fn("grad")?;
            let eval = load_fn("eval")?;
            let init_path = dir.join(m.str_of("init")?);
            let raw = std::fs::read(&init_path)?;
            anyhow::ensure!(raw.len() == 4 * meta.param_count,
                            "init size mismatch for {name}");
            let init: Vec<f32> = raw
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                .collect();
            models.insert(name, Arc::new(ModelEntry { meta, grad, eval, init }));
        }
        anyhow::ensure!(!models.is_empty(), "no models loaded from {}", dir.display());
        Ok(XlaRuntime { client: Arc::new(SendSync(client)), models, dir })
    }

    pub fn model_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.models.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    /// A `Backend` view of one model. The returned handle shares the
    /// runtime's compiled executables.
    pub fn backend(&self, name: &str) -> anyhow::Result<XlaBackend> {
        let entry = self
            .models
            .get(name)
            .ok_or_else(|| {
                anyhow::anyhow!("model `{name}` not in manifest (have: {:?})",
                                self.model_names())
            })?
            .clone();
        Ok(XlaBackend { entry, _client: self.client.clone() })
    }
}

/// `Backend` implementation over one compiled model. Holds a keep-alive
/// reference to the PJRT client so it outlives the `XlaRuntime` it came
/// from.
pub struct XlaBackend {
    entry: Arc<ModelEntry>,
    _client: Arc<SendSync<xla::PjRtClient>>,
}

impl XlaBackend {
    pub fn meta(&self) -> &ModelMeta {
        &self.entry.meta
    }

    fn run(&self, exec: &Executable, theta: &[f32], batch: &Batch)
           -> anyhow::Result<Vec<xla::Literal>> {
        anyhow::ensure!(theta.len() == self.entry.meta.param_count,
                        "theta length mismatch");
        let mut lits: Vec<xla::Literal> = Vec::with_capacity(exec.inputs.len());
        lits.push(xla::Literal::vec1(theta));
        match batch {
            Batch::Weighted { x, y, sw, .. } => {
                lits.push(reshaped_f32(x, &exec.inputs[1])?);
                lits.push(reshaped_f32(y, &exec.inputs[2])?);
                lits.push(reshaped_f32(sw, &exec.inputs[3])?);
            }
            Batch::Labeled { x, y } => {
                lits.push(reshaped_f32(x, &exec.inputs[1])?);
                lits.push(reshaped_i32(y, &exec.inputs[2])?);
            }
            Batch::Tokens { t } => {
                lits.push(reshaped_i32(t, &exec.inputs[1])?);
            }
        }
        anyhow::ensure!(lits.len() == exec.inputs.len(), "batch arity mismatch");
        let guard = exec.exe.lock().unwrap();
        let result = guard.0
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        drop(guard);
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("tuple unwrap: {e:?}"))?;
        anyhow::ensure!(parts.len() == exec.num_outputs, "output arity mismatch");
        Ok(parts)
    }
}

fn reshaped_f32(data: &[f32], sig: &TensorSig) -> anyhow::Result<xla::Literal> {
    anyhow::ensure!(sig.dtype == "f32", "expected f32 input, sig is {}", sig.dtype);
    let expect: i64 = sig.shape.iter().product();
    anyhow::ensure!(data.len() as i64 == expect,
                    "input length {} != shape {:?}", data.len(), sig.shape);
    let lit = xla::Literal::vec1(data);
    if sig.shape.len() == 1 {
        Ok(lit)
    } else {
        lit.reshape(&sig.shape).map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
    }
}

fn reshaped_i32(data: &[i32], sig: &TensorSig) -> anyhow::Result<xla::Literal> {
    anyhow::ensure!(sig.dtype == "i32", "expected i32 input, sig is {}", sig.dtype);
    let expect: i64 = sig.shape.iter().product();
    anyhow::ensure!(data.len() as i64 == expect,
                    "input length {} != shape {:?}", data.len(), sig.shape);
    let lit = xla::Literal::vec1(data);
    if sig.shape.len() == 1 {
        Ok(lit)
    } else {
        lit.reshape(&sig.shape).map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
    }
}

fn scalar_f32(lit: &xla::Literal) -> anyhow::Result<f64> {
    lit.get_first_element::<f32>()
        .map(|v| v as f64)
        .map_err(|e| anyhow::anyhow!("scalar: {e:?}"))
}

impl Backend for XlaBackend {
    fn name(&self) -> String {
        format!("xla:{}", self.entry.meta.name)
    }

    fn param_count(&self) -> usize {
        self.entry.meta.param_count
    }

    fn init_params(&self) -> Vec<f32> {
        self.entry.init.clone()
    }

    fn grad(&self, theta: &[f32], batch: &Batch) -> anyhow::Result<GradOut> {
        let parts = self.run(&self.entry.grad, theta, batch)?;
        let grad = parts[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("grad tensor: {e:?}"))?;
        Ok(GradOut { grad, loss: scalar_f32(&parts[1])?, correct: scalar_f32(&parts[2])? })
    }

    fn eval(&self, theta: &[f32], batch: &Batch) -> anyhow::Result<EvalOut> {
        let parts = self.run(&self.entry.eval, theta, batch)?;
        let loss = scalar_f32(&parts[0])?;
        let correct = scalar_f32(&parts[1])?;
        let count = batch.count(self.entry.meta.tokens_per_sample);
        Ok(EvalOut { loss, accuracy: correct / count })
    }

    fn static_train_batch(&self) -> bool {
        // the logreg artifacts run the full-gradient convex regime: the
        // batch is a deterministic function of the shard, so the
        // environment may cache it
        self.entry.meta.kind == "logreg"
    }

    fn make_train_batch(&self, shard: &Dataset, rng: &mut Rng) -> Batch {
        let m = &self.entry.meta;
        match m.kind.as_str() {
            "logreg" => {
                let (x, y, sw) = Batcher::new(shard).full_weighted(m.train_batch);
                Batch::weighted(x, y, sw)
            }
            "lm" => {
                let (x, _) = Batcher::new(shard).sample(m.train_batch, rng);
                Batch::Tokens { t: x.iter().map(|&v| v as i32).collect() }
            }
            _ => {
                let (x, y) = Batcher::new(shard).sample(m.train_batch, rng);
                Batch::Labeled { x, y }
            }
        }
    }

    fn make_eval_batch(&self, data: &Dataset) -> Batch {
        let m = &self.entry.meta;
        match m.kind.as_str() {
            "logreg" => {
                let (x, y, sw) = Batcher::new(data).eval_weighted(m.eval_batch, m.eval_batch);
                Batch::weighted(x, y, sw)
            }
            "lm" => {
                let idx: Vec<usize> = (0..m.eval_batch).map(|i| i % data.len()).collect();
                let sub = data.subset(&idx);
                Batch::Tokens { t: sub.features.iter().map(|&v| v as i32).collect() }
            }
            _ => {
                let idx: Vec<usize> = (0..m.eval_batch).map(|i| i % data.len()).collect();
                let sub = data.subset(&idx);
                Batch::Labeled { x: sub.features.clone(), y: sub.labels.clone() }
            }
        }
    }
}
