//! Environment builders: wire datasets, partitioners and backends into the
//! [`FedEnv`] the algorithms consume. This is the leader-side setup path of
//! the system (the launcher `pfl` CLI and every bench goes through here).

use std::sync::Arc;

use crate::algorithms::FedEnv;
use crate::data::{dirichlet, libsvm, synth};
use crate::runtime::{Backend, NativeLogreg};
use crate::util::threadpool::ThreadPool;
use crate::util::Rng;

/// The paper's §VII-A convex setup: a1a/a2a-shaped logistic data split
/// contiguously over `n` workers (a real LIBSVM file is used when present
/// at `libsvm_path`, otherwise the synthetic substitute of identical shape).
#[derive(Clone, Debug)]
pub struct LogregEnvCfg {
    pub n_clients: usize,
    pub rows_per_worker: usize, // a1a: 321, a2a: 453
    pub dim: usize,             // 123
    pub noise: f64,
    pub l2: f32,
    pub seed: u64,
    pub libsvm_path: Option<String>,
}

impl Default for LogregEnvCfg {
    fn default() -> Self {
        LogregEnvCfg {
            n_clients: 5,
            rows_per_worker: 321,
            dim: 123,
            noise: 0.05,
            l2: 0.01,
            seed: 0,
            libsvm_path: None,
        }
    }
}

/// Build the convex environment on the pure-Rust backend (used for the huge
/// Fig 3 sweeps; the XLA artifact path is exercised by `logreg_env_with`).
pub fn logreg_env(cfg: &LogregEnvCfg) -> FedEnv {
    let backend: Arc<dyn Backend> = Arc::new(NativeLogreg::new(
        cfg.dim,
        cfg.l2,
        padded(cfg.rows_per_worker),
        2048,
    ));
    logreg_env_with(cfg, backend)
}

/// Same environment, caller-chosen backend (native or `XlaRuntime::backend`).
pub fn logreg_env_with(cfg: &LogregEnvCfg, backend: Arc<dyn Backend>) -> FedEnv {
    let total = cfg.n_clients * cfg.rows_per_worker;
    let (train, test) = match cfg
        .libsvm_path
        .as_deref()
        .and_then(|p| libsvm::load_if_present(p, cfg.dim))
    {
        // real LIBSVM file: hold out the tail third as the test set
        Some(all) => {
            let n_train = (all.len() * 3) / 4;
            let train = all.subset(&(0..n_train).collect::<Vec<_>>());
            let test = all.subset(&(n_train..all.len()).collect::<Vec<_>>());
            (train, test)
        }
        None => synth::logistic_split(total, total / 3, cfg.dim, cfg.noise, cfg.seed),
    };
    let shards = train.split_contiguous(cfg.n_clients);
    FedEnv::new(backend, shards, train, test,
                ThreadPool::new(ThreadPool::default_size()), cfg.seed)
}

fn padded(rows: usize) -> usize {
    rows.next_power_of_two().max(64)
}

/// The paper's §VII-B DNN setup: synthetic-CIFAR images partitioned with
/// Dirichlet(α) heterogeneity over `n` clients.
#[derive(Clone, Debug)]
pub struct ImageEnvCfg {
    pub n_clients: usize,
    pub dirichlet_alpha: f64,
    pub n_train: usize,
    pub n_test: usize,
    pub hw: usize,
    pub channels: usize,
    pub classes: usize,
    pub separation: f32,
    pub seed: u64,
}

impl Default for ImageEnvCfg {
    fn default() -> Self {
        ImageEnvCfg {
            n_clients: 10,
            dirichlet_alpha: 0.5,
            n_train: 2000,
            n_test: 512,
            hw: 16,
            channels: 3,
            classes: 10,
            separation: 1.5,
            seed: 0,
        }
    }
}

pub fn image_env(cfg: &ImageEnvCfg, backend: Arc<dyn Backend>) -> FedEnv {
    let (train, test) = synth::images_split(cfg.n_train, cfg.n_test, cfg.classes,
                                            cfg.hw, cfg.channels,
                                            cfg.separation, cfg.seed);
    let mut rng = Rng::new(cfg.seed ^ 0xD121);
    let shards = dirichlet::partition(&train, cfg.n_clients, cfg.dirichlet_alpha,
                                      8, &mut rng);
    FedEnv::new(backend, shards, train, test,
                ThreadPool::new(ThreadPool::default_size()), cfg.seed)
}

/// Token-sequence environment for the transformer end-to-end driver.
#[derive(Clone, Debug)]
pub struct TokenEnvCfg {
    pub n_clients: usize,
    pub n_train_seq: usize,
    pub n_test_seq: usize,
    pub seq: usize,
    pub vocab: usize,
    pub determinism: f64,
    pub seed: u64,
}

impl Default for TokenEnvCfg {
    fn default() -> Self {
        TokenEnvCfg {
            n_clients: 4,
            n_train_seq: 2000,
            n_test_seq: 256,
            seq: 32,
            vocab: 256,
            determinism: 0.85,
            seed: 0,
        }
    }
}

pub fn token_env(cfg: &TokenEnvCfg, backend: Arc<dyn Backend>) -> FedEnv {
    let (train, test) = synth::tokens_split(cfg.n_train_seq, cfg.n_test_seq,
                                            cfg.seq, cfg.vocab,
                                            cfg.determinism, cfg.seed);
    let shards = train.split_contiguous(cfg.n_clients);
    FedEnv::new(backend, shards, train, test,
                ThreadPool::new(ThreadPool::default_size()), cfg.seed)
}

/// Build the environment matching a manifest model's `kind` (used by the
/// `pfl train` CLI path).
pub fn env_for_model(rt: &crate::runtime::XlaRuntime, model: &str,
                     n_clients: usize, dirichlet_alpha: f64, seed: u64)
                     -> anyhow::Result<FedEnv> {
    let backend = rt.backend(model)?;
    let kind = backend.meta().kind.clone();
    let be: Arc<dyn Backend> = Arc::new(backend);
    Ok(match kind.as_str() {
        "logreg" => logreg_env_with(
            &LogregEnvCfg { n_clients, seed, ..Default::default() }, be),
        "lm" => token_env(
            &TokenEnvCfg { n_clients, seed, ..Default::default() }, be),
        _ => image_env(
            &ImageEnvCfg { n_clients, dirichlet_alpha, seed, ..Default::default() },
            be),
    })
}

/// Instantiate the algorithm a `TrainConfig` describes.
///
/// Compression plumbing is descriptor-based: the algorithm constructors
/// parse the (pipeline) specs once into shared `Arc<dyn Compressor>`
/// descriptors; per-client stateful instances (RNG streams, error-feedback
/// residuals) are created inside `run`, so nothing here is per-client.
pub fn algo_from_config(cfg: &crate::config::TrainConfig)
                        -> anyhow::Result<Box<dyn crate::algorithms::FedAlgorithm>> {
    use crate::algorithms::{FedAvg, FedOpt, L2gd};
    Ok(match cfg.algo.as_str() {
        "l2gd" => {
            let alg = if cfg.eta > 0.0 {
                L2gd::new(cfg.p, cfg.lambda, cfg.eta, cfg.n_clients,
                          &cfg.client_comp, &cfg.master_comp)?
            } else {
                L2gd::from_local_and_agg(cfg.p, cfg.local_lr, cfg.agg,
                                         cfg.n_clients, &cfg.client_comp,
                                         &cfg.master_comp)?
            };
            Box::new(alg)
        }
        "fedavg" => Box::new(FedAvg::new(cfg.local_lr, cfg.local_steps,
                                         &cfg.client_comp, &cfg.master_comp)?),
        "fedopt" => Box::new(FedOpt::new(cfg.local_lr, cfg.local_steps,
                                         cfg.server_lr)),
        other => anyhow::bail!(
            "unknown algo `{other}` (registered: {})",
            crate::algorithms::FLEET_ALGS.join(", ")),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logreg_env_matches_paper_shapes() {
        let env = logreg_env(&LogregEnvCfg::default());
        assert_eq!(env.n_clients(), 5);
        assert_eq!(env.shards[0].len(), 321);
        assert_eq!(env.shards[0].feat_len(), 123);
        assert_eq!(env.backend.param_count(), 123);
    }

    #[test]
    fn image_env_is_heterogeneous() {
        // backend-free check via a native stand-in is impossible (image
        // models need XLA), so use a trivial native logreg backend just to
        // construct the env and inspect the shards.
        let cfg = ImageEnvCfg { n_train: 1000, ..Default::default() };
        let be: Arc<dyn Backend> = Arc::new(NativeLogreg::new(4, 0.0, 8, 8));
        let env = image_env(&cfg, be);
        assert_eq!(env.n_clients(), 10);
        let het = crate::data::dirichlet::heterogeneity_tv(&env.shards);
        assert!(het > 0.1, "tv = {het}");
        for s in &env.shards {
            assert!(s.len() >= 8);
        }
    }

    #[test]
    fn algo_from_config_builds_pipeline_specs() {
        use crate::algorithms::FedAlgorithm;
        let cfg = crate::config::TrainConfig {
            algo: "l2gd".into(),
            client_comp: "ef(randk:10>qsgd:8)".into(),
            master_comp: "natural".into(),
            ..Default::default()
        };
        let algo = algo_from_config(&cfg).unwrap();
        assert!(algo.label().contains("ef(randk:10>qsgd:8)"), "{}", algo.label());
    }

    #[test]
    fn token_env_shapes() {
        let cfg = TokenEnvCfg { n_train_seq: 200, ..Default::default() };
        let be: Arc<dyn Backend> = Arc::new(NativeLogreg::new(4, 0.0, 8, 8));
        let env = token_env(&cfg, be);
        assert_eq!(env.shards.len(), 4);
        assert_eq!(env.shards[0].feat_len(), 33);
    }
}
