//! Per-round training records, CSV emission, and the bits-to-accuracy
//! extraction behind Table II.

use std::io::Write;
use std::path::Path;

/// One evaluation snapshot during a run.
#[derive(Clone, Debug)]
pub struct Record {
    /// algorithm step: L2GD iteration k, or FedAvg/FedOpt round
    pub step: u64,
    /// communication rounds so far
    pub comm_rounds: u64,
    pub bits_per_client: f64,
    pub bits_up: u64,
    pub bits_down: u64,
    /// global model x̄ on the (subsampled) train set
    pub train_loss: f64,
    pub train_acc: f64,
    /// global model x̄ on the test set
    pub test_loss: f64,
    pub test_acc: f64,
    /// personalized objective: (1/n) Σ_i f_i(x_i) on each device's own data
    pub personal_loss: f64,
    pub personal_acc: f64,
    /// projected communication wall-clock under the transport time model
    /// (replaced by the fleet simulator's event-driven clock in sim runs)
    pub sim_time_s: f64,
    /// clients that uplinked in the last completed communication round
    /// (n under full participation; the arrived cohort size in sim runs)
    pub participants: u64,
}

/// A labelled metric series (one algorithm × configuration run).
#[derive(Clone, Debug)]
pub struct Series {
    pub label: String,
    pub records: Vec<Record>,
}

impl Record {
    /// False once training has diverged (any headline metric non-finite).
    pub fn is_finite(&self) -> bool {
        self.train_loss.is_finite() && self.test_loss.is_finite()
            && self.personal_loss.is_finite() && self.train_acc.is_finite()
            && self.test_acc.is_finite() && self.personal_acc.is_finite()
    }
}

pub const CSV_HEADER: &str = "step,comm_rounds,bits_per_client,bits_up,bits_down,\
train_loss,train_acc,test_loss,test_acc,personal_loss,personal_acc,sim_time_s,\
participants";

impl Series {
    pub fn new(label: impl Into<String>) -> Series {
        Series { label: label.into(), records: Vec::new() }
    }

    pub fn last(&self) -> Option<&Record> {
        self.records.last()
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from(CSV_HEADER);
        s.push('\n');
        for r in &self.records {
            s.push_str(&format!(
                "{},{},{},{},{},{:.6},{:.4},{:.6},{:.4},{:.6},{:.4},{:.3},{}\n",
                r.step, r.comm_rounds, r.bits_per_client, r.bits_up, r.bits_down,
                r.train_loss, r.train_acc, r.test_loss, r.test_acc,
                r.personal_loss, r.personal_acc, r.sim_time_s, r.participants
            ));
        }
        s
    }

    pub fn write_csv(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())?;
        Ok(())
    }

    /// First bits/n at which `test_acc ≥ target` (Table II's measurement).
    pub fn bits_to_test_accuracy(&self, target: f64) -> Option<f64> {
        self.records
            .iter()
            .find(|r| r.test_acc >= target)
            .map(|r| r.bits_per_client)
    }

    /// Best (minimum) train loss seen.
    pub fn best_train_loss(&self) -> Option<f64> {
        self.records
            .iter()
            .map(|r| r.train_loss)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Best (maximum) test accuracy seen.
    pub fn best_test_acc(&self) -> Option<f64> {
        self.records
            .iter()
            .map(|r| r.test_acc)
            .max_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Loss reached by the time bits/n first exceeds `budget` (the paper's
    /// "same amount of data sent" comparison).
    pub fn loss_at_bits_budget(&self, budget: f64) -> Option<f64> {
        let mut best: Option<f64> = None;
        for r in &self.records {
            if r.bits_per_client > budget {
                break;
            }
            best = Some(best.map_or(r.train_loss, |b: f64| b.min(r.train_loss)));
        }
        best
    }
}

/// RFC 4180 field escaping: quote when the value contains a comma, quote,
/// CR, or LF, doubling embedded quotes. Plain labels pass through verbatim.
fn csv_escape(field: &str) -> String {
    if field.contains(['"', ',', '\r', '\n']) {
        format!("\"{}\"", field.replace('"', "\"\""))
    } else {
        field.to_string()
    }
}

/// Write several series side by side as one long-format CSV
/// (`label` column first), convenient for plotting. Labels carry raw
/// scenario specs (commas included), so the label column is RFC
/// 4180-escaped.
pub fn write_multi_csv(series: &[Series], path: impl AsRef<Path>) -> anyhow::Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut out = String::from("label,");
    out.push_str(CSV_HEADER);
    out.push('\n');
    for s in series {
        let label = csv_escape(&s.label);
        for line in s.to_csv().lines().skip(1) {
            out.push_str(&label);
            out.push(',');
            out.push_str(line);
            out.push('\n');
        }
    }
    std::fs::write(path, out)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: u64, bits: f64, acc: f64, loss: f64) -> Record {
        Record {
            step,
            comm_rounds: step / 2,
            bits_per_client: bits,
            bits_up: bits as u64,
            bits_down: 0,
            train_loss: loss,
            train_acc: acc,
            test_loss: loss,
            test_acc: acc,
            personal_loss: loss,
            personal_acc: acc,
            sim_time_s: 0.0,
            participants: 0,
        }
    }

    #[test]
    fn bits_to_accuracy_finds_first_crossing() {
        let mut s = Series::new("x");
        s.records.push(rec(0, 100.0, 0.2, 2.0));
        s.records.push(rec(1, 200.0, 0.65, 1.0));
        s.records.push(rec(2, 300.0, 0.72, 0.8));
        s.records.push(rec(3, 400.0, 0.71, 0.7));
        assert_eq!(s.bits_to_test_accuracy(0.7), Some(300.0));
        assert_eq!(s.bits_to_test_accuracy(0.9), None);
    }

    #[test]
    fn loss_at_budget_respects_bit_limit() {
        let mut s = Series::new("x");
        s.records.push(rec(0, 100.0, 0.2, 2.0));
        s.records.push(rec(1, 200.0, 0.5, 1.5));
        s.records.push(rec(2, 900.0, 0.9, 0.1));
        assert_eq!(s.loss_at_bits_budget(250.0), Some(1.5));
        assert_eq!(s.loss_at_bits_budget(50.0), None);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut s = Series::new("alg");
        s.records.push(rec(5, 10.0, 0.5, 1.25));
        let csv = s.to_csv();
        let mut lines = csv.lines();
        assert_eq!(lines.next().unwrap(), CSV_HEADER);
        let row = lines.next().unwrap();
        assert!(row.starts_with("5,2,10,10,0,1.25"), "{row}");
    }

    /// `bits_per_client` is written at full precision — a `{:.1}` round
    /// would alias distinct per-step bit counts at DNN scales.
    #[test]
    fn csv_keeps_bits_per_client_precision() {
        let mut s = Series::new("alg");
        s.records.push(rec(1, 123456789.0625, 0.5, 1.0));
        let row = s.to_csv().lines().nth(1).unwrap().to_string();
        assert!(row.contains(",123456789.0625,"), "{row}");
    }

    #[test]
    fn multi_csv_has_labels() {
        let mut a = Series::new("a");
        a.records.push(rec(0, 1.0, 0.1, 3.0));
        let mut b = Series::new("b");
        b.records.push(rec(0, 2.0, 0.2, 2.0));
        let dir = std::env::temp_dir().join("pfl_test_multi.csv");
        write_multi_csv(&[a, b], &dir).unwrap();
        let text = std::fs::read_to_string(&dir).unwrap();
        assert!(text.contains("\na,0,"));
        assert!(text.contains("\nb,0,"));
        let _ = std::fs::remove_file(dir);
    }

    /// Scenario-spec labels carry commas and may carry quotes — the label
    /// column must stay one RFC 4180 field, not shift every column right.
    #[test]
    fn multi_csv_escapes_hostile_labels() {
        let mut a = Series::new("straggler-heavy:clients=12,quorum=0.5");
        a.records.push(rec(0, 1.0, 0.1, 3.0));
        let mut b = Series::new("say \"hi\"\nplease");
        b.records.push(rec(0, 2.0, 0.2, 2.0));
        let path = std::env::temp_dir().join("pfl_test_multi_escape.csv");
        write_multi_csv(&[a, b], &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\n\"straggler-heavy:clients=12,quorum=0.5\",0,"),
                "{text}");
        assert!(text.contains("\"say \"\"hi\"\"\nplease\",0,"), "{text}");
        let _ = std::fs::remove_file(path);
    }
}
