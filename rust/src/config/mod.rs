//! Experiment configuration: JSON files + CLI overrides → one validated
//! `TrainConfig`. Presets reproduce the paper's setups (DESIGN.md §6).

use crate::util::cli::Args;
use crate::util::json::{self, Value};

#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// backend: `native_logreg` or a manifest model name (e.g. `resnet_tiny`)
    pub model: String,
    /// `l2gd` | `fedavg` | `fedopt`
    pub algo: String,
    pub n_clients: usize,
    pub steps: u64,
    pub eval_every: u64,
    pub seed: u64,
    // --- L2GD ---
    pub p: f64,
    pub lambda: f64,
    /// explicit η; if 0, derived from local_lr/agg (from_local_and_agg)
    pub eta: f64,
    pub agg: f64,
    // --- shared ---
    pub local_lr: f64,
    pub local_steps: usize,
    pub server_lr: f64,
    pub client_comp: String,
    pub master_comp: String,
    /// Dirichlet α for image environments
    pub dirichlet_alpha: f64,
    pub out_dir: String,
    pub artifacts: String,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "native_logreg".into(),
            algo: "l2gd".into(),
            n_clients: 10,
            steps: 500,
            eval_every: 50,
            seed: 0,
            p: 0.4,
            lambda: 10.0,
            eta: 0.0,
            agg: 0.1,
            local_lr: 0.05,
            local_steps: 2,
            server_lr: 0.05,
            client_comp: "natural".into(),
            master_comp: "natural".into(),
            dirichlet_alpha: 0.5,
            out_dir: "results".into(),
            artifacts: "artifacts".into(),
        }
    }
}

impl TrainConfig {
    pub fn from_json(v: &Value) -> anyhow::Result<TrainConfig> {
        let mut c = TrainConfig::default();
        let gs = |k: &str, cur: &str| -> String {
            v.get(k).and_then(|x| x.as_str()).map(str::to_string)
                .unwrap_or_else(|| cur.to_string())
        };
        let gf = |k: &str, cur: f64| v.get(k).and_then(|x| x.as_f64()).unwrap_or(cur);
        let gu = |k: &str, cur: usize| v.get(k).and_then(|x| x.as_usize()).unwrap_or(cur);
        c.model = gs("model", &c.model);
        c.algo = gs("algo", &c.algo);
        c.n_clients = gu("n_clients", c.n_clients);
        c.steps = gu("steps", c.steps as usize) as u64;
        c.eval_every = gu("eval_every", c.eval_every as usize) as u64;
        c.seed = gu("seed", c.seed as usize) as u64;
        c.p = gf("p", c.p);
        c.lambda = gf("lambda", c.lambda);
        c.eta = gf("eta", c.eta);
        c.agg = gf("agg", c.agg);
        c.local_lr = gf("local_lr", c.local_lr);
        c.local_steps = gu("local_steps", c.local_steps);
        c.server_lr = gf("server_lr", c.server_lr);
        c.client_comp = gs("client_comp", &c.client_comp);
        c.master_comp = gs("master_comp", &c.master_comp);
        c.dirichlet_alpha = gf("dirichlet_alpha", c.dirichlet_alpha);
        c.out_dir = gs("out_dir", &c.out_dir);
        c.artifacts = gs("artifacts", &c.artifacts);
        c.validate()?;
        Ok(c)
    }

    /// Load `--config file.json` (if given), then apply CLI overrides.
    pub fn from_args(args: &Args) -> anyhow::Result<TrainConfig> {
        let base = match args.get("config") {
            Some(path) => {
                let text = std::fs::read_to_string(path)
                    .map_err(|e| anyhow::anyhow!("read {path}: {e}"))?;
                let v = json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
                TrainConfig::from_json(&v)?
            }
            None => TrainConfig::default(),
        };
        let mut c = base;
        if let Some(v) = args.get("model") { c.model = v.to_string(); }
        if let Some(v) = args.get("algo") { c.algo = v.to_string(); }
        c.n_clients = args.parse_or("n", c.n_clients)?;
        c.steps = args.parse_or("steps", c.steps)?;
        c.eval_every = args.parse_or("eval-every", c.eval_every)?;
        c.seed = args.parse_or("seed", c.seed)?;
        c.p = args.parse_or("p", c.p)?;
        c.lambda = args.parse_or("lambda", c.lambda)?;
        c.eta = args.parse_or("eta", c.eta)?;
        c.agg = args.parse_or("agg", c.agg)?;
        c.local_lr = args.parse_or("local-lr", c.local_lr)?;
        c.local_steps = args.parse_or("local-steps", c.local_steps)?;
        c.server_lr = args.parse_or("server-lr", c.server_lr)?;
        if let Some(v) = args.get("client-comp") { c.client_comp = v.to_string(); }
        if let Some(v) = args.get("master-comp") { c.master_comp = v.to_string(); }
        c.dirichlet_alpha = args.parse_or("alpha", c.dirichlet_alpha)?;
        if let Some(v) = args.get("out") { c.out_dir = v.to_string(); }
        if let Some(v) = args.get("artifacts") { c.artifacts = v.to_string(); }
        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(matches!(self.algo.as_str(), "l2gd" | "fedavg" | "fedopt"),
                        "unknown algo `{}`", self.algo);
        anyhow::ensure!(self.n_clients >= 1, "need ≥ 1 client");
        anyhow::ensure!((0.0..1.0).contains(&self.p) || self.algo != "l2gd",
                        "l2gd needs p in (0,1)");
        anyhow::ensure!(self.steps >= 1 && self.eval_every >= 1, "bad step counts");
        // compressor specs must parse
        crate::compress::from_spec(&self.client_comp)?;
        crate::compress::from_spec(&self.master_comp)?;
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("model".into(), Value::Str(self.model.clone())),
            ("algo".into(), Value::Str(self.algo.clone())),
            ("n_clients".into(), Value::Num(self.n_clients as f64)),
            ("steps".into(), Value::Num(self.steps as f64)),
            ("eval_every".into(), Value::Num(self.eval_every as f64)),
            ("seed".into(), Value::Num(self.seed as f64)),
            ("p".into(), Value::Num(self.p)),
            ("lambda".into(), Value::Num(self.lambda)),
            ("eta".into(), Value::Num(self.eta)),
            ("agg".into(), Value::Num(self.agg)),
            ("local_lr".into(), Value::Num(self.local_lr)),
            ("local_steps".into(), Value::Num(self.local_steps as f64)),
            ("server_lr".into(), Value::Num(self.server_lr)),
            ("client_comp".into(), Value::Str(self.client_comp.clone())),
            ("master_comp".into(), Value::Str(self.master_comp.clone())),
            ("dirichlet_alpha".into(), Value::Num(self.dirichlet_alpha)),
            ("out_dir".into(), Value::Str(self.out_dir.clone())),
            ("artifacts".into(), Value::Str(self.artifacts.clone())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let c = TrainConfig { p: 0.65, lambda: 25.0, ..Default::default() };
        let v = c.to_json();
        let c2 = TrainConfig::from_json(&v).unwrap();
        assert_eq!(c2.p, 0.65);
        assert_eq!(c2.lambda, 25.0);
        assert_eq!(c2.model, c.model);
    }

    #[test]
    fn cli_overrides() {
        let args = Args::parse(
            ["--p", "0.2", "--client-comp", "qsgd:8", "--steps", "99"]
                .iter().map(|s| s.to_string()),
            &[],
        ).unwrap();
        let c = TrainConfig::from_args(&args).unwrap();
        assert_eq!(c.p, 0.2);
        assert_eq!(c.client_comp, "qsgd:8");
        assert_eq!(c.steps, 99);
        assert_eq!(c.lambda, TrainConfig::default().lambda);
    }

    #[test]
    fn accepts_pipeline_and_ef_specs() {
        let mut c = TrainConfig {
            client_comp: "ef(randk:50>qsgd:8)".into(),
            master_comp: "bernoulli:0.2>natural".into(),
            ..Default::default()
        };
        c.validate().unwrap();
        // malformed pipelines are caught at config time, not mid-run
        c.master_comp = "randk:50>".into();
        assert!(c.validate().is_err());
        c.master_comp = "ef(natural".into();
        assert!(c.validate().is_err());
    }

    #[test]
    fn spec_error_names_registered_codecs() {
        let c = TrainConfig { client_comp: "gzip".into(), ..Default::default() };
        let err = format!("{:#}", c.validate().unwrap_err());
        assert!(err.contains("unknown compressor `gzip`"), "{err}");
        assert!(err.contains("natural") && err.contains("qsgd"), "{err}");
    }

    #[test]
    fn rejects_bad_configs() {
        let mut c = TrainConfig { algo: "sgd".into(), ..Default::default() };
        assert!(c.validate().is_err());
        c.algo = "l2gd".into();
        c.client_comp = "nope".into();
        assert!(c.validate().is_err());
        c.client_comp = "natural".into();
        c.p = 1.5;
        assert!(c.validate().is_err());
    }
}
