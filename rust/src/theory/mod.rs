//! The paper's complexity theory (§V–§VI), executable.
//!
//! Closed forms for the expected-smoothness constants (Lemma 6), the
//! optimal aggregation probability for iteration complexity (Theorem 3 +
//! Lemma 7) and for communication (Theorem 4), plus helpers that estimate
//! the problem constants (L_f, μ) from data so `pfl tune` can recommend
//! settings. Every closed form is cross-checked against brute-force grid
//! minimization in the tests.

use crate::compress::Compressor;
use crate::data::Dataset;

/// Joint compression factor of C = (C_1, …, C_n): ω = max_i ω_i (Lemma 1).
pub fn omega_joint(omegas: &[f64]) -> f64 {
    omegas.iter().cloned().fold(0.0, f64::max)
}

/// ω of a pipeline C_m ∘ … ∘ C_1 of unbiased stages: Π(1+ωᵢ) − 1.
///
/// The scalar form of [`crate::compress::compose_omega`]; the spec parser
/// applies it stage-by-stage, this helper serves hand-computed chains.
pub fn omega_chain(omegas: &[f64]) -> f64 {
    omegas.iter().fold(1.0, |acc, w| acc * (1.0 + w)) - 1.0
}

/// α := 4(4ω + 4ω_M(1+ω))/μ (Lemma 5).
pub fn alpha(omega: f64, omega_m: f64, mu: f64) -> f64 {
    4.0 * (4.0 * omega + 4.0 * omega_m * (1.0 + omega)) / mu
}

/// Problem + algorithm constants feeding γ/δ.
#[derive(Clone, Copy, Debug)]
pub struct Consts {
    pub n: usize,
    /// smoothness of f (f = (1/n)Σ f_i of the *stacked* objective);
    /// the paper sets L := n·L_f
    pub lf: f64,
    pub mu: f64,
    pub lambda: f64,
    /// client compression factor ω (0 = no compression)
    pub omega: f64,
    /// master compression factor ω_M
    pub omega_m: f64,
}

impl Consts {
    /// Build constants from compressor specs: ω/ω_M are the (possibly
    /// pipeline-composed) factors of the parsed specs at dimension `dim`.
    /// Fails with a readable message for biased specs (`topk:k`, `ef(...)`)
    /// — Theorems 3–4 require Assumption 1.
    pub fn for_specs(n: usize, lf: f64, mu: f64, lambda: f64, dim: usize,
                     client_spec: &str, master_spec: &str) -> anyhow::Result<Consts> {
        let biased = |spec: &str| {
            anyhow::anyhow!(
                "`{spec}` is biased (no Assumption-1 ω): Theorems 3-4 need \
                 unbiased compression — wrap biased stages differently or \
                 use an unbiased chain"
            )
        };
        let cc = crate::compress::from_spec(client_spec)?;
        let cm = crate::compress::from_spec(master_spec)?;
        let omega = cc.omega(dim).ok_or_else(|| biased(client_spec))?;
        let omega_m = cm.omega(dim).ok_or_else(|| biased(master_spec))?;
        Ok(Consts { n, lf, mu, lambda, omega, omega_m })
    }

    pub fn big_l(&self) -> f64 {
        self.n as f64 * self.lf
    }

    pub fn alpha(&self) -> f64 {
        alpha(self.omega, self.omega_m, self.mu)
    }

    /// Expected-smoothness constant γ(p) (Lemma 6).
    pub fn gamma(&self, p: f64) -> f64 {
        assert!(p > 0.0 && p < 1.0, "gamma needs p in (0,1)");
        let n = self.n as f64;
        let a = self.alpha();
        a * self.lambda * self.lambda * (1.0 - p) / (2.0 * n * n * p)
            + (self.lf / (1.0 - p))
                .max(self.lambda / n * (1.0 + 4.0 * (1.0 - p) / p))
    }

    /// Upper bound γ_u (§VI).
    pub fn gamma_u(&self, p: f64) -> f64 {
        let n = self.n as f64;
        let a = self.alpha();
        a * self.lambda * self.lambda * (1.0 - p) / (2.0 * n * n * p)
            + (self.lf / (1.0 - p)).max(4.0 * self.lambda / (n * p))
    }

    /// p_e: the crossing point of the two branches inside γ's max
    /// (Theorems 3–4): p_e = (7λ + L − √(λ² + 14λL + L²)) / (6λ).
    pub fn p_e(&self) -> f64 {
        let l = self.big_l();
        let lam = self.lambda;
        if lam <= 0.0 {
            return 0.0;
        }
        (7.0 * lam + l - (lam * lam + 14.0 * lam * l + l * l).sqrt()) / (6.0 * lam)
    }

    /// Remark 3: p_e simplifies to 4λ/(L + 4λ) when optimizing γ_u.
    pub fn p_e_upper(&self) -> f64 {
        let l = self.big_l();
        4.0 * self.lambda / (l + 4.0 * self.lambda)
    }

    /// Lemma 7: minimizer of A(p) = αλ²/(2n²p) + L/(n(1−p)) in (0,1).
    /// Algebraically p_A = 1/(1 + √(2nL/(αλ²))) (equals the paper's
    /// case-split quadratic roots; verified in tests).
    pub fn p_a_rate(&self) -> f64 {
        let a = self.alpha();
        let u = a * self.lambda * self.lambda;
        if u <= 0.0 {
            return 0.0; // no compression: A is increasing, minimizer → 0
        }
        let v = 2.0 * self.n as f64 * self.big_l();
        if v <= 0.0 {
            return 1.0;
        }
        1.0 / (1.0 + (v / u).sqrt())
    }

    /// Theorem 3: p* = max{p_e, p_A} minimizes iteration complexity.
    pub fn p_star_rate(&self) -> f64 {
        self.p_e().max(self.p_a_rate()).clamp(1e-6, 1.0 - 1e-6)
    }

    /// Theorem 4: p_A for communication C = p(1−p)γ is 1 − Ln/(αλ²)
    /// (may be ≤ 0, in which case p* = p_e).
    pub fn p_a_comm(&self) -> f64 {
        let u = self.alpha() * self.lambda * self.lambda;
        if u <= 0.0 {
            return 0.0;
        }
        1.0 - self.big_l() * self.n as f64 / u
    }

    /// Theorem 4: communication-optimal p*.
    pub fn p_star_comm(&self) -> f64 {
        self.p_e().max(self.p_a_comm()).clamp(1e-6, 1.0 - 1e-6)
    }

    /// Theorem 1 stepsize bound: η ≤ 1/(2γ).
    pub fn eta_max(&self, p: f64) -> f64 {
        1.0 / (2.0 * self.gamma(p))
    }

    /// Iterations for E‖x−x*‖² ≤ ε·‖x⁰−x*‖² at η = 1/(2γ)
    /// (Theorem 1 contraction (1 − ημ/n)^k, neglecting the δ-ball).
    pub fn iterations_to_eps(&self, p: f64, eps: f64) -> f64 {
        let eta = self.eta_max(p);
        let rate = eta * self.mu / self.n as f64;
        (1.0 / eps).ln() / rate
    }

    /// Expected communication rounds for the same target:
    /// rounds = p(1−p)·K (only 0→1 transitions communicate).
    pub fn comm_rounds_to_eps(&self, p: f64, eps: f64) -> f64 {
        p * (1.0 - p) * self.iterations_to_eps(p, eps)
    }
}

/// Estimate the logistic-regression smoothness L_f = σ_max(XᵀX)/(4m) + l2
/// by power iteration (the constant `pfl tune` feeds into `Consts`).
pub fn logreg_smoothness(data: &Dataset, l2: f64, iters: usize) -> f64 {
    let d = data.feat_len();
    let m = data.len();
    let mut v = vec![1.0f64 / (d as f64).sqrt(); d];
    let mut lam_est = 0.0;
    for _ in 0..iters {
        // u = (1/m) Xᵀ(X v)
        let mut xv = vec![0.0f64; m];
        for i in 0..m {
            let row = data.row(i);
            xv[i] = row.iter().zip(&v).map(|(&a, &b)| a as f64 * b).sum();
        }
        let mut u = vec![0.0f64; d];
        for i in 0..m {
            let row = data.row(i);
            let s = xv[i] / m as f64;
            for (uj, &xj) in u.iter_mut().zip(row) {
                *uj += xj as f64 * s;
            }
        }
        lam_est = u.iter().map(|x| x * x).sum::<f64>().sqrt();
        if lam_est <= 0.0 {
            break;
        }
        for (vj, uj) in v.iter_mut().zip(&u) {
            *vj = uj / lam_est;
        }
    }
    lam_est / 4.0 + l2
}

#[cfg(test)]
mod tests {
    use super::*;

    fn consts(omega: f64, omega_m: f64) -> Consts {
        Consts { n: 10, lf: 2.0, mu: 0.01, lambda: 5.0, omega, omega_m }
    }

    fn grid_min(f: impl Fn(f64) -> f64) -> f64 {
        let mut best = (f64::INFINITY, 0.0);
        for i in 1..100_000 {
            let p = i as f64 / 100_000.0;
            let v = f(p);
            if v < best.0 {
                best = (v, p);
            }
        }
        best.1
    }

    #[test]
    fn p_star_rate_matches_grid_minimum_uncompressed() {
        let c = consts(0.0, 0.0);
        let p_grid = grid_min(|p| c.gamma(p));
        let p_closed = c.p_star_rate();
        assert!((p_grid - p_closed).abs() < 2e-3,
                "grid {p_grid} vs closed {p_closed}");
    }

    #[test]
    fn p_star_rate_matches_grid_minimum_compressed() {
        for (w, wm) in [(0.125, 0.125), (1.0, 0.0), (3.0, 0.125)] {
            let c = consts(w, wm);
            let p_grid = grid_min(|p| c.gamma(p));
            let p_closed = c.p_star_rate();
            assert!(
                (c.gamma(p_closed) - c.gamma(p_grid)).abs()
                    <= 1e-3 * c.gamma(p_grid).abs(),
                "ω={w}: γ(closed {p_closed}) = {} vs γ(grid {p_grid}) = {}",
                c.gamma(p_closed),
                c.gamma(p_grid)
            );
        }
    }

    #[test]
    fn lemma7_closed_form_equals_paper_quadratic() {
        // our simplified p_A = 1/(1+√(2nL/(αλ²))) must equal the paper's
        // (−2αλ² + 2λ√(2αnL)) / (2(2nL − αλ²)) when 2nL ≠ αλ²
        let c = consts(0.5, 0.125);
        let a = c.alpha();
        let (lam, n, l) = (c.lambda, c.n as f64, c.big_l());
        let u = a * lam * lam;
        let v = 2.0 * n * l;
        assert!((u - v).abs() > 1.0, "pick constants off the degenerate case");
        let paper = (-2.0 * u + 2.0 * lam * (2.0 * a * n * l).sqrt()) / (2.0 * (v - u));
        assert!((c.p_a_rate() - paper).abs() < 1e-9,
                "ours {} paper {paper}", c.p_a_rate());
    }

    #[test]
    fn limits_lambda() {
        // λ → 0 ⇒ p* → 0 (never communicate); λ → ∞ ⇒ p* → 1 (§VI)
        let mut c = consts(0.125, 0.125);
        c.lambda = 1e-6;
        assert!(c.p_star_comm() < 0.01, "p* = {}", c.p_star_comm());
        c.lambda = 1e6;
        assert!(c.p_star_rate() > 0.9, "p* = {}", c.p_star_rate());
    }

    #[test]
    fn gamma_upper_bounds_gamma() {
        let c = consts(0.125, 0.125);
        for i in 1..50 {
            let p = i as f64 / 50.0;
            assert!(c.gamma_u(p) >= c.gamma(p) - 1e-9, "p={p}");
        }
    }

    #[test]
    fn no_compression_reduces_alpha_to_zero() {
        let c = consts(0.0, 0.0);
        assert_eq!(c.alpha(), 0.0);
        // and γ reduces to max{L_f/(1−p), λ/n·(1+4(1−p)/p)}
        let p = 0.3;
        let expect = (c.lf / 0.7).max(c.lambda / 10.0 * (1.0 + 4.0 * 0.7 / 0.3));
        assert!((c.gamma(p) - expect).abs() < 1e-12);
    }

    #[test]
    fn compression_increases_gamma() {
        let c0 = consts(0.0, 0.0);
        let c1 = consts(1.0, 0.125);
        for i in 1..20 {
            let p = i as f64 / 20.0;
            assert!(c1.gamma(p) > c0.gamma(p), "p={p}");
        }
    }

    #[test]
    fn eta_and_iteration_counts_positive_monotone() {
        let c = consts(0.125, 0.125);
        let p = c.p_star_rate();
        assert!(c.eta_max(p) > 0.0);
        let k1 = c.iterations_to_eps(p, 1e-2);
        let k2 = c.iterations_to_eps(p, 1e-4);
        assert!(k2 > k1 && k1 > 0.0);
        let rounds = c.comm_rounds_to_eps(p, 1e-2);
        assert!(rounds < k1);
    }

    #[test]
    fn omega_joint_is_max() {
        assert_eq!(omega_joint(&[0.1, 0.5, 0.3]), 0.5);
        assert_eq!(omega_joint(&[]), 0.0);
    }

    #[test]
    fn omega_chain_composes_multiplicatively() {
        assert_eq!(omega_chain(&[]), 0.0);
        assert!((omega_chain(&[0.125]) - 0.125).abs() < 1e-15);
        assert!((omega_chain(&[1.0, 0.125]) - 1.25).abs() < 1e-12);
        // matches the spec parser's stage-by-stage composition
        let spec = crate::compress::from_spec("randk:50>qsgd:8").unwrap();
        let by_hand = omega_chain(&[
            1000.0 / 50.0 - 1.0,
            (50.0f64 / 64.0).min(50.0f64.sqrt() / 8.0),
        ]);
        assert!((spec.omega(1000).unwrap() - by_hand).abs() < 1e-12);
    }

    #[test]
    fn consts_for_specs_composes_and_refuses_biased() {
        let c = Consts::for_specs(10, 2.0, 0.01, 5.0, 1000,
                                  "randk:50>qsgd:8", "natural").unwrap();
        assert!((c.omega_m - 0.125).abs() < 1e-15);
        let w1 = 1000.0 / 50.0 - 1.0;
        let w2 = (50.0f64 / 64.0).min(50.0f64.sqrt() / 8.0);
        assert!((c.omega - ((1.0 + w1) * (1.0 + w2) - 1.0)).abs() < 1e-12);
        // biased client or master spec is refused with a readable message
        for (cl, ms) in [("topk:10", "natural"), ("natural", "ef(randk:5)")] {
            let err = Consts::for_specs(10, 2.0, 0.01, 5.0, 1000, cl, ms)
                .expect_err("biased spec must be refused");
            assert!(format!("{err}").contains("biased"), "{err}");
        }
    }

    #[test]
    fn logreg_smoothness_estimates_spectral_norm() {
        // orthonormal-ish rows: X = I ⇒ σ_max(XᵀX)/m = 1/m... use a known
        // case: X with a single repeated row r ⇒ (1/m)XᵀX has top eig ‖r‖².
        let row = vec![3.0f32, 4.0]; // ‖r‖² = 25
        let mut feats = Vec::new();
        for _ in 0..8 {
            feats.extend_from_slice(&row);
        }
        let data = Dataset::new(feats, vec![2], vec![0; 8], 2);
        let lf = logreg_smoothness(&data, 0.0, 50);
        assert!((lf - 25.0 / 4.0).abs() < 1e-6, "lf = {lf}");
    }
}
