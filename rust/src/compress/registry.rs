//! Open codec registry: name → builder, consulted by the spec parser.
//!
//! Built-in operators self-register on first use; downstream code (or
//! tests, or embedding applications) adds operators at runtime with
//! [`register_codec`] — no edits to `compress/mod.rs` required. Spec
//! parsing, error messages (`registered_names`) and the registry-driven
//! test harness ([`examples`]) are all table-driven off this map.

use std::collections::BTreeMap;
use std::ops::Range;
use std::sync::{Arc, OnceLock, RwLock};

use super::pipeline::DenseStage;
use super::Codec;
use crate::sim::lang::{suggest, SpecError};

/// Builds a codec from its optional `:arg` and the already-built rest of
/// the chain to its right (`None` when the atom is last). Selector codecs
/// embed `inner` as their survivor codec; dense operators should pass it
/// to [`dense_chain`].
pub type BuildFn = Box<
    dyn Fn(Option<&str>, Option<Arc<dyn Codec>>) -> anyhow::Result<Arc<dyn Codec>>
        + Send
        + Sync,
>;

pub struct Entry {
    /// usage string shown in errors/docs, e.g. `qsgd:<levels>`
    pub help: String,
    /// a concrete valid spec, e.g. `qsgd:8` — drives registry-wide tests
    pub example: String,
    /// Arc so the parser can clone it out and invoke it with the registry
    /// lock released (a builder may itself consult the registry)
    build: Arc<BuildFn>,
}

#[derive(Default)]
pub struct Registry {
    map: BTreeMap<String, Entry>,
}

impl Registry {
    pub fn add(&mut self, name: &str, help: &str, example: &str, build: BuildFn) {
        self.map.insert(
            name.to_string(),
            Entry {
                help: help.to_string(),
                example: example.to_string(),
                build: Arc::new(build),
            },
        );
    }
}

static REGISTRY: OnceLock<RwLock<Registry>> = OnceLock::new();

fn global() -> &'static RwLock<Registry> {
    REGISTRY.get_or_init(|| {
        let mut r = Registry::default();
        super::identity::register(&mut r);
        super::natural::register(&mut r);
        super::qsgd::register(&mut r);
        super::terngrad::register(&mut r);
        super::bernoulli::register(&mut r);
        super::randk::register(&mut r);
        super::topk::register(&mut r);
        RwLock::new(r)
    })
}

/// Register (or replace) a codec under `name`. `example` must be a valid
/// standalone spec for it — the registry-driven property tests exercise it.
pub fn register_codec(name: &str, help: &str, example: &str, build: BuildFn) {
    global().write().unwrap().add(name, help, example, build);
}

/// Sorted names of all registered codecs.
pub fn registered_names() -> Vec<String> {
    global().read().unwrap().map.keys().cloned().collect()
}

/// `(name, example-spec)` for every registered codec.
pub fn examples() -> Vec<(String, String)> {
    global()
        .read()
        .unwrap()
        .map
        .iter()
        .map(|(n, e)| (n.clone(), e.example.clone()))
        .collect()
}

/// `(name, help)` for every registered codec (CLI/doc listings).
pub fn help_lines() -> Vec<(String, String)> {
    global()
        .read()
        .unwrap()
        .map
        .iter()
        .map(|(n, e)| (n.clone(), e.help.clone()))
        .collect()
}

/// Chain a dense (non-selector) codec with the rest of the pipeline: the
/// codec is applied in full and the next stage encodes its output.
pub fn dense_chain(codec: Arc<dyn Codec>, inner: Option<Arc<dyn Codec>>) -> Arc<dyn Codec> {
    match inner {
        None => codec,
        Some(next) => Arc::new(DenseStage::new(codec, next)),
    }
}

/// Parse a chain spec (`atom (">" atom)*`) into one codec, right-to-left so
/// each stage receives the already-built remainder as its inner codec.
pub fn codec_from_spec(spec: &str) -> anyhow::Result<Arc<dyn Codec>> {
    Ok(codec_from_spec_at(spec, 0..spec.len())?)
}

/// [`codec_from_spec`] for a chain living at `span` inside `src`: errors
/// are span-pointing [`SpecError`]s against the whole source string, so
/// the scenario parser's `codec=` key puts the caret on the offending
/// stage of the original spec.
pub fn codec_from_spec_at(
    src: &str,
    span: Range<usize>,
) -> Result<Arc<dyn Codec>, SpecError> {
    let raw = &src[span.clone()];
    let lo = span.start + (raw.len() - raw.trim_start().len());
    let hi = span.start + raw.trim_end().len();
    let spec = &src[lo..hi.max(lo)];
    if spec.is_empty() {
        return Err(SpecError::new(src, span, "empty compressor spec"));
    }
    // absolute start offset of every `>`-separated stage
    let mut stages: Vec<(usize, &str)> = Vec::new();
    let mut pos = lo;
    for piece in spec.split('>') {
        stages.push((pos, piece));
        pos += piece.len() + 1;
    }
    let mut inner: Option<Arc<dyn Codec>> = None;
    for (start, piece) in stages.into_iter().rev() {
        let a_lo = start + (piece.len() - piece.trim_start().len());
        let atom = piece.trim();
        let a_hi = a_lo + atom.len();
        if atom.is_empty() {
            return Err(SpecError::new(
                src,
                start..start + piece.len().max(1),
                format!("empty stage in pipeline spec `{spec}`"),
            )
            .with_help("stages chain as `a>b`; drop the dangling `>`"));
        }
        if atom.contains("ef(") {
            return Err(SpecError::new(
                src,
                a_lo..a_hi,
                format!(
                    "`ef(...)` must wrap the entire spec, not a pipeline \
                     stage (got `{spec}`)"
                ),
            ));
        }
        let (name, arg) = match atom.split_once(':') {
            Some((n, a)) => (n, Some(a)),
            None => (atom, None),
        };
        // clone the builder out so the lock is released before invoking it —
        // a builder is then free to consult the registry itself
        let build = {
            let guard = global().read().unwrap();
            match guard.map.get(name) {
                Some(entry) => Arc::clone(&entry.build),
                None => {
                    let names: Vec<&str> =
                        guard.map.keys().map(|s| s.as_str()).collect();
                    return Err(SpecError::new(
                        src,
                        a_lo..a_lo + name.len(),
                        format!("unknown compressor `{name}` (registered: {})",
                                names.join(", ")),
                    )
                    .maybe_help(suggest(name, names.iter().copied())
                        .map(|s| format!("did you mean `{s}`?"))));
                }
            }
        };
        let built = (*build)(arg, inner.take()).map_err(|e| {
            SpecError::new(src, a_lo..a_hi, format!("in stage `{atom}`: {e}"))
        })?;
        inner = Some(built);
    }
    Ok(inner.expect("non-empty spec yields a codec"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_are_registered() {
        let names = registered_names();
        for n in ["identity", "none", "natural", "qsgd", "terngrad",
                  "bernoulli", "randk", "topk"] {
            assert!(names.contains(&n.to_string()), "missing builtin `{n}`");
        }
    }

    #[test]
    fn every_example_spec_parses() {
        for (name, example) in examples() {
            assert!(codec_from_spec(&example).is_ok(),
                    "example `{example}` for `{name}` must parse");
        }
    }

    #[test]
    fn stage_errors_name_the_stage() {
        let err = format!("{:#}", codec_from_spec("natural>qsgd:zero").unwrap_err());
        assert!(err.contains("qsgd:zero"), "{err}");
    }

    #[test]
    fn codec_errors_carry_spans_and_suggestions() {
        let err = codec_from_spec_at("natural>qzgd:8", 0..14).unwrap_err();
        assert_eq!(err.span(), 8..12, "span covers the unknown stage name");
        assert!(err.to_string().contains("did you mean `qsgd`?"), "{err}");

        // a bad stage argument spans the whole stage
        let err = codec_from_spec_at("natural>qsgd:zero", 0..17).unwrap_err();
        assert_eq!(err.span(), 8..17);

        // and offsets survive embedding in a larger source string
        let src = "uniform:codec=natural>qzgd:8";
        let err = codec_from_spec_at(src, 14..28).unwrap_err();
        assert_eq!(err.span(), 22..26);
    }

    #[test]
    fn help_lines_nonempty() {
        for (name, help) in help_lines() {
            assert!(!help.is_empty(), "`{name}` has no help text");
        }
    }
}
