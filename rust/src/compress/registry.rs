//! Open codec registry: name → builder, consulted by the spec parser.
//!
//! Built-in operators self-register on first use; downstream code (or
//! tests, or embedding applications) adds operators at runtime with
//! [`register_codec`] — no edits to `compress/mod.rs` required. Spec
//! parsing, error messages (`registered_names`) and the registry-driven
//! test harness ([`examples`]) are all table-driven off this map.

use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock, RwLock};

use super::pipeline::DenseStage;
use super::Codec;

/// Builds a codec from its optional `:arg` and the already-built rest of
/// the chain to its right (`None` when the atom is last). Selector codecs
/// embed `inner` as their survivor codec; dense operators should pass it
/// to [`dense_chain`].
pub type BuildFn = Box<
    dyn Fn(Option<&str>, Option<Arc<dyn Codec>>) -> anyhow::Result<Arc<dyn Codec>>
        + Send
        + Sync,
>;

pub struct Entry {
    /// usage string shown in errors/docs, e.g. `qsgd:<levels>`
    pub help: String,
    /// a concrete valid spec, e.g. `qsgd:8` — drives registry-wide tests
    pub example: String,
    /// Arc so the parser can clone it out and invoke it with the registry
    /// lock released (a builder may itself consult the registry)
    build: Arc<BuildFn>,
}

#[derive(Default)]
pub struct Registry {
    map: BTreeMap<String, Entry>,
}

impl Registry {
    pub fn add(&mut self, name: &str, help: &str, example: &str, build: BuildFn) {
        self.map.insert(
            name.to_string(),
            Entry {
                help: help.to_string(),
                example: example.to_string(),
                build: Arc::new(build),
            },
        );
    }
}

static REGISTRY: OnceLock<RwLock<Registry>> = OnceLock::new();

fn global() -> &'static RwLock<Registry> {
    REGISTRY.get_or_init(|| {
        let mut r = Registry::default();
        super::identity::register(&mut r);
        super::natural::register(&mut r);
        super::qsgd::register(&mut r);
        super::terngrad::register(&mut r);
        super::bernoulli::register(&mut r);
        super::randk::register(&mut r);
        super::topk::register(&mut r);
        RwLock::new(r)
    })
}

/// Register (or replace) a codec under `name`. `example` must be a valid
/// standalone spec for it — the registry-driven property tests exercise it.
pub fn register_codec(name: &str, help: &str, example: &str, build: BuildFn) {
    global().write().unwrap().add(name, help, example, build);
}

/// Sorted names of all registered codecs.
pub fn registered_names() -> Vec<String> {
    global().read().unwrap().map.keys().cloned().collect()
}

/// `(name, example-spec)` for every registered codec.
pub fn examples() -> Vec<(String, String)> {
    global()
        .read()
        .unwrap()
        .map
        .iter()
        .map(|(n, e)| (n.clone(), e.example.clone()))
        .collect()
}

/// `(name, help)` for every registered codec (CLI/doc listings).
pub fn help_lines() -> Vec<(String, String)> {
    global()
        .read()
        .unwrap()
        .map
        .iter()
        .map(|(n, e)| (n.clone(), e.help.clone()))
        .collect()
}

/// Chain a dense (non-selector) codec with the rest of the pipeline: the
/// codec is applied in full and the next stage encodes its output.
pub fn dense_chain(codec: Arc<dyn Codec>, inner: Option<Arc<dyn Codec>>) -> Arc<dyn Codec> {
    match inner {
        None => codec,
        Some(next) => Arc::new(DenseStage::new(codec, next)),
    }
}

/// Parse a chain spec (`atom (">" atom)*`) into one codec, right-to-left so
/// each stage receives the already-built remainder as its inner codec.
pub fn codec_from_spec(spec: &str) -> anyhow::Result<Arc<dyn Codec>> {
    let spec = spec.trim();
    anyhow::ensure!(!spec.is_empty(), "empty compressor spec");
    let mut inner: Option<Arc<dyn Codec>> = None;
    for atom in spec.split('>').rev() {
        let atom = atom.trim();
        anyhow::ensure!(!atom.is_empty(), "empty stage in pipeline spec `{spec}`");
        anyhow::ensure!(
            !atom.contains("ef("),
            "`ef(...)` must wrap the entire spec, not a pipeline stage (got `{spec}`)"
        );
        let (name, arg) = match atom.split_once(':') {
            Some((n, a)) => (n, Some(a)),
            None => (atom, None),
        };
        // clone the builder out so the lock is released before invoking it —
        // a builder is then free to consult the registry itself
        let build = {
            let guard = global().read().unwrap();
            let entry = guard.map.get(name).ok_or_else(|| {
                let names: Vec<&str> = guard.map.keys().map(|s| s.as_str()).collect();
                anyhow::anyhow!("unknown compressor `{name}` (registered: {})",
                                names.join(", "))
            })?;
            Arc::clone(&entry.build)
        };
        let built = (*build)(arg, inner.take())
            .map_err(|e| anyhow::anyhow!("in stage `{atom}`: {e}"))?;
        inner = Some(built);
    }
    Ok(inner.expect("non-empty spec yields a codec"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_are_registered() {
        let names = registered_names();
        for n in ["identity", "none", "natural", "qsgd", "terngrad",
                  "bernoulli", "randk", "topk"] {
            assert!(names.contains(&n.to_string()), "missing builtin `{n}`");
        }
    }

    #[test]
    fn every_example_spec_parses() {
        for (name, example) in examples() {
            assert!(codec_from_spec(&example).is_ok(),
                    "example `{example}` for `{name}` must parse");
        }
    }

    #[test]
    fn stage_errors_name_the_stage() {
        let err = format!("{:#}", codec_from_spec("natural>qsgd:zero").unwrap_err());
        assert!(err.contains("qsgd:zero"), "{err}");
    }

    #[test]
    fn help_lines_nonempty() {
        for (name, help) in help_lines() {
            assert!(!help.is_empty(), "`{name}` has no help text");
        }
    }
}
