//! Top-k sparsifier (Aji & Heafield 2017): keep the k largest-magnitude
//! coordinates. **Biased** — the paper includes it "out of scientific
//! curiosity" (§VII-B); extending the theory to biased operators is listed
//! as future work, so `omega` returns `None` and the theory module refuses
//! it. It is a δ-contraction with δ = k/d (`contraction_delta`).
//!
//! Wire format: per kept coordinate ⌈log₂ d⌉ index bits + 32 value bits.

use super::{Codec, Compressed, Compressor};
use crate::util::{BitReader, BitWriter, Rng};

pub struct TopK {
    k: usize,
}

impl TopK {
    pub fn new(k: usize) -> TopK {
        assert!(k >= 1);
        TopK { k }
    }

    /// δ such that E‖C(x) − x‖² ≤ (1 − δ)‖x‖² (contractive-compressor
    /// constant; k/d for Top-k).
    pub fn contraction_delta(&self, dim: usize) -> f64 {
        (self.k.min(dim) as f64) / dim as f64
    }
}

fn index_bits(d: usize) -> u32 {
    (usize::BITS - (d - 1).leading_zeros()).max(1)
}

impl Compressor for TopK {
    fn name(&self) -> String {
        format!("topk:{}", self.k)
    }

    fn omega(&self, _dim: usize) -> Option<f64> {
        None // biased: Assumption 1 does not hold
    }

    fn compress(&self, x: &[f32], _rng: &mut Rng) -> Compressed {
        let d = x.len();
        let k = self.k.min(d);
        // partial selection of the k largest |x_i|
        let mut idx: Vec<usize> = (0..d).collect();
        idx.select_nth_unstable_by(k - 1, |&a, &b| {
            x[b].abs().partial_cmp(&x[a].abs()).unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut top: Vec<usize> = idx[..k].to_vec();
        top.sort_unstable(); // ascending indices compress better + cache-friendly decode
        let ib = index_bits(d);
        let mut w = BitWriter::with_capacity((k * (ib as usize + 32)) / 8 + 8);
        for &i in &top {
            w.put(i as u64, ib);
            w.put_f32(x[i]);
        }
        let bits = w.bit_len();
        Compressed::new(w.finish(), bits, d, Codec::TopK { k })
    }
}

pub(super) fn decode(payload: &[u8], k: usize, out: &mut [f32]) {
    out.fill(0.0);
    decode_add(payload, k, out, 1.0);
}

pub(super) fn decode_add(payload: &[u8], k: usize, acc: &mut [f32], scale: f32) {
    let d = acc.len();
    let k = k.min(d);
    let ib = index_bits(d);
    let mut r = BitReader::new(payload);
    for _ in 0..k {
        let i = r.get(ib) as usize;
        let v = r.get_f32();
        acc[i] += scale * v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::testutil;

    #[test]
    fn keeps_largest_magnitudes_exactly() {
        let x = vec![0.1f32, -9.0, 0.5, 3.0, -0.2, 7.0];
        let y = TopK::new(3).apply(&x, &mut Rng::new(0));
        assert_eq!(y, vec![0.0, -9.0, 0.0, 3.0, 0.0, 7.0]);
    }

    #[test]
    fn is_contraction() {
        // E‖C(x) − x‖² ≤ (1 − k/d)‖x‖² — deterministic here
        let x = testutil::test_vector(500, 1);
        let tk = TopK::new(50);
        let y = tk.apply(&x, &mut Rng::new(0));
        let err: f64 = x.iter().zip(&y).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum();
        let norm: f64 = x.iter().map(|&a| (a as f64).powi(2)).sum();
        assert!(err <= (1.0 - tk.contraction_delta(500)) * norm + 1e-9);
    }

    #[test]
    fn is_biased_and_refuses_omega() {
        assert!(TopK::new(5).omega(100).is_none());
        assert!(!TopK::new(5).unbiased());
    }

    #[test]
    fn wire_size_formula() {
        let x = testutil::test_vector(1000, 2);
        let c = TopK::new(100).compress(&x, &mut Rng::new(0));
        // ⌈log₂ 1000⌉ = 10 index bits + 32 value bits per coordinate
        assert_eq!(c.bits, 100 * (10 + 32));
    }

    #[test]
    fn k_geq_d_keeps_everything() {
        let x = testutil::test_vector(10, 3);
        let y = TopK::new(64).apply(&x, &mut Rng::new(0));
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn decode_add_matches_decode() {
        let x = testutil::test_vector(300, 4);
        let c = TopK::new(30).compress(&x, &mut Rng::new(0));
        let y = c.decode();
        let mut acc = vec![1.0f32; 300];
        c.decode_add(&mut acc, 2.0);
        for i in 0..300 {
            assert!((acc[i] - (1.0 + 2.0 * y[i])).abs() < 1e-5);
        }
    }
}
