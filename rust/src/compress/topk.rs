//! Top-k sparsifier (Aji & Heafield 2017): keep the k largest-magnitude
//! coordinates. **Biased** — the paper includes it "out of scientific
//! curiosity" (§VII-B); extending the theory to biased operators is listed
//! as future work, so `omega` returns `None` and the theory module refuses
//! it (wrap it in `ef(topk:k)` to compensate the bias with a residual). It
//! is a δ-contraction with δ = k/d (`contraction_delta`).
//!
//! Wire format, standalone: per kept coordinate ⌈log₂ d⌉ index bits + 32
//! value bits, interleaved (the legacy layout, kept bit-compatible). In a
//! pipeline (`topk:100>natural`): all k indices first, then the survivor
//! vector through the inner codec.

use std::sync::Arc;

use super::registry::Registry;
use super::{scratch, Codec};
use crate::util::{BitReader, BitWriter, Rng};

pub struct TopK {
    k: usize,
    /// survivor codec for pipeline specs; `None` = interleaved legacy wire
    inner: Option<Arc<dyn Codec>>,
}

impl TopK {
    pub fn new(k: usize) -> TopK {
        Self::chained(k, None)
    }

    pub fn chained(k: usize, inner: Option<Arc<dyn Codec>>) -> TopK {
        assert!(k >= 1);
        TopK { k, inner }
    }

    /// δ such that E‖C(x) − x‖² ≤ (1 − δ)‖x‖² (contractive-compressor
    /// constant; k/d for Top-k).
    pub fn contraction_delta(&self, dim: usize) -> f64 {
        (self.k.min(dim) as f64) / dim as f64
    }
}

fn index_bits(d: usize) -> u32 {
    (usize::BITS - (d - 1).leading_zeros()).max(1)
}

impl Codec for TopK {
    fn name(&self) -> String {
        match &self.inner {
            None => format!("topk:{}", self.k),
            Some(i) => format!("topk:{}>{}", self.k, i.name()),
        }
    }

    fn omega(&self, _dim: usize) -> Option<f64> {
        None // biased: Assumption 1 does not hold (chains inherit this)
    }

    fn encode_into(&self, x: &[f32], w: &mut BitWriter, rng: &mut Rng)
                   -> anyhow::Result<()> {
        let d = x.len();
        anyhow::ensure!(
            self.k <= d,
            "topk:{} cannot compress a {d}-dim vector: k exceeds the dimension \
             (use k ≤ d or drop the sparsifier)",
            self.k
        );
        let k = self.k;
        scratch::with_usize(|idx| {
            // partial selection of the k largest |x_i|
            idx.extend(0..d);
            idx.select_nth_unstable_by(k - 1, |&a, &b| {
                x[b].abs().partial_cmp(&x[a].abs()).unwrap_or(std::cmp::Ordering::Equal)
            });
            // ascending indices compress better + cache-friendly decode
            idx[..k].sort_unstable();
            let ib = index_bits(d);
            match &self.inner {
                None => {
                    for &i in idx[..k].iter() {
                        w.put(i as u64, ib);
                        w.put_f32(x[i]);
                    }
                    Ok(())
                }
                Some(inner) => {
                    for &i in idx[..k].iter() {
                        w.put(i as u64, ib);
                    }
                    scratch::with_f32(|vals| {
                        vals.extend(idx[..k].iter().map(|&i| x[i]));
                        inner.encode_into(vals, w, rng)
                    })
                }
            }
        })
    }

    fn decode_into(&self, r: &mut BitReader, out: &mut [f32]) {
        out.fill(0.0);
        self.decode_add(r, out, 1.0);
    }

    fn decode_add(&self, r: &mut BitReader, acc: &mut [f32], scale: f32) {
        let d = acc.len();
        let k = self.k.min(d); // encoder refuses k > d; stay in bounds
        let ib = index_bits(d);
        match &self.inner {
            None => {
                for _ in 0..k {
                    let i = r.get(ib) as usize;
                    let v = r.get_f32();
                    acc[i] += scale * v;
                }
            }
            Some(inner) => scratch::with_usize(|idx| {
                for _ in 0..k {
                    idx.push(r.get(ib) as usize);
                }
                scratch::with_f32(|vals| {
                    vals.resize(k, 0.0);
                    inner.decode_into(r, vals);
                    for (j, &i) in idx.iter().enumerate() {
                        acc[i] += scale * vals[j];
                    }
                })
            }),
        }
    }
}

pub(super) fn register(r: &mut Registry) {
    r.add("topk", "topk:<k> (largest-magnitude k, biased — pair with ef(...))",
          "topk:5",
          Box::new(|arg, inner| {
              let arg = arg.ok_or_else(|| {
                  anyhow::anyhow!("topk requires `:k` (e.g. topk:100)")
              })?;
              let k: usize = arg.parse()
                  .map_err(|e| anyhow::anyhow!("topk k `{arg}`: {e}"))?;
              anyhow::ensure!(k >= 1, "topk k must be ≥ 1");
              Ok(Arc::new(TopK::chained(k, inner)))
          }));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{testutil, Compressor};

    #[test]
    fn keeps_largest_magnitudes_exactly() {
        let x = vec![0.1f32, -9.0, 0.5, 3.0, -0.2, 7.0];
        let y = TopK::new(3).apply(&x, &mut Rng::new(0)).unwrap();
        assert_eq!(y, vec![0.0, -9.0, 0.0, 3.0, 0.0, 7.0]);
    }

    #[test]
    fn is_contraction() {
        // E‖C(x) − x‖² ≤ (1 − k/d)‖x‖² — deterministic here
        let x = testutil::test_vector(500, 1);
        let tk = TopK::new(50);
        let y = tk.apply(&x, &mut Rng::new(0)).unwrap();
        let err: f64 = x.iter().zip(&y).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum();
        let norm: f64 = x.iter().map(|&a| (a as f64).powi(2)).sum();
        assert!(err <= (1.0 - tk.contraction_delta(500)) * norm + 1e-9);
    }

    #[test]
    fn is_biased_and_refuses_omega() {
        assert!(TopK::new(5).omega(100).is_none());
        assert!(!crate::compress::from_spec("topk:5").unwrap().unbiased());
    }

    #[test]
    fn wire_size_formula() {
        let x = testutil::test_vector(1000, 2);
        let c = testutil::compress("topk:100", &x, 0);
        // ⌈log₂ 1000⌉ = 10 index bits + 32 value bits per coordinate
        assert_eq!(c.bits, 100 * (10 + 32));
    }

    #[test]
    fn k_above_dim_is_a_compress_time_error() {
        let x = testutil::test_vector(10, 3);
        let err = TopK::new(64).apply(&x, &mut Rng::new(0)).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("topk:64") && msg.contains("10-dim"), "{msg}");
    }

    #[test]
    fn k_equal_dim_keeps_everything() {
        let x = testutil::test_vector(10, 3);
        let y = TopK::new(10).apply(&x, &mut Rng::new(0)).unwrap();
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn decode_add_matches_decode() {
        let x = testutil::test_vector(300, 4);
        let c = testutil::compress("topk:30", &x, 0);
        let y = c.decode();
        let mut acc = vec![1.0f32; 300];
        c.decode_add(&mut acc, 2.0);
        for i in 0..300 {
            assert!((acc[i] - (1.0 + 2.0 * y[i])).abs() < 1e-5);
        }
    }

    #[test]
    fn chained_survivors_use_inner_codec() {
        // topk:50>natural: indices block + 9-bit survivors
        let x = testutil::test_vector(1000, 5);
        let c = testutil::compress("topk:50>natural", &x, 6);
        assert_eq!(c.bits, 50 * 10 + 9 * 50);
        // the support is still the top-50 coordinates
        let plain = testutil::compress("topk:50", &x, 6).decode();
        let chained = c.decode();
        for i in 0..1000 {
            assert_eq!(plain[i] == 0.0, chained[i] == 0.0, "support differs at {i}");
        }
    }
}
