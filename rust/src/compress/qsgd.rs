//! QSGD / random dithering (Alistarh et al. 2017) with `s` levels.
//!
//! C(x)_i = ‖x‖₂ · sign(x_i) · ξ_i/s, ξ_i ∈ {⌊t⌋, ⌈t⌉}, t = s|x_i|/‖x‖₂,
//! P(ξ = ⌈t⌉) = t − ⌊t⌋. Unbiased with ω ≤ min(d/s², √d/s).
//!
//! Wire format: 32-bit norm header, then per coordinate 1 sign bit +
//! Elias-γ(level + 1). Since E[Σ levels] ≤ s·√d, the γ-code keeps dense
//! small levels near 1–3 bits — the "encoding" half of QSGD's guarantee.

use std::sync::Arc;

use super::registry::{dense_chain, Registry};
use super::Codec;
use crate::util::{BitReader, BitWriter, Rng};

pub struct Qsgd {
    s: u32,
}

impl Qsgd {
    pub fn new(s: u32) -> Qsgd {
        assert!(s >= 1);
        Qsgd { s }
    }
}

impl Codec for Qsgd {
    fn name(&self) -> String {
        format!("qsgd:{}", self.s)
    }

    fn omega(&self, dim: usize) -> Option<f64> {
        let d = dim as f64;
        let s = self.s as f64;
        Some((d / (s * s)).min(d.sqrt() / s))
    }

    fn encode_into(&self, x: &[f32], w: &mut BitWriter, rng: &mut Rng)
                   -> anyhow::Result<()> {
        let norm = crate::util::stats::l2_norm(x) as f32;
        w.put_f32(norm);
        if norm > 0.0 {
            // §Perf: hoist the s/norm division and emit sign + Elias-γ as a
            // single put (bitstream identical to sign-then-γ): LSB-first the
            // code is [sign][nbits−1 zeros][reversed m], 2·nbits total.
            let k = self.s as f32 / norm;
            for &v in x {
                let t = k * v.abs(); // ∈ [0, s]
                let lo = t as u64;   // floor for t ≥ 0
                let level = lo + (rng.f32() < (t - lo as f32)) as u64;
                let m = level + 1;
                let nbits = 64 - m.leading_zeros();
                let sign = (v < 0.0) as u64;
                if 2 * nbits <= 57 {
                    let rev = m.reverse_bits() >> (64 - nbits);
                    w.put(sign | (rev << nbits), 2 * nbits);
                } else {
                    w.put(sign, 1);
                    w.put_elias_gamma(m);
                }
            }
        }
        Ok(())
    }

    fn decode_into(&self, r: &mut BitReader, out: &mut [f32]) {
        let norm = r.get_f32();
        if norm <= 0.0 {
            out.fill(0.0);
            return;
        }
        let step = norm / self.s as f32;
        for o in out.iter_mut() {
            let neg = r.get_bit();
            let level = (r.get_elias_gamma() - 1) as f32;
            let mut v = step * level;
            if neg {
                v = -v;
            }
            *o = v;
        }
    }

    fn decode_add(&self, r: &mut BitReader, acc: &mut [f32], scale: f32) {
        let norm = r.get_f32();
        if norm <= 0.0 {
            return;
        }
        let step = norm / self.s as f32;
        for a in acc.iter_mut() {
            let neg = r.get_bit();
            let level = (r.get_elias_gamma() - 1) as f32;
            let mut v = step * level;
            if neg {
                v = -v;
            }
            *a += scale * v;
        }
    }
}

pub(super) fn register(r: &mut Registry) {
    r.add("qsgd", "qsgd:<levels> (random dithering, ω = min(d/s², √d/s))",
          "qsgd:8",
          Box::new(|arg, inner| {
              let arg = arg.ok_or_else(|| {
                  anyhow::anyhow!("qsgd requires `:levels` (e.g. qsgd:8)")
              })?;
              let s: u32 = arg.parse()
                  .map_err(|e| anyhow::anyhow!("qsgd levels `{arg}`: {e}"))?;
              anyhow::ensure!(s >= 1, "qsgd levels must be ≥ 1");
              Ok(dense_chain(Arc::new(Qsgd::new(s)), inner))
          }));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::testutil;
    use crate::util::stats::l2_norm;

    #[test]
    fn roundtrip_levels_on_grid() {
        let x = testutil::test_vector(500, 1);
        let c = testutil::compress("qsgd:8", &x, 2);
        let y = c.decode();
        let norm = l2_norm(&x) as f32;
        let step = norm / 8.0;
        for (xi, yi) in x.iter().zip(&y) {
            let lv = yi.abs() / step;
            assert!((lv - lv.round()).abs() < 1e-3, "level off-grid: {yi}");
            if *yi != 0.0 {
                assert_eq!(yi.signum(), xi.signum());
            }
        }
    }

    #[test]
    fn assumption1_holds_s4() {
        let x = testutil::test_vector(64, 3);
        testutil::check_assumption1(&Qsgd::new(4), &x, 800, 11);
    }

    #[test]
    fn assumption1_holds_s1_terngrad_regime() {
        let x = testutil::test_vector(32, 5);
        testutil::check_assumption1(&Qsgd::new(1), &x, 800, 13);
    }

    #[test]
    fn zero_vector_compresses_to_header_only() {
        let x = vec![0.0f32; 100];
        let c = testutil::compress("qsgd:8", &x, 0);
        assert_eq!(c.bits, 32);
        assert_eq!(c.decode(), x);
    }

    #[test]
    fn wire_much_smaller_than_raw_for_large_s_d() {
        // E[bits/coord] ≈ 1 + E[2⌊log₂(level+1)⌋+1]; for s = 15, d = 10k,
        // levels are mostly 0/1 ⇒ ≈ 2.5 bits ≪ 32.
        let x = testutil::test_vector(10_000, 7);
        let c = testutil::compress("qsgd:15", &x, 1);
        assert!(c.bits < 8 * 10_000, "bits = {}", c.bits);
        assert!(c.bits > 32 + 2 * 10_000);
    }

    #[test]
    fn omega_formula() {
        let q = Qsgd::new(10);
        // d = 100, s = 10: min(100/100, 10/10) = 1.0
        assert_eq!(q.omega(100).unwrap(), 1.0);
        // d = 10000, s = 10: min(100, 10) = 10
        assert_eq!(q.omega(10_000).unwrap(), 10.0);
    }

    #[test]
    fn decode_add_matches_decode() {
        let x = testutil::test_vector(200, 9);
        let c = testutil::compress("qsgd:4", &x, 4);
        let y = c.decode();
        let mut acc = vec![0.5f32; 200];
        c.decode_add(&mut acc, -1.5);
        for i in 0..200 {
            assert!((acc[i] - (0.5 - 1.5 * y[i])).abs() < 1e-5);
        }
    }
}
