//! Thread-local scratch pools for the compression hot path.
//!
//! Selector codecs need short-lived buffers (survivor values, index sets,
//! dense-stage intermediates). Allocating them per call would put a malloc
//! on every wire operation, so each worker thread keeps small pools of
//! reusable vectors: steady state, `compress_into`/`decode_add` touch the
//! allocator zero times (asserted in `benches/perf_compressors.rs`).
//!
//! Nested acquisitions (a chain inside a chain) pop distinct vectors, so
//! re-entrancy is safe; a panic inside a closure merely drops the buffer.

use std::cell::RefCell;

thread_local! {
    static F32S: RefCell<Vec<Vec<f32>>> = const { RefCell::new(Vec::new()) };
    static USIZES: RefCell<Vec<Vec<usize>>> = const { RefCell::new(Vec::new()) };
    static BYTES: RefCell<Vec<Vec<u8>>> = const { RefCell::new(Vec::new()) };
}

/// Run `f` with a cleared pooled `Vec<f32>` (capacity persists per thread).
pub(crate) fn with_f32<R>(f: impl FnOnce(&mut Vec<f32>) -> R) -> R {
    let mut v = F32S.with(|p| p.borrow_mut().pop()).unwrap_or_default();
    v.clear();
    let r = f(&mut v);
    F32S.with(|p| p.borrow_mut().push(v));
    r
}

/// Run `f` with a cleared pooled `Vec<usize>`.
pub(crate) fn with_usize<R>(f: impl FnOnce(&mut Vec<usize>) -> R) -> R {
    let mut v = USIZES.with(|p| p.borrow_mut().pop()).unwrap_or_default();
    v.clear();
    let r = f(&mut v);
    USIZES.with(|p| p.borrow_mut().push(v));
    r
}

/// Run `f` with a cleared pooled `Vec<u8>` (dense-stage bitstreams).
pub(crate) fn with_bytes<R>(f: impl FnOnce(&mut Vec<u8>) -> R) -> R {
    let mut v = BYTES.with(|p| p.borrow_mut().pop()).unwrap_or_default();
    v.clear();
    let r = f(&mut v);
    BYTES.with(|p| p.borrow_mut().push(v));
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_persists_across_acquisitions() {
        with_f32(|v| v.resize(1000, 1.0));
        let cap = with_f32(|v| {
            assert!(v.is_empty(), "pooled buffer must come back cleared");
            v.capacity()
        });
        assert!(cap >= 1000);
    }

    #[test]
    fn nested_acquisitions_get_distinct_buffers() {
        with_f32(|a| {
            a.push(1.0);
            with_f32(|b| {
                b.push(2.0);
                assert_eq!(a.len(), 1);
                assert_eq!(b.len(), 1);
            });
            assert_eq!(a[0], 1.0);
        });
    }
}
