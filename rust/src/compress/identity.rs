//! Identity "compressor": raw f32 wire format (ω = 0).
//!
//! The no-compression baseline every experiment compares against; its
//! 32·d wire bits are exactly what FedAvg/FedOpt send per vector.

use std::sync::Arc;

use super::registry::{dense_chain, Registry};
use super::Codec;
use crate::util::{BitReader, BitWriter, Rng};

pub struct Identity;

impl Codec for Identity {
    fn name(&self) -> String {
        "identity".into()
    }

    fn omega(&self, _dim: usize) -> Option<f64> {
        Some(0.0)
    }

    fn encode_into(&self, x: &[f32], w: &mut BitWriter, _rng: &mut Rng)
                   -> anyhow::Result<()> {
        for &v in x {
            w.put_f32(v);
        }
        Ok(())
    }

    fn decode_into(&self, r: &mut BitReader, out: &mut [f32]) {
        for o in out.iter_mut() {
            *o = r.get_f32();
        }
    }

    fn decode_add(&self, r: &mut BitReader, acc: &mut [f32], scale: f32) {
        for a in acc.iter_mut() {
            *a += scale * r.get_f32();
        }
    }
}

pub(super) fn register(r: &mut Registry) {
    r.add("identity", "identity (raw f32, ω = 0)", "identity",
          Box::new(|_arg, inner| Ok(dense_chain(Arc::new(Identity), inner))));
    r.add("none", "none (alias of identity)", "none",
          Box::new(|_arg, inner| Ok(dense_chain(Arc::new(Identity), inner))));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{testutil, Compressor};

    #[test]
    fn exact_roundtrip() {
        let x = vec![1.5f32, -2.25, 0.0, f32::MIN_POSITIVE, 1e30];
        let c = testutil::compress("identity", &x, 0);
        assert_eq!(c.bits, 160);
        assert_eq!(c.decode(), x);
    }

    #[test]
    fn decode_add_accumulates() {
        let x = vec![1.0f32, 2.0];
        let c = testutil::compress("identity", &x, 0);
        let mut acc = vec![10.0f32, 10.0];
        c.decode_add(&mut acc, 0.5);
        assert_eq!(acc, vec![10.5, 11.0]);
    }

    #[test]
    fn omega_zero() {
        assert_eq!(Identity.omega(100), Some(0.0));
        assert!(crate::compress::from_spec("identity").unwrap().unbiased());
    }
}
