//! Identity "compressor": raw f32 wire format (ω = 0).
//!
//! The no-compression baseline every experiment compares against; its
//! 32·d wire bits are exactly what FedAvg/FedOpt send per vector.

use super::{Codec, Compressed, Compressor};
use crate::util::Rng;

pub struct Identity;

impl Compressor for Identity {
    fn name(&self) -> String {
        "identity".into()
    }

    fn omega(&self, _dim: usize) -> Option<f64> {
        Some(0.0)
    }

    fn compress(&self, x: &[f32], _rng: &mut Rng) -> Compressed {
        let mut payload = Vec::with_capacity(x.len() * 4);
        for &v in x {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        Compressed::new(payload, 32 * x.len() as u64, x.len(), Codec::Identity)
    }
}

pub(super) fn decode(payload: &[u8], out: &mut [f32]) {
    for (i, o) in out.iter_mut().enumerate() {
        *o = f32::from_le_bytes(payload[4 * i..4 * i + 4].try_into().unwrap());
    }
}

pub(super) fn decode_add(payload: &[u8], acc: &mut [f32], scale: f32) {
    for (i, a) in acc.iter_mut().enumerate() {
        *a += scale * f32::from_le_bytes(payload[4 * i..4 * i + 4].try_into().unwrap());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_roundtrip() {
        let x = vec![1.5f32, -2.25, 0.0, f32::MIN_POSITIVE, 1e30];
        let mut rng = Rng::new(0);
        let c = Identity.compress(&x, &mut rng);
        assert_eq!(c.bits, 160);
        assert_eq!(c.decode(), x);
    }

    #[test]
    fn decode_add_accumulates() {
        let x = vec![1.0f32, 2.0];
        let mut rng = Rng::new(0);
        let c = Identity.compress(&x, &mut rng);
        let mut acc = vec![10.0f32, 10.0];
        c.decode_add(&mut acc, 0.5);
        assert_eq!(acc, vec![10.5, 11.0]);
    }

    #[test]
    fn omega_zero() {
        assert_eq!(Identity.omega(100), Some(0.0));
        assert!(Identity.unbiased());
    }
}
