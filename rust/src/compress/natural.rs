//! Natural compression (Horváth et al. 2019): stochastic rounding to signed
//! powers of two. Unbiased with ω = 1/8 — the smallest-variance operator in
//! Table I, and the one the paper finds "empirically behaves the best".
//!
//! Wire format: 9 bits/coordinate — 1 sign + 8-bit exponent code, where
//! code 0 ⇒ value 0 and code c ∈ [1, 255] ⇒ magnitude 2^(c − 128)
//! (covers 2^-127 .. 2^127; f32 subnormal results flush to zero).

use std::sync::Arc;

use super::registry::{dense_chain, Registry};
use super::Codec;
use crate::util::{BitReader, BitWriter, Rng};

pub struct Natural;

const BIAS: i32 = 128;

impl Codec for Natural {
    fn name(&self) -> String {
        "natural".into()
    }

    fn omega(&self, _dim: usize) -> Option<f64> {
        Some(0.125)
    }

    fn encode_into(&self, x: &[f32], w: &mut BitWriter, rng: &mut Rng)
                   -> anyhow::Result<()> {
        // §Perf: one 9-bit put per coordinate (sign in the low bit — wire
        // format identical to the two-put version), and the rounding
        // probability read directly off the mantissa field:
        // for normal a = (1 + m/2²³)·2^e, (a − 2^e)/2^e = m/2²³ exactly.
        const INV_M: f32 = 1.0 / (1u32 << 23) as f32;
        for &v in x {
            let bits = v.to_bits();
            let exp_field = (bits >> 23) & 0xFF;
            // zero, subnormal (flush), inf/NaN all map to code 0
            if exp_field == 0 || exp_field == 0xFF || !v.is_finite() {
                w.put(0, 9);
                continue;
            }
            let mant = bits & 0x7F_FFFF;
            let e = exp_field as i32 - 127; // 2^e ≤ |v| < 2^{e+1}
            let p_up = mant as f32 * INV_M;
            let e_out = if rng.f32() < p_up { e + 1 } else { e };
            let code = (e_out + BIAS).clamp(1, 255) as u64;
            let sign = (bits >> 31) as u64;
            w.put(sign | (code << 1), 9);
        }
        Ok(())
    }

    fn decode_into(&self, r: &mut BitReader, out: &mut [f32]) {
        let t = lut(1.0);
        for o in out.iter_mut() {
            *o = t[r.get(9) as usize];
        }
    }

    fn decode_add(&self, r: &mut BitReader, acc: &mut [f32], scale: f32) {
        let t = lut(scale);
        for a in acc.iter_mut() {
            *a += t[r.get(9) as usize];
        }
    }
}

#[inline]
fn sym(sign: bool, code: u64) -> f32 {
    if code == 0 {
        return 0.0;
    }
    let e = code as i32 - BIAS; // ∈ [-127, 127]
    let exp_field = e + 127;
    let mag = if (1..=254).contains(&exp_field) {
        f32::from_bits((exp_field as u32) << 23)
    } else if exp_field <= 0 {
        0.0 // subnormal flush
    } else {
        f32::MAX
    };
    if sign { -mag } else { mag }
}

/// §Perf: 512-entry table mapping the 9-bit wire symbol straight to its
/// f32 value — replaces the per-coordinate branch chain in `sym`.
fn lut(scale: f32) -> [f32; 512] {
    let mut t = [0.0f32; 512];
    for (v, slot) in t.iter_mut().enumerate() {
        *slot = scale * sym(v & 1 != 0, (v >> 1) as u64);
    }
    t
}

pub(super) fn register(r: &mut Registry) {
    r.add("natural", "natural (powers-of-two rounding, 9 bits/coord, ω = 1/8)",
          "natural",
          Box::new(|_arg, inner| Ok(dense_chain(Arc::new(Natural), inner))));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::testutil;

    fn apply(x: &[f32], seed: u64) -> Vec<f32> {
        Natural.apply(x, &mut Rng::new(seed)).unwrap()
    }

    #[test]
    fn wire_is_9_bits_per_coordinate() {
        let x = testutil::test_vector(1000, 1);
        let c = testutil::compress("natural", &x, 0);
        assert_eq!(c.bits, 9 * 1000);
        assert_eq!(c.payload.len(), (9 * 1000_usize).div_ceil(8));
    }

    #[test]
    fn outputs_are_signed_powers_of_two() {
        let x = testutil::test_vector(512, 2);
        let y = apply(&x, 3);
        for (xi, yi) in x.iter().zip(&y) {
            if *xi == 0.0 {
                assert_eq!(*yi, 0.0);
                continue;
            }
            assert_eq!(yi.signum(), xi.signum());
            let m = yi.abs().log2();
            assert!((m - m.round()).abs() < 1e-6, "{yi} not a power of two");
            // within a factor of 2 of the input
            assert!(yi.abs() >= xi.abs() * 0.999 / 2.0 && yi.abs() <= xi.abs() * 2.001,
                    "{xi} -> {yi}");
        }
    }

    #[test]
    fn powers_of_two_are_fixed_points() {
        let x = vec![1.0f32, -2.0, 0.5, 4096.0, -0.015625];
        let y = apply(&x, 9);
        assert_eq!(x, y);
    }

    #[test]
    fn assumption1_holds() {
        let x = testutil::test_vector(128, 4);
        testutil::check_assumption1(&Natural, &x, 800, 5);
    }

    #[test]
    fn zeros_and_nonfinite_map_to_zero() {
        let x = vec![0.0f32, f32::NAN, f32::INFINITY, -0.0];
        let y = apply(&x, 0);
        assert_eq!(y, vec![0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn decode_add_matches_decode() {
        let x = testutil::test_vector(333, 6);
        let c = testutil::compress("natural", &x, 7);
        let y = c.decode();
        let mut acc = vec![1.0f32; 333];
        c.decode_add(&mut acc, 2.0);
        for i in 0..333 {
            assert!((acc[i] - (1.0 + 2.0 * y[i])).abs() < 1e-6);
        }
    }
}
