//! Pipeline plumbing: the spec-level [`Pipeline`] descriptor, its
//! per-client [`CodecState`], and the [`DenseStage`] combinator that chains
//! non-selector codecs.
//!
//! Chaining model (`a>b`, data flows left to right):
//!
//! * **Selector stages** (rand-k, top-k, Bernoulli) write their structure
//!   bits and hand only the *survivor values* to the next stage — that is
//!   how `randk:50>qsgd:8` quantizes 50 values instead of d.
//! * **Dense stages** (identity, natural, qsgd, terngrad, …) mid-chain are
//!   wrapped in [`DenseStage`]: the stage is applied locally
//!   (compress→decompress, same distribution as crossing the wire) and the
//!   next stage encodes its output, so only the last dense stage's bits hit
//!   the wire. The composed operator is C₂∘C₁ with
//!   ω = (1+ω₁)(1+ω₂) − 1 ([`compose_omega`]).

use std::sync::Arc;

use super::{compose_omega, scratch, Codec, Compressed, Compressor, CompressorState};
use crate::util::{BitReader, BitWriter, Rng};

/// Dense composition C_then ∘ C_first: `first` is applied in full, `then`
/// encodes its output (and alone determines the wire format).
pub struct DenseStage {
    first: Arc<dyn Codec>,
    then: Arc<dyn Codec>,
}

impl DenseStage {
    pub fn new(first: Arc<dyn Codec>, then: Arc<dyn Codec>) -> DenseStage {
        DenseStage { first, then }
    }
}

impl Codec for DenseStage {
    fn name(&self) -> String {
        format!("{}>{}", self.first.name(), self.then.name())
    }

    fn omega(&self, dim: usize) -> Option<f64> {
        compose_omega(self.first.omega(dim), self.then.omega(dim))
    }

    fn encode_into(&self, x: &[f32], w: &mut BitWriter, rng: &mut Rng)
                   -> anyhow::Result<()> {
        scratch::with_f32(|z| {
            z.resize(x.len(), 0.0);
            self.first.apply_into(x, z, rng)?;
            self.then.encode_into(z, w, rng)
        })
    }

    fn decode_into(&self, r: &mut BitReader, out: &mut [f32]) {
        self.then.decode_into(r, out);
    }

    fn decode_add(&self, r: &mut BitReader, acc: &mut [f32], scale: f32) {
        self.then.decode_add(r, acc, scale);
    }
}

/// Shareable descriptor wrapping a (possibly chained) codec — what
/// [`super::from_spec`] returns for everything except `ef(...)`.
pub struct Pipeline {
    codec: Arc<dyn Codec>,
}

impl Pipeline {
    pub fn new(codec: Arc<dyn Codec>) -> Pipeline {
        Pipeline { codec }
    }

    /// The underlying wire codec (e.g. for direct `apply` in analyses).
    pub fn codec(&self) -> &Arc<dyn Codec> {
        &self.codec
    }
}

impl Compressor for Pipeline {
    fn name(&self) -> String {
        self.codec.name()
    }

    fn omega(&self, dim: usize) -> Option<f64> {
        self.codec.omega(dim)
    }

    fn instantiate(&self, _dim: usize, seed: u64) -> Box<dyn CompressorState> {
        Box::new(CodecState { codec: Arc::clone(&self.codec), rng: Rng::new(seed) })
    }
}

/// Stateless-codec instance: the only per-client state is the RNG stream.
pub struct CodecState {
    codec: Arc<dyn Codec>,
    rng: Rng,
}

impl CompressorState for CodecState {
    fn compress_into(&mut self, x: &[f32], out: &mut Compressed) -> anyhow::Result<()> {
        // round-trip the payload Vec through the writer: capacity (and
        // steady-state storage) is reused, so this path never allocates
        // after warmup.
        let mut w = BitWriter::reuse(std::mem::take(&mut out.payload));
        let res = self.codec.encode_into(x, &mut w, &mut self.rng);
        out.bits = w.bit_len();
        out.payload = w.finish();
        res?;
        out.dim = x.len();
        out.set_codec(Arc::clone(&self.codec));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::testutil;
    use crate::compress::{codec_from_spec, from_spec};

    #[test]
    fn dense_stage_wire_is_final_stage_only() {
        // natural>qsgd:8 puts only qsgd bits on the wire
        let x = testutil::test_vector(300, 1);
        let chained = testutil::compress("natural>qsgd:8", &x, 5);
        assert!(chained.bits < 32 + 300 * 12, "bits = {}", chained.bits);
        assert_eq!(chained.dim, 300);
        // decode reproduces a vector on qsgd's grid (norm · level/s)
        let y = chained.decode();
        assert_eq!(y.len(), 300);
    }

    #[test]
    fn selector_survivor_chaining_preserves_sparsity() {
        let x = testutil::test_vector(400, 2);
        let c = testutil::compress("randk:40>qsgd:8", &x, 3);
        let y = c.decode();
        let nnz = y.iter().filter(|v| **v != 0.0).count();
        assert!(nnz <= 40, "nnz = {nnz}");
    }

    #[test]
    fn pipeline_descriptor_shares_codec_across_instances() {
        let p = from_spec("randk:10>natural").unwrap();
        let mut a = p.instantiate(100, 1);
        let mut b = p.instantiate(100, 1);
        let x = testutil::test_vector(100, 4);
        // same seed ⇒ bit-identical independent streams
        let ca = a.compress(&x).unwrap();
        let cb = b.compress(&x).unwrap();
        assert_eq!(ca.payload, cb.payload);
        assert_eq!(ca.bits, cb.bits);
    }

    #[test]
    fn codec_accessor_matches_spec() {
        let p = Pipeline::new(codec_from_spec("terngrad").unwrap());
        assert_eq!(p.codec().name(), "terngrad");
    }

    #[test]
    fn instantiations_with_different_seeds_differ() {
        let p = from_spec("natural").unwrap();
        let x = testutil::test_vector(128, 6);
        let ca = p.instantiate(128, 1).compress(&x).unwrap();
        let cb = p.instantiate(128, 2).compress(&x).unwrap();
        assert_ne!(ca.payload, cb.payload);
    }
}
