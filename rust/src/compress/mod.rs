//! Bidirectional communication compression (the paper's §IV).
//!
//! Every operator from Table I is implemented with a *real bit-packed wire
//! format* — `compress` produces the bytes that would cross the network and
//! `Compressed::decode` reconstructs the vector — so the bits/n metric the
//! paper reports is measured, not estimated.
//!
//! Unbiased operators satisfy Assumption 1: `E[C(x)] = x` and
//! `E‖C(x) − x‖² ≤ ω‖x‖²`; `omega(d)` returns the constant the theory
//! module (§V–§VI) consumes. Top-k is biased (kept as the paper's
//! proof-of-concept; `omega` returns `None`).

pub mod bernoulli;
pub mod identity;
pub mod natural;
pub mod qsgd;
pub mod randk;
pub mod terngrad;
pub mod topk;

use crate::util::Rng;

pub use bernoulli::Bernoulli;
pub use identity::Identity;
pub use natural::Natural;
pub use qsgd::Qsgd;
pub use randk::RandK;
pub use terngrad::TernGrad;
pub use topk::TopK;

/// A compressed vector: exact wire bits + everything needed to decode.
#[derive(Clone, Debug)]
pub struct Compressed {
    pub payload: Vec<u8>,
    /// exact encoded size in bits (before byte-alignment padding)
    pub bits: u64,
    pub dim: usize,
    codec: Codec,
}

#[derive(Clone, Debug)]
enum Codec {
    Identity,
    Natural,
    Qsgd { s: u32 },
    TernGrad,
    Bernoulli { q: f32 },
    RandK { k: usize },
    TopK { k: usize },
}

impl Compressed {
    /// Reconstruct the (randomly rounded / sparsified) vector.
    pub fn decode(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim];
        self.decode_into(&mut out);
        out
    }

    /// Decode into a caller-provided buffer (hot path: no allocation).
    pub fn decode_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.dim);
        match &self.codec {
            Codec::Identity => identity::decode(&self.payload, out),
            Codec::Natural => natural::decode(&self.payload, out),
            Codec::Qsgd { s } => qsgd::decode_with_s(&self.payload, *s, out, 1.0, false),
            Codec::TernGrad => terngrad::decode(&self.payload, out),
            Codec::Bernoulli { q } => bernoulli::decode(&self.payload, *q, out),
            Codec::RandK { k } => randk::decode(&self.payload, *k, out),
            Codec::TopK { k } => topk::decode(&self.payload, *k, out),
        }
    }

    /// Fused decode + scaled accumulate: `acc += scale · decode()`.
    /// The master's aggregation ȳ = (1/n) Σ C_i(x_i) runs on this to avoid
    /// materializing n temporary vectors (§Perf).
    pub fn decode_add(&self, acc: &mut [f32], scale: f32) {
        assert_eq!(acc.len(), self.dim);
        match &self.codec {
            Codec::Identity => identity::decode_add(&self.payload, acc, scale),
            Codec::Natural => natural::decode_add(&self.payload, acc, scale),
            Codec::Qsgd { s } => qsgd::decode_with_s(&self.payload, *s, acc, scale, true),
            Codec::TernGrad => terngrad::decode_add(&self.payload, acc, scale),
            Codec::Bernoulli { q } => bernoulli::decode_add(&self.payload, *q, acc, scale),
            Codec::RandK { k } => randk::decode_add(&self.payload, *k, acc, scale),
            Codec::TopK { k } => topk::decode_add(&self.payload, *k, acc, scale),
        }
    }

    fn new(payload: Vec<u8>, bits: u64, dim: usize, codec: Codec) -> Compressed {
        Compressed { payload, bits, dim, codec }
    }
}

/// A compression operator C : R^d → R^d (Assumption 1 interface).
pub trait Compressor: Send + Sync {
    fn name(&self) -> String;

    /// Variance bound ω (Assumption 1); `None` for biased operators.
    fn omega(&self, dim: usize) -> Option<f64>;

    fn unbiased(&self) -> bool {
        self.omega(1).is_some()
    }

    fn compress(&self, x: &[f32], rng: &mut Rng) -> Compressed;

    /// Convenience: compress→decode (what the receiving end sees).
    fn apply(&self, x: &[f32], rng: &mut Rng) -> Vec<f32> {
        self.compress(x, rng).decode()
    }
}

/// Parse a compressor spec string:
/// `identity` | `natural` | `qsgd:<s>` | `terngrad` | `bernoulli:<q>` |
/// `randk:<k>` | `topk:<k>`.
pub fn from_spec(spec: &str) -> anyhow::Result<Box<dyn Compressor>> {
    let (name, arg) = match spec.split_once(':') {
        Some((n, a)) => (n, Some(a)),
        None => (spec, None),
    };
    let need = |what: &str| {
        anyhow::anyhow!("compressor `{name}` requires `:{what}` (got `{spec}`)")
    };
    Ok(match name {
        "identity" | "none" => Box::new(Identity),
        "natural" => Box::new(Natural),
        "qsgd" => {
            let s: u32 = arg.ok_or_else(|| need("levels"))?.parse()?;
            anyhow::ensure!(s >= 1, "qsgd levels must be ≥ 1");
            Box::new(Qsgd::new(s))
        }
        "terngrad" => Box::new(TernGrad),
        "bernoulli" => {
            let q: f32 = arg.ok_or_else(|| need("prob"))?.parse()?;
            anyhow::ensure!(q > 0.0 && q <= 1.0, "bernoulli prob must be in (0,1]");
            Box::new(Bernoulli::new(q))
        }
        "randk" => {
            let k: usize = arg.ok_or_else(|| need("k"))?.parse()?;
            anyhow::ensure!(k >= 1, "randk k must be ≥ 1");
            Box::new(RandK::new(k))
        }
        "topk" => {
            let k: usize = arg.ok_or_else(|| need("k"))?.parse()?;
            anyhow::ensure!(k >= 1, "topk k must be ≥ 1");
            Box::new(TopK::new(k))
        }
        other => anyhow::bail!("unknown compressor `{other}`"),
    })
}

/// The unbiased client-side set used across the paper's DNN experiments.
pub fn paper_suite(dim: usize) -> Vec<Box<dyn Compressor>> {
    let k = (dim / 20).max(1);
    vec![
        Box::new(Natural),
        Box::new(Qsgd::new(15)),
        Box::new(TernGrad),
        Box::new(Bernoulli::new(0.1)),
        Box::new(TopK::new(k)),
    ]
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::util::stats::{l2_dist_sq, l2_norm};

    /// Monte-Carlo check of Assumption 1 on a fixed vector.
    ///
    /// Variance: `E‖C(x) − x‖² ≤ ω‖x‖²` within 5% MC slack.
    /// Unbiasedness: the MC mean satisfies `E‖mean − x‖² = Var_total/T ≤
    /// ω‖x‖²/T`, so `‖mean − x‖ ≤ 6√(ω/T)·‖x‖` is a sound aggregate bound
    /// (robust to rare-event coordinates where per-coordinate empirical
    /// CIs are meaningless).
    pub fn check_assumption1(c: &dyn Compressor, x: &[f32], trials: usize, seed: u64) {
        let d = x.len();
        let omega = c.omega(d).expect("unbiased compressor");
        let mut rng = Rng::new(seed);
        let mut mean = vec![0.0f64; d];
        let mut var_acc = 0.0f64;
        for _ in 0..trials {
            let y = c.apply(x, &mut rng);
            for i in 0..d {
                mean[i] += y[i] as f64;
            }
            var_acc += l2_dist_sq(&y, x);
        }
        let norm_sq = l2_norm(x).powi(2);
        // variance bound
        let mc_var = var_acc / trials as f64;
        assert!(
            mc_var <= omega * norm_sq * 1.05 + 1e-9,
            "{}: E‖C(x)−x‖² = {mc_var:.4} exceeds ω‖x‖² = {:.4}",
            c.name(),
            omega * norm_sq
        );
        // unbiasedness (aggregate ℓ2 bound)
        let mut dev_sq = 0.0f64;
        for i in 0..d {
            let m = mean[i] / trials as f64;
            dev_sq += (m - x[i] as f64).powi(2);
        }
        let bound = 6.0 * (omega / trials as f64).sqrt() * norm_sq.sqrt() + 1e-7;
        assert!(
            dev_sq.sqrt() <= bound,
            "{}: ‖MC-mean − x‖ = {:.5} exceeds 6σ bound {bound:.5}",
            c.name(),
            dev_sq.sqrt()
        );
    }

    pub fn test_vector(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..d)
            .map(|_| rng.normal_f32(0.0, 1.0) * 10f32.powi(rng.below(5) as i32 - 2))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parsing() {
        assert_eq!(from_spec("identity").unwrap().name(), "identity");
        assert_eq!(from_spec("natural").unwrap().name(), "natural");
        assert_eq!(from_spec("qsgd:8").unwrap().name(), "qsgd:8");
        assert_eq!(from_spec("terngrad").unwrap().name(), "terngrad");
        assert_eq!(from_spec("bernoulli:0.25").unwrap().name(), "bernoulli:0.25");
        assert_eq!(from_spec("randk:10").unwrap().name(), "randk:10");
        assert_eq!(from_spec("topk:5").unwrap().name(), "topk:5");
        assert!(from_spec("qsgd").is_err());
        assert!(from_spec("bernoulli:1.5").is_err());
        assert!(from_spec("nope").is_err());
    }

    #[test]
    fn paper_suite_covers_table1() {
        let suite = paper_suite(1000);
        let names: Vec<String> = suite.iter().map(|c| c.name()).collect();
        assert!(names.iter().any(|n| n == "natural"));
        assert!(names.iter().any(|n| n.starts_with("qsgd")));
        assert!(names.iter().any(|n| n == "terngrad"));
        assert!(names.iter().any(|n| n.starts_with("bernoulli")));
        assert!(names.iter().any(|n| n.starts_with("topk")));
        // exactly one biased operator in the suite (Top-k)
        assert_eq!(suite.iter().filter(|c| !c.unbiased()).count(), 1);
    }
}
