//! Bidirectional communication compression (the paper's §IV), organized as
//! a **composable pipeline API**.
//!
//! Three layers:
//!
//! * [`Codec`] — a stateless wire operator: `encode_into` writes the exact
//!   bits that cross the network, `decode_into` / `decode_add` read them
//!   back. Every operator from Table I implements it, and *pipelines are
//!   codecs too*: `randk:50>qsgd:8` chains sparsification into quantization
//!   of the survivors, with the composed variance factor
//!   ω = (1+ω₁)(1+ω₂) − 1 for unbiased stages ([`compose_omega`]).
//! * [`Compressor`] — a shareable descriptor produced by [`from_spec`].
//!   `instantiate(dim, seed)` yields a per-client…
//! * [`CompressorState`] — …stateful instance owning its RNG stream and any
//!   cross-round memory. `compress_into` reuses the output buffers, so the
//!   round-loop wire path performs no steady-state heap allocation.
//!   Error feedback (`ef(<spec>)`, the paper's §VII-B memory mechanism) is
//!   a stateful wrapper at this layer.
//!
//! Operators live in an **open registry** ([`register_codec`]): spec
//! parsing, [`paper_suite`] and the Table-I harness are table-driven, so a
//! new operator plugs in without touching this module.
//!
//! Unbiased operators satisfy Assumption 1: `E[C(x)] = x` and
//! `E‖C(x) − x‖² ≤ ω‖x‖²`; `omega(d)` returns the constant the theory
//! module (§V–§VI) consumes. Biased operators (Top-k, `ef(...)`) return
//! `None` and the theory layer refuses them.

pub mod bernoulli;
pub mod ef;
pub mod identity;
pub mod natural;
pub mod pipeline;
pub mod qsgd;
pub mod randk;
pub mod registry;
mod scratch;
pub mod terngrad;
pub mod topk;

use std::sync::Arc;

use crate::util::{BitReader, BitWriter, Rng};

pub use bernoulli::Bernoulli;
pub use ef::ErrorFeedback;
pub use identity::Identity;
pub use natural::Natural;
pub use pipeline::{DenseStage, Pipeline};
pub use qsgd::Qsgd;
pub use randk::RandK;
pub use registry::{codec_from_spec, register_codec, registered_names};
pub use terngrad::TernGrad;
pub use topk::TopK;

/// A wire operator C : R^d → R^d with a self-describing bit format.
///
/// `encode_into`/`decode_into` stream through caller-provided bit I/O so
/// operators nest: a selector (rand-k, top-k, Bernoulli) writes its
/// structure and hands the survivor values to an inner codec in the same
/// bitstream. Implementations must read exactly the bits they wrote.
pub trait Codec: Send + Sync {
    /// Canonical spec string (`qsgd:8`, `randk:50>qsgd:8`, …).
    fn name(&self) -> String;

    /// Variance bound ω at dimension `dim` (Assumption 1);
    /// `None` for biased operators.
    fn omega(&self, dim: usize) -> Option<f64>;

    /// Encode `x`, drawing randomness from `rng`. Fails (rather than
    /// truncating or panicking) on inputs the operator cannot represent,
    /// e.g. `randk:k` with `k > x.len()`.
    fn encode_into(&self, x: &[f32], w: &mut BitWriter, rng: &mut Rng)
                   -> anyhow::Result<()>;

    /// Decode into `out` (overwriting), consuming this codec's bits.
    fn decode_into(&self, r: &mut BitReader, out: &mut [f32]);

    /// Fused decode + scaled accumulate: `acc += scale · decode()`.
    fn decode_add(&self, r: &mut BitReader, acc: &mut [f32], scale: f32);

    /// Apply compress→decompress in place (what the receiving end sees),
    /// without materializing a `Compressed`. Used by dense chaining and
    /// the Assumption-1 test harness.
    fn apply_into(&self, x: &[f32], out: &mut [f32], rng: &mut Rng)
                  -> anyhow::Result<()> {
        debug_assert_eq!(x.len(), out.len());
        scratch::with_bytes(|bytes| {
            let mut w = BitWriter::reuse(std::mem::take(bytes));
            let res = self.encode_into(x, &mut w, rng);
            *bytes = w.finish();
            res?;
            let mut r = BitReader::new(bytes);
            self.decode_into(&mut r, out);
            Ok(())
        })
    }

    /// Allocating convenience for tests and one-off analysis.
    fn apply(&self, x: &[f32], rng: &mut Rng) -> anyhow::Result<Vec<f32>> {
        let mut out = vec![0.0f32; x.len()];
        self.apply_into(x, &mut out, rng)?;
        Ok(out)
    }
}

/// ω of a two-stage unbiased chain: (1+ω₁)(1+ω₂) − 1.
///
/// For independent unbiased stages, E‖C₂(C₁(x)) − x‖² telescopes:
/// ω₂·E‖C₁(x)‖² + ω₁‖x‖² ≤ (ω₂(1+ω₁) + ω₁)‖x‖². A biased stage (`None`)
/// poisons the chain — the composed operator has no Assumption-1 constant.
pub fn compose_omega(first: Option<f64>, second: Option<f64>) -> Option<f64> {
    match (first, second) {
        (Some(a), Some(b)) => Some((1.0 + a) * (1.0 + b) - 1.0),
        _ => None,
    }
}

/// A compressed vector: exact wire bits + the codec that can decode them.
pub struct Compressed {
    pub payload: Vec<u8>,
    /// exact encoded size in bits (before byte-alignment padding)
    pub bits: u64,
    pub dim: usize,
    codec: Arc<dyn Codec>,
}

impl Clone for Compressed {
    fn clone(&self) -> Compressed {
        Compressed {
            payload: self.payload.clone(),
            bits: self.bits,
            dim: self.dim,
            codec: Arc::clone(&self.codec),
        }
    }
}

impl std::fmt::Debug for Compressed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Compressed")
            .field("codec", &self.codec.name())
            .field("bits", &self.bits)
            .field("dim", &self.dim)
            .finish()
    }
}

impl Compressed {
    /// An empty buffer to be filled by [`CompressorState::compress_into`];
    /// reusing one across rounds keeps the wire path allocation-free.
    pub fn empty() -> Compressed {
        Compressed { payload: Vec::new(), bits: 0, dim: 0, codec: Arc::new(Identity) }
    }

    /// Spec string of the codec that produced this payload.
    pub fn codec_name(&self) -> String {
        self.codec.name()
    }

    /// Reconstruct the (randomly rounded / sparsified) vector.
    pub fn decode(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim];
        self.decode_into(&mut out);
        out
    }

    /// Decode into a caller-provided buffer (hot path: no allocation).
    pub fn decode_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.dim);
        let mut r = BitReader::new(&self.payload);
        self.codec.decode_into(&mut r, out);
    }

    /// Fused decode + scaled accumulate: `acc += scale · decode()`.
    /// The master's aggregation ȳ = (1/n) Σ C_i(x_i) runs on this to avoid
    /// materializing n temporary vectors (§Perf).
    pub fn decode_add(&self, acc: &mut [f32], scale: f32) {
        assert_eq!(acc.len(), self.dim);
        let mut r = BitReader::new(&self.payload);
        self.codec.decode_add(&mut r, acc, scale);
    }

    pub(crate) fn set_codec(&mut self, codec: Arc<dyn Codec>) {
        self.codec = codec;
    }
}

/// Shareable compression descriptor (Assumption 1 interface).
///
/// One descriptor serves any number of clients; each client gets its own
/// [`CompressorState`] via `instantiate`, which owns the RNG stream and any
/// cross-round memory (error-feedback residuals).
pub trait Compressor: Send + Sync {
    fn name(&self) -> String;

    /// Variance bound ω (Assumption 1); `None` for biased operators.
    fn omega(&self, dim: usize) -> Option<f64>;

    fn unbiased(&self) -> bool {
        self.omega(2).is_some()
    }

    /// Build a per-client instance for `dim`-dimensional vectors, seeded
    /// deterministically (same seed ⇒ bit-identical wire stream).
    fn instantiate(&self, dim: usize, seed: u64) -> Box<dyn CompressorState>;
}

/// Per-client stateful compression instance.
///
/// `Sync` is a supertrait so shared slices of slot structs embedding a
/// `Box<dyn CompressorState>` can cross into the pool's `Fn + Sync`
/// closures (the master's tree reduction reads `&[ClientSlot]`); all
/// mutation goes through `&mut self`, so the bound costs implementations
/// nothing beyond Sync-able fields.
pub trait CompressorState: Send + Sync {
    /// Encode `x` into `out`, reusing its buffers (the zero-alloc wire
    /// path: steady state performs no heap allocation). On error `out` is
    /// left in an unspecified-but-valid state.
    fn compress_into(&mut self, x: &[f32], out: &mut Compressed) -> anyhow::Result<()>;

    /// Allocating convenience.
    fn compress(&mut self, x: &[f32]) -> anyhow::Result<Compressed> {
        let mut out = Compressed::empty();
        self.compress_into(x, &mut out)?;
        Ok(out)
    }
}

/// Parse a compressor spec into a shareable descriptor.
///
/// Grammar:
///   spec  := "ef(" spec ")" | chain
///   chain := atom (">" atom)*
///   atom  := name [":" arg]
///
/// `a>b` feeds a's output into b left-to-right; selector stages (rand-k,
/// top-k, Bernoulli) hand only their *survivors* to the next stage, so
/// `randk:50>qsgd:8` quantizes 50 values, not d. `ef(...)` wraps the whole
/// spec in stateful error feedback (residual carried across rounds).
/// Registered names: see [`registered_names`] / `pfl compressors`.
pub fn from_spec(spec: &str) -> anyhow::Result<Arc<dyn Compressor>> {
    Ok(parse_spec_at(spec, 0..spec.len())?)
}

/// [`from_spec`] for a spec living at `span` inside `src`: errors are
/// span-pointing [`crate::sim::lang::SpecError`]s against the whole
/// source string (the scenario parser's `codec=` key hands in the full
/// scenario spec so the caret lands inside the original text).
pub fn parse_spec_at(
    src: &str,
    span: std::ops::Range<usize>,
) -> Result<Arc<dyn Compressor>, crate::sim::lang::SpecError> {
    use crate::sim::lang::SpecError;
    let raw = &src[span.clone()];
    let lo = span.start + (raw.len() - raw.trim_start().len());
    let hi = span.start + raw.trim_end().len();
    let s = &src[lo..hi.max(lo)];
    if let Some(body) = s.strip_prefix("ef(") {
        if body.strip_suffix(')').is_some() {
            // recurse on the parenthesized interior (nested `ef` allowed)
            let inner = parse_spec_at(src, lo + 3..hi - 1)?;
            return Ok(Arc::new(ErrorFeedback::new(inner)));
        }
        return Err(SpecError::new(
            src,
            lo..hi.max(lo),
            format!("`ef(...)` must wrap the entire spec (got `{s}`)"),
        )
        .with_help("missing the closing `)`"));
    }
    Ok(Arc::new(Pipeline::new(registry::codec_from_spec_at(
        src,
        lo..hi.max(lo),
    )?)))
}

/// Validate the codec spec at `span` inside `src` without keeping the
/// built compressor — the scenario parser's eager `codec=` check.
pub fn validate_spec_at(
    src: &str,
    span: std::ops::Range<usize>,
) -> Result<(), crate::sim::lang::SpecError> {
    parse_spec_at(src, span).map(|_| ())
}

/// The unbiased client-side set used across the paper's DNN experiments —
/// table-driven off the registry like everything else.
pub fn paper_suite(dim: usize) -> Vec<Arc<dyn Compressor>> {
    let k = (dim / 20).max(1);
    let specs = [
        "natural".to_string(),
        "qsgd:15".to_string(),
        "terngrad".to_string(),
        "bernoulli:0.1".to_string(),
        format!("topk:{k}"),
    ];
    specs.iter().map(|s| from_spec(s).expect("builtin spec")).collect()
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::util::stats::{l2_dist_sq, l2_norm};

    /// Compress `x` through the full spec path with a fresh stream seeded
    /// at `seed` — consumes randomness exactly like the pre-registry
    /// implementation's `compress(&x, &mut Rng::new(seed))`, which the
    /// wire-stability tests rely on.
    pub fn compress(spec: &str, x: &[f32], seed: u64) -> Compressed {
        let comp = from_spec(spec).expect("spec parses");
        let mut st = comp.instantiate(x.len(), seed);
        st.compress(x).expect("compress succeeds")
    }

    /// Monte-Carlo check of Assumption 1 on a fixed vector.
    ///
    /// Variance: `E‖C(x) − x‖² ≤ ω‖x‖²` within 5% MC slack.
    /// Unbiasedness: the MC mean satisfies `E‖mean − x‖² = Var_total/T ≤
    /// ω‖x‖²/T`, so `‖mean − x‖ ≤ 6√(ω/T)·‖x‖` is a sound aggregate bound
    /// (robust to rare-event coordinates where per-coordinate empirical
    /// CIs are meaningless).
    pub fn check_assumption1(c: &dyn Codec, x: &[f32], trials: usize, seed: u64) {
        let d = x.len();
        let omega = c.omega(d).expect("unbiased compressor");
        let mut rng = Rng::new(seed);
        let mut mean = vec![0.0f64; d];
        let mut var_acc = 0.0f64;
        for _ in 0..trials {
            let y = c.apply(x, &mut rng).expect("apply succeeds");
            for i in 0..d {
                mean[i] += y[i] as f64;
            }
            var_acc += l2_dist_sq(&y, x);
        }
        let norm_sq = l2_norm(x).powi(2);
        // variance bound
        let mc_var = var_acc / trials as f64;
        assert!(
            mc_var <= omega * norm_sq * 1.05 + 1e-9,
            "{}: E‖C(x)−x‖² = {mc_var:.4} exceeds ω‖x‖² = {:.4}",
            c.name(),
            omega * norm_sq
        );
        // unbiasedness (aggregate ℓ2 bound)
        let mut dev_sq = 0.0f64;
        for i in 0..d {
            let m = mean[i] / trials as f64;
            dev_sq += (m - x[i] as f64).powi(2);
        }
        let bound = 6.0 * (omega / trials as f64).sqrt() * norm_sq.sqrt() + 1e-7;
        assert!(
            dev_sq.sqrt() <= bound,
            "{}: ‖MC-mean − x‖ = {:.5} exceeds 6σ bound {bound:.5}",
            c.name(),
            dev_sq.sqrt()
        );
    }

    pub fn test_vector(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..d)
            .map(|_| rng.normal_f32(0.0, 1.0) * 10f32.powi(rng.below(5) as i32 - 2))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parsing() {
        assert_eq!(from_spec("identity").unwrap().name(), "identity");
        assert_eq!(from_spec("none").unwrap().name(), "identity");
        assert_eq!(from_spec("natural").unwrap().name(), "natural");
        assert_eq!(from_spec("qsgd:8").unwrap().name(), "qsgd:8");
        assert_eq!(from_spec("terngrad").unwrap().name(), "terngrad");
        assert_eq!(from_spec("bernoulli:0.25").unwrap().name(), "bernoulli:0.25");
        assert_eq!(from_spec("randk:10").unwrap().name(), "randk:10");
        assert_eq!(from_spec("topk:5").unwrap().name(), "topk:5");
        assert!(from_spec("qsgd").is_err());
        assert!(from_spec("bernoulli:1.5").is_err());
        assert!(from_spec("nope").is_err());
    }

    #[test]
    fn pipeline_spec_parsing() {
        assert_eq!(from_spec("randk:50>qsgd:8").unwrap().name(), "randk:50>qsgd:8");
        assert_eq!(from_spec("bernoulli:0.2>natural").unwrap().name(),
                   "bernoulli:0.2>natural");
        assert_eq!(from_spec("topk:10>natural").unwrap().name(), "topk:10>natural");
        // dense chaining of two quantizers parses too
        assert_eq!(from_spec("natural>qsgd:4").unwrap().name(), "natural>qsgd:4");
        // three stages: selector survivors flow through the rest
        assert_eq!(from_spec("randk:20>qsgd:8>natural").unwrap().name(),
                   "randk:20>qsgd:8>natural");
        assert!(from_spec("randk:10>").is_err(), "trailing stage");
        assert!(from_spec(">qsgd:8").is_err(), "leading stage");
    }

    #[test]
    fn ef_spec_parsing() {
        assert_eq!(from_spec("ef(topk:10)").unwrap().name(), "ef(topk:10)");
        assert_eq!(from_spec("ef(randk:50>qsgd:8)").unwrap().name(),
                   "ef(randk:50>qsgd:8)");
        assert_eq!(from_spec("ef(ef(topk:3))").unwrap().name(), "ef(ef(topk:3))");
        assert!(from_spec("ef(topk:10").is_err(), "unclosed ef");
        assert!(from_spec("ef(topk:5)>natural").is_err(),
                "ef must wrap the whole spec");
        // ef is always biased: no Assumption-1 constant
        assert!(from_spec("ef(natural)").unwrap().omega(100).is_none());
    }

    #[test]
    fn unknown_codec_error_lists_registered_names() {
        let err = format!("{:#}", from_spec("zstd").unwrap_err());
        assert!(err.contains("unknown compressor `zstd`"), "{err}");
        for name in ["bernoulli", "identity", "natural", "qsgd", "randk",
                     "terngrad", "topk"] {
            assert!(err.contains(name), "error should list `{name}`: {err}");
        }
    }

    #[test]
    fn composed_omega_formula() {
        assert_eq!(compose_omega(Some(1.0), Some(0.125)), Some(1.25));
        assert_eq!(compose_omega(Some(0.0), Some(0.5)), Some(0.5));
        assert_eq!(compose_omega(None, Some(0.5)), None);
        assert_eq!(compose_omega(Some(0.5), None), None);
        // spec-level: randk:50 over d=1000 (ω=19) into qsgd:8 over 50
        // survivors (ω = min(50/64, √50/8))
        let chain = from_spec("randk:50>qsgd:8").unwrap();
        let w1 = 1000.0 / 50.0 - 1.0;
        let w2 = (50.0f64 / 64.0).min(50.0f64.sqrt() / 8.0);
        let expect = (1.0 + w1) * (1.0 + w2) - 1.0;
        assert!((chain.omega(1000).unwrap() - expect).abs() < 1e-12);
        // a biased stage poisons the chain
        assert!(from_spec("topk:10>natural").unwrap().omega(1000).is_none());
    }

    #[test]
    fn composed_chain_satisfies_assumption1_randk_qsgd() {
        let x = testutil::test_vector(100, 3);
        let c = codec_from_spec("randk:50>qsgd:8").unwrap();
        testutil::check_assumption1(c.as_ref(), &x, 1200, 7);
    }

    #[test]
    fn composed_chain_satisfies_assumption1_bernoulli_natural() {
        let x = testutil::test_vector(80, 5);
        let c = codec_from_spec("bernoulli:0.2>natural").unwrap();
        testutil::check_assumption1(c.as_ref(), &x, 1500, 11);
    }

    #[test]
    fn composed_chain_satisfies_assumption1_dense_pair() {
        // quantizer→quantizer exercises the dense-composition fallback
        let x = testutil::test_vector(64, 9);
        let c = codec_from_spec("natural>qsgd:4").unwrap();
        testutil::check_assumption1(c.as_ref(), &x, 1200, 13);
    }

    #[test]
    fn chained_wire_is_smaller_than_raw_survivors() {
        // randk:50>qsgd:8 sends 50 quantized survivors, far below the
        // 64 + 32·50 bits of plain randk:50
        let x = testutil::test_vector(1000, 1);
        let c = testutil::compress("randk:50>qsgd:8", &x, 2);
        let raw = testutil::compress("randk:50", &x, 2);
        assert_eq!(raw.bits, 64 + 32 * 50);
        assert!(c.bits < raw.bits / 2, "chained bits = {}", c.bits);
        assert!(c.bits > 64 + 32, "chained bits = {}", c.bits);
    }

    #[test]
    fn decode_add_matches_decode_plus_axpy_for_every_registered_codec() {
        // registry-driven property test: every entry's example spec must
        // satisfy decode_add(acc, s) == decode() scaled-added into acc
        for (name, example) in registry::examples() {
            let x = testutil::test_vector(200, 17);
            let c = testutil::compress(&example, &x, 23);
            let y = c.decode();
            let mut acc = vec![0.75f32; 200];
            c.decode_add(&mut acc, -1.5);
            for i in 0..200 {
                let expect = 0.75 - 1.5 * y[i];
                assert!(
                    (acc[i] - expect).abs() <= 1e-4 * (1.0 + y[i].abs()),
                    "{name} ({example}): acc[{i}] = {} vs {expect}",
                    acc[i]
                );
            }
        }
    }

    #[test]
    fn paper_suite_covers_table1() {
        let suite = paper_suite(1000);
        let names: Vec<String> = suite.iter().map(|c| c.name()).collect();
        assert!(names.iter().any(|n| n == "natural"));
        assert!(names.iter().any(|n| n.starts_with("qsgd")));
        assert!(names.iter().any(|n| n == "terngrad"));
        assert!(names.iter().any(|n| n.starts_with("bernoulli")));
        assert!(names.iter().any(|n| n.starts_with("topk")));
        // exactly one biased operator in the suite (Top-k)
        assert_eq!(suite.iter().filter(|c| !c.unbiased()).count(), 1);
    }

    #[test]
    fn compress_into_reuses_buffers() {
        let x = testutil::test_vector(500, 2);
        let comp = from_spec("natural").unwrap();
        let mut st = comp.instantiate(500, 4);
        let mut buf = Compressed::empty();
        st.compress_into(&x, &mut buf).unwrap();
        let cap = buf.payload.capacity();
        let ptr = buf.payload.as_ptr();
        for _ in 0..10 {
            st.compress_into(&x, &mut buf).unwrap();
            assert_eq!(buf.payload.capacity(), cap, "payload capacity changed");
            assert_eq!(buf.payload.as_ptr(), ptr, "payload storage moved");
            assert_eq!(buf.bits, 9 * 500);
        }
    }

    #[test]
    fn open_registry_accepts_custom_codec() {
        use std::sync::Arc;

        /// Toy codec: raw f32 passthrough under a custom name.
        struct Passthru;
        impl Codec for Passthru {
            fn name(&self) -> String {
                "passthru".into()
            }
            fn omega(&self, _dim: usize) -> Option<f64> {
                Some(0.0)
            }
            fn encode_into(&self, x: &[f32], w: &mut BitWriter, _rng: &mut Rng)
                           -> anyhow::Result<()> {
                for &v in x {
                    w.put_f32(v);
                }
                Ok(())
            }
            fn decode_into(&self, r: &mut BitReader, out: &mut [f32]) {
                for o in out.iter_mut() {
                    *o = r.get_f32();
                }
            }
            fn decode_add(&self, r: &mut BitReader, acc: &mut [f32], scale: f32) {
                for a in acc.iter_mut() {
                    *a += scale * r.get_f32();
                }
            }
        }

        register_codec("passthru", "passthru", "passthru", Box::new(|_arg, inner| {
            Ok(registry::dense_chain(Arc::new(Passthru), inner))
        }));
        // parses standalone, in chains, and under ef — no core edits needed
        let x = testutil::test_vector(50, 1);
        let c = testutil::compress("passthru", &x, 0);
        assert_eq!(c.bits, 32 * 50);
        assert_eq!(c.decode(), x);
        assert_eq!(from_spec("randk:10>passthru").unwrap().name(),
                   "randk:10>passthru");
        assert!(from_spec("ef(passthru)").is_ok());
        assert!(registered_names().contains(&"passthru".to_string()));
    }
}
