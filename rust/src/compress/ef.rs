//! Error feedback (`ef(<spec>)`): the stateful residual-correction wrapper
//! (Seide et al. 2014; the mechanism behind the paper's §VII-B
//! difference-compressed FedAvg, here available to any algorithm).
//!
//! Per round, with residual e carried across rounds (e⁰ = 0):
//!
//!   u = x + e,   wire = C(u),   e ← u − C(u)
//!
//! The transmitted operator is biased (omega = `None` — the theory layer
//! refuses it), but the residual re-injects every round's compression error
//! into the next round, so the *time-averaged* decoded signal tracks x with
//! O(1/T) error even under aggressive biased inner codecs like top-k.
//!
//! The wrapper adds zero wire bits: the payload is exactly the inner
//! codec's encoding of the shifted vector.

use std::sync::Arc;

use super::{Compressed, Compressor, CompressorState};

pub struct ErrorFeedback {
    inner: Arc<dyn Compressor>,
}

impl ErrorFeedback {
    pub fn new(inner: Arc<dyn Compressor>) -> ErrorFeedback {
        ErrorFeedback { inner }
    }
}

impl Compressor for ErrorFeedback {
    fn name(&self) -> String {
        format!("ef({})", self.inner.name())
    }

    /// Always `None`: error feedback is a memory operator, not an unbiased
    /// compressor — Assumption 1 does not apply (even for unbiased inners,
    /// the residual correlates consecutive rounds).
    fn omega(&self, _dim: usize) -> Option<f64> {
        None
    }

    fn instantiate(&self, dim: usize, seed: u64) -> Box<dyn CompressorState> {
        Box::new(EfState {
            inner: self.inner.instantiate(dim, seed),
            residual: vec![0.0; dim],
            shifted: vec![0.0; dim],
        })
    }
}

struct EfState {
    inner: Box<dyn CompressorState>,
    /// e: accumulated compression error, fed back into the next round
    residual: Vec<f32>,
    /// scratch for u = x + e (owned: the wire path stays allocation-free)
    shifted: Vec<f32>,
}

impl CompressorState for EfState {
    fn compress_into(&mut self, x: &[f32], out: &mut Compressed) -> anyhow::Result<()> {
        anyhow::ensure!(
            x.len() == self.residual.len(),
            "ef instantiated for dim {} but got a {}-dim vector",
            self.residual.len(),
            x.len()
        );
        for ((u, &xi), &e) in self.shifted.iter_mut().zip(x).zip(&self.residual) {
            *u = xi + e;
        }
        self.inner.compress_into(&self.shifted, out)?;
        // e ← u − C(u), via the fused decode path
        self.residual.copy_from_slice(&self.shifted);
        out.decode_add(&mut self.residual, -1.0);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{from_spec, testutil, Compressed};

    #[test]
    fn wire_bits_match_inner_codec() {
        let x = testutil::test_vector(200, 1);
        let c = testutil::compress("ef(natural)", &x, 3);
        assert_eq!(c.bits, 9 * 200);
        let c = testutil::compress("ef(topk:20)", &x, 3);
        assert_eq!(c.bits, 20 * (8 + 32)); // ⌈log₂200⌉ = 8 index bits
    }

    #[test]
    fn residual_makes_time_average_track_x() {
        // Compress the SAME x repeatedly through ef(topk:10): top-k alone
        // would never transmit the small coordinates; with the residual,
        // (1/T)Σ_t C(u_t) = x − (e_T − e_0)/T, so the running mean
        // converges at rate ‖e‖/T.
        let d = 50;
        let x = testutil::test_vector(d, 7);
        let comp = from_spec("ef(topk:10)").unwrap();
        let mut st = comp.instantiate(d, 11);
        let t = 200;
        let mut sum = vec![0.0f32; d];
        let mut buf = Compressed::empty();
        for _ in 0..t {
            st.compress_into(&x, &mut buf).unwrap();
            buf.decode_add(&mut sum, 1.0);
        }
        let norm: f64 = x.iter().map(|&v| (v as f64).powi(2)).sum::<f64>().sqrt();
        let err: f64 = x
            .iter()
            .zip(&sum)
            .map(|(&xi, &s)| ((s / t as f32 - xi) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(err <= 0.1 * norm, "mean error {err:.4} vs ‖x‖ = {norm:.4}");
    }

    #[test]
    fn plain_topk_does_not_track_but_ef_does() {
        // control for the test above: without the residual the small
        // coordinates are lost forever
        let d = 50;
        let x = testutil::test_vector(d, 7);
        let y = testutil::compress("topk:10", &x, 11).decode();
        let dropped = x.iter().zip(&y).filter(|(_, &yi)| yi == 0.0).count();
        assert!(dropped >= d - 10);
    }

    #[test]
    fn dim_mismatch_is_a_clean_error() {
        let comp = from_spec("ef(natural)").unwrap();
        let mut st = comp.instantiate(10, 0);
        let x = vec![1.0f32; 20];
        let err = st.compress(&x).unwrap_err();
        assert!(format!("{err}").contains("dim 10"), "{err}");
    }

    #[test]
    fn ef_of_unbiased_first_round_matches_inner() {
        // e⁰ = 0 ⇒ the first compression is exactly the inner codec's
        let x = testutil::test_vector(100, 2);
        let a = testutil::compress("ef(natural)", &x, 9);
        let b = testutil::compress("natural", &x, 9);
        assert_eq!(a.payload, b.payload);
        assert_eq!(a.bits, b.bits);
    }
}
