//! TernGrad (Wen et al. 2017): ternary quantization against the ℓ∞ norm.
//!
//! C(x)_i = m · sign(x_i) · B_i with m = ‖x‖∞ and B_i ~ Bernoulli(|x_i|/m).
//! Unbiased. Variance: E‖C−x‖² = Σ_i |x_i|(m − |x_i|) ≤ m‖x‖₁ − ‖x‖² ≤
//! (√d − 1)‖x‖², so ω = √d − 1 is the Assumption-1 constant we expose
//! (tight when mass concentrates on one coordinate).
//!
//! Wire format: 32-bit scale header + 2 bits/coordinate
//! (00 = 0, 01 = +m, 10 = −m).

use std::sync::Arc;

use super::registry::{dense_chain, Registry};
use super::Codec;
use crate::util::{BitReader, BitWriter, Rng};

pub struct TernGrad;

impl Codec for TernGrad {
    fn name(&self) -> String {
        "terngrad".into()
    }

    fn omega(&self, dim: usize) -> Option<f64> {
        Some(((dim as f64).sqrt() - 1.0).max(0.0))
    }

    fn encode_into(&self, x: &[f32], w: &mut BitWriter, rng: &mut Rng)
                   -> anyhow::Result<()> {
        let m = x.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        w.put_f32(m);
        if m > 0.0 {
            for &v in x {
                let keep = rng.f32() < v.abs() / m;
                let code = if !keep || v == 0.0 {
                    0u64
                } else if v > 0.0 {
                    1
                } else {
                    2
                };
                w.put(code, 2);
            }
        }
        Ok(())
    }

    fn decode_into(&self, r: &mut BitReader, out: &mut [f32]) {
        let m = r.get_f32();
        if m <= 0.0 {
            out.fill(0.0);
            return;
        }
        for o in out.iter_mut() {
            *o = match r.get(2) {
                1 => m,
                2 => -m,
                _ => 0.0,
            };
        }
    }

    fn decode_add(&self, r: &mut BitReader, acc: &mut [f32], scale: f32) {
        let m = r.get_f32();
        if m <= 0.0 {
            return;
        }
        let pm = scale * m;
        for a in acc.iter_mut() {
            match r.get(2) {
                1 => *a += pm,
                2 => *a -= pm,
                _ => {}
            }
        }
    }
}

pub(super) fn register(r: &mut Registry) {
    r.add("terngrad", "terngrad (ternary vs ℓ∞, 2 bits/coord, ω = √d − 1)",
          "terngrad",
          Box::new(|_arg, inner| Ok(dense_chain(Arc::new(TernGrad), inner))));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::testutil;

    #[test]
    fn wire_is_2_bits_per_coordinate_plus_header() {
        let x = testutil::test_vector(1000, 1);
        let c = testutil::compress("terngrad", &x, 0);
        assert_eq!(c.bits, 32 + 2 * 1000);
    }

    #[test]
    fn outputs_are_ternary() {
        let x = testutil::test_vector(500, 2);
        let m = x.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        let y = TernGrad.apply(&x, &mut Rng::new(1)).unwrap();
        for v in &y {
            assert!(*v == 0.0 || (v.abs() - m).abs() < 1e-6, "{v} vs m={m}");
        }
    }

    #[test]
    fn max_coordinate_always_survives() {
        // |x_i| = m ⇒ keep-probability 1
        let x = vec![0.1f32, -5.0, 0.2];
        for seed in 0..20 {
            let y = TernGrad.apply(&x, &mut Rng::new(seed)).unwrap();
            assert_eq!(y[1], -5.0);
        }
    }

    #[test]
    fn assumption1_holds() {
        let x = testutil::test_vector(64, 3);
        testutil::check_assumption1(&TernGrad, &x, 1000, 17);
    }

    #[test]
    fn zero_vector() {
        let x = vec![0.0f32; 10];
        let c = testutil::compress("terngrad", &x, 0);
        assert_eq!(c.bits, 32);
        assert_eq!(c.decode(), x);
    }

    #[test]
    fn decode_add_matches_decode() {
        let x = testutil::test_vector(100, 4);
        let c = testutil::compress("terngrad", &x, 5);
        let y = c.decode();
        let mut acc = vec![1.0f32; 100];
        c.decode_add(&mut acc, 3.0);
        for i in 0..100 {
            assert!((acc[i] - (1.0 + 3.0 * y[i])).abs() < 1e-5);
        }
    }
}
