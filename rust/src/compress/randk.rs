//! Rand-k sparsifier: keep k uniformly chosen coordinates scaled by d/k.
//! Unbiased with ω = d/k − 1 — the textbook unbiased sparsifier, included
//! as the unbiased counterpart to Top-k.
//!
//! Wire format: 64-bit selection seed + k raw f32 values; the receiver
//! regenerates the index set from the seed (shared RNG), so indices cost
//! 64 bits total instead of k·log₂d.

use super::{Codec, Compressed, Compressor};
use crate::util::{BitReader, BitWriter, Rng};

pub struct RandK {
    k: usize,
}

impl RandK {
    pub fn new(k: usize) -> RandK {
        assert!(k >= 1);
        RandK { k }
    }
}

impl Compressor for RandK {
    fn name(&self) -> String {
        format!("randk:{}", self.k)
    }

    fn omega(&self, dim: usize) -> Option<f64> {
        let k = self.k.min(dim) as f64;
        Some(dim as f64 / k - 1.0)
    }

    fn compress(&self, x: &[f32], rng: &mut Rng) -> Compressed {
        let k = self.k.min(x.len());
        let seed = rng.next_u64();
        let idx = Rng::new(seed).sample_indices(x.len(), k);
        let mut w = BitWriter::with_capacity(8 + 4 * k);
        w.put(seed & ((1 << 53) - 1), 53);
        w.put(seed >> 53, 11);
        for &i in &idx {
            w.put_f32(x[i]);
        }
        let bits = w.bit_len();
        Compressed::new(w.finish(), bits, x.len(), Codec::RandK { k })
    }
}

pub(super) fn decode(payload: &[u8], k: usize, out: &mut [f32]) {
    out.fill(0.0);
    decode_add(payload, k, out, 1.0);
}

pub(super) fn decode_add(payload: &[u8], k: usize, acc: &mut [f32], scale: f32) {
    let mut r = BitReader::new(payload);
    let seed = r.get(53) | (r.get(11) << 53);
    let d = acc.len();
    let k = k.min(d);
    let idx = Rng::new(seed).sample_indices(d, k);
    let rescale = scale * d as f32 / k as f32;
    for &i in &idx {
        acc[i] += rescale * r.get_f32();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::testutil;

    #[test]
    fn exactly_k_nonzeros_scaled() {
        let x = testutil::test_vector(200, 1);
        let rk = RandK::new(20);
        let y = rk.apply(&x, &mut Rng::new(2));
        let nz: Vec<usize> = (0..200).filter(|&i| y[i] != 0.0).collect();
        assert!(nz.len() <= 20); // (could collide with a genuine 0 in x)
        for &i in &nz {
            assert!((y[i] - x[i] * 10.0).abs() < 1e-4);
        }
    }

    #[test]
    fn wire_is_seed_plus_k_floats() {
        let x = testutil::test_vector(1000, 3);
        let c = RandK::new(50).compress(&x, &mut Rng::new(4));
        assert_eq!(c.bits, 64 + 32 * 50);
    }

    #[test]
    fn assumption1_holds() {
        let x = testutil::test_vector(60, 5);
        testutil::check_assumption1(&RandK::new(15), &x, 1500, 21);
    }

    #[test]
    fn k_geq_d_is_identity() {
        let x = testutil::test_vector(10, 7);
        let y = RandK::new(100).apply(&x, &mut Rng::new(8));
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn omega_formula() {
        assert_eq!(RandK::new(10).omega(100).unwrap(), 9.0);
        assert_eq!(RandK::new(100).omega(100).unwrap(), 0.0);
    }
}
