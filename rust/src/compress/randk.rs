//! Rand-k sparsifier: keep k uniformly chosen coordinates scaled by d/k.
//! Unbiased with ω = d/k − 1 — the textbook unbiased sparsifier, included
//! as the unbiased counterpart to Top-k.
//!
//! Wire format: 64-bit selection seed + the k survivor values; the receiver
//! regenerates the index set from the seed (shared RNG), so indices cost
//! 64 bits total instead of k·log₂d. Standalone the survivors are raw f32;
//! in a pipeline (`randk:50>qsgd:8`) they are handed to the inner codec —
//! quantization of the survivors, at survivor dimension k.

use std::sync::Arc;

use super::registry::Registry;
use super::{compose_omega, scratch, Codec};
use crate::util::{BitReader, BitWriter, Rng};

pub struct RandK {
    k: usize,
    /// survivor codec for pipeline specs; `None` = raw f32 (legacy wire)
    inner: Option<Arc<dyn Codec>>,
}

impl RandK {
    pub fn new(k: usize) -> RandK {
        Self::chained(k, None)
    }

    pub fn chained(k: usize, inner: Option<Arc<dyn Codec>>) -> RandK {
        assert!(k >= 1);
        RandK { k, inner }
    }
}

impl Codec for RandK {
    fn name(&self) -> String {
        match &self.inner {
            None => format!("randk:{}", self.k),
            Some(i) => format!("randk:{}>{}", self.k, i.name()),
        }
    }

    fn omega(&self, dim: usize) -> Option<f64> {
        let k = self.k.min(dim);
        let sel = dim as f64 / k as f64 - 1.0;
        match &self.inner {
            None => Some(sel),
            // the inner codec sees the k-dimensional survivor vector
            Some(i) => compose_omega(Some(sel), i.omega(k)),
        }
    }

    fn encode_into(&self, x: &[f32], w: &mut BitWriter, rng: &mut Rng)
                   -> anyhow::Result<()> {
        anyhow::ensure!(
            self.k <= x.len(),
            "randk:{} cannot compress a {}-dim vector: k exceeds the dimension \
             (use k ≤ d or drop the sparsifier)",
            self.k,
            x.len()
        );
        let seed = rng.next_u64();
        w.put(seed, 53); // low 53 bits (57-bit put limit)
        w.put(seed >> 53, 11); // high 11 bits
        scratch::with_usize(|idx| {
            Rng::new(seed).sample_indices_into(x.len(), self.k, idx);
            match &self.inner {
                None => {
                    for &i in idx.iter() {
                        w.put_f32(x[i]);
                    }
                    Ok(())
                }
                Some(inner) => scratch::with_f32(|vals| {
                    vals.extend(idx.iter().map(|&i| x[i]));
                    inner.encode_into(vals, w, rng)
                }),
            }
        })
    }

    fn decode_into(&self, r: &mut BitReader, out: &mut [f32]) {
        out.fill(0.0);
        self.decode_add(r, out, 1.0);
    }

    fn decode_add(&self, r: &mut BitReader, acc: &mut [f32], scale: f32) {
        let seed = r.get(53) | (r.get(11) << 53);
        let d = acc.len();
        // the encoder refuses k > d; clamp here so a decoder on foreign
        // payloads stays in bounds
        let k = self.k.min(d);
        let rescale = scale * d as f32 / k as f32;
        scratch::with_usize(|idx| {
            Rng::new(seed).sample_indices_into(d, k, idx);
            match &self.inner {
                None => {
                    for &i in idx.iter() {
                        acc[i] += rescale * r.get_f32();
                    }
                }
                Some(inner) => scratch::with_f32(|vals| {
                    vals.resize(k, 0.0);
                    inner.decode_into(r, vals);
                    for (j, &i) in idx.iter().enumerate() {
                        acc[i] += rescale * vals[j];
                    }
                }),
            }
        })
    }
}

pub(super) fn register(r: &mut Registry) {
    r.add("randk", "randk:<k> (uniform k-sparsification, ω = d/k − 1)",
          "randk:10",
          Box::new(|arg, inner| {
              let arg = arg.ok_or_else(|| {
                  anyhow::anyhow!("randk requires `:k` (e.g. randk:50)")
              })?;
              let k: usize = arg.parse()
                  .map_err(|e| anyhow::anyhow!("randk k `{arg}`: {e}"))?;
              anyhow::ensure!(k >= 1, "randk k must be ≥ 1");
              Ok(Arc::new(RandK::chained(k, inner)))
          }));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{testutil, Compressor, CompressorState};

    #[test]
    fn exactly_k_nonzeros_scaled() {
        let x = testutil::test_vector(200, 1);
        let y = RandK::new(20).apply(&x, &mut Rng::new(2)).unwrap();
        let nz: Vec<usize> = (0..200).filter(|&i| y[i] != 0.0).collect();
        assert!(nz.len() <= 20); // (could collide with a genuine 0 in x)
        for &i in &nz {
            assert!((y[i] - x[i] * 10.0).abs() < 1e-4);
        }
    }

    #[test]
    fn wire_is_seed_plus_k_floats() {
        let x = testutil::test_vector(1000, 3);
        let c = testutil::compress("randk:50", &x, 4);
        assert_eq!(c.bits, 64 + 32 * 50);
    }

    #[test]
    fn assumption1_holds() {
        let x = testutil::test_vector(60, 5);
        testutil::check_assumption1(&RandK::new(15), &x, 1500, 21);
    }

    #[test]
    fn k_above_dim_is_a_compress_time_error() {
        let x = testutil::test_vector(10, 7);
        let err = RandK::new(100).apply(&x, &mut Rng::new(8)).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("randk:100") && msg.contains("10-dim"), "{msg}");
        // and through the full spec path
        let comp = crate::compress::from_spec("randk:100").unwrap();
        assert!(comp.instantiate(10, 0).compress(&x).is_err());
    }

    #[test]
    fn k_equal_dim_is_identity() {
        let x = testutil::test_vector(10, 7);
        let y = RandK::new(10).apply(&x, &mut Rng::new(8)).unwrap();
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn omega_formula() {
        assert_eq!(RandK::new(10).omega(100).unwrap(), 9.0);
        assert_eq!(RandK::new(100).omega(100).unwrap(), 0.0);
    }

    #[test]
    fn chained_survivors_use_inner_codec() {
        // randk:50>natural: 50 survivors at 9 bits instead of 32
        let x = testutil::test_vector(1000, 9);
        let c = testutil::compress("randk:50>natural", &x, 10);
        assert_eq!(c.bits, 64 + 9 * 50);
        let y = c.decode();
        let nnz = y.iter().filter(|v| **v != 0.0).count();
        assert!(nnz <= 50);
    }
}
