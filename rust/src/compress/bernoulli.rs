//! Bernoulli sparsifier (Khirirat et al. 2018): keep each coordinate with
//! probability q, rescaled by 1/q. Unbiased with ω = (1 − q)/q.
//!
//! Wire format: 64-bit mask seed + 32-bit kept-count + the kept values.
//! The receiver regenerates the Bernoulli mask from the seed (both ends
//! share the RNG), so mask bits cost 64 on the wire instead of d. Standalone
//! the kept values are raw f32 (expected size 64 + 32 + 32·q·d bits); in a
//! pipeline (`bernoulli:0.2>natural`) the survivor vector is handed to the
//! inner codec instead.

use std::sync::Arc;

use super::registry::Registry;
use super::{compose_omega, scratch, Codec};
use crate::util::{BitReader, BitWriter, Rng};

pub struct Bernoulli {
    q: f32,
    /// survivor codec for pipeline specs; `None` = raw f32 (legacy wire)
    inner: Option<Arc<dyn Codec>>,
}

impl Bernoulli {
    pub fn new(q: f32) -> Bernoulli {
        Self::chained(q, None)
    }

    pub fn chained(q: f32, inner: Option<Arc<dyn Codec>>) -> Bernoulli {
        assert!(q > 0.0 && q <= 1.0);
        Bernoulli { q, inner }
    }
}

impl Codec for Bernoulli {
    fn name(&self) -> String {
        match &self.inner {
            None => format!("bernoulli:{}", self.q),
            Some(i) => format!("bernoulli:{}>{}", self.q, i.name()),
        }
    }

    fn omega(&self, dim: usize) -> Option<f64> {
        let sel = (1.0 - self.q as f64) / self.q as f64;
        match &self.inner {
            None => Some(sel),
            // the survivor count is random (≤ dim); evaluating the inner ω
            // at dim is a sound upper bound for the dimension-monotone
            // operators in the registry
            Some(i) => compose_omega(Some(sel), i.omega(dim)),
        }
    }

    fn encode_into(&self, x: &[f32], w: &mut BitWriter, rng: &mut Rng)
                   -> anyhow::Result<()> {
        let mask_seed = rng.next_u64();
        let mut mask_rng = Rng::new(mask_seed);
        w.put(mask_seed, 53); // low 53 bits (57-bit put limit)
        w.put(mask_seed >> 53, 11); // high 11 bits
        scratch::with_f32(|kept| {
            // reserve the d-bound up front: the kept count varies per call,
            // so amortized growth would otherwise allocate sporadically —
            // this keeps the steady-state wire path allocation-free
            kept.reserve(x.len());
            for &v in x {
                if mask_rng.f32() < self.q {
                    kept.push(v);
                }
            }
            w.put_u32(kept.len() as u32);
            match &self.inner {
                None => {
                    for &v in kept.iter() {
                        w.put_f32(v);
                    }
                    Ok(())
                }
                Some(inner) => inner.encode_into(kept, w, rng),
            }
        })
    }

    fn decode_into(&self, r: &mut BitReader, out: &mut [f32]) {
        out.fill(0.0);
        self.decode_add(r, out, 1.0);
    }

    fn decode_add(&self, r: &mut BitReader, acc: &mut [f32], scale: f32) {
        let seed = r.get(53) | (r.get(11) << 53);
        let mut mask_rng = Rng::new(seed);
        let count = r.get_u32() as usize;
        let inv_q = scale / self.q;
        match &self.inner {
            None => {
                let mut seen = 0usize;
                for a in acc.iter_mut() {
                    if mask_rng.f32() < self.q {
                        debug_assert!(seen < count);
                        seen += 1;
                        *a += inv_q * r.get_f32();
                    }
                }
                debug_assert_eq!(seen, count);
            }
            Some(inner) => scratch::with_f32(|vals| {
                vals.reserve(acc.len()); // d-bound, see encode_into
                vals.resize(count, 0.0);
                inner.decode_into(r, vals);
                let mut j = 0usize;
                for a in acc.iter_mut() {
                    if mask_rng.f32() < self.q {
                        *a += inv_q * vals[j];
                        j += 1;
                    }
                }
                debug_assert_eq!(j, count);
            }),
        }
    }
}

pub(super) fn register(r: &mut Registry) {
    r.add("bernoulli", "bernoulli:<prob> (keep w.p. q, rescale 1/q, ω = (1−q)/q)",
          "bernoulli:0.25",
          Box::new(|arg, inner| {
              let arg = arg.ok_or_else(|| {
                  anyhow::anyhow!("bernoulli requires `:prob` (e.g. bernoulli:0.25)")
              })?;
              let q: f32 = arg.parse()
                  .map_err(|e| anyhow::anyhow!("bernoulli prob `{arg}`: {e}"))?;
              anyhow::ensure!(q > 0.0 && q <= 1.0, "bernoulli prob must be in (0,1]");
              Ok(Arc::new(Bernoulli::chained(q, inner)))
          }));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::testutil;

    #[test]
    fn kept_coordinates_are_scaled_by_inv_q() {
        let x = testutil::test_vector(400, 1);
        let y = Bernoulli::new(0.25).apply(&x, &mut Rng::new(2)).unwrap();
        let mut kept = 0;
        for (xi, yi) in x.iter().zip(&y) {
            if *yi != 0.0 {
                kept += 1;
                assert!((yi - xi * 4.0).abs() < 1e-5, "{xi} -> {yi}");
            }
        }
        // q = 0.25 over 400 coords: ~100 kept
        assert!((50..180).contains(&kept), "kept = {kept}");
    }

    #[test]
    fn wire_size_tracks_kept_count() {
        let x = testutil::test_vector(1000, 3);
        let c = testutil::compress("bernoulli:0.1", &x, 4);
        let kept = (c.bits - 64 - 32) / 32;
        assert!((40..220).contains(&kept), "kept = {kept}");
        assert!(c.bits < 32 * 1000 / 2, "bits = {}", c.bits);
    }

    #[test]
    fn assumption1_holds() {
        let x = testutil::test_vector(64, 5);
        testutil::check_assumption1(&Bernoulli::new(0.3), &x, 1200, 19);
    }

    #[test]
    fn q_one_is_identity() {
        let x = testutil::test_vector(100, 7);
        let y = Bernoulli::new(1.0).apply(&x, &mut Rng::new(8)).unwrap();
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn omega_formula() {
        assert!((Bernoulli::new(0.1).omega(10).unwrap() - 9.0).abs() < 1e-5);
        assert_eq!(Bernoulli::new(1.0).omega(10).unwrap(), 0.0);
    }

    #[test]
    fn decode_add_matches_decode() {
        let x = testutil::test_vector(150, 9);
        let c = testutil::compress("bernoulli:0.5", &x, 10);
        let y = c.decode();
        let mut acc = vec![2.0f32; 150];
        c.decode_add(&mut acc, 0.25);
        for i in 0..150 {
            assert!((acc[i] - (2.0 + 0.25 * y[i])).abs() < 1e-5);
        }
    }

    #[test]
    fn chained_survivors_use_inner_codec() {
        // bernoulli:0.2>natural: survivors cost 9 bits instead of 32
        let x = testutil::test_vector(1000, 11);
        let raw = testutil::compress("bernoulli:0.2", &x, 12);
        let chained = testutil::compress("bernoulli:0.2>natural", &x, 12);
        // same mask seed (same rng stream) ⇒ same kept count
        let kept = (raw.bits - 64 - 32) / 32;
        assert_eq!(chained.bits, 64 + 32 + 9 * kept);
        // every decoded survivor is (1/q)·power-of-two
        let y = chained.decode();
        for v in y.iter().filter(|v| **v != 0.0) {
            let m = (v.abs() * 0.2).log2();
            assert!((m - m.round()).abs() < 1e-3, "{v}");
        }
    }
}
