//! Bernoulli sparsifier (Khirirat et al. 2018): keep each coordinate with
//! probability q, rescaled by 1/q. Unbiased with ω = (1 − q)/q.
//!
//! Wire format: 64-bit mask seed + 32-bit kept-count + raw f32 values of the
//! kept coordinates. The receiver regenerates the Bernoulli mask from the
//! seed (both ends share the RNG), so mask bits cost 64 on the wire instead
//! of d — expected size 64 + 32 + 32·q·d bits.

use super::{Codec, Compressed, Compressor};
use crate::util::{BitReader, BitWriter, Rng};

pub struct Bernoulli {
    q: f32,
}

impl Bernoulli {
    pub fn new(q: f32) -> Bernoulli {
        assert!(q > 0.0 && q <= 1.0);
        Bernoulli { q }
    }
}

impl Compressor for Bernoulli {
    fn name(&self) -> String {
        format!("bernoulli:{}", self.q)
    }

    fn omega(&self, _dim: usize) -> Option<f64> {
        Some((1.0 - self.q as f64) / self.q as f64)
    }

    fn compress(&self, x: &[f32], rng: &mut Rng) -> Compressed {
        let mask_seed = rng.next_u64();
        let mut mask_rng = Rng::new(mask_seed);
        let mut w = BitWriter::with_capacity(8 + 4 + (x.len() as f32 * self.q) as usize * 4);
        w.put(mask_seed & 0x1FF_FFFF_FFFF_FFFF, 57 - 4); // low 53 bits
        w.put(mask_seed >> 53, 11); // high 11 bits (57-bit put limit)
        let mut kept_vals = Vec::new();
        for &v in x {
            if mask_rng.f32() < self.q {
                kept_vals.push(v);
            }
        }
        w.put_u32(kept_vals.len() as u32);
        for v in kept_vals {
            w.put_f32(v);
        }
        let bits = w.bit_len();
        Compressed::new(w.finish(), bits, x.len(), Codec::Bernoulli { q: self.q })
    }
}

fn read_seed(r: &mut BitReader) -> u64 {
    let low = r.get(53);
    let high = r.get(11);
    low | (high << 53)
}

pub(super) fn decode(payload: &[u8], q: f32, out: &mut [f32]) {
    out.fill(0.0);
    decode_add(payload, q, out, 1.0);
}

pub(super) fn decode_add(payload: &[u8], q: f32, acc: &mut [f32], scale: f32) {
    let mut r = BitReader::new(payload);
    let seed = read_seed(&mut r);
    let mut mask_rng = Rng::new(seed);
    let count = r.get_u32() as usize;
    let inv_q = scale / q;
    let mut seen = 0usize;
    for a in acc.iter_mut() {
        if mask_rng.f32() < q {
            debug_assert!(seen < count);
            seen += 1;
            *a += inv_q * r.get_f32();
        }
    }
    debug_assert_eq!(seen, count);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::testutil;

    #[test]
    fn kept_coordinates_are_scaled_by_inv_q() {
        let x = testutil::test_vector(400, 1);
        let b = Bernoulli::new(0.25);
        let y = b.apply(&x, &mut Rng::new(2));
        let mut kept = 0;
        for (xi, yi) in x.iter().zip(&y) {
            if *yi != 0.0 {
                kept += 1;
                assert!((yi - xi * 4.0).abs() < 1e-5, "{xi} -> {yi}");
            }
        }
        // q = 0.25 over 400 coords: ~100 kept
        assert!((50..180).contains(&kept), "kept = {kept}");
    }

    #[test]
    fn wire_size_tracks_kept_count() {
        let x = testutil::test_vector(1000, 3);
        let c = Bernoulli::new(0.1).compress(&x, &mut Rng::new(4));
        let kept = (c.bits - 64 - 32) / 32;
        assert!((40..220).contains(&kept), "kept = {kept}");
        assert!(c.bits < 32 * 1000 / 2, "bits = {}", c.bits);
    }

    #[test]
    fn assumption1_holds() {
        let x = testutil::test_vector(64, 5);
        testutil::check_assumption1(&Bernoulli::new(0.3), &x, 1200, 19);
    }

    #[test]
    fn q_one_is_identity() {
        let x = testutil::test_vector(100, 7);
        let y = Bernoulli::new(1.0).apply(&x, &mut Rng::new(8));
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn omega_formula() {
        assert!((Bernoulli::new(0.1).omega(10).unwrap() - 9.0).abs() < 1e-5);
        assert_eq!(Bernoulli::new(1.0).omega(10).unwrap(), 0.0);
    }

    #[test]
    fn decode_add_matches_decode() {
        let x = testutil::test_vector(150, 9);
        let c = Bernoulli::new(0.5).compress(&x, &mut Rng::new(10));
        let y = c.decode();
        let mut acc = vec![2.0f32; 150];
        c.decode_add(&mut acc, 0.25);
        for i in 0..150 {
            assert!((acc[i] - (2.0 + 0.25 * y[i])).abs() < 1e-5);
        }
    }
}
