//! Discrete-event fleet simulator: partial participation, heterogeneous
//! devices, and byte-accurate wire framing.
//!
//! The lockstep harness answers "what does the algorithm do"; this module
//! answers "what does it do on a *fleet*" — phones next to laptops, WAN
//! links, day/night churn, stragglers — with communication measured in
//! serialized bytes ([`crate::transport::frame`]) and progress measured in
//! simulated seconds, not just theoretical bits.
//!
//! * [`queue`] — deterministic timestamped event queue (binary heap, FIFO
//!   ties).
//! * [`fleet`] — device profiles drawn from configurable distributions
//!   (uniform / log-normal / bimodal "phone vs laptop") via O(1)
//!   random-access streams (lazy at mega-fleet sizes) and seeded
//!   availability traces (windowed dropout, diurnal cycles).
//! * [`scenario`] — presets (`uniform`, `lognormal-wan`, `diurnal-churn`,
//!   `straggler-heavy`, `megafleet`, `megafleet-churn`) behind a
//!   `name[:key=val,...]` spec grammar.
//! * [`runner`] — drives the sharded cohort engine
//!   ([`crate::algorithms::ShardedL2gdEngine`], copy-on-write client
//!   state): cohort selection per event in O(cohort) — lazy id-space
//!   sampling at mega-fleet sizes — first-k-of-m quorum under a straggler
//!   deadline, and a fleet clock advanced by the event queue.
//!
//! `pfl sim` is the CLI front end; with the `uniform` preset the simulated
//! series is bit-identical to the dense lockstep engine (the equivalence
//! is pinned by `rust/tests/integration_sim.rs`), and the `megafleet`
//! presets run a million devices with resident state proportional to the
//! clients actually touched.

pub mod fleet;
pub mod queue;
pub mod runner;
pub mod scenario;

pub use fleet::{Churn, DeviceProfile, Dist, Fleet, FleetSpec};
pub use queue::EventQueue;
pub use runner::{sample_device_ids, FleetSim, SimCfg, SimResult, SimStats};
pub use scenario::Scenario;
