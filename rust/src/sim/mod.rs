//! Discrete-event fleet simulator: partial participation, heterogeneous
//! devices, and byte-accurate wire framing — for every registered fleet
//! algorithm ([`crate::algorithms::FLEET_ALGS`]).
//!
//! The lockstep harness answers "what does the algorithm do"; this module
//! answers "what does it do on a *fleet*" — phones next to laptops, WAN
//! links, day/night churn, stragglers — with communication measured in
//! serialized bytes ([`crate::transport::frame`]) and progress measured in
//! simulated seconds, not just theoretical bits. L2GD's probabilistic
//! protocol and the FedAvg/FedOpt fixed-cadence baselines all run on the
//! same generic cohort engine (`alg=` in the scenario grammar), so the
//! paper's bits-per-accuracy comparison holds up under realistic cohort
//! sampling, churn, and million-device scale.
//!
//! * [`queue`] — deterministic timestamped event queue: an O(1)-amortized
//!   timing wheel (bucket granularity derived from the fleet's delay
//!   distributions, overflow rung for far-future events) pinned
//!   bit-identical against the retained binary-heap oracle
//!   (`HeapQueue`), FIFO ties either way.
//! * [`fleet`] — device profiles drawn from configurable distributions
//!   (uniform / log-normal / bimodal "phone vs laptop") via O(1)
//!   random-access streams (never materialized fleet-wide) and seeded
//!   availability traces (windowed dropout, diurnal cycles).
//! * [`lang`] — the spec language: span-tracking lexer,
//!   recursive-descent parser, and the [`lang::SpecError`] diagnostic
//!   type (caret rendering + "did you mean" suggestions) shared with the
//!   codec and staleness-weight parsers.
//! * [`scenario`] — presets (`async-bursty`, `diurnal-churn`,
//!   `lognormal-wan`, `megafleet`, `megafleet-async`, `megafleet-churn`,
//!   `megafleet-fedavg`, `straggler-heavy`, `uniform`) behind a
//!   `name[:key=val,...]` spec grammar with `alg=l2gd|fedavg|fedopt`,
//!   `codec=<registry spec>`, and
//!   `async=buffered,buffer=K|cohort,inflight=M,stale=W,max_stale=S|none`
//!   keys, plus round-boundary phase sequencing:
//!   `phases(<spec> @rounds=N; ...; <spec>)`.
//! * [`runner`] — drives the generic cohort engine
//!   ([`crate::algorithms::ShardedL2gdEngine`], copy-on-write client
//!   state): one O(cohort) id-space cohort draw at every fleet size,
//!   first-k-of-m quorum under a straggler deadline, and a fleet clock
//!   advanced by the event queue.
//! * [`async_runner`] — the asynchronous runtime: up to `max_in_flight`
//!   version-stamped rounds overlap in the shared event queue, arrivals
//!   aggregate staleness-weighted once a K-update buffer fills, and the
//!   staleness distribution plus uplink goodput are metered; `inflight=1`
//!   with `buffer=cohort` reproduces [`runner`] bit for bit.
//!
//! ### Device → data-shard mapping (the canonical definition)
//! A simulated fleet can be far larger than the number of distinct data
//! shards the environment carries: fleet device `i` trains and evaluates
//! on data shard **`i mod n_clients`**, where `n_clients` is
//! `FedEnv::n_clients()` (= [`SimCfg::data_clients`] at environment build
//! time). Ordinary scenarios keep fleet == shards, making the mapping the
//! identity; mega scenarios map a million devices onto the run default's
//! few heterogeneous shards. This paragraph is the single source of truth
//! for the mapping — other docs (README "Architecture", the engine's
//! `data_shard` accessor, `SimCfg`) link here instead of restating it.
//!
//! `pfl sim` is the CLI front end; with the `uniform` preset the simulated
//! series is bit-identical to the dense lockstep engine (the equivalence
//! is pinned by `rust/tests/integration_sim.rs`), and the `megafleet`
//! presets run a million devices — under L2GD *or* the baselines — with
//! resident state proportional to the clients actually touched.

pub mod async_runner;
pub mod fleet;
pub mod lang;
pub mod queue;
pub mod runner;
pub mod scenario;

pub use async_runner::{AsyncDenseSim, AsyncFleetSim, AsyncShardedSim, AsyncStats};
pub use fleet::{Churn, DeviceProfile, Dist, Fleet, FleetSpec};
pub use lang::SpecError;
pub use queue::{EventQueue, HeapQueue};
pub use runner::{sample_device_ids, FleetSim, SimCfg, SimResult, SimStats};
pub use scenario::{Phase, Scenario};
