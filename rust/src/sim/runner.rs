//! The fleet simulator: drives the [`L2gdEngine`] over a modeled device
//! fleet with partial participation, churn, straggler deadlines, and
//! byte-accurate wire framing.
//!
//! ### Time model
//! Protocol iterations are synchronous (the paper's Algorithm 1): a local
//! or cached-aggregation step advances the clock by the slowest *active*
//! device's compute time. A fresh aggregation opens a communication round:
//! every sampled device's upload-arrival event (`compute + latency +
//! framed-bytes / uplink-bandwidth`) is pushed into the discrete-event
//! queue; arrivals pop in time order until the quorum is met or the
//! straggler deadline passes, and the round closes after the slowest
//! arrived device's downlink completes. Devices that miss the cut are
//! dropped stragglers — their model update is skipped for the round,
//! though their uplink frames are still metered as transmitted-but-
//! discarded traffic (the bytes crossed the network either way).
//!
//! ### Anchor possession
//! Only the cohort of a committed fresh round receives (and pays the
//! downlink for) the new anchor C_M(ȳ). The simulator tracks who holds
//! the *current* anchor: on later cached-aggregation steps, devices that
//! missed the latest broadcast skip the aggregation instead of silently
//! using bytes they never downloaded. (Everyone starts with the shared
//! init anchor — Algorithm 1's ξ₋₁ = 1 convention.)
//!
//! ### Determinism
//! Fleet profiles, churn traces, cohort sampling, and every engine stream
//! fork deterministically from the run seed, so a scenario replays
//! bit-exactly. With the `uniform` preset (always on, full cohort, no
//! deadline) the executed update sequence is *identical* to the lockstep
//! engine's, so the loss series matches it bit for bit — only the wire
//! accounting differs (serialized frames instead of theoretical bits).

use crate::algorithms::l2gd::L2gdEngine;
use crate::algorithms::{FedEnv, L2gd};
use crate::experiments::fig3;
use crate::metrics::{Record, Series};
use crate::protocol::StepKind;
use crate::util::json::Value;
use crate::util::Rng;

use super::fleet::{Churn, Fleet};
use super::queue::EventQueue;
use super::scenario::Scenario;

/// One simulated training job: the Fig-3 convex configuration under a
/// fleet [`Scenario`].
#[derive(Clone, Debug)]
pub struct SimCfg {
    pub scenario: Scenario,
    pub steps: u64,
    pub eval_every: u64,
    pub seed: u64,
    /// fleet size when the scenario does not pin one (`clients=0`)
    pub n_clients: usize,
    pub rows_per_worker: usize,
    pub p: f64,
    pub lambda: f64,
    pub eta: f64,
    pub client_comp: String,
    pub master_comp: String,
}

impl SimCfg {
    /// The Fig-3 convex configuration (§VII-A) under `scenario`.
    pub fn fig3(scenario: Scenario) -> SimCfg {
        SimCfg {
            scenario,
            steps: 400,
            eval_every: 50,
            seed: 0,
            n_clients: 5,
            rows_per_worker: 321,
            p: 0.65,
            lambda: 10.0,
            eta: 1.0,
            client_comp: "natural".into(),
            master_comp: "natural".into(),
        }
    }

    /// CI-sized run: same shapes, small shards and few steps.
    pub fn smoke(scenario: Scenario) -> SimCfg {
        SimCfg { steps: 200, eval_every: 100, rows_per_worker: 40,
                 ..SimCfg::fig3(scenario) }
    }

    /// Fleet size: the scenario override, else the run default.
    pub fn effective_clients(&self) -> usize {
        if self.scenario.clients > 0 {
            self.scenario.clients
        } else {
            self.n_clients
        }
    }
}

/// The Fig-3 heterogeneous convex environment at the configured fleet
/// size — built by `fig3::build_env` so the simulator can never drift
/// from the configuration the paper figures use.
pub fn build_env(cfg: &SimCfg) -> FedEnv {
    fig3::build_env(&fig3::Fig3Cfg {
        rows_per_worker: cfg.rows_per_worker,
        n_clients: cfg.effective_clients(),
        eta: cfg.eta,
        seed: cfg.seed,
        ..fig3::Fig3Cfg::a1a()
    })
}

/// Counters accumulated over a simulated run.
#[derive(Clone, Debug, Default)]
pub struct SimStats {
    /// fresh-aggregation rounds that actually committed
    pub comm_events: u64,
    /// fresh draws with nobody available / nobody arrived in time
    pub skipped_rounds: u64,
    /// sampled devices that missed the quorum or the deadline
    pub dropped_stragglers: u64,
    /// Σ cohort size over committed rounds
    pub total_participants: u64,
    /// iterations where no device was available (clock still advances)
    pub idle_steps: u64,
    /// scheduler events processed (steps + arrival pushes + pops) — the
    /// denominator of the allocation-discipline bench
    pub events: u64,
}

impl SimStats {
    pub fn mean_participants(&self) -> f64 {
        self.total_participants as f64 / self.comm_events.max(1) as f64
    }
}

/// A stepping fleet simulation over a borrowed environment.
pub struct FleetSim<'e> {
    eng: L2gdEngine<'e>,
    fleet: Fleet,
    churn: Churn,
    churn_seed: u64,
    sample_frac: f64,
    quorum_frac: f64,
    deadline_s: f64,
    sampler: Rng,
    clock: f64,
    stats: SimStats,
    /// devices holding the current anchor (see the module docs)
    has_anchor: Vec<bool>,
    // reusable per-step scratch (the hot loop is allocation-bounded)
    active: Vec<bool>,
    sampled: Vec<bool>,
    arrived: Vec<bool>,
    agg_mask: Vec<bool>,
    avail: Vec<usize>,
    pick: Vec<usize>,
    queue: EventQueue<usize>,
}

impl<'e> FleetSim<'e> {
    pub fn new(cfg: &SimCfg, env: &'e FedEnv) -> anyhow::Result<FleetSim<'e>> {
        let n = env.n_clients();
        anyhow::ensure!(n == cfg.effective_clients(),
                        "environment has {n} clients, config wants {}",
                        cfg.effective_clients());
        let mut alg = L2gd::new(cfg.p, cfg.lambda, cfg.eta, n,
                                &cfg.client_comp, &cfg.master_comp)?;
        fig3::clamp_agg_stability(&mut alg, n);
        let mut eng = alg.engine(env)?;
        eng.enable_wire_framing();
        let fleet = Fleet::build(&cfg.scenario.fleet, n, cfg.seed ^ 0xF1EE7);
        Ok(FleetSim {
            eng,
            fleet,
            churn: cfg.scenario.churn.clone(),
            churn_seed: cfg.seed ^ 0xC4A9,
            sample_frac: cfg.scenario.sample_frac,
            quorum_frac: cfg.scenario.quorum_frac,
            deadline_s: cfg.scenario.deadline_s,
            sampler: Rng::new(cfg.seed ^ 0x5A3E),
            clock: 0.0,
            stats: SimStats::default(),
            // the identical inits double as the shared ξ₋₁ = 1 anchor
            has_anchor: vec![true; n],
            active: vec![false; n],
            sampled: vec![false; n],
            arrived: vec![false; n],
            agg_mask: vec![false; n],
            avail: Vec::with_capacity(n),
            pick: Vec::with_capacity(n),
            queue: EventQueue::with_capacity(n),
        })
    }

    pub fn clock(&self) -> f64 {
        self.clock
    }

    pub fn stats(&self) -> &SimStats {
        &self.stats
    }

    pub fn engine(&self) -> &L2gdEngine<'e> {
        &self.eng
    }

    /// Advance one protocol iteration at the current simulated time.
    pub fn step(&mut self, k: u64) -> anyhow::Result<()> {
        let (churn, seed, clock) = (&self.churn, self.churn_seed, self.clock);
        for (i, a) in self.active.iter_mut().enumerate() {
            *a = churn.available(seed, i, clock);
        }
        self.stats.events += 1;
        match self.eng.draw() {
            StepKind::Local => match self.fleet.max_step_time(&self.active) {
                Some(dt) => {
                    self.eng.step_local(&self.active)?;
                    self.clock += dt;
                }
                None => self.idle_tick(),
            },
            StepKind::AggregateCached => match self.fleet.max_step_time(&self.active) {
                Some(dt) => {
                    // only devices holding the current anchor can aggregate
                    // toward it; the rest idle through the iteration
                    let mut any = false;
                    for ((m, &a), &h) in self.agg_mask.iter_mut()
                        .zip(&self.active)
                        .zip(&self.has_anchor)
                    {
                        *m = a && h;
                        any |= *m;
                    }
                    if any {
                        self.eng.step_aggregate_cached(&self.agg_mask);
                    }
                    self.clock += dt;
                }
                None => self.idle_tick(),
            },
            StepKind::AggregateFresh => self.fresh_round(k)?,
        }
        Ok(())
    }

    pub fn run_steps(&mut self, from: u64, count: u64) -> anyhow::Result<()> {
        for k in from + 1..=from + count {
            self.step(k)?;
        }
        Ok(())
    }

    /// Evaluate into a `Record`, with the fleet clock as the sim-time
    /// column (replacing the engine's homogeneous TimeModel projection).
    pub fn evaluate(&self, step: u64) -> anyhow::Result<Record> {
        let mut rec = self.eng.evaluate(step)?;
        rec.sim_time_s = self.clock;
        Ok(rec)
    }

    /// Nobody is online: the iteration is a fleet-wide no-op, but the
    /// clock still moves.
    fn idle_tick(&mut self) {
        self.stats.idle_steps += 1;
        self.clock += self.fleet.mean_step_time();
    }

    /// A fresh-aggregation round: sample a cohort from the available
    /// devices, schedule their upload arrivals through the event queue,
    /// close at quorum or deadline, and commit the round over whoever made
    /// it.
    fn fresh_round(&mut self, k: u64) -> anyhow::Result<()> {
        let n = self.fleet.len();
        self.avail.clear();
        self.avail.extend((0..n).filter(|&i| self.active[i]));
        if self.avail.is_empty() {
            self.stats.skipped_rounds += 1;
            self.idle_tick();
            return Ok(());
        }
        // over-selection: sample m available devices, wait for the first
        // quorum of them
        let m = ((self.sample_frac * self.avail.len() as f64).ceil() as usize)
            .clamp(1, self.avail.len());
        self.sampler.sample_indices_into(self.avail.len(), m, &mut self.pick);
        self.sampled.fill(false);
        for &j in &self.pick {
            self.sampled[self.avail[j]] = true;
        }
        self.eng.compress_uplinks(&self.sampled)?;
        // schedule arrivals: compute + latency + serialized frame transfer
        self.queue.clear();
        for &j in &self.pick {
            let i = self.avail[j];
            let dev = &self.fleet.devices[i];
            let bits = self.eng.uplink_frame_bytes(i) as f64 * 8.0;
            let t = self.clock + dev.step_time_s + dev.latency_s + bits / dev.up_bps;
            self.queue.push(t, i);
            self.stats.events += 1;
        }
        let quorum = ((self.quorum_frac * m as f64).ceil() as usize).clamp(1, m);
        let deadline = self.clock + self.deadline_s;
        self.arrived.fill(false);
        let mut arrived_n = 0usize;
        let mut round_end = self.clock;
        while let Some((t, i)) = self.queue.pop() {
            self.stats.events += 1;
            if t > deadline {
                // this device and everything still queued missed the round
                self.stats.dropped_stragglers += 1 + self.queue.len() as u64;
                round_end = deadline;
                break;
            }
            self.arrived[i] = true;
            arrived_n += 1;
            round_end = t;
            if arrived_n >= quorum {
                self.stats.dropped_stragglers += self.queue.len() as u64;
                break;
            }
        }
        if arrived_n == 0 {
            // everyone blew the deadline: the anchor does not move, but
            // the cohort's frames were transmitted — meter them as
            // discarded traffic
            self.eng.abort_fresh(k, &self.sampled)?;
            self.stats.skipped_rounds += 1;
            self.clock = round_end.max(self.clock + self.fleet.mean_step_time());
            return Ok(());
        }
        self.eng.complete_fresh(k, &self.arrived, &self.sampled)?;
        // the broadcast reached only the cohort: they alone hold the new
        // anchor for subsequent cached-aggregation steps
        self.has_anchor.copy_from_slice(&self.arrived);
        self.stats.comm_events += 1;
        self.stats.total_participants += arrived_n as u64;
        // the round closes once the slowest cohort member has the anchor
        let dbits = self.eng.downlink_frame_bytes() as f64 * 8.0;
        let mut down_t = 0.0f64;
        for (i, dev) in self.fleet.devices.iter().enumerate() {
            if self.arrived[i] {
                down_t = down_t.max(dev.latency_s + dbits / dev.down_bps);
            }
        }
        self.clock = round_end + down_t;
        Ok(())
    }
}

/// A completed scenario run.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// the full scenario spec (overrides included) — the output key
    pub scenario: String,
    pub series: Series,
    pub stats: SimStats,
}

impl SimResult {
    pub fn to_json(&self) -> Value {
        let last = self.series.last().expect("series has records");
        Value::obj(vec![
            ("scenario".into(), Value::Str(self.scenario.clone())),
            ("label".into(), Value::Str(self.series.label.clone())),
            ("steps".into(), Value::Num(last.step as f64)),
            ("comm_events".into(), Value::Num(self.stats.comm_events as f64)),
            ("skipped_rounds".into(), Value::Num(self.stats.skipped_rounds as f64)),
            ("dropped_stragglers".into(),
             Value::Num(self.stats.dropped_stragglers as f64)),
            ("mean_participants".into(),
             Value::Num(self.stats.mean_participants())),
            ("idle_steps".into(), Value::Num(self.stats.idle_steps as f64)),
            ("sim_time_s".into(), Value::Num(last.sim_time_s)),
            ("bytes_up".into(), Value::Num((last.bits_up / 8) as f64)),
            ("bytes_down".into(), Value::Num((last.bits_down / 8) as f64)),
            ("final_train_loss".into(), Value::Num(last.train_loss)),
            ("final_personal_loss".into(), Value::Num(last.personal_loss)),
            ("final_test_acc".into(), Value::Num(last.test_acc)),
        ])
    }
}

/// Run one scenario end to end (environment build + simulation + eval
/// cadence) and return the sim-time series plus counters.
pub fn run(cfg: &SimCfg) -> anyhow::Result<SimResult> {
    let env = build_env(cfg);
    let mut sim = FleetSim::new(cfg, &env)?;
    let mut series = Series::new(format!(
        "sim[{}] l2gd[{}|{}]:p={},λ={}",
        cfg.scenario.spec, cfg.client_comp, cfg.master_comp, cfg.p, cfg.lambda));
    series.records.push(sim.evaluate(0)?);
    for k in 1..=cfg.steps {
        sim.step(k)?;
        if k % cfg.eval_every == 0 || k == cfg.steps {
            series.records.push(sim.evaluate(k)?);
            if !series.records.last().unwrap().is_finite() {
                break; // diverged: record it and stop
            }
        }
    }
    Ok(SimResult {
        scenario: cfg.scenario.spec.clone(),
        series,
        stats: sim.stats().clone(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::scenario;

    fn smoke(spec: &str, seed: u64) -> SimCfg {
        let mut cfg = SimCfg::smoke(scenario::from_spec(spec).unwrap());
        cfg.seed = seed;
        cfg
    }

    #[test]
    fn uniform_scenario_learns_and_frames_bytes() {
        let res = run(&smoke("uniform", 0)).unwrap();
        let first = res.series.records.first().unwrap();
        let last = res.series.last().unwrap();
        assert!(last.personal_loss < first.personal_loss,
                "loss {} -> {}", first.personal_loss, last.personal_loss);
        assert!(res.stats.comm_events > 0);
        assert_eq!(res.stats.skipped_rounds, 0);
        assert_eq!(res.stats.dropped_stragglers, 0);
        // full participation every committed round
        assert_eq!(res.stats.total_participants, res.stats.comm_events * 5);
        assert_eq!(last.participants, 5);
        // frame metering: whole bytes on the wire, header overhead included
        assert_eq!(last.bits_up % 8, 0);
        assert!(last.sim_time_s > 0.0);
    }

    #[test]
    fn deterministic_across_reruns() {
        let a = run(&smoke("straggler-heavy", 3)).unwrap();
        let b = run(&smoke("straggler-heavy", 3)).unwrap();
        assert_eq!(a.series.records.len(), b.series.records.len());
        for (ra, rb) in a.series.records.iter().zip(&b.series.records) {
            assert_eq!(ra.train_loss, rb.train_loss);
            assert_eq!(ra.personal_loss, rb.personal_loss);
            assert_eq!(ra.bits_up, rb.bits_up);
            assert_eq!(ra.sim_time_s, rb.sim_time_s);
            assert_eq!(ra.participants, rb.participants);
        }
        assert_eq!(a.stats.dropped_stragglers, b.stats.dropped_stragglers);
    }

    #[test]
    fn straggler_scenario_drops_and_still_learns() {
        let mut cfg = smoke("straggler-heavy:clients=12,quorum=0.5,deadline=0.5", 1);
        cfg.steps = 300;
        let res = run(&cfg).unwrap();
        let last = res.series.last().unwrap();
        assert!(res.stats.dropped_stragglers > 0, "{:?}", res.stats);
        assert!(res.stats.mean_participants() < 12.0);
        assert!(res.stats.mean_participants() >= 1.0);
        assert!(last.personal_loss.is_finite());
        assert!(last.personal_loss < res.series.records[0].personal_loss);
        // every sampled device transmitted — arrived or dropped, its frame
        // bytes meter. Natural wire at d=123: 9·123 bits → 139 B payload +
        // 22 B header per frame. (Arrivals here are far inside the 0.5 s
        // deadline, so no round skips and the identity is exact.)
        assert_eq!(res.stats.skipped_rounds, 0, "{:?}", res.stats);
        let frame_bits = (22 + 139) * 8;
        assert_eq!(last.bits_up,
                   (res.stats.total_participants + res.stats.dropped_stragglers)
                       * frame_bits);
    }

    #[test]
    fn diurnal_churn_varies_participation() {
        let mut cfg = smoke("diurnal-churn:clients=16", 2);
        cfg.steps = 400;
        let res = run(&cfg).unwrap();
        assert!(res.stats.comm_events > 0);
        // churn must bite: some committed round ran below full fleet, or
        // rounds were skipped outright
        assert!(res.stats.total_participants < res.stats.comm_events * 16
                    || res.stats.skipped_rounds > 0,
                "{:?}", res.stats);
        assert!(res.series.last().unwrap().train_loss.is_finite());
    }

    #[test]
    fn summary_json_roundtrips() {
        let res = run(&smoke("uniform", 4)).unwrap();
        let text = res.to_json().to_string_pretty();
        let v = crate::util::json::parse(&text).unwrap();
        assert_eq!(v.get("scenario").unwrap().as_str(), Some("uniform"));
        assert!(v.get("sim_time_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(v.get("bytes_up").unwrap().as_f64().unwrap() > 0.0);
    }
}
