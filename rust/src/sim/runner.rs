//! The fleet simulator: drives the generic cohort engine
//! ([`ShardedL2gdEngine`] — the copy-on-write instantiation of
//! [`crate::algorithms::engine::Engine`]) over a modeled device fleet
//! with partial participation, churn, straggler deadlines, and
//! byte-accurate wire framing — at up to million-device fleet sizes, for
//! **any registered fleet algorithm** ([`crate::algorithms::FLEET_ALGS`]:
//! L2GD's probabilistic protocol, or the FedAvg/FedOpt fixed-cadence
//! baselines via the scenario grammar's `alg=` key). That makes the
//! paper's headline comparison — compressed L2GD vs fixed-schedule
//! baselines on communicated bits — runnable under realistic cohort
//! sampling, churn, and fleet scale.
//!
//! ### Time model
//! Protocol iterations are synchronous (the paper's Algorithm 1): a local
//! or cached-aggregation step advances the clock by the slowest *cohort*
//! device's compute time. A fresh aggregation opens a communication round:
//! every sampled device's upload-arrival event (`compute + latency +
//! framed-bytes / uplink-bandwidth`) is pushed into the discrete-event
//! queue; arrivals pop in time order until the quorum is met or the
//! straggler deadline passes, and the round closes after the slowest
//! arrived device's downlink completes. Devices that miss the cut are
//! dropped stragglers — their model update is skipped for the round,
//! though their uplink frames are still metered as transmitted-but-
//! discarded traffic (the bytes crossed the network either way).
//!
//! ### Cohorts, not fleets
//! Every event touches a *cohort*, never the fleet — one id-space path at
//! every fleet size: cohort ids are drawn directly from `[0, n)` in
//! O(cohort) ([`sample_device_ids`]; a full-sample draw enumerates), then
//! filtered by the churn hash, and device profiles are lazy O(1) lookups
//! ([`FleetSpec::device`]) — a fleet is never materialized. Client model
//! state lives in the engine's copy-on-write sharded store, so resident
//! bytes scale with |ever-touched clients| (bounded for mega runs by
//! [`resident_bound_bytes`], enforced at the end of every mega `run` —
//! whichever algorithm ran).
//!
//! ### Anchor possession
//! Only the cohort of a committed fresh round receives (and pays the
//! downlink for) the new anchor. The simulator tracks who holds the
//! *current* anchor (a sorted holder list, `None` = everyone at init —
//! Algorithm 1's ξ₋₁ = 1 convention): on later cached-aggregation steps,
//! devices that missed the latest broadcast skip the aggregation instead
//! of silently using bytes they never downloaded. (Fixed-cadence
//! schedules never deal cached aggregations, so the mechanism is inert
//! for the baselines.)
//!
//! ### Determinism
//! Fleet profiles, churn traces, cohort sampling, and every engine stream
//! fork deterministically from the run seed, so a scenario replays
//! bit-exactly. With the `uniform` preset (always on, full cohort, no
//! deadline) the executed update sequence is *identical* to the dense
//! lockstep engine's, so the loss series matches it bit for bit — only
//! the wire accounting differs (serialized frames instead of theoretical
//! bits).

use std::cmp::Ordering;
use std::collections::HashSet;

use crate::algorithms::{AlgSpec, FedEnv, L2gd, ShardedL2gdEngine, FLEET_ALGS};
use crate::experiments::fig3;
use crate::metrics::{Record, Series};
use crate::obs;
use crate::obs::registry;
use crate::protocol::StepKind;
use crate::util::json::Value;
use crate::util::Rng;

use super::async_runner::AsyncStats;
use super::fleet::{Churn, DeviceProfile, FleetSpec};
use super::queue::EventQueue;
use super::scenario::Scenario;

/// One simulated training job: the Fig-3 convex configuration under a
/// fleet [`Scenario`].
#[derive(Clone, Debug)]
pub struct SimCfg {
    pub scenario: Scenario,
    pub steps: u64,
    pub eval_every: u64,
    pub seed: u64,
    /// fleet size when the scenario does not pin one (`clients=0`); for
    /// mega scenarios this is instead the number of *data shards* the
    /// fleet maps onto (see the device → shard mapping in [`crate::sim`])
    pub n_clients: usize,
    pub rows_per_worker: usize,
    /// L2GD meta-parameters (`alg=l2gd`)
    pub p: f64,
    pub lambda: f64,
    pub eta: f64,
    /// baseline parameters (`alg=fedavg` / `alg=fedopt`)
    pub local_lr: f64,
    pub local_steps: u64,
    pub server_lr: f64,
    pub client_comp: String,
    pub master_comp: String,
}

impl SimCfg {
    /// The Fig-3 convex configuration (§VII-A) under `scenario`.
    pub fn fig3(scenario: Scenario) -> SimCfg {
        SimCfg {
            scenario,
            steps: 400,
            eval_every: 50,
            seed: 0,
            n_clients: 5,
            rows_per_worker: 321,
            p: 0.65,
            lambda: 10.0,
            eta: 1.0,
            local_lr: 0.5,
            local_steps: 5,
            server_lr: 0.05,
            client_comp: "natural".into(),
            master_comp: "natural".into(),
        }
    }

    /// CI-sized run: same shapes, small shards and few steps.
    pub fn smoke(scenario: Scenario) -> SimCfg {
        SimCfg { steps: 200, eval_every: 100, rows_per_worker: 40,
                 ..SimCfg::fig3(scenario) }
    }

    /// Fleet size: the scenario override, else the run default.
    pub fn effective_clients(&self) -> usize {
        if self.scenario.clients > 0 {
            self.scenario.clients
        } else {
            self.n_clients
        }
    }

    /// Data shards the environment carries: the fleet size for ordinary
    /// scenarios, the run default for mega scenarios. The device → shard
    /// mapping itself is documented once, in [`crate::sim`].
    pub fn data_clients(&self) -> usize {
        if self.scenario.mega {
            self.n_clients
        } else {
            self.effective_clients()
        }
    }

    /// Effective `(client, master)` compressor specs for the run's first
    /// phase: the scenario's `codec=` override (applied in both
    /// directions) when present, else the run defaults.
    pub fn comps(&self) -> (String, String) {
        self.comps_for(&self.scenario)
    }

    /// [`Self::comps`] for an arbitrary phase configuration — phase
    /// boundaries may swap codecs mid-run (`phases(...)`).
    pub fn comps_for(&self, ph: &Scenario) -> (String, String) {
        match &ph.codec {
            Some(c) => (c.clone(), c.clone()),
            None => (self.client_comp.clone(), self.master_comp.clone()),
        }
    }

    /// The engine spec for this run's `alg=` choice ([`FLEET_ALGS`]) at
    /// fleet size `fleet_n`. L2GD gets the same λ stability clamp the
    /// Fig-3 sweeps use.
    pub fn alg_spec(&self, fleet_n: usize) -> anyhow::Result<AlgSpec> {
        let (cc, mc) = self.comps();
        match self.scenario.alg.as_str() {
            "l2gd" => {
                let mut alg = L2gd::new(self.p, self.lambda, self.eta, fleet_n,
                                        &cc, &mc)?;
                fig3::clamp_agg_stability(&mut alg, fleet_n);
                AlgSpec::l2gd(&alg, fleet_n)
            }
            "fedavg" => AlgSpec::fedavg(self.local_lr, self.local_steps,
                                        &cc, &mc),
            "fedopt" => AlgSpec::fedopt(self.local_lr, self.local_steps,
                                        self.server_lr, &cc, &mc),
            other => anyhow::bail!(
                "unknown fleet algorithm `{other}` (registered: {})",
                FLEET_ALGS.join(", ")),
        }
    }

    /// Series label for this run (algorithm-specific parameter echo).
    pub fn label(&self) -> String {
        let sc = &self.scenario.spec;
        match self.scenario.alg.as_str() {
            "fedavg" => format!("sim[{sc}] fedavg[{}|{}]:lr={},T={}",
                                self.client_comp, self.master_comp,
                                self.local_lr, self.local_steps),
            "fedopt" => format!("sim[{sc}] fedopt:lr={},T={},slr={}",
                                self.local_lr, self.local_steps, self.server_lr),
            _ => format!("sim[{sc}] l2gd[{}|{}]:p={},λ={}",
                         self.client_comp, self.master_comp, self.p, self.lambda),
        }
    }
}

/// The Fig-3 heterogeneous convex environment at the configured
/// *data-shard* count — built by `fig3::build_env` so the simulator can
/// never drift from the configuration the paper figures use.
pub fn build_env(cfg: &SimCfg) -> FedEnv {
    fig3::build_env(&fig3::Fig3Cfg {
        rows_per_worker: cfg.rows_per_worker,
        n_clients: cfg.data_clients(),
        eta: cfg.eta,
        seed: cfg.seed,
        ..fig3::Fig3Cfg::a1a()
    })
}

/// Documented resident-bytes ceiling for a mega run that has touched
/// `touched` clients at dimension `d`: one f32 row plus bookkeeping per
/// touched client, with 4× slack for Vec/HashMap growth doubling, plus a
/// fixed 64 KiB floor. Mega `run`s fail if the store exceeds this — the
/// bound the `scale-smoke` CI job enforces.
pub fn resident_bound_bytes(d: usize, touched: usize) -> u64 {
    (4 * (4 * d + 64) * touched + 64 * 1024) as u64
}

/// Draw `m` distinct device ids uniformly from `[0, n)` in O(m) expected
/// time — the mega-fleet cohort sampler (rejection via the reusable
/// `seen` set; with m ≪ n collisions are rare). Ids land in `out` in draw
/// order; callers sort when they need index order.
pub fn sample_device_ids(rng: &mut Rng, n: usize, m: usize,
                         seen: &mut HashSet<u32>, out: &mut Vec<u32>) {
    assert!(m <= n, "cannot draw {m} distinct ids from a fleet of {n}");
    seen.clear();
    out.clear();
    while out.len() < m {
        let i = rng.usize_below(n) as u32;
        if seen.insert(i) {
            out.push(i);
        }
    }
}

/// Counters accumulated over a simulated run.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SimStats {
    /// fresh-aggregation rounds that actually committed
    pub comm_events: u64,
    /// fresh draws with nobody available / nobody arrived in time
    pub skipped_rounds: u64,
    /// sampled devices that missed the quorum or the deadline
    pub dropped_stragglers: u64,
    /// Σ cohort size over committed rounds
    pub total_participants: u64,
    /// iterations where no device was available (clock still advances)
    pub idle_steps: u64,
    /// scheduler events processed (steps + arrival pushes + pops) — the
    /// denominator of the allocation-discipline bench
    pub events: u64,
}

impl SimStats {
    /// Mean committed-round cohort size — **well-defined (0.0, never NaN)
    /// for zero-communication runs** (e.g. a deadline so tight every
    /// round aborts), so summary JSON stays parseable.
    pub fn mean_participants(&self) -> f64 {
        if self.comm_events == 0 {
            return 0.0;
        }
        self.total_participants as f64 / self.comm_events as f64
    }
}

/// A stepping fleet simulation over a borrowed environment, driving any
/// registered fleet algorithm on the copy-on-write cohort engine.
pub struct FleetSim<'e> {
    eng: ShardedL2gdEngine<'e>,
    /// lazy O(1) per-device profiles — a fleet is never materialized
    fleet: FleetSpec,
    fleet_seed: u64,
    churn: Churn,
    churn_seed: u64,
    sample_frac: f64,
    quorum_frac: f64,
    deadline_s: f64,
    sampler: Rng,
    clock: f64,
    mean_step_s: f64,
    /// `(client, master)` compressor specs currently installed in the
    /// engine — compared against the incoming phase's to skip no-op swaps
    comp_specs: (String, String),
    stats: SimStats,
    /// sorted clients holding the current anchor; `None` = everyone (the
    /// identical inits double as the shared ξ₋₁ = 1 anchor)
    anchor_holders: Option<Vec<u32>>,
    // reusable per-step scratch (the hot loop is allocation-bounded)
    cohort: Vec<u32>,
    agg_cohort: Vec<u32>,
    arrived: Vec<u32>,
    seen: HashSet<u32>,
    queue: EventQueue<u32>,
}

impl<'e> FleetSim<'e> {
    pub fn new(cfg: &SimCfg, env: &'e FedEnv) -> anyhow::Result<FleetSim<'e>> {
        let data_n = env.n_clients();
        anyhow::ensure!(data_n == cfg.data_clients(),
                        "environment has {data_n} data shards, config wants {}",
                        cfg.data_clients());
        let fleet_n = cfg.effective_clients();
        let spec = cfg.alg_spec(fleet_n)?;
        let mut eng = ShardedL2gdEngine::from_spec(&spec, env, fleet_n)?;
        eng.enable_wire_framing();
        let fleet = cfg.scenario.fleet.clone();
        let mean_step_s = fleet.mean_step_time();
        // Wheel bucket width from the fleet's mean arrival delay
        // (compute + network latency); capacity for one round's cohort.
        let granularity =
            EventQueue::<u32>::granularity_for(mean_step_s + fleet.latency.mean());
        let cohort_cap =
            ((cfg.scenario.sample_frac * fleet_n as f64).ceil() as usize).clamp(1, fleet_n);
        Ok(FleetSim {
            eng,
            fleet,
            fleet_seed: cfg.seed ^ 0xF1EE7,
            churn: cfg.scenario.churn.clone(),
            churn_seed: cfg.seed ^ 0xC4A9,
            sample_frac: cfg.scenario.sample_frac,
            quorum_frac: cfg.scenario.quorum_frac,
            deadline_s: cfg.scenario.deadline_s,
            sampler: Rng::new(cfg.seed ^ 0x5A3E),
            clock: 0.0,
            mean_step_s,
            comp_specs: cfg.comps(),
            stats: SimStats::default(),
            anchor_holders: None,
            cohort: Vec::new(),
            agg_cohort: Vec::new(),
            arrived: Vec::new(),
            seen: HashSet::new(),
            queue: EventQueue::with_capacity_and_granularity(cohort_cap, granularity),
        })
    }

    /// Device `i`'s profile — a pure O(1) function of the fleet seed.
    fn profile(&self, i: usize) -> DeviceProfile {
        self.fleet.device(self.fleet_seed, i as u64)
    }

    pub fn clock(&self) -> f64 {
        self.clock
    }

    pub fn stats(&self) -> &SimStats {
        &self.stats
    }

    pub fn engine(&self) -> &ShardedL2gdEngine<'e> {
        &self.eng
    }

    /// Cross a phase boundary (`phases(...)`): install the new phase's
    /// fleet model, sampling/quorum/deadline knobs, and — when its
    /// `codec=` differs from what the engine currently runs — swap the
    /// compressors. Fleet size, mega mode, and the algorithm are pinned
    /// constant across phases by the scenario parser, so the engine's
    /// client state carries over untouched.
    pub fn apply_phase(&mut self, cfg: &SimCfg, ph: &Scenario) -> anyhow::Result<()> {
        self.fleet = ph.fleet.clone();
        self.mean_step_s = self.fleet.mean_step_time();
        self.churn = ph.churn.clone();
        self.sample_frac = ph.sample_frac;
        self.quorum_frac = ph.quorum_frac;
        self.deadline_s = ph.deadline_s;
        let specs = cfg.comps_for(ph);
        if specs != self.comp_specs {
            let client = crate::compress::from_spec(&specs.0)?;
            let master = crate::compress::from_spec(&specs.1)?;
            self.eng.set_compressors(client, master);
            self.comp_specs = specs;
        }
        Ok(())
    }

    /// Advance one protocol iteration at the current simulated time.
    pub fn step(&mut self, k: u64) -> anyhow::Result<()> {
        self.stats.events += 1;
        let kind = self.eng.draw();
        self.select_cohort();
        if self.cohort.is_empty() {
            if matches!(kind, StepKind::AggregateFresh) {
                self.stats.skipped_rounds += 1;
            }
            self.idle_tick();
            return Ok(());
        }
        match kind {
            StepKind::Local => {
                self.eng.step_local(&self.cohort)?;
                self.clock += self.max_cohort_step_time();
            }
            StepKind::AggregateCached => {
                // only devices holding the current anchor can aggregate
                // toward it; the rest idle through the iteration
                self.intersect_anchor_holders();
                if !self.agg_cohort.is_empty() {
                    self.eng.step_aggregate_cached(&self.agg_cohort);
                }
                self.clock += self.max_cohort_step_time();
            }
            StepKind::AggregateFresh => self.fresh_round(k)?,
        }
        Ok(())
    }

    pub fn run_steps(&mut self, from: u64, count: u64) -> anyhow::Result<()> {
        for k in from + 1..=from + count {
            self.step(k)?;
        }
        Ok(())
    }

    /// Evaluate into a `Record`, with the fleet clock as the sim-time
    /// column (replacing the engine's transport-model projection).
    pub fn evaluate(&self, step: u64) -> anyhow::Result<Record> {
        let mut rec = self.eng.evaluate(step)?;
        rec.sim_time_s = self.clock;
        // copy-on-write occupancy at each evaluation point
        registry::observe(registry::Hist::ShardOccupancy,
                          self.eng.store().materialized_rows() as u64);
        Ok(rec)
    }

    /// The event's cohort — **one id-space path at every fleet size**:
    /// draw `⌈sample · n⌉` distinct device ids in O(cohort)
    /// ([`sample_device_ids`]; a full-sample draw enumerates instead of
    /// coupon-collecting n from n), sort ascending, then drop whoever the
    /// churn hash has offline. The mega flag plays no part in selection —
    /// enumerated-fleet and mega runs draw identical cohorts for the same
    /// seed (pinned by the sampling property test).
    fn select_cohort(&mut self) {
        let n = self.eng.n_fleet();
        let (churn, seed, clock) = (&self.churn, self.churn_seed, self.clock);
        self.cohort.clear();
        let m = ((self.sample_frac * n as f64).ceil() as usize).clamp(1, n);
        if m >= n {
            self.cohort.extend(0..n as u32);
        } else {
            sample_device_ids(&mut self.sampler, n, m,
                              &mut self.seen, &mut self.cohort);
            self.cohort.sort_unstable();
        }
        self.cohort
            .retain(|&i| churn.available(seed, i as usize, clock));
    }

    /// Slowest per-iteration compute time in the current cohort.
    fn max_cohort_step_time(&self) -> f64 {
        let mut t = 0.0f64;
        for &i in &self.cohort {
            t = t.max(self.profile(i as usize).step_time_s);
        }
        t
    }

    /// `agg_cohort ← cohort ∩ anchor_holders` (both sorted).
    fn intersect_anchor_holders(&mut self) {
        self.agg_cohort.clear();
        let cohort = &self.cohort;
        match &self.anchor_holders {
            None => self.agg_cohort.extend_from_slice(cohort),
            Some(h) => {
                let (mut a, mut b) = (0usize, 0usize);
                while a < cohort.len() && b < h.len() {
                    match cohort[a].cmp(&h[b]) {
                        Ordering::Less => a += 1,
                        Ordering::Greater => b += 1,
                        Ordering::Equal => {
                            self.agg_cohort.push(cohort[a]);
                            a += 1;
                            b += 1;
                        }
                    }
                }
            }
        }
    }

    /// Nobody is online: the iteration is a fleet-wide no-op, but the
    /// clock still moves.
    fn idle_tick(&mut self) {
        self.stats.idle_steps += 1;
        self.clock += self.mean_step_s;
    }

    /// A fresh-aggregation round over the already-selected cohort:
    /// schedule upload arrivals through the event queue, close at quorum
    /// or deadline, and commit the round over whoever made it.
    fn fresh_round(&mut self, k: u64) -> anyhow::Result<()> {
        // round-lifecycle trace: the sync runner has exactly one round in
        // flight, so it always rides round slot 0 (the async runner at
        // `inflight=1` lands on the same lane and emits the same ordered
        // name sequence — pinned by the obs_trace integration test)
        obs::span_begin(obs::ROUND, obs::round_lane(0), self.clock);
        obs::instant(obs::COHORT_DRAW, obs::round_lane(0), self.clock,
                     self.cohort.len() as f64);
        self.eng.compress_uplinks(&self.cohort)?;
        // schedule arrivals: compute + latency + serialized frame transfer
        self.queue.clear();
        for &i in &self.cohort {
            let dev = self.profile(i as usize);
            let bits = self.eng.uplink_frame_bytes(i as usize) as f64 * 8.0;
            let t = self.clock + dev.step_time_s + dev.latency_s + bits / dev.up_bps;
            self.queue.push(t, i);
            self.stats.events += 1;
        }
        let m = self.cohort.len();
        registry::observe(registry::Hist::CohortSize, m as u64);
        registry::observe(registry::Hist::QueueDepth, self.queue.len() as u64);
        obs::span_begin(obs::QUORUM_WAIT, obs::round_lane(0), self.clock);
        let quorum = ((self.quorum_frac * m as f64).ceil() as usize).clamp(1, m);
        let deadline = self.clock + self.deadline_s;
        self.arrived.clear();
        let mut round_end = self.clock;
        while let Some((t, i)) = self.queue.pop() {
            self.stats.events += 1;
            if t > deadline {
                // this device and everything still queued missed the round
                self.stats.dropped_stragglers += 1 + self.queue.len() as u64;
                round_end = deadline;
                obs::instant(obs::DEADLINE_ABORT, obs::round_lane(0), deadline,
                             (1 + self.queue.len()) as f64);
                break;
            }
            self.arrived.push(i);
            obs::instant(obs::DEVICE_ARRIVAL, obs::device_lane(i as usize), t, 0.0);
            round_end = t;
            if self.arrived.len() >= quorum {
                self.stats.dropped_stragglers += self.queue.len() as u64;
                break;
            }
        }
        if self.arrived.is_empty() {
            // everyone blew the deadline: the anchor does not move, but
            // the cohort's frames were transmitted — meter them as
            // discarded traffic
            self.eng.abort_fresh(k, &self.cohort)?;
            self.stats.skipped_rounds += 1;
            self.clock = round_end.max(self.clock + self.mean_step_s);
            obs::span_end(obs::QUORUM_WAIT, obs::round_lane(0), round_end);
            obs::instant(obs::ROUND_ABORT, obs::round_lane(0), round_end, 0.0);
            obs::span_end(obs::ROUND, obs::round_lane(0), round_end);
            return Ok(());
        }
        self.arrived.sort_unstable();
        // committed-round wire volume: every sampled uplink frame crossed
        // the network (arrived or dropped) + the anchor broadcast
        let mut round_bits = 0u64;
        for &i in &self.cohort {
            round_bits += self.eng.uplink_frame_bytes(i as usize) as u64 * 8;
        }
        round_bits +=
            self.eng.downlink_frame_bytes() as u64 * 8 * self.arrived.len() as u64;
        registry::observe(registry::Hist::RoundBits, round_bits);
        self.eng.complete_fresh(k, &self.arrived, &self.cohort)?;
        // the broadcast reached only the cohort: they alone hold the new
        // anchor for subsequent cached-aggregation steps
        match &mut self.anchor_holders {
            Some(h) => {
                h.clear();
                h.extend_from_slice(&self.arrived);
            }
            None => self.anchor_holders = Some(self.arrived.clone()),
        }
        self.stats.comm_events += 1;
        self.stats.total_participants += self.arrived.len() as u64;
        // the round closes once the slowest cohort member has the anchor
        let dbits = self.eng.downlink_frame_bytes() as f64 * 8.0;
        let mut down_t = 0.0f64;
        for &i in &self.arrived {
            let dev = self.profile(i as usize);
            down_t = down_t.max(dev.latency_s + dbits / dev.down_bps);
        }
        self.clock = round_end + down_t;
        obs::span_end(obs::QUORUM_WAIT, obs::round_lane(0), round_end);
        obs::instant(obs::ROUND_COMMIT, obs::round_lane(0), round_end,
                     self.arrived.len() as f64);
        obs::span_end(obs::ROUND, obs::round_lane(0), self.clock);
        Ok(())
    }
}

/// A completed scenario run.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// the full scenario spec (overrides included) — the output key
    pub scenario: String,
    /// the fleet algorithm that ran (`l2gd` | `fedavg` | `fedopt`)
    pub alg: String,
    pub series: Series,
    pub stats: SimStats,
    pub fleet_size: u64,
    /// distinct clients that ever entered a cohort
    pub touched_clients: u64,
    /// copy-on-write store occupancy at the end of the run
    pub resident_rows: u64,
    pub resident_bytes: u64,
    /// applied fraction of the uplink byte meter
    /// ([`crate::transport::Network::uplink_goodput`]) — 1.0 for a run
    /// with no wasted or stale traffic
    pub goodput: f64,
    /// staleness accounting, filled only by the asynchronous runtime
    /// ([`super::async_runner::run`]); `None` for synchronous runs
    pub async_stats: Option<AsyncStats>,
}

impl SimResult {
    pub fn to_json(&self) -> Value {
        let last = self.series.last().expect("series has records");
        let per_device = self.resident_bytes as f64 / self.fleet_size.max(1) as f64;
        let mut pairs = vec![
            ("scenario".into(), Value::Str(self.scenario.clone())),
            ("alg".into(), Value::Str(self.alg.clone())),
            ("label".into(), Value::Str(self.series.label.clone())),
            ("steps".into(), Value::Num(last.step as f64)),
            ("fleet_size".into(), Value::Num(self.fleet_size as f64)),
            ("comm_events".into(), Value::Num(self.stats.comm_events as f64)),
            ("skipped_rounds".into(), Value::Num(self.stats.skipped_rounds as f64)),
            ("dropped_stragglers".into(),
             Value::Num(self.stats.dropped_stragglers as f64)),
            ("mean_participants".into(),
             Value::Num(self.stats.mean_participants())),
            ("idle_steps".into(), Value::Num(self.stats.idle_steps as f64)),
            ("touched_clients".into(), Value::Num(self.touched_clients as f64)),
            ("resident_rows".into(), Value::Num(self.resident_rows as f64)),
            ("resident_bytes".into(), Value::Num(self.resident_bytes as f64)),
            ("resident_bytes_per_device".into(), Value::Num(per_device)),
            ("sim_time_s".into(), Value::Num(last.sim_time_s)),
            ("bytes_up".into(), Value::Num((last.bits_up / 8) as f64)),
            ("bytes_down".into(), Value::Num((last.bits_down / 8) as f64)),
            ("final_train_loss".into(), Value::Num(last.train_loss)),
            ("final_personal_loss".into(), Value::Num(last.personal_loss)),
            ("final_test_acc".into(), Value::Num(last.test_acc)),
            ("goodput".into(), Value::Num(self.goodput)),
        ];
        if let Some(a) = &self.async_stats {
            pairs.push(("async_dispatched".into(),
                        Value::Num(a.dispatched_rounds as f64)));
            pairs.push(("applied_updates".into(),
                        Value::Num(a.applied_updates as f64)));
            pairs.push(("stale_discarded".into(),
                        Value::Num(a.stale_discarded as f64)));
            pairs.push(("staleness_mean".into(), Value::Num(a.mean_staleness())));
            pairs.push(("staleness_p95".into(),
                        Value::Num(a.p95_staleness() as f64)));
            pairs.push(("staleness_hist".into(), Value::Arr(
                a.histogram().iter().map(|&c| Value::Num(c as f64)).collect())));
        }
        Value::obj(pairs)
    }
}

/// Run one scenario end to end (environment build + simulation + eval
/// cadence) and return the sim-time series plus counters. Mega runs are
/// additionally checked against the documented copy-on-write resident
/// bound — a sharded store that silently densified fails the run (and the
/// `scale-smoke` CI job with it).
pub fn run(cfg: &SimCfg) -> anyhow::Result<SimResult> {
    let env = build_env(cfg);
    env.pool.enable_profiling();
    let mut sim = FleetSim::new(cfg, &env)?;
    let mut series = Series::new(cfg.label());
    series.records.push(sim.evaluate(0)?);
    let changes = cfg.scenario.phase_changes();
    let mut next = 0usize;
    for k in 1..=cfg.steps {
        while next < changes.len() && changes[next].0 <= k {
            sim.apply_phase(cfg, changes[next].1)?;
            next += 1;
        }
        sim.step(k)?;
        if k % cfg.eval_every == 0 || k == cfg.steps {
            series.records.push(sim.evaluate(k)?);
            if !series.records.last().unwrap().is_finite() {
                break; // diverged: record it and stop
            }
        }
    }
    let store = sim.engine().store();
    let touched = sim.engine().touched_clients();
    anyhow::ensure!(store.materialized_rows() <= touched,
                    "store holds {} rows for {touched} touched clients",
                    store.materialized_rows());
    if cfg.scenario.mega {
        let bound = resident_bound_bytes(store.dim(), touched);
        anyhow::ensure!(
            (store.resident_bytes() as u64) <= bound,
            "mega run resident bytes {} exceed the documented bound {bound} \
             ({touched} touched clients of {})",
            store.resident_bytes(), store.len());
    }
    for ns in env.pool.busy_ns() {
        registry::observe(registry::Hist::WorkerBusyNs, ns);
    }
    registry::set_gauge(registry::Gauge::PoolUtilization, env.pool.utilization());
    Ok(SimResult {
        scenario: cfg.scenario.spec.clone(),
        alg: cfg.scenario.alg.clone(),
        series,
        stats: sim.stats().clone(),
        fleet_size: store.len() as u64,
        touched_clients: touched as u64,
        resident_rows: store.materialized_rows() as u64,
        resident_bytes: store.resident_bytes() as u64,
        goodput: sim.engine().net().uplink_goodput(),
        async_stats: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::scenario;

    fn smoke(spec: &str, seed: u64) -> SimCfg {
        let mut cfg = SimCfg::smoke(scenario::from_spec(spec).unwrap());
        cfg.seed = seed;
        cfg
    }

    #[test]
    fn uniform_scenario_learns_and_frames_bytes() {
        let res = run(&smoke("uniform", 0)).unwrap();
        let first = res.series.records.first().unwrap();
        let last = res.series.last().unwrap();
        assert!(last.personal_loss < first.personal_loss,
                "loss {} -> {}", first.personal_loss, last.personal_loss);
        assert!(res.stats.comm_events > 0);
        assert_eq!(res.stats.skipped_rounds, 0);
        assert_eq!(res.stats.dropped_stragglers, 0);
        // full participation every committed round
        assert_eq!(res.stats.total_participants, res.stats.comm_events * 5);
        assert_eq!(last.participants, 5);
        // frame metering: whole bytes on the wire, header overhead included
        assert_eq!(last.bits_up % 8, 0);
        assert!(last.sim_time_s > 0.0);
        // every client of a 5-device uniform fleet diverges immediately
        assert_eq!(res.fleet_size, 5);
        assert_eq!(res.touched_clients, 5);
    }

    #[test]
    fn phased_run_swaps_codecs_and_stays_deterministic() {
        let spec = "phases(uniform @rounds=60; \
                    uniform:codec=qsgd:8,sample=0.6)";
        let a = run(&smoke(spec, 7)).unwrap();
        let b = run(&smoke(spec, 7)).unwrap();
        assert_eq!(a.series.records.len(), b.series.records.len());
        for (ra, rb) in a.series.records.iter().zip(&b.series.records) {
            assert_eq!(ra.train_loss, rb.train_loss);
            assert_eq!(ra.bits_up, rb.bits_up);
        }
        assert!(a.stats.comm_events > 0);
        assert!(a.series.last().unwrap().train_loss.is_finite());
    }

    #[test]
    fn phase_zero_prefix_matches_the_unphased_run() {
        // before the first boundary a phased run is bit-identical to a
        // plain run of its phase-0 configuration
        let cfg_ph = smoke("phases(uniform @rounds=60; \
                            uniform:codec=qsgd:8)", 7);
        let cfg_u = smoke("uniform", 7);
        let env = build_env(&cfg_u);
        let mut s1 = FleetSim::new(&cfg_ph, &env).unwrap();
        let mut s2 = FleetSim::new(&cfg_u, &env).unwrap();
        s1.run_steps(0, 60).unwrap();
        s2.run_steps(0, 60).unwrap();
        let (r1, r2) = (s1.evaluate(60).unwrap(), s2.evaluate(60).unwrap());
        assert_eq!(r1.train_loss, r2.train_loss);
        assert_eq!(r1.bits_up, r2.bits_up);
        assert_eq!(r1.sim_time_s, r2.sim_time_s);
    }

    #[test]
    fn deterministic_across_reruns() {
        let a = run(&smoke("straggler-heavy", 3)).unwrap();
        let b = run(&smoke("straggler-heavy", 3)).unwrap();
        assert_eq!(a.series.records.len(), b.series.records.len());
        for (ra, rb) in a.series.records.iter().zip(&b.series.records) {
            assert_eq!(ra.train_loss, rb.train_loss);
            assert_eq!(ra.personal_loss, rb.personal_loss);
            assert_eq!(ra.bits_up, rb.bits_up);
            assert_eq!(ra.sim_time_s, rb.sim_time_s);
            assert_eq!(ra.participants, rb.participants);
        }
        assert_eq!(a.stats.dropped_stragglers, b.stats.dropped_stragglers);
    }

    #[test]
    fn straggler_scenario_drops_and_still_learns() {
        let mut cfg = smoke("straggler-heavy:clients=12,quorum=0.5,deadline=0.5", 1);
        cfg.steps = 300;
        let res = run(&cfg).unwrap();
        let last = res.series.last().unwrap();
        assert!(res.stats.dropped_stragglers > 0, "{:?}", res.stats);
        assert!(res.stats.mean_participants() < 12.0);
        assert!(res.stats.mean_participants() >= 1.0);
        assert!(last.personal_loss.is_finite());
        assert!(last.personal_loss < res.series.records[0].personal_loss);
        // every sampled device transmitted — arrived or dropped, its frame
        // bytes meter. Natural wire at d=123: 9·123 bits → 139 B payload +
        // 22 B header per frame. (Arrivals here are far inside the 0.5 s
        // deadline, so no round skips and the identity is exact.)
        assert_eq!(res.stats.skipped_rounds, 0, "{:?}", res.stats);
        let frame_bits = (22 + 139) * 8;
        assert_eq!(last.bits_up,
                   (res.stats.total_participants + res.stats.dropped_stragglers)
                       * frame_bits);
    }

    #[test]
    fn diurnal_churn_varies_participation() {
        let mut cfg = smoke("diurnal-churn:clients=16", 2);
        cfg.steps = 400;
        let res = run(&cfg).unwrap();
        assert!(res.stats.comm_events > 0);
        // churn must bite: some committed round ran below full fleet, or
        // rounds were skipped outright
        assert!(res.stats.total_participants < res.stats.comm_events * 16
                    || res.stats.skipped_rounds > 0,
                "{:?}", res.stats);
        assert!(res.series.last().unwrap().train_loss.is_finite());
    }

    /// Satellite: a deadline so tight every round aborts produces a
    /// zero-communication run whose summary is still fully defined —
    /// mean_participants is 0 (not NaN) and the JSON parses.
    #[test]
    fn zero_comm_event_run_has_well_defined_summary() {
        let mut cfg = smoke("straggler-heavy:clients=8,deadline=0.000001", 6);
        cfg.steps = 150;
        let res = run(&cfg).unwrap();
        assert_eq!(res.stats.comm_events, 0, "{:?}", res.stats);
        assert!(res.stats.skipped_rounds > 0);
        assert_eq!(res.stats.total_participants, 0);
        assert_eq!(res.stats.mean_participants(), 0.0);
        // the wasted frames still metered
        assert!(res.series.last().unwrap().bits_up > 0);
        let text = res.to_json().to_string_pretty();
        assert!(!text.contains("NaN"), "summary contains NaN: {text}");
        let v = crate::util::json::parse(&text).unwrap();
        assert_eq!(v.get("comm_events").unwrap().as_f64(), Some(0.0));
        assert_eq!(v.get("mean_participants").unwrap().as_f64(), Some(0.0));
    }

    /// The mega path at a reduced (but still mega-mode) fleet: O(cohort)
    /// sampling, lazy profiles, sparse store.
    #[test]
    fn megafleet_path_runs_sparse_at_reduced_scale() {
        let mut cfg = smoke("megafleet:clients=100000,sample=0.001", 4);
        cfg.steps = 40;
        cfg.eval_every = 20;
        let res = run(&cfg).unwrap();
        assert_eq!(res.fleet_size, 100_000);
        assert!(res.touched_clients > 0);
        // ~100-device cohorts over 40 events: a sliver of the fleet
        assert!(res.touched_clients < 8_000, "{} touched", res.touched_clients);
        assert!(res.resident_rows <= res.touched_clients);
        let last = res.series.last().unwrap();
        assert!(last.train_loss.is_finite());
        assert!(last.personal_loss.is_finite());
    }

    /// The scenario grammar's `alg=` key swaps the engine's schedule: the
    /// FedAvg cadence commits exactly one round per T+1 iterations under
    /// full participation, and the run still learns and frames bytes.
    #[test]
    fn fedavg_scenario_runs_and_communicates_on_cadence() {
        let mut cfg = smoke("uniform:alg=fedavg", 8);
        cfg.steps = 120;
        let res = run(&cfg).unwrap();
        assert_eq!(res.alg, "fedavg");
        // T = 5 local iterations then one fresh round ⇒ 120 / 6 = 20
        assert_eq!(res.stats.comm_events, 20, "{:?}", res.stats);
        assert_eq!(res.stats.skipped_rounds, 0);
        let last = res.series.last().unwrap();
        assert!(last.train_loss < res.series.records[0].train_loss,
                "fedavg fleet run must learn");
        assert_eq!(last.bits_up % 8, 0, "framed bytes on the wire");
        assert_eq!(last.participants, 5);
        let v = crate::util::json::parse(&res.to_json().to_string_pretty()).unwrap();
        assert_eq!(v.get("alg").unwrap().as_str(), Some("fedavg"));
    }

    #[test]
    fn fedopt_scenario_runs_and_learns() {
        let mut cfg = smoke("uniform:alg=fedopt", 9);
        cfg.steps = 120;
        let res = run(&cfg).unwrap();
        assert_eq!(res.alg, "fedopt");
        assert_eq!(res.stats.comm_events, 20);
        let last = res.series.last().unwrap();
        assert!(last.train_loss.is_finite());
        assert!(last.train_loss < res.series.records[0].train_loss,
                "fedopt fleet run must learn");
    }

    #[test]
    fn summary_json_roundtrips() {
        let res = run(&smoke("uniform", 4)).unwrap();
        let text = res.to_json().to_string_pretty();
        let v = crate::util::json::parse(&text).unwrap();
        assert_eq!(v.get("scenario").unwrap().as_str(), Some("uniform"));
        assert!(v.get("sim_time_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(v.get("bytes_up").unwrap().as_f64().unwrap() > 0.0);
        assert!(v.get("fleet_size").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn sample_device_ids_draws_distinct_in_range() {
        let mut rng = Rng::new(9);
        let mut seen = HashSet::new();
        let mut out = Vec::new();
        sample_device_ids(&mut rng, 1_000_000, 500, &mut seen, &mut out);
        assert_eq!(out.len(), 500);
        let mut sorted = out.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 500, "ids must be distinct");
        assert!(sorted.iter().all(|&i| (i as usize) < 1_000_000));
        // reuse draws a fresh, different cohort
        let prev = out.clone();
        sample_device_ids(&mut rng, 1_000_000, 500, &mut seen, &mut out);
        assert_ne!(prev, out);
    }
}
