//! Scenario presets behind a small spec grammar (mirroring the codec
//! registry's UX: unknown names list what exists).
//!
//! Grammar:
//!
//! ```text
//! scenario := name [":" kv ("," kv)*]
//! kv       := key "=" value
//! ```
//!
//! Presets: `uniform`, `lognormal-wan`, `diurnal-churn`,
//! `straggler-heavy`, `async-bursty`, `megafleet`, `megafleet-churn`,
//! `megafleet-fedavg`, `megafleet-async`.
//! Override keys:
//!
//! * `clients=N`   — fleet size (0 = inherit the run default)
//! * `sample=F`    — fraction of the fleet drawn per event, (0, 1]
//!   (drawn devices that churn has offline simply drop out of the
//!   cohort — one id-space sampling path at every fleet size)
//! * `quorum=F`    — fraction of the sampled cohort to wait for, (0, 1]
//!   (the "first k of m" over-selection policy)
//! * `deadline=S`  — straggler deadline in seconds (`inf` = wait for the
//!   quorum however long it takes)
//! * `alg=A`       — fleet algorithm: one of
//!   [`crate::algorithms::FLEET_ALGS`] (`l2gd` | `fedavg` | `fedopt`);
//!   unknown names list what is registered
//! * `async=D`     — dispatch discipline: `sync` (one round at a time) or
//!   `buffered` (FedBuff-style overlapping rounds —
//!   [`crate::sim::async_runner`])
//! * `buffer=K`    — updates per buffered aggregate; `cohort` closes each
//!   round on its own quorum instead (requires `async=buffered`)
//! * `inflight=M`  — overlapping dispatched cohorts allowed, ≥ 1
//!   (requires `async=buffered`)
//! * `stale=W`     — staleness weight `const` | `inv` | `poly[:A]`
//!   ([`StalenessWeight`]; requires `async=buffered`)
//! * `max_stale=S` — discard updates staler than S server versions
//!   (requires `async=buffered`)
//!
//! Example: `straggler-heavy:clients=20,sample=0.5,quorum=0.8,deadline=2`.
//! Async example: `uniform:async=buffered,buffer=4,inflight=8,stale=inv`.
//!
//! ### Mega fleets
//! The `megafleet*` presets (and any scenario whose fleet reaches
//! [`MEGA_THRESHOLD`] devices) run in **mega mode**. Cohort selection is
//! the same O(cohort) id-space draw at every fleet size; the flag only
//! switches on the fleet-scale bookkeeping: touched-mode evaluation in
//! the engine and the resident-bytes bound `runner::run` enforces over
//! the copy-on-write store. (Device profiles are lazy O(1) lookups
//! everywhere — a fleet is never materialized.)

use super::fleet::{Churn, Dist, FleetSpec};
use crate::algorithms::FLEET_ALGS;
use crate::protocol::{AsyncSchedule, StalenessWeight};

#[derive(Clone, Debug)]
pub struct Scenario {
    /// preset name (`uniform`, `straggler-heavy`, …)
    pub name: String,
    /// the full spec this scenario was parsed from, overrides included —
    /// the key for output files and summaries, so two variants of one
    /// preset stay distinguishable
    pub spec: String,
    /// 0 = inherit the caller's default fleet size
    pub clients: usize,
    pub fleet: FleetSpec,
    pub churn: Churn,
    /// fraction of the fleet drawn per communication event (churn then
    /// filters the draw down to the cohort)
    pub sample_frac: f64,
    /// fraction of the sampled cohort whose arrival completes the round
    pub quorum_frac: f64,
    /// straggler deadline per round, seconds (INFINITY = no deadline)
    pub deadline_s: f64,
    /// fleet algorithm driving the engine: one of
    /// [`crate::algorithms::FLEET_ALGS`]
    pub alg: String,
    /// mega mode: touched-mode evaluation + enforced resident-bytes bound
    /// (forced on whenever the fleet reaches [`MEGA_THRESHOLD`])
    pub mega: bool,
    /// dispatch discipline: synchronous one-round-at-a-time or buffered
    /// overlapping rounds (`async` is a Rust keyword, hence the name)
    pub async_sched: AsyncSchedule,
}

/// Fleet size at which a scenario is promoted to mega mode regardless of
/// preset — beyond this, O(fleet)-per-event bookkeeping is off the table.
pub const MEGA_THRESHOLD: usize = 65_536;

pub const PRESETS: &[(&str, &str)] = &[
    ("uniform",
     "homogeneous fleet, zero latency, always on, full participation — \
      reproduces the lockstep engine series bit for bit"),
    ("lognormal-wan",
     "log-normal compute and WAN link distributions, always on, full \
      cohort (heavy-tailed round times)"),
    ("diurnal-churn",
     "day/night availability cycle over a uniform fleet; whoever is \
      online participates"),
    ("straggler-heavy",
     "bimodal phone-vs-laptop fleet; over-selects and closes each round \
      at a 60% quorum under a 2 s deadline"),
    ("async-bursty",
     "bimodal fleet under bursty windowed availability, running the \
      buffered asynchronous runtime: 6 cohorts in flight, 6-update \
      buffer, 1/(1+s) staleness weights"),
    ("megafleet",
     "one million always-on phone-vs-laptop devices, 0.02% sampled per \
      event (≈200-device cohorts), 90% quorum under a 5 s deadline — \
      lazy profiles, copy-on-write client state"),
    ("megafleet-churn",
     "the megafleet under a diurnal availability cycle: sampled devices \
      that are offline simply miss the event"),
    ("megafleet-fedavg",
     "the megafleet fleet running the FedAvg baseline (alg=fedavg): fixed \
      local-step cadence, cohort resets onto the broadcast — the \
      engine-vs-engine comparison the paper's bits accounting needs"),
    ("megafleet-async",
     "the megafleet under the buffered asynchronous runtime: 4 cohorts in \
      flight, 64-update buffer, 1/(1+s) staleness weights — overlapping \
      rounds at one million devices under the same resident-bytes bound"),
];

/// Sorted preset names (error messages, docs, CLI listings).
pub fn preset_names() -> Vec<&'static str> {
    PRESETS.iter().map(|(n, _)| *n).collect()
}

fn preset(name: &str) -> Option<Scenario> {
    let uniform_fleet = FleetSpec {
        step_time: Dist::Fixed(0.01),
        up_bw: Dist::Fixed(10e6),
        down_bw: Dist::Fixed(10e6),
        latency: Dist::Fixed(0.0),
    };
    Some(match name {
        "uniform" => Scenario {
            name: name.into(),
            spec: name.into(),
            clients: 0,
            fleet: uniform_fleet,
            churn: Churn::AlwaysOn,
            sample_frac: 1.0,
            quorum_frac: 1.0,
            deadline_s: f64::INFINITY,
            alg: "l2gd".into(),
            mega: false,
            async_sched: AsyncSchedule::RoundSync,
        },
        "lognormal-wan" => Scenario {
            name: name.into(),
            spec: name.into(),
            clients: 0,
            fleet: FleetSpec {
                step_time: Dist::LogNormal { mu: (0.01f64).ln(), sigma: 0.6 },
                up_bw: Dist::LogNormal { mu: (5e6f64).ln(), sigma: 0.8 },
                down_bw: Dist::LogNormal { mu: (20e6f64).ln(), sigma: 0.8 },
                latency: Dist::LogNormal { mu: (0.04f64).ln(), sigma: 0.5 },
            },
            churn: Churn::AlwaysOn,
            sample_frac: 1.0,
            quorum_frac: 1.0,
            deadline_s: f64::INFINITY,
            alg: "l2gd".into(),
            mega: false,
            async_sched: AsyncSchedule::RoundSync,
        },
        "diurnal-churn" => Scenario {
            name: name.into(),
            spec: name.into(),
            clients: 0,
            fleet: FleetSpec {
                step_time: Dist::Uniform { lo: 0.005, hi: 0.02 },
                up_bw: Dist::Uniform { lo: 2e6, hi: 20e6 },
                down_bw: Dist::Uniform { lo: 10e6, hi: 50e6 },
                latency: Dist::Uniform { lo: 0.01, hi: 0.05 },
            },
            // a "day" compressed to one simulated minute: shipped runs
            // total tens of simulated seconds (local steps are 5–20 ms),
            // so the cycle must fit inside that or the preset degenerates
            // to static dropout (availability is re-drawn per 1/24-period
            // slot = 2.5 s here)
            churn: Churn::Diurnal { base: 0.55, amplitude: 0.4, period_s: 60.0 },
            sample_frac: 1.0,
            quorum_frac: 1.0,
            deadline_s: f64::INFINITY,
            alg: "l2gd".into(),
            mega: false,
            async_sched: AsyncSchedule::RoundSync,
        },
        "straggler-heavy" => Scenario {
            name: name.into(),
            spec: name.into(),
            clients: 0,
            fleet: FleetSpec {
                // 30% phones: 16× slower compute, 20× thinner uplink
                step_time: Dist::Bimodal { p_slow: 0.3, fast: 0.005, slow: 0.08 },
                up_bw: Dist::Bimodal { p_slow: 0.3, fast: 20e6, slow: 1e6 },
                down_bw: Dist::Bimodal { p_slow: 0.3, fast: 50e6, slow: 4e6 },
                latency: Dist::Uniform { lo: 0.01, hi: 0.1 },
            },
            churn: Churn::AlwaysOn,
            sample_frac: 1.0,
            quorum_frac: 0.6,
            deadline_s: 2.0,
            alg: "l2gd".into(),
            mega: false,
            async_sched: AsyncSchedule::RoundSync,
        },
        "async-bursty" => Scenario {
            name: name.into(),
            spec: name.into(),
            clients: 24,
            fleet: FleetSpec {
                // the straggler-heavy phone-vs-laptop mix: slow devices
                // are what makes rounds overlap interestingly
                step_time: Dist::Bimodal { p_slow: 0.3, fast: 0.005, slow: 0.08 },
                up_bw: Dist::Bimodal { p_slow: 0.3, fast: 20e6, slow: 1e6 },
                down_bw: Dist::Bimodal { p_slow: 0.3, fast: 50e6, slow: 4e6 },
                latency: Dist::Uniform { lo: 0.01, hi: 0.1 },
            },
            // bursty availability: iid 70%-up windows, re-drawn every 10 s
            churn: Churn::Windowed { up_frac: 0.7, period_s: 10.0 },
            sample_frac: 0.35,
            quorum_frac: 0.6,
            deadline_s: 2.0,
            alg: "l2gd".into(),
            mega: false,
            async_sched: AsyncSchedule::Buffered {
                buffer: 6,
                max_in_flight: 6,
                stale: StalenessWeight::Inverse,
                max_stale: 16,
            },
        },
        "megafleet" | "megafleet-churn" | "megafleet-fedavg"
        | "megafleet-async" => Scenario {
            name: name.into(),
            spec: name.into(),
            clients: 1_000_000,
            fleet: FleetSpec {
                // the straggler-heavy phone-vs-laptop mix at fleet scale
                step_time: Dist::Bimodal { p_slow: 0.3, fast: 0.005, slow: 0.08 },
                up_bw: Dist::Bimodal { p_slow: 0.3, fast: 20e6, slow: 1e6 },
                down_bw: Dist::Bimodal { p_slow: 0.3, fast: 50e6, slow: 4e6 },
                latency: Dist::Uniform { lo: 0.01, hi: 0.1 },
            },
            churn: if name == "megafleet-churn" {
                // the compressed one-minute "day" of diurnal-churn
                Churn::Diurnal { base: 0.55, amplitude: 0.4, period_s: 60.0 }
            } else {
                Churn::AlwaysOn
            },
            // ≈200-device cohorts out of 10⁶ — well under the ISSUE's ≤1%
            // ceiling, and the per-event cost at which the engine is
            // asserted allocation-bounded
            sample_frac: 0.0002,
            quorum_frac: 0.9,
            deadline_s: 5.0,
            alg: if name == "megafleet-fedavg" { "fedavg" } else { "l2gd" }.into(),
            mega: true,
            // a 64-update buffer against ≈180-device cohorts guarantees
            // several mid-round aggregates per dispatch — the staleness
            // histogram is non-degenerate by construction
            async_sched: if name == "megafleet-async" {
                AsyncSchedule::Buffered {
                    buffer: 64,
                    max_in_flight: 4,
                    stale: StalenessWeight::Inverse,
                    max_stale: 16,
                }
            } else {
                AsyncSchedule::RoundSync
            },
        },
        _ => return None,
    })
}

/// Parse a scenario spec (`name[:key=val,...]`, see the module docs).
pub fn from_spec(spec: &str) -> anyhow::Result<Scenario> {
    let spec = spec.trim();
    anyhow::ensure!(!spec.is_empty(), "empty scenario spec");
    let (name, args) = match spec.split_once(':') {
        Some((n, a)) => (n.trim(), Some(a)),
        None => (spec, None),
    };
    let mut sc = preset(name).ok_or_else(|| {
        anyhow::anyhow!("unknown scenario `{name}` (known: {})",
                        preset_names().join(", "))
    })?;
    // async overrides are collected during the loop and assembled after —
    // they only make sense together (and `buffer=…` without a buffered
    // discipline is an error, not a silent no-op)
    let mut a_buffered: Option<bool> = None;
    let mut a_buffer: Option<usize> = None;
    let mut a_inflight: Option<usize> = None;
    let mut a_stale: Option<StalenessWeight> = None;
    let mut a_max_stale: Option<u64> = None;
    if let Some(args) = args {
        for kv in args.split(',') {
            let kv = kv.trim();
            let (key, val) = kv.split_once('=').ok_or_else(|| {
                anyhow::anyhow!("scenario option `{kv}` is not key=value")
            })?;
            let val = val.trim();
            let fval = || -> anyhow::Result<f64> {
                val.parse::<f64>()
                    .map_err(|e| anyhow::anyhow!("{key}={val}: {e}"))
            };
            match key.trim() {
                "clients" => {
                    sc.clients = val
                        .parse::<usize>()
                        .map_err(|e| anyhow::anyhow!("clients={val}: {e}"))?;
                }
                "sample" => sc.sample_frac = fval()?,
                "quorum" => sc.quorum_frac = fval()?,
                "deadline" => sc.deadline_s = fval()?,
                "alg" => sc.alg = val.to_string(),
                "async" => {
                    a_buffered = Some(match val {
                        "buffered" => true,
                        "sync" => false,
                        other => anyhow::bail!(
                            "async={other}: unknown dispatch discipline \
                             (known: buffered, sync)"),
                    });
                }
                "buffer" => {
                    a_buffer = Some(if val == "cohort" {
                        0
                    } else {
                        let k = val.parse::<usize>().map_err(|e| {
                            anyhow::anyhow!("buffer={val}: {e}")
                        })?;
                        anyhow::ensure!(k > 0,
                                        "buffer=0 is not a buffer; use \
                                         buffer=cohort for per-round closes");
                        k
                    });
                }
                "inflight" => {
                    a_inflight = Some(val.parse::<usize>().map_err(|e| {
                        anyhow::anyhow!("inflight={val}: {e}")
                    })?);
                }
                "stale" => a_stale = Some(StalenessWeight::from_spec(val)?),
                "max_stale" => {
                    a_max_stale = Some(val.parse::<u64>().map_err(|e| {
                        anyhow::anyhow!("max_stale={val}: {e}")
                    })?);
                }
                other => anyhow::bail!(
                    "unknown scenario option `{other}` (known: clients, \
                     sample, quorum, deadline, alg, async, buffer, \
                     inflight, stale, max_stale)"),
            }
        }
    }
    let buffered = a_buffered.unwrap_or(sc.async_sched.is_async());
    if buffered {
        // start from the preset's buffered parameters (or the
        // synchronous-equivalent defaults) and lay overrides on top
        let (mut buffer, mut inflight, mut stale, mut max_stale) =
            match sc.async_sched {
                AsyncSchedule::Buffered { buffer, max_in_flight, stale,
                                          max_stale } => {
                    (buffer, max_in_flight, stale, max_stale)
                }
                AsyncSchedule::RoundSync => {
                    (0, 1, StalenessWeight::Constant, 16)
                }
            };
        if let Some(k) = a_buffer {
            buffer = k;
        }
        if let Some(m) = a_inflight {
            inflight = m;
        }
        if let Some(w) = a_stale {
            stale = w;
        }
        if let Some(s) = a_max_stale {
            max_stale = s;
        }
        anyhow::ensure!(inflight >= 1, "inflight={inflight} must be ≥ 1");
        sc.async_sched = AsyncSchedule::Buffered {
            buffer,
            max_in_flight: inflight,
            stale,
            max_stale,
        };
    } else {
        for (key, given) in [("buffer", a_buffer.is_some()),
                             ("inflight", a_inflight.is_some()),
                             ("stale", a_stale.is_some()),
                             ("max_stale", a_max_stale.is_some())] {
            anyhow::ensure!(!given,
                            "scenario option `{key}` requires async=buffered");
        }
        sc.async_sched = AsyncSchedule::RoundSync;
    }
    anyhow::ensure!(FLEET_ALGS.contains(&sc.alg.as_str()),
                    "unknown fleet algorithm `{}` (registered: {})",
                    sc.alg, FLEET_ALGS.join(", "));
    anyhow::ensure!(sc.sample_frac > 0.0 && sc.sample_frac <= 1.0,
                    "sample={} outside (0, 1]", sc.sample_frac);
    anyhow::ensure!(sc.quorum_frac > 0.0 && sc.quorum_frac <= 1.0,
                    "quorum={} outside (0, 1]", sc.quorum_frac);
    anyhow::ensure!(sc.deadline_s > 0.0, "deadline={} must be positive",
                    sc.deadline_s);
    // a fleet this size cannot afford O(fleet)-per-event bookkeeping,
    // whatever the preset says
    if sc.clients >= MEGA_THRESHOLD {
        sc.mega = true;
    }
    sc.spec = spec.to_string();
    Ok(sc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_preset_parses() {
        for &(name, _) in PRESETS {
            let sc = from_spec(name).unwrap();
            assert_eq!(sc.name, name);
        }
    }

    #[test]
    fn unknown_scenario_lists_presets() {
        let err = format!("{:#}", from_spec("5g-dreams").unwrap_err());
        assert!(err.contains("unknown scenario `5g-dreams`"), "{err}");
        for &(name, _) in PRESETS {
            assert!(err.contains(name), "error should list `{name}`: {err}");
        }
    }

    #[test]
    fn overrides_apply() {
        let sc = from_spec("straggler-heavy:clients=20,sample=0.5,\
                            quorum=0.8,deadline=3.5")
            .unwrap();
        assert_eq!(sc.name, "straggler-heavy");
        // the full spec survives as the output key, so two variants of
        // one preset stay distinguishable
        assert!(sc.spec.contains("deadline=3.5"), "{}", sc.spec);
        assert_eq!(sc.clients, 20);
        assert_eq!(sc.sample_frac, 0.5);
        assert_eq!(sc.quorum_frac, 0.8);
        assert_eq!(sc.deadline_s, 3.5);
        // untouched preset fields survive
        assert_eq!(sc.churn, Churn::AlwaysOn);
    }

    #[test]
    fn bad_overrides_are_rejected() {
        assert!(from_spec("uniform:sample=0").is_err());
        assert!(from_spec("uniform:sample=1.5").is_err());
        assert!(from_spec("uniform:quorum=-1").is_err());
        assert!(from_spec("uniform:deadline=0").is_err());
        assert!(from_spec("uniform:sample").is_err(), "missing =value");
        assert!(from_spec("uniform:warp=9").is_err(), "unknown key");
        assert!(from_spec("").is_err());
    }

    #[test]
    fn megafleet_presets_are_mega_and_sparse() {
        for name in ["megafleet", "megafleet-churn"] {
            let sc = from_spec(name).unwrap();
            assert!(sc.mega, "{name}");
            assert!(sc.clients >= 1_000_000, "{name}: {} clients", sc.clients);
            // ≤ 1% sampling is the ISSUE's ceiling for the preset
            assert!(sc.sample_frac <= 0.01, "{name}: sample {}", sc.sample_frac);
            assert!(sc.deadline_s.is_finite());
        }
        assert_eq!(from_spec("megafleet").unwrap().churn, Churn::AlwaysOn);
        assert!(matches!(from_spec("megafleet-churn").unwrap().churn,
                         Churn::Diurnal { .. }));
        // shrinking the fleet below the threshold drops mega promotion
        // only via the explicit preset flag (still mega — preset says so)
        let small = from_spec("megafleet:clients=1000").unwrap();
        assert!(small.mega, "preset keeps mega semantics at any size");
        // and a big enough ordinary preset is promoted
        let promoted = from_spec("straggler-heavy:clients=100000").unwrap();
        assert!(promoted.mega);
        let not_promoted = from_spec("straggler-heavy:clients=1000").unwrap();
        assert!(!not_promoted.mega);
    }

    #[test]
    fn alg_key_selects_and_validates_the_algorithm() {
        assert_eq!(from_spec("uniform").unwrap().alg, "l2gd");
        assert_eq!(from_spec("uniform:alg=fedavg").unwrap().alg, "fedavg");
        assert_eq!(from_spec("straggler-heavy:alg=fedopt,clients=10").unwrap().alg,
                   "fedopt");
        // the preset bakes the algorithm in; an override still wins
        assert_eq!(from_spec("megafleet-fedavg").unwrap().alg, "fedavg");
        assert_eq!(from_spec("megafleet-fedavg:alg=l2gd").unwrap().alg, "l2gd");
        // unknown algorithms list what is registered
        let err = format!("{:#}", from_spec("uniform:alg=dropout-sgd").unwrap_err());
        assert!(err.contains("unknown fleet algorithm `dropout-sgd`"), "{err}");
        for &name in crate::algorithms::FLEET_ALGS {
            assert!(err.contains(name), "error should list `{name}`: {err}");
        }
    }

    #[test]
    fn megafleet_fedavg_preset_is_mega_with_fedavg() {
        let sc = from_spec("megafleet-fedavg").unwrap();
        assert!(sc.mega);
        assert_eq!(sc.alg, "fedavg");
        assert_eq!(sc.clients, 1_000_000);
        assert_eq!(sc.churn, Churn::AlwaysOn);
        assert!(sc.sample_frac <= 0.01);
    }

    #[test]
    fn uniform_preset_is_the_lockstep_configuration() {
        let sc = from_spec("uniform").unwrap();
        assert_eq!(sc.sample_frac, 1.0);
        assert_eq!(sc.quorum_frac, 1.0);
        assert_eq!(sc.churn, Churn::AlwaysOn);
        assert!(sc.deadline_s.is_infinite());
        assert_eq!(sc.fleet.latency, Dist::Fixed(0.0));
        assert_eq!(sc.async_sched, AsyncSchedule::RoundSync);
    }

    #[test]
    fn async_keys_parse_and_assemble() {
        let sc = from_spec("uniform:async=buffered,buffer=4,inflight=8,\
                            stale=inv,max_stale=9")
            .unwrap();
        assert_eq!(sc.async_sched,
                   AsyncSchedule::Buffered {
                       buffer: 4,
                       max_in_flight: 8,
                       stale: StalenessWeight::Inverse,
                       max_stale: 9,
                   });
        // enabling without parameters gets the synchronous-equivalent
        // defaults: per-cohort buffering, one round in flight, constant
        // weights
        let sc = from_spec("uniform:async=buffered").unwrap();
        assert_eq!(sc.async_sched,
                   AsyncSchedule::Buffered {
                       buffer: 0,
                       max_in_flight: 1,
                       stale: StalenessWeight::Constant,
                       max_stale: 16,
                   });
        // buffer=cohort is the explicit spelling of per-round closes
        let sc = from_spec("uniform:async=buffered,buffer=cohort,inflight=3")
            .unwrap();
        assert!(matches!(sc.async_sched,
                         AsyncSchedule::Buffered { buffer: 0,
                                                   max_in_flight: 3, .. }));
        // poly weights thread through
        let sc = from_spec("uniform:async=buffered,stale=poly:2").unwrap();
        assert!(matches!(sc.async_sched,
                         AsyncSchedule::Buffered {
                             stale: StalenessWeight::Polynomial { .. }, ..
                         }));
    }

    #[test]
    fn async_keys_require_buffered_mode() {
        for spec in ["uniform:buffer=4", "uniform:inflight=2",
                     "uniform:stale=inv", "uniform:max_stale=3"] {
            let err = format!("{:#}", from_spec(spec).unwrap_err());
            assert!(err.contains("requires async=buffered"), "{spec}: {err}");
        }
        // async=sync on a buffered preset turns the runtime off — and the
        // guard then applies to its parameters too
        let sc = from_spec("async-bursty:async=sync").unwrap();
        assert_eq!(sc.async_sched, AsyncSchedule::RoundSync);
        assert!(from_spec("async-bursty:async=sync,buffer=4").is_err());
        // bad values are rejected with the key named
        assert!(from_spec("uniform:async=eventually").is_err());
        assert!(from_spec("uniform:async=buffered,buffer=0").is_err());
        assert!(from_spec("uniform:async=buffered,inflight=0").is_err());
        assert!(from_spec("uniform:async=buffered,stale=linear").is_err());
        assert!(from_spec("uniform:async=buffered,max_stale=many").is_err());
    }

    #[test]
    fn async_presets_are_buffered() {
        let sc = from_spec("async-bursty").unwrap();
        assert!(!sc.mega);
        assert!(matches!(sc.churn, Churn::Windowed { .. }));
        assert_eq!(sc.async_sched,
                   AsyncSchedule::Buffered {
                       buffer: 6,
                       max_in_flight: 6,
                       stale: StalenessWeight::Inverse,
                       max_stale: 16,
                   });
        let sc = from_spec("megafleet-async").unwrap();
        assert!(sc.mega);
        assert_eq!(sc.clients, 1_000_000);
        assert!(sc.sample_frac <= 0.01);
        assert!(matches!(sc.async_sched,
                         AsyncSchedule::Buffered { buffer: 64,
                                                   max_in_flight: 4, .. }));
        // preset parameters accept overrides like any other key
        let sc = from_spec("megafleet-async:inflight=8,stale=const").unwrap();
        assert_eq!(sc.async_sched,
                   AsyncSchedule::Buffered {
                       buffer: 64,
                       max_in_flight: 8,
                       stale: StalenessWeight::Constant,
                       max_stale: 16,
                   });
    }
}
